"""``IngestServer`` — many concurrent producer streams over one store.

The server multiplexes named **tenant sessions** over a single
:class:`~repro.store.store.CameoStore`:

* every mutation of the shared store happens under one re-entrant lock
  (``_lock``) — pushes from N producer threads serialize into the
  store's append discipline, so any interleaving of tenants yields
  per-series blocks, catalog entries and query answers **identical** to
  serial per-tenant ingest (the file-level block order differs; nothing
  derived from it does);
* acks ride the journaled-before-ack WAL path unchanged: a
  ``session().push()`` returns once the chunk is journaled, and after a
  crash ``IngestServer(path, ..., resume=True)`` +
  ``session(resume=True)`` replays every tenant's acked pushes
  deterministically (see ``store/README.md``);
* **admission + backpressure**: at most ``max_sessions`` sessions are
  open at once — opening one more either blocks (``backpressure=
  "block"``) or raises :class:`ServerBusy` (``"reject"``);
* per-tenant ε and point quotas come from the footer-resident tenant
  catalog (:mod:`repro.server.catalog`); quota is checked *before* the
  journal write, so an over-quota push is refused, never acked;
* sessions seal small blocks (``seal_block_len``) for low-latency
  durability and the background :class:`CompactionWorker` rewrites them
  to full size on session close (``auto_compact``); the
  :class:`TierManager` moves finished series between the hot / warm /
  cold storage tiers.

``server.view(tenant)`` hands out the tenant-scoped
:class:`ServerView` query surface (reads are the plain
``DatasetView``; ingest methods route back through the server lock,
quota and admission);
``metrics_text()`` / ``metrics_app()`` expose the ``obs`` registry as a
Prometheus-style ``/metrics`` endpoint.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.api.dataset import Dataset, DatasetView, Series, StreamWriter
from repro.obs import OBS
from repro.server.catalog import DEFAULT_TENANT, TenantCatalog, tenant_sid
from repro.server.compaction import CompactionWorker
from repro.server.tiers import TierManager
from repro.store import maintenance as _maint
from repro.store import wal as _wal
from repro.store.store import DEFAULT_CACHE_BYTES, CameoStore


class ServerBusy(RuntimeError):
    """Session admission rejected (``backpressure="reject"`` and every
    slot is taken)."""


class QuotaExceeded(RuntimeError):
    """A push/write would take the tenant past its ``max_points`` quota
    (refused before the journal — never acked)."""


@dataclasses.dataclass
class ServerConfig:
    """Server-level knobs (the compression contract is a separate
    ``CameoConfig``).  ``seal_block_len`` is the per-session small-block
    length streams seal at (``None`` streams at the store-wide
    ``block_len`` and disables auto-compaction — nothing to merge);
    ``compact_target_len`` is the rewrite target (default: store
    ``block_len``)."""

    block_len: int = 4096
    seal_block_len: Optional[int] = None
    compact_target_len: Optional[int] = None
    value_codec: str = "gorilla"
    entropy: str = "auto"
    cache_bytes: int = DEFAULT_CACHE_BYTES
    store_residuals: bool = True
    stream_window: int = 4096
    queue_depth: int = 1
    wal: Optional[bool] = None
    wal_group_ms: float = _wal.DEFAULT_GROUP_MS
    wal_group_bytes: int = _wal.DEFAULT_GROUP_BYTES
    max_sessions: int = 64
    backpressure: str = "block"      # or "reject" -> ServerBusy
    auto_compact: bool = True


class ServerSession:
    """One tenant's open ingest stream (obtain via
    ``IngestServer.session``).  Wraps a :class:`StreamWriter`: pushes
    serialize under the server lock, quota is enforced before the
    journal ack, and ``close()`` releases the admission slot and queues
    the series for compaction."""

    def __init__(self, server: "IngestServer", tenant: str, series: str,
                 writer: StreamWriter, quota: Optional[int]):
        self._server = server
        self.tenant = tenant
        self.series = series
        self.sid = writer.sid
        self._w = writer
        self._quota = quota
        self.closed = False
        self._slot_released = False

    # -- introspection -------------------------------------------------------

    @property
    def resume_from(self) -> int:
        return self._w.resume_from

    @property
    def n_seen(self) -> int:
        return self._w.n_seen

    @property
    def channels(self) -> int:
        return self._w.channels

    def deviation(self) -> float:
        return self._w.deviation()

    def deviations(self) -> np.ndarray:
        return self._w.deviations()

    # -- feeding -------------------------------------------------------------

    def push(self, chunk) -> int:
        """Feed a chunk (journaled-before-ack; see ``StreamWriter.push``).
        Raises :class:`QuotaExceeded` *before* journaling when the chunk
        would take the tenant past its quota."""
        if self.closed:
            raise ValueError(f"session {self.tenant!r}/{self.series!r} "
                             "is closed")
        chunk = np.asarray(chunk)
        m = int(chunk.size)           # channel-expanded points
        srv = self._server
        with srv._lock:
            if self._quota is not None:
                used = srv._used_points.get(self.tenant, 0)
                if used + m > self._quota:
                    if OBS.enabled:
                        OBS.inc("server.quota_rejects")
                    raise QuotaExceeded(
                        f"tenant {self.tenant!r}: push of {m} points would "
                        f"exceed max_points={self._quota} (used {used})")
            wins = self._w.push(chunk)
            srv._used_points[self.tenant] = (
                srv._used_points.get(self.tenant, 0) + m)
        if OBS.enabled:
            OBS.inc("server.pushes")
            OBS.inc("server.points", m)
            labels = {"tenant": self.tenant or "default"}
            OBS.inc("server.tenant.pushes", labels=labels)
            OBS.inc("server.tenant.points", m, labels=labels)
        return wins

    def flush(self) -> None:
        with self._server._lock:
            self._w.flush()

    def close(self) -> dict:
        """Finalize the series (durable footer publish), release the
        admission slot, and queue the series for background compaction
        when the server seals small blocks.  The slot is released even
        when finalize fails — a failed close never shrinks admission
        capacity (the session stays in the table for a retry)."""
        srv = self._server
        try:
            with srv._lock:
                entry = self._w.close()
                srv._sessions.pop((self.tenant, self.series), None)
            self.closed = True
        finally:
            if not self._slot_released:
                self._slot_released = True
                srv._slots.release()
        if OBS.enabled:
            OBS.gauge("server.sessions", len(srv._sessions))
        if srv.cfg.auto_compact and srv.cfg.seal_block_len:
            srv._compactor.enqueue(self.sid)
        return entry

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if exc[0] is None and not self.closed:
            self.close()


class ServerView(DatasetView):
    """Tenant-scoped facade handed out by :meth:`IngestServer.view`.

    Reads are the plain :class:`DatasetView` surface; the ingest
    methods are overridden to route back through the server, so a view
    can never bypass admission control — ``write``/``write_batch`` run
    under the server lock with the tenant quota checked *before* the
    journal append (raising :class:`QuotaExceeded`), and ``stream``
    opens a full :class:`ServerSession` (it takes an admission slot and
    accepts the ``session`` keywords: ``channels``, ``resume``,
    ``window_len``, ``queue_depth``, ``eps``)."""

    def __init__(self, server: "IngestServer", tenant: str):
        super().__init__(server._ds,
                         "" if tenant == DEFAULT_TENANT else tenant + "/")
        self._server = server
        self._tenant = tenant

    def write(self, sid: str, x, *, eps=None) -> dict:
        return self._server.write(sid, x, tenant=self._tenant, eps=eps)

    def write_batch(self, items: Dict[str, np.ndarray]) -> Dict[str, dict]:
        return self._server.write_batch(items, tenant=self._tenant)

    def stream(self, sid: str, **kw) -> ServerSession:
        return self._server.session(sid, tenant=self._tenant, **kw)


class IngestServer:
    """See module docstring.  ``resume=True`` reopens an existing store
    (``mode="a"``), recovering from the WAL if the previous run crashed;
    sessions that were open then are resumed with
    ``session(..., resume=True)``."""

    def __init__(self, path: str, ccfg, cfg: ServerConfig = None, *,
                 resume: bool = False):
        self.cfg = cfg = cfg or ServerConfig()
        if cfg.backpressure not in ("block", "reject"):
            raise ValueError(
                f"backpressure={cfg.backpressure!r}; use 'block' or 'reject'")
        self.ccfg = ccfg
        self.store = CameoStore(
            path, "a" if resume else "w", block_len=cfg.block_len,
            value_codec=cfg.value_codec, entropy=cfg.entropy,
            cache_bytes=cfg.cache_bytes, wal=cfg.wal,
            wal_group_ms=cfg.wal_group_ms,
            wal_group_bytes=cfg.wal_group_bytes)
        self._ds = Dataset(self.store, ccfg,
                           store_residuals=cfg.store_residuals,
                           stream_window=cfg.stream_window)
        self.catalog = TenantCatalog(self.store)
        self._lock = threading.RLock()
        self.tiers = TierManager(self.store, lock=self._lock)
        self._sessions: Dict[Tuple[str, str], ServerSession] = {}
        self._slots = threading.BoundedSemaphore(int(cfg.max_sessions))
        self._used_points: Dict[str, int] = {}
        self._compactor = CompactionWorker(self)
        self._closed = False

    # -- lifecycle -----------------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self) -> None:
        """Drain compaction, publish the footer and close the store.
        Sessions still open are *not* finalized — their resume state is
        stashed in the footer, exactly like a store close mid-stream, so
        a later ``resume=True`` server continues them."""
        if self._closed:
            return
        self._compactor.stop()
        with self._lock:
            self._closed = True
            self.store.close()

    def flush(self) -> None:
        with self._lock:
            self.store.flush()

    def _require_open(self):
        if self._closed:
            raise ValueError("server is closed")

    # -- tenants -------------------------------------------------------------

    def register_tenant(self, tenant: str, *, eps: float = None,
                        max_points: int = None) -> dict:
        """Register/configure a tenant (namespace ``tenant + "/"``).
        Registration is control-plane: the footer is published (fsynced)
        before this returns, so a registered tenant survives any crash —
        its sessions' crash images replay into a catalog that knows it."""
        self._require_open()
        with self._lock:
            cfg = self.catalog.register(tenant, eps=eps,
                                        max_points=max_points)
            self.store.flush()
            return cfg

    def _tenant_ccfg(self, tenant: str, eps=None):
        tcfg = self.catalog.config(tenant) if tenant != DEFAULT_TENANT else {}
        e = eps if eps is not None else tcfg.get("eps")
        ccfg = self.ccfg
        if e is not None:
            ccfg = dataclasses.replace(ccfg, eps=float(e))
        return ccfg, tcfg.get("max_points")

    def _check_quota(self, tenant: str, quota: Optional[int], m: int):
        """Admit ``m`` channel-expanded points against a tenant quota
        (caller holds the lock); bumps the usage tally on success."""
        used = self._used_points.setdefault(
            tenant, self.catalog.usage(tenant)["points"]
            if self.catalog.is_registered(tenant) else 0)
        if quota is not None and used + m > quota:
            if OBS.enabled:
                OBS.inc("server.quota_rejects")
            raise QuotaExceeded(
                f"tenant {tenant!r}: {m} points would exceed "
                f"max_points={quota} (used {used})")
        self._used_points[tenant] = used + m

    # -- sessions ------------------------------------------------------------

    def session(self, series: str, *, tenant: str = DEFAULT_TENANT,
                channels: int = 1, resume: bool = False,
                window_len: int = None, queue_depth: int = None,
                eps: float = None) -> ServerSession:
        """Open (or ``resume``) one tenant's ingest stream.

        Admission: a session takes one of ``max_sessions`` slots until
        closed — the call blocks for a free slot, or raises
        :class:`ServerBusy` under ``backpressure="reject"``.  ``eps``
        overrides both the server default and the tenant's configured ε
        for this stream.
        """
        self._require_open()
        if tenant != DEFAULT_TENANT and not self.catalog.is_registered(
                tenant):
            raise KeyError(f"unknown tenant {tenant!r}; call "
                           "register_tenant first")
        if not self._slots.acquire(blocking=self.cfg.backpressure == "block"):
            if OBS.enabled:
                OBS.inc("server.rejects")
            raise ServerBusy(
                f"all {self.cfg.max_sessions} session slots are taken")
        try:
            key = (tenant, series)
            with self._lock:
                if key in self._sessions:
                    raise ValueError(
                        f"tenant {tenant!r} already has an open session "
                        f"for series {series!r}")
                ccfg, quota = self._tenant_ccfg(tenant, eps)
                # seed the quota tally before any push can race it
                self._check_quota(tenant, None, 0)
                writer = StreamWriter(
                    self.store, ccfg, tenant_sid(tenant, series),
                    window_len=window_len or self.cfg.stream_window,
                    with_resid=self.cfg.store_residuals,
                    channels=channels, resume=resume,
                    queue_depth=queue_depth or self.cfg.queue_depth,
                    block_len=self.cfg.seal_block_len)
                sess = ServerSession(self, tenant, series, writer, quota)
                self._sessions[key] = sess
            if OBS.enabled:
                OBS.gauge("server.sessions", len(self._sessions))
            return sess
        except BaseException:
            self._slots.release()
            raise

    def sessions(self) -> Dict[Tuple[str, str], ServerSession]:
        with self._lock:
            return dict(self._sessions)

    # -- one-shot ingest (the deprecated service shim routes here) ----------

    def write(self, series: str, x, *, tenant: str = DEFAULT_TENANT,
              eps=None) -> dict:
        self._require_open()
        x = np.asarray(x)
        with self._lock:
            ccfg, quota = self._tenant_ccfg(tenant, None)
            self._check_quota(tenant, quota, int(x.size))
            try:
                saved, self._ds.cfg = self._ds.cfg, ccfg
                return self._ds.write(tenant_sid(tenant, series), x, eps=eps)
            except BaseException:
                self._used_points[tenant] -= int(x.size)
                raise
            finally:
                self._ds.cfg = saved

    def write_batch(self, items: Dict[str, np.ndarray], *,
                    tenant: str = DEFAULT_TENANT) -> Dict[str, dict]:
        self._require_open()
        items = {s: np.asarray(x) for s, x in items.items()}
        m = sum(int(x.size) for x in items.values())
        with self._lock:
            ccfg, quota = self._tenant_ccfg(tenant, None)
            self._check_quota(tenant, quota, m)
            try:
                saved, self._ds.cfg = self._ds.cfg, ccfg
                out = self._ds.write_batch(
                    {tenant_sid(tenant, s): x for s, x in items.items()})
            except BaseException:
                self._used_points[tenant] -= m
                raise
            finally:
                self._ds.cfg = saved
        k = 0 if tenant == DEFAULT_TENANT else len(tenant) + 1
        return {sid[k:]: e for sid, e in out.items()}

    # -- reads ---------------------------------------------------------------

    def view(self, tenant: str = DEFAULT_TENANT) -> ServerView:
        """The tenant-scoped query/ingest facade.  Ingest methods route
        back through the server (lock + quota + admission) — see
        :class:`ServerView`."""
        if tenant != DEFAULT_TENANT and not self.catalog.is_registered(
                tenant):
            raise KeyError(f"unknown tenant {tenant!r}")
        return ServerView(self, tenant)

    def series(self, series: str, *,
               tenant: str = DEFAULT_TENANT) -> Series:
        return self._ds.series(tenant_sid(tenant, series))

    # -- maintenance ---------------------------------------------------------

    def compact(self, series: str, *, tenant: str = DEFAULT_TENANT) -> dict:
        """Synchronously compact one series (see
        ``store/maintenance.compact_series``)."""
        self._require_open()
        with self._lock:
            return _maint.compact_series(
                self.store, tenant_sid(tenant, series),
                target_len=self.cfg.compact_target_len)

    def drain_compaction(self) -> None:
        """Block until the background compaction queue is empty."""
        self._compactor.drain()

    # -- observability -------------------------------------------------------

    def metrics_text(self, prefix: str = "cameo") -> str:
        """The ``obs`` registry as Prometheus-style exposition text."""
        return OBS.exposition(prefix)

    def metrics_app(self):
        """A WSGI callable serving :meth:`metrics_text` at ``/metrics``
        (mount it under any WSGI server, e.g. ``wsgiref.simple_server``);
        other paths return 404."""
        def app(environ, start_response):
            if environ.get("PATH_INFO", "/") not in ("/metrics",
                                                     "/metrics/"):
                start_response("404 Not Found",
                               [("Content-Type",
                                 "text/plain; charset=utf-8")])
                return [b"not found\n"]
            body = self.metrics_text().encode()
            start_response("200 OK", [
                ("Content-Type",
                 "text/plain; version=0.0.4; charset=utf-8"),
                ("Content-Length", str(len(body)))])
            return [body]
        return app

    def stats(self, *, deep: bool = False) -> dict:
        """Unified dataset stats + server-level keys: open ``sessions``,
        per-``tenant`` usage, storage ``tiers``, and ``compaction``
        progress."""
        out = self._ds.stats(deep=deep)
        with self._lock:
            out["sessions"] = len(self._sessions)
            out["tenants"] = {
                t: self.catalog.usage(t)
                for t in [DEFAULT_TENANT] + self.catalog.tenants()}
        out["tiers"] = self.store.tier_stats()
        out["compaction"] = dict(compacted=self._compactor.compacted,
                                 merged_runs=self._compactor.merged_runs,
                                 last_error=self._compactor.last_error)
        return out
