"""``repro.server`` — multi-tenant ingest server over one CAMEO store.

>>> from repro.server import IngestServer, ServerConfig
>>> srv = IngestServer("fleet.cameo", CameoConfig(eps=1e-3, lags=24),
...                    ServerConfig(seal_block_len=512, max_sessions=8))
>>> srv.register_tenant("acme", eps=5e-3, max_points=10_000_000)
>>> with srv.session("turbine-1", tenant="acme") as sess:
...     sess.push(chunk)                       # journaled-before-ack
>>> srv.drain_compaction()                     # small blocks -> full size
>>> srv.view("acme").series("turbine-1").mean()
>>> srv.close()

Layers (each documented in its module):

* :mod:`.ingest_server` — session multiplexing, admission/backpressure,
  quotas, the WSGI ``/metrics`` hook;
* :mod:`.catalog` — tenant namespacing + config in the store footer;
* :mod:`.compaction` — background rewrite of small streamed blocks;
* :mod:`.tiers` — hot (pinned LRU) / warm (mmap) / cold (entropy-wrapped)
  block storage.
"""
from repro.server.catalog import DEFAULT_TENANT, TenantCatalog, tenant_sid
from repro.server.compaction import CompactionWorker
from repro.server.ingest_server import (
    IngestServer,
    QuotaExceeded,
    ServerBusy,
    ServerConfig,
    ServerSession,
    ServerView,
)
from repro.server.tiers import TierManager

__all__ = [
    "IngestServer", "ServerConfig", "ServerSession", "ServerView",
    "ServerBusy", "QuotaExceeded", "TenantCatalog", "TierManager",
    "CompactionWorker", "DEFAULT_TENANT", "tenant_sid",
]
