"""Background compaction worker for the ingest server.

Server stream sessions seal *small* blocks (``seal_block_len``) so a
tenant's freshly pushed points become durable and queryable with low
latency; the price is per-block header overhead and more blocks per
window.  This worker pays that debt back: when a session closes, its
sid is queued, and a daemon thread rewrites runs of small blocks into
full-size blocks via ``store/maintenance.compact_series`` — under the
server's store lock, so compaction interleaves safely with live pushes
to *other* sessions (the store's append discipline means the rewrite
never touches bytes another session could be writing).

The worker is deliberately simple and deterministic:

* one thread, one FIFO of sids (duplicates collapse);
* every rewrite is all-or-nothing via the two-phase footer publish (a
  crash mid-compaction rolls back to the pre-compaction footer — no
  torn state, because old blocks are superseded, never overwritten);
* ``drain()`` blocks until the queue is empty and the thread idle, so
  tests (and ``IngestServer.close``) can sequence deterministically;
* a failed rewrite records the error (``last_error``) and counts in
  ``obs`` rather than killing the thread.
"""
from __future__ import annotations

import collections
import threading

from repro.obs import OBS
from repro.store import maintenance as _maint


class CompactionWorker:
    """FIFO compaction queue + daemon thread (see module doc)."""

    def __init__(self, server):
        self._server = server
        self._q = collections.deque()
        self._queued = set()
        self._cv = threading.Condition()
        self._stop = False
        self._busy = False
        self._thread = None
        self.compacted = 0
        self.merged_runs = 0
        self.last_error = None

    def enqueue(self, sid: str) -> None:
        with self._cv:
            if self._stop:
                return
            if sid not in self._queued:
                self._q.append(sid)
                self._queued.add(sid)
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="cameo-compaction", daemon=True)
                self._thread.start()
            self._cv.notify_all()

    def drain(self) -> None:
        """Block until every queued sid has been processed."""
        with self._cv:
            self._cv.wait_for(lambda: not self._q and not self._busy)

    def stop(self) -> None:
        """Drain, then stop the thread (idempotent)."""
        with self._cv:
            self._cv.wait_for(lambda: not self._q and not self._busy)
            self._stop = True
            self._cv.notify_all()
            t = self._thread
        if t is not None:
            t.join(timeout=10.0)

    def _run(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._q or self._stop)
                if self._stop and not self._q:
                    return
                sid = self._q.popleft()
                self._queued.discard(sid)
                self._busy = True
            try:
                self._compact(sid)
            except Exception as e:   # noqa: BLE001 — worker must survive
                self.last_error = f"{sid}: {e}"
                if OBS.enabled:
                    OBS.inc("server.compaction.errors")
            finally:
                with self._cv:
                    self._busy = False
                    self._cv.notify_all()

    def _compact(self, sid: str) -> None:
        srv = self._server
        with srv._lock:
            if sid not in srv.store:
                return                       # superseded before we ran
            report = _maint.compact_series(
                srv.store, sid, target_len=srv.cfg.compact_target_len)
        if report["runs"]:
            self.compacted += 1
            self.merged_runs += report["runs"]
