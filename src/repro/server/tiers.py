"""Tier manager — the serving-side handle on the store's storage tiers.

Three tiers, cheapest first:

* **hot** — the store's decoded-block LRU (``BlockCache``).  A hit
  serves decoded kept points (and, once materialized, the jitted
  reconstruction) with no file access.  ``pin`` exempts a window's
  blocks from eviction; ``prefetch`` warms them ahead of a query.
* **warm** — plain block bodies on disk, served via mmap page-cache
  slices (read-only opens) or coalesced preads.
* **cold** — entropy-wrapped block bodies (``store/maintenance.py``
  ``rewrite_cold``): smaller at rest, one extra unwrap per fetch, and
  byte-identical on every parse and query answer.

Demotion/promotion rewrites are append-and-republish (never in-place),
so they inherit the store's crash-atomicity; see the maintenance module
for the mechanics.  ``stats()`` surfaces the per-tier hit/byte counters
(also exported as ``store.cache.*`` / ``store.tier.*`` in ``obs``).
"""
from __future__ import annotations

import threading
from typing import List

from repro.store import maintenance as _maint


class TierManager:
    """Pin/prefetch over the hot tier + demote/promote between warm and
    cold, for one store.  Every operation runs under ``lock`` — the
    owning :class:`~repro.server.IngestServer` passes its ``_lock`` so
    tier rewrites serialize against live session pushes (standalone use
    gets a private lock)."""

    def __init__(self, store, lock=None):
        self._store = store
        self._lock = lock if lock is not None else threading.RLock()

    # -- hot tier ------------------------------------------------------------

    def prefetch(self, sid: str, a: int = 0, b: int = None) -> List[int]:
        """Decode the blocks overlapping ``[a, b)`` into the LRU."""
        with self._lock:
            return self._store.prefetch(sid, a, b)

    def pin(self, sid: str, a: int = 0, b: int = None) -> List[int]:
        """Prefetch + pin a window's blocks hot (evict-exempt); returns
        the pinned block indices.  Pins survive until ``unpin``."""
        with self._lock:
            bis = self._store.prefetch(sid, a, b)
            for bi in bis:
                self._store._cache.pin((sid, bi))
            return bis

    def unpin(self, sid: str, a: int = 0, b: int = None) -> None:
        with self._lock:
            entry = self._store._series[sid]
            b = entry["n"] if b is None else b
            for bi in self._store._overlapping(sid, int(a), int(b)):
                self._store._cache.unpin((sid, bi))

    # -- warm <-> cold -------------------------------------------------------

    def demote_cold(self, sid: str, *, codec: str = "auto") -> dict:
        """Entropy-wrap one series' block bodies (see ``rewrite_cold``)."""
        with self._lock:
            return _maint.rewrite_cold(self._store, sid, codec=codec)

    def promote_warm(self, sid: str) -> dict:
        """Unwrap one series' bodies back to the warm tier."""
        with self._lock:
            return _maint.promote_warm(self._store, sid)

    # -- accounting ----------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return self._store.tier_stats()
