"""Tenant catalog — the namespacing layer over the store footer.

Tenancy is a *naming* convention plus a small config table, both living
in the store footer so they share the store's durability story (two-
phase footer publish, WAL checkpoint rollback):

* a named tenant ``t`` owns the sid namespace ``"t/"`` — its series
  ``s`` is stored under the physical sid ``"t/s"``;
* the **default tenant** (the empty name) owns every sid that does not
  belong to a registered tenant's namespace, so legacy single-tenant
  stores (and the deprecated ``TimeSeriesService`` path) are exactly the
  default tenant's view and stay byte-identical;
* per-tenant config (ε override, point quota) lives in the footer's
  optional ``"tenants"`` key (``CameoStore._tenants``), written only
  when at least one tenant is registered — stores that never see the
  server layer keep byte-identical footers.

Tenant names must not contain ``"/"`` (it is the namespace separator)
and must be non-empty; series names are unrestricted — a ``"/"`` inside
a *series* name is legal but keeps the sid inside its tenant's
namespace only if the tenant is registered first (the default tenant's
``series_of`` excludes every registered prefix).
"""
from __future__ import annotations

from typing import Dict, List

DEFAULT_TENANT = ""


def tenant_sid(tenant: str, series: str) -> str:
    """Physical store sid of one tenant's series."""
    return series if tenant == DEFAULT_TENANT else f"{tenant}/{series}"


class TenantCatalog:
    """Registration + lookup over ``store._tenants`` (see module doc)."""

    def __init__(self, store):
        self._store = store

    def register(self, tenant: str, *, eps: float = None,
                 max_points: int = None) -> dict:
        """Register (or re-configure) a tenant.  ``eps`` overrides the
        server's compression budget for this tenant's streams;
        ``max_points`` caps its total ingested points (channel-expanded),
        enforced *before* a push is journaled/acked.  Re-registering
        merges: an omitted kwarg keeps its configured value, so updating
        ``eps`` never silently drops an existing quota."""
        if tenant == DEFAULT_TENANT:
            raise ValueError("the default tenant needs no registration")
        if "/" in tenant:
            raise ValueError(f"tenant name {tenant!r} must not contain '/'")
        cfg = dict(self._store._tenants.get(tenant, {}))
        if eps is not None:
            cfg["eps"] = float(eps)
        if max_points is not None:
            cfg["max_points"] = int(max_points)
        self._store._tenants[tenant] = cfg
        return cfg

    def config(self, tenant: str) -> dict:
        if tenant == DEFAULT_TENANT:
            return {}
        return dict(self._store._tenants[tenant])

    def tenants(self) -> List[str]:
        """Registered tenant names (the default tenant is implicit)."""
        return sorted(self._store._tenants)

    def is_registered(self, tenant: str) -> bool:
        return tenant == DEFAULT_TENANT or tenant in self._store._tenants

    def series_of(self, tenant: str) -> List[str]:
        """Series names owned by one tenant (namespace prefix stripped).
        The default tenant owns everything outside every registered
        namespace."""
        sids = self._store.series_ids()
        if tenant == DEFAULT_TENANT:
            prefixes = tuple(t + "/" for t in self._store._tenants)
            return [s for s in sids
                    if not prefixes or not s.startswith(prefixes)]
        if tenant not in self._store._tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        pre = tenant + "/"
        return [s[len(pre):] for s in sids if s.startswith(pre)]

    def usage(self, tenant: str) -> Dict[str, int]:
        """Points / kept / stored bytes over one tenant's series
        (channel-expanded, streaming series counting their committed
        prefix — the same conventions as ``ingest_totals``)."""
        out = dict(series=0, points=0, n_kept=0, stored_nbytes=0)
        for s in self.series_of(tenant):
            e = self._store.series_meta(tenant_sid(tenant, s))
            C = int(e.get("channels", 1))
            out["series"] += 1
            out["points"] += e["n"] * C
            out["n_kept"] += e["n_kept"] * C
            out["stored_nbytes"] += e["stored_nbytes"]
        return out
