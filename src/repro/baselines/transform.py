"""Domain-transform baseline: FFT top-m coefficient truncation (paper §5.1).

``fft_compress(x, m)`` keeps the ``m`` largest-magnitude rFFT coefficients
(DC always kept), zeroes the rest, and reconstructs by inverse transform.
Storage: 2 values per kept complex coefficient + 1 for its index.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def fft_compress(x, m: int):
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    spec = np.fft.rfft(x)
    m = int(max(1, min(m, spec.shape[0])))
    mag = np.abs(spec)
    mag[0] = np.inf  # always keep DC
    keep = np.argsort(mag)[::-1][:m]
    trunc = np.zeros_like(spec)
    trunc[keep] = spec[keep]
    recon = np.fft.irfft(trunc, n=n)
    return jnp.asarray(recon), 3 * m
