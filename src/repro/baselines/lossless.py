"""Lossless XOR-based compressors (Table 2): Gorilla and Chimp bit costs.

The public functions count exact bitstream sizes (bits-per-value) — that is
all the paper's Table 2 uses.  Since the CameoStore subsystem landed, the
actual *encoders* live in ``store/codec.py``; the counters here delegate to
the shared vectorized branch plans (``xor_parts`` + ``_gorilla_plan`` /
``_chimp_plan``), so counted bits equal emitted bits by construction.  The
original per-value Python loops are kept as ``*_loop`` oracle forms: they
iterate one value at a time (the O(n) hot spot the vectorized paths
replace) and pin the published encodings in their most literal shape for
the parity tests.

Encodings follow the published schemes; Chimp uses the plain (non-128)
variant with the paper's rounded leading-zero buckets.
"""
from __future__ import annotations

import numpy as np

from repro.store.codec import chimp_stream_bits, gorilla_stream_bits

_CHIMP_LZ_BUCKETS = np.array([0, 8, 12, 16, 18, 20, 22, 24])


def gorilla_bits_per_value(x) -> float:
    """Gorilla (Pelkonen et al. 2015) value encoding, 64-bit floats.

    Vectorized fast path (shared with ``store/codec.py``'s encoder);
    bit-identical to :func:`gorilla_bits_per_value_loop`.
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if n == 0:
        return 0.0
    return gorilla_stream_bits(x) / n


def chimp_bits_per_value(x) -> float:
    """Chimp (Liakos et al. 2022), plain variant with LZ bucket rounding.

    Vectorized fast path (shared with ``store/codec.py``'s encoder);
    bit-identical to :func:`chimp_bits_per_value_loop`.
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if n == 0:
        return 0.0
    return chimp_stream_bits(x) / n


# ---------------------------------------------------------------------------
# literal per-value loop forms — parity oracles for the vectorized paths
# ---------------------------------------------------------------------------

def _bit_parts(x: np.ndarray):
    bits = np.ascontiguousarray(np.asarray(x, np.float64)).view(np.uint64)
    xor = bits[1:] ^ bits[:-1]
    xor_py = [int(v) for v in xor]
    lz = np.array([64 - v.bit_length() if v else 64 for v in xor_py])
    tz = np.array([((v & -v).bit_length() - 1) if v else 64 for v in xor_py])
    return xor_py, lz, tz


def gorilla_bits_per_value_loop(x) -> float:
    """Reference form of :func:`gorilla_bits_per_value` (per-value loop)."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if n == 0:
        return 0.0
    xor, lz, tz = _bit_parts(x)
    total = 64  # first value verbatim
    plz, ptz = -1, -1  # previous meaningful-bit window
    for i in range(n - 1):
        if xor[i] == 0:
            total += 1
            continue
        li = min(int(lz[i]), 31)  # gorilla caps LZ at 31 (5-bit field)
        ti = int(tz[i])
        if plz >= 0 and li >= plz and ti >= ptz:
            total += 2 + (64 - plz - ptz)
        else:
            sig = 64 - li - ti
            total += 2 + 5 + 6 + sig
            plz, ptz = li, ti
    return total / n


def chimp_bits_per_value_loop(x) -> float:
    """Reference form of :func:`chimp_bits_per_value` (per-value loop)."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if n == 0:
        return 0.0
    xor, lz, tz = _bit_parts(x)
    total = 64
    prev_lz_bucket = -1
    for i in range(n - 1):
        if xor[i] == 0:
            total += 2
            prev_lz_bucket = -1
            continue
        lzb = int(_CHIMP_LZ_BUCKETS[np.searchsorted(
            _CHIMP_LZ_BUCKETS, min(int(lz[i]), 24), side="right") - 1])
        ti = int(tz[i])
        if ti > 6:
            # '01': 3-bit LZ bucket + 6-bit significant length + center bits
            center = 64 - lzb - ti
            total += 2 + 3 + 6 + max(center, 0)
            prev_lz_bucket = -1
        elif lzb == prev_lz_bucket:
            total += 2 + (64 - lzb)
        else:
            total += 2 + 3 + (64 - lzb)
            prev_lz_bucket = lzb
    return total / n
