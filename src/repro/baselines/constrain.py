"""Trial-and-error ACF-constraint adapter for parameterized lossy baselines.

The paper (§5.1): "Since enforcing the ACF constraint while compressing is
not straightforward [for PMC/SWING/SP/FFT], we perform a trial-and-error
exploration of the parameters of these methods while recording the ACF
deviation."  This module automates that exploration with a bracketing +
bisection search over the method's error parameter, maximizing compression
subject to the exact ACF deviation bound.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.acf import acf, aggregate_series
from repro.core import measures
from repro.core.cameo import CameoConfig


def acf_deviation(x, recon, cfg: CameoConfig) -> float:
    y0 = aggregate_series(jnp.asarray(x), cfg.kappa)
    y1 = aggregate_series(jnp.asarray(recon), cfg.kappa)
    mfn = measures.get_measure(cfg.measure)
    if cfg.stat == "pacf":
        from repro.core.acf import pacf_from_acf
        s0 = pacf_from_acf(acf(y0, cfg.lags))
        s1 = pacf_from_acf(acf(y1, cfg.lags))
    else:
        s0 = acf(y0, cfg.lags)
        s1 = acf(y1, cfg.lags)
    return float(mfn(s1, s0))


def acf_constrained_search(
    x,
    cfg: CameoConfig,
    compress_fn: Callable,
    *,
    param_is_int: bool = False,
    lo: float = None,
    hi: float = None,
    iters: int = 12,
) -> Tuple[jnp.ndarray, int, float, float]:
    """Find the most aggressive parameter for ``compress_fn(x, p)`` whose
    reconstruction keeps the ACF deviation <= cfg.eps.

    For error-bound methods (PMC/SWING/SP) larger p => more compression;
    for FFT the parameter is the kept-coefficient count m where *smaller*
    m => more compression (pass ``param_is_int=True``).

    Returns (recon, stored_values, achieved_dev, param).
    """
    x = np.asarray(x, np.float64)
    if cfg.kappa > 1:
        n = (x.shape[0] // cfg.kappa) * cfg.kappa
        x = x[:n]
    rng = float(np.max(x) - np.min(x))

    if param_is_int:
        # FFT-style: bisect kept-coefficient count in [1, n//2]
        lo_m, hi_m = 1, x.shape[0] // 2 + 1
        best = None
        while lo_m < hi_m:
            mid = (lo_m + hi_m) // 2
            recon, stored = compress_fn(x, mid)
            dev = acf_deviation(x, recon, cfg)
            if dev <= cfg.eps:
                best = (recon, stored, dev, float(mid))
                hi_m = mid
            else:
                lo_m = mid + 1
        if best is None:
            recon, stored = compress_fn(x, x.shape[0] // 2 + 1)
            best = (recon, stored, acf_deviation(x, recon, cfg),
                    float(x.shape[0] // 2 + 1))
        return best

    lo = 1e-8 * rng if lo is None else lo
    hi = 2.0 * rng if hi is None else hi
    # bracket: grow hi while still feasible is unnecessary (larger err is
    # always more compression); bisect the largest feasible err.
    best = None
    for _ in range(iters):
        mid = float(np.sqrt(lo * hi))  # log-space bisection
        recon, stored = compress_fn(x, mid)
        dev = acf_deviation(x, recon, cfg)
        if dev <= cfg.eps:
            best = (recon, stored, dev, mid)
            lo = mid
        else:
            hi = mid
    if best is None:
        recon, stored = compress_fn(x, lo)
        best = (recon, stored, acf_deviation(x, recon, cfg), lo)
    return best
