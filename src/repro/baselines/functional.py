"""Functional-approximation lossy baselines: PMC, SWING, Sim-Piece.

Each exposes ``<name>_compress(x, err) -> (recon, stored_values)`` where
``err`` is the per-value error bound and ``stored_values`` is the number of
64-bit values the compressed form needs (the paper's accounting).  The ACF
constraint is enforced externally by trial-and-error over ``err``
(``baselines.constrain``), exactly as the paper does for these methods.

Scans run compiled (lax.scan); light segment post-processing is numpy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# PMC-Mean (Lazaridis & Mehrotra): constant segments, max error <= err
# ---------------------------------------------------------------------------

def pmc_compress(x, err: float):
    x = jnp.asarray(x)
    n = x.shape[0]

    def step(carry, xi):
        lo, hi = carry
        nlo = jnp.minimum(lo, xi)
        nhi = jnp.maximum(hi, xi)
        brk = (nhi - nlo) > 2.0 * err
        lo2 = jnp.where(brk, xi, nlo)
        hi2 = jnp.where(brk, xi, nhi)
        return (lo2, hi2), brk

    inf = jnp.asarray(jnp.inf, x.dtype)
    (_, _), brks = jax.lax.scan(step, (inf, -inf), x)
    seg_id = jnp.cumsum(brks.astype(jnp.int32))
    nseg = int(seg_id[-1]) + 1
    # PMC emits the segment midrange: |x - (min+max)/2| <= err is exactly the
    # invariant the (max - min) <= 2*err check maintains.
    lo = jax.ops.segment_min(x, seg_id, num_segments=nseg)
    hi = jax.ops.segment_max(x, seg_id, num_segments=nseg)
    mid = 0.5 * (lo + hi)
    recon = mid[seg_id]
    # storage: (value, run length) per segment
    return recon, 2 * nseg


# ---------------------------------------------------------------------------
# SWING filter (Elmeleegy et al.): connected linear segments via slope cones
# ---------------------------------------------------------------------------

def swing_compress(x, err: float):
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    xj = jnp.asarray(x)

    def step(carry, inp):
        t0, x0, u, l, _ = carry
        t, xi = inp
        dt_ = jnp.maximum(t - t0, 1.0)
        s_hi = (xi + err - x0) / dt_
        s_lo = (xi - err - x0) / dt_
        nu = jnp.minimum(u, s_hi)
        nl = jnp.maximum(l, s_lo)
        fresh = t0 == t            # first point of a fresh segment
        brk = (~fresh) & (nl > nu)
        # on break: new segment anchored at the previous approximation point
        anchor_x = x0 + 0.5 * (u + l) * (t - 1.0 - t0)
        t0n = jnp.where(brk, t - 1.0, t0)
        x0n = jnp.where(brk, anchor_x, x0)
        dt2 = jnp.maximum(t - t0n, 1.0)
        un = jnp.where(brk, (xi + err - x0n) / dt2, nu)
        ln = jnp.where(brk, (xi - err - x0n) / dt2, nl)
        out = (brk, t0n, x0n, un, ln)
        return (t0n, x0n, un, ln, brk), out

    t_arr = jnp.arange(n, dtype=jnp.float64)
    init = (jnp.asarray(0.0), xj[0], jnp.asarray(jnp.inf),
            jnp.asarray(-jnp.inf), jnp.asarray(False))
    _, (brks, t0s, x0s, us, ls) = jax.lax.scan(step, init, (t_arr, xj))

    brks = np.asarray(brks)
    seg_id = np.cumsum(brks.astype(np.int64))
    nseg = int(seg_id[-1]) + 1
    # parameters at each segment's LAST point
    last_idx = np.searchsorted(seg_id, np.arange(nseg), side="right") - 1
    t0f = np.asarray(t0s)[last_idx]
    x0f = np.asarray(x0s)[last_idx]
    slope = 0.5 * (np.asarray(us)[last_idx] + np.asarray(ls)[last_idx])
    slope = np.where(np.isfinite(slope), slope, 0.0)
    t = np.arange(n, dtype=np.float64)
    recon = x0f[seg_id] + slope[seg_id] * (t - t0f[seg_id])
    # storage: swing stores one (value) per segment + final point (connected)
    return jnp.asarray(recon), 2 * nseg


# ---------------------------------------------------------------------------
# Sim-Piece (Kitsios et al. 2023): PLA with quantized intercepts, grouped
# ---------------------------------------------------------------------------

def simpiece_compress(x, err: float):
    """Simplified Sim-Piece: greedy maximal segments whose intercept is
    quantized to a multiple of ``err``; segments grouped by intercept with
    overlapping slope intervals merged (the paper's storage trick).

    Storage model: per intercept group, 1 value for the intercept; per merged
    slope-interval, 1 value for the representative slope; per segment, 1
    value for its start offset.
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if err <= 0:
        return jnp.asarray(x), 2 * n
    xq = np.floor(x / err) * err + err / 2.0   # quantized intercepts

    segs = []  # (t0, b, lo_slope, hi_slope, end)
    t0, b = 0, xq[0]
    lo, hi = -np.inf, np.inf
    for t in range(1, n):
        dt_ = t - t0
        s_hi = (x[t] + err - b) / dt_
        s_lo = (x[t] - err - b) / dt_
        nlo, nhi = max(lo, s_lo), min(hi, s_hi)
        if nlo > nhi:
            segs.append((t0, b, lo, hi, t - 1))
            t0, b = t, xq[t]
            lo, hi = -np.inf, np.inf
        else:
            lo, hi = nlo, nhi
    segs.append((t0, b, lo, hi, n - 1))

    # group by intercept; merge segments whose slope intervals INTERSECT
    # (the shared slope must lie inside every member's interval, else the
    # per-point error bound breaks)
    groups: dict = {}
    for (t0, b, lo, hi, end) in segs:
        groups.setdefault(b, []).append((lo, hi, t0, end))
    stored = 0
    recon = np.empty(n)
    for b, items in groups.items():
        stored += 1  # intercept
        items.sort(key=lambda it: it[0])  # -inf (single-point) first
        merged: list = []  # (isect_lo, isect_hi, members)
        for lo, hi, t0, end in items:
            if merged:
                m_lo, m_hi, members = merged[-1]
                i_lo, i_hi = max(m_lo, lo), min(m_hi, hi)
                if i_lo <= i_hi:
                    merged[-1] = (i_lo, i_hi, members + [(t0, end)])
                    continue
            merged.append((lo, hi, [(t0, end)]))
        for m_lo, m_hi, members in merged:
            stored += 1  # representative slope
            if np.isfinite(m_lo) and np.isfinite(m_hi):
                s = 0.5 * (m_lo + m_hi)
            elif np.isfinite(m_lo):
                s = m_lo
            elif np.isfinite(m_hi):
                s = m_hi
            else:
                s = 0.0
            for (t0, end) in members:
                stored += 1  # segment start
                tt = np.arange(t0, end + 1)
                recon[t0:end + 1] = b + s * (tt - t0)
    return jnp.asarray(recon), stored
