"""Line-simplification baselines adapted to the ACF constraint (paper §5.1).

The engine mirrors CAMEO's batched-rounds loop, but candidates are ranked by
*geometric* criteria instead of ACF impact.  Every accepted round is still
validated with CAMEO's exact incremental aggregate update, so each baseline
provides the same hard guarantee ``D(ACF(X'), ACF(X)) <= eps`` — this is the
paper's "we adapted them to support the constraint on the ACF".

Ranks (lower = removed first):

* ``vw_rank``     — Visvalingam–Whyatt triangle area [90].
* ``tp_rank_s``   — Turning Points, Sum-of-Absolute-Values importance [83];
                    non-turning points rank at -inf (the TP initial phase
                    that removes all non-TPs first).
* ``tp_rank_m``   — Turning Points, mean-absolute-error importance.
* ``pip_rank_v``  — Perceptual Important Points, vertical distance [33]
                    (bottom-up removal order = reverse PIP insertion).
* ``pip_rank_e``  — PIP, euclidean (perpendicular) distance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acf import acf_from_aggregates, aggregate_series, extract_aggregates
from repro.core.cameo import (
    CameoConfig,
    CompressResult,
    _independent_set,
    _measure_fn,
    _reconstruct,
    _stat_transform,
    _x_to_y_delta,
)
from repro.core.aggregates import alive_neighbors, apply_delta_dense, interpolate_at


# ---------------------------------------------------------------------------
# geometric ranking functions: (xr, alive, prev, nxt) -> [n] scores
# ---------------------------------------------------------------------------

def _neighbor_vals(xr, alive):
    n = xr.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive)
    p = jnp.clip(prev, 0, n - 1)
    q = jnp.clip(nxt, 0, n - 1)
    return idx, prev, nxt, xr[p], xr[q]


def vw_rank(xr, alive):
    """Triangle area over (prev, i, next) — the VW criterion."""
    n = xr.shape[0]
    idx, prev, nxt, xp, xq = _neighbor_vals(xr, alive)
    dt = xr.dtype
    base = (nxt - prev).astype(dt)
    # 2*area of triangle (prev, xp) (i, x_i) (next, xq)
    area2 = jnp.abs(base * (xr - xp) - (idx - prev).astype(dt) * (xq - xp))
    return 0.5 * area2


def _is_turning_point(xr, alive):
    """Direction change w.r.t. alive neighbors."""
    n = xr.shape[0]
    idx, prev, nxt, xp, xq = _neighbor_vals(xr, alive)
    dl = xr - xp
    dr = xq - xr
    return (dl * dr) < 0.0


def tp_rank_s(xr, alive):
    """TP importance: sum of absolute neighbor deltas; non-TPs first."""
    idx, prev, nxt, xp, xq = _neighbor_vals(xr, alive)
    imp = jnp.abs(xr - xp) + jnp.abs(xq - xr)
    tp = _is_turning_point(xr, alive)
    # non-turning points are removed first (the TP initial phase)
    return jnp.where(tp, imp, -jnp.ones_like(imp))


def tp_rank_m(xr, alive):
    """TP importance: MAE the removal would introduce; non-TPs first."""
    n = xr.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive)
    interp = interpolate_at(xr, prev, nxt, idx)
    imp = jnp.abs(interp - xr)
    tp = _is_turning_point(xr, alive)
    return jnp.where(tp, imp, -jnp.ones_like(imp))


def pip_rank_v(xr, alive):
    """Vertical distance to the alive-neighbor chord (PIPv)."""
    n = xr.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive)
    interp = interpolate_at(xr, prev, nxt, idx)
    return jnp.abs(interp - xr)


def pip_rank_e(xr, alive):
    """Perpendicular (euclidean) distance to the alive-neighbor chord."""
    idx, prev, nxt, xp, xq = _neighbor_vals(xr, alive)
    dt = xr.dtype
    dxx = (nxt - prev).astype(dt)
    dyy = xq - xp
    num = jnp.abs(dyy * (idx - prev).astype(dt) - dxx * (xr - xp))
    den = jnp.sqrt(dxx * dxx + dyy * dyy)
    return num / jnp.maximum(den, 1e-12)


# ---------------------------------------------------------------------------
# removal engine (rank-then-validate, exact ACF constraint)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "rank_fn"))
def constrained_removal(x: jax.Array, cfg: CameoConfig, rank_fn) -> CompressResult:
    """Greedy removal by ``rank_fn`` score under the exact ACF constraint.

    Identical loop structure to CAMEO's rounds mode; only the ranking
    criterion differs (geometry instead of ACF impact), which is what makes
    CAMEO win the comparison — it optimizes the quantity being constrained.
    """
    dt = cfg.jdtype()
    x = x.astype(dt)
    n = x.shape[0]
    L = cfg.lags
    kap = cfg.kappa
    y0 = aggregate_series(x, kap)
    ny = y0.shape[0]
    agg0 = extract_aggregates(y0, L)
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    p0 = transform(acf_from_aggregates(agg0, ny))

    if cfg.target_cr is not None:
        min_alive = max(2, int(np.ceil(n / cfg.target_cr)))
        eps = jnp.asarray(jnp.inf, dt)
    else:
        min_alive = 2
        eps = jnp.asarray(cfg.eps, dt)
    if cfg.max_cr is not None:
        min_alive = max(min_alive, int(np.ceil(n / cfg.max_cr)))
    k_max = max(1, int(cfg.alpha * n))

    def cond(c):
        (xr, alive, y, agg, alpha, dev, rounds, done, blocked) = c
        return (~done) & (rounds < cfg.max_rounds) & (jnp.sum(alive) > min_alive)

    def body(c):
        (xr, alive, y, agg, alpha, dev, rounds, done, blocked) = c
        inf = jnp.asarray(jnp.inf, dt)
        idx = jnp.arange(n, dtype=jnp.int32)
        score = rank_fn(xr, alive).astype(dt)
        removable = alive & (idx > 0) & (idx < n - 1) & (~blocked)
        score = jnp.where(removable, score, inf)

        n_alive = jnp.sum(alive)
        k_dyn = jnp.maximum(
            1, jnp.minimum(
                (alpha * n_alive.astype(dt)).astype(jnp.int32),
                (n_alive - min_alive).astype(jnp.int32)))
        neg_vals, sel_idx = jax.lax.top_k(-score, k_max)
        vals = -neg_vals
        rank_ok = (jnp.arange(k_max) < k_dyn) & jnp.isfinite(vals)
        sel = jnp.zeros((n,), bool).at[sel_idx].set(rank_ok, mode="drop")
        sel = _independent_set(sel, score, alive)
        n_sel = jnp.sum(sel)
        any_sel = n_sel > 0

        alive_new = alive & (~sel)
        xr_new = _reconstruct(x, alive_new)
        dy = _x_to_y_delta(xr_new - xr, kap, dt)
        agg_new = apply_delta_dense(agg, y, dy)
        dev_new = mfn(transform(acf_from_aggregates(agg_new, ny)), p0)

        accept = (dev_new <= eps) & any_sel
        single_fail = (~accept) & (n_sel <= 1) & any_sel
        failed_idx = jnp.argmax(sel)
        blocked_new = jnp.where(
            accept, jnp.zeros_like(blocked),
            jnp.where(single_fail, blocked.at[failed_idx].set(True), blocked))
        exhausted = ~jnp.any(alive & (~blocked_new) &
                             (idx > 0) & (idx < n - 1))
        done_new = done | (~any_sel) | ((~accept) & exhausted)
        alpha_new = jnp.where(accept, jnp.minimum(alpha * 1.1, cfg.alpha),
                              jnp.maximum(alpha * 0.5, jnp.asarray(1.5 / n, dt)))

        pick = lambda a, b: jnp.where(accept, a, b)
        return (pick(xr_new, xr), pick(alive_new, alive), pick(y + dy, y),
                jax.tree.map(pick, agg_new, agg), alpha_new,
                pick(dev_new, dev), rounds + 1, done_new, blocked_new)

    init = (x, jnp.ones((n,), bool), y0, agg0, jnp.asarray(cfg.alpha, dt),
            jnp.asarray(0.0, dt), jnp.asarray(0, jnp.int32),
            jnp.asarray(False), jnp.zeros((n,), bool))
    (xr, alive, y, agg, _, dev, rounds, _, _) = jax.lax.while_loop(
        cond, body, init)
    stat_new = transform(acf_from_aggregates(agg, ny))
    return CompressResult(
        kept=alive, xr=xr, deviation=dev, n_kept=jnp.sum(alive),
        iters=rounds, stat_orig=p0, stat_new=stat_new)


LINE_SIMPL_BASELINES = {
    "vw": vw_rank,
    "tps": tp_rank_s,
    "tpm": tp_rank_m,
    "pipv": pip_rank_v,
    "pipe": pip_rank_e,
}


def compress_baseline(x, cfg: CameoConfig, name: str) -> CompressResult:
    if name in LINE_SIMPL_BASELINES:
        if cfg.kappa > 1:
            n = (np.asarray(x).shape[0] // cfg.kappa) * cfg.kappa
            x = jnp.asarray(x)[:n]
        return constrained_removal(jnp.asarray(x), cfg,
                                   LINE_SIMPL_BASELINES[name])
    raise ValueError(f"unknown line-simplification baseline {name!r}")
