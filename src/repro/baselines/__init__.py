"""Baselines from the paper's evaluation (§5.1), all adapted to report or
enforce the ACF-deviation constraint:

* line simplification: VW, TPs, TPm, PIPv, PIPe  (removal engine with exact
  incremental ACF constraint checks — the paper's own adaptation strategy)
* functional approximation: PMC, SWING, Sim-Piece (trial-and-error search of
  the value error bound that meets the ACF bound, as in the paper)
* domain transform: FFT (top-m coefficients, binary search on m)
* lossless: Gorilla, Chimp (bits-per-value cost models for Table 2)
"""
from repro.baselines.line_simpl import (
    constrained_removal, vw_rank, tp_rank_s, tp_rank_m, pip_rank_v, pip_rank_e,
    LINE_SIMPL_BASELINES,
)
from repro.baselines.functional import pmc_compress, swing_compress, simpiece_compress
from repro.baselines.transform import fft_compress
from repro.baselines.constrain import acf_constrained_search
from repro.baselines.lossless import gorilla_bits_per_value, chimp_bits_per_value
