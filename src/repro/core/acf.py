"""Autocorrelation (ACF) and partial autocorrelation (PACF) machinery.

Implements the paper's two ACF formulations:

* Eq. (1): stationary form (global mean/std).
* Eq. (2): non-stationary aggregate form, driven by the five per-lag
  aggregates ``sx, sx_l, sx^2, sx_l^2, sxx_l`` (Eq. 7) that CAMEO maintains
  incrementally.  All CAMEO code paths use this form.

Index conventions are 0-based: for lag ``l`` the head range is
``t in [0, n-1-l]`` and the tail range is ``t in [l, n-1]``; both have
``n - l`` elements.  ``n`` (series length) is *static* throughout CAMEO —
removal replaces values by interpolation but never shortens the series.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Aggregates(NamedTuple):
    """Per-lag ACF aggregates (Eq. 7). Each field has shape ``[L]``.

    Entry ``j`` corresponds to lag ``l = j + 1``.
    """

    sx: jax.Array     # sum of head values        sum_{t<=n-1-l} x_t
    sxl: jax.Array    # sum of tail values        sum_{t>=l}     x_t
    sx2: jax.Array    # sum of head squares
    sxl2: jax.Array   # sum of tail squares
    sxx: jax.Array    # lagged product            sum_{t<=n-1-l} x_t x_{t+l}


def lags_arange(L: int, dtype=jnp.float64) -> jax.Array:
    return jnp.arange(1, L + 1, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("L", "backend"))
def extract_aggregates(x: jax.Array, L: int,
                       backend: str = "auto") -> Aggregates:
    """ExtractAggregates (Algorithm 1): O(nL), dominated by ``sxx_l``.

    The four moment sums are O(n + L) prefix work; the lagged products go
    through the impact-engine backend (``kernels/ops.lag_dot`` — the Pallas
    kernel on TPU, the jnp reference elsewhere).
    """
    from repro.kernels.ops import lag_dot  # deferred: kernels sit below core
    n = x.shape[0]
    csum = jnp.cumsum(x)
    csum2 = jnp.cumsum(x * x)
    total, total2 = csum[-1], csum2[-1]
    l = jnp.arange(1, L + 1)
    # head sums: prefix up to index n-1-l.
    sx = csum[n - 1 - l]
    sx2 = csum2[n - 1 - l]
    # tail sums: total minus prefix up to l-1.
    sxl = total - csum[l - 1]
    sxl2 = total2 - csum2[l - 1]
    sxx = lag_dot(x, L, backend=backend)
    return Aggregates(sx=sx, sxl=sxl, sx2=sx2, sxl2=sxl2, sxx=sxx)


def extract_aggregates_masked(x: jax.Array, L: int, n_valid,
                              backend: str = "auto") -> Aggregates:
    """ExtractAggregates over a zero-padded buffer: aggregates of
    ``x[:n_valid]`` where ``n_valid`` may be a traced scalar.

    ``x`` must be zero beyond ``n_valid`` (the padded-bucket discipline of
    the rounds mode): the tail sums and the lagged products are then exact
    as-is, and only the head prefix sums need dynamic gathers.  Not jitted —
    intended to be traced inside a caller's jit.
    """
    from repro.kernels.ops import lag_dot  # deferred: kernels sit below core
    csum = jnp.cumsum(x)
    csum2 = jnp.cumsum(x * x)
    total, total2 = csum[-1], csum2[-1]
    l = jnp.arange(1, L + 1)
    sx = csum[n_valid - 1 - l]
    sx2 = csum2[n_valid - 1 - l]
    sxl = total - csum[l - 1]
    sxl2 = total2 - csum2[l - 1]
    sxx = lag_dot(x, L, backend=backend)
    return Aggregates(sx=sx, sxl=sxl, sx2=sx2, sxl2=sxl2, sxx=sxx)


def acf_from_aggregates(agg, n: int) -> jax.Array:
    """Eq. (2).  Returns the ACF for lags ``1..L`` (shape ``[L]``).

    ``agg`` is any structure indexable as the five per-lag rows — the
    :class:`Aggregates` NamedTuple or the packed ``[5, L]`` moment table the
    rounds mode carries.
    """
    sx, sxl, sx2, sxl2, sxx = agg[0], agg[1], agg[2], agg[3], agg[4]
    L = sx.shape[-1]
    m = n - jnp.arange(1, L + 1, dtype=sx.dtype)  # n - l per lag
    num = m * sxx - sx * sxl
    var_head = m * sx2 - sx * sx
    var_tail = m * sxl2 - sxl * sxl
    denom2 = var_head * var_tail
    tiny = jnp.asarray(1e-30, sx.dtype)
    denom = jnp.sqrt(jnp.maximum(denom2, tiny))
    return jnp.where(denom2 > tiny, num / denom, jnp.zeros_like(num))


@functools.partial(jax.jit, static_argnames=("L",))
def acf(x: jax.Array, L: int) -> jax.Array:
    """Non-stationary ACF (Eq. 2) computed from scratch.  Shape ``[L]``."""
    return acf_from_aggregates(extract_aggregates(x, L), x.shape[0])


@functools.partial(jax.jit, static_argnames=("L",))
def acf_stationary(x: jax.Array, L: int) -> jax.Array:
    """Eq. (1): stationary ACF with global mean/variance (oracle/tests)."""
    n = x.shape[0]
    mu = jnp.mean(x)
    var = jnp.mean((x - mu) ** 2)
    xc = x - mu

    def one(l):
        shifted = jnp.roll(xc, -l)
        mask = jnp.arange(n) <= (n - 1 - l)
        return jnp.sum(jnp.where(mask, xc * shifted, 0.0)) / ((n - l) * var)

    return jax.vmap(one)(jnp.arange(1, L + 1))


def pacf_from_acf(r: jax.Array) -> jax.Array:
    """Durbin–Levinson recursion (Eq. 3), O(L^2).

    ``r`` is the ACF for lags 1..L; returns ``phi_{l,l}`` for l = 1..L.
    """
    L = r.shape[0]
    dtype = r.dtype

    if L == 1:
        return r

    phi0 = jnp.zeros((L,), dtype).at[0].set(r[0])  # phi_{1,k} row (k=1..L)
    diag0 = jnp.zeros((L,), dtype).at[0].set(r[0])

    def body(lm1, carry):
        # computing row l = lm1 + 1 (so lm1 ranges 1..L-1)
        phi_prev, diag = carry
        l = lm1 + 1
        k = jnp.arange(1, L + 1)
        kmask = (k <= l - 1).astype(dtype)
        # r_{l-k} for k = 1..l-1 ; clamp indices, mask handles validity.
        r_lk = r[jnp.clip(l - k - 1, 0, L - 1)]
        num = r[l - 1] - jnp.sum(phi_prev * r_lk * kmask)
        den = 1.0 - jnp.sum(phi_prev * r * kmask)
        den = jnp.where(jnp.abs(den) < 1e-12, jnp.asarray(1e-12, dtype), den)
        phi_ll = num / den
        # phi_{l,k} = phi_{l-1,k} - phi_ll * phi_{l-1,l-k}
        phi_rev = phi_prev[jnp.clip(l - k - 1, 0, L - 1)]
        phi_new = (phi_prev - phi_ll * phi_rev) * kmask
        phi_new = phi_new.at[l - 1].set(phi_ll)
        diag = diag.at[l - 1].set(phi_ll)
        return phi_new, diag

    _, diag = jax.lax.fori_loop(1, L, body, (phi0, diag0))
    return diag


@functools.partial(jax.jit, static_argnames=("L",))
def pacf(x: jax.Array, L: int) -> jax.Array:
    return pacf_from_acf(acf(x, L))


# ---------------------------------------------------------------------------
# Tumbling-window aggregation (SIP-on-Aggregates, Def. 2)
# ---------------------------------------------------------------------------

def aggregate_series(x: jax.Array, kappa: int, agg: str = "mean") -> jax.Array:
    """``AGG_kappa(X)``: tumbling windows of ``kappa`` points.

    ``n`` must be divisible by ``kappa`` (callers pad/trim in the pipeline).
    """
    if kappa == 1:
        return x
    n = x.shape[0]
    assert n % kappa == 0, f"length {n} not divisible by kappa={kappa}"
    xw = x.reshape(n // kappa, kappa)
    if agg == "mean":
        return xw.mean(axis=1)
    if agg == "sum":
        return xw.sum(axis=1)
    if agg == "max":
        return xw.max(axis=1)
    if agg == "min":
        return xw.min(axis=1)
    raise ValueError(f"unknown aggregation {agg!r}")
