"""Coarse-grained parallel CAMEO (paper §4.4) mapped onto JAX collectives.

The paper partitions the series across T threads; each thread compresses its
partition against a local budget ``p*eps/T`` and synchronizes aggregates
lazily, with the cross-partition ``sxx_l`` overlap terms handled separately.

TPU adaptation (DESIGN.md §2): per-round synchronization of the five [L]
aggregates is a ~KB ``psum`` — negligible on ICI — so the *lockstep* variant
checks the **global** constraint every round (a strictly tighter guarantee
than the paper's local budgets) while all ranking/selection/reconstruction
work stays partition-local.  Overlap regions are L-point halos exchanged with
``ppermute`` (shard_map) or array shifts (single-device global form).

Three entry points:

* :func:`compress_partitioned`          — lockstep, global-array form
  ([T, m] stacked partitions, axis-0 reductions standing in for psum).
  Runs on any device count; used by tests and the Fig. 10/11 benchmarks.
* :func:`compress_partitioned_shardmap` — lockstep under ``shard_map`` with
  ``psum``/``ppermute``; same math, one partition per device.
* :func:`compress_partitioned_local`    — paper-faithful local-budget
  variant (independent per-partition compressions at ``p*eps/T``; exact
  global deviation reported after merging).

Partition borders are pinned alive, so interpolation never crosses chunks
(the paper's partitions behave identically).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.core.acf import Aggregates, acf_from_aggregates, aggregate_series, acf
from repro.core.cameo import (
    CameoConfig,
    CompressResult,
    _independent_set,
    _measure_fn,
    _reconstruct,
    _stat_transform,
    _x_to_y_delta,
    compress_rounds,
)
from repro.kernels import ops as _ops


# ---------------------------------------------------------------------------
# per-chunk aggregate contributions (overlap terms via right halos)
# ---------------------------------------------------------------------------

def chunk_agg_contrib(y_c, halo_r, off, ny: int, L: int,
                      backend: str = "auto") -> Aggregates:
    """This chunk's contribution to the global per-lag aggregates.

    ``halo_r`` is the next chunk's first L values (zeros past the series
    end) — it carries exactly the paper's ``sxx_l(Overlap_ij)`` cross terms.
    Summing contributions over chunks (``psum``) yields the global Eq. 7
    aggregates exactly: each lag pair (t, t+l) is owned by the chunk of t.
    """
    m = y_c.shape[0]
    l = jnp.arange(1, L + 1)
    csum = jnp.cumsum(y_c)
    csum2 = jnp.cumsum(y_c * y_c)
    total, total2 = csum[-1], csum2[-1]

    # head: sum of y_c[t] with off+t <= ny-1-l
    hi = (ny - 1 - off) - l                     # local head end, may be <0/>m
    sx = jnp.where(hi >= 0, csum[jnp.clip(hi, 0, m - 1)], 0.0)
    sx2 = jnp.where(hi >= 0, csum2[jnp.clip(hi, 0, m - 1)], 0.0)
    # tail: sum of y_c[t] with off+t >= l
    lo = l - off
    sxl = jnp.where(lo <= 0, total,
                    jnp.where(lo >= m, 0.0,
                              total - csum[jnp.clip(lo - 1, 0, m - 1)]))
    sxl2 = jnp.where(lo <= 0, total2,
                     jnp.where(lo >= m, 0.0,
                               total2 - csum2[jnp.clip(lo - 1, 0, m - 1)]))
    # lagged products: the zero halo past the series end masks invalid pairs
    sxx = _ops.lag_dot(y_c, L, halo=halo_r, backend=backend)
    return Aggregates(sx=sx, sxl=sxl, sx2=sx2, sxl2=sxl2, sxx=sxx)


def chunk_delta_contrib(y_c, d_c, halo_y, halo_d, off, ny: int, L: int,
                        backend: str = "auto") -> Aggregates:
    """This chunk's contribution to the global aggregate *delta* for a dense
    per-chunk delta ``d_c`` (Eq. 9 generalized across partitions).

    ``halo_y``/``halo_d`` are the next chunk's first L old-values/deltas.
    """
    m = y_c.shape[0]
    l = jnp.arange(1, L + 1)
    e = d_c * (2.0 * y_c + d_c)
    cd, ce = jnp.cumsum(d_c), jnp.cumsum(e)
    dtot, etot = cd[-1], ce[-1]

    hi = (ny - 1 - off) - l
    dsx = jnp.where(hi >= 0, cd[jnp.clip(hi, 0, m - 1)], 0.0)
    dsx2 = jnp.where(hi >= 0, ce[jnp.clip(hi, 0, m - 1)], 0.0)
    lo = l - off
    dsxl = jnp.where(lo <= 0, dtot,
                     jnp.where(lo >= m, 0.0,
                               dtot - cd[jnp.clip(lo - 1, 0, m - 1)]))
    dsxl2 = jnp.where(lo <= 0, etot,
                      jnp.where(lo >= m, 0.0,
                                etot - ce[jnp.clip(lo - 1, 0, m - 1)]))

    # new*new - old*old expanded per lag pair:
    #   d_t y_{t+l} + y_t d_{t+l} + d_t d_{t+l}  — three halo'd lagged dots
    dsxx = (_ops.lag_dot(d_c, L, b=y_c, halo=halo_y, backend=backend)
            + _ops.lag_dot(y_c, L, b=d_c, halo=halo_d, backend=backend)
            + _ops.lag_dot(d_c, L, b=d_c, halo=halo_d, backend=backend))
    return Aggregates(sx=dsx, sxl=dsxl, sx2=dsx2, sxl2=dsxl2, sxx=dsxx)


def _chunk_select(impact, alive_c, k_dyn, k_max: int):
    mx = impact.shape[0]
    neg_vals, sel_idx = jax.lax.top_k(-impact, k_max)
    vals = -neg_vals
    rank_ok = (jnp.arange(k_max) < k_dyn) & jnp.isfinite(vals)
    sel = jnp.zeros((mx,), bool).at[sel_idx].set(rank_ok, mode="drop")
    return _independent_set(sel, impact, alive_c)


def _plan(cfg: CameoConfig, n: int, T: int):
    mx = n // T
    kap = cfg.kappa
    my = mx // kap
    ny = n // kap
    L, W = cfg.lags, cfg.window
    if n % T or mx % kap:
        raise ValueError(f"n={n} must be divisible by T*kappa={T}*{kap}")
    if my < L + W:
        raise ValueError(
            f"partition too small: my={my} < L+W={L + W}; lower T or W")
    if cfg.target_cr is not None:
        min_alive = max(2, int(np.ceil(n / cfg.target_cr)))
        eps = float("inf")
    else:
        min_alive = 2
        eps = cfg.eps
    if cfg.max_cr is not None:
        min_alive = max(min_alive, int(np.ceil(n / cfg.max_cr)))
    k_max = max(1, int(cfg.alpha * mx))
    return mx, my, ny, min_alive, eps, k_max


# ---------------------------------------------------------------------------
# lockstep partitioned compression — global-array form
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "T"))
def compress_partitioned(x: jax.Array, cfg: CameoConfig, T: int) -> CompressResult:
    dt = cfg.jdtype()
    x = x.astype(dt)
    n = x.shape[0]
    L, W, kap = cfg.lags, cfg.window, cfg.kappa
    mx, my, ny, min_alive, eps_f, k_max = _plan(cfg, n, T)
    eps = jnp.asarray(eps_f, dt)
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)

    xp = x.reshape(T, mx)
    offs_y = jnp.arange(T, dtype=jnp.int32) * my

    def right_halo(yparts, width):
        nxt_chunk = jnp.concatenate([yparts[1:], jnp.zeros((1, my), dt)], 0)
        return nxt_chunk[:, :width]

    def left_halo(yparts):
        prv = jnp.concatenate([jnp.zeros((1, my), dt), yparts[:-1]], 0)
        return prv[:, my - L:]

    def global_agg_from(yparts):
        contribs = jax.vmap(
            lambda yc, hr, off: chunk_agg_contrib(
                yc, hr, off, ny, L, backend=cfg.backend)
        )(yparts, right_halo(yparts, L), offs_y)
        return jax.tree.map(lambda a: a.sum(0), contribs)

    yp0 = jax.vmap(lambda c: aggregate_series(c, kap))(xp)
    agg0 = global_agg_from(yp0)
    p0 = transform(acf_from_aggregates(agg0, ny))

    impacts_fn = functools.partial(_ops.chunk_ranking_impact, cfg)

    def cond(c):
        (xr, alive, yp, agg, alpha, dev, rounds, done, blocked) = c
        return (~done) & (rounds < cfg.max_rounds) & \
            (jnp.sum(alive) > min_alive)

    def body(c):
        (xr, alive, yp, agg, alpha, dev, rounds, done, blocked) = c
        inf = jnp.asarray(jnp.inf, dt)
        hl = left_halo(yp)
        hr = right_halo(yp, L + W)
        y_ctx = jnp.concatenate([hl, yp, hr], axis=1)      # [T, my+2L+W]
        impact = jax.vmap(
            lambda ctx, xc, ac, off: impacts_fn(agg, ctx, xc, ac, p0, off, ny)
        )(y_ctx, xr, alive, offs_y)                        # [T, mx]
        impact = jnp.where(blocked, inf, impact)

        alive_local = jnp.sum(alive, axis=1)
        k_dyn = jnp.maximum(1, (alpha * alive_local.astype(dt)).astype(jnp.int32))
        sel = jax.vmap(lambda im, ac, k: _chunk_select(im, ac, k, k_max))(
            impact, alive, k_dyn)
        n_sel = jnp.sum(sel)
        any_sel = n_sel > 0

        alive_new = alive & (~sel)
        xr_new = jax.vmap(_reconstruct)(xp, alive_new)
        delta_x = xr_new - xr
        dyp = jax.vmap(lambda d: _x_to_y_delta(d, kap, dt))(delta_x)
        dcontrib = jax.vmap(
            lambda yc, dc, hy, hd, off: chunk_delta_contrib(
                yc, dc, hy, hd, off, ny, L, backend=cfg.backend)
        )(yp, dyp, right_halo(yp, L), right_halo(dyp, L), offs_y)
        dagg = jax.tree.map(lambda a: a.sum(0), dcontrib)
        agg_new = jax.tree.map(lambda a, d: a + d, agg, dagg)
        dev_new = mfn(transform(acf_from_aggregates(agg_new, ny)), p0)

        accept = (dev_new <= eps) & any_sel
        single_fail = (~accept) & (n_sel <= 1) & any_sel
        blocked_new = jnp.where(
            accept, jnp.zeros_like(blocked),
            jnp.where(single_fail, blocked | sel, blocked))
        exhausted = ~jnp.any(alive & (~blocked_new) & jnp.isfinite(impact))
        done_new = done | (~any_sel) | ((~accept) & exhausted)
        alpha_new = jnp.where(accept, jnp.minimum(alpha * 1.1, cfg.alpha),
                              jnp.maximum(alpha * 0.5, jnp.asarray(1.5 / mx, dt)))

        pick = lambda newv, oldv: jnp.where(accept, newv, oldv)
        return (pick(xr_new, xr), pick(alive_new, alive), pick(yp + dyp, yp),
                jax.tree.map(pick, agg_new, agg), alpha_new,
                pick(dev_new, dev), rounds + 1, done_new, blocked_new)

    init = (xp, jnp.ones((T, mx), bool), yp0, agg0,
            jnp.asarray(cfg.alpha, dt), jnp.asarray(0.0, dt),
            jnp.asarray(0, jnp.int32), jnp.asarray(False),
            jnp.zeros((T, mx), bool))
    (xr, alive, yp, agg, _, dev, rounds, _, _) = jax.lax.while_loop(
        cond, body, init)
    stat_new = transform(acf_from_aggregates(agg, ny))
    return CompressResult(
        kept=alive.reshape(n), xr=xr.reshape(n), deviation=dev,
        n_kept=jnp.sum(alive), iters=rounds, stat_orig=p0, stat_new=stat_new)


# ---------------------------------------------------------------------------
# lockstep partitioned compression — shard_map form (one partition/device)
# ---------------------------------------------------------------------------

def compress_partitioned_shardmap(x, cfg: CameoConfig, mesh, axis: str = "data"):
    """Same algorithm as :func:`compress_partitioned`, with axis-0 reductions
    replaced by ``psum`` and halo shifts by ``ppermute``.  ``x`` must be
    evenly divisible over ``mesh.shape[axis]`` partitions."""
    T = mesh.shape[axis]
    dt = cfg.jdtype()
    n = x.shape[0]
    L, W, kap = cfg.lags, cfg.window, cfg.kappa
    mx, my, ny, min_alive, eps_f, k_max = _plan(cfg, n, T)
    eps = jnp.asarray(eps_f, dt)
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    impacts_fn = functools.partial(_ops.chunk_ranking_impact, cfg)

    fwd = [(i, i - 1) for i in range(1, T)]   # i sends to i-1 (right halo)
    bwd = [(i, i + 1) for i in range(T - 1)]  # i sends to i+1 (left halo)

    def right_halo(y_c, width):
        return jax.lax.ppermute(y_c[:width], axis, fwd)

    def left_halo(y_c):
        return jax.lax.ppermute(y_c[my - L:], axis, bwd)

    def body_shard(x_c):
        x_c = x_c.astype(dt)
        off_y = jax.lax.axis_index(axis).astype(jnp.int32) * my
        y0 = aggregate_series(x_c, kap)
        agg0 = jax.tree.map(
            lambda a: jax.lax.psum(a, axis),
            chunk_agg_contrib(y0, right_halo(y0, L), off_y, ny, L,
                              backend=cfg.backend))
        p0 = transform(acf_from_aggregates(agg0, ny))

        def cond(c):
            (xr, alive, y, agg, alpha, dev, rounds, done, blocked) = c
            n_alive = jax.lax.psum(jnp.sum(alive), axis)
            return (~done) & (rounds < cfg.max_rounds) & (n_alive > min_alive)

        def body(c):
            (xr, alive, y, agg, alpha, dev, rounds, done, blocked) = c
            inf = jnp.asarray(jnp.inf, dt)
            y_ctx = jnp.concatenate([left_halo(y), y, right_halo(y, L + W)])
            impact = impacts_fn(agg, y_ctx, xr, alive, p0, off_y, ny)
            impact = jnp.where(blocked, inf, impact)

            alive_local = jnp.sum(alive)
            k_dyn = jnp.maximum(1, (alpha * alive_local.astype(dt)).astype(jnp.int32))
            sel = _chunk_select(impact, alive, k_dyn, k_max)
            n_sel = jax.lax.psum(jnp.sum(sel), axis)
            any_sel = n_sel > 0

            alive_new = alive & (~sel)
            xr_new = _reconstruct(x_c, alive_new)
            delta_x = xr_new - xr
            dy = _x_to_y_delta(delta_x, kap, dt)
            dagg = jax.tree.map(
                lambda a: jax.lax.psum(a, axis),
                chunk_delta_contrib(y, dy, right_halo(y, L),
                                    right_halo(dy, L), off_y, ny, L,
                                    backend=cfg.backend))
            agg_new = jax.tree.map(lambda a, d: a + d, agg, dagg)
            dev_new = mfn(transform(acf_from_aggregates(agg_new, ny)), p0)

            accept = (dev_new <= eps) & any_sel
            single_fail = (~accept) & (n_sel <= 1) & any_sel
            blocked_new = jnp.where(
                accept, jnp.zeros_like(blocked),
                jnp.where(single_fail, blocked | sel, blocked))
            has_candidates = jax.lax.psum(
                jnp.sum(alive & (~blocked_new) & jnp.isfinite(impact)), axis)
            done_new = done | (~any_sel) | ((~accept) & (has_candidates == 0))
            alpha_new = jnp.where(
                accept, jnp.minimum(alpha * 1.1, cfg.alpha),
                jnp.maximum(alpha * 0.5, jnp.asarray(1.5 / mx, dt)))

            pick = lambda newv, oldv: jnp.where(accept, newv, oldv)
            return (pick(xr_new, xr), pick(alive_new, alive), pick(y + dy, y),
                    jax.tree.map(pick, agg_new, agg), alpha_new,
                    pick(dev_new, dev), rounds + 1, done_new, blocked_new)

        init = (x_c, jnp.ones((mx,), bool), y0, agg0,
                jnp.asarray(cfg.alpha, dt), jnp.asarray(0.0, dt),
                jnp.asarray(0, jnp.int32), jnp.asarray(False),
                jnp.zeros((mx,), bool))
        (xr, alive, y, agg, _, dev, rounds, _, _) = jax.lax.while_loop(
            cond, body, init)
        stat_new = transform(acf_from_aggregates(agg, ny))
        n_kept = jax.lax.psum(jnp.sum(alive), axis)
        return xr, alive, dev, n_kept, rounds, p0, stat_new

    shard = shd.shard_map(
        body_shard, mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis), P(), P(), P(), P(), P()))
    xr, alive, dev, n_kept, rounds, p0, stat_new = jax.jit(shard)(x)
    return CompressResult(kept=alive, xr=xr, deviation=dev, n_kept=n_kept,
                          iters=rounds, stat_orig=p0, stat_new=stat_new)


# ---------------------------------------------------------------------------
# paper-faithful local-budget variant (§4.4 coarse-grained semantics)
# ---------------------------------------------------------------------------

def compress_partitioned_local(x, cfg: CameoConfig, T: int, p: float = 1.0):
    """Independent per-partition compressions with local budget ``p*eps/T``
    (the paper's §4.4 semantics).  Reports the exact *global* deviation of
    the merged reconstruction (measured, not guaranteed, exactly as in the
    paper, where partitions synchronize only when exhausting their budget).
    """
    dt = cfg.jdtype()
    x = jnp.asarray(x, dt)
    n = x.shape[0]
    if n % T:
        raise ValueError(f"n={n} not divisible by T={T}")
    mx = n // T
    local_cfg = dataclasses.replace(cfg, eps=cfg.eps * p / T)
    res = jax.vmap(lambda c: compress_rounds(c, local_cfg))(x.reshape(T, mx))
    kept = res.kept.reshape(n)
    xr = res.xr.reshape(n)
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    y_orig = aggregate_series(x, cfg.kappa)
    y_new = aggregate_series(xr, cfg.kappa)
    s0 = transform(acf(y_orig, cfg.lags))
    s1 = transform(acf(y_new, cfg.lags))
    dev = mfn(s1, s0)
    return CompressResult(kept=kept, xr=xr, deviation=dev,
                          n_kept=jnp.sum(kept), iters=jnp.max(res.iters),
                          stat_orig=s0, stat_new=s1)
