"""Incremental maintenance of the ACF aggregates (paper Eqs. 8-11).

This is the paper's core contribution: after removing a point (and replacing
the interior of the affected segment by linear interpolation), the five
per-lag aggregates are updated from the *delta vector* between the old and
new reconstruction — O(L) for a single-point delta, O(mL) for an m-point
segment — instead of recomputing the ACF in O(nL).

This module owns the exact *update* math (Eqs. 10-11) and the alive-neighbor
geometry:

* ``apply_delta_dense``   — exact update from a dense delta vector (used by
  the TPU batched-rounds mode: one O(nL) regular kernel per round, including
  the cross-lag bilinear term across *all* of this round's segments).
* ``apply_delta_window``  — exact update from a delta confined to a static
  window ``W`` (used by the paper-faithful sequential mode; Eq. 9).

The hypothetical-ACF *ranking* forms (Eqs. 8-9) live once in
``kernels/ref.py`` — ``acf_after_single_delta`` / ``acf_after_window_delta``
here are thin aliases kept for the core-level API, and all GetAllImpact
ranking dispatches through ``kernels/ops.py``.

All functions operate on the *target* series ``y`` (the raw series for
``kappa == 1``, or the tumbling-window aggregate series for Def. 2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.acf import Aggregates
from repro.kernels import ref as _ref

# head/tail validity masks live with the single-copy Eq. 8/9 math.
_lag_masks = _ref.head_tail_masks


# ---------------------------------------------------------------------------
# Dense exact update (rounds mode)
# ---------------------------------------------------------------------------

def apply_delta_dense(agg, y_old: jax.Array, delta: jax.Array, ny=None,
                      form: str = "auto"):
    """Exact aggregate update for an arbitrary dense delta vector.

    ``y_old`` is the reconstruction *before* the update.  Cost: O(ny + L) for
    the four moment sums (via cumulative sums) + one ``[ny] x [ny, L]``
    contraction for ``sxx`` (lag shifts gathered against a constant shift
    basis — no per-lag op chains).

    ``agg`` may be the ``Aggregates`` NamedTuple or the packed ``[5, L]``
    moment table (the rounds-mode loop carry); the update comes back in the
    same form — for the table that is a single fused add.

    ``ny`` (optionally traced) gives the valid length when ``y_old``/``delta``
    live in a zero-padded bucket; both must be zero beyond it.

    ``form`` picks the bilinear-term lowering: ``"gather"`` (two matvecs
    against the [nyb, L] shift basis), ``"roll"`` (one batched
    roll-and-reduce over the lag axis), or ``"auto"`` (roll on CPU, gather
    elsewhere — see the comment at the term).
    """
    nyb = y_old.shape[0]
    if ny is None:
        ny = nyb
    L = agg[0].shape[-1]
    l = jnp.arange(1, L + 1)

    cd = jnp.cumsum(delta)
    e = delta * (2.0 * y_old + delta)
    ce = jnp.cumsum(e)
    dtot, etot = cd[-1], ce[-1]

    dsx = cd[ny - 1 - l]
    dsx2 = ce[ny - 1 - l]
    dsxl = dtot - cd[l - 1]
    dsxl2 = etot - ce[l - 1]

    # new*new - old*old expanded over lag shifts:
    #   d_t*y_{t+l} + y_t*d_{t+l} + d_t*d_{t+l}
    #     = d_t*(y+d)_{t+l} + y_t*d_{t+l}
    # Backend-conditional trace-time form (parity-tested in
    # tests/test_contractions.py): XLA's CPU emitter runs both the [nyb, L]
    # shift-basis gather and a per-lag chain of 2L small dots an order of
    # magnitude slower than one batched roll+mask+reduce (the gather takes
    # the slow general-gather path; the dot chain is dispatch-bound on the
    # legacy runtime).  Elsewhere the gathered basis keeps the whole term at
    # two matvecs against a [nyb, L] operand — matmul-shaped for the MXU.
    if form == "auto":
        form = "roll" if jax.default_backend() == "cpu" else "gather"
    if form == "roll":
        z = y_old + delta
        t = jnp.arange(nyb)

        def lag_term(ll):
            keep = (t <= (ny - 1 - ll)).astype(y_old.dtype)
            # roll wraps the head into the tail, so the validity mask is
            # load-bearing even with zero-padded operands
            return jnp.sum(keep * (delta * jnp.roll(z, -ll)
                                   + y_old * jnp.roll(delta, -ll)))

        dsxx = jax.vmap(lag_term)(l)
    else:
        z_pad = jnp.pad(y_old + delta, (0, L))
        d_pad = jnp.pad(delta, (0, L))
        t = jnp.arange(nyb)
        shift = t[:, None] + l[None, :]                   # [nyb, L]
        dsxx = delta @ z_pad[shift] + y_old @ d_pad[shift]

    dtable = jnp.stack([dsx, dsxl, dsx2, dsxl2, dsxx])
    if isinstance(agg, jax.Array):
        return agg + dtable
    return Aggregates(
        sx=agg.sx + dtable[0],
        sxl=agg.sxl + dtable[1],
        sx2=agg.sx2 + dtable[2],
        sxl2=agg.sxl2 + dtable[3],
        sxx=agg.sxx + dtable[4],
    )


def apply_delta_dense_ref(agg: Aggregates, y_old: jax.Array,
                          delta: jax.Array, ny=None) -> Aggregates:
    """Per-lag loop oracle for :func:`apply_delta_dense` (the historical
    vmapped roll-multiply-sum form), kept for parity tests of the shift-basis
    contraction."""
    nyb = y_old.shape[0]
    if ny is None:
        ny = nyb
    L = agg[0].shape[-1]
    l = jnp.arange(1, L + 1)

    cd = jnp.cumsum(delta)
    e = delta * (2.0 * y_old + delta)
    ce = jnp.cumsum(e)
    dtot, etot = cd[-1], ce[-1]

    dsx = cd[ny - 1 - l]
    dsx2 = ce[ny - 1 - l]
    dsxl = dtot - cd[l - 1]
    dsxl2 = etot - ce[l - 1]

    def lag_term(ll):
        mask = (jnp.arange(nyb) <= (ny - 1 - ll)).astype(y_old.dtype)
        y_sh = jnp.roll(y_old, -ll)
        d_sh = jnp.roll(delta, -ll)
        return jnp.sum(mask * (delta * y_sh + y_old * d_sh + delta * d_sh))

    dsxx = jax.vmap(lag_term)(l)
    return Aggregates(
        sx=agg[0] + dsx,
        sxl=agg[1] + dsxl,
        sx2=agg[2] + dsx2,
        sxl2=agg[3] + dsxl2,
        sxx=agg[4] + dsxx,
    )


# ---------------------------------------------------------------------------
# Windowed exact update (sequential mode, Eq. 9)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("W", "L"))
def apply_delta_window(
    agg: Aggregates,
    y_old: jax.Array,
    delta_win: jax.Array,   # [W] deltas for positions start .. start+W-1
    start: jax.Array,       # scalar int32: absolute index of delta_win[0]
    *,
    W: int,
    L: int,
) -> Aggregates:
    """Exact Eq. 9 update for a delta confined to ``W`` contiguous points.

    Out-of-range window positions must carry zero delta (masked by caller).
    Cost O(W * L).
    """
    ny = y_old.shape[0]
    dtype = y_old.dtype
    # Pad y by L left and L+W right so the slice below never clamps for any
    # start in [0, ny); head/tail masks null out padded contributions.
    y_pad = jnp.pad(y_old, (L, L + W))
    # ywin[j] == y_old[start - L + j] for j in [0, W + 2L)
    ywin = jax.lax.dynamic_slice(y_pad, (start,), (W + 2 * L,))
    j = jnp.arange(W)
    abs_t = start + j                                     # [W]
    head, tail = _lag_masks(abs_t, ny, L, dtype)          # [W, L]

    d = delta_win                                          # [W]
    y_at = ywin[L + j]                                     # y_old at window
    e = d * (2.0 * y_at + d)                               # [W]

    dsx = jnp.sum(d[:, None] * head, axis=0)
    dsxl = jnp.sum(d[:, None] * tail, axis=0)
    dsx2 = jnp.sum(e[:, None] * head, axis=0)
    dsxl2 = jnp.sum(e[:, None] * tail, axis=0)

    l = jnp.arange(1, L + 1)
    # y_{t+l} and y_{t-l} gathered from the padded window.
    y_fwd = ywin[(L + j)[:, None] + l[None, :]]            # [W, L]
    y_bwd = ywin[(L + j)[:, None] - l[None, :]]            # [W, L]
    # cross term d_t * d_{t+l}: pad delta window on the right by L.
    d_pad = jnp.pad(d, (0, L))
    d_fwd = d_pad[j[:, None] + l[None, :]]                 # [W, L]
    dsxx = jnp.sum(
        d[:, None] * (y_fwd * head + y_bwd * tail + d_fwd * head), axis=0
    )
    return Aggregates(
        sx=agg.sx + dsx,
        sxl=agg.sxl + dsxl,
        sx2=agg.sx2 + dsx2,
        sxl2=agg.sxl2 + dsxl2,
        sxx=agg.sxx + dsxx,
    )


# ---------------------------------------------------------------------------
# Vectorized single-delta impact (Algorithm 2 / Eq. 8) — ranking only
# ---------------------------------------------------------------------------

def acf_after_single_delta(
    agg: Aggregates,
    y: jax.Array,
    idx: jax.Array,     # [P] absolute indices receiving a delta
    dval: jax.Array,    # [P] delta magnitudes
) -> jax.Array:
    """Hypothetical ACF (per Eq. 8) after adding ``dval[p]`` at ``idx[p]``,
    independently for each p.  Returns ``[P, L]``.

    Thin alias: the math lives in ``kernels/ref.py`` (single source of
    truth, shared with the ``kernels/acf_impact`` Pallas kernel).
    """
    return _ref.acf_after_single_delta(agg, y, idx, dval)


def acf_after_window_delta_ctx(
    agg: Aggregates,
    y_ctx: jax.Array,    # [m + 2L + W] context: y_ctx[j] = y_global[off-L+j]
    starts: jax.Array,   # [P] *local* index of each window's first delta
    dwins: jax.Array,    # [P, W] per-candidate delta windows (zero-padded)
    *,
    ny: int,
    off,
) -> jax.Array:
    """Hypothetical ACF after applying each candidate's *windowed* delta
    independently (vectorized Eq. 9).  Returns ``[P, L]``.

    Thin alias for the single-copy math in ``kernels/ref.py`` (shared with
    the ``kernels/acf_window_impact`` Pallas kernel); see there for the
    context-layout contract.
    """
    return _ref.acf_after_window_delta_ctx(
        agg, y_ctx, starts, dwins, ny=ny, off=off)


def acf_after_window_delta(agg: Aggregates, y: jax.Array, starts: jax.Array,
                           dwins: jax.Array) -> jax.Array:
    """Single-partition wrapper around :func:`acf_after_window_delta_ctx`."""
    L = agg.sx.shape[0]
    W = dwins.shape[1]
    y_ctx = jnp.pad(y, (L, L + W))
    return acf_after_window_delta_ctx(
        agg, y_ctx, starts, dwins, ny=y.shape[0], off=0)


def segment_interp(xr: jax.Array, prev: jax.Array, nxt: jax.Array,
                   i: jax.Array, W: int):
    """Interpolated values over the interior of segment (prev[i], nxt[i]):
    the line between the segment endpoints, evaluated at the first ``W``
    interior positions.

    Vectorized over ``i``; returns ``(vals [..., W], absj [..., W],
    start [...], span [...])``.  ``absj`` are the absolute indices the
    values land on (clipped in-range); positions at or beyond the span
    carry garbage values the caller must mask (spans > W are truncated).
    The arithmetic matches :func:`interpolate_at` bit-for-bit, so a
    scatter of these values is exactly the reconstruction
    :func:`~repro.core.cameo._reconstruct` would produce there.
    """
    n = xr.shape[0]
    dt = xr.dtype
    p = prev[i]
    q = nxt[i]
    start = p + 1
    span = q - p - 1
    j = jnp.arange(W, dtype=jnp.int32)
    absj = jnp.clip(start[..., None] + j, 0, n - 1)
    pc = jnp.clip(p, 0, n - 1)[..., None]
    qc = jnp.clip(q, 0, n - 1)[..., None]
    denom = jnp.maximum((q - p).astype(dt), 1.0)[..., None]
    t = (absj - jnp.clip(p, 0, n - 1)[..., None]).astype(dt) / denom
    vals = xr[pc] + (xr[qc] - xr[pc]) * t
    return vals, absj, start, span


def segment_deltas(xr: jax.Array, prev: jax.Array, nxt: jax.Array,
                   i: jax.Array, W: int):
    """Delta window from removing point(s) ``i``: the interior of segment
    (prev[i], nxt[i]) is re-interpolated on the line between the endpoints.

    Vectorized over ``i``; returns ``(dwin [..., W], start [...], span [...])``
    with deltas zero beyond the span (spans > W are truncated — callers treat
    those candidates as unrankable).
    """
    dt = xr.dtype
    vals, absj, start, span = segment_interp(xr, prev, nxt, i, W)
    j = jnp.arange(W, dtype=jnp.int32)
    m = (j < span[..., None]).astype(dt)
    dwin = (vals - xr[absj]) * m
    return dwin, start, span


# ---------------------------------------------------------------------------
# Alive-neighbor machinery (replaces the paper's linked list, vectorized)
# ---------------------------------------------------------------------------

def alive_neighbors(alive: jax.Array):
    """For every index i, the nearest alive index strictly left / right.

    Returns ``(prev, nxt)`` int32 arrays; ``prev[i] = -1`` if none,
    ``nxt[i] = n`` if none.  O(n) via cumulative max/min.
    """
    n = alive.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    left_ids = jnp.where(alive, idx, jnp.int32(-1))
    prev_incl = jax.lax.associative_scan(jnp.maximum, left_ids)
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), prev_incl[:-1]])
    right_ids = jnp.where(alive, idx, jnp.int32(n))
    nxt_incl = jax.lax.associative_scan(jnp.minimum, right_ids, reverse=True)
    nxt = jnp.concatenate([nxt_incl[1:], jnp.array([n], jnp.int32)])
    return prev, nxt


def neighbors_after_removal(prev: jax.Array, nxt: jax.Array,
                            removed: jax.Array):
    """``alive_neighbors`` after removing an *independent* set, by pointer
    jump: a removed point's own neighbors are alive (no two removed points
    are alive-adjacent), so any index whose neighbor was removed inherits
    that neighbor's neighbor.  O(n) gathers instead of two associative
    scans — exact (integer) equivalence with recomputing from scratch.
    """
    n = prev.shape[0]
    pj = jnp.clip(prev, 0, n - 1)
    qj = jnp.clip(nxt, 0, n - 1)
    prev_new = jnp.where(removed[pj] & (prev >= 0), prev[pj], prev)
    nxt_new = jnp.where(removed[qj] & (nxt <= n - 1), nxt[qj], nxt)
    return prev_new, nxt_new


def interpolate_at(x: jax.Array, prev: jax.Array, nxt: jax.Array, i: jax.Array):
    """Value of the line through the alive neighbors of i, evaluated at i."""
    n = x.shape[0]
    p = jnp.clip(prev, 0, n - 1)
    q = jnp.clip(nxt, 0, n - 1)
    xp, xq = x[p], x[q]
    denom = jnp.maximum((q - p).astype(x.dtype), 1.0)
    t = (i - p).astype(x.dtype) / denom
    return xp + (xq - xp) * t
