"""Quality measures ``D(a, b)`` (paper §2.3).

Used both for the ACF-deviation constraint (vectors of length L) and for
reconstruction error of full series.  All return scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mae(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.mean(jnp.abs(a - b))


def rmse(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean((a - b) ** 2))


def nrmse(a: jax.Array, b: jax.Array) -> jax.Array:
    rng = jnp.max(a) - jnp.min(a)
    rng = jnp.where(rng <= 0, jnp.ones_like(rng), rng)
    return rmse(a, b) / rng


def mape(a: jax.Array, b: jax.Array) -> jax.Array:
    denom = jnp.maximum(jnp.abs(a), 1e-12)
    return jnp.mean(jnp.abs(a - b) / denom)


def cheb(a: jax.Array, b: jax.Array) -> jax.Array:
    """Chebyshev distance: max absolute deviation across lags."""
    return jnp.max(jnp.abs(a - b))


def msmape(a: jax.Array, b: jax.Array) -> jax.Array:
    """Modified symmetric MAPE (paper §2.3), with the expanding-window
    mean-absolute-deviation stabilizer ``S_i``."""
    n = a.shape[0]
    idx = jnp.arange(1, n + 1, dtype=a.dtype)
    csum = jnp.cumsum(a)
    # expanding mean of a_1..a_{i-1}; define S_1 = 0.
    prev_mean = jnp.where(idx > 1, (csum - a) / jnp.maximum(idx - 1, 1), 0.0)
    # expanding mean absolute deviation around the running mean (approximate
    # the paper's S_i with a causal cumulative form).
    dev = jnp.abs(a - prev_mean)
    cdev = jnp.cumsum(dev)
    s = jnp.where(idx > 1, (cdev - dev) / jnp.maximum(idx - 1, 1), 0.0)
    denom = jnp.abs(a + b) / 2.0 + s
    denom = jnp.maximum(denom, 1e-12)
    return jnp.mean(jnp.abs(a - b) / denom)


def psnr(a: jax.Array, b: jax.Array) -> jax.Array:
    rng = jnp.max(a) - jnp.min(a)
    m = jnp.mean((a - b) ** 2)
    return 10.0 * jnp.log10(jnp.maximum(rng * rng, 1e-30) / jnp.maximum(m, 1e-30))


_MEASURES = {
    "mae": mae,
    "rmse": rmse,
    "nrmse": nrmse,
    "mape": mape,
    "cheb": cheb,
    "msmape": msmape,
}


def get_measure(name: str):
    try:
        return _MEASURES[name]
    except KeyError:
        raise ValueError(f"unknown measure {name!r}; have {sorted(_MEASURES)}")
