"""Streaming CAMEO ingest: window-at-a-time compression with bounded state.

The paper positions CAMEO for sensor/IoT feeds, but ``compress()`` wants the
whole series materialized.  This module is the online front-end: a
:class:`StreamingCompressor` absorbs arbitrary-size point chunks, buffers
them into fixed **tumbling windows** of ``window_len`` points, compresses
each window independently the moment it fills (through the ordinary
``compress()`` path — rounds or sequential, so every window carries the full
per-window ε guarantee), and emits the closed window as a
:class:`WindowResult`.  Peak state is O(window): one raw buffer plus O(L)
running aggregates — the Sprintz-style bounded-state discipline.

Semantics (the differential contract ``tests/test_streaming.py`` enforces):

* **Chunking invariance** — the emitted kept masks, reconstructions and the
  reported deviation are a pure function of the *stream contents* and
  ``window_len``; how the points were sliced into ``push()`` calls is
  unobservable (bit-identical results for every chunking, including the
  one-chunk case — which is exactly :func:`compress_windowed`, the one-shot
  reference).
* **Per-window fidelity** — each full window's mask/reconstruction is
  bit-identical to ``compress(x[s:s+window_len], cfg)`` on that slice; with
  ``window_len >= len(x)`` streaming therefore reproduces the one-shot
  ``compress(x, cfg)`` result exactly.
* **Exact global accounting** — the running Eq. 7 aggregates of the original
  and reconstructed target streams are maintained incrementally (O(L) state;
  the cross-window lagged products go through ``kernels/ops.lag_dot`` with a
  right-halo, the same dispatch the partitioned mode uses, so the Pallas and
  reference backends both serve the hot loop).  ``deviation()`` is the exact
  measured D(S(recon), S(orig)) of the stream so far — the per-window ε
  guarantee is what is *enforced* (the paper's §4.4 local-budget discipline);
  the global deviation is *reported*, exactly as in
  ``core/parallel.compress_partitioned_local``.

Durability is the layer above's concern: this class acks nothing — a
``push()`` return only means the points are buffered/compressed in memory.
The serving façade (``repro.api.StreamWriter`` over a journaling store)
journals each chunk *before* it reaches this compressor, so there an acked
push survives a crash and replays deterministically on resume — the replay
rides exactly the chunking-invariance contract below (re-feeding the
journaled chunks regenerates bit-identical windows regardless of how the
crashed run had chunked them).

Window borders are always kept (``compress`` never removes endpoints), so
windows concatenate without any interpolation segment crossing a border and
the stream's reconstruction is the per-window reconstructions laid side by
side.  A final partial window is compressed if its target-series length
reaches ``lags + 2`` (the shortest series the aggregate math is defined on);
anything shorter — including a tail remainder not divisible by ``kappa`` —
is kept verbatim, so the last stream point is always kept and the store's
block coverage reaches the end.

``state_dict()`` / ``from_state()`` round-trip the complete compressor state
(raw buffer + running aggregates) through JSON-safe types, bit-exactly —
the store stashes it in its footer so a closed ingest session resumes as if
it had never stopped.
"""
from __future__ import annotations

import math
import warnings
from time import perf_counter as _perf_counter
from typing import List, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.acf import Aggregates, acf_from_aggregates, aggregate_series
from repro.core.cameo import (
    CameoConfig,
    CompressResult,
    MVCompressResult,
    _measure_fn,
    _stat_transform,
    compress,
    compress_batch,
    compress_multivariate,
    compress_rounds,
)
from repro.kernels import ops as _ops
from repro.obs import OBS


def compile_cache_size() -> int:
    """Deprecated shim over :func:`repro.obs.recompile_watermark`.

    The streaming discipline promises *no per-length recompiles*: full
    windows share one program and a partial tail rides the same bucket via
    ``compress_rounds(..., pad_to=window_len)``.  The watermark now covers
    **every** registered jitted entry point (rounds/batch, sequential,
    multivariate reconstruct, block reconstruct), not just the rounds
    kernel; the perf gates snapshot it around a timed ingest run and
    assert it stays flat.
    """
    warnings.warn(
        "compile_cache_size() is deprecated; use "
        "repro.obs.recompile_watermark() (covers all jitted entry points)",
        DeprecationWarning, stacklevel=2)
    return OBS.recompile_watermark()


def _observe_window(window_len, m, ndiv, cfg, n_kept, iters, verbatim, dev):
    """Record one closed window into the registry.  Callers hold the
    ``OBS.enabled`` guard; ``dev`` is the measured deviation (scalar,
    per-column array, or None when the window closed without one)."""
    OBS.inc("stream.windows")
    OBS.observe("stream.window_rounds", iters)
    OBS.observe("stream.window_kept_frac", n_kept / m if m else 0.0)
    if verbatim:
        OBS.inc("stream.windows_verbatim")
    elif cfg.mode == "rounds" and ndiv < window_len:
        OBS.inc("stream.pad_to_bucket_hits")
    eps = cfg.eps
    if dev is not None and eps and math.isfinite(eps):
        for d in np.atleast_1d(dev):
            OBS.observe("stream.window_eps_headroom", float(d) / eps)


class WindowResult(NamedTuple):
    """One closed stream window: ``x[start : start + len(x)]`` of the feed."""

    start: int          # absolute index of the window's first point
    x: np.ndarray       # original points of the window
    kept: np.ndarray    # bool mask (window-local)
    xr: np.ndarray      # reconstruction (kept points bit-exact)
    n_kept: int
    iters: int          # compressor rounds/removals (0 for verbatim windows)


def min_window_len(cfg: CameoConfig) -> int:
    """Shortest window the aggregate math is defined on (x-space points)."""
    return cfg.kappa * (cfg.lags + 2)


# ---------------------------------------------------------------------------
# incremental Eq. 7 aggregates of an append-only stream
# ---------------------------------------------------------------------------

class RunningAggregates:
    """Exact Eq. 7 sufficient statistics of an append-only series, O(L) state.

    The four moment rows are derived on demand from the scalar totals plus
    the stream's first/last ``L`` values (``sx(l) = T - sum(last l)``, etc. —
    the same derivation the v3 block headers use); the lagged products
    ``sxx`` are accumulated chunk-by-chunk through ``kernels/ops.lag_dot``
    with a right halo, so each lag pair ``(t, t+l)`` is owned by the chunk
    of ``t`` — identical pair-ownership to ``core/parallel``'s
    ``chunk_agg_contrib``.  A chunk's ``sxx`` contribution needs the next
    chunk's head as halo, so it is folded in one ``append`` late (or with a
    zero halo at ``finalize`` — the stream ends, so missing partners vanish).

    Only the *final* chunk may be shorter than ``L``: a short interior chunk
    could not serve as its predecessor's halo.
    """

    def __init__(self, L: int, backend: str = "auto"):
        self.L = int(L)
        self.backend = backend
        self.n = 0
        self.total = 0.0
        self.total2 = 0.0
        self.head = np.empty(0, np.float64)   # first min(L, n) values
        self.tail = np.empty(0, np.float64)   # last  min(L, n) values
        self.sxx = np.zeros(self.L, np.float64)
        self._pend: Optional[np.ndarray] = None  # last chunk, awaits halo
        self._final = False

    def append(self, y) -> None:
        y = np.asarray(y, np.float64)
        if self._final:
            raise ValueError("stream already finalized")
        if y.size == 0:
            return
        if self._pend is not None:
            if self._pend.shape[0] < self.L:
                raise ValueError(
                    f"non-final chunk of {self._pend.shape[0]} < L={self.L} "
                    "values cannot anchor its successor's lag pairs")
            self.sxx = self._fold_pending(y)
        self._pend = y
        self.n += y.shape[0]
        self.total += float(y.sum())
        self.total2 += float(np.dot(y, y))
        if self.head.shape[0] < self.L:
            self.head = np.concatenate(
                [self.head, y[:self.L - self.head.shape[0]]])
        self.tail = np.concatenate([self.tail, y])[-self.L:]

    def finalize(self) -> None:
        """Fold the last pending chunk (zero halo: the stream ended)."""
        if not self._final:
            self.sxx = self._fold_pending(np.empty(0, np.float64))
            self._pend = None
            self._final = True

    def _fold_pending(self, nxt: np.ndarray) -> np.ndarray:
        """``sxx`` with the pending chunk's pairs folded in against the
        continuation ``nxt`` (non-mutating; callers assign)."""
        if self._pend is None:
            return self.sxx
        halo = np.zeros(self.L, np.float64)
        m = min(self.L, nxt.shape[0])
        halo[:m] = nxt[:m]
        return self.sxx + np.asarray(
            _ops.lag_dot(jnp.asarray(self._pend), self.L,
                         halo=jnp.asarray(halo), backend=self.backend))

    def aggregates(self) -> Aggregates:
        """Eq. 7 five-tuple of the stream seen so far.  The pending chunk's
        lag pairs are folded in on the fly (zero halo — pairs reaching past
        the seen prefix don't exist yet), so the answer is exact for the
        prefix at any point, not just after :meth:`finalize`."""
        L = self.L
        l = np.arange(1, L + 1)
        valid = l < self.n
        sx = np.zeros(L)
        sxl = np.zeros(L)
        sx2 = np.zeros(L)
        sxl2 = np.zeros(L)
        if self.n:
            csh = np.cumsum(self.head)
            csh2 = np.cumsum(self.head * self.head)
            cst = np.cumsum(self.tail[::-1])
            cst2 = np.cumsum((self.tail * self.tail)[::-1])
            k = np.clip(l - 1, 0, self.tail.shape[0] - 1)
            kh = np.clip(l - 1, 0, self.head.shape[0] - 1)
            sx = np.where(valid, self.total - cst[k], 0.0)
            sx2 = np.where(valid, self.total2 - cst2[k], 0.0)
            sxl = np.where(valid, self.total - csh[kh], 0.0)
            sxl2 = np.where(valid, self.total2 - csh2[kh], 0.0)
        sxx = self._fold_pending(np.empty(0, np.float64))
        return Aggregates(sx=jnp.asarray(sx), sxl=jnp.asarray(sxl),
                          sx2=jnp.asarray(sx2), sxl2=jnp.asarray(sxl2),
                          sxx=jnp.asarray(np.where(valid, sxx, 0.0)))

    # -- resume support ------------------------------------------------------

    def state_dict(self) -> dict:
        return dict(
            L=self.L, n=self.n, total=self.total, total2=self.total2,
            head=self.head.tolist(), tail=self.tail.tolist(),
            sxx=self.sxx.tolist(),
            pend=None if self._pend is None else self._pend.tolist(),
            final=self._final)

    @classmethod
    def from_state(cls, state: dict, backend: str = "auto"):
        out = cls(state["L"], backend)
        out.n = int(state["n"])
        out.total = float(state["total"])
        out.total2 = float(state["total2"])
        out.head = np.asarray(state["head"], np.float64)
        out.tail = np.asarray(state["tail"], np.float64)
        out.sxx = np.asarray(state["sxx"], np.float64)
        out._pend = (None if state["pend"] is None
                     else np.asarray(state["pend"], np.float64))
        out._final = bool(state["final"])
        return out


# ---------------------------------------------------------------------------
# the streaming compressor
# ---------------------------------------------------------------------------

class StreamingCompressor:
    """Window-at-a-time CAMEO over an unbounded feed; O(window) state.

    ``push(chunk)`` buffers points and returns the windows it closed (zero
    or more :class:`WindowResult`, in stream order); ``finish()`` flushes
    the final partial window.  See the module docstring for the exact
    semantics and the differential guarantees.

    ``queue_depth`` (default 1: every window compresses synchronously the
    moment it fills) lets the ingest pipeline accumulate up to K filled
    windows and close them as **one** ``compress_batch`` ``[K, window]``
    device program — a single dispatch for the whole batch, materialized
    back into per-window results in stream order.  Per-window results are
    bit-identical to the ``queue_depth=1`` path (``compress_batch``'s
    per-series no-op-round guarantee), so store bytes are invariant to the
    queue depth; windows are simply *emitted* in bursts of K.  A partial
    tail window rides the full-window compiled program via
    ``compress_rounds(..., pad_to=window_len)`` — no per-length recompiles
    (see :func:`compile_cache_size`).

    Where a deeper queue pays: on TPU (and any multi-core host) the
    batched drain amortizes dispatch and fills lanes.  On a single-core
    CPU host the lane-compacted ``compress_batch`` driver runs within
    ~2x of the per-series loop per lane-round (it was ~3.4x before the
    matmul-shaped round body; the residual tax is vmap executing both
    sides of each branch until the driver's one-way small-round switch),
    so a deeper queue trades a modest throughput factor for burst
    emission rather than multiplying work — ``queue_depth=1`` remains
    the latency-optimal CPU default.
    """

    def __init__(self, cfg: CameoConfig, window_len: int = 4096, *,
                 start: int = 0, queue_depth: int = 1):
        if window_len % cfg.kappa:
            raise ValueError(f"window_len={window_len} not divisible by "
                             f"kappa={cfg.kappa}")
        if window_len < min_window_len(cfg):
            raise ValueError(
                f"window_len={window_len} shorter than the minimum "
                f"{min_window_len(cfg)} for lags={cfg.lags}, "
                f"kappa={cfg.kappa}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth={queue_depth} must be >= 1")
        self.cfg = cfg
        self.window_len = int(window_len)
        self.queue_depth = int(queue_depth)
        self._buf = np.empty(0, np.dtype(cfg.dtype))
        self._queue: List[tuple] = []   # (start, window) awaiting batch close
        self._next_start = int(start)   # absolute index of _buf[0]
        self.n_seen = int(start)        # absolute index past the last point
        self.windows = 0
        self.n_kept = 0
        self.iters = 0
        self._finished = False
        self._orig = RunningAggregates(cfg.lags, cfg.backend)
        self._recon = RunningAggregates(cfg.lags, cfg.backend)

    # -- feeding -------------------------------------------------------------

    def push(self, chunk) -> List[WindowResult]:
        """Absorb an arbitrary-size chunk; returns the windows it closed."""
        if not OBS.enabled:
            return self._push(chunk)
        t0 = _perf_counter()
        out = self._push(chunk)
        OBS.observe("stream.push_seconds", _perf_counter() - t0)
        OBS.inc("stream.push_calls")
        OBS.gauge("stream.queue_depth", len(self._queue))
        return out

    def _push(self, chunk) -> List[WindowResult]:
        if self._finished:
            raise ValueError("stream already finished")
        chunk = np.asarray(chunk, self._buf.dtype)
        if chunk.ndim != 1:
            raise ValueError(f"chunks must be 1-D, got {chunk.shape}")
        if chunk.size:
            self._buf = np.concatenate([self._buf, chunk])
            self.n_seen += chunk.shape[0]
        out = []
        W = self.window_len
        while self._buf.shape[0] >= W:
            self._queue.append((self._next_start, self._buf[:W].copy()))
            self._buf = self._buf[W:]
            self._next_start += W
            if len(self._queue) >= self.queue_depth:
                out += self._drain()
        return out

    def finish(self) -> List[WindowResult]:
        """Flush queued windows and the final partial one; finalize."""
        if self._finished:
            return []
        out = self._drain()
        if self._buf.shape[0]:
            out.append(self._close(self._buf, final=True))
            self._next_start += self._buf.shape[0]
            self._buf = self._buf[:0]
        self._orig.finalize()
        self._recon.finalize()
        self._finished = True
        return out

    # -- window close --------------------------------------------------------

    def _drain(self) -> List[WindowResult]:
        """Close every queued full window — one ``[K, window]`` device
        program when several are waiting (rounds mode), the plain per-window
        path otherwise.  Results materialize in stream order."""
        q, self._queue = self._queue, []
        if not q:
            return []
        if OBS.enabled:
            OBS.inc("stream.queue_drains")
            OBS.observe("stream.drain_windows", len(q))
        if len(q) == 1 or self.cfg.mode != "rounds":
            return [self._close(w, final=False, start=s) for s, w in q]
        xs = np.stack([w for _, w in q])
        res = compress_batch(xs, self.cfg)   # one dispatch for all K windows
        devs = np.asarray(res.deviation) if OBS.enabled else None
        return [self._close(w, final=False, start=s,
                            precomputed=(np.asarray(res.kept[i]),
                                         np.asarray(res.xr[i]),
                                         int(res.iters[i]),
                                         None if devs is None
                                         else float(devs[i])))
                for i, (s, w) in enumerate(q)]

    def _close(self, w_x: np.ndarray, final: bool, start: int = None,
               precomputed: tuple = None) -> WindowResult:
        cfg = self.cfg
        if start is None:
            start = self._next_start
        m = w_x.shape[0]
        ndiv = (m // cfg.kappa) * cfg.kappa
        dev = None
        verbatim = False
        if precomputed is not None:     # full window closed by a batch drain
            kept, xr, iters, dev = precomputed
        elif ndiv // cfg.kappa >= cfg.lags + 2:
            if cfg.mode == "rounds":
                # pad to the full-window bucket: a partial tail reuses the
                # full-window program instead of compiling its own shape
                res = compress_rounds(jnp.asarray(w_x[:ndiv], cfg.jdtype()),
                                      cfg, pad_to=self.window_len)
            else:
                res = compress(jnp.asarray(w_x[:ndiv]), cfg)
            kept = np.asarray(res.kept)
            xr = np.asarray(res.xr)
            iters = int(res.iters)
            if OBS.enabled:
                dev = float(res.deviation)
            if ndiv < m:    # kappa-remainder of the final window: verbatim
                kept = np.concatenate([kept, np.ones(m - ndiv, bool)])
                xr = np.concatenate([xr, w_x[ndiv:]])
        else:               # too short for the aggregate math: verbatim
            kept = np.ones(m, bool)
            xr = np.asarray(w_x).copy()
            iters = 0
            verbatim = True
        # global accounting over the kappa-divisible prefix of the stream
        if ndiv:
            self._orig.append(aggregate_series(
                np.asarray(w_x[:ndiv], np.float64), cfg.kappa))
            self._recon.append(aggregate_series(
                np.asarray(xr[:ndiv], np.float64), cfg.kappa))
        w = WindowResult(start=start, x=np.asarray(w_x),
                         kept=kept, xr=xr, n_kept=int(kept.sum()),
                         iters=iters)
        self.windows += 1
        self.n_kept += w.n_kept
        self.iters += iters
        if OBS.enabled:
            _observe_window(self.window_len, m, ndiv, cfg, w.n_kept,
                            iters, verbatim, dev)
        return w

    # -- exact global accounting --------------------------------------------

    def stats(self):
        """(stat_orig, stat_new): S of the original / reconstructed target
        stream so far, from the running Eq. 7 aggregates."""
        transform = _stat_transform(self.cfg)
        ny = self._orig.n
        s0 = transform(acf_from_aggregates(self._orig.aggregates(), ny))
        s1 = transform(acf_from_aggregates(self._recon.aggregates(), ny))
        return s0, s1

    def deviation(self) -> float:
        """Exact measured D(S(recon), S(orig)) over the stream so far."""
        if self._orig.n <= self.cfg.lags + 1:
            return 0.0
        s0, s1 = self.stats()
        return float(_measure_fn(self.cfg)(s1, s0))

    # -- resume support ------------------------------------------------------

    def state_dict(self) -> dict:
        """Complete state, JSON-safe and bit-exact (floats round-trip via
        repr); ``from_state`` continues as if the stream never paused.
        Queued-but-unclosed windows serialize back into the raw buffer
        (they re-queue and recompress on resume — deterministic, so the
        resumed stream stays bit-identical)."""
        buf = self._buf
        next_start = self._next_start
        if self._queue:
            buf = np.concatenate([w for _, w in self._queue] + [buf])
            next_start = self._queue[0][0]
        return dict(
            version=1, window_len=self.window_len,
            queue_depth=self.queue_depth,
            dtype=str(self._buf.dtype),
            next_start=next_start, n_seen=self.n_seen,
            windows=self.windows, n_kept=self.n_kept, iters=self.iters,
            finished=self._finished,
            buf=buf.astype(np.float64).tolist(),
            orig=self._orig.state_dict(), recon=self._recon.state_dict())

    @classmethod
    def from_state(cls, cfg: CameoConfig, state: dict):
        out = cls(cfg, int(state["window_len"]),
                  queue_depth=int(state.get("queue_depth", 1)))
        out._buf = np.asarray(state["buf"], np.float64).astype(
            np.dtype(state["dtype"]))
        out._next_start = int(state["next_start"])
        out.n_seen = int(state["n_seen"])
        out.windows = int(state["windows"])
        out.n_kept = int(state["n_kept"])
        out.iters = int(state["iters"])
        out._finished = bool(state["finished"])
        out._orig = RunningAggregates.from_state(state["orig"], cfg.backend)
        out._recon = RunningAggregates.from_state(state["recon"], cfg.backend)
        # windows that were queued at pause time re-queue (the serialized
        # buffer holds them verbatim); pre-pause the queue was < queue_depth
        # deep, so re-queueing alone never triggers a drain
        W = out.window_len
        while out._buf.shape[0] >= W:
            out._queue.append((out._next_start, out._buf[:W].copy()))
            out._buf = out._buf[W:]
            out._next_start += W
        return out


# ---------------------------------------------------------------------------
# multivariate streaming: shared-index windows, per-column accounting
# ---------------------------------------------------------------------------

class MVWindowResult(NamedTuple):
    """One closed multivariate stream window (shared kept mask)."""

    start: int          # absolute index of the window's first point
    x: np.ndarray       # original points [m, C]
    kept: np.ndarray    # bool [m] — shared union mask (window-local)
    xr: np.ndarray      # reconstruction [m, C]
    n_kept: int
    iters: int


class MVStreamingCompressor:
    """Window-at-a-time multivariate CAMEO over an unbounded feed.

    The multivariate sibling of :class:`StreamingCompressor`: chunks are
    ``[m, C]``, each full window closes through
    :func:`~repro.core.cameo.compress_multivariate` (per-window per-column
    ε guarantee on one shared kept index), and **per-column**
    :class:`RunningAggregates` pairs keep the exact global Eq. 7 accounting
    of every original/reconstructed column stream — ``deviations()`` is the
    exact measured per-column global deviation, O(C·L) state.  Chunking
    invariance, window-border kept points and JSON-safe bit-exact
    ``state_dict()`` resume all carry over from the univariate contract.
    """

    def __init__(self, cfg: CameoConfig, window_len: int = 4096,
                 channels: int = None, *, start: int = 0,
                 queue_depth: int = 1):
        if channels is None or int(channels) < 1:
            raise ValueError("MVStreamingCompressor needs channels >= 1")
        if window_len % cfg.kappa:
            raise ValueError(f"window_len={window_len} not divisible by "
                             f"kappa={cfg.kappa}")
        if window_len < min_window_len(cfg):
            raise ValueError(
                f"window_len={window_len} shorter than the minimum "
                f"{min_window_len(cfg)} for lags={cfg.lags}, "
                f"kappa={cfg.kappa}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth={queue_depth} must be >= 1")
        self.cfg = cfg
        self.window_len = int(window_len)
        self.queue_depth = int(queue_depth)
        self.channels = int(channels)
        self._buf = np.empty((0, self.channels), np.dtype(cfg.dtype))
        self._queue: List[tuple] = []   # (start, window) awaiting close
        self._next_start = int(start)
        self.n_seen = int(start)
        self.windows = 0
        self.n_kept = 0
        self.iters = 0
        self._finished = False
        self._orig = [RunningAggregates(cfg.lags, cfg.backend)
                      for _ in range(self.channels)]
        self._recon = [RunningAggregates(cfg.lags, cfg.backend)
                       for _ in range(self.channels)]

    # -- feeding -------------------------------------------------------------

    def push(self, chunk) -> List[MVWindowResult]:
        """Absorb an arbitrary-size ``[m, C]`` chunk; returns the windows
        it closed."""
        if not OBS.enabled:
            return self._push(chunk)
        t0 = _perf_counter()
        out = self._push(chunk)
        OBS.observe("stream.push_seconds", _perf_counter() - t0)
        OBS.inc("stream.push_calls")
        OBS.gauge("stream.queue_depth", len(self._queue))
        return out

    def _push(self, chunk) -> List[MVWindowResult]:
        if self._finished:
            raise ValueError("stream already finished")
        chunk = np.asarray(chunk, self._buf.dtype)
        if chunk.ndim != 2 or chunk.shape[1] != self.channels:
            raise ValueError(f"chunks must be [m, {self.channels}], "
                             f"got {chunk.shape}")
        if chunk.size:
            self._buf = np.concatenate([self._buf, chunk])
            self.n_seen += chunk.shape[0]
        out = []
        W = self.window_len
        while self._buf.shape[0] >= W:
            self._queue.append((self._next_start, self._buf[:W].copy()))
            self._buf = self._buf[W:]
            self._next_start += W
            if len(self._queue) >= self.queue_depth:
                out += self._drain()
        return out

    def finish(self) -> List[MVWindowResult]:
        if self._finished:
            return []
        out = self._drain()
        if self._buf.shape[0]:
            out.append(self._close(self._buf, final=True))
            self._next_start += self._buf.shape[0]
            self._buf = self._buf[:0]
        for ra in self._orig + self._recon:
            ra.finalize()
        self._finished = True
        return out

    # -- window close --------------------------------------------------------

    def _drain(self) -> List[MVWindowResult]:
        """Close queued windows in stream order.  Each window runs its own
        ``compress_multivariate`` (the per-column ε repair loop is inherently
        per-window); the queue still defers work so callers control when the
        device burst happens."""
        q, self._queue = self._queue, []
        if q and OBS.enabled:
            OBS.inc("stream.queue_drains")
            OBS.observe("stream.drain_windows", len(q))
        return [self._close(w, final=False, start=s) for s, w in q]

    def _close(self, w_x: np.ndarray, final: bool,
               start: int = None) -> MVWindowResult:
        cfg = self.cfg
        if start is None:
            start = self._next_start
        m = w_x.shape[0]
        ndiv = (m // cfg.kappa) * cfg.kappa
        dev = None
        verbatim = False
        if ndiv // cfg.kappa >= cfg.lags + 2:
            res = compress_multivariate(
                w_x[:ndiv], cfg,
                pad_to=self.window_len if cfg.mode == "rounds" else None)
            kept = np.asarray(res.kept)
            xr = np.asarray(res.xr)
            iters = int(res.iters)
            if OBS.enabled:
                dev = np.asarray(res.deviations)
            if ndiv < m:    # kappa-remainder of the final window: verbatim
                kept = np.concatenate([kept, np.ones(m - ndiv, bool)])
                xr = np.concatenate([xr, w_x[ndiv:]])
        else:               # too short for the aggregate math: verbatim
            kept = np.ones(m, bool)
            xr = np.asarray(w_x).copy()
            iters = 0
            verbatim = True
        if ndiv:
            for c in range(self.channels):
                self._orig[c].append(aggregate_series(
                    np.asarray(w_x[:ndiv, c], np.float64), cfg.kappa))
                self._recon[c].append(aggregate_series(
                    np.asarray(xr[:ndiv, c], np.float64), cfg.kappa))
        w = MVWindowResult(start=start, x=np.asarray(w_x),
                           kept=kept, xr=xr, n_kept=int(kept.sum()),
                           iters=iters)
        self.windows += 1
        self.n_kept += w.n_kept
        self.iters += iters
        if OBS.enabled:
            _observe_window(self.window_len, m, ndiv, cfg, w.n_kept,
                            iters, verbatim, dev)
        return w

    # -- exact global accounting --------------------------------------------

    def deviations(self) -> np.ndarray:
        """[C] exact measured per-column global deviation so far."""
        transform = _stat_transform(self.cfg)
        mfn = _measure_fn(self.cfg)
        out = np.zeros(self.channels)
        for c in range(self.channels):
            ny = self._orig[c].n
            if ny <= self.cfg.lags + 1:
                continue
            s0 = transform(acf_from_aggregates(
                self._orig[c].aggregates(), ny))
            s1 = transform(acf_from_aggregates(
                self._recon[c].aggregates(), ny))
            out[c] = float(mfn(s1, s0))
        return out

    def deviation(self) -> float:
        """Max per-column exact deviation (the headline number)."""
        return float(self.deviations().max()) if self.channels else 0.0

    # -- resume support ------------------------------------------------------

    def state_dict(self) -> dict:
        buf = self._buf
        next_start = self._next_start
        if self._queue:
            buf = np.concatenate([w for _, w in self._queue] + [buf])
            next_start = self._queue[0][0]
        return dict(
            version=1, kind="mvar", window_len=self.window_len,
            queue_depth=self.queue_depth,
            channels=self.channels, dtype=str(self._buf.dtype),
            next_start=next_start, n_seen=self.n_seen,
            windows=self.windows, n_kept=self.n_kept, iters=self.iters,
            finished=self._finished,
            buf=buf.astype(np.float64).tolist(),
            orig=[ra.state_dict() for ra in self._orig],
            recon=[ra.state_dict() for ra in self._recon])

    @classmethod
    def from_state(cls, cfg: CameoConfig, state: dict):
        out = cls(cfg, int(state["window_len"]), int(state["channels"]),
                  queue_depth=int(state.get("queue_depth", 1)))
        out._buf = np.asarray(state["buf"], np.float64).reshape(
            -1, out.channels).astype(np.dtype(state["dtype"]))
        out._next_start = int(state["next_start"])
        out.n_seen = int(state["n_seen"])
        out.windows = int(state["windows"])
        out.n_kept = int(state["n_kept"])
        out.iters = int(state["iters"])
        out._finished = bool(state["finished"])
        out._orig = [RunningAggregates.from_state(s, cfg.backend)
                     for s in state["orig"]]
        out._recon = [RunningAggregates.from_state(s, cfg.backend)
                      for s in state["recon"]]
        W = out.window_len
        while out._buf.shape[0] >= W:
            out._queue.append((out._next_start, out._buf[:W].copy()))
            out._buf = out._buf[W:]
            out._next_start += W
        return out


def compressor_from_state(cfg: CameoConfig, state: dict):
    """Rebuild the right streaming compressor (uni- or multivariate) from a
    ``state_dict()`` blob — the store footer stash does not record which
    class wrote it, the state does."""
    if state.get("kind") == "mvar":
        return MVStreamingCompressor.from_state(cfg, state)
    return StreamingCompressor.from_state(cfg, state)


# ---------------------------------------------------------------------------
# one-shot references for the streaming semantics
# ---------------------------------------------------------------------------

def _compress_windowed(x, cfg: CameoConfig,
                       window_len: int = 4096) -> CompressResult:
    x = np.asarray(x)
    sc = StreamingCompressor(cfg, window_len)
    wins = sc.push(x) + sc.finish()
    kept = np.concatenate([w.kept for w in wins])
    xr = np.concatenate([w.xr for w in wins])
    s0, s1 = sc.stats()
    return CompressResult(
        kept=jnp.asarray(kept), xr=jnp.asarray(xr),
        deviation=jnp.asarray(sc.deviation()),
        n_kept=jnp.asarray(sc.n_kept), iters=jnp.asarray(sc.iters),
        stat_orig=s0, stat_new=s1)


def compress_windowed(x, cfg: CameoConfig,
                      window_len: int = 4096) -> CompressResult:
    """One-shot windowed compression — the reference the streaming path is
    differentially tested against (it feeds the whole series as a single
    chunk, so any chunked ``push`` sequence must match it bit-for-bit).

    Returns a whole-series :class:`CompressResult`: concatenated mask and
    reconstruction, the exact measured global deviation, and the global
    stream statistics.  ``iters`` is the total across windows.

    .. deprecated:: repro.api
        Application code should go through the façade —
        ``repro.api.open(path, cfg).stream(sid)`` for ingest; this function
        stays as the differential-test oracle.
    """
    warnings.warn(
        "compress_windowed is deprecated as an application entry point; "
        "use repro.api.open(...).stream(sid) (it remains the streaming "
        "differential-test oracle)", DeprecationWarning, stacklevel=2)
    return _compress_windowed(x, cfg, window_len)


def compress_windowed_mv(X, cfg: CameoConfig,
                         window_len: int = 4096) -> MVCompressResult:
    """One-shot windowed multivariate compression — the differential
    reference for :class:`MVStreamingCompressor` (single-chunk feed)."""
    X = np.asarray(X)
    sc = MVStreamingCompressor(cfg, window_len, X.shape[1])
    wins = sc.push(X) + sc.finish()
    kept = np.concatenate([w.kept for w in wins])
    xr = np.concatenate([w.xr for w in wins])
    devs = sc.deviations()
    return MVCompressResult(
        kept=kept, xr=xr, deviation=float(devs.max()),
        n_kept=int(sc.n_kept), iters=int(sc.iters), deviations=devs,
        col_n_kept=np.full(X.shape[1], -1))
