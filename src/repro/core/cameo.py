"""CAMEO: autocorrelation-preserving lossy compression (paper §4).

Two execution modes share the same incremental-aggregate substrate:

* ``mode="sequential"`` — paper-faithful Algorithm 1: one point removed per
  iteration (heap replaced by a dense masked argmin), exact Eq. 9 windowed
  aggregate update + constraint check at pop time, and *blocking* — only the
  ``h`` alive neighbors on each side get their cached impact recomputed
  (ReHeap) after a removal.

* ``mode="rounds"`` — the TPU-native batched-greedy adaptation: every round
  computes the Algorithm-2 impact for *all* alive points as one dense O(nL)
  kernel (see ``kernels/acf_impact``), removes an independent set of the
  lowest-impact α-fraction, applies one exact dense aggregate update for the
  whole round, and accepts/rejects the round against the ε constraint
  (rejections halve α, so the mode converges to the same guarantee).

Both modes support the three problem variants of §3:
  Def. 1 (SIP)                — ``eps`` bound on D(S(X'), S(X));
  Def. 2 (SIP on aggregates)  — ``kappa > 1`` tumbling-window mean;
  Def. 3 (compression-centric)— ``target_cr`` (minimize D s.t. CR ≥ c).
and both statistics ``S ∈ {acf, pacf}``.

The guarantee discipline matches the paper: the *ranking* of candidates is a
heuristic (single-delta Eq. 8 approximation, possibly stale under blocking),
but every actual removal is validated with an exact incremental update, so
the returned deviation is exact w.r.t. the reconstruction's true ACF/PACF.

All ranking math is served by the impact-engine backend (``kernels/ops.py``,
selected via ``CameoConfig.backend``): the Pallas kernels on TPU, the
pure-jnp reference forms elsewhere.  This module holds only the greedy
control loops; ``compress_batch`` vmaps/shards the rounds mode over a fleet
of independent series.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures as _measures
from repro.core.acf import (
    acf_from_aggregates,
    aggregate_series,
    extract_aggregates,
    extract_aggregates_masked,
)
from repro.core.aggregates import (
    alive_neighbors,
    apply_delta_dense,
    apply_delta_window,
    interpolate_at,
    neighbors_after_removal,
    segment_deltas,
)
from repro.kernels import fused_round as _fused
from repro.kernels import ops as _ops
from repro.kernels import ref as _ref
from repro.obs import OBS


@dataclasses.dataclass(frozen=True)
class CameoConfig:
    """Static configuration (hashable: safe to close over / pass as static)."""

    eps: float = 0.01
    lags: int = 24
    stat: str = "acf"              # "acf" | "pacf"
    measure: str = "mae"           # see core.measures
    kappa: int = 1                 # Def. 2 tumbling-window size (mean agg)
    mode: str = "rounds"           # "rounds" | "sequential"
    # -- rounds mode --
    alpha: float = 0.10            # per-round removal fraction cap
    max_rounds: int = 400
    impact_chunk: int = 4096
    rank: str = "window"           # "window" (exact Eq. 9) | "single" (Alg. 2)
    stop_policy: str = "exhaustive"  # "exhaustive" | "first_violation"
    # "backoff" (adaptive alpha, no per-round prefix search — fastest and
    # the default) | "scan" (fused prefix-deviation curve) | "bisect"
    # (dense prefix search)
    select: str = "backoff"
    bisect_probes: int = 6
    # -- sequential mode --
    hops: int = 16                 # blocking neighborhood h per side
    window: int = 64               # max re-interpolated span W (static)
    max_iters: Optional[int] = None
    # -- Def. 3 / halting --
    target_cr: Optional[float] = None   # minimize D s.t. CR >= target_cr
    max_cr: Optional[float] = None      # optional halt once CR reaches this
    dtype: str = "float64"
    # -- impact-engine backend (see kernels/ops.py):
    #    "pallas" (TPU kernels; interpret mode off-TPU) | "reference"
    #    (pure-jnp) | "auto" (pallas on TPU, reference elsewhere)
    backend: str = "auto"

    def jdtype(self):
        return jnp.dtype(self.dtype)


class CompressResult(NamedTuple):
    kept: jax.Array        # bool [n] — True where the original point is kept
    xr: jax.Array          # float [n] — reconstruction (kept pts bit-exact)
    deviation: jax.Array   # scalar — exact D(S(recon), S(orig))
    n_kept: jax.Array      # scalar int
    iters: jax.Array       # rounds (rounds mode) or removals (sequential)
    stat_orig: jax.Array   # [L] S of the original target series
    stat_new: jax.Array    # [L] S of the reconstruction's target series


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _stat_transform(cfg: CameoConfig):
    # single stat registry, shared with the impact-engine dispatch
    return _ops._transform_fn(cfg.stat)


def _measure_fn(cfg: CameoConfig):
    return _measures.get_measure(cfg.measure)


def _ranking_impact(cfg, agg, y, xr, alive, p0, n):
    """GetAllImpact via the impact-engine backend (see kernels/ops.py)."""
    return _ops.ranking_impact(cfg, agg, y, xr, alive, p0, n)


def _independent_set(sel: jax.Array, impact: jax.Array, alive: jax.Array,
                     prev=None, nxt=None):
    """Drop alive-adjacent picks: keep a pick iff it beats both its nearest
    *selected* alive neighbors (vectorized local-minima rule on the alive
    chain, so no two removed points ever share a segment endpoint).

    ``prev``/``nxt`` may be passed when the caller already has the alive
    neighbor maps (saves recomputing the two associative scans)."""
    n = sel.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    if prev is None or nxt is None:
        prev, nxt = alive_neighbors(alive)
    inf = jnp.asarray(jnp.inf, impact.dtype)
    # impact of my adjacent alive neighbors IF they are also selected
    pc, qc = jnp.clip(prev, 0, n - 1), jnp.clip(nxt, 0, n - 1)
    left_imp = jnp.where(sel[pc] & (prev >= 0), impact[pc], inf)
    right_imp = jnp.where(sel[qc] & (nxt <= n - 1), impact[qc], inf)
    li = jnp.where(prev >= 0, prev, n)
    beats_left = (impact < left_imp) | ((impact == left_imp) & (idx < li))
    ri = jnp.where(nxt <= n - 1, nxt, -1)
    beats_right = (impact < right_imp) | ((impact == right_imp) & (idx < ri))
    return sel & beats_left & beats_right


def _reconstruct(x_kept_vals: jax.Array, alive: jax.Array) -> jax.Array:
    """Full-length reconstruction: alive points keep their value, dead points
    take the line between their alive neighbors."""
    n = alive.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive)
    interp = interpolate_at(x_kept_vals, prev, nxt, idx)
    return jnp.where(alive, x_kept_vals, interp)


def _x_to_y_delta(delta_x: jax.Array, kappa: int, dt):
    if kappa == 1:
        return delta_x
    ny = delta_x.shape[0] // kappa
    return delta_x.reshape(ny, kappa).sum(axis=1) / jnp.asarray(kappa, dt)


# ---------------------------------------------------------------------------
# rounds mode (TPU-native batched greedy, padded-bucket fused rounds)
# ---------------------------------------------------------------------------

# Fixed-capacity eviction buffers for the tiered exact ranking: short
# segments (span <= _TIER_SMALL_W) are abundant and cheap, long ones rare
# and expensive.  Capacity overflow ranks +inf for *this* round only —
# accepted rounds or blocking free the slots, so every candidate is
# eventually ranked exactly.
_TIER_SMALL_W = 8


def _round_bucket(n: int, cfg: CameoConfig) -> int:
    """Padded length bucket for ``n`` (<= ~6% overhead, few distinct
    compiles across lengths, always a multiple of kappa)."""
    step = max(64, (1 << max(1, int(n - 1).bit_length())) // 16)
    nb = -(-n // step) * step
    if cfg.kappa > 1:
        nb = -(-nb // cfg.kappa) * cfg.kappa
    return nb


def _halting_params(n: int, cfg: CameoConfig):
    """(min_alive, eps) for the Def. 1/3 halting rules at true length n."""
    if cfg.target_cr is not None:
        min_alive = max(2, int(np.ceil(n / cfg.target_cr)))
        eps = np.inf
    else:
        min_alive = 2
        eps = float(cfg.eps)
    if cfg.max_cr is not None:
        min_alive = max(min_alive, int(np.ceil(n / cfg.max_cr)))
    return min_alive, eps


def _round_fns(cfg: CameoConfig, nb: int, n_valid: jax.Array,
               min_alive: jax.Array, eps: jax.Array, p0: jax.Array,
               tier_c: bool = True, tier_cond: bool = True,
               small_rounds="cond"):
    """``(cond, body)`` closures for the rounds loop at bucket size ``nb``.

    Shared by the run-to-completion program (:func:`_rounds_padded`) and the
    budgeted chunk program (:func:`_rounds_chunk`) that drives lane-compacted
    batching.  ``n_valid``/``min_alive``/``eps`` are (possibly per-lane
    traced) scalars and ``p0`` the [L] target stat; the aggregate rides the
    carry as the packed ``[5, L]`` moment table, so a round's accept gate and
    update are each one fused op instead of five.

    Each round runs as one fused pass: tiered exact Eq. 9 ranking into
    fixed-capacity buffers, top-k + independent-set selection, the
    prefix-deviation scan (kernels/fused_round) to pick the largest feasible
    prefix, and a dense exact Eq. 10/11 aggregate update as the
    authoritative accept check.

    ``tier_c=False`` compiles a variant with the wide-window (span > WB)
    ranking tier elided entirely.  Serial runs skip an empty tier through a
    ``lax.cond`` at run time, but under vmap a batched cond executes both
    branches every round — so the compacted batch driver starts on the
    elided program and watches the ``saw_c`` carry flag, which the body
    raises the moment any round's candidate set actually reaches the wide
    tier.  The driver then replays that chunk from its saved carry on the
    ``tier_c=True`` program, keeping results bit-identical to per-series
    runs (spans only grow, so the switch is one-way).

    ``small_rounds="cond"`` (default) adds a ``lax.cond`` fast path: when the
    candidate budget fits ``k_small``, the round runs a ``round_at``
    instantiation a third the size (shrunk ranking buffers too — tier
    overflow is correctness-neutral, unranked candidates retry next
    round).  The branch choice is trajectory-defining, so every program
    that can reach a small round must compile the same cond.  Late-game
    rounds dominate long compressions (hundreds of few-candidate rounds
    after the early mass removals), so the fast path is worth roughly a
    1.5x end-to-end speedup on real ingest traces.  Batched chunk
    programs pay both branches under vmap (cond lowers to a select), so
    the compacted driver watches for the moment *every* lane's candidate
    budget is provably pinned at or below ``k_small`` — ``n_alive`` only
    shrinks and ``alpha <= cfg.alpha`` always, making the small regime
    absorbing — and switches (one-way) to ``small_rounds="only"``: the
    small instantiation compiled unconditionally, bit-identical to the
    cond's taken branch from that point on.
    """
    dt = cfg.jdtype()
    L = cfg.lags
    kap = cfg.kappa
    W = cfg.window
    nyb = nb // kap
    idx = jnp.arange(nb, dtype=jnp.int32)
    inf = jnp.asarray(jnp.inf, dt)

    n_valid = n_valid.astype(jnp.int32)
    validm = idx < n_valid
    ny_valid = n_valid // kap

    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)

    def rows_dev(rows):
        p0r = p0.astype(rows.dtype)
        if cfg.stat == "acf" and cfg.measure in _ref.KERNEL_MEASURES:
            return _ref.measure_rows(rows, p0r, cfg.measure)
        return jax.vmap(lambda r: mfn(transform(r), p0r))(rows)

    k_max = max(1, min(int(cfg.alpha * nb), nb - 2))
    WB = max(2, min(_TIER_SMALL_W, W))
    # Tiered eviction-buffer capacities (overflow is correctness-neutral:
    # unranked candidates retry next round).  Deliberately lean: early big
    # rounds are all span-1 candidates ranked by the shared Eq. 8 pass, and
    # by the time segments outgrow span 1 the removal fraction has usually
    # backed off — so one small-capacity program serves every round, instead
    # of the historical large-round/endgame-round branch pair that doubled
    # the lowered op count (and ran both sides under vmap).
    cap_b = min(nb, max(24, nb // 24))
    cap_c = min(nb, max(16, nb // 48))
    # Small-round fast path (serial programs only, see docstring): a
    # third-size instantiation for the late-game rounds, entered only when
    # provably equivalent to the full one.
    k_small = max(8, min(k_max, 32))
    cap_b_s = min(cap_b, max(16, nb // 32))
    cap_c_s = min(cap_c, max(8, nb // 64))

    # Ranking runs in float32: it only orders the heuristic candidate
    # selection (every accepted removal is re-validated by the exact dense
    # update in the configured dtype), and single-precision halves the
    # bandwidth of the per-round O(nL) ranking kernels.
    rdt = jnp.float32

    def tier_impacts(mask, xr, yr, tbl_r, prev, nxt, Wt, cap):
        """Eq. 9 ranking impacts for the first ``cap`` mask positions; +inf
        elsewhere.  Returns (impact [nb], ranked-mask [nb])."""
        taken = jnp.cumsum(mask.astype(jnp.int32))
        ranked = mask & (taken <= cap)

        def some(_):
            # first cap true indices, in index order, via rank scatter
            # (cheaper than a top_k over nb); unfilled slots read nb and
            # are dropped on the write-back below.
            slots = jnp.full((cap,), nb, jnp.int32).at[
                jnp.where(ranked, taken - 1, cap)].set(idx, mode="drop")
            cand = jnp.clip(slots, 0, nb - 1)
            dwin, start, _ = segment_deltas(xr, prev, nxt, cand, Wt)
            dyw, ystart = _ops.x_window_to_y(cfg, dwin, start)
            acf_rows = _fused.window_rows(
                cfg, yr, dyw.astype(rdt), ystart, tbl_r, ny_valid, L=L)
            imp = rows_dev(acf_rows).astype(dt)
            return jnp.full((nb,), jnp.inf, dt).at[slots].set(
                imp, mode="drop")

        if tier_cond:
            # Tier classes are often empty (all spans start at 1 and only
            # grow as removals accumulate) — skip the whole ranking pass
            # then.  Worth it only in the serial program: under vmap the
            # batched cond lowers to select-over-both-branches, and the
            # select machinery costs more than the ranking pass it guards.
            imp_full = jax.lax.cond(
                jnp.any(mask), some,
                lambda _: jnp.full((nb,), jnp.inf, dt), operand=None)
        else:
            # Unconditional variant is bit-identical: with an empty mask the
            # rank scatter writes nothing and `some` returns all-inf, same
            # as the cond's false branch.
            imp_full = some(None)
        return imp_full, ranked

    def single_impacts(xr, yr, tbl_r, prev, nxt):
        """Eq. 8 single-delta impacts for every point (exact at span 1)."""
        xhat = interpolate_at(xr, prev, nxt, idx)
        dx = xhat - xr
        dval = dx if kap == 1 else dx / jnp.asarray(kap, dt)
        y_idx = idx // kap
        rows = _ref.acf_after_single_delta(
            tbl_r, yr, y_idx, dval.astype(rdt), ny=ny_valid)
        return rows_dev(rows).astype(dt)

    def cond(c):
        (xr, alive, prev, nxt, y, tbl, alpha, dev, rounds, done, blocked,
         retried, saw_c) = c
        return (~done) & (rounds < cfg.max_rounds) & \
            (jnp.sum(alive) > min_alive)

    def body(c):
        (xr, alive, prev, nxt, y, tbl, alpha, dev, rounds, done, blocked,
         retried, saw_c) = c
        n_alive = jnp.sum(alive)
        # Per-lane re-check of `cond`: under vmap (compress_batch) the body
        # keeps executing for lanes whose own loop has finished as long as
        # any lane is live; gating acceptance on `live` makes those extra
        # executions exact no-ops, so batched results match per-series runs.
        live = (~done) & (rounds < cfg.max_rounds) & (n_alive > min_alive)

        removable = alive & (idx > 0) & (idx < n_valid - 1)
        cand = removable & (~blocked)
        span = nxt - prev - 1
        # Raised (one-way) as soon as a live round's candidate set reaches
        # the wide-window tier — the compacted batch driver's signal to
        # replay this chunk on the tier_c=True program (see docstring).
        if cfg.rank != "single" and WB < W:
            saw_c = saw_c | (live & jnp.any(
                cand & (span > WB) & (span <= W)))

        y_r = y.astype(rdt)
        tbl_r = tbl.astype(rdt)
        imp_sd = single_impacts(xr, y_r, tbl_r, prev, nxt)
        k_cap = jnp.maximum(
            1, jnp.minimum(
                (alpha * n_alive.astype(dt)).astype(jnp.int32),
                (n_alive - min_alive).astype(jnp.int32),
            ),
        )

        def dense_apply(sel_idx_a, take):
            """Authoritative dense evaluation of removing the rank positions
            marked in ``take``."""
            sel = jnp.zeros((nb,), bool).at[sel_idx_a].set(take, mode="drop")
            alive_new = alive & (~sel)
            # The selection is an independent set, so the post-removal
            # neighbors come from a one-step pointer jump — no O(nb)
            # associative scans — and one vectorized interpolation pass
            # over the jumped pointers reproduces _reconstruct bit-for-bit
            # (unchanged dead points re-derive their stored value; moved
            # ones re-line against the inherited endpoints).
            prev_n, nxt_n = neighbors_after_removal(prev, nxt, sel)
            interp = interpolate_at(xr, prev_n, nxt_n, idx)
            xr_new = jnp.where(validm,
                               jnp.where(alive_new, xr, interp),
                               jnp.asarray(0.0, dt))
            dy = _x_to_y_delta(xr_new - xr, kap, dt)
            tbl_new = apply_delta_dense(tbl, y, dy, ny=ny_valid)
            dev_new = mfn(transform(acf_from_aggregates(tbl_new, ny_valid)),
                          p0)
            return dev_new, sel, alive_new, xr_new, dy, tbl_new, prev_n, nxt_n

        def round_at(k_rows: int, cb: int, cc: int):
            """Ranking + selection at one static problem size.  Outputs are
            padded to ``k_max`` so both size branches unify shapes."""
            def go(_):
                if cfg.rank == "single":
                    impact = jnp.where(cand, imp_sd, inf)
                    exact_ranked = cand & (span == 1)
                    overflowed = jnp.zeros((nb,), bool)
                else:
                    a_mask = cand & (span == 1)
                    b_mask = cand & (span >= 2) & (span <= WB)
                    imp_b, ranked_b = tier_impacts(
                        b_mask, xr, y_r, tbl_r, prev, nxt, WB, cb)
                    impact = jnp.where(a_mask, imp_sd, inf)
                    impact = jnp.where(b_mask, imp_b, impact)
                    exact_ranked = a_mask | (b_mask & ranked_b)
                    overflowed = b_mask & (~ranked_b)
                    if WB < W and tier_c:
                        c_mask = cand & (span > WB) & (span <= W)
                        imp_c, ranked_c = tier_impacts(
                            c_mask, xr, y_r, tbl_r, prev, nxt, W, cc)
                        impact = jnp.where(c_mask, imp_c, impact)
                        exact_ranked = exact_ranked | (c_mask & ranked_c)
                        overflowed = overflowed | (c_mask & (~ranked_c))
                    # Overgrown segments (span > W): unrankable exactly.
                    # Under a finite eps they stay unremovable; in the
                    # Def. 3 regime (eps = inf) the deviation never gates
                    # acceptance, so they are admitted with a large rank
                    # penalty (ordered by the Eq. 8 estimate) and validated
                    # by the dense authoritative update.
                    over_mask = cand & (span > W)
                    over_val = jnp.where(jnp.isfinite(eps), inf,
                                         jnp.asarray(1e30, dt) + imp_sd)
                    impact = jnp.where(over_mask, over_val, impact)

                # Rank keys in float32: CPU/TPU top_k has a fast path there,
                # and ranking order only steers the heuristic selection —
                # every removal is still validated by the exact dense update
                # in the configured dtype.
                neg_vals, sel_idx = jax.lax.top_k(
                    -impact.astype(jnp.float32), k_rows)
                finite = jnp.isfinite(-neg_vals)
                rank_ok = finite & (jnp.arange(k_rows) < k_cap)
                sel_all = jnp.zeros((nb,), bool).at[sel_idx].set(
                    rank_ok, mode="drop")
                sel_surv = _independent_set(sel_all, impact, alive, prev, nxt)
                # Independent-set survival is prefix-independent under the
                # (impact, idx) total order, so one survival pass serves
                # every prefix the selection below may choose.
                ok = sel_surv[sel_idx] & rank_ok

                ar0 = jnp.arange(k_rows)
                if cfg.select == "scan":
                    dwin_k, start_k, _ = segment_deltas(
                        xr, prev, nxt, sel_idx, W)
                    dyw_k, ystart_k = _ops.x_window_to_y(cfg, dwin_k, start_k)
                    if _ops._kernel_eligible(
                            cfg.backend, cfg.stat, cfg.measure) \
                            and not _ops.interpret_mode():
                        # Fused greedy kernel (real TPU): one VMEM pass walks
                        # the rank order, committing every candidate whose
                        # trial deviation on the exact running reconstruction
                        # fits and *skipping* violators.  The dense check
                        # below still gates the round, with the feasible
                        # prefix (greedy decisions up to the first skip) as
                        # the fallback proposal.
                        take_g, _ = _fused.greedy_feasible(
                            cfg, y, dyw_k, ystart_k, ok, tbl, p0,
                            ny_valid, eps)
                        out_a = dense_apply(sel_idx, take_g)
                        first_skip = jnp.min(jnp.where(
                            ok & (~take_g), ar0, jnp.int32(k_rows)))
                        take_pre = take_g & (ar0 < first_skip)
                        more = jnp.sum(take_g) > jnp.sum(take_pre)
                        out = jax.lax.cond(
                            (out_a[0] <= eps) | (~more),
                            lambda _: out_a,
                            lambda _: dense_apply(sel_idx, take_pre),
                            operand=None)
                        no_fit = ~jnp.any(take_g)
                    else:
                        # Linearized slack packing (reference path): score
                        # each survivor by the directional derivative of the
                        # deviation along its solo aggregate delta, sort by
                        # marginal ascending, and take the largest prefix
                        # whose projected deviation fits.  This packs the
                        # eps budget near-optimally — in particular it
                        # harvests the deviation-*reducing* candidates the
                        # rank-order grind would defer across many rounds —
                        # at the cost of one gradient plus one einsum.  The
                        # dense authoritative check gates the round; on a
                        # miss (linearization error) the proposal halves up
                        # to three times.
                        def dev_of_table(t5):
                            return mfn(transform(
                                acf_from_aggregates(t5, ny_valid)), p0)
                        gtbl = jax.grad(dev_of_table)(tbl)
                        dagg = _fused.solo_moment_rows(
                            y, dyw_k, ystart_k, ny_valid, L=L)
                        g = jnp.einsum("al,kal->k", gtbl, dagg)
                        gi = jnp.where(ok, g, inf)
                        order = jnp.argsort(gi)
                        gs = gi[order]
                        csum = jnp.cumsum(
                            jnp.where(jnp.isfinite(gs), gs,
                                      jnp.asarray(0.0, dt)))
                        pred = dev + csum
                        kidx = jnp.arange(1, k_rows + 1, dtype=jnp.int32)
                        finite_g = jnp.isfinite(gs)
                        rank_pos = jnp.zeros((k_rows,), jnp.int32).at[
                            order].set(ar0.astype(jnp.int32))

                        def at_k(k):
                            return dense_apply(sel_idx, ok & (rank_pos < k))

                        # Bracketed Newton search for the max dense-feasible
                        # prefix of the g-order: each dense probe calibrates
                        # the linearization bias `err`, the re-pack proposes
                        # the largest prefix fitting the corrected budget,
                        # clipped into the open feasible/infeasible bracket
                        # (degenerating to bisection when the model stalls).
                        n_ok = jnp.sum(finite_g).astype(jnp.int32)
                        out_empty = (dev, jnp.zeros((nb,), bool), alive,
                                     xr, jnp.zeros((nyb,), dt), tbl,
                                     prev, nxt)

                        # A while_loop (not a fixed fori_loop): the bracket
                        # usually closes after one or two dense probes, and a
                        # while stops there — crucially also under vmap,
                        # where a fori would charge every lane the full probe
                        # budget every round (a cond inside a batched loop
                        # runs both branches).
                        def probe_cond(carry):
                            it, k_lo, out_lo, k_hi, err = carry
                            return (it < 4) & ((k_hi - k_lo) > 1)

                        def probe(carry):
                            it, k_lo, out_lo, k_hi, err = carry
                            k_p = jnp.max(jnp.where(
                                finite_g & (pred + err <= eps), kidx,
                                jnp.int32(0)))
                            k_p = jnp.clip(k_p, k_lo + 1, k_hi - 1)
                            out_p = at_k(k_p)
                            fits = out_p[0] <= eps
                            err = out_p[0] - pred[jnp.maximum(k_p - 1, 0)]
                            out_lo = jax.tree.map(
                                lambda a, b: jnp.where(fits, a, b),
                                out_p, out_lo)
                            return (it + 1, jnp.where(fits, k_p, k_lo),
                                    out_lo, jnp.where(fits, k_hi, k_p), err)

                        _, k_lo, out, _, _ = jax.lax.while_loop(
                            probe_cond, probe,
                            (jnp.int32(0), jnp.int32(0), out_empty,
                             n_ok + 1, jnp.asarray(0.0, dt)))
                        no_fit = k_lo == 0
                elif cfg.select == "bisect":
                    def probe(_, lohi):
                        lo, hi = lohi
                        mid = (lo + hi + 1) // 2
                        dev_mid = dense_apply(sel_idx, ok & (ar0 < mid))[0]
                        fits = dev_mid <= eps
                        return (jnp.where(fits, mid, lo),
                                jnp.where(fits, hi, mid - 1))
                    lo, _ = jax.lax.fori_loop(
                        0, cfg.bisect_probes, probe,
                        (jnp.asarray(0, jnp.int32),
                         jnp.minimum(k_cap, k_rows).astype(jnp.int32)))
                    out = dense_apply(sel_idx, ok & (ar0 < lo))
                    no_fit = lo == 0
                else:                           # "backoff"
                    kf = jnp.minimum(k_cap, k_rows).astype(jnp.int32)
                    out = dense_apply(sel_idx, ok & (ar0 < kf))
                    no_fit = ~jnp.any(ok)
                return out + (impact, exact_ranked, overflowed,
                              sel_idx[0], finite[0], no_fit)
            return go

        if small_rounds == "only" and k_small < k_max:
            # Compiled only by the compacted batch driver once every lane
            # is provably inside the small regime (see docstring).
            (dev_new, sel, alive_new, xr_new, dy, agg_new, prev_new,
             nxt_new, impact, exact_ranked, overflowed, best_idx, finite0,
             no_fit) = round_at(k_small, cap_b_s, cap_c_s)(None)
        elif small_rounds and k_small < k_max:
            (dev_new, sel, alive_new, xr_new, dy, agg_new, prev_new,
             nxt_new, impact, exact_ranked, overflowed, best_idx, finite0,
             no_fit) = jax.lax.cond(
                k_cap <= k_small,
                round_at(k_small, cap_b_s, cap_c_s),
                round_at(k_max, cap_b, cap_c),
                operand=None)
        else:
            (dev_new, sel, alive_new, xr_new, dy, agg_new, prev_new,
             nxt_new, impact, exact_ranked, overflowed, best_idx, finite0,
             no_fit) = round_at(k_max, cap_b, cap_c)(None)
        n_sel = jnp.sum(sel)
        any_sel = n_sel > 0
        accept = (dev_new <= eps) & any_sel & live
        reject = (~accept) & live

        was_single = n_sel <= 1
        if cfg.stop_policy == "first_violation":
            done_new = done | (live & (((~accept) & was_single) | no_fit))
            blocked_new = blocked
            retried_new = retried
        else:
            # exhaustive: a rejected round proves every exactly-ranked
            # candidate with impact > eps cannot fit alone at the current
            # state — block them all at once, with the best candidate as a
            # backstop so no-progress rounds cannot repeat.  Blocks persist
            # across accepts (the deviation headroom only shrinks as
            # removals accumulate, so a once-unfit candidate rarely becomes
            # fit); when the candidate pool is exhausted, all blocks are
            # dropped once and the search retried from scratch — only a
            # second back-to-back exhaustion terminates.
            mass = exact_ranked & (impact > eps)
            bump = (blocked | mass).at[best_idx].set(True)
            blocked_new = jnp.where(reject & finite0, bump, blocked)
            avail = removable & (~blocked_new) & \
                (jnp.isfinite(impact) | overflowed)
            exhausted = reject & (~jnp.any(avail))
            clear_now = exhausted & (~retried)
            blocked_new = jnp.where(clear_now, jnp.zeros_like(blocked),
                                    blocked_new)
            retried_new = jnp.where(accept, jnp.asarray(False),
                                    retried | clear_now)
            done_new = done | (exhausted & retried)
        if cfg.select == "backoff":
            alpha_new = jnp.where(accept, jnp.minimum(alpha * 1.1, cfg.alpha),
                                  jnp.maximum(alpha * 0.5,
                                              jnp.asarray(1.5 / nb, dt)))
        else:
            alpha_new = alpha

        xr_out = jnp.where(accept, xr_new, xr)
        alive_out = jnp.where(accept, alive_new, alive)
        prev_out = jnp.where(accept, prev_new, prev)
        nxt_out = jnp.where(accept, nxt_new, nxt)
        y_out = jnp.where(accept, y + dy, y)
        tbl_out = jnp.where(accept, agg_new, tbl)
        dev_out = jnp.where(accept, dev_new, dev)
        return (xr_out, alive_out, prev_out, nxt_out, y_out, tbl_out,
                alpha_new, dev_out, rounds + live.astype(jnp.int32),
                done_new, blocked_new, retried_new, saw_c)

    return cond, body


def _rounds_init(xp: jax.Array, n_valid: jax.Array, cfg: CameoConfig):
    """Initial rounds carry + target stat ``p0`` for one padded series
    (plain traced function — callers jit)."""
    dt = cfg.jdtype()
    nb = xp.shape[0]
    idx = jnp.arange(nb, dtype=jnp.int32)
    n_valid = n_valid.astype(jnp.int32)
    validm = idx < n_valid
    xp = jnp.where(validm, xp.astype(dt), jnp.asarray(0.0, dt))
    ny_valid = n_valid // cfg.kappa
    y0 = aggregate_series(xp, cfg.kappa)
    agg0 = extract_aggregates_masked(y0, cfg.lags, ny_valid,
                                     backend=cfg.backend)
    tbl0 = _ops.agg_to_table(agg0)
    p0 = _stat_transform(cfg)(acf_from_aggregates(agg0, ny_valid))
    alive0 = validm
    prev0, nxt0 = alive_neighbors(alive0)
    carry = (xp, alive0, prev0, nxt0, y0, tbl0, jnp.asarray(cfg.alpha, dt),
             jnp.asarray(0.0, dt), jnp.asarray(0, jnp.int32),
             jnp.asarray(False), jnp.zeros((nb,), bool), jnp.asarray(False),
             jnp.asarray(False))
    return carry, p0


def _rounds_result(carry, n_valid: jax.Array, p0: jax.Array,
                   cfg: CameoConfig) -> CompressResult:
    """Final carry → ``CompressResult`` (plain traced function)."""
    (xr, alive, _, _, _, tbl, _, dev, rounds, _, _, _, _) = carry
    ny_valid = n_valid.astype(jnp.int32) // cfg.kappa
    stat_new = _stat_transform(cfg)(acf_from_aggregates(tbl, ny_valid))
    return CompressResult(
        kept=alive, xr=xr, deviation=dev, n_kept=jnp.sum(alive),
        iters=rounds, stat_orig=p0, stat_new=stat_new)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _rounds_padded(xp: jax.Array, n_valid: jax.Array, min_alive: jax.Array,
                   eps: jax.Array, cfg: CameoConfig) -> CompressResult:
    """Rounds mode over a zero-padded bucket ``xp [nb]`` with runtime valid
    length ``n_valid`` — one compiled program per (bucket, cfg), running the
    whole elimination to completion in a single ``lax.while_loop``."""
    carry, p0 = _rounds_init(xp, n_valid, cfg)
    cond, body = _round_fns(cfg, xp.shape[0], n_valid, min_alive, eps, p0)
    final = jax.lax.while_loop(cond, body, carry)
    return _rounds_result(final, n_valid, p0, cfg)


def _rounds_chunk(carry, n_valid, min_alive, eps, p0, cfg: CameoConfig,
                  budget: int, tier_c: bool = True, tier_cond: bool = True,
                  small_rounds="cond"):
    """Advance the rounds loop by at most ``budget`` rounds.

    Returns ``(carry', live)`` where ``live`` is the per-lane continuation
    flag (True while the loop would keep going).  The chunk-step counter is
    a scalar shared across vmapped lanes, so a batched chunk stops early
    the moment every lane is done — finished lanes inside a chunk execute
    the body as exact no-ops (the same ``live`` gating that makes vmapped
    results bit-identical to per-series runs).
    """
    nb = carry[0].shape[0]
    cond, body = _round_fns(cfg, nb, n_valid, min_alive, eps, p0,
                            tier_c=tier_c, tier_cond=tier_cond,
                            small_rounds=small_rounds)

    def ccond(tc):
        t, c = tc
        return (t < budget) & cond(c)

    def cbody(tc):
        t, c = tc
        return t + 1, body(c)

    _, out = jax.lax.while_loop(
        ccond, cbody, (jnp.asarray(0, jnp.int32), carry))
    return out, cond(out)


def compress_rounds(x: jax.Array, cfg: CameoConfig, *,
                    pad_to: Optional[int] = None) -> CompressResult:
    """Rounds-mode compression of one series.

    The series is zero-padded to a shape bucket (see ``_round_bucket``) and
    compressed with its true length as a runtime scalar, so nearby lengths
    share one compiled program.  ``pad_to`` forces at least that bucket —
    streaming callers pass their full window length so a partial tail
    window reuses the full-window program (no per-length recompiles).
    """
    dt = cfg.jdtype()
    x = jnp.asarray(x, dt)
    n = x.shape[0]
    if cfg.kappa > 1 and n % cfg.kappa:
        raise ValueError(f"length {n} not divisible by kappa={cfg.kappa}")
    nb = _round_bucket(max(n, int(pad_to or 0)), cfg)
    xp = jnp.pad(x, (0, nb - n)) if nb > n else x
    min_alive, eps = _halting_params(n, cfg)
    res = _rounds_padded(
        xp, jnp.asarray(n, jnp.int32), jnp.asarray(min_alive, jnp.int32),
        jnp.asarray(eps, dt), cfg)
    if nb == n:
        return res
    return res._replace(kept=res.kept[:n], xr=res.xr[:n])


# the rounds program is the streaming hot path: its compiled-variant count
# is the original no-recompile watermark (see repro.obs.recompile_watermark)
OBS.register_jit("cameo.rounds", _rounds_padded)


# ---------------------------------------------------------------------------
# sequential mode (paper-faithful Algorithm 1)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def compress_sequential(x: jax.Array, cfg: CameoConfig) -> CompressResult:
    dt = cfg.jdtype()
    x = x.astype(dt)
    n = x.shape[0]
    L = cfg.lags
    W = cfg.window
    h = cfg.hops
    kap = cfg.kappa
    y0 = aggregate_series(x, kap)
    ny = y0.shape[0]
    agg0 = extract_aggregates(y0, L, backend=cfg.backend)
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    p0 = transform(acf_from_aggregates(agg0, ny))
    inf = jnp.asarray(jnp.inf, dt)

    if cfg.target_cr is not None:
        min_alive = max(2, int(np.ceil(n / cfg.target_cr)))
        eps = inf
    else:
        min_alive = 2
        eps = jnp.asarray(cfg.eps, dt)
    if cfg.max_cr is not None:
        min_alive = max(min_alive, int(np.ceil(n / cfg.max_cr)))
    max_iters = cfg.max_iters if cfg.max_iters is not None else (n - min_alive)

    # y-window size for kappa>1 windowed updates.
    Wy = W if kap == 1 else (W // kap + 2)

    def trial(agg, y, xr, prev, nxt, i):
        """Exact Eq. 9 trial removal of point i (segment (prev[i], nxt[i]));
        the impact-engine provides the delta geometry, the incremental
        aggregate update validates the removal exactly."""
        dwin, start, span = segment_deltas(xr, prev, nxt, i, W)
        dyw, ystart = _ops.x_window_to_y(cfg, dwin, start)
        agg_t = apply_delta_window(agg, y, dyw, ystart, W=Wy, L=L)
        dev_t = mfn(transform(acf_from_aggregates(agg_t, ny)), p0)
        return agg_t, dev_t, dwin, dyw, start, ystart, span <= W

    def collect_neighbors(prev, nxt, p, q):
        """h alive indices walking left from p and right from q (incl. p, q)."""
        # left walk
        def left_body(i, acc):
            ids, ptr = acc
            ids = ids.at[i].set(ptr)
            ptr = jnp.clip(prev[jnp.clip(ptr, 0, n - 1)], -1, n - 1)
            ptr = jnp.where(ptr < 0, jnp.int32(0), ptr)
            return ids, ptr
        ids_l, _ = jax.lax.fori_loop(
            0, h + 1, left_body,
            (jnp.zeros((h + 1,), jnp.int32), jnp.clip(p, 0, n - 1)))
        def right_body(i, acc):
            ids, ptr = acc
            ids = ids.at[i].set(ptr)
            ptr = jnp.clip(nxt[jnp.clip(ptr, 0, n - 1)], 0, n)
            ptr = jnp.where(ptr >= n, jnp.int32(n - 1), ptr)
            return ids, ptr
        ids_r, _ = jax.lax.fori_loop(
            0, h + 1, right_body,
            (jnp.zeros((h + 1,), jnp.int32), jnp.clip(q, 0, n - 1)))
        return jnp.concatenate([ids_l, ids_r])

    def init_impacts(agg, y, xr, prev, nxt):
        # Exact impacts are O(nWL) to initialize; Algorithm 2 initializes with
        # the O(nL) single-delta form, which is exact while all points are
        # alive (every segment has span 1).  We do the same.
        alive = jnp.ones((n,), bool)
        return _ops.ranking_impact(cfg, agg, y, xr, alive, p0, n,
                                   rank="single")

    def cond(c):
        (xr, alive, prev, nxt, imp, agg, y, dev, it, done) = c
        return (~done) & (it < max_iters) & (jnp.sum(alive) > min_alive)

    def body(c):
        (xr, alive, prev, nxt, imp, agg, y, dev, it, done) = c
        i = jnp.argmin(imp)
        best = imp[i]
        p, q = prev[i], nxt[i]
        agg_t, dev_t, dwin, dyw, start, ystart, valid = trial(
            agg, y, xr, prev, nxt, i)

        can_remove = jnp.isfinite(best) & valid & (dev_t <= eps)
        # Algorithm 1 stops at the first violation, which is sound when the
        # heap is fresh; under blocking the popped impact can be stale (the
        # paper's ReHeap keeps neighborhoods fresh, but distant entries age),
        # so a stale pop would end the run prematurely.  We block the
        # offending candidate (impact=inf; ReHeap revives neighbors later)
        # and stop only when no finite candidate remains.  With
        # stop_policy="first_violation" the paper's literal semantics apply.
        if cfg.stop_policy == "first_violation":
            violation = jnp.isfinite(best) & valid & (dev_t > eps)
            done_new = done | violation | (~jnp.isfinite(best))
        else:
            done_new = done | (~jnp.isfinite(best))

        # apply removal (no-ops when rejected)
        def windowed_add(arr, win, st, Wn):
            """arr[st + j] += win[j] with clamp-safe shifting near the end."""
            size = arr.shape[0]
            offset = jnp.clip(st, 0, size - Wn)
            shift = st - offset
            k = jnp.arange(Wn)
            buf = jnp.where(k >= shift, win[jnp.clip(k - shift, 0, Wn - 1)], 0.0)
            return jax.lax.dynamic_update_slice(
                arr, jax.lax.dynamic_slice(arr, (offset,), (Wn,)) + buf, (offset,))

        def apply(_):
            xr2 = windowed_add(xr, dwin, start, W)
            alive2 = alive.at[i].set(False)
            prev2 = prev.at[q].set(p, mode="drop")
            nxt2 = nxt.at[p].set(q, mode="drop")
            y2 = windowed_add(y, dyw, ystart, Wy)
            imp2 = imp.at[i].set(inf)
            # ReHeap: exact impact recompute for h alive neighbors per side,
            # through the impact-engine backend (exact Eq. 9 ranking).
            nbrs = collect_neighbors(prev2, nxt2, p, q)
            new_imps = _ops.window_impact_at(
                cfg, agg_t, y2, xr2, prev2, nxt2, nbrs, p0)
            # only alive points get updates (dedup: later writes win, values
            # identical for duplicated indices so order is irrelevant)
            alive_n = alive2[nbrs]
            imp2 = imp2.at[nbrs].set(
                jnp.where(alive_n, new_imps, imp2[nbrs]), mode="drop")
            return xr2, alive2, prev2, nxt2, imp2, agg_t, y2, dev_t

        def reject(_):
            # rejected candidates (span overflow or eps violation under the
            # skip policy) become unremovable until a ReHeap revives them
            imp2 = imp.at[i].set(inf)
            return xr, alive, prev, nxt, imp2, agg, y, dev

        xr2, alive2, prev2, nxt2, imp2, agg2, y2, dev2 = jax.lax.cond(
            can_remove, apply, reject, operand=None)
        return (xr2, alive2, prev2, nxt2, imp2, agg2, y2, dev2,
                it + 1, done_new)

    idx = jnp.arange(n, dtype=jnp.int32)
    prev0 = idx - 1
    nxt0 = idx + 1
    imp0 = init_impacts(agg0, y0, x, prev0, nxt0)
    init = (x, jnp.ones((n,), bool), prev0, nxt0, imp0, agg0, y0,
            jnp.asarray(0.0, dt), jnp.asarray(0, jnp.int32),
            jnp.asarray(False))
    xr, alive, prev, nxt, imp, agg, y, dev, it, _ = jax.lax.while_loop(
        cond, body, init)
    stat_new = transform(acf_from_aggregates(agg, ny))
    return CompressResult(
        kept=alive, xr=xr, deviation=dev, n_kept=jnp.sum(alive),
        iters=it, stat_orig=p0, stat_new=stat_new)


OBS.register_jit("cameo.sequential", compress_sequential)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def compress(x, cfg: CameoConfig) -> CompressResult:
    """Compress ``x`` under ``cfg``.  Trims a tail remainder so the length is
    divisible by ``kappa`` (the trimmed points are kept verbatim by callers
    that need exact framing; the registry uses divisible lengths)."""
    x = jnp.asarray(x)
    if cfg.kappa > 1:
        n = (x.shape[0] // cfg.kappa) * cfg.kappa
        x = x[:n]
    if cfg.mode == "rounds":
        return compress_rounds(x, cfg)
    if cfg.mode == "sequential":
        return compress_sequential(x, cfg)
    raise ValueError(f"unknown mode {cfg.mode!r}")


@functools.partial(jax.jit, static_argnames=("cfg",))
def _batch_init(xps, n_valid, cfg: CameoConfig):
    return jax.vmap(lambda x, nv: _rounds_init(x, nv, cfg))(xps, n_valid)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "budget", "tier_c", "small"))
def _batch_chunk(carry, n_valid, min_alive, eps, p0, cfg: CameoConfig,
                 budget: int, tier_c: bool = True, small="cond"):
    # Batched chunks always compile with tier_cond=False: under vmap the
    # empty-tier `lax.cond` lowers to a select over both branches and costs
    # more than running the ranking pass unconditionally.
    return jax.vmap(
        lambda c, nv, ma, ep, p: _rounds_chunk(c, nv, ma, ep, p, cfg, budget,
                                               tier_c=tier_c, tier_cond=False,
                                               small_rounds=small)
    )(carry, n_valid, min_alive, eps, p0)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "budget", "tier_c", "small"))
def _batch_chunk_gathered(carry, consts, sel, cfg: CameoConfig,
                          budget: int, tier_c: bool = True, small="cond"):
    """One fused super-round for a compacted lane subset: gather the lanes
    named by ``sel`` out of the full carry, advance them ``budget`` rounds,
    and scatter the results back — all in one compiled program, so the host
    driver pays a single dispatch per super-round instead of two eager
    tree-sized gather/scatter passes.  Padding duplicates in ``sel`` (the
    pow-2 bucket fill) recompute the same lane deterministically, so the
    duplicate scatter writes are value-identical and order-independent."""
    sub = jax.tree.map(lambda a: a[sel], carry)
    subc = jax.tree.map(lambda a: a[sel], consts)
    sub, live = jax.vmap(
        lambda c, nv, ma, ep, p: _rounds_chunk(c, nv, ma, ep, p, cfg, budget,
                                               tier_c=tier_c, tier_cond=False,
                                               small_rounds=small)
    )(sub, *subc)
    carry = jax.tree.map(lambda full, s: full.at[sel].set(s), carry, sub)
    return carry, live


@functools.partial(jax.jit, static_argnames=("cfg",))
def _batch_result(carry, n_valid, p0, cfg: CameoConfig):
    return jax.vmap(lambda c, nv, p: _rounds_result(c, nv, p, cfg))(
        carry, n_valid, p0)


OBS.register_jit("cameo.batch_init", _batch_init)
OBS.register_jit("cameo.batch_chunk", _batch_chunk)
OBS.register_jit("cameo.batch_result", _batch_result)

# Rounds advanced per compacted super-round: small enough that finished
# lanes drop out of the working set quickly, large enough that the host
# sync + gather/scatter per super-round stays amortized.
_BATCH_CHUNK_ROUNDS = 8


def _next_pow2(k: int) -> int:
    return 1 << max(0, (int(k) - 1)).bit_length()


def _compress_batch_compacted(xs: jax.Array, cfg: CameoConfig,
                              pad_to: Optional[int]) -> CompressResult:
    """Host-driven lane-compacted batch: the jitted chunk program advances
    every lane up to ``_BATCH_CHUNK_ROUNDS`` rounds, the host reads the
    per-lane live flags, and the next super-round gathers only the still-
    live lanes into the smallest power-of-two bucket (padded by duplicating
    a live lane, whose copy is discarded on scatter-back).  Finished lanes
    stop paying for the round body entirely — under plain vmap they execute
    both branches of every round conditional until the slowest lane drains.

    Per-lane math is untouched (same ``_round_fns`` body), so results stay
    bit-identical to per-series ``compress_rounds`` runs; the differential
    harness in ``tests/test_backend.py`` pins that.
    """
    dt = cfg.jdtype()
    B, n = xs.shape
    nb = _round_bucket(max(n, int(pad_to or 0)), cfg)
    xp = jnp.asarray(xs, dt)
    if nb > n:
        xp = jnp.pad(xp, ((0, 0), (0, nb - n)))
    min_alive, eps = _halting_params(n, cfg)
    nv = jnp.full((B,), n, jnp.int32)
    ma = jnp.full((B,), min_alive, jnp.int32)
    ep = jnp.full((B,), eps, dt)
    carry, p0 = _batch_init(xp, nv, cfg)
    consts = (nv, ma, ep, p0)

    live = np.ones(B, bool)
    occ_active = occ_slots = 0
    # Start on the program with the wide-window ranking tier compiled out —
    # under vmap the elided tier would otherwise run every round for every
    # lane, empty or not.  The first chunk whose body actually reaches the
    # tier (saw_c carry flag) is replayed from its saved carry on the full
    # program; spans only grow, so the switch is one-way and the replayed
    # trajectory is bit-identical to a per-series run.
    need_c = cfg.rank == "single"
    # The small-rounds cond (see _round_fns) runs both branches under vmap,
    # so chunks start on the dual-branch program and switch — one-way — to
    # the small-instantiation-only program once every live lane's candidate
    # budget is provably pinned at or below k_small: k_cap is bounded by
    # min(int(cfg.alpha * n_alive), n_alive - min_alive), n_alive only
    # shrinks, and alpha never exceeds cfg.alpha, so the regime is
    # absorbing and the switched trajectory stays bit-identical to the
    # serial cond's taken branch.
    k_max = max(1, min(int(cfg.alpha * nb), nb - 2))
    k_small = max(8, min(k_max, 32))
    small = "cond"

    def all_small(lanes):
        if small == "only" or k_small >= k_max:
            return small
        n_alive = np.asarray(jnp.sum(carry[1][lanes], axis=-1))
        ma_l = np.asarray(ma)[lanes]
        bound = np.minimum(
            (np.asarray(cfg.alpha, dt) *
             n_alive.astype(dt)).astype(np.int32),
            (n_alive - ma_l).astype(np.int32))
        return "only" if bool(np.all(bound <= k_small)) else "cond"

    while live.any():
        active = np.nonzero(live)[0]
        na = len(active)
        bucket = min(B, _next_pow2(na))
        saved = carry
        if bucket == B:
            # every lane live: no gather/scatter, run the chunk in place
            small = all_small(active)
            carry, sub_live = _batch_chunk(carry, *consts, cfg=cfg,
                                           budget=_BATCH_CHUNK_ROUNDS,
                                           tier_c=need_c, small=small)
            if not need_c and bool(np.asarray(carry[12]).any()):
                need_c = True
                carry, sub_live = _batch_chunk(saved, *consts, cfg=cfg,
                                               budget=_BATCH_CHUNK_ROUNDS,
                                               tier_c=True, small=small)
            live[:] = np.asarray(sub_live)
        else:
            sel = np.concatenate(
                [active, np.full(bucket - na, active[0])])
            sel_j = jnp.asarray(sel, jnp.int32)
            small = all_small(active)
            carry, sub_live = _batch_chunk_gathered(
                carry, consts, sel_j, cfg=cfg,
                budget=_BATCH_CHUNK_ROUNDS, tier_c=need_c, small=small)
            if not need_c and bool(np.asarray(carry[12][sel_j]).any()):
                need_c = True
                carry, sub_live = _batch_chunk_gathered(
                    saved, consts, sel_j, cfg=cfg,
                    budget=_BATCH_CHUNK_ROUNDS, tier_c=True, small=small)
            live[active] = np.asarray(sub_live)[:na]
        occ_active += na
        occ_slots += bucket

    res = _batch_result(carry, nv, p0, cfg)
    if OBS.enabled:
        OBS.inc("cameo.batch_rounds_total",
                int(np.asarray(jnp.sum(res.iters))))
        OBS.gauge("cameo.batch_lane_occupancy",
                  occ_active / occ_slots if occ_slots else 1.0)
    if nb > n:
        res = res._replace(kept=res.kept[:, :n], xr=res.xr[:, :n])
    return res


def compress_batch(xs, cfg: CameoConfig, mesh=None,
                   axis: str = "data", *,
                   pad_to: Optional[int] = None) -> CompressResult:
    """Batched multi-series compression — the fleet-of-sensors workload.

    ``xs`` is ``[B, n]`` (B independent series of equal length); returns a
    ``CompressResult`` whose leaves carry a leading batch axis.  Built on the
    ``rounds`` mode: per-series results are bit-identical to
    ``compress_rounds(xs[b], cfg)``.  Off-TPU the batch runs lane-compacted
    (see :func:`_compress_batch_compacted`): finished lanes are dropped from
    the working set between jitted chunks, so a mixed-convergence batch pays
    for the slowest lane only at its own width.  On TPU (or with ``mesh``)
    the whole loop stays device-resident under vmap/``shard_map`` — with
    ``mesh`` given, the batch is sharded over ``mesh.shape[axis]`` devices
    (B must divide evenly); each device vmaps its local shard.
    """
    xs = jnp.asarray(xs)
    if xs.ndim != 2:
        raise ValueError(f"compress_batch wants [B, n], got {xs.shape}")
    if cfg.mode != "rounds":
        raise ValueError("compress_batch batches the rounds mode; got "
                         f"mode={cfg.mode!r}")
    if cfg.kappa > 1:
        n = (xs.shape[1] // cfg.kappa) * cfg.kappa
        xs = xs[:, :n]
    if mesh is None:
        if xs.shape[0] > 1 and jax.default_backend() != "tpu":
            return _compress_batch_compacted(xs, cfg, pad_to)
        return jax.vmap(lambda x: compress_rounds(x, cfg, pad_to=pad_to))(xs)
    batched = jax.vmap(lambda x: compress_rounds(x, cfg, pad_to=pad_to))
    from jax.sharding import PartitionSpec as P
    from repro import sharding as shd
    T = mesh.shape[axis]
    if xs.shape[0] % T:
        raise ValueError(f"batch {xs.shape[0]} not divisible over "
                         f"{T} devices on axis {axis!r}")
    sharded = shd.shard_map(batched, mesh=mesh, in_specs=P(axis),
                            out_specs=P(axis))
    return jax.jit(sharded)(xs)


class MVCompressResult(NamedTuple):
    """Multivariate compression result: one shared kept-index stream, per-
    column values re-evaluated on it (see :func:`compress_multivariate`)."""

    kept: np.ndarray        # bool [n] — shared union kept mask
    xr: np.ndarray          # float [n, C] — per-column reconstructions
    deviation: float        # max per-column deviation (the stored headline)
    n_kept: int             # |union|
    iters: int              # total compressor rounds/removals across columns
    deviations: np.ndarray  # [C] exact measured per-column deviation
    col_n_kept: np.ndarray  # [C] per-column own kept counts (pre-union)


def _column_masks(X: np.ndarray, cfg: CameoConfig, eps_c: np.ndarray,
                  cols, pad_to: Optional[int] = None) -> tuple:
    """(masks[C, n] for the requested ``cols``, iters) — rounds mode batches
    same-eps columns through ``compress_batch``; anything else runs
    per-column ``compress``.  ``pad_to`` rides through to the rounds bucket
    (streaming tails reuse the full-window program)."""
    import jax as _jax

    masks = {}
    iters = 0
    cols = list(cols)
    if cfg.mode == "rounds":
        by_eps = {}
        for c in cols:
            by_eps.setdefault(float(eps_c[c]), []).append(c)
        for eps, group in by_eps.items():
            gcfg = dataclasses.replace(cfg, eps=eps)
            if len(group) > 1:
                res = compress_batch(X[:, group].T, gcfg, pad_to=pad_to)
                _jax.block_until_ready(res.kept)
                for i, c in enumerate(group):
                    masks[c] = np.asarray(res.kept[i])
                    iters += int(res.iters[i])
            else:
                res = compress_rounds(jnp.asarray(X[:, group[0]]), gcfg,
                                      pad_to=pad_to)
                masks[group[0]] = np.asarray(res.kept)
                iters += int(res.iters)
    else:
        for c in cols:
            res = compress(jnp.asarray(X[:, c]),
                           dataclasses.replace(cfg, eps=float(eps_c[c])))
            masks[c] = np.asarray(res.kept)
            iters += int(res.iters)
    return masks, iters


_mv_recon_jit = None


def _union_reconstruct(x_col: np.ndarray, union: np.ndarray) -> np.ndarray:
    """Canonical one-shot interpolation of one column on the shared index —
    the same jitted ``_reconstruct`` the store decode uses, so the measured
    per-column deviation is exact for what readers will actually see."""
    global _mv_recon_jit
    if _mv_recon_jit is None:
        _mv_recon_jit = jax.jit(_reconstruct)
        OBS.register_jit("cameo.mvar_reconstruct", _mv_recon_jit)
    return np.asarray(_mv_recon_jit(jnp.asarray(x_col), jnp.asarray(union)))


def _column_deviation(x_col: np.ndarray, xr_col: np.ndarray,
                      cfg: CameoConfig) -> float:
    """Exact measured D(S(recon), S(orig)) of one column (Eq. 7 path)."""
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    y0 = aggregate_series(jnp.asarray(x_col, cfg.jdtype()), cfg.kappa)
    y1 = aggregate_series(jnp.asarray(xr_col, cfg.jdtype()), cfg.kappa)
    ny = int(y0.shape[0])
    s0 = transform(acf_from_aggregates(
        extract_aggregates(y0, cfg.lags, backend=cfg.backend), ny))
    s1 = transform(acf_from_aggregates(
        extract_aggregates(y1, cfg.lags, backend=cfg.backend), ny))
    return float(mfn(s1, s0))


def compress_multivariate(X, cfg: CameoConfig, *,
                          eps_c=None, max_retries: int = 4,
                          pad_to: Optional[int] = None) -> MVCompressResult:
    """Compress a multivariate series ``X [n, C]`` onto one shared index.

    The Sprintz-style shared-timestamp layout: every column is compressed
    independently (``compress_batch`` over the columns in rounds mode), the
    per-column kept masks are **unioned** into a single index stream, and
    every column is then *re-evaluated on the shared index* — its stored
    values are the original ``X[idx, c]`` at every union index, so each
    column's reconstruction interpolates through strictly more original
    points than its own greedy solution kept.

    The per-column ε guarantee is *enforced by measurement*, not assumed:
    each column's exact deviation is recomputed on the shared index, and a
    column that exceeds its budget (possible in principle — the ACF is not
    monotone in pointwise error) is recompressed at half its working budget
    and the union rebuilt, up to ``max_retries`` times; a still-violating
    column finally keeps all of its points (deviation exactly 0).  With
    ``target_cr`` set there is no ε to enforce and the measured deviations
    are reported as-is.

    ``eps_c`` (length-C) gives each column its own ε budget — channels with
    different fidelity needs share one index stream while each column's
    deviation is enforced against *its* budget (``None``: every column uses
    ``cfg.eps``).  ``pad_to`` rides through to the rounds shape bucket so
    streaming tail windows reuse the full-window compiled program.

    Returns an :class:`MVCompressResult` whose ``kept``/``xr`` feed
    ``CameoStore.append_series`` (v4 shared-index block layout) directly.
    """
    X = np.asarray(X)
    if X.ndim != 2:
        raise ValueError(f"compress_multivariate wants [n, C], got {X.shape}")
    if cfg.kappa > 1:
        X = X[:(X.shape[0] // cfg.kappa) * cfg.kappa]
    n, C = X.shape
    if eps_c is None:
        budget = np.full(C, float(cfg.eps))
    else:
        budget = np.asarray(eps_c, np.float64).reshape(-1)
        if budget.shape[0] != C:
            raise ValueError(
                f"eps_c has {budget.shape[0]} budgets for {C} columns")
        if np.any(budget <= 0):
            raise ValueError("eps_c budgets must be positive")
    eps_work = budget.copy()    # halves on repair; budget stays the bar
    masks, iters = _column_masks(X, cfg, eps_work, range(C), pad_to)
    enforce = cfg.target_cr is None
    retries = 0
    while True:
        union = np.zeros(n, bool)
        for c in range(C):
            union |= masks[c]
        xr = np.stack([_union_reconstruct(X[:, c], union)
                       for c in range(C)], axis=1)
        devs = np.array([_column_deviation(X[:, c], xr[:, c], cfg)
                         for c in range(C)])
        bad = [c for c in range(C)
               if enforce and np.isfinite(budget[c]) and devs[c] > budget[c]
               and not masks[c].all()]
        if not bad:
            break
        if retries >= max_retries:
            if OBS.enabled:
                OBS.inc("mvar.keep_all_columns", len(bad))
            for c in bad:     # last resort: the column keeps everything
                masks[c] = np.ones(n, bool)
            continue          # keep-all columns measure deviation 0 next pass
        retries += 1
        if OBS.enabled:
            OBS.inc("mvar.repair_halvings", len(bad))
        eps_work[bad] = eps_work[bad] / 2.0
        new_masks, it = _column_masks(X, cfg, eps_work, bad, pad_to)
        masks.update(new_masks)
        iters += it
    if OBS.enabled:
        for c in range(C):
            if np.isfinite(budget[c]) and budget[c] > 0:
                OBS.observe("mvar.eps_headroom", float(devs[c]) / budget[c])
    # per-column counts of the masks that actually went into the union
    # (recompressed/keep-all columns included, not their discarded firsts)
    col_n_kept = np.array([int(masks[c].sum()) for c in range(C)])
    return MVCompressResult(
        kept=union, xr=xr, deviation=float(devs.max()) if C else 0.0,
        n_kept=int(union.sum()), iters=iters, deviations=devs,
        col_n_kept=col_n_kept)


def kept_points(res: CompressResult):
    """(indices, values) numpy views of the kept points."""
    kept = np.asarray(res.kept)
    idx = np.nonzero(kept)[0]
    vals = np.asarray(res.xr)[idx]
    return idx, vals


def decompress(indices, values, n: int, dtype=jnp.float64) -> jax.Array:
    """Linear-interpolation decompression (paper §4.1): one forward pass."""
    indices = jnp.asarray(indices, dtype=dtype)
    values = jnp.asarray(values, dtype=dtype)
    grid = jnp.arange(n, dtype=dtype)
    return jnp.interp(grid, indices, values)


def compression_ratio(res: CompressResult) -> float:
    return float(res.kept.shape[0]) / float(res.n_kept)
