"""CAMEO: autocorrelation-preserving lossy compression (paper §4).

Two execution modes share the same incremental-aggregate substrate:

* ``mode="sequential"`` — paper-faithful Algorithm 1: one point removed per
  iteration (heap replaced by a dense masked argmin), exact Eq. 9 windowed
  aggregate update + constraint check at pop time, and *blocking* — only the
  ``h`` alive neighbors on each side get their cached impact recomputed
  (ReHeap) after a removal.

* ``mode="rounds"`` — the TPU-native batched-greedy adaptation: every round
  computes the Algorithm-2 impact for *all* alive points as one dense O(nL)
  kernel (see ``kernels/acf_impact``), removes an independent set of the
  lowest-impact α-fraction, applies one exact dense aggregate update for the
  whole round, and accepts/rejects the round against the ε constraint
  (rejections halve α, so the mode converges to the same guarantee).

Both modes support the three problem variants of §3:
  Def. 1 (SIP)                — ``eps`` bound on D(S(X'), S(X));
  Def. 2 (SIP on aggregates)  — ``kappa > 1`` tumbling-window mean;
  Def. 3 (compression-centric)— ``target_cr`` (minimize D s.t. CR ≥ c).
and both statistics ``S ∈ {acf, pacf}``.

The guarantee discipline matches the paper: the *ranking* of candidates is a
heuristic (single-delta Eq. 8 approximation, possibly stale under blocking),
but every actual removal is validated with an exact incremental update, so
the returned deviation is exact w.r.t. the reconstruction's true ACF/PACF.

All ranking math is served by the impact-engine backend (``kernels/ops.py``,
selected via ``CameoConfig.backend``): the Pallas kernels on TPU, the
pure-jnp reference forms elsewhere.  This module holds only the greedy
control loops; ``compress_batch`` vmaps/shards the rounds mode over a fleet
of independent series.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures as _measures
from repro.core.acf import (
    acf_from_aggregates,
    aggregate_series,
    extract_aggregates,
)
from repro.core.aggregates import (
    alive_neighbors,
    apply_delta_dense,
    apply_delta_window,
    interpolate_at,
    segment_deltas,
)
from repro.kernels import ops as _ops


@dataclasses.dataclass(frozen=True)
class CameoConfig:
    """Static configuration (hashable: safe to close over / pass as static)."""

    eps: float = 0.01
    lags: int = 24
    stat: str = "acf"              # "acf" | "pacf"
    measure: str = "mae"           # see core.measures
    kappa: int = 1                 # Def. 2 tumbling-window size (mean agg)
    mode: str = "rounds"           # "rounds" | "sequential"
    # -- rounds mode --
    alpha: float = 0.10            # per-round removal fraction cap
    max_rounds: int = 400
    impact_chunk: int = 4096
    rank: str = "window"           # "window" (exact Eq. 9) | "single" (Alg. 2)
    stop_policy: str = "exhaustive"  # "exhaustive" | "first_violation"
    select: str = "bisect"         # "bisect" (prefix search) | "backoff"
    bisect_probes: int = 6
    # -- sequential mode --
    hops: int = 16                 # blocking neighborhood h per side
    window: int = 64               # max re-interpolated span W (static)
    max_iters: Optional[int] = None
    # -- Def. 3 / halting --
    target_cr: Optional[float] = None   # minimize D s.t. CR >= target_cr
    max_cr: Optional[float] = None      # optional halt once CR reaches this
    dtype: str = "float64"
    # -- impact-engine backend (see kernels/ops.py):
    #    "pallas" (TPU kernels; interpret mode off-TPU) | "reference"
    #    (pure-jnp) | "auto" (pallas on TPU, reference elsewhere)
    backend: str = "auto"

    def jdtype(self):
        return jnp.dtype(self.dtype)


class CompressResult(NamedTuple):
    kept: jax.Array        # bool [n] — True where the original point is kept
    xr: jax.Array          # float [n] — reconstruction (kept pts bit-exact)
    deviation: jax.Array   # scalar — exact D(S(recon), S(orig))
    n_kept: jax.Array      # scalar int
    iters: jax.Array       # rounds (rounds mode) or removals (sequential)
    stat_orig: jax.Array   # [L] S of the original target series
    stat_new: jax.Array    # [L] S of the reconstruction's target series


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _stat_transform(cfg: CameoConfig):
    # single stat registry, shared with the impact-engine dispatch
    return _ops._transform_fn(cfg.stat)


def _measure_fn(cfg: CameoConfig):
    return _measures.get_measure(cfg.measure)


def _ranking_impact(cfg, agg, y, xr, alive, p0, n):
    """GetAllImpact via the impact-engine backend (see kernels/ops.py)."""
    return _ops.ranking_impact(cfg, agg, y, xr, alive, p0, n)


def _independent_set(sel: jax.Array, impact: jax.Array, alive: jax.Array):
    """Drop alive-adjacent picks: keep a pick iff it beats both its nearest
    *selected* alive neighbors (vectorized local-minima rule on the alive
    chain, so no two removed points ever share a segment endpoint)."""
    n = sel.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive)
    inf = jnp.asarray(jnp.inf, impact.dtype)
    # impact of my adjacent alive neighbors IF they are also selected
    pc, qc = jnp.clip(prev, 0, n - 1), jnp.clip(nxt, 0, n - 1)
    left_imp = jnp.where(sel[pc] & (prev >= 0), impact[pc], inf)
    right_imp = jnp.where(sel[qc] & (nxt <= n - 1), impact[qc], inf)
    li = jnp.where(prev >= 0, prev, n)
    beats_left = (impact < left_imp) | ((impact == left_imp) & (idx < li))
    ri = jnp.where(nxt <= n - 1, nxt, -1)
    beats_right = (impact < right_imp) | ((impact == right_imp) & (idx < ri))
    return sel & beats_left & beats_right


def _reconstruct(x_kept_vals: jax.Array, alive: jax.Array) -> jax.Array:
    """Full-length reconstruction: alive points keep their value, dead points
    take the line between their alive neighbors."""
    n = alive.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive)
    interp = interpolate_at(x_kept_vals, prev, nxt, idx)
    return jnp.where(alive, x_kept_vals, interp)


def _x_to_y_delta(delta_x: jax.Array, kappa: int, dt):
    if kappa == 1:
        return delta_x
    ny = delta_x.shape[0] // kappa
    return delta_x.reshape(ny, kappa).sum(axis=1) / jnp.asarray(kappa, dt)


# ---------------------------------------------------------------------------
# rounds mode (TPU-native batched greedy)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def compress_rounds(x: jax.Array, cfg: CameoConfig) -> CompressResult:
    dt = cfg.jdtype()
    x = x.astype(dt)
    n = x.shape[0]
    L = cfg.lags
    y0 = aggregate_series(x, cfg.kappa)
    ny = y0.shape[0]
    agg0 = extract_aggregates(y0, L, backend=cfg.backend)
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    p0 = transform(acf_from_aggregates(agg0, ny))

    if cfg.target_cr is not None:
        min_alive = max(2, int(np.ceil(n / cfg.target_cr)))
        eps = jnp.asarray(jnp.inf, dt)
    else:
        min_alive = 2
        eps = jnp.asarray(cfg.eps, dt)
    if cfg.max_cr is not None:
        min_alive = max(min_alive, int(np.ceil(n / cfg.max_cr)))

    k_max = max(1, int(cfg.alpha * n))

    def cond(c):
        (xr, alive, y, agg, alpha, dev, rounds, done, blocked) = c
        return (~done) & (rounds < cfg.max_rounds) & (jnp.sum(alive) > min_alive)

    def eval_prefix(impact, sel_idx, finite, alive, xr, y, agg, kp):
        """Trial-removal of the kp lowest-impact candidates (independent-set
        filtered).  Returns (dev, sel, alive', xr', dy, agg')."""
        rank_ok = (jnp.arange(k_max) < kp) & finite
        sel = jnp.zeros((n,), bool).at[sel_idx].set(rank_ok, mode="drop")
        sel = _independent_set(sel, impact, alive)
        alive_new = alive & (~sel)
        xr_new = _reconstruct(x, alive_new)
        dy = _x_to_y_delta(xr_new - xr, cfg.kappa, dt)
        agg_new = apply_delta_dense(agg, y, dy)
        dev_new = mfn(transform(acf_from_aggregates(agg_new, ny)), p0)
        return dev_new, sel, alive_new, xr_new, dy, agg_new

    def body(c):
        (xr, alive, y, agg, alpha, dev, rounds, done, blocked) = c
        n_alive = jnp.sum(alive)
        # Per-lane re-check of `cond`: under vmap (compress_batch) the body
        # keeps executing for lanes whose own loop has finished as long as
        # any lane is live; gating acceptance on `live` makes those extra
        # executions exact no-ops, so batched results match per-series runs.
        live = (~done) & (rounds < cfg.max_rounds) & (n_alive > min_alive)
        impact = _ranking_impact(cfg, agg, y, xr, alive, p0, n)
        inf = jnp.asarray(jnp.inf, dt)
        impact = jnp.where(blocked, inf, impact)
        k_cap = jnp.maximum(
            1, jnp.minimum(
                (alpha * n_alive.astype(dt)).astype(jnp.int32),
                (n_alive - min_alive).astype(jnp.int32),
            ),
        )
        neg_vals, sel_idx = jax.lax.top_k(-impact, k_max)
        finite = jnp.isfinite(-neg_vals)

        if cfg.select == "bisect":
            # largest feasible prefix via bisection (dev(0)=dev <= eps holds)
            def probe(_, lohi):
                lo, hi = lohi
                mid = (lo + hi + 1) // 2
                dev_mid, *_ = eval_prefix(
                    impact, sel_idx, finite, alive, xr, y, agg, mid)
                ok = dev_mid <= eps
                return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1))
            lo, hi = jax.lax.fori_loop(
                0, cfg.bisect_probes, probe,
                (jnp.asarray(0, jnp.int32), k_cap.astype(jnp.int32)))
            k_final = lo
        else:
            k_final = k_cap.astype(jnp.int32)

        dev_new, sel, alive_new, xr_new, dy, agg_new = eval_prefix(
            impact, sel_idx, finite, alive, xr, y, agg, k_final)
        n_sel = jnp.sum(sel)
        any_sel = n_sel > 0
        accept = (dev_new <= eps) & any_sel & live

        was_single = n_sel <= 1
        if cfg.stop_policy == "first_violation":
            done_new = done | ((~accept) & was_single) | \
                ((k_final == 0) if cfg.select == "bisect" else (~any_sel))
            blocked_new = blocked
        else:
            # exhaustive: when not even the single best candidate fits,
            # block it and keep searching; blocks clear on any accept.
            best_idx = sel_idx[0]
            no_fit = (k_final == 0) if cfg.select == "bisect" else \
                ((~accept) & was_single & any_sel)
            blocked_new = jnp.where(
                accept, jnp.zeros_like(blocked),
                jnp.where(no_fit & finite[0],
                          blocked.at[best_idx].set(True), blocked))
            exhausted = ~jnp.any(alive & (~blocked_new) & jnp.isfinite(impact))
            done_new = done | ((~accept) & exhausted) | (~finite[0])
        if cfg.select == "backoff":
            alpha_new = jnp.where(accept, jnp.minimum(alpha * 1.1, cfg.alpha),
                                  jnp.maximum(alpha * 0.5,
                                              jnp.asarray(1.5 / n, dt)))
        else:
            alpha_new = alpha

        xr_out = jnp.where(accept, xr_new, xr)
        alive_out = jnp.where(accept, alive_new, alive)
        y_out = jnp.where(accept, y + dy, y)
        agg_out = jax.tree.map(
            lambda new, old: jnp.where(accept, new, old), agg_new, agg)
        dev_out = jnp.where(accept, dev_new, dev)
        return (xr_out, alive_out, y_out, agg_out, alpha_new,
                dev_out, rounds + live.astype(jnp.int32), done_new,
                blocked_new)

    alive0 = jnp.ones((n,), bool)
    init = (x, alive0, y0, agg0, jnp.asarray(cfg.alpha, dt),
            jnp.asarray(0.0, dt), jnp.asarray(0, jnp.int32),
            jnp.asarray(False), jnp.zeros((n,), bool))
    (xr, alive, y, agg, _, dev, rounds, _, _) = jax.lax.while_loop(
        cond, body, init)
    stat_new = transform(acf_from_aggregates(agg, ny))
    return CompressResult(
        kept=alive, xr=xr, deviation=dev, n_kept=jnp.sum(alive),
        iters=rounds, stat_orig=p0, stat_new=stat_new)


# ---------------------------------------------------------------------------
# sequential mode (paper-faithful Algorithm 1)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def compress_sequential(x: jax.Array, cfg: CameoConfig) -> CompressResult:
    dt = cfg.jdtype()
    x = x.astype(dt)
    n = x.shape[0]
    L = cfg.lags
    W = cfg.window
    h = cfg.hops
    kap = cfg.kappa
    y0 = aggregate_series(x, kap)
    ny = y0.shape[0]
    agg0 = extract_aggregates(y0, L, backend=cfg.backend)
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    p0 = transform(acf_from_aggregates(agg0, ny))
    inf = jnp.asarray(jnp.inf, dt)

    if cfg.target_cr is not None:
        min_alive = max(2, int(np.ceil(n / cfg.target_cr)))
        eps = inf
    else:
        min_alive = 2
        eps = jnp.asarray(cfg.eps, dt)
    if cfg.max_cr is not None:
        min_alive = max(min_alive, int(np.ceil(n / cfg.max_cr)))
    max_iters = cfg.max_iters if cfg.max_iters is not None else (n - min_alive)

    # y-window size for kappa>1 windowed updates.
    Wy = W if kap == 1 else (W // kap + 2)

    def trial(agg, y, xr, prev, nxt, i):
        """Exact Eq. 9 trial removal of point i (segment (prev[i], nxt[i]));
        the impact-engine provides the delta geometry, the incremental
        aggregate update validates the removal exactly."""
        dwin, start, span = segment_deltas(xr, prev, nxt, i, W)
        dyw, ystart = _ops.x_window_to_y(cfg, dwin, start)
        agg_t = apply_delta_window(agg, y, dyw, ystart, W=Wy, L=L)
        dev_t = mfn(transform(acf_from_aggregates(agg_t, ny)), p0)
        return agg_t, dev_t, dwin, dyw, start, ystart, span <= W

    def collect_neighbors(prev, nxt, p, q):
        """h alive indices walking left from p and right from q (incl. p, q)."""
        # left walk
        def left_body(i, acc):
            ids, ptr = acc
            ids = ids.at[i].set(ptr)
            ptr = jnp.clip(prev[jnp.clip(ptr, 0, n - 1)], -1, n - 1)
            ptr = jnp.where(ptr < 0, jnp.int32(0), ptr)
            return ids, ptr
        ids_l, _ = jax.lax.fori_loop(
            0, h + 1, left_body,
            (jnp.zeros((h + 1,), jnp.int32), jnp.clip(p, 0, n - 1)))
        def right_body(i, acc):
            ids, ptr = acc
            ids = ids.at[i].set(ptr)
            ptr = jnp.clip(nxt[jnp.clip(ptr, 0, n - 1)], 0, n)
            ptr = jnp.where(ptr >= n, jnp.int32(n - 1), ptr)
            return ids, ptr
        ids_r, _ = jax.lax.fori_loop(
            0, h + 1, right_body,
            (jnp.zeros((h + 1,), jnp.int32), jnp.clip(q, 0, n - 1)))
        return jnp.concatenate([ids_l, ids_r])

    def init_impacts(agg, y, xr, prev, nxt):
        # Exact impacts are O(nWL) to initialize; Algorithm 2 initializes with
        # the O(nL) single-delta form, which is exact while all points are
        # alive (every segment has span 1).  We do the same.
        alive = jnp.ones((n,), bool)
        return _ops.ranking_impact(cfg, agg, y, xr, alive, p0, n,
                                   rank="single")

    def cond(c):
        (xr, alive, prev, nxt, imp, agg, y, dev, it, done) = c
        return (~done) & (it < max_iters) & (jnp.sum(alive) > min_alive)

    def body(c):
        (xr, alive, prev, nxt, imp, agg, y, dev, it, done) = c
        i = jnp.argmin(imp)
        best = imp[i]
        p, q = prev[i], nxt[i]
        agg_t, dev_t, dwin, dyw, start, ystart, valid = trial(
            agg, y, xr, prev, nxt, i)

        can_remove = jnp.isfinite(best) & valid & (dev_t <= eps)
        # Algorithm 1 stops at the first violation, which is sound when the
        # heap is fresh; under blocking the popped impact can be stale (the
        # paper's ReHeap keeps neighborhoods fresh, but distant entries age),
        # so a stale pop would end the run prematurely.  We block the
        # offending candidate (impact=inf; ReHeap revives neighbors later)
        # and stop only when no finite candidate remains.  With
        # stop_policy="first_violation" the paper's literal semantics apply.
        if cfg.stop_policy == "first_violation":
            violation = jnp.isfinite(best) & valid & (dev_t > eps)
            done_new = done | violation | (~jnp.isfinite(best))
        else:
            done_new = done | (~jnp.isfinite(best))

        # apply removal (no-ops when rejected)
        def windowed_add(arr, win, st, Wn):
            """arr[st + j] += win[j] with clamp-safe shifting near the end."""
            size = arr.shape[0]
            offset = jnp.clip(st, 0, size - Wn)
            shift = st - offset
            k = jnp.arange(Wn)
            buf = jnp.where(k >= shift, win[jnp.clip(k - shift, 0, Wn - 1)], 0.0)
            return jax.lax.dynamic_update_slice(
                arr, jax.lax.dynamic_slice(arr, (offset,), (Wn,)) + buf, (offset,))

        def apply(_):
            xr2 = windowed_add(xr, dwin, start, W)
            alive2 = alive.at[i].set(False)
            prev2 = prev.at[q].set(p, mode="drop")
            nxt2 = nxt.at[p].set(q, mode="drop")
            y2 = windowed_add(y, dyw, ystart, Wy)
            imp2 = imp.at[i].set(inf)
            # ReHeap: exact impact recompute for h alive neighbors per side,
            # through the impact-engine backend (exact Eq. 9 ranking).
            nbrs = collect_neighbors(prev2, nxt2, p, q)
            new_imps = _ops.window_impact_at(
                cfg, agg_t, y2, xr2, prev2, nxt2, nbrs, p0)
            # only alive points get updates (dedup: later writes win, values
            # identical for duplicated indices so order is irrelevant)
            alive_n = alive2[nbrs]
            imp2 = imp2.at[nbrs].set(
                jnp.where(alive_n, new_imps, imp2[nbrs]), mode="drop")
            return xr2, alive2, prev2, nxt2, imp2, agg_t, y2, dev_t

        def reject(_):
            # rejected candidates (span overflow or eps violation under the
            # skip policy) become unremovable until a ReHeap revives them
            imp2 = imp.at[i].set(inf)
            return xr, alive, prev, nxt, imp2, agg, y, dev

        xr2, alive2, prev2, nxt2, imp2, agg2, y2, dev2 = jax.lax.cond(
            can_remove, apply, reject, operand=None)
        return (xr2, alive2, prev2, nxt2, imp2, agg2, y2, dev2,
                it + 1, done_new)

    idx = jnp.arange(n, dtype=jnp.int32)
    prev0 = idx - 1
    nxt0 = idx + 1
    imp0 = init_impacts(agg0, y0, x, prev0, nxt0)
    init = (x, jnp.ones((n,), bool), prev0, nxt0, imp0, agg0, y0,
            jnp.asarray(0.0, dt), jnp.asarray(0, jnp.int32),
            jnp.asarray(False))
    xr, alive, prev, nxt, imp, agg, y, dev, it, _ = jax.lax.while_loop(
        cond, body, init)
    stat_new = transform(acf_from_aggregates(agg, ny))
    return CompressResult(
        kept=alive, xr=xr, deviation=dev, n_kept=jnp.sum(alive),
        iters=it, stat_orig=p0, stat_new=stat_new)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def compress(x, cfg: CameoConfig) -> CompressResult:
    """Compress ``x`` under ``cfg``.  Trims a tail remainder so the length is
    divisible by ``kappa`` (the trimmed points are kept verbatim by callers
    that need exact framing; the registry uses divisible lengths)."""
    x = jnp.asarray(x)
    if cfg.kappa > 1:
        n = (x.shape[0] // cfg.kappa) * cfg.kappa
        x = x[:n]
    if cfg.mode == "rounds":
        return compress_rounds(x, cfg)
    if cfg.mode == "sequential":
        return compress_sequential(x, cfg)
    raise ValueError(f"unknown mode {cfg.mode!r}")


def compress_batch(xs, cfg: CameoConfig, mesh=None,
                   axis: str = "data") -> CompressResult:
    """Batched multi-series compression — the fleet-of-sensors workload.

    ``xs`` is ``[B, n]`` (B independent series of equal length); returns a
    ``CompressResult`` whose leaves carry a leading batch axis.  Built on the
    TPU-native ``rounds`` mode: per-series results are bit-identical to
    ``compress_rounds(xs[b], cfg)`` (the round loop no-ops for series that
    finish early while the batch drains).  With ``mesh`` given, the batch is
    additionally sharded over ``mesh.shape[axis]`` devices via ``shard_map``
    (B must divide evenly); each device vmaps its local shard.
    """
    xs = jnp.asarray(xs)
    if xs.ndim != 2:
        raise ValueError(f"compress_batch wants [B, n], got {xs.shape}")
    if cfg.mode != "rounds":
        raise ValueError("compress_batch batches the rounds mode; got "
                         f"mode={cfg.mode!r}")
    if cfg.kappa > 1:
        n = (xs.shape[1] // cfg.kappa) * cfg.kappa
        xs = xs[:, :n]
    batched = jax.vmap(lambda x: compress_rounds(x, cfg))
    if mesh is None:
        return batched(xs)
    from jax.sharding import PartitionSpec as P
    from repro import sharding as shd
    T = mesh.shape[axis]
    if xs.shape[0] % T:
        raise ValueError(f"batch {xs.shape[0]} not divisible over "
                         f"{T} devices on axis {axis!r}")
    sharded = shd.shard_map(batched, mesh=mesh, in_specs=P(axis),
                            out_specs=P(axis))
    return jax.jit(sharded)(xs)


class MVCompressResult(NamedTuple):
    """Multivariate compression result: one shared kept-index stream, per-
    column values re-evaluated on it (see :func:`compress_multivariate`)."""

    kept: np.ndarray        # bool [n] — shared union kept mask
    xr: np.ndarray          # float [n, C] — per-column reconstructions
    deviation: float        # max per-column deviation (the stored headline)
    n_kept: int             # |union|
    iters: int              # total compressor rounds/removals across columns
    deviations: np.ndarray  # [C] exact measured per-column deviation
    col_n_kept: np.ndarray  # [C] per-column own kept counts (pre-union)


def _column_masks(X: np.ndarray, cfg: CameoConfig, eps_c: np.ndarray,
                  cols) -> tuple:
    """(masks[C, n] for the requested ``cols``, iters) — rounds mode batches
    same-eps columns through ``compress_batch``; anything else runs
    per-column ``compress``."""
    import jax as _jax

    masks = {}
    iters = 0
    cols = list(cols)
    if cfg.mode == "rounds" and len(cols) > 1:
        by_eps = {}
        for c in cols:
            by_eps.setdefault(float(eps_c[c]), []).append(c)
        for eps, group in by_eps.items():
            gcfg = dataclasses.replace(cfg, eps=eps)
            if len(group) > 1:
                res = compress_batch(X[:, group].T, gcfg)
                _jax.block_until_ready(res.kept)
                for i, c in enumerate(group):
                    masks[c] = np.asarray(res.kept[i])
                    iters += int(res.iters[i])
            else:
                res = compress(jnp.asarray(X[:, group[0]]), gcfg)
                masks[group[0]] = np.asarray(res.kept)
                iters += int(res.iters)
    else:
        for c in cols:
            res = compress(jnp.asarray(X[:, c]),
                           dataclasses.replace(cfg, eps=float(eps_c[c])))
            masks[c] = np.asarray(res.kept)
            iters += int(res.iters)
    return masks, iters


_mv_recon_jit = None


def _union_reconstruct(x_col: np.ndarray, union: np.ndarray) -> np.ndarray:
    """Canonical one-shot interpolation of one column on the shared index —
    the same jitted ``_reconstruct`` the store decode uses, so the measured
    per-column deviation is exact for what readers will actually see."""
    global _mv_recon_jit
    if _mv_recon_jit is None:
        _mv_recon_jit = jax.jit(_reconstruct)
    return np.asarray(_mv_recon_jit(jnp.asarray(x_col), jnp.asarray(union)))


def _column_deviation(x_col: np.ndarray, xr_col: np.ndarray,
                      cfg: CameoConfig) -> float:
    """Exact measured D(S(recon), S(orig)) of one column (Eq. 7 path)."""
    transform = _stat_transform(cfg)
    mfn = _measure_fn(cfg)
    y0 = aggregate_series(jnp.asarray(x_col, cfg.jdtype()), cfg.kappa)
    y1 = aggregate_series(jnp.asarray(xr_col, cfg.jdtype()), cfg.kappa)
    ny = int(y0.shape[0])
    s0 = transform(acf_from_aggregates(
        extract_aggregates(y0, cfg.lags, backend=cfg.backend), ny))
    s1 = transform(acf_from_aggregates(
        extract_aggregates(y1, cfg.lags, backend=cfg.backend), ny))
    return float(mfn(s1, s0))


def compress_multivariate(X, cfg: CameoConfig, *,
                          max_retries: int = 4) -> MVCompressResult:
    """Compress a multivariate series ``X [n, C]`` onto one shared index.

    The Sprintz-style shared-timestamp layout: every column is compressed
    independently (``compress_batch`` over the columns in rounds mode), the
    per-column kept masks are **unioned** into a single index stream, and
    every column is then *re-evaluated on the shared index* — its stored
    values are the original ``X[idx, c]`` at every union index, so each
    column's reconstruction interpolates through strictly more original
    points than its own greedy solution kept.

    The per-column ε guarantee is *enforced by measurement*, not assumed:
    each column's exact deviation is recomputed on the shared index, and a
    column that exceeds ``cfg.eps`` (possible in principle — the ACF is not
    monotone in pointwise error) is recompressed at half its budget and the
    union rebuilt, up to ``max_retries`` times; a still-violating column
    finally keeps all of its points (deviation exactly 0).  With
    ``target_cr`` set there is no ε to enforce and the measured deviations
    are reported as-is.

    Returns an :class:`MVCompressResult` whose ``kept``/``xr`` feed
    ``CameoStore.append_series`` (v4 shared-index block layout) directly.
    """
    X = np.asarray(X)
    if X.ndim != 2:
        raise ValueError(f"compress_multivariate wants [n, C], got {X.shape}")
    if cfg.kappa > 1:
        X = X[:(X.shape[0] // cfg.kappa) * cfg.kappa]
    n, C = X.shape
    eps_c = np.full(C, float(cfg.eps))
    masks, iters = _column_masks(X, cfg, eps_c, range(C))
    enforce = cfg.target_cr is None and np.isfinite(cfg.eps)
    retries = 0
    while True:
        union = np.zeros(n, bool)
        for c in range(C):
            union |= masks[c]
        xr = np.stack([_union_reconstruct(X[:, c], union)
                       for c in range(C)], axis=1)
        devs = np.array([_column_deviation(X[:, c], xr[:, c], cfg)
                         for c in range(C)])
        bad = [c for c in range(C) if enforce and devs[c] > cfg.eps
               and not masks[c].all()]
        if not bad:
            break
        if retries >= max_retries:
            for c in bad:     # last resort: the column keeps everything
                masks[c] = np.ones(n, bool)
            continue          # keep-all columns measure deviation 0 next pass
        retries += 1
        eps_c[bad] = eps_c[bad] / 2.0
        new_masks, it = _column_masks(X, cfg, eps_c, bad)
        masks.update(new_masks)
        iters += it
    # per-column counts of the masks that actually went into the union
    # (recompressed/keep-all columns included, not their discarded firsts)
    col_n_kept = np.array([int(masks[c].sum()) for c in range(C)])
    return MVCompressResult(
        kept=union, xr=xr, deviation=float(devs.max()) if C else 0.0,
        n_kept=int(union.sum()), iters=iters, deviations=devs,
        col_n_kept=col_n_kept)


def kept_points(res: CompressResult):
    """(indices, values) numpy views of the kept points."""
    kept = np.asarray(res.kept)
    idx = np.nonzero(kept)[0]
    vals = np.asarray(res.xr)[idx]
    return idx, vals


def decompress(indices, values, n: int, dtype=jnp.float64) -> jax.Array:
    """Linear-interpolation decompression (paper §4.1): one forward pass."""
    indices = jnp.asarray(indices, dtype=dtype)
    values = jnp.asarray(values, dtype=dtype)
    grid = jnp.arange(n, dtype=dtype)
    return jnp.interp(grid, indices, values)


def compression_ratio(res: CompressResult) -> float:
    return float(res.kept.shape[0]) / float(res.n_kept)
