"""Fault-tolerant checkpointing: atomic, checksummed, keep-k, async-capable,
and elastic (restore reshards onto whatever mesh the new job brings up).

Layout:  <dir>/step_<N>/
             manifest.json     tree structure, shapes, dtypes, checksums
             arrays.npz.<c>    flattened leaves (zstd stream if the optional
                               zstandard module is present, zlib otherwise;
                               the manifest records the codec)

Atomicity: written to ``step_<N>.tmp`` then ``os.rename``d — a crashed save
never shadows the previous good checkpoint.  ``restore`` verifies checksums
and re-places leaves with ``jax.device_put`` against a sharding template
(possibly from a *different* mesh shape than the one that saved — elastic
restart).  A SIGTERM handler in train.loop triggers a final synchronous
save (preemption safety).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: fall back to stdlib zlib compression
    zstandard = None

_SEP = "/"


def _default_codec() -> str:
    return "zstd" if zstandard is not None else "zlib"


def _array_file(codec: str) -> str:
    return "arrays.npz." + ("zst" if codec == "zstd" else "zlib")


def _compress_bytes(raw: bytes, codec: str) -> bytes:
    if codec == "zstd":
        return zstandard.ZstdCompressor(level=3).compress(raw)
    if codec == "zlib":
        return zlib.compress(raw, 6)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _decompress_bytes(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise IOError("checkpoint is zstd-compressed but the zstandard "
                          "module is not installed")
        return zstandard.ZstdDecompressor().decompress(blob)
    if codec == "zlib":
        return zlib.decompress(blob)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save(directory: str, step: int, tree, extra: Optional[dict] = None,
         keep: int = 3):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, **leaves)
    raw = buf.getvalue()
    codec = _default_codec()
    comp = _compress_bytes(raw, codec)
    with open(os.path.join(tmp, _array_file(codec)), "wb") as f:
        f.write(comp)

    manifest = {
        "step": step,
        "codec": codec,
        "checksum": hashlib.sha256(raw).hexdigest(),
        "bytes_raw": len(raw),
        "bytes_compressed": len(comp),
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in leaves.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _cleanup(directory, keep)
    return final


def save_async(directory: str, step: int, tree, extra=None, keep: int = 3):
    """Off-critical-path save: device_get happens here (synchronously, so the
    arrays are consistent), compression+IO on a worker thread."""
    leaves, _ = _flatten(tree)

    def work():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        buf = io.BytesIO()
        np.savez(buf, **leaves)
        raw = buf.getvalue()
        codec = _default_codec()
        with open(os.path.join(tmp, _array_file(codec)), "wb") as f:
            f.write(_compress_bytes(raw, codec))
        manifest = {"step": step,
                    "codec": codec,
                    "checksum": hashlib.sha256(raw).hexdigest(),
                    "bytes_raw": len(raw),
                    "keys": {k: {"shape": list(v.shape),
                                 "dtype": str(v.dtype)}
                             for k, v in leaves.items()},
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _cleanup(directory, keep)

    t = threading.Thread(target=work, daemon=True)
    t.start()
    return t


def _cleanup(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template=None, *, verify: bool = True):
    """Load a checkpoint.  ``template`` (pytree of arrays or
    ShapeDtypeStructs with shardings) drives re-placement: leaves are
    device_put against the template's shardings — restoring onto a different
    mesh (elastic resize) just means passing the new template."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    codec = manifest.get("codec", "zstd")   # pre-codec checkpoints were zstd
    with open(os.path.join(path, _array_file(codec)), "rb") as f:
        raw = _decompress_bytes(f.read(), codec)
    if verify:
        digest = hashlib.sha256(raw).hexdigest()
        if digest != manifest["checksum"]:
            raise IOError(
                f"checkpoint {path} corrupt: checksum mismatch")
    arrs = np.load(io.BytesIO(raw))
    leaves = {k: arrs[k] for k in arrs.files}
    if template is None:
        return leaves, manifest
    tpl_flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for tpath, tleaf in tpl_flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in tpath)
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = leaves[key]
        sharding = getattr(tleaf, "sharding", None)
        if sharding is not None and not callable(sharding):
            out.append(jax.device_put(arr.astype(tleaf.dtype), sharding))
        else:
            out.append(jax.device_put(arr.astype(tleaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
