"""The ``repro.api`` dataset façade — one handle for every CAMEO workflow.

``open(path, cfg)`` returns a :class:`Dataset`, the single documented way
to ingest and query compressed time series; everything underneath
(``core.cameo`` compression, the ``CameoStore`` physical layer,
``core.streaming`` windows, ``store.query`` pushdown) is driven through it
and stays an internal detail:

* **one-shot ingest** — ``ds.write(sid, x)`` compresses and persists a
  series; a 2-D ``x [n, C]`` is a first-class **multivariate** series
  (one shared kept-index stream, per-column value streams and per-column
  ε guarantees — the v4 store layout).
* **batched ingest** — ``ds.write_batch({sid: x, ...})`` groups
  equal-length series through ``compress_batch`` (one compile, B series).
* **streaming ingest** — ``ds.stream(sid)`` returns a
  :class:`StreamWriter`: push arbitrary-size chunks, query the written
  prefix mid-stream, ``flush()`` for durability, stop and ``resume`` from
  the state stashed in the store footer.  Chunking-invariant and
  byte-identical to the one-shot windowed write.
* **reads** — ``ds.series(sid)`` returns a :class:`Series` handle:
  ``window`` decodes touch only overlapping blocks, and the pushdown
  aggregates ``sum/mean/var/acf/pacf`` come back as ``(value, bound)``
  with deterministic error bounds, answered from block metadata (Plato-
  style) without decompressing interior blocks.  On a multivariate series
  every read takes ``col=`` or returns stacked per-column answers.

The Plato-style discipline (Lin et al., VLDB'18): the handle owns both the
storage *and* the error-bounded query surface, so there is exactly one
place where a series' compression contract (ε, lags, stat, κ) lives.

Univariate operations are byte- and bit-identical to the legacy call
paths they replace (``TimeSeriesService.submit``/``ingest_stream``, free
``store.window_*`` functions, ``compress_windowed``), which now live on as
deprecated shims over the same internals.
"""
from __future__ import annotations

import dataclasses
import math
import os
from time import perf_counter as _perf_counter
from typing import Dict, List, Optional

import numpy as np

from repro.core.cameo import (
    CameoConfig,
    compress,
    compress_batch,
    compress_multivariate,
)
from repro.core.streaming import (
    MVStreamingCompressor,
    StreamingCompressor,
    compressor_from_state,
)
from repro.obs import OBS
from repro.store import query as _query
from repro.store import wal as _wal
from repro.store.store import DEFAULT_CACHE_BYTES, CameoStore


def open(path: str, cfg: Optional[CameoConfig] = None, *,
         mode: str = None, block_len: int = None,
         value_codec: str = None, entropy: str = None,
         cache_bytes: int = DEFAULT_CACHE_BYTES,
         store_residuals: bool = True,
         stream_window: int = 4096, wal: bool = None,
         wal_group_ms: float = _wal.DEFAULT_GROUP_MS,
         wal_group_bytes: int = _wal.DEFAULT_GROUP_BYTES) -> "Dataset":
    """Open (or create) a CAMEO dataset at ``path``.

    ``mode`` is ``"w"`` (create), ``"r"`` (read-only) or ``"a"`` (append /
    resume); the default picks ``"r"`` when the file exists, else ``"w"``.
    ``cfg`` (a :class:`~repro.core.cameo.CameoConfig`) sets the compression
    contract for writes and may be omitted for read-only handles.
    ``store_residuals`` keeps Plato-style residual moments so value
    aggregates carry bounds vs the *original* series; ``stream_window`` is
    the default :meth:`Dataset.stream` window length.

    The store-layout parameters (``block_len``, ``value_codec``,
    ``entropy``) take effect when **creating** a file (``mode="w"``); an
    existing file keeps the settings recorded in its footer, and passing
    *different* values in ``"r"``/``"a"`` mode raises rather than
    silently ignoring them (re-passing the matching values is fine).

    Writable handles keep a per-store write-ahead journal (``wal``;
    default on, ``CAMEO_WAL=0`` opts the process out): every
    :meth:`StreamWriter.push` is acked once journaled, a crash never loses
    an acked push (``mode="a"`` recovers and replays), and the fsync
    cadence is the ``wal_group_ms`` / ``wal_group_bytes`` group-commit
    policy (see ``store/README.md`` for the durability contract).
    """
    if mode is None:
        mode = "r" if os.path.exists(path) else "w"
    if mode not in ("r", "w", "a"):
        raise ValueError(f"unknown mode {mode!r}; use 'r', 'w' or 'a'")
    if mode != "r" and cfg is None:
        raise ValueError(f"mode {mode!r} needs a CameoConfig to write with")
    if mode == "w":
        store = CameoStore.create(
            path, block_len=4096 if block_len is None else block_len,
            value_codec=value_codec or "gorilla", entropy=entropy or "auto",
            cache_bytes=cache_bytes, wal=wal, wal_group_ms=wal_group_ms,
            wal_group_bytes=wal_group_bytes)
    else:
        store = CameoStore.open(path, mode, cache_bytes=cache_bytes,
                                wal=wal, wal_group_ms=wal_group_ms,
                                wal_group_bytes=wal_group_bytes)
        clash = [f"{name}={want!r} (stored {getattr(store, name)!r})"
                 for name, want in (("block_len", block_len),
                                    ("value_codec", value_codec),
                                    ("entropy", entropy))
                 if want is not None and want != getattr(store, name)]
        if clash:
            if store._wal is not None:   # abandon without a footer rewrite
                store._wal.close()
                store._wal = None
            store._f.close()
            raise ValueError(
                f"{path!r} was created with different store-layout "
                f"settings: {', '.join(clash)}; layout parameters take "
                "effect only when creating a store (mode='w')")
    return Dataset(store, cfg, store_residuals=store_residuals,
                   stream_window=stream_window)


class Series:
    """Read handle for one stored series (obtain via ``Dataset.series``).

    ``window`` serves bit-exact reconstruction slices; the aggregate
    methods push the query down to block metadata and return
    ``(value, bound)`` with deterministic error bounds (``store/query``).
    On a multivariate series ``col`` selects one column; with ``col=None``
    aggregates come back stacked ``[C, ...]`` (one header pass serves all
    columns) and ``window`` returns ``[m, C]``.
    """

    def __init__(self, store: CameoStore, sid: str):
        if sid not in store:
            raise KeyError(f"no series {sid!r} in store")
        self._store = store
        self.sid = sid

    # -- metadata ------------------------------------------------------------

    @property
    def meta(self) -> dict:
        """The catalog entry (n, n_kept, eps, lags, deviation, bytes...)."""
        return self._store.series_meta(self.sid)

    @property
    def n(self) -> int:
        return int(self.meta["n"])

    @property
    def channels(self) -> int:
        return self._store.channels(self.sid)

    @property
    def deviation(self) -> float:
        """Recorded exact measured deviation (max over columns)."""
        return float(self.meta["deviation"])

    @property
    def deviations(self) -> np.ndarray:
        """[C] per-column recorded deviations (length 1 for univariate)."""
        return np.asarray(self.meta.get("deviations",
                                        [self.meta["deviation"]]))

    def stats(self) -> dict:
        """Byte-true compression accounting (``compression_stats``)."""
        return self._store.compression_stats(self.sid)

    # -- decodes -------------------------------------------------------------

    def window(self, a: int = None, b: int = None,
               col: int = None) -> np.ndarray:
        """Reconstruction slice ``xr[a:b]`` (whole series by default),
        bit-exact, decoding only the overlapping blocks."""
        a = 0 if a is None else a
        b = self.n if b is None else b
        return self._store.read_window(self.sid, a, b, col=col)

    def kept(self):
        """(indices, values) of the stored kept points."""
        return self._store.read_kept(self.sid)

    # -- pushdown aggregates -------------------------------------------------

    def sum(self, a: int = None, b: int = None, col: int = None):
        return _query.query(self._store, self.sid, "sum", a, b, col=col)

    def mean(self, a: int = None, b: int = None, col: int = None):
        return _query.query(self._store, self.sid, "mean", a, b, col=col)

    def var(self, a: int = None, b: int = None, col: int = None):
        return _query.query(self._store, self.sid, "var", a, b, col=col)

    def acf(self, a: int = None, b: int = None, col: int = None):
        return _query.query(self._store, self.sid, "acf", a, b, col=col)

    def pacf(self, a: int = None, b: int = None, col: int = None):
        """Window PACF with a first-order propagated deterministic bound.

        The pushdown ACF answer (exact-on-reconstruction up to its float-
        reassembly bound) is mapped through the same Durbin–Levinson
        transform the compressor uses; the bound is propagated through the
        transform's exact Jacobian (forward-mode jax), doubled for
        curvature headroom — deterministic, never measured against a
        decode.
        """
        r, rb = self.acf(a, b, col=col)
        if np.ndim(r) == 2:
            vals, bounds = zip(*(_pacf_with_bound(r[c], rb[c])
                                 for c in range(r.shape[0])))
            return np.asarray(vals), np.asarray(bounds)
        return _pacf_with_bound(r, rb)


def _pacf_with_bound(r: np.ndarray, r_bound: np.ndarray):
    import jax
    import jax.numpy as jnp

    from repro.core.acf import pacf_from_acf

    r = jnp.asarray(np.asarray(r, np.float64))
    val = pacf_from_acf(r)
    jac = jax.jacfwd(pacf_from_acf)(r)
    bound = 2.0 * jnp.abs(jac) @ jnp.asarray(r_bound) + 1e-14
    return np.asarray(val), np.asarray(bound)


class StreamWriter:
    """One unbounded-feed ingest stream (obtain via ``Dataset.stream``).

    Chunks in, blocks out, O(window) state: pushes buffer into fixed
    tumbling windows, each window compresses the moment it fills (full
    per-window ε guarantee — per *column* for multivariate streams), and
    blocks hit disk the moment their border is provable.  The written
    prefix serves reads the whole time; ``flush()`` makes it durable
    (stashing resume state in the footer) and ``close()`` finalizes the
    series **byte-identical** to the one-shot windowed write of the same
    feed.  The result is chunking-invariant bit-for-bit.

    ``queue_depth`` pipelines the ingest: up to K filled windows accumulate
    and close as one batched ``[K, window]`` device program (see
    ``core/streaming.StreamingCompressor``).  Store bytes are invariant to
    the depth — windows are merely emitted in bursts — so the default of 1
    (compress each window the moment it fills) is purely a latency choice.
    """

    def __init__(self, store: CameoStore, ccfg: CameoConfig, sid: str, *,
                 window_len: int = 4096, with_resid: bool = True,
                 channels: int = 1, resume: bool = False,
                 queue_depth: int = None, block_len: int = None):
        self.sid = sid
        self._store = store
        self._wal = store._wal
        self._block_len = block_len   # per-session seal override (server)
        # journaled-but-unreplayed pushes from a crashed run (the store's
        # recovery scan parks them per-sid); consumed exactly once here
        pending = (store._wal_pending.pop(sid, None)
                   if self._wal is not None else None)
        if resume:
            entry = store._series.get(sid)
            if (entry is None or not entry.get("streaming")) and pending:
                # the crashed run journaled this stream's pushes but never
                # published a footer that catalogs it — re-create the
                # stream from scratch and let the journal replay rebuild it
                if pending[0].start != 0:
                    raise IOError(
                        f"series {sid!r}: journal replay starts at point "
                        f"{pending[0].start}, but the catalog has no "
                        "stream to resume — the journal lost its prefix")
                channels = (1 if pending[0].x.ndim == 1
                            else int(pending[0].x.shape[1]))
                self._build_fresh(store, ccfg, sid, window_len=window_len,
                                  with_resid=with_resid, channels=channels,
                                  queue_depth=queue_depth)
            else:
                self._sess = store.open_stream(sid, ccfg, resume=True,
                                               block_len=block_len)
                state = self._sess.restored_client_state
                if state is None:
                    # unwind: re-stash the session state and release the
                    # slot, so a raw-store resume of the same stream still
                    # works (and re-park the journal records)
                    store._series[sid]["stream_state"] = self._sess._stash()
                    store._streams.pop(sid, None)
                    if pending:
                        store._wal_pending[sid] = pending
                    raise ValueError(
                        f"series {sid!r}: stream was not opened through "
                        "the streaming façade — no compressor state to "
                        "resume")
                self._comp = compressor_from_state(ccfg, state)
                if queue_depth is not None:   # explicit override wins
                    if queue_depth < 1:
                        raise ValueError(
                            f"queue_depth={queue_depth} must be >= 1")
                    self._comp.queue_depth = int(queue_depth)
        else:
            self._build_fresh(store, ccfg, sid, window_len=window_len,
                              with_resid=with_resid, channels=channels,
                              queue_depth=queue_depth)
        self._sess.state_provider = self._comp.state_dict
        self.closed = False
        # a fresh (non-resume) open of the same sid supersedes any crashed
        # run's journal records: they are consumed (dropped), not replayed
        if resume and pending:
            self._replay(pending)

    def _build_fresh(self, store, ccfg, sid, *, window_len, with_resid,
                     channels, queue_depth):
        if int(channels) > 1:
            self._comp = MVStreamingCompressor(
                ccfg, window_len, channels, queue_depth=queue_depth or 1)
        else:
            self._comp = StreamingCompressor(
                ccfg, window_len, queue_depth=queue_depth or 1)
        self._sess = store.open_stream(
            sid, ccfg, with_resid=with_resid, channels=channels,
            block_len=self._block_len)

    def _replay(self, pending) -> None:
        """Re-feed journaled pushes a crashed run had acked.  Replay is
        idempotent (records at or below the resumed watermark are skipped)
        and deterministic — the regenerated blocks are byte-identical to
        the ones the crashed run wrote or would have written."""
        replayed = points = 0
        for rec in pending:
            end = rec.start + int(np.shape(rec.x)[0])
            if end <= self._comp.n_seen:
                continue              # footer already covers this record
            if rec.start != self._comp.n_seen:
                raise IOError(
                    f"series {self.sid!r}: journal gap — replay record "
                    f"starts at {rec.start} but the stream resumed at "
                    f"{self._comp.n_seen}")
            self._sess.append_windows(self._comp.push(rec.x))
            replayed += 1
            points += int(np.shape(rec.x)[0])
        if OBS.enabled and replayed:
            OBS.inc("wal.replayed_records", replayed)
            OBS.inc("wal.replayed_points", points)

    # -- introspection -------------------------------------------------------

    @property
    def resume_from(self) -> int:
        """Absolute index of the next point this stream expects."""
        return self._comp.n_seen

    @property
    def n_seen(self) -> int:
        return self._comp.n_seen

    @property
    def channels(self) -> int:
        return getattr(self._comp, "channels", 1)

    def deviation(self) -> float:
        """Exact measured global deviation of the stream so far (max over
        columns for multivariate streams)."""
        return self._comp.deviation()

    def deviations(self) -> np.ndarray:
        """[C] exact per-column deviations so far."""
        if hasattr(self._comp, "deviations"):
            return self._comp.deviations()
        return np.asarray([self._comp.deviation()])

    # -- feeding -------------------------------------------------------------

    def _journal(self, chunk: np.ndarray) -> None:
        """Write-ahead: the chunk is journaled (and acked) *before* it is
        compressed, so a crash anywhere downstream replays it on resume.
        Validation happens first — a rejected chunk must never ack."""
        C = self.channels
        if C > 1:
            if chunk.ndim != 2 or int(chunk.shape[1]) != C:
                raise ValueError(
                    f"stream {self.sid!r} expects [m, {C}] chunks, got "
                    f"shape {chunk.shape}")
        elif chunk.ndim != 1:
            raise ValueError(
                f"stream {self.sid!r} expects 1-D chunks, got shape "
                f"{chunk.shape}")
        if chunk.shape[0]:
            self._wal.append_push(_wal.PushRecord(
                self.sid, self._comp.n_seen,
                np.asarray(chunk, np.float64)))

    def push(self, chunk) -> int:
        """Feed a chunk (``[m]``, or ``[m, C]`` for multivariate streams);
        compresses and stores every window it closes (one burst append per
        batched drain).  Returns the number of windows closed.

        With the journal on (the default) the push is **acked once
        journaled**: the raw points are on their way to stable storage
        (group-commit fsync cadence) before compression starts, and a
        crash at any later point replays them on ``resume`` — so a return
        from ``push`` means the data cannot be silently lost, even though
        its compressed form may not exist yet."""
        if not OBS.enabled:
            if self._wal is not None:
                self._journal(np.asarray(chunk))
            wins = self._comp.push(chunk)
            self._sess.append_windows(wins)
            return len(wins)
        t0 = _perf_counter()
        if self._wal is not None:
            self._journal(np.asarray(chunk))
            OBS.observe("ingest.ack_seconds", _perf_counter() - t0)
        wins = self._comp.push(chunk)
        self._sess.append_windows(wins)
        OBS.observe("ingest.push_seconds", _perf_counter() - t0)
        OBS.inc("ingest.points", int(np.shape(np.asarray(chunk))[0]))
        return len(wins)

    def flush(self) -> None:
        """Durability checkpoint: footer (incl. resume state) rewritten,
        fsynced, and the journal truncated to it."""
        self._sess.flush()

    def close(self) -> dict:
        """Flush the final partial window, finalize the series, and return
        its catalog entry.  On a journaling store the footer is also
        published (checkpointing the journal), so the finalized series is
        durable — not just staged for the dataset's own close."""
        self._sess.append_windows(self._comp.finish())
        if getattr(self._comp, "channels", 1) > 1:
            entry = self._sess.close(deviation=self._comp.deviation(),
                                     deviations=self._comp.deviations())
        else:
            entry = self._sess.close(deviation=self._comp.deviation())
        self.closed = True
        if self._wal is not None:
            self._store.flush()
        return entry

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        # finalize only on clean exit — an exception mid-feed must leave
        # the stream incomplete (and hence resumable)
        if exc[0] is None and not self.closed:
            self.close()


class Dataset:
    """Handle over one CAMEO store file (see :func:`open`)."""

    def __init__(self, store: CameoStore, cfg: Optional[CameoConfig] = None,
                 *, store_residuals: bool = True, stream_window: int = 4096):
        self._store = store
        self.cfg = cfg
        self.store_residuals = bool(store_residuals)
        self.stream_window = int(stream_window)

    # -- lifecycle -----------------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        self._store.close()

    def flush(self):
        """Make everything ingested so far durable (footer rewrite)."""
        self._store.flush()

    @property
    def writable(self) -> bool:
        return self._store._writable

    @property
    def store(self) -> CameoStore:
        """The underlying physical store (escape hatch; the façade methods
        cover the documented surface)."""
        return self._store

    def _require_write(self):
        if not self.writable:
            raise IOError("dataset opened read-only")
        if self.cfg is None:
            raise ValueError("dataset has no CameoConfig; reopen with "
                             "repro.api.open(path, cfg, mode='a')")

    # -- ingest --------------------------------------------------------------

    def write(self, sid: str, x, *, eps=None) -> dict:
        """Compress and persist one series; returns its catalog entry.

        1-D ``x [n]`` stores a univariate series (bit- and byte-identical
        to the legacy compress-then-append path).  2-D ``x [n, C]`` stores
        a **multivariate** series: columns compress through
        ``compress_batch``, their kept masks union into one shared
        delta-of-delta index stream, and every column re-evaluates on the
        shared index with its exact deviation measured (and enforced)
        against the per-column ε — the v4 block layout.

        ``eps`` overrides the dataset's compression budget for this write:
        a scalar replaces ``cfg.eps``; on a multivariate series a length-C
        sequence gives **each column its own ε budget** (enforced per
        column through the repair loop; see ``compress_multivariate``).
        """
        self._require_write()
        x = np.asarray(x)
        if x.ndim == 2 and x.shape[1] == 1:
            x = x[:, 0]
        cfg = self.cfg
        eps_c = None
        if eps is not None:
            if np.ndim(eps) == 0:
                cfg = dataclasses.replace(cfg, eps=float(eps))
            elif x.ndim == 2:
                eps_c = np.asarray(eps, np.float64)
            else:
                raise ValueError(
                    "per-column eps budgets need a 2-D [n, C] series")
        if x.ndim not in (1, 2):
            raise ValueError(f"series must be [n] or [n, C], got {x.shape}")
        t0 = _perf_counter() if OBS.enabled else 0.0
        if x.ndim == 1:
            res = compress(x, cfg)
        else:
            res = compress_multivariate(x, cfg, eps_c=eps_c)
        entry = self._store.append_series(
            sid, res, cfg, x=x if self.store_residuals else None)
        if OBS.enabled:
            OBS.observe("write.seconds", _perf_counter() - t0)
            OBS.inc("write.series")
            devs = np.atleast_1d(entry.get("deviations", entry["deviation"]))
            budget = (eps_c if eps_c is not None
                      else np.full(devs.shape, cfg.eps, np.float64))
            for d, e in zip(devs, budget):
                if e and math.isfinite(e):
                    OBS.observe("write.eps_headroom", float(d) / float(e))
        return entry

    def write_batch(self, items: Dict[str, np.ndarray]) -> Dict[str, dict]:
        """Compress and persist a fleet of 1-D series, batching
        equal-length groups through ``compress_batch`` (one compile, B
        series; per-series results bit-identical to solo runs)."""
        self._require_write()
        import jax

        groups: Dict[int, List] = {}
        for sid, x in items.items():
            x = np.asarray(x)
            if x.ndim != 1:
                raise ValueError(
                    f"write_batch takes 1-D series ({sid!r} is {x.shape}); "
                    "use write() for multivariate data")
            groups.setdefault(x.shape[0], []).append((sid, x))
        out = {}
        for length in sorted(groups):
            group = groups[length]
            xs = np.stack([x for _, x in group])
            if self.cfg.mode == "rounds" and len(group) > 1:
                res = compress_batch(xs, self.cfg)
                jax.block_until_ready(res.kept)
                per = [jax.tree.map(lambda leaf: leaf[i], res)
                       for i in range(len(group))]
            else:
                per = [compress(xs[i], self.cfg)
                       for i in range(len(group))]
            for (sid, x), r in zip(group, per):
                out[sid] = self._store.append_series(
                    sid, r, self.cfg,
                    x=x if self.store_residuals else None)
        return out

    def stream(self, sid: str, *, window_len: int = None, channels: int = 1,
               resume: bool = False, queue_depth: int = None,
               block_len: int = None) -> StreamWriter:
        """Open a continuous-feed ingest stream for ``sid``.

        ``channels > 1`` opens a multivariate stream (push ``[m, C]``
        chunks).  ``resume=True`` (on a dataset opened with ``mode="a"``)
        continues an interrupted stream from the footer-stashed state;
        feed points from ``writer.resume_from`` onward.  ``queue_depth=K``
        batches K filled windows into one device program per drain (bytes
        are invariant to the depth; default 1 compresses synchronously).
        ``block_len`` seals this stream's blocks at a non-default length
        (the ingest server seals small and compacts later — see
        ``store/maintenance.py``).
        """
        self._require_write()
        return StreamWriter(
            self._store, self.cfg, sid,
            window_len=window_len or self.stream_window,
            with_resid=self.store_residuals, channels=channels,
            resume=resume, queue_depth=queue_depth, block_len=block_len)

    # -- reads ---------------------------------------------------------------

    def series(self, sid: str) -> Series:
        return Series(self._store, sid)

    def sids(self) -> List[str]:
        return self._store.series_ids()

    def __contains__(self, sid: str) -> bool:
        return sid in self._store

    def __iter__(self):
        return iter(self._store.series_ids())

    def view(self, prefix: str) -> "DatasetView":
        """A prefix-scoped facade over this dataset: every sid passed to
        the view maps to ``prefix + sid`` in the store, and ``sids()``
        lists only (and un-prefixes) the matching series.  The ingest
        server hands out ``view(tenant + "/")`` as the tenant-scoped
        query surface; an empty prefix is the identity view."""
        return DatasetView(self, prefix)

    # -- accounting ----------------------------------------------------------

    def cache_stats(self) -> dict:
        return self._store.cache_stats()

    def stats(self, *, deep: bool = False) -> dict:
        """Whole-dataset accounting in the unified stats schema (see
        :mod:`repro.obs`): ``series``, ``points``, ``n_kept``,
        ``stored_nbytes``, ``raw_nbytes``, ``point_cr``, ``bytes_cr``,
        ``cache`` — the same keys ``TimeSeriesService.stats()`` returns
        for these concepts.  Answered from the store's O(1) running
        ingest totals, so polling cost is independent of how many series
        or blocks are stored.  ``deep=True`` walks ``compression_stats``
        for every series (O(total series)) and adds the per-series dicts
        under ``per_series``."""
        t = self._store.ingest_totals()
        out = dict(
            series=t["series"], points=t["points"], n_kept=t["n_kept"],
            stored_nbytes=t["stored_nbytes"], raw_nbytes=t["raw_nbytes"],
            point_cr=t["points"] / max(t["n_kept"], 1),
            bytes_cr=t["raw_nbytes"] / max(t["stored_nbytes"], 1),
            cache=self._store.cache_stats())
        if deep:
            out["per_series"] = {s: self._store.compression_stats(s)
                                 for s in self._store.series_ids()}
        return out


class DatasetView:
    """A sid-prefix-scoped view of a :class:`Dataset` (``Dataset.view``).

    Exposes the ingest/read surface of the dataset with every series id
    transparently mapped through ``prefix + sid`` — the mechanism behind
    tenant-scoped access in :mod:`repro.server` (tenant ``t`` owns the
    ``"t/"`` namespace of the shared store).  The view adds no state of
    its own: handles it returns (:class:`Series`, :class:`StreamWriter`)
    are the ordinary ones, bound to the prefixed sid.
    """

    def __init__(self, dataset: Dataset, prefix: str):
        self._ds = dataset
        self.prefix = str(prefix)

    def _sid(self, sid: str) -> str:
        return self.prefix + sid

    # -- ingest --------------------------------------------------------------

    def write(self, sid: str, x, *, eps=None) -> dict:
        return self._ds.write(self._sid(sid), x, eps=eps)

    def write_batch(self, items: Dict[str, np.ndarray]) -> Dict[str, dict]:
        out = self._ds.write_batch(
            {self._sid(sid): x for sid, x in items.items()})
        k = len(self.prefix)
        return {sid[k:]: entry for sid, entry in out.items()}

    def stream(self, sid: str, **kw) -> StreamWriter:
        return self._ds.stream(self._sid(sid), **kw)

    # -- reads ---------------------------------------------------------------

    def series(self, sid: str) -> Series:
        return self._ds.series(self._sid(sid))

    def sids(self) -> List[str]:
        k = len(self.prefix)
        return [s[k:] for s in self._ds.sids() if s.startswith(self.prefix)]

    def __contains__(self, sid: str) -> bool:
        return self._sid(sid) in self._ds

    def __iter__(self):
        return iter(self.sids())
