"""``repro.api`` — the unified dataset façade over the CAMEO stack.

>>> import repro.api as cameo
>>> ds = cameo.open("fleet.cameo", CameoConfig(eps=1e-3, lags=24))
>>> ds.write("sensor-1", x)                 # 1-D: univariate
>>> ds.write("rack-7", X)                   # [n, C]: multivariate (v4)
>>> with ds.stream("feed") as w:            # unbounded chunked ingest
...     w.push(chunk)
>>> s = ds.series("rack-7")
>>> s.mean(a, b)                            # ([C], [C]) value + bound
>>> s.acf(col=0)                            # one column's pushdown ACF
>>> ds.close()

See :mod:`repro.api.dataset` for the full contract.  The legacy entry
points (``TimeSeriesService.submit``/``ingest_stream``, the free
``repro.store.window_*`` functions, ``compress_windowed``) are deprecated
shims over the same internals.
"""
from repro.api.dataset import Dataset, DatasetView, Series, StreamWriter, open

__all__ = ["Dataset", "DatasetView", "Series", "StreamWriter", "open"]
