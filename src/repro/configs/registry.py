"""Architecture registry: ``--arch <id>`` resolution + parameter accounting.

Also owns ``expected_long_context``: which archs run the ``long_500k`` cell
(sub-quadratic capable) vs. skip it (pure full-attention; see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import SHAPES, ModelConfig

_MODULES = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "musicgen-large": "repro.configs.musicgen_large",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
}

ARCH_IDS = tuple(_MODULES)

# long_500k runs only for sub-quadratic-capable archs (SSM / hybrid /
# sliding-window); pure full-attention archs skip it by assignment.
LONG_CONTEXT_ARCHS = ("gemma3-27b", "mamba2-2.7b", "jamba-1.5-large-398b")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.reduced()


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) dry-run cells.

    Yields (arch, shape_name, runnable: bool)."""
    for arch in ARCH_IDS:
        for shape in SHAPES:
            runnable = shape != "long_500k" or arch in LONG_CONTEXT_ARCHS
            if runnable or include_skipped:
                yield arch, shape, runnable


def param_count(cfg: ModelConfig) -> int:
    from repro.models.model import model_defs
    from repro.models.params import count_params
    return count_params(model_defs(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of n_experts + shared)."""
    total = param_count(cfg)
    if cfg.n_experts == 0:
        return total
    from repro.models.model import model_defs
    from repro.models.params import count_params, _map_defs
    import numpy as np

    expert_total = 0

    def visit(path, d):
        nonlocal expert_total
        if len(path) >= 1 and any("moe" == p for p in path) and \
                path[-1] in ("wi_gate", "wi_up", "wo"):
            expert_total += int(np.prod(d.shape))
        return None

    _map_defs(visit, model_defs(cfg))
    active_frac = (cfg.top_k / cfg.n_experts)
    return int(total - expert_total * (1.0 - active_frac))
