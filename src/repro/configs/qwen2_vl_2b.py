"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
M-RoPE (sections 16/24/24 over half of head_dim=128), dynamic resolution.
[arXiv:2409.12191; hf]

The vision tower is a STUB per the brief: ``input_specs()`` provides
precomputed patch embeddings [B, n_patches, d] that replace the prefix of
the token embedding sequence; M-RoPE positions default to text-style.
qkv_bias=True (Qwen2 attention biases); tied embeddings.
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    d_model=1536, n_layers=28, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936,
    pattern=(LayerSpec("attn"),), n_blocks=28,
    qkv_bias=True, tie_embeddings=True,
    pos="mrope", mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    attn_chunk=1024,
    frontend="vision_stub", n_patches=256,
    family="vlm",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-2b-reduced",
        d_model=128, n_layers=3, n_blocks=3, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=256, mrope_sections=(8, 4, 4),
        n_patches=8, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
