"""stablelm-12b [dense]: 40L d=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; hf]  head_dim = d/H = 160.
Note: StableLM-2 uses LayerNorm+bias; we use RMSNorm uniformly (DESIGN.md §2).
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    d_model=5120, n_layers=40, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352,
    pattern=(LayerSpec("attn"),), n_blocks=40,
    pos="rope", rope_theta=10000.0, attn_chunk=1024,
    family="dense",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="stablelm-12b-reduced",
        d_model=128, n_layers=3, n_blocks=3, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=256, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
