"""kimi-k2-1t-a32b [moe]: 61L d=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8, expert d_ff=2048 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

Table-faithful: all 61 layers MoE with GQA kv=8 as assigned.  (The released
K2 uses MLA attention, one dense first layer and one shared expert; the
assigned table overrides those — noted in DESIGN.md §Arch-applicability.)
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    d_model=7168, n_layers=61, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab=163840,
    pattern=(LayerSpec("attn", moe=True),), n_blocks=61,
    n_experts=384, top_k=8, d_ff_expert=2048,
    pos="rope", rope_theta=50000.0, attn_chunk=1024,
    family="moe",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="kimi-k2-1t-a32b-reduced",
        d_model=128, n_layers=3, n_blocks=3, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=128, vocab=256,
        n_experts=8, top_k=2, d_ff_expert=128, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
