"""musicgen-large [audio]: 48L d=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only per the brief: the EnCodec tokenizer/delay-pattern frontend is
a STUB — ``input_specs()`` feeds precomputed frame-token streams.  Sinusoidal
positions, GELU MLP (MusicGen's transformer), head_dim=64.

This is the arch whose inputs are literally sensor-like time series (audio
frames) — the CAMEO data plane applies directly (examples/audio_ingest).
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    d_model=2048, n_layers=48, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048,
    pattern=(LayerSpec("attn"),), n_blocks=48,
    pos="sinusoidal", mlp_kind="gelu", attn_chunk=1024,
    frontend="audio_stub",
    family="audio",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="musicgen-large-reduced",
        d_model=128, n_layers=3, n_blocks=3, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=256, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
