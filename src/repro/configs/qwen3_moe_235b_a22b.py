"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=1536.  qk_norm.  [hf:Qwen/Qwen3-30B-A3B; hf]
All layers are MoE (no dense MLP layers).
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    d_model=4096, n_layers=94, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    pattern=(LayerSpec("attn", moe=True),), n_blocks=94,
    n_experts=128, top_k=8, d_ff_expert=1536,
    qk_norm=True,
    pos="rope", rope_theta=1_000_000.0, attn_chunk=1024,
    family="moe",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-235b-a22b-reduced",
        d_model=128, n_layers=3, n_blocks=3, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=128, vocab=256,
        n_experts=8, top_k=2, d_ff_expert=128, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
