"""gemma3-27b [dense]: 62L d=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
5:1 local(1024-window):global interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
head_dim=128 (gemma3 decouples from d/H); sandwich norms; qk-norm;
embeddings scaled by sqrt(d) and tied (as in Gemma).
Long-context capable: local layers cache O(window); decode over a 512k
global-layer cache is O(n) per token.
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec("attn", window=1024)
_GLOBAL = LayerSpec("attn")

CONFIG = ModelConfig(
    name="gemma3-27b",
    d_model=5376, n_layers=62, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL), n_blocks=10,
    remainder=(_LOCAL, _LOCAL),
    qk_norm=True, sandwich_norm=True, scale_embed=True, tie_embeddings=True,
    pos="rope", rope_theta=1_000_000.0, attn_chunk=1024,
    family="dense",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma3-27b-reduced",
        d_model=128, n_layers=8, n_blocks=1,
        pattern=(dataclasses.replace(_LOCAL, window=16),) * 5 + (_GLOBAL,),
        remainder=(dataclasses.replace(_LOCAL, window=16),) * 2,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=256,
        attn_chunk=None, param_dtype="float32", activ_dtype="float32",
        remat="none")
