"""Architecture config schema: ModelConfig + per-layer LayerSpec patterns.

A model is ``n_blocks`` repetitions of ``pattern`` (a tuple of LayerSpecs)
plus an optional ``remainder`` — this keeps the lowered HLO O(len(pattern))
regardless of depth (scan over stacked block params), which is what makes
the 61..94-layer dry-runs compile quickly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"            # "attn" | "mamba"
    window: Optional[int] = None  # sliding-window size (attn only)
    moe: bool = False             # MoE MLP instead of dense
    mlp: bool = True              # False: mixer-only block (pure Mamba2)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...]
    n_blocks: int
    remainder: Tuple[LayerSpec, ...] = ()
    # attention
    qk_norm: bool = False
    qkv_bias: bool = False
    pos: str = "rope"             # rope | mrope | sinusoidal | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    attn_chunk: Optional[int] = None   # flash-style chunk (long prefill)
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    router_z_coef: float = 1e-3
    moe_impl: str = "scatter"     # scatter | a2a (shard_map all-to-all EP)
    # mamba
    d_state: int = 0
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    mamba_chunk: int = 128
    # misc
    mlp_kind: str = "swiglu"      # swiglu | gelu
    tie_embeddings: bool = False
    scale_embed: bool = False
    sandwich_norm: bool = False
    norm_eps: float = 1e-6
    frontend: Optional[str] = None    # None | "vision_stub" | "audio_stub"
    n_patches: int = 0                # vision stub: prefix embeddings
    # execution
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"
    remat: str = "full"               # none | full | dots
    kv_cache_dtype: str = "same"      # same | int8 (quantized KV cache)
    kv_prune: int = 1                 # CAMEO cache pruning: keep 1/kv_prune
    # family tag for applicability notes
    family: str = "dense"             # dense | moe | ssm | hybrid | vlm | audio

    def __post_init__(self):
        assert self.n_layers == self.n_blocks * len(self.pattern) + \
            len(self.remainder), (
                self.name, self.n_layers, self.n_blocks, len(self.pattern),
                len(self.remainder))

    # ---- derived ----
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def m_heads(self) -> int:
        return self.d_inner // self.headdim if self.headdim else 0

    def pdtype(self):
        import jax.numpy as jnp
        return jnp.dtype(self.param_dtype)

    def adtype(self):
        import jax.numpy as jnp
        return jnp.dtype(self.activ_dtype)

    def all_layers(self) -> Tuple[LayerSpec, ...]:
        return self.pattern * self.n_blocks + self.remainder

    def supports_long_context(self) -> bool:
        """True when every layer is sub-quadratic-capable (SSM or windowed
        attention) or the arch is hybrid with O(1)/O(W) per-layer state."""
        return all(
            ls.kind == "mamba" or ls.window is not None
            for ls in self.all_layers()
        ) or self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class LayerCtx:
    """Merged view of ModelConfig + LayerSpec handed to layer functions."""
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    window: Optional[int]
    pos: str
    rope_theta: float
    mrope_sections: Tuple[int, ...]
    attn_chunk: Optional[int]
    kv_cache_dtype: str
    kv_prune: int
    # moe
    n_experts: int
    top_k: int
    capacity_factor: float
    aux_loss_coef: float
    router_z_coef: float
    # mamba
    d_inner: int
    m_heads: int
    headdim: int
    n_groups: int
    d_state: int
    conv_width: int
    mamba_chunk: int


def layer_ctx(cfg: ModelConfig, ls: LayerSpec) -> LayerCtx:
    return LayerCtx(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.head_dim, window=ls.window, pos=cfg.pos,
        rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
        attn_chunk=cfg.attn_chunk, kv_cache_dtype=cfg.kv_cache_dtype,
        kv_prune=cfg.kv_prune,
        n_experts=cfg.n_experts, top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        aux_loss_coef=cfg.aux_loss_coef, router_z_coef=cfg.router_z_coef,
        d_inner=cfg.d_inner, m_heads=cfg.m_heads, headdim=cfg.headdim,
        n_groups=cfg.n_groups, d_state=cfg.d_state,
        conv_width=cfg.conv_width, mamba_chunk=cfg.mamba_chunk,
    )


# input shapes assigned to the LM pool (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
