"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7 interleave, MoE on
every other layer.  [arXiv:2403.19887; hf]

Block pattern (period 8, 9 blocks): one attention layer per 8 (index 4),
MoE MLP on odd indices, dense MLP elsewhere.  Mamba sublayers: d_state=16,
headdim=128 (128 heads), 8 B/C groups.
Long-context capable: O(1) SSM state on 7/8 of layers; attention layers
decode in O(n) reads over the cache.
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

_M = LayerSpec("mamba")                 # mamba + dense MLP
_MM = LayerSpec("mamba", moe=True)      # mamba + MoE
_A = LayerSpec("attn")                  # attention + dense MLP
_AM = LayerSpec("attn", moe=True)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    d_model=8192, n_layers=72, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    pattern=(_M, _MM, _M, _MM, _A, _MM, _M, _MM), n_blocks=9,
    n_experts=16, top_k=2, d_ff_expert=24576,
    d_state=16, expand=2, headdim=128, n_groups=8, conv_width=4,
    mamba_chunk=256,
    pos="rope", rope_theta=1_000_000.0, attn_chunk=1024,
    family="hybrid",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="jamba-1.5-large-398b-reduced",
        d_model=128, n_layers=8, n_blocks=1, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=256,
        n_experts=4, top_k=2, d_ff_expert=256,
        d_state=16, headdim=32, n_groups=2, mamba_chunk=16, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
