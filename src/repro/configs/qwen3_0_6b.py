"""qwen3-0.6b [dense]: 28L d=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
qk_norm, GQA, head_dim=128, tied embeddings.  [hf:Qwen/Qwen3-8B; hf]
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    d_model=1024, n_layers=28, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab=151936,
    pattern=(LayerSpec("attn"),), n_blocks=28,
    qk_norm=True, tie_embeddings=True,
    pos="rope", rope_theta=1_000_000.0, attn_chunk=1024,
    family="dense",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-0.6b-reduced",
        d_model=128, n_layers=3, n_blocks=3, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=256, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
