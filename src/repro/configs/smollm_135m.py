"""smollm-135m [dense]: 30L d=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
llama-arch small; head_dim=64; tied embeddings.
[hf:HuggingFaceTB/SmolLM-135M; hf]
Note: 9 query heads / 3 KV heads are not divisible by a 16-way model axis —
the sharding divisibility guard replicates them (see roofline notes).
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    d_model=576, n_layers=30, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab=49152,
    pattern=(LayerSpec("attn"),), n_blocks=30,
    tie_embeddings=True,
    pos="rope", rope_theta=10000.0, attn_chunk=1024,
    family="dense",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-135m-reduced",
        d_model=96, n_layers=3, n_blocks=3, n_heads=3, n_kv_heads=1,
        head_dim=32, d_ff=192, vocab=256, attn_chunk=None,
        param_dtype="float32", activ_dtype="float32", remat="none")
