"""mamba2-2.7b [ssm]: 64L d=2560 (attn-free) vocab=50280, ssm_state=128.
SSD (state-space duality), expand=2 -> d_inner=5120, headdim=64 (80 heads),
n_groups=1, conv width 4.  Mixer-only blocks (no MLP), tied embeddings.
[arXiv:2405.21060; unverified]
Long-context capable: O(1) recurrent state per layer.
"""
import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    d_model=2560, n_layers=64, n_heads=1, n_kv_heads=1, head_dim=1,
    d_ff=0, vocab=50280,
    pattern=(LayerSpec("mamba", mlp=False),), n_blocks=64,
    d_state=128, expand=2, headdim=64, n_groups=1, conv_width=4,
    mamba_chunk=256,
    tie_embeddings=True, pos="none",
    family="ssm",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-2.7b-reduced",
        d_model=128, n_layers=3, n_blocks=3, d_state=16, headdim=32,
        mamba_chunk=16, vocab=256,
        param_dtype="float32", activ_dtype="float32", remat="none")
