"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and everything else must keep seeing the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; ``pod`` is the
    slow (DCI) axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for subprocess tests with forced host device counts."""
    return jax.make_mesh(shape, axes)


def device_count_required(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
