"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.models.model import model_defs
from repro.models.params import init_params
from repro.serving.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=None)
    args = ap.parse_args()

    on_cpu = jax.default_backend() == "cpu"
    reduced = args.reduced if args.reduced is not None else on_cpu
    cfg = get_reduced(args.arch) if reduced else get_config(args.arch)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    eng.generate(prompts)
    t0 = time.perf_counter()
    eng.generate(prompts)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.batch * args.new_tokens / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
