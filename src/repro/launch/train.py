"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this builds the production mesh and jits with the sharding
rules; on this container it runs reduced configs on the local device(s).
Fault tolerance (resume/SIGTERM checkpointing) comes from train.loop.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.data.pipeline import token_batch
from repro.launch.mesh import make_production_mesh
from repro.models.model import model_defs
from repro.models.params import init_params
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainConfig
from repro.launch.specs import default_train_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=None,
                    help="use the reduced config (default on CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    args = ap.parse_args()

    on_cpu = jax.default_backend() == "cpu"
    reduced = args.reduced if args.reduced is not None else on_cpu
    cfg = get_reduced(args.arch) if reduced else get_config(args.arch)
    tcfg = default_train_config(cfg)
    tcfg = TrainConfig(optimizer=tcfg.optimizer, peak_lr=args.peak_lr,
                       warmup=max(args.steps // 20, 2),
                       total_steps=args.steps)

    use_mesh = len(jax.devices()) >= 256
    ctx_mesh = make_production_mesh(multi_pod=args.multi_pod) if use_mesh \
        else None
    rules = shd.default_rules(multi_pod=args.multi_pod) if use_mesh else None

    def batch_fn(step):
        return token_batch(cfg, args.batch, args.seq, step)

    with shd.use_sharding(ctx_mesh, rules):
        params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
        lcfg = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=max(args.steps // 4, 10),
                          log_every=max(args.steps // 20, 1))
        train_loop(cfg, tcfg, lcfg, params, batch_fn,
                   log_fn=lambda s, m: print(
                       f"step {s:5d} loss {m['loss']:.4f} "
                       f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}"))


if __name__ == "__main__":
    main()
