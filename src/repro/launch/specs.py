"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

Follows the shannon/kernels pattern: weak-type-correct, shardable, zero
allocation.  ``train_*`` shapes feed ``train_step``; ``prefill_*`` feed
``prefill``; ``decode_*`` / ``long_*`` feed ``serve_step`` (one token
against a seq_len cache).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import SHAPES, ModelConfig
from repro.models.model import cache_specs, model_defs
from repro.models.params import abstract_params, param_specs
from repro.optim.adafactor import AdafactorConfig, _factored
from repro.train.step import TrainConfig


def _sds(shape, dtype, axes, mesh, rules):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=shd.named_sharding(shape, axes, mesh, rules))


def batch_specs(cfg: ModelConfig, shape_name: str, mesh, rules):
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    if kind in ("train", "prefill"):
        out = {"tokens": _sds((B, S), jnp.int32,
                              ("act_batch", "act_seq"), mesh, rules)}
        if cfg.frontend == "vision_stub" and cfg.n_patches:
            out["patch_embeds"] = _sds(
                (B, cfg.n_patches, cfg.d_model), cfg.adtype(),
                ("act_batch", None, "act_embed"), mesh, rules)
        return out
    # decode: one new token against a seq_len cache
    token = _sds((B, 1), jnp.int32, ("act_batch", None), mesh, rules)
    caches = cache_specs(cfg, B, S, mesh, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"token": token, "caches": caches, "pos": pos}


def params_abstract(cfg: ModelConfig, mesh, rules):
    return abstract_params(model_defs(cfg), mesh, rules,
                           param_dtype=cfg.pdtype())


def opt_state_abstract(params_abs, tcfg: TrainConfig, mesh):
    """Optimizer-state stand-ins mirroring parameter shardings.

    AdamW: m/v mirror params exactly (ZeRO via FSDP rules).  Adafactor:
    row/col factors inherit the parameter spec minus the reduced dim.
    """
    from repro.optim.adamw import AdamWState
    from repro.optim.adafactor import AdafactorState

    def spec_of(p):
        return p.sharding.spec if isinstance(p.sharding, NamedSharding) else P()

    if tcfg.optimizer == "adamw":
        dt = tcfg.adamw.state_dtype

        def mirror(p):
            return jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(dt) if dt else p.dtype, sharding=p.sharding)

        step = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P()))
        return AdamWState(m=jax.tree.map(mirror, params_abs),
                          v=jax.tree.map(mirror, params_abs), step=step)

    acfg = tcfg.adafactor

    def vr_abs(p):
        spec = tuple(spec_of(p))
        if _factored(p.shape, acfg):
            return jax.ShapeDtypeStruct(
                p.shape[:-1], jnp.float32,
                sharding=NamedSharding(mesh, P(*spec[:-1])))
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding)

    def vc_abs(p):
        spec = tuple(spec_of(p))
        if _factored(p.shape, acfg):
            return jax.ShapeDtypeStruct(
                p.shape[:-2] + p.shape[-1:], jnp.float32,
                sharding=NamedSharding(mesh, P(*(spec[:-2] + spec[-1:]))))
        return jax.ShapeDtypeStruct((1,), jnp.float32,
                                    sharding=NamedSharding(mesh, P()))

    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return AdafactorState(vr=jax.tree.map(vr_abs, params_abs),
                          vc=jax.tree.map(vc_abs, params_abs), step=step)


def default_train_config(cfg: ModelConfig) -> TrainConfig:
    """Per-arch training substrate defaults: the >=200B MoE/hybrid cells use
    Adafactor (factored second moments) so optimizer state fits the pod."""
    if cfg.n_experts and cfg.name.startswith(("kimi", "jamba", "qwen3-moe")):
        return TrainConfig(optimizer="adafactor")
    return TrainConfig(optimizer="adamw")
