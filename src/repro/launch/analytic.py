"""Analytic per-step FLOP / HBM-traffic model, derived from the config.

Why analytic: XLA's ``cost_analysis`` counts while (scan) bodies once, and
text-level re-multiplication fights XLA's loop widening/unrolling transforms
(verified on the partitioned HLO).  Since we own every architecture here,
exact matmul-level accounting from the config is both simpler and more
trustworthy; the dry-run still cross-checks against ``cost_analysis`` (our
number must exceed the body-once XLA count) and takes collectives and memory
images from the compiled artifact.

Conventions:
* flops are *global* (divide by chips for per-device);
* train multiplier: fwd 1x + bwd 2x + full-remat recompute 1x;
* attention scores/probs count 2*2*H*dh*S_kv_avg per token (causal: S/2);
* HBM traffic model (per device): weight streams (post-all-gather
  materialization under FSDP), optimizer state read+write, activation
  tensor reads/writes per layer, attention tiles, KV/state caches.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import SHAPES, LayerSpec, ModelConfig, layer_ctx


def _attn_proj_flops(cfg) -> float:
    """qkv + out projection MACs per token (x2 for flops)."""
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2.0 * d * dh * (H + 2 * K + H)


def _attn_score_flops(cfg, s_kv: float) -> float:
    """score + weighted-value MACs per token against s_kv keys."""
    H, dh = cfg.n_heads, cfg.head_dim
    return 2.0 * 2.0 * H * dh * s_kv


def _mlp_flops(cfg, ls: LayerSpec) -> float:
    d = cfg.d_model
    if ls.moe:
        e = 2.0 * 3.0 * d * cfg.d_ff_expert * cfg.top_k
        e += 2.0 * d * cfg.n_experts                       # router
        if cfg.n_shared_experts:
            e += 2.0 * 3.0 * d * cfg.d_ff_expert * cfg.n_shared_experts
        return e
    if not ls.mlp:
        return 0.0
    mults = 3.0 if cfg.mlp_kind == "swiglu" else 2.0
    return 2.0 * mults * d * cfg.d_ff


def _mamba_flops(cfg) -> float:
    """per-token MACs (x2): projections + SSD terms."""
    d = cfg.d_model
    di, H, P = cfg.d_inner, cfg.m_heads, cfg.headdim
    G, N, Q = cfg.n_groups, cfg.d_state, cfg.mamba_chunk
    proj = 2.0 * d * (2 * di + 2 * G * N + H) + 2.0 * di * d
    conv = 2.0 * cfg.conv_width * (di + 2 * G * N)
    # within-chunk: scores 2*Q*G*N + L-weighted apply 2*Q*H*P (avg Q/2 -> Q)
    intra = 2.0 * (Q / 2) * G * N + 2.0 * (Q / 2) * H * P
    # chunk states build + emit: 2 * H*P*N each, amortized per token
    states = 2.0 * 2.0 * H * P * N
    return proj + conv + intra + states


def layer_flops_per_token(cfg: ModelConfig, ls: LayerSpec, s_kv: float) -> float:
    if ls.kind == "attn":
        f = _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_kv)
    else:
        f = _mamba_flops(cfg)
    return f + _mlp_flops(cfg, ls)


def model_flops_per_token(cfg: ModelConfig, s_kv: float,
                          decode: bool = False) -> float:
    total = 0.0
    for ls in cfg.all_layers():
        if ls.kind == "attn" and ls.window is not None:
            eff = min(s_kv, ls.window if decode else ls.window / 1.0)
        else:
            eff = s_kv
        total += layer_flops_per_token(cfg, ls, eff)
    total += 2.0 * cfg.d_model * cfg.vocab                 # unembed
    return total


def step_flops(cfg: ModelConfig, shape_name: str) -> Dict[str, float]:
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    if kind == "train":
        tokens = B * S
        per_tok = model_flops_per_token(cfg, S / 2.0)
        mult = 4.0 if cfg.remat in ("full", "dots") else 3.0
        flops = per_tok * tokens * mult
    elif kind == "prefill":
        tokens = B * S
        flops = model_flops_per_token(cfg, S / 2.0) * tokens
    else:  # decode: one token against an S-length cache
        tokens = B
        flops = model_flops_per_token(cfg, float(S), decode=True) * tokens
    return {"flops_global": flops, "tokens": tokens}


# ---------------------------------------------------------------------------
# HBM traffic model (per device)
# ---------------------------------------------------------------------------

def _param_bytes(cfg: ModelConfig) -> float:
    from repro.configs.registry import param_count
    return float(param_count(cfg)) * np.dtype(cfg.param_dtype).itemsize


def _active_param_bytes(cfg: ModelConfig) -> float:
    from repro.configs.registry import active_param_count
    return float(active_param_count(cfg)) * np.dtype(cfg.param_dtype).itemsize


def step_hbm_bytes(cfg: ModelConfig, shape_name: str, chips: int,
                   model_par: int = 16) -> Dict[str, float]:
    """Per-device HBM traffic estimate.

    Weight streams: under FSDP+TP the full weights materialize per device
    divided only by the TP (model) factor; they are read for fwd, bwd and
    the remat recompute.  MoE: only routed-expert traffic counts per pass
    on the EP-sharded experts (E/model_par experts resident per device).
    """
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    act_bytes = np.dtype(cfg.activ_dtype).itemsize
    p_bytes = _param_bytes(cfg)

    # Weights materialized per device after FSDP all-gather: total/model_par.
    # MoE expert weights are EP-sharded (not FSDP-gathered): resident slice.
    w_per_dev = p_bytes / model_par
    reads = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[kind]
    weight_traffic = w_per_dev * reads
    if kind == "train":
        # grad write (1x) + optimizer state read+write on the local shard
        local = p_bytes / chips
        weight_traffic += w_per_dev + 6.0 * local

    # activations: ~12 intermediate streams of [B_loc, S, d] per layer
    dp = max(chips // model_par, 1)
    if kind == "decode":
        b_loc = max(B / dp, 1.0)
        act_traffic = 12.0 * b_loc * 1 * cfg.d_model * act_bytes * cfg.n_layers
        # cache read (+ write of one slot)
        kv_bytes = act_bytes
        if cfg.kv_cache_dtype == "int8":
            # int8 values + f32 scale per (slot, head)
            kv_bytes = 1.0 + 4.0 / max(cfg.head_dim, 1)
        cache = 0.0
        kv_shards = model_par if cfg.n_kv_heads and \
            cfg.n_kv_heads % model_par == 0 else 1
        m_shards = model_par if cfg.m_heads and \
            cfg.m_heads % model_par == 0 else 1
        for ls in cfg.all_layers():
            if ls.kind == "attn":
                size = min(ls.window, S) if ls.window else \
                    max(S // max(cfg.kv_prune, 1), 1)
                per_seq = 2 * cfg.n_kv_heads * cfg.head_dim * size \
                    * kv_bytes / kv_shards
                cache += per_seq * max(B / dp, 1.0) if B >= dp else per_seq / (dp / B)
            else:
                cache += (cfg.m_heads * cfg.headdim * cfg.d_state * 4
                          + 3 * (cfg.conv_width - 1) * cfg.d_inner) \
                    / m_shards * max(B / dp, 1.0) * 2
        act_traffic += cache
    else:
        toks_loc = B * S / dp
        passes = 3.0 if kind == "train" else 1.0
        act_traffic = 12.0 * toks_loc * cfg.d_model * act_bytes \
            * cfg.n_layers * passes
        # attention tile traffic (flash chunks, f32 scores)
        for ls in cfg.all_layers():
            if ls.kind == "attn":
                s_eff = min(ls.window or S, S)
                act_traffic += 2.0 * toks_loc * (cfg.n_heads / 1.0) * s_eff \
                    * 4 / max(model_par, 1) * (2 if kind == "train" else 1) \
                    * 0.5  # causal half, streamed tiles
    return {"hbm_bytes_per_device": weight_traffic + act_traffic,
            "weight_traffic": weight_traffic, "act_traffic": act_traffic}
