import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh(es); record memory analysis, cost analysis, and collective
traffic for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --arch gemma3-27b --shape long_500k --multi-pod
  python -m repro.launch.dryrun --all            # subprocess per cell
"""
import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs.base import SHAPES
from repro.configs.registry import (LONG_CONTEXT_ARCHS, ARCH_IDS, cells,
                                    get_config, active_param_count)
from repro.launch.hlo import collective_summary, module_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (batch_specs, default_train_config,
                                opt_state_abstract, params_abstract)
from repro.models.model import decode_step, prefill
from repro.train.step import build_train_step

# TPU v5e-class hardware model (per chip)
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "benchmarks", "results", "dryrun")


def _arg_bytes(tree, mesh) -> int:
    """Per-device bytes of abstract inputs (sharded sizes)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= d
        shards = 1
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "num_devices") and sh.num_devices:
            # per-device shard size = global size / number of distinct shards
            try:
                shard_shape = sh.shard_shape(leaf.shape)
                n = 1
                for d in shard_shape:
                    n *= d
            except Exception:
                pass
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def _memory_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                            None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    if v == "None":
        return k, None
    return k, v


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             extra_rules: dict | None = None, save: bool = True,
             overrides: dict | None = None, tag: str = "") -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    info = SHAPES[shape_name]
    chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.default_rules(multi_pod=multi_pod, fsdp=True)
    if shape_name == "long_500k":
        rules["act_cache_seq"] = "data"   # 512k caches sharded over data
    if extra_rules:
        rules.update(extra_rules)

    t0 = time.time()
    with shd.use_sharding(mesh, rules):
        kind = info["kind"]
        if kind == "train":
            tcfg = default_train_config(cfg)
            params = params_abstract(cfg, mesh, rules)
            opt = opt_state_abstract(params, tcfg, mesh)
            batch = batch_specs(cfg, shape_name, mesh, rules)
            step_fn = build_train_step(cfg, tcfg)
            step = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(step_fn).lower(params, opt, batch, step)
            step_kind = "train_step"
            tokens = info["global_batch"] * info["seq_len"]
            model_flops = 6.0 * active_param_count(cfg) * tokens
        elif kind == "prefill":
            params = params_abstract(cfg, mesh, rules)
            batch = batch_specs(cfg, shape_name, mesh, rules)
            fn = lambda p, b: prefill(p, cfg, b)
            lowered = jax.jit(fn).lower(params, batch)
            step_kind = "prefill_step"
            tokens = info["global_batch"] * info["seq_len"]
            model_flops = 2.0 * active_param_count(cfg) * tokens
        else:  # decode
            params = params_abstract(cfg, mesh, rules)
            spec = batch_specs(cfg, shape_name, mesh, rules)
            fn = lambda p, c, t, pos: decode_step(p, cfg, t, c, pos)
            lowered = jax.jit(fn).lower(
                params, spec["caches"], spec["token"], spec["pos"])
            step_kind = "serve_step"
            tokens = info["global_batch"]   # one token per sequence
            model_flops = 2.0 * active_param_count(cfg) * tokens
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", -1.0))
    xla_bytes = float(cost.get("bytes accessed", -1.0))
    mem = _memory_analysis(compiled)
    hlo = compiled.as_text()
    colls = collective_summary(hlo, chips, default_trip=cfg.n_blocks)
    # XLA's cost_analysis counts while (scan) bodies once, and re-multiplying
    # the HLO text fights XLA's loop-widening transforms — so compute/memory
    # terms come from the exact analytic per-arch model (launch.analytic),
    # cross-checked against the XLA per-body count recorded alongside.
    from repro.launch.analytic import step_flops, step_hbm_bytes
    model_par = mesh.shape.get("model", 1)
    fl = step_flops(cfg, shape_name)
    flops = fl["flops_global"] / chips
    hb = step_hbm_bytes(cfg, shape_name, chips, model_par=model_par)
    bytes_acc = hb["hbm_bytes_per_device"]

    compute_term = flops / HW["peak_flops"]
    memory_term = bytes_acc / HW["hbm_bw"]
    collective_term = colls["per_device_wire_bytes"] / HW["link_bw"]
    terms = {"compute_s": compute_term, "memory_s": memory_term,
             "collective_s": collective_term}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf_per_device = model_flops / chips
    useful_ratio = mf_per_device / flops if flops > 0 else None
    roofline_fraction = (mf_per_device / HW["peak_flops"]) / step_time \
        if step_time > 0 else None

    result = {
        "arch": arch, "shape": shape_name, "step": step_kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device": {
            "hlo_flops": flops, "hlo_bytes": bytes_acc,
            "xla_cost_flops": xla_flops, "xla_cost_bytes": xla_bytes,
            "collective_wire_bytes": colls["per_device_wire_bytes"],
            "arg_bytes": _arg_bytes(
                params if kind != "train" else (params, opt), mesh),
        },
        "collectives": {"by_kind": colls["by_kind_bytes"],
                        "op_counts": colls["op_counts"]},
        "memory_analysis": mem,
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_global": model_flops,
            "model_flops_per_device": mf_per_device,
            "useful_flops_ratio": useful_ratio,
            "roofline_fraction": roofline_fraction,
        },
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fname = f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}"
        if tag:
            fname += f"__{tag}"
            result["variant"] = tag
        with open(os.path.join(RESULTS_DIR, fname + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def _print_result(r: dict):
    rf = r["roofline"]
    print(f"[dryrun] {r['arch']} x {r['shape']} on {r['mesh']} ({r['step']})")
    print(f"  lower {r['lower_s']}s  compile {r['compile_s']}s")
    print(f"  per-device: {r['per_device']['hlo_flops']:.3e} flops, "
          f"{r['per_device']['hlo_bytes']:.3e} bytes, "
          f"{r['per_device']['collective_wire_bytes']:.3e} coll bytes")
    print(f"  memory_analysis: {r['memory_analysis']}")
    print(f"  roofline: compute {rf['compute_s']:.4f}s | memory "
          f"{rf['memory_s']:.4f}s | collective {rf['collective_s']:.4f}s "
          f"-> dominant {rf['dominant']}")
    print(f"  useful-flops ratio {rf['useful_flops_ratio'] and round(rf['useful_flops_ratio'], 3)}; "
          f"roofline fraction {rf['roofline_fraction'] and round(rf['roofline_fraction'], 4)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every runnable cell in subprocesses")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. moe_impl=a2a)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule override key=value "
                         "(e.g. fsdp=None, act_seq=model)")
    ap.add_argument("--tag", default="",
                    help="variant tag for the result filename (perf log)")
    args = ap.parse_args()

    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = []
        for arch, shape, runnable in cells(include_skipped=False):
            for mp in meshes:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape] + \
                    (["--multi-pod"] if mp else [])
                print(f"=== {arch} x {shape} ({'2x16x16' if mp else '16x16'}) ===",
                      flush=True)
                rc = subprocess.run(cmd, timeout=args.timeout).returncode
                if rc != 0:
                    failures.append((arch, shape, mp))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("ALL CELLS COMPILED")
        return

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    if args.shape == "long_500k" and args.arch not in LONG_CONTEXT_ARCHS:
        print(f"[dryrun] SKIP {args.arch} x long_500k: pure full-attention "
              f"arch (see DESIGN.md §Arch-applicability)")
        return
    overrides = dict(_parse_override(kv) for kv in args.set)
    extra_rules = dict(_parse_override(kv) for kv in args.rule) or None
    r = run_cell(args.arch, args.shape, args.multi_pod,
                 extra_rules=extra_rules, overrides=overrides, tag=args.tag)
    _print_result(r)


if __name__ == "__main__":
    main()
