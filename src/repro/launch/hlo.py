"""Post-SPMD HLO analysis: per-device collective traffic for the roofline.

``cost_analysis()`` gives FLOPs and memory bytes but not collective volume,
so we parse the optimized (partitioned) HLO text:

* every ``all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute`` instruction's operand/output bytes,
* its replica-group size g (both ``{{0,1},...}`` and iota
  ``[groups,size]<=[N]`` forms),
* the *loop multiplier*: collectives inside a ``while`` body (scan over
  blocks, microbatch loops) execute once per iteration — trip counts come
  from XLA's ``known_trip_count`` backend config, with a caller-provided
  fallback for bodies XLA didn't annotate.

Ring-model bytes-on-the-wire per device:
  all-gather: O*(g-1)/g       (O = per-device output bytes)
  reduce-scatter: O*(g-1)     (O = per-device scattered output)
  all-reduce: 2*Z*(g-1)/g
  all-to-all: Z*(g-1)/g
  collective-permute: Z
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string like 'f32[128,1024]' or a tuple
    '(f32[8], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        first = m.group(1).strip()
        return len(first.split(",")) if first else 1
    m = re.search(r"replica_groups=\{\}", line)
    if m:
        return total_devices
    return total_devices


@dataclasses.dataclass
class Collective:
    kind: str
    bytes_buffer: int       # per-device buffer bytes (shape on the line)
    group: int
    computation: str
    multiplier: int         # loop trip count product

    @property
    def wire_bytes(self) -> float:
        g = max(self.group, 1)
        z = self.bytes_buffer
        if self.kind == "all-gather":
            return z * (g - 1) / g
        if self.kind == "reduce-scatter":
            return z * (g - 1)
        if self.kind == "all-reduce":
            return 2.0 * z * (g - 1) / g
        if self.kind == "all-to-all":
            return z * (g - 1) / g
        return float(z)     # collective-permute


def _computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ") -> " in stripped:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None and (stripped.startswith("%")
                                    or stripped.startswith("ROOT")):
            comps[current].append(stripped)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    return m.group(1) if m else None


def parse_collectives(hlo: str, total_devices: int,
                      default_trip: int = 1) -> List[Collective]:
    comps = _computations(hlo)
    entry = _entry_name(hlo)

    # while-op edges: caller computation -> (body name, trip count)
    body_trip: Dict[str, int] = {}
    call_edges: Dict[str, List[str]] = {c: [] for c in comps}
    for cname, lines in comps.items():
        for line in lines:
            if re.search(r"=\s*\S*\s*while\(", line) or " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mt = re.search(r'known_trip_count[="\{:\s]+"?n"?[":\s]+"?(\d+)',
                               line)
                if mb:
                    trip = int(mt.group(1)) if mt else default_trip
                    body_trip[mb.group(1)] = trip
                    call_edges[cname].append(mb.group(1))
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mc:
                    call_edges[cname].append(mc.group(1))
            else:
                for attr in ("to_apply", "body", "condition", "branch_computations"):
                    for mm in re.finditer(attr + r"=%?([\w\.\-]+)", line):
                        call_edges[cname].append(mm.group(1))
                for mm in re.finditer(r"calls=%?([\w\.\-]+)", line):
                    call_edges[cname].append(mm.group(1))

    # propagate multipliers from entry through the call graph
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps:
            return
        mult[name] = max(mult.get(name, 0), m)
        for callee in call_edges.get(name, []):
            child_m = m * body_trip.get(callee, 1)
            if mult.get(callee, 0) < child_m:
                visit(callee, child_m)

    if entry:
        visit(entry, 1)
    else:
        for c in comps:
            mult.setdefault(c, 1)

    out: List[Collective] = []
    for cname, lines in comps.items():
        m = mult.get(cname, default_trip)
        for line in lines:
            for kind in _COLL_KINDS:
                # match `kind(` as the opcode (avoid -start/-done dupes:
                # count only the -start or the plain form)
                op_m = re.search(rf"\s{kind}(-start)?\(", line)
                if op_m and f"{kind}-done" not in line:
                    # shape(s) live between '=' and the opcode; tuple shapes
                    # (e.g. variadic all-to-all) parse element-wise
                    eq = line.find("=")
                    shape_part = line[eq + 1: op_m.start() + 1] if eq >= 0 \
                        else line[: op_m.start() + 1]
                    nbytes = _shape_bytes(shape_part)
                    g = _group_size(line, total_devices)
                    out.append(Collective(kind=kind, bytes_buffer=nbytes,
                                          group=g, computation=cname,
                                          multiplier=m))
                    break
    return out


# ---------------------------------------------------------------------------
# analytic module cost (XLA's cost_analysis counts while bodies ONCE; we
# re-derive flops/bytes with loop-trip multipliers from the same text)
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _call_graph(comps: Dict[str, List[str]], default_trip: int):
    """Returns (multipliers, fusion_bodies) over the computation graph."""
    body_trip: Dict[str, int] = {}
    call_edges: Dict[str, List[str]] = {c: [] for c in comps}
    fusion_bodies = set()
    for cname, lines in comps.items():
        for line in lines:
            if re.search(r"\swhile\(", line):
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mt = re.search(
                    r'known_trip_count[="\{:\s]+"?n"?[":\s]+"?(\d+)', line)
                if mb:
                    body_trip[mb.group(1)] = int(mt.group(1)) if mt \
                        else default_trip
                    call_edges[cname].append(mb.group(1))
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mc:
                    call_edges[cname].append(mc.group(1))
                continue
            is_fusion = re.search(r"\sfusion\(", line) is not None
            for mm in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                call_edges[cname].append(mm.group(1))
                if is_fusion:
                    fusion_bodies.add(mm.group(1))
            for mm in re.finditer(r"branch_computations=\{([^}]*)\}", line):
                for b in mm.group(1).split(","):
                    call_edges[cname].append(b.strip().lstrip("%"))
    return body_trip, call_edges, fusion_bodies


def _multipliers(hlo: str, comps, default_trip: int):
    body_trip, call_edges, fusion_bodies = _call_graph(comps, default_trip)
    entry = _entry_name(hlo)
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps:
            return
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        for callee in call_edges.get(name, []):
            visit(callee, m * body_trip.get(callee, 1))

    if entry:
        visit(entry, 1)
    for c in comps:
        mult.setdefault(c, 1)
    return mult, fusion_bodies


def _parse_dims(shape_str: str):
    """First shape in the string -> (dtype, [dims])."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def module_cost(hlo: str, default_trip: int = 1) -> dict:
    """Per-device (flops, hbm_bytes) with while-loop multipliers.

    flops: dot/convolution ops (2 * out_elems * contracted), counted in every
    computation (fusion bodies inherit their caller's multiplier).
    hbm_bytes: operand+output bytes of every top-level (post-fusion)
    instruction — each fusion reads its inputs and writes its outputs from/to
    HBM exactly once, so this is the natural traffic model.
    """
    comps = _computations(hlo)
    mult, fusion_bodies = _multipliers(hlo, comps, default_trip)

    # def-site shape maps: per computation, name -> (dtype, dims, bytes)
    defs: Dict[str, Dict[str, tuple]] = {}
    global_defs: Dict[str, tuple] = {}
    parsed: Dict[str, List[tuple]] = {}
    for cname, lines in comps.items():
        dmap = {}
        plist = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape_str, opcode, rest = m.groups()
            nbytes = _shape_bytes(shape_str)
            dt, dims = _parse_dims(shape_str)
            dmap[name] = (dt, dims, nbytes)
            global_defs.setdefault(name, (dt, dims, nbytes))
            plist.append((name, shape_str, opcode, rest, line))
        defs[cname] = dmap
        parsed[cname] = plist

    def lookup(cname, opname):
        return defs[cname].get(opname) or global_defs.get(opname) \
            or (None, [], 0)

    flops = 0.0
    hbm = 0.0
    for cname, plist in parsed.items():
        m = mult.get(cname, 1)
        top_level = cname not in fusion_bodies
        for name, shape_str, opcode, rest, line in plist:
            if opcode == "dot":
                _, out_dims, _ = lookup(cname, name)
                ops = re.findall(r"%([\w\.\-]+)", rest)
                lhs_dims = lookup(cname, ops[0])[1] if ops else []
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                contracted = 1
                if mcd and mcd.group(1):
                    for d in mcd.group(1).split(","):
                        if int(d) < len(lhs_dims):
                            contracted *= lhs_dims[int(d)]
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                flops += 2.0 * out_elems * contracted * m
            elif opcode == "convolution":
                _, out_dims, _ = lookup(cname, name)
                mw = re.search(r"window=\{size=([\dx]+)", line)
                ksize = 1
                if mw:
                    for d in mw.group(1).split("x"):
                        ksize *= int(d)
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                flops += 2.0 * out_elems * ksize * m
            if top_level and opcode not in _SKIP_BYTES_OPS:
                _, _, out_bytes = lookup(cname, name)
                total = out_bytes
                for op in re.findall(r"%([\w\.\-]+)", rest.split("),")[0]):
                    total += lookup(cname, op)[2]
                hbm += total * m
    return {"flops": flops, "hbm_bytes": hbm}


def collective_summary(hlo: str, total_devices: int,
                       default_trip: int = 1) -> dict:
    colls = parse_collectives(hlo, total_devices, default_trip)
    by_kind: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for c in colls:
        by_kind[c.kind] = by_kind.get(c.kind, 0.0) + c.wire_bytes * c.multiplier
        count[c.kind] = count.get(c.kind, 0) + c.multiplier
    return {
        "per_device_wire_bytes": sum(by_kind.values()),
        "by_kind_bytes": by_kind,
        "op_counts": count,
        "n_sites": len(colls),
    }
