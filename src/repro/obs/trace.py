"""Span tracing, structured JSONL events, and the ``profile()`` bracket.

Spans are lightweight context managers recording wall-clock duration
into the registry (``span.<name>.seconds`` histogram plus a
``span.<name>.calls`` counter) and, when an event sink is attached,
emitting one structured event per span with nesting depth, parent span
name, and per-span attrs.  When the registry is disabled,
``span(...)`` returns a shared no-op object — no allocation, no timer.

Event sinks are callables taking one dict; ``jsonl_sink(path)`` adapts
a file path.  Setting ``CAMEO_OBS_EVENTS=<path>`` in the environment
attaches a JSONL file sink to the process-wide registry at import.

``profile(logdir)`` is the opt-in ``jax.profiler`` bracket for TPU/CPU
trace capture; it imports jax lazily so the obs package itself stays
dependency-free.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

_TLS = threading.local()


def _stack():
    s = getattr(_TLS, "spans", None)
    if s is None:
        s = _TLS.spans = []
    return s


class _NullSpan:
    """Shared no-op span returned when the registry is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):
        return self


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "attrs", "registry", "t0", "depth", "parent")

    def __init__(self, registry, name, attrs):
        self.registry = registry
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.depth = 0
        self.parent = None

    def set(self, key, value):
        """Attach/overwrite an attr mid-span."""
        self.attrs[key] = value
        return self

    def __enter__(self):
        stack = _stack()
        self.depth = len(stack)
        self.parent = stack[-1].name if stack else None
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dt = time.perf_counter() - self.t0
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        reg = self.registry
        reg.observe(f"span.{self.name}.seconds", dt)
        reg.inc(f"span.{self.name}.calls")
        if reg._sinks:
            ev = {"ev": "span", "name": self.name, "dur_s": dt,
                  "depth": self.depth, "parent": self.parent}
            if exc_type is not None:
                ev["error"] = exc_type.__name__
            if self.attrs:
                ev["attrs"] = self.attrs
            emit_event(reg, ev)
        return False


def current_span():
    """The innermost active span on this thread, or None."""
    s = _stack()
    return s[-1] if s else None


def jsonl_sink(path):
    """An event sink appending one JSON object per line to ``path``."""
    lock = threading.Lock()

    def sink(ev):
        line = json.dumps(ev, sort_keys=True, default=str)
        with lock:
            with open(path, "a") as f:
                f.write(line + "\n")

    sink.path = path
    return sink


def emit_event(registry, ev):
    """Deliver one structured event dict to every attached sink."""
    if "ts" not in ev:
        ev = dict(ev, ts=time.time())
    for sink in registry._sinks:
        try:
            sink(ev)
        except Exception:
            pass  # telemetry must never take down the data path


def attach_env_sink(registry):
    """Honor ``CAMEO_OBS_EVENTS=<path>`` by attaching a JSONL sink."""
    path = os.environ.get("CAMEO_OBS_EVENTS", "").strip()
    if path:
        registry._sinks.append(jsonl_sink(path))


@contextlib.contextmanager
def profile(logdir=None):
    """Opt-in ``jax.profiler`` bracket: traces device + host activity
    for the wrapped region into ``logdir`` (viewable with TensorBoard
    or Perfetto).  Usable regardless of the ``CAMEO_OBS`` flag — the
    explicit call *is* the opt-in.  Never raises: if the profiler is
    unavailable or already active the region simply runs untraced.
    """
    import tempfile

    if logdir is None:
        logdir = os.environ.get("CAMEO_OBS_PROFILE_DIR") or os.path.join(
            tempfile.gettempdir(), "cameo_profile")
    started = False
    try:
        import jax

        jax.profiler.start_trace(logdir)
        started = True
    except Exception:
        pass
    try:
        yield logdir
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass
