"""``repro.obs`` — the unified telemetry layer for the CAMEO stack.

One process-wide :class:`~repro.obs.registry.MetricsRegistry` (``OBS``)
collects counters, gauges, and bounded-memory streaming histograms from
every layer — streaming ingest (``stream.*``), the elimination kernels
(``mvar.*``, ``write.*``), the block store (``store.*``), the pushdown
query planner (``query.*``), and span timings (``span.*``) — and
exports them as a plain dict (:func:`snapshot`) or Prometheus-style
text (:func:`exposition`).

Enabling
--------
Telemetry is **off by default**.  Set ``CAMEO_OBS=1`` in the
environment or call :func:`enable` at runtime.  Every instrumented hot
path is guarded by ``if OBS.enabled:`` so the disabled cost is a single
attribute lookup (bounded by a microbench in ``tests/test_obs.py``),
and enabling telemetry changes **no** compressed bytes and **no** query
answers (differential-tested).  Steady-state ingest overhead with
telemetry on is gated at <= 3% in ``benchmarks/perf_smoke.py``
(``obs_overhead`` row).

Metric name inventory (the production names; benchmarks reuse them)
-------------------------------------------------------------------
================================  =====================================
``stream.push_seconds``            per-push latency histogram
``stream.windows`` / ``stream.windows_verbatim``  windows closed / kept-verbatim
``stream.window_rounds``           elimination rounds per window (hist)
``stream.window_eps_headroom``     measured deviation / eps budget (hist)
``stream.pad_to_bucket_hits``      partial tails padded to the full bucket
``stream.queue_depth`` (gauge) / ``stream.queue_drains`` / ``stream.drain_windows``
``mvar.repair_halvings``           per-column eps repair loop halvings
``write.seconds`` / ``write.eps_headroom``  one-shot facade writes
``store.cache.hits|misses|evictions``  decoded-block LRU traffic
``store.read.mmap_bytes|pread_bytes``  body bytes by read path
``store.read.coalesced_runs|blocks_fetched``  pread coalescing
``store.write.blocks|bytes``       block bodies appended
``wal.records`` / ``wal.append_bytes``  write-ahead journal appends
``wal.group_commits`` / ``wal.group_batch_records``  fsync barriers / batch size (hist)
``wal.fsync_seconds``              group-commit fsync latency (hist)
``wal.checkpoints`` / ``wal.recoveries``  journal truncations / crash recoveries
``wal.replayed_records|points``    journaled pushes re-fed on resume
``ingest.ack_seconds``             façade push journal-ack latency (hist)
``query.count`` / ``query.kind.<agg>`` / ``query.seconds``  query dispatch
``query.segments_meta|segments_edge``  pushdown-vs-decode block decisions
``query.meta_only|with_edge_decode``   per-query decision outcome
``query.bound_width``              realized pushdown bound widths (hist)
``span.<name>.seconds|calls``      user/code spans
``server.sessions`` (gauge) / ``server.pushes|points|rejects``  ingest server
``server.tenant.pushes|points``    per-tenant (labeled ``{tenant="..."}``)
``store.tier.cold.hits|bytes``     cold-tier (entropy-wrapped) body fetches
``store.compaction.runs|blocks_merged|dead_bytes``  compaction rewrites
================================  =====================================

Labels
------
``inc``/``gauge``/``observe`` take an optional ``labels`` dict; a
labeled series is stored under the rendered key ``name{k="v"}`` (sorted
keys), shares its base metric's ``# TYPE`` line in :func:`exposition`,
and costs nothing when ``labels`` is ``None`` — the disabled-path
contract (one attribute lookup behind ``if OBS.enabled:``) is
unchanged.  The ingest server labels its per-tenant traffic this way;
unlabeled call sites produce byte-identical exposition to before.

The unified stats snapshot schema
---------------------------------
The historical per-layer ``stats()`` dicts now share one schema for
overlapping concepts.  ``Dataset.stats()`` and
``TimeSeriesService.stats()`` both return::

    series, points, n_kept, stored_nbytes, raw_nbytes,
    point_cr, bytes_cr, cache={hits,misses,evictions,entries,nbytes,budget}

computed from O(1) running ingest totals (``CameoStore.ingest_totals``)
— pass ``deep=True`` for the exhaustive per-series ``compression_stats``
walk (adds ``per_series``).  The same cache counters also stream into
the registry as ``store.cache.*``.  :func:`snapshot` is the documented
registry schema (see :meth:`MetricsRegistry.snapshot`).

Recompiles
----------
:func:`register_jit` + :func:`recompile_watermark` generalize the old
``core.streaming.compile_cache_size`` (now a shim) to every jitted
entry point — rounds/batch, sequential, multivariate reconstruct, and
block reconstruct.  A zero watermark delta across a warmed region is
the no-recompile property the perf gates assert.
"""
from __future__ import annotations

from .registry import MetricsRegistry, StreamingHistogram, sanitize_metric_name
from .trace import (NULL_SPAN, Span, attach_env_sink, current_span,
                    emit_event, jsonl_sink, profile)

__all__ = [
    "OBS", "MetricsRegistry", "StreamingHistogram", "Span", "NULL_SPAN",
    "enable", "disable", "enabled", "reset", "inc", "gauge", "observe",
    "span", "event", "add_event_sink", "jsonl_sink", "current_span",
    "profile", "snapshot", "exposition", "register_jit",
    "recompile_watermark", "recompile_counts", "sanitize_metric_name",
]

#: The process-wide registry every instrumented layer records into.
OBS = MetricsRegistry()
attach_env_sink(OBS)


def enable():
    """Turn telemetry on for the process-wide registry."""
    OBS.enable()


def disable():
    """Turn telemetry off (instrumented sites fall back to one attribute
    lookup per potential observation)."""
    OBS.disable()


def enabled():
    return OBS.enabled


def reset():
    """Clear recorded metrics (jit registrations and sinks survive)."""
    OBS.reset()


def inc(name, delta=1, labels=None):
    OBS.inc(name, delta, labels=labels)


def gauge(name, value, labels=None):
    OBS.gauge(name, value, labels=labels)


def observe(name, value, labels=None):
    OBS.observe(name, value, labels=labels)


def span(name, **attrs):
    """``with obs.span("stream.push", sid=sid): ...`` — times the block
    into ``span.<name>.seconds``; nests; no-op when disabled."""
    if not OBS.enabled:
        return NULL_SPAN
    return Span(OBS, name, attrs)


def event(name, **fields):
    """Emit a structured event to the attached JSONL sinks."""
    if not OBS.enabled:
        return
    emit_event(OBS, dict(fields, ev=name))


def add_event_sink(sink):
    """Attach an event sink (a callable taking one dict, e.g.
    ``jsonl_sink(path)``)."""
    OBS._sinks.append(sink)


def snapshot():
    """The documented registry snapshot dict (see
    :meth:`MetricsRegistry.snapshot`)."""
    return OBS.snapshot()


def exposition(prefix="cameo"):
    """Prometheus-style text exposition of the process-wide registry."""
    return OBS.exposition(prefix)


def register_jit(name, fn):
    """Register a jitted entry point under the recompile watermark."""
    OBS.register_jit(name, fn)


def recompile_watermark():
    """Total compiled variants across all registered jitted entries."""
    return OBS.recompile_watermark()


def recompile_counts():
    """Per-entry compiled-variant counts."""
    return OBS.recompile_counts()
