"""Process-wide metrics registry: counters, gauges, streaming histograms.

Zero-dependency by design (stdlib only — no numpy/jax import at module
level) so that ``repro.obs`` can be threaded through every layer of the
stack without changing import graphs or adding overhead to processes
that never enable it.

Hot-path contract
-----------------
Instrumented call sites guard every observation with::

    if OBS.enabled:
        OBS.inc("stream.windows")

so the disabled path costs exactly one attribute lookup (verified by a
microbench in ``tests/test_obs.py``).  The registry itself never
allocates per-observation when disabled because the guard lives at the
call site, not inside the registry.

Histograms
----------
``StreamingHistogram`` is a bounded-memory log-bucketed sketch: buckets
are spaced ``2**(1/16)`` apart (16 sub-buckets per octave), giving a
worst-case relative quantile error of ~4.4% over the clamped range
``[2**-40, 2**40]`` (~9e-13 .. ~1.1e12) with at most 1280 occupied
buckets.  ``count``/``sum``/``min``/``max`` are exact.

Recompile watermark
-------------------
``register_jit(name, fn)`` records a jitted entry point; the registry's
``recompile_watermark()`` sums ``fn._cache_size()`` over every
registered entry.  A before/after delta of the watermark around a
region counts XLA compilations triggered inside it — the generalization
of the old ``core.streaming.compile_cache_size`` (which watched only
the rounds kernel).  Registration and watermarking work regardless of
the enabled flag: they are introspection, not instrumentation.
"""
from __future__ import annotations

import math
import os
import threading

# Sub-buckets per octave (power of two).  16 -> ~4.4% relative error.
_SUB = 16
_LOG2_SUB = _SUB / math.log(2.0)  # multiply ln(v) by this to get bucket idx
_IDX_MIN = -40 * _SUB
_IDX_MAX = 40 * _SUB
_QUANTILES = (0.5, 0.95, 0.99)


def _fmt(v):
    """Deterministic number formatting for the exposition surface."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    if v != v:  # NaN
        return "NaN"
    return format(v, ".10g")


def sanitize_metric_name(name):
    """Dotted metric name -> Prometheus-legal name (``a.b-c`` -> ``a_b_c``)."""
    out = []
    for ch in name:
        out.append(ch if (ch.isalnum() and ch.isascii()) or ch == "_" else "_")
    s = "".join(out)
    if s and s[0].isdigit():
        s = "_" + s
    return s


def _escape_label(v):
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def labeled(name, labels):
    """Render a metric name + label dict into the registry's labeled-key
    form, ``name{k="v",...}`` (keys sorted, values escaped).  Labeled
    series are just distinct keys in the counter/gauge/histogram dicts —
    the hot path stays a plain dict operation and the exposition surface
    recognises the embedded suffix (see ``exposition``).  An empty/None
    label dict returns the bare name, so unlabeled call sites are
    byte-for-byte unchanged."""
    if not labels:
        return name
    inner = ",".join(f'{sanitize_metric_name(str(k))}="{_escape_label(v)}"'
                     for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


def _split_key(name):
    """Split a (possibly labeled) metric key into ``(base, suffix)`` where
    ``suffix`` is the literal ``{...}`` label block or ``""``."""
    i = name.find("{")
    if i < 0:
        return name, ""
    return name[:i], name[i:]


def _expo_sorted(keys):
    """Exposition order: group by *sanitized* base name, then label
    block.  Sorting raw keys would let a dotted name (``a.b.c``) sort
    between a base (``a.b``) and its labeled ``a.b{...}`` keys and split
    the family across two ``# TYPE`` lines, which Prometheus parsers
    reject as a duplicate."""
    def order(name):
        base, suffix = _split_key(name)
        return sanitize_metric_name(base), suffix
    return sorted(keys, key=order)


class StreamingHistogram:
    """Bounded-memory streaming histogram with interpolated quantiles.

    Designed for non-negative measurements (latencies, byte counts,
    rounds).  Non-positive observations are counted and contribute to
    ``count``/``sum``/``min``/``max`` exactly; quantiles that land in the
    non-positive mass resolve to the tracked minimum.
    """

    __slots__ = ("count", "sum", "min", "max", "_nonpos", "_buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._nonpos = 0
        self._buckets = {}

    def observe(self, value):
        v = float(value)
        if v != v:  # drop NaN: it would poison sum/min/max
            return
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self._nonpos += 1
            return
        i = int(math.floor(math.log(v) * _LOG2_SUB))
        if i < _IDX_MIN:
            i = _IDX_MIN
        elif i > _IDX_MAX:
            i = _IDX_MAX
        b = self._buckets
        b[i] = b.get(i, 0) + 1

    def quantile(self, q):
        """Interpolated quantile; exact to within one bucket (~4.4% rel)."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        if target < 1.0:
            target = 1.0
        cum = self._nonpos
        if target <= cum:
            return self.min
        for i in sorted(self._buckets):
            c = self._buckets[i]
            if cum + c >= target:
                lo = 2.0 ** (i / _SUB)
                hi = 2.0 ** ((i + 1) / _SUB)
                frac = (target - cum) / c
                v = lo * (hi / lo) ** frac
                return min(max(v, self.min), self.max)
            cum += c
        return self.max

    def snapshot(self):
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": math.nan, "max": math.nan,
                    "p50": math.nan, "p95": math.nan, "p99": math.nan}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.5),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


def _env_enabled():
    return os.environ.get("CAMEO_OBS", "0").strip().lower() not in (
        "", "0", "false", "off", "no")


class MetricsRegistry:
    """Counters, gauges, histograms, span stats, and the jit watermark.

    One process-wide instance (``repro.obs.OBS``) is created at import;
    independent instances can be built for tests.  Mutating calls are
    cheap dict operations (no locking on the hot path — CPython's GIL
    makes the worst race a lost increment, acceptable for telemetry);
    a lock guards structural operations (histogram creation, sinks).
    """

    def __init__(self, enabled=None):
        self.enabled = _env_enabled() if enabled is None else bool(enabled)
        self._counters = {}
        self._gauges = {}
        self._hists = {}
        self._jits = {}
        self._sinks = []
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------
    def inc(self, name, delta=1, labels=None):
        if labels:
            name = labeled(name, labels)
        c = self._counters
        c[name] = c.get(name, 0) + delta

    def gauge(self, name, value, labels=None):
        if labels:
            name = labeled(name, labels)
        self._gauges[name] = value

    def observe(self, name, value, labels=None):
        if labels:
            name = labeled(name, labels)
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, StreamingHistogram())
        h.observe(value)

    def counter_value(self, name, default=0):
        return self._counters.get(name, default)

    def histogram(self, name):
        return self._hists.get(name)

    # -- enable / disable --------------------------------------------------
    def enable(self):
        self.enabled = True

    def disable(self):
        self.enabled = False

    def reset(self):
        """Clear recorded metrics.  Jit registrations and sinks survive:
        they describe process structure, not accumulated measurements."""
        self._counters.clear()
        self._gauges.clear()
        with self._lock:
            self._hists.clear()

    # -- jit watermark -----------------------------------------------------
    def register_jit(self, name, fn):
        """Register a jitted entry point for the recompile watermark.

        ``fn`` must expose jax's ``_cache_size()``.  Re-registering a
        name replaces the previous function (lazily re-created jits).
        """
        if not hasattr(fn, "_cache_size"):
            raise TypeError(
                f"register_jit({name!r}): object has no _cache_size(); "
                "pass the jax.jit wrapper itself")
        self._jits[name] = fn

    def recompile_counts(self):
        """Per-entry compiled-variant counts, ``{name: cache_size}``."""
        return {name: int(fn._cache_size()) for name, fn in
                sorted(self._jits.items())}

    def recompile_watermark(self):
        """Total compiled variants across every registered jitted entry.

        Take a delta of this around any region to count recompiles
        triggered inside it (0 delta == the no-recompile property the
        perf gates assert).
        """
        return sum(int(fn._cache_size()) for fn in self._jits.values())

    # -- export surfaces ---------------------------------------------------
    def snapshot(self):
        """The documented snapshot schema (stable keys, plain types)::

            {
              "enabled":    bool,
              "counters":   {name: int},
              "gauges":     {name: number},
              "histograms": {name: {count,sum,min,max,p50,p95,p99}},
              "recompiles": {"total": int, "entries": {name: int}},
            }
        """
        return {
            "enabled": self.enabled,
            "counters": dict(sorted(self._counters.items())),
            "gauges": dict(sorted(self._gauges.items())),
            "histograms": {k: self._hists[k].snapshot()
                           for k in sorted(self._hists)},
            "recompiles": {
                "total": self.recompile_watermark(),
                "entries": self.recompile_counts(),
            },
        }

    def exposition(self, prefix="cameo"):
        """Prometheus-style text exposition of the current registry.

        Counters become ``<prefix>_<name>_total``, gauges bare samples,
        histograms summaries with ``quantile`` labels plus ``_sum`` /
        ``_count``.  Dots in metric names map to underscores.  Labeled
        series (keys of the ``name{k="v"}`` form written by the
        ``labels=`` kwarg) render their label block after the sample
        name, share one ``# TYPE`` line with their base metric, and for
        histograms merge the ``quantile`` label into the block.  Output
        is deterministic (sorted) so it can be golden-tested.
        """
        lines = []
        last = None
        for name in _expo_sorted(self._counters):
            base, suffix = _split_key(name)
            m = f"{prefix}_{sanitize_metric_name(base)}"
            if m != last:
                lines.append(f"# TYPE {m} counter")
                last = m
            lines.append(f"{m}_total{suffix} {_fmt(self._counters[name])}")
        last = None
        for name in _expo_sorted(self._gauges):
            base, suffix = _split_key(name)
            m = f"{prefix}_{sanitize_metric_name(base)}"
            if m != last:
                lines.append(f"# TYPE {m} gauge")
                last = m
            lines.append(f"{m}{suffix} {_fmt(self._gauges[name])}")
        last = None
        for name in _expo_sorted(self._hists):
            h = self._hists[name]
            base, suffix = _split_key(name)
            m = f"{prefix}_{sanitize_metric_name(base)}"
            if m != last:
                lines.append(f"# TYPE {m} summary")
                last = m
            for q in _QUANTILES:
                qlab = (f'{{{suffix[1:-1]},quantile="{_fmt(q)}"}}' if suffix
                        else f'{{quantile="{_fmt(q)}"}}')
                lines.append(f"{m}{qlab} {_fmt(h.quantile(q))}")
            lines.append(f"{m}_sum{suffix} {_fmt(h.sum)}")
            lines.append(f"{m}_count{suffix} {_fmt(h.count)}")
        if self._jits:
            m = f"{prefix}_recompile_watermark"
            lines.append(f"# TYPE {m} gauge")
            lines.append(f"{m} {_fmt(self.recompile_watermark())}")
        return "\n".join(lines) + "\n"
