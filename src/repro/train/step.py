"""Train-step builder: loss, grad, optimizer update, microbatch accumulation.

The returned ``train_step(params, opt_state, batch, step)`` is what the
dry-run lowers for ``train_*`` shapes and what train.loop jits for real
runs.  Sharding comes entirely from the in/out shardings + the logical
constraints inside the model — the step body is mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.adafactor import (AdafactorConfig, adafactor_init,
                                   adafactor_update)
from repro.optim.schedule import SCHEDULES


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"        # adamw | adafactor
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    schedule: str = "warmup_cosine"
    z_loss: float = 1e-4
    num_microbatches: int = 1
    adamw: AdamWConfig = AdamWConfig()
    adafactor: AdafactorConfig = AdafactorConfig()


def next_token_loss(logits, tokens, z_loss_coef: float = 0.0):
    """Causal LM loss: predict tokens[t+1] from logits[t].

    The gold logit is picked with a one-hot contraction (not
    take_along_axis): over a vocab-sharded logits tensor the contraction
    stays sharded under SPMD, whereas a gather would all-gather the full
    [B, S, V] logits onto every device."""
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    from repro import sharding as shd
    onehot = shd.constrain(onehot, "act_batch", "act_seq", "act_vocab")
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = jnp.mean(logz - gold)
    if z_loss_coef:
        nll = nll + z_loss_coef * jnp.mean(logz ** 2)
    return nll


def loss_fn(params, cfg: ModelConfig, tcfg: TrainConfig, batch):
    logits, aux = forward(params, cfg, batch)
    loss = next_token_loss(logits, batch["tokens"], tcfg.z_loss)
    return loss + aux.astype(jnp.float32), (loss, aux)


def init_opt_state(params, tcfg: TrainConfig):
    if tcfg.optimizer == "adamw":
        return adamw_init(params, tcfg.adamw)
    if tcfg.optimizer == "adafactor":
        return adafactor_init(params, tcfg.adafactor)
    raise ValueError(tcfg.optimizer)


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    sched = SCHEDULES[tcfg.schedule]
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, tcfg=tcfg), has_aux=True)

    def compute_grads(params, batch):
        if tcfg.num_microbatches <= 1:
            (total, (loss, aux)), grads = grad_fn(params, batch=batch)
            return total, loss, aux, grads
        # gradient accumulation: split the global batch into microbatches
        nm = tcfg.num_microbatches

        def reshape(x):
            return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def body(acc, mb):
            (total, (loss, aux)), grads = grad_fn(params, batch=mb)
            acc_g, acc_t, acc_l, acc_a = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / nm, acc_g, grads)
            return (acc_g, acc_t + total / nm, acc_l + loss / nm,
                    acc_a + aux / nm), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, total, loss, aux), _ = jax.lax.scan(
            body, (zero_g, 0.0, 0.0, 0.0), micro)
        return total, loss, aux, grads

    def train_step(params, opt_state, batch, step):
        total, loss, aux, grads = compute_grads(params, batch)
        lr = sched(step, peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                   total=tcfg.total_steps)
        if tcfg.optimizer == "adamw":
            params, opt_state, gnorm = adamw_update(
                grads, opt_state, params, lr, tcfg.adamw)
        else:
            params, opt_state = adafactor_update(
                grads, opt_state, params, lr, tcfg.adafactor)
            gnorm = jnp.asarray(0.0, jnp.float32)
        metrics = {"loss": loss, "total_loss": total, "aux_loss": aux,
                   "lr": lr, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step
