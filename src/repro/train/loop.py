"""Fault-tolerant training loop.

* resumes from the latest intact checkpoint (corrupt/partial ones are
  skipped by the manifest check);
* SIGTERM/SIGINT trigger a final synchronous checkpoint (preemption);
* periodic async checkpoints off the critical path;
* data is a pure function of the step (restart-consistent);
* metrics CSV appended per step (idempotent on resume).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.base import ModelConfig
from repro.train.step import TrainConfig, build_train_step, init_opt_state


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, lcfg: LoopConfig,
               params, batch_fn: Callable[[int], dict],
               log_fn: Callable[[int, dict], None] | None = None):
    """Run the loop; returns (params, opt_state, history)."""
    step_fn = jax.jit(build_train_step(cfg, tcfg))
    opt_state = init_opt_state(params, tcfg)
    start = 0
    if lcfg.ckpt_dir:
        latest = ckpt.latest_step(lcfg.ckpt_dir)
        if latest is not None:
            (params, opt_state), _ = ckpt.restore(
                lcfg.ckpt_dir, latest, template=(params, opt_state))
            start = latest
            print(f"[train] resumed from step {latest}")

    stop = {"now": False}

    def handler(signum, frame):
        stop["now"] = True

    prev_term = signal.signal(signal.SIGTERM, handler)
    history = []
    pending_save = None
    try:
        for step in range(start, lcfg.steps):
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, step)
            if step % lcfg.log_every == 0 or step == lcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                history.append({"step": step, **m})
                if log_fn:
                    log_fn(step, m)
            if lcfg.ckpt_dir and (step + 1) % lcfg.ckpt_every == 0:
                if pending_save is not None:
                    pending_save.join()
                pending_save = ckpt.save_async(
                    lcfg.ckpt_dir, step + 1, (params, opt_state),
                    keep=lcfg.keep)
            if stop["now"]:
                print(f"[train] SIGTERM at step {step}: checkpointing")
                if pending_save is not None:
                    pending_save.join()
                if lcfg.ckpt_dir:
                    ckpt.save(lcfg.ckpt_dir, step + 1, (params, opt_state),
                              keep=lcfg.keep)
                break
    finally:
        signal.signal(signal.SIGTERM, prev_term)
        if pending_save is not None:
            pending_save.join()
    return params, opt_state, history
