"""Explicit data-parallel train step under shard_map, with optional gradient
compression (error feedback) applied *before* the cross-replica psum.

Under plain pjit the gradient all-reduce is implicit and cannot be
compressed; this variant makes it explicit so (a) the collective volume
reduction is visible in the lowered HLO (dry-run §Perf evidence) and
(b) the CAMEO-style "keep the important points" codec from optim.compress
actually changes what crosses the wire.  Params are replicated across the
dp axis here (pure DP) — it composes with TP by nesting meshes, and the
pjit+FSDP path remains the default for the big cells.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.compress import CompressConfig, compress_with_feedback
from repro.train.step import TrainConfig, loss_fn


def build_dp_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                        ccfg: CompressConfig, axis: str = "data") -> Callable:
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, tcfg=tcfg), has_aux=True)

    def shard_body(params, opt_state, residuals, batch, step):
        (total, (loss, aux)), grads = grad_fn(params, batch=batch)
        # compress the local gradient contribution, then reduce the sparse/
        # quantized representation across replicas; residual carries error.
        sent, residuals = compress_with_feedback(grads, residuals, ccfg)
        grads = jax.tree.map(
            lambda g: jax.lax.pmean(g, axis), sent)
        lr = jnp.asarray(tcfg.peak_lr, jnp.float32)
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr, tcfg.adamw)
        metrics = {"loss": jax.lax.pmean(loss, axis),
                   "grad_norm": gnorm}
        return params, opt_state, residuals, metrics

    pspec = P()          # replicated params/opt state (pure DP)
    bspec = P(axis)      # batch sharded over the dp axis

    shard = shd.shard_map(
        shard_body, mesh=mesh,
        in_specs=(pspec, pspec, pspec, bspec, pspec),
        out_specs=(pspec, pspec, pspec, pspec),
        check_vma=False)
    return jax.jit(shard)
