"""Logical-axis sharding rules (MaxText-style) for the model substrate.

Model code annotates parameters and activations with *logical* axis names;
a rule table maps those to physical mesh axes at trace time.  The same model
definition then runs on the single-pod ``(data, model)`` mesh, the multi-pod
``(pod, data, model)`` mesh, a tiny test mesh, or a single device (where the
annotations are no-ops).

Divisibility guard: a logical axis whose mapped mesh-axis product does not
divide the tensor dimension is dropped (replicated) — e.g. 8 KV heads on a
16-way ``model`` axis, or smollm's 9 query heads.  This matches how
production frameworks degrade and keeps every (arch x mesh) cell compilable.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Axes = Union[None, str, tuple]

_state = threading.local()


def _ctx():
    if not hasattr(_state, "mesh"):
        _state.mesh = None
        _state.rules = None
    return _state


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable ``shard_map``: new jax exposes ``jax.shard_map``
    (``check_vma``); older releases ship ``jax.experimental.shard_map``
    (``check_rep``).  All repo call sites go through this wrapper."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[dict]):
    """Activate (mesh, rules) for logical annotations in this thread."""
    st = _ctx()
    prev = (st.mesh, st.rules)
    st.mesh, st.rules = mesh, rules
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx().mesh


def default_rules(multi_pod: bool = False, *, fsdp: bool = True,
                  seq_shard: bool = False, expert_axis: str = "model",
                  pod_pipeline: bool = False) -> dict:
    """Baseline rule table.

    * DP: batch over (pod, data)
    * FSDP/ZeRO-3: weights' non-TP dim over data (within-pod only, so the
      per-layer all-gathers stay on ICI; cross-pod traffic is just the
      gradient all-reduce)
    * TP: heads / ff / vocab over model
    * EP: experts over ``expert_axis``
    * SP (optional): sequence over data for long-context prefill
    """
    data_axes = ("pod", "data") if (multi_pod and not pod_pipeline) else ("data",)
    return {
        # activations
        "act_batch": data_axes,
        "act_seq": "data" if seq_shard else None,
        # residual-stream [B,S,d] tensors only: setting this to "model"
        # turns the TP boundary all-reduces into reduce-scatter+all-gather
        # pairs (sequence parallelism) without touching head/ff axes
        "act_res_seq": "data" if seq_shard else None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_ff": "model",
        "act_vocab": "model",
        "act_kv_seq": "data" if seq_shard else None,
        "act_cache_batch": data_axes,
        "act_cache_seq": None,
        "act_experts": expert_axis,
        "act_inner": "model",
        # parameters
        "fsdp": "data" if fsdp else None,
        "tp": "model",
        "kv_tp": "model",
        "embed_vocab": "model",
        "experts": expert_axis,
        "stage": "pod" if pod_pipeline else None,
        "none": None,
    }


def _resolve(axes: Sequence[Axes], rules: dict):
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        else:
            r = rules.get(a, None)
            out.append(r)
    return out


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([_axis_size(mesh, a) for a in axis]))
    return mesh.shape[axis]


def spec_for(shape: Sequence[int], axes: Sequence[Axes],
             mesh: Optional[Mesh] = None,
             rules: Optional[dict] = None) -> P:
    """PartitionSpec for ``shape`` under logical ``axes`` with the
    divisibility guard applied per dimension."""
    st = _ctx()
    mesh = mesh if mesh is not None else st.mesh
    rules = rules if rules is not None else st.rules
    if mesh is None or rules is None:
        return P()
    assert len(shape) == len(axes), (shape, axes)
    resolved = _resolve(axes, rules)
    parts = []
    for dim, phys in zip(shape, resolved):
        if phys is None or _axis_size(mesh, phys) <= 1 \
                or dim % _axis_size(mesh, phys) != 0:
            parts.append(None)
        else:
            parts.append(phys)
    return P(*parts)


def constrain(x: jax.Array, *axes: Axes) -> jax.Array:
    """with_sharding_constraint via logical axes (no-op outside a mesh)."""
    st = _ctx()
    if st.mesh is None or st.rules is None:
        return x
    spec = spec_for(x.shape, axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(st.mesh, spec))


def named_sharding(shape, axes, mesh=None, rules=None) -> NamedSharding:
    st = _ctx()
    mesh = mesh if mesh is not None else st.mesh
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))
