"""Synthetic stand-ins for the paper's eight datasets (Table 1).

The container is offline, so each generator reproduces the *structural*
properties the paper's experiments depend on: length, sampling granularity,
seasonal periods (the ACF/PACF signature), noise level, value range, and the
oddities called out in Table 1 (SolarPower's 75% repeated values at night,
Pedestrian's non-negative counts).  Lags/kappa per dataset follow the
paper's "ACF #Lag" column ("7 on 48" = 7 lags on kappa=48 aggregates).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    length: int
    lags: int
    kappa: int          # 1 = raw-ACF group; >1 = SIP-on-aggregates group
    description: str


DATASETS: Dict[str, DatasetSpec] = {
    "elec_power": DatasetSpec("elec_power", 2976, 48, 1,
                              "household power, 15-min, daily cycle"),
    "min_temp": DatasetSpec("min_temp", 3650, 365, 1,
                            "daily min temperature, yearly cycle"),
    "pedestrian": DatasetSpec("pedestrian", 8760, 24, 1,
                              "hourly counts, daily+weekly cycles"),
    "uk_elec": DatasetSpec("uk_elec", 17520, 48, 1,
                           "half-hourly national demand, daily cycle"),
    "aus_elec": DatasetSpec("aus_elec", 230688, 7, 48,
                            "half-hourly demand, 7 lags on 48-aggregates"),
    "humidity": DatasetSpec("humidity", 397440, 24, 60,
                            "1-min humidity, 24 lags on hourly aggregates"),
    "ir_bio_temp": DatasetSpec("ir_bio_temp", 878400, 24, 60,
                               "1-min IR surface temperature"),
    "solar": DatasetSpec("solar", 986160, 24, 120,
                         "30-sec solar power, zero at night"),
}


def _season(t, period, harmonics=2):
    out = np.zeros_like(t, dtype=np.float64)
    for h in range(1, harmonics + 1):
        out += np.cos(2 * np.pi * h * t / period) / h
    return out


def _ar1(rng, n, phi=0.7, sigma=1.0):
    from scipy.signal import lfilter
    e = rng.standard_normal(n) * sigma
    return lfilter([1.0], [1.0, -phi], e)


def make_dataset(name: str, seed: int = 0, length: int | None = None) -> np.ndarray:
    spec = DATASETS[name]
    n = length or spec.length
    # stable per-name offset: Python's str hash is salted per process, which
    # made "deterministic" datasets differ between runs (and benchmark CRs
    # drift across invocations) — crc32 is reproducible everywhere.
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)
    t = np.arange(n, dtype=np.float64)

    if name == "elec_power":
        x = 1.2 + 0.8 * _season(t, 96) + 0.3 * _ar1(rng, n, 0.6, 0.4)
        x += (rng.random(n) < 0.02) * rng.exponential(2.0, n)  # spikes
        return np.maximum(x, 0.05)
    if name == "min_temp":
        x = 11.0 + 6.0 * _season(t, 365.25, 1) + _ar1(rng, n, 0.7, 1.6)
        return x
    if name == "pedestrian":
        base = 400 + 380 * _season(t, 24) + 150 * _season(t, 168, 1)
        x = np.maximum(base + _ar1(rng, n, 0.5, 90.0), 0.0)
        return np.round(x)
    if name == "uk_elec":
        x = 27000 + 5200 * _season(t, 48) + 1500 * _season(t, 336, 1) \
            + _ar1(rng, n, 0.85, 450.0)
        return x
    if name == "aus_elec":
        x = 6800 + 1100 * _season(t, 48) + 400 * _season(t, 336, 1) \
            + _ar1(rng, n, 0.8, 120.0)
        return x
    if name == "humidity":
        x = 76 + 15 * _season(t, 1440) + _ar1(rng, n, 0.95, 0.8)
        return np.clip(x, 10.0, 100.0)
    if name == "ir_bio_temp":
        x = 23 + 7.5 * _season(t, 1440) + 2.0 * _season(t, 1440 * 30, 1) \
            + _ar1(rng, n, 0.9, 0.5)
        return x
    if name == "solar":
        day = 2880  # 30-sec samples per day
        phase = (t % day) / day
        daylight = np.clip(np.sin(np.pi * (phase - 0.25) / 0.5), 0.0, None)
        cloud = np.clip(1.0 - 0.35 * np.abs(_ar1(rng, n, 0.98, 0.12)), 0.1, 1.0)
        x = 110.0 * daylight * cloud
        x[x < 1.0] = 0.0  # night: exact repeated zeros (p_= = 75%)
        return x
    raise KeyError(name)


def dataset_cameo_kwargs(name: str) -> dict:
    spec = DATASETS[name]
    return dict(lags=spec.lags, kappa=spec.kappa)
