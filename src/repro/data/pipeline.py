"""Deterministic, restart-consistent data pipeline.

Batches are pure functions of (seed, step) — after a preemption/restart the
loop resumes at the checkpointed step and sees exactly the data it would
have seen (no loader state to checkpoint; the straggler/elastic story in
DESIGN.md §5 relies on this).

Two front doors:

* ``token_batch``      — synthetic LM token batches for the assigned archs.
* ``SeriesTokenizer``  — the CAMEO data plane: real/synthetic sensor series
  -> (optionally CAMEO-compressed) -> binned into vocab tokens -> windows,
  used by the forecasting examples and benchmarks (paper §5.8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro import sharding as shd


def token_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                seed: int = 0):
    """Synthetic LM batch for smoke/e2e runs; deterministic in (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab, jnp.int32)
    out = {"tokens": toks}
    if cfg.frontend == "vision_stub" and cfg.n_patches:
        pk = jax.random.fold_in(key, 1)
        out["patch_embeds"] = 0.02 * jax.random.normal(
            pk, (batch, cfg.n_patches, cfg.d_model), jnp.float32)
    return out


@dataclasses.dataclass
class SeriesTokenizer:
    """Uniform-bin quantizer mapping a scalar series into LM tokens.

    Fit on the raw series (min/max), so compressed and raw variants of the
    same series share a codebook — forecasting comparisons stay apples-to-
    apples (paper §5.8 trains models on compressed data, evaluates on raw).
    """
    vocab: int
    lo: float = 0.0
    hi: float = 1.0

    @classmethod
    def fit(cls, x, vocab: int) -> "SeriesTokenizer":
        x = np.asarray(x)
        lo, hi = float(np.min(x)), float(np.max(x))
        if hi <= lo:
            hi = lo + 1.0
        return cls(vocab=vocab, lo=lo, hi=hi)

    def encode(self, x) -> np.ndarray:
        x = np.asarray(x)
        t = (x - self.lo) / (self.hi - self.lo)
        return np.clip((t * (self.vocab - 1)).round(), 0,
                       self.vocab - 1).astype(np.int32)

    def decode(self, tokens) -> np.ndarray:
        t = np.asarray(tokens, np.float64) / (self.vocab - 1)
        return t * (self.hi - self.lo) + self.lo


def series_windows(tokens: np.ndarray, window: int, stride: int) -> np.ndarray:
    """[n] token stream -> [num_windows, window] training rows."""
    n = tokens.shape[0]
    starts = np.arange(0, n - window + 1, stride)
    return np.stack([tokens[s:s + window] for s in starts])


def forecast_batches(windows: np.ndarray, batch: int, step: int,
                     seed: int = 0):
    """Deterministic batch of windows for a given step."""
    rng = np.random.default_rng(seed + step)
    idx = rng.integers(0, windows.shape[0], size=batch)
    return {"tokens": jnp.asarray(windows[idx])}
