"""Batched time-series ingest + query service over the CameoStore.

.. deprecated:: repro.api
    The service's ingest entry points (``submit``, ``ingest_stream``) are
    **deprecated shims** over the unified :mod:`repro.api` façade —
    ``repro.api.open(path, cfg)`` returns a ``Dataset`` whose ``write`` /
    ``write_batch`` / ``stream`` / ``series`` methods are the single
    documented surface, with first-class multivariate series.  The shims
    keep working and stay byte-identical to the façade — since the
    multi-tenant server landed they are a single-tenant wrapper over
    :class:`repro.server.IngestServer` (default tenant, no small-block
    sealing, no compaction) — but new code should not use them.

The fleet-of-sensors front-end: producers ``submit`` raw series, the
service buffers them into length groups and drives one
``compress_batch`` per group (the TPU-native vmapped rounds mode — one
compile, B series), then streams the results into an append-oriented
:class:`~repro.store.store.CameoStore`.  Reads never wait for ingest:
window decodes and pushdown aggregates are served from the store's block
index the moment a series is flushed.

For feeds that never end, :meth:`TimeSeriesService.ingest_stream` opens a
:class:`StreamIngest` handle instead: arbitrary-size chunks stream through
a ``core/streaming.StreamingCompressor`` (window-at-a-time compression,
per-window ε guarantee) straight into a store ``StreamSession`` that
appends a block the moment its border is provable — the service holds
O(window) state per open stream, no matter how long the feed runs, and
the written prefix is queryable mid-stream.  Closing the *service*
mid-stream stashes the compressor + session state in the store footer;
reopening with ``resume=True`` and ``ingest_stream(sid, resume=True)``
continues bit-exactly (``handle.resume_from`` says which absolute index
to feed next).  The finalized series is byte-identical to compressing
the same windows one-shot (``core/streaming.compress_windowed``) and
storing them with ``append_series``.

This is the same continuous-batching-lite discipline as
``serving/engine.py``'s decode loop — slots fill, a burst runs, results
drain — applied to compression instead of token decoding.  Groups flush
automatically when ``max_batch`` series of one length are waiting;
``flush()`` drains everything (e.g. on shutdown, via the context manager).

Per-series results are bit-identical to ``compress(x, cfg)`` run alone
(see ``compress_batch``'s no-op-round guarantee), so storing through the
service changes nothing about the roundtrip contract.

Reads ride the store's decoded-block LRU (``TsServiceConfig.cache_bytes``):
repeated window decodes and pushdown edge-block decodes over hot blocks
skip bitstream decode entirely; ``stats()["cache"]`` surfaces the
hit/miss/eviction counters for capacity planning.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cameo import CameoConfig
from repro.server.ingest_server import IngestServer, ServerConfig
from repro.store import wal as _wal
from repro.store.query import query as _pushdown_query


@dataclasses.dataclass
class TsServiceConfig:
    max_batch: int = 32           # series per compress_batch burst
    block_len: int = 4096
    value_codec: str = "gorilla"
    entropy: str = "auto"
    store_residuals: bool = True  # keep Plato-style bound metadata
    cache_bytes: int = 64 << 20   # decoded-block LRU budget (0 disables)
    stream_window: int = 4096     # default ingest_stream window length
    queue_depth: int = 1          # ingest_stream windows per batched drain
    # write-ahead journal (crash-safe ingest; see store/README.md):
    # None defers to CAMEO_WAL (default on); the group-commit policy
    # amortizes one fsync over wal_group_ms of wall clock or
    # wal_group_bytes of journal appends, whichever fills first
    wal: Optional[bool] = None
    wal_group_ms: float = _wal.DEFAULT_GROUP_MS
    wal_group_bytes: int = _wal.DEFAULT_GROUP_BYTES


class StreamIngest:
    """One unbounded-feed ingest stream: chunks in, blocks out, O(window)
    state.  A thin service-bookkeeping shim over the ingest server's
    session API (:meth:`repro.server.IngestServer.session`, default
    tenant) — the same ``StreamWriter`` code path underneath, so service
    streams stay byte-identical to ``Dataset.stream`` writes.  Obtain via
    :meth:`TimeSeriesService.ingest_stream`; feed with :meth:`push` and
    :meth:`close` when the feed ends.
    """

    def __init__(self, service: "TimeSeriesService", sid: str,
                 window_len: int, resume: bool, queue_depth: int = None):
        self._svc = service
        self.sid = sid
        self._sess = service._server.session(
            sid, resume=resume, window_len=window_len,
            queue_depth=(service.scfg.queue_depth
                         if queue_depth is None else queue_depth))

    @property
    def resume_from(self) -> int:
        return self._sess.resume_from

    @property
    def n_seen(self) -> int:
        return self._sess.n_seen

    @property
    def channels(self) -> int:
        return self._sess.channels

    @property
    def closed(self) -> bool:
        return self._sess.closed

    def deviation(self) -> float:
        return self._sess.deviation()

    def deviations(self) -> np.ndarray:
        return self._sess.deviations()

    def push(self, chunk) -> int:
        return self._sess.push(chunk)

    def flush(self) -> None:
        self._sess.flush()

    def close(self) -> dict:
        entry = self._sess.close()
        self._svc._streams.pop(self.sid, None)
        self._svc._ingested += 1
        return entry

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if exc[0] is None and not self.closed:
            self.close()


class TimeSeriesService:
    """Ingest+query front-end over one store file."""

    def __init__(self, path: str, ccfg: CameoConfig,
                 scfg: Optional[TsServiceConfig] = None, *,
                 resume: bool = False):
        self.ccfg = ccfg
        self.scfg = scfg or TsServiceConfig()
        # the service is a single-tenant shim over the ingest server:
        # every entry point routes through the server's default-tenant
        # surface (seal_block_len=None, no compaction), so the stored
        # bytes stay identical to the pre-server service and to the
        # Dataset façade
        self._server = IngestServer(
            path, ccfg, ServerConfig(
                block_len=self.scfg.block_len, seal_block_len=None,
                value_codec=self.scfg.value_codec,
                entropy=self.scfg.entropy,
                cache_bytes=self.scfg.cache_bytes,
                store_residuals=self.scfg.store_residuals,
                stream_window=self.scfg.stream_window,
                queue_depth=self.scfg.queue_depth, wal=self.scfg.wal,
                wal_group_ms=self.scfg.wal_group_ms,
                wal_group_bytes=self.scfg.wal_group_bytes,
                max_sessions=1 << 30, auto_compact=False),
            resume=resume)
        self.store = self._server.store
        self._ds = self._server._ds
        # pending ingest, grouped by length (compress_batch wants [B, n])
        self._pending: Dict[int, List[Tuple[str, np.ndarray]]] = {}
        self._streams: Dict[str, StreamIngest] = {}   # open feed streams
        self._ingested = 0
        self._rounds = 0

    # -- lifecycle ----------------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        """Drain pending batches and close the store: the footer publish
        is fsynced and checkpoints the write-ahead journal, so everything
        acked — including open streams' resume state — survives the
        shutdown even if the process dies right after."""
        self.flush()
        self._server.close()

    # -- ingest -------------------------------------------------------------

    def submit(self, sid: str, x) -> None:
        """Queue one series for compression; auto-flushes its length group
        when ``max_batch`` series are waiting.

        .. deprecated:: repro.api
            Use ``repro.api.open(path, cfg).write(sid, x)`` (or
            ``write_batch`` for fleets) — identical bytes, one surface.
        """
        warnings.warn(
            "TimeSeriesService.submit is deprecated; use "
            "repro.api.open(...).write/write_batch",
            DeprecationWarning, stacklevel=2)
        if sid in self.store or any(
                s == sid for g in self._pending.values() for s, _ in g):
            raise ValueError(f"series {sid!r} already submitted")
        x = np.asarray(x)
        if x.ndim != 1:
            raise ValueError(f"series must be 1-D, got {x.shape}")
        group = self._pending.setdefault(x.shape[0], [])
        group.append((sid, x))
        if len(group) >= self.scfg.max_batch:
            self._flush_group(x.shape[0])

    def _flush_group(self, length: int) -> None:
        group = self._pending.pop(length, [])
        if not group:
            return
        # one server call: the default-tenant write_batch drives the same
        # compress_batch-per-length-group burst and append order this
        # method used to hand-roll, so stored bytes are unchanged
        self._server.write_batch(dict(group))
        self._ingested += len(group)
        self._rounds += 1

    def flush(self) -> None:
        """Compress and store every pending series."""
        for length in sorted(self._pending):
            self._flush_group(length)

    def ingest_stream(self, sid: str, *, window_len: int = None,
                      resume: bool = False,
                      queue_depth: int = None) -> StreamIngest:
        """Open a continuous-feed ingest stream for ``sid``.

        Returns a :class:`StreamIngest`: ``push`` arbitrary chunks,
        ``close`` when the feed ends.  ``resume=True`` (on a service opened
        with ``resume=True``) continues an interrupted stream from the
        state stashed in the store footer; feed points from
        ``handle.resume_from`` onward.

        .. deprecated:: repro.api
            Use ``repro.api.open(path, cfg).stream(sid)`` — identical
            bytes, one surface, multivariate-capable.
        """
        warnings.warn(
            "TimeSeriesService.ingest_stream is deprecated; use "
            "repro.api.open(...).stream(sid)",
            DeprecationWarning, stacklevel=2)
        if not resume and (sid in self.store or any(
                s == sid for g in self._pending.values() for s, _ in g)):
            raise ValueError(f"series {sid!r} already submitted")
        if sid in self._streams:
            raise ValueError(f"series {sid!r} already has an open stream")
        h = StreamIngest(self, sid,
                         window_len or self.scfg.stream_window, resume,
                         queue_depth)
        self._streams[sid] = h
        return h

    # -- queries ------------------------------------------------------------

    def query_window(self, sid: str, a: int, b: int) -> np.ndarray:
        """Reconstruction slice ``xr[a:b]`` (bit-exact, edge blocks only)."""
        return self.store.read_window(sid, a, b)

    def query_aggregate(self, sid: str, kind: str, a=None, b=None):
        """Pushdown aggregate ``(value, bound)``; see ``store/query.py``."""
        return _pushdown_query(self.store, sid, kind, a, b)

    def series_ids(self) -> List[str]:
        return self.store.series_ids()

    # -- accounting ---------------------------------------------------------

    def stats(self, *, deep: bool = False) -> dict:
        """Service snapshot in the unified stats schema (see
        :mod:`repro.obs`): the shared keys — ``series``, ``points``,
        ``n_kept``, ``stored_nbytes``, ``raw_nbytes``, ``point_cr``,
        ``bytes_cr``, ``cache`` — match ``Dataset.stats()`` exactly, plus
        service bookkeeping (``ingested``/``pending``/``batches``/
        ``streams``).  Served from the store's O(1) running ingest totals
        — polling is constant-time regardless of how many series or
        blocks are stored.  ``deep=True`` additionally walks
        ``compression_stats`` per series into ``per_series`` (O(total
        series), the pre-telemetry behavior)."""
        out = dict(
            ingested=self._ingested,
            pending=sum(len(g) for g in self._pending.values()),
            batches=self._rounds,
            streams=len(self._streams))
        out.update(self._ds.stats(deep=deep))
        return out
