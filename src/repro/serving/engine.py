"""Batched serving engine: prefill + jitted decode loop.

Supports greedy and temperature sampling, per-sequence EOS tracking, and a
simple waiting-queue refill model (slots freed by finished sequences are
refilled between decode bursts — continuous-batching-lite).  The decode step
it drives is exactly the ``serve_step`` the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, init_caches, prefill


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(
            lambda p, b, ml: prefill(p, cfg, b, max_len=ml),
            static_argnums=(2,))
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        scaled = logits[:, -1, :] / self.scfg.temperature
        return jax.random.categorical(key, scaled, axis=-1)

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: [B, S] int32 (left-aligned, same length).  Returns
        [B, max_new_tokens] generated ids (EOS-padded)."""
        cfg, scfg = self.cfg, self.scfg
        B, S = prompts.shape
        max_len = S + scfg.max_new_tokens
        batch = {"tokens": jnp.asarray(prompts)}
        logits, caches = self._prefill(self.params, batch, max_len)
        key = jax.random.PRNGKey(scfg.seed)
        out = np.full((B, scfg.max_new_tokens), scfg.eos_id or 0, np.int32)
        done = np.zeros((B,), bool)
        tok = self._sample(logits, key)
        for i in range(scfg.max_new_tokens):
            out[:, i] = np.where(done, out[:, i], np.asarray(tok))
            if scfg.eos_id is not None:
                done |= np.asarray(tok) == scfg.eos_id
                if done.all():
                    break
            logits, caches = self._decode(
                self.params, jnp.asarray(tok)[:, None], caches,
                jnp.asarray(S + i, jnp.int32))
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, key)
        return out
