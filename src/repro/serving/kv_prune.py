"""CAMEO-style KV-cache pruning (beyond-paper, DESIGN.md §4).

The paper keeps the *statistically important points* of a series and lets
interpolation carry the rest.  A KV cache is a time series of per-position
keys; its "signal" for attention purposes is well summarized by the
per-position key-norm sequence.  We rank cache positions with CAMEO's exact
greedy machinery (Def. 3, compression-centric: keep n/keep_ratio points
that best preserve the key-norm ACF — i.e. the temporal structure of what
the model attends to) and compact the cache to the kept slots.

The roofline effect is structural: a serve_step lowered against a cache of
``S/keep_ratio`` entries reads 1/keep_ratio of the bytes (dry-run
``kv_prune`` config knob); this module provides the actual selection +
compaction so the pruned serve path is runnable, and the tests pin the
mechanism (no-op prune is exact; impulse positions survive pruning).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cameo import CameoConfig, compress_rounds
from repro.models.attention import KVCache


def importance_series(cache: KVCache) -> jax.Array:
    """Per-position signal: mean key L2 norm across KV heads.  [B, S]."""
    k = cache.k.astype(jnp.float32)
    if cache.k_scale.ndim == 4:      # int8 cache
        k = k * cache.k_scale
    return jnp.sqrt(jnp.mean(jnp.sum(k * k, axis=-1), axis=-1))


def select_positions(cache: KVCache, keep: int, lags: int = 16):
    """CAMEO Def.-3 selection on the key-norm series.  Returns kept slot
    indices [B, keep] (sorted by position)."""
    B, S = cache.pos_ids.shape
    sig = importance_series(cache)
    cr = max(S / keep, 1.0 + 1e-6)
    cfg = CameoConfig(lags=min(lags, S // 4), target_cr=float(cr),
                      mode="rounds", dtype="float32", max_rounds=64)
    res = jax.vmap(lambda row: compress_rounds(row, cfg))(sig)
    kept = np.asarray(res.kept)                     # [B, S] bool
    idx = np.zeros((B, keep), np.int32)
    for b in range(B):
        sel = np.nonzero(kept[b])[0]
        if len(sel) >= keep:
            # drop lowest-importance interior picks down to `keep`
            order = np.argsort(np.asarray(sig)[b][sel])
            drop = len(sel) - keep
            interior = order[(sel[order] != 0) & (sel[order] != S - 1)]
            sel = np.sort(np.setdiff1d(sel, sel[interior[:drop]]))
        else:
            # top-up with the highest-importance unkept positions
            unsel = np.setdiff1d(np.arange(S), sel)
            extra = unsel[np.argsort(-np.asarray(sig)[b][unsel])][: keep - len(sel)]
            sel = np.sort(np.concatenate([sel, extra]))
        idx[b] = sel[:keep]
    return jnp.asarray(idx)


def compact_cache(cache: KVCache, idx: jax.Array) -> KVCache:
    """Gather the kept slots into a cache of size keep (per layer leaf)."""
    B = idx.shape[0]
    bidx = jnp.arange(B)[:, None]

    def take(a):
        if a.ndim >= 2 and a.shape[0] == B and a.shape[1] == cache.pos_ids.shape[1]:
            return a[bidx, idx]
        return a

    return KVCache(k=take(cache.k), v=take(cache.v),
                   pos_ids=take(cache.pos_ids),
                   k_scale=take(cache.k_scale), v_scale=take(cache.v_scale))


def prune_tree(caches, keep: int, lags: int = 16):
    """Apply selection+compaction to every attention KVCache in a cache tree
    (selection computed per layer; Mamba caches pass through)."""
    def visit(node):
        if isinstance(node, KVCache):
            idx = select_positions(node, keep, lags)
            return compact_cache(node, idx)
        if isinstance(node, dict):
            return {k: visit(v) for k, v in node.items()}
        return node

    # stacked block caches: vmap over the leading block axis is overkill for
    # the demo path; handle unstacked (remainder/engine) caches and stacked
    # ones by folding the block axis into batch.
    def visit_stacked(node):
        if isinstance(node, KVCache) and node.pos_ids.ndim == 3:
            L, B, S = node.pos_ids.shape
            flat = KVCache(*[a.reshape((L * B,) + a.shape[2:])
                             if a.ndim >= 3 else a for a in node])
            idx = select_positions(flat, keep, lags)
            out = compact_cache(flat, idx)
            return KVCache(*[a.reshape((L, B) + a.shape[1:])
                             if a.ndim >= 2 and a.shape[0] == L * B else a
                             for a in out])
        if isinstance(node, KVCache):
            return visit(node)
        if isinstance(node, dict):
            return {k: visit_stacked(v) for k, v in node.items()}
        return node

    return visit_stacked(caches)
