"""Background maintenance rewrites over a writable :class:`CameoStore`.

Three rewrites, all sharing one atomicity story: new bytes are appended
past the last published footer, the in-memory catalog is repointed, and
``store.flush()`` publishes the new footer with the two-phase fsync
protocol.  **Nothing is ever overwritten in place** — the superseded
block bodies stay intact below the old footer offset, so a crash at any
point rolls back to the previous footer via the WAL checkpoint and the
store reads exactly as before the rewrite started.  The orphaned bytes
are accounted in ``store.tier_stats()['dead_nbytes']``.

``compact_series``
    Merge runs of adjacent small blocks (the low-latency seal output of
    server stream sessions, ``open_stream(block_len=...)``) into
    full-size blocks.  Block borders are kept points and owned ranges
    partition the series, so the merge is a pure re-blocking: kept
    points and window decodes are **bit-exact** before and after, and
    the stored Plato residual moments of the merged block are the sums
    (max for ``emax``) of the parts' moments — no access to the original
    series is needed.  Pushdown aggregates keep their deterministic
    bounds; their values are re-associated sums, so they agree to
    floating-point re-association (~1 ulp per merge), not bitwise.

``rewrite_cold`` / ``promote_warm``
    Demote block bodies to the cold tier by entropy-wrapping them
    (``codec.entropy_wrap``; a wrap that does not shrink is skipped), or
    promote them back to plain warm bodies.  The catalog block dict of a
    cold block carries ``"wrap": <codec>``; the read path unwraps on
    fetch (``CameoStore._finish_body``), reproducing the original body —
    crc included — so every parse, decode and query answer is
    byte-identical across tiers.
"""
from __future__ import annotations

import numpy as np

from repro.obs import OBS
from repro.store import codec as _codec
from repro.store.blocks import (
    _HDR,
    build_block,
    parse_block,
    reconstruct_block,
)


def _check_rewritable(store, sid: str) -> dict:
    if not store._writable:
        raise IOError("store opened read-only")
    entry = store._series.get(sid)
    if entry is None:
        raise KeyError(f"series {sid!r} not in store")
    if entry.get("streaming"):
        raise ValueError(f"series {sid!r} is still streaming — close the "
                         "session before maintenance rewrites")
    return entry


def _finish(store, sid: str) -> None:
    """Publish a rewrite: drop stale decoded state, then flush the footer
    (the atomic commit point — see module docstring)."""
    store._cache.invalidate(sid)
    for key in [k for k in store._metas if k[0] == sid]:
        del store._metas[key]
    store.flush()


def compact_series(store, sid: str, *, target_len: int = None) -> dict:
    """Merge runs of adjacent small blocks of one finished univariate
    series into blocks of at least ``target_len`` span (default: the
    store-wide ``block_len``).  Returns a report dict; a series with
    nothing to merge is a no-op (no bytes written, no footer flush).
    """
    entry = _check_rewritable(store, sid)
    if int(entry.get("channels", 1)) > 1:
        raise ValueError(f"series {sid!r}: compaction of multivariate "
                         "series is not supported yet")
    if store._block_meta_version < 3:
        raise ValueError("compaction needs a v3+ store")
    blocks = entry["blocks"]
    target = int(target_len or store.block_len)

    # greedy run plan: extend a run while its covered span is still short
    # of the target; only runs of >= 2 blocks are rewritten
    runs = []
    i = 0
    while i < len(blocks):
        j = i
        while (j + 1 < len(blocks)
               and blocks[j]["t1"] - blocks[i]["t0"] + 1 < target):
            j += 1
        if j > i:
            runs.append((i, j))
        i = j + 1
    report = dict(sid=sid, runs=len(runs), blocks_before=len(blocks),
                  blocks_after=len(blocks), stored_before=entry[
                      "stored_nbytes"], stored_after=entry["stored_nbytes"],
                  dead_nbytes=0)
    if not runs:
        return report

    dtype = np.dtype(entry["dtype"])
    L = int(entry["lags"])
    has_resid = bool(entry.get("has_resid"))
    old_stored = entry["stored_nbytes"]

    new_blocks = []
    stored = payload = meta_n = meta_raw = 0
    dead = 0
    run_iter = iter(runs + [(len(blocks), len(blocks))])
    run_i, run_j = next(run_iter)
    bi = 0
    while bi < len(blocks):
        if bi < run_i:
            # kept verbatim: recompute its byte accounting from the header
            blk = blocks[bi]
            body = store._read_body(blk)
            meta, _, _ = parse_block(body, with_payload=False)
            stored += 4 + blk["nbytes"]
            payload += meta.payload_nbytes
            meta_n += len(body) - _HDR.size - meta.payload_nbytes - 4
            meta_raw += 8 * (L + meta.head_vec.shape[0]
                             + meta.tail_vec.shape[0])
            new_blocks.append(blk)
            bi += 1
            continue
        # merge blocks [run_i, run_j]: decode every part, concatenate the
        # kept points (each shared border appears as part k's last point
        # and part k+1's first — drop the duplicate), sum the moments
        part_blks = blocks[run_i:run_j + 1]
        bodies = store._read_bodies(part_blks)
        idx_parts, val_parts = [], []
        r1 = r2 = rx = 0.0
        emax = 0.0
        for k, body in enumerate(bodies):
            meta, idx, vals = parse_block(body)
            r1 += meta.r1
            r2 += meta.r2
            rx += meta.rx
            emax = max(emax, meta.emax)
            if k < len(bodies) - 1:
                idx, vals = idx[:-1], vals[:-1]
            idx_parts.append(idx)
            val_parts.append(vals)
        kept_idx = np.concatenate(idx_parts)
        kept_vals = np.ascontiguousarray(
            np.concatenate(val_parts).astype(dtype))
        t0 = int(part_blks[0]["t0"])
        t1 = int(part_blks[-1]["t1"])
        is_last = run_j == len(blocks) - 1
        o1 = t1 + 1 if is_last else t1
        owned_xr = reconstruct_block(kept_idx - t0, kept_vals,
                                     t1 - t0 + 1, str(dtype))[:o1 - t0]
        body, binfo = build_block(
            kept_idx, kept_vals, t0=t0, t1=t1, is_last=is_last,
            owned_xr=owned_xr, L=L, kappa=int(entry["kappa"]),
            stat=entry["stat"], eps=float(entry["eps"]),
            resid_moments=(r1, r2, rx, emax) if has_resid else None,
            value_codec=store.value_codec, entropy=store.entropy,
            meta_version=3)
        off = store._append_body(body)
        dead += sum(4 + b["nbytes"] for b in part_blks)
        stored += 4 + len(body)
        payload += binfo["payload_nbytes"]
        meta_n += binfo["meta_nbytes"]
        meta_raw += binfo["meta_raw_nbytes"]
        new_blocks.append(dict(offset=off, nbytes=len(body), t0=t0, t1=t1))
        bi = run_j + 1
        run_i, run_j = next(run_iter)

    entry["blocks"] = new_blocks
    entry["stored_nbytes"] = stored
    entry["payload_nbytes"] = payload
    entry["meta_nbytes"] = meta_n
    entry["meta_raw_nbytes"] = meta_raw
    store._dead_nbytes += dead
    store._bump_totals(stored=stored - old_stored)
    if OBS.enabled:
        OBS.inc("store.compaction.runs", len(runs))
        OBS.inc("store.compaction.blocks_merged",
                len(blocks) - len(new_blocks) + len(runs))
        OBS.inc("store.compaction.dead_bytes", dead)
    _finish(store, sid)
    report.update(blocks_after=len(new_blocks), stored_after=stored,
                  dead_nbytes=dead)
    return report


def rewrite_cold(store, sid: str, *, codec: str = "auto") -> dict:
    """Demote one series' block bodies to the cold tier: each plain body
    is entropy-wrapped and appended; the catalog block dict gains a
    ``"wrap"`` key.  Bodies the wrap cannot shrink stay warm.  Works for
    univariate and multivariate series (the body is opaque bytes here).
    """
    entry = _check_rewritable(store, sid)
    blocks = entry["blocks"]
    rewritten = skipped = 0
    dead = 0
    delta = 0
    for bi, blk in enumerate(blocks):
        if blk.get("wrap"):
            continue
        body = store._read_body(blk)
        wrapped, used = _codec.entropy_wrap(body, codec)
        if used == "none":
            skipped += 1
            continue
        off = store._append_body(wrapped)
        dead += 4 + blk["nbytes"]
        delta += len(wrapped) - blk["nbytes"]
        blocks[bi] = dict(offset=off, nbytes=len(wrapped),
                          t0=blk["t0"], t1=blk["t1"], wrap=used)
        rewritten += 1
    if rewritten:
        entry["stored_nbytes"] += delta
        store._dead_nbytes += dead
        store._bump_totals(stored=delta)
        _finish(store, sid)
    return dict(sid=sid, rewritten=rewritten, skipped=skipped,
                saved_nbytes=-delta, dead_nbytes=dead)


def promote_warm(store, sid: str) -> dict:
    """Promote one series back out of the cold tier: every wrapped body
    is unwrapped and re-appended as a plain warm body (the exact bytes
    the block was originally written with)."""
    entry = _check_rewritable(store, sid)
    blocks = entry["blocks"]
    rewritten = 0
    dead = 0
    delta = 0
    for bi, blk in enumerate(blocks):
        if not blk.get("wrap"):
            continue
        body = store._read_body(blk)   # _finish_body already unwrapped it
        off = store._append_body(body)
        dead += 4 + blk["nbytes"]
        delta += len(body) - blk["nbytes"]
        blocks[bi] = dict(offset=off, nbytes=len(body),
                          t0=blk["t0"], t1=blk["t1"])
        rewritten += 1
    if rewritten:
        entry["stored_nbytes"] += delta
        store._dead_nbytes += dead
        store._bump_totals(stored=delta)
        _finish(store, sid)
    return dict(sid=sid, rewritten=rewritten, dead_nbytes=dead)
