"""Control-stream scanners for the vectorized bitstream decoders.

The decode hot path in ``store/codec.py`` is two-phase: a *scan* walks the
control bits once to recover each record's branch case and payload
bit-offset (sequential by construction — Gorilla's meaningful-bit window
and Chimp's leading-zero bucket are carried state), then numpy gathers all
payload fields in bulk and closes the value chains with
``np.bitwise_xor.accumulate`` / ``np.cumsum``.  This module provides the
scan in two interchangeable forms:

* a **native scanner** — ~60 lines of dependency-free C99, compiled once
  with the system ``cc`` on first use (cached per source hash under the
  temp dir) and called through ``ctypes``.  A few ns per record; this is
  what makes store reads ~10-30x faster than the ``*_loop`` oracles.
* a **pure-Python fallback** — the same algorithm over precomputed 24-bit
  byte windows, used automatically when no C compiler is available (or
  when ``CAMEO_NATIVE_SCAN=0``).  Still several times faster than the
  loop decoders because it touches only control bits and consumes runs of
  zero-control records in bulk.

Both forms emit the identical packed ``int64`` record array (one entry per
*non-zero* record; zero-xor / repeated-delta records are implicit), so the
numpy post-processing in ``codec.py`` is oblivious to which scanner ran.
Parity of the two scanners is pinned by ``tests/test_store.py``.
"""
from __future__ import annotations

import atexit
import ctypes
import hashlib
import os
import shutil
import subprocess
import tempfile

import numpy as np

_C_SOURCE = r"""
#include <stdint.h>

/* 24-bit big-endian window starting at byte b, masked to the low
   24 - (bit & 7) bits: bits [bit, bit + avail) of the stream.  The caller
   pads the buffer so the 3-byte read never runs past the end. */
static inline long win24(const uint8_t *d, long bp, long *avail) {
    long b = bp >> 3, r = bp & 7;
    long x = ((long)d[b] << 16) | ((long)d[b + 1] << 8) | (long)d[b + 2];
    *avail = 24 - r;
    return x & ((1L << *avail) - 1);
}

static inline long bitlen(long x) {
    return x ? 64 - __builtin_clzll((unsigned long long)x) : 0;
}

/* Gorilla value stream: out[k] = (i << 15) | (new_window << 14)
   | (sig << 7) | shift.  Returns the number of non-zero records. */
long gorilla_scan(const uint8_t *d, long m, int64_t *out) {
    long bp = 64, i = 0, k = 0, plz = -1, ptz = -1, avail;
    while (i < m) {
        long x = win24(d, bp, &avail);
        if (!(x >> (avail - 1))) {            /* '0' run: zero xors */
            long take = avail - bitlen(x);
            if (take > m - i) take = m - i;
            bp += take; i += take;
            continue;
        }
        long w = x >> (avail - 13), sig;
        if (w < 0x1800) {                     /* '10' reuse window */
            sig = 64 - plz - ptz;
            out[k++] = ((int64_t)i << 15) | (sig << 7) | ptz;
            bp += 2 + sig;
        } else {                              /* '11' new window */
            plz = (w >> 6) & 0x1F;
            sig = w & 0x3F; if (!sig) sig = 64;
            ptz = 64 - plz - sig;
            out[k++] = ((int64_t)i << 15) | 0x4000 | (sig << 7) | ptz;
            bp += 13 + sig;
        }
        i++;
    }
    return k;
}

/* Chimp value stream: out[k] = (i << 15) | (case << 13) | (width << 6)
   | shift.  Returns the number of non-zero records. */
long chimp_scan(const uint8_t *d, long m, int64_t *out) {
    static const long buckets[8] = {0, 8, 12, 16, 18, 20, 22, 24};
    long bp = 64, i = 0, k = 0, prev_lzb = -1, avail;
    while (i < m) {
        long x = win24(d, bp, &avail);
        if (!(x >> (avail - 2))) {            /* '00' run: zero xors */
            long take = (avail - bitlen(x)) >> 1;
            if (take > m - i) take = m - i;
            bp += 2 * take; i += take;
            prev_lzb = -1;
            continue;
        }
        long w = x >> (avail - 11), c = w >> 9;
        if (c == 1) {                         /* '01' center form */
            long lzb = buckets[(w >> 6) & 7];
            long center = w & 0x3F; if (!center) center = 64;
            out[k++] = ((int64_t)i << 15) | (1L << 13) | (center << 6)
                       | (64 - lzb - center);
            bp += 11 + center;
            prev_lzb = -1;
        } else if (c == 2) {                  /* '10' bucket reuse */
            long width = 64 - prev_lzb;
            out[k++] = ((int64_t)i << 15) | (2L << 13) | (width << 6);
            bp += 2 + width;
        } else {                              /* '11' new bucket */
            prev_lzb = buckets[(w >> 6) & 7];
            long width = 64 - prev_lzb;
            out[k++] = ((int64_t)i << 15) | (3L << 13) | (width << 6);
            bp += 5 + width;
        }
        i++;
    }
    return k;
}

/* Delta-of-delta index stream: out[k] = (i << 2) | bucket. */
long index_scan(const uint8_t *d, long m, int64_t *out) {
    long bp = 32, i = 0, k = 0, avail;
    while (i < m) {
        long x = win24(d, bp, &avail);
        if (!(x >> (avail - 1))) {            /* '0' run: repeated deltas */
            long take = avail - bitlen(x);
            if (take > m - i) take = m - i;
            bp += take; i += take;
            continue;
        }
        long w = x >> (avail - 4);
        if (w < 12)       { out[k++] = ((int64_t)i << 2);     bp += 2 + 7;  }
        else if (w < 14)  { out[k++] = ((int64_t)i << 2) | 1; bp += 3 + 9;  }
        else if (w == 14) { out[k++] = ((int64_t)i << 2) | 2; bp += 4 + 12; }
        else              { out[k++] = ((int64_t)i << 2) | 3; bp += 4 + 32; }
        i++;
    }
    return k;
}
"""


def _cache_dir() -> str:
    """Private (0700, caller-owned) build-cache dir.

    Never a shared world-writable location: loading a ``.so`` from a
    predictable path in /tmp would let another local user pre-plant a
    malicious library.  Falls back to a fresh per-process mkdtemp when no
    suitable user cache dir exists.
    """
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    path = os.path.join(base, "cameo-scan")
    try:
        os.makedirs(path, mode=0o700, exist_ok=True)
        st = os.stat(path)
        owned = not hasattr(os, "getuid") or st.st_uid == os.getuid()
        if owned and not (st.st_mode & 0o022):
            return path
    except OSError:
        pass
    path = tempfile.mkdtemp(prefix="cameo-scan-")   # per-process, private
    atexit.register(shutil.rmtree, path, True)
    return path


def _build_native():
    """Compile the scanner once per source hash; None when unavailable."""
    if os.environ.get("CAMEO_NATIVE_SCAN", "1") == "0":
        return None
    try:
        tag = hashlib.sha1(_C_SOURCE.encode()).hexdigest()[:16]
        so_path = os.path.join(_cache_dir(), f"cameo_scan_{tag}.so")
        if not os.path.exists(so_path):
            src = so_path[:-3] + ".c"
            with open(src, "w") as f:
                f.write(_C_SOURCE)
            tmp = so_path + f".{os.getpid()}.tmp"
            subprocess.run(
                ["cc", "-O2", "-shared", "-fPIC", "-o", tmp, src],
                check=True, capture_output=True, timeout=120)
            os.replace(tmp, so_path)          # atomic vs concurrent builds
    except Exception:
        return None
    try:
        lib = ctypes.CDLL(so_path)
        ptr = np.ctypeslib.ndpointer(np.uint8, flags="C_CONTIGUOUS")
        outp = np.ctypeslib.ndpointer(np.int64, flags="C_CONTIGUOUS")
        for name in ("gorilla_scan", "chimp_scan", "index_scan"):
            fn = getattr(lib, name)
            fn.restype = ctypes.c_long
            fn.argtypes = [ptr, ctypes.c_long, outp]
        return lib
    except Exception:
        return None


# The native library is built lazily on the first scan call (not at import
# time): encode-only users — e.g. baselines/lossless pulling the Table 2
# counters through store.codec — never pay the cc subprocess.
_LIB = None
_TRIED = False


def _lib():
    global _LIB, _TRIED
    if not _TRIED:
        _LIB = _build_native()
        _TRIED = True
    return _LIB


def __getattr__(name):
    if name == "NATIVE":     # lazy module attribute: triggers the build
        return _lib() is not None
    raise AttributeError(f"module 'repro.store._scan' has no attribute "
                         f"{name!r}")


# 24-bit window masks per bit misalignment (python fallback scans)
_WMASK = tuple((1 << (24 - r)) - 1 for r in range(8))


def _padded(data: bytes) -> np.ndarray:
    """Stream bytes with zero padding so 3-byte window reads never overrun."""
    return np.concatenate(
        [np.frombuffer(data, np.uint8), np.zeros(8, np.uint8)])


def _ctrl_windows(data: bytes) -> list:
    d = _padded(data).astype(np.uint32)
    return ((d[:-2] << np.uint32(16)) | (d[1:-1] << np.uint32(8))
            | d[2:]).tolist()


def _gorilla_scan_py(data: bytes, m: int) -> np.ndarray:
    win = _ctrl_windows(data)
    wmask = _WMASK
    acc = []
    append = acc.append
    bp = 64
    plz = ptz = -1
    i = 0
    while i < m:
        r = bp & 7
        x = win[bp >> 3] & wmask[r]
        avail = 24 - r
        if x < (1 << (avail - 1)):        # '0' — run of zero-xor records
            take = avail - x.bit_length()
            if take > m - i:
                take = m - i
            bp += take
            i += take
            continue
        w = x >> (avail - 13)
        if w < 0x1800:                    # '10' — reuse previous window
            sig = 64 - plz - ptz
            append((i << 15) | (sig << 7) | ptz)
            bp += 2 + sig
        else:                             # '11' — new window
            plz = (w >> 6) & 0x1F
            sig = (w & 0x3F) or 64
            ptz = 64 - plz - sig
            append((i << 15) | 0x4000 | (sig << 7) | ptz)
            bp += 13 + sig
        i += 1
    return np.asarray(acc, np.int64)


def _chimp_scan_py(data: bytes, m: int) -> np.ndarray:
    win = _ctrl_windows(data)
    wmask = _WMASK
    buckets = (0, 8, 12, 16, 18, 20, 22, 24)
    acc = []
    append = acc.append
    bp = 64
    prev_lzb = -1
    i = 0
    while i < m:
        r = bp & 7
        x = win[bp >> 3] & wmask[r]
        avail = 24 - r
        if x < (1 << (avail - 2)):        # '00' — run of zero-xor records
            take = (avail - x.bit_length()) >> 1
            if take > m - i:
                take = m - i
            bp += 2 * take
            i += take
            prev_lzb = -1
            continue
        w = x >> (avail - 11)
        c = w >> 9
        if c == 1:                        # '01' — center form
            lzb = buckets[(w >> 6) & 7]
            center = (w & 0x3F) or 64
            append((i << 15) | (1 << 13) | (center << 6)
                   | (64 - lzb - center))
            bp += 11 + center
            prev_lzb = -1
        elif c == 2:                      # '10' — bucket reuse
            width = 64 - prev_lzb
            append((i << 15) | (2 << 13) | (width << 6))
            bp += 2 + width
        else:                             # '11' — new bucket
            prev_lzb = buckets[(w >> 6) & 7]
            width = 64 - prev_lzb
            append((i << 15) | (3 << 13) | (width << 6))
            bp += 5 + width
        i += 1
    return np.asarray(acc, np.int64)


def _index_scan_py(data: bytes, m: int) -> np.ndarray:
    win = _ctrl_windows(data)
    wmask = _WMASK
    acc = []
    append = acc.append
    bp = 32
    i = 0
    while i < m:
        r = bp & 7
        x = win[bp >> 3] & wmask[r]
        avail = 24 - r
        if x < (1 << (avail - 1)):        # '0' — run of repeated deltas
            take = avail - x.bit_length()
            if take > m - i:
                take = m - i
            bp += take
            i += take
            continue
        w = x >> (avail - 4)
        if w < 0b1100:                    # '10'
            append(i << 2)
            bp += 2 + 7
        elif w < 0b1110:                  # '110'
            append((i << 2) | 1)
            bp += 3 + 9
        elif w == 0b1110:                 # '1110'
            append((i << 2) | 2)
            bp += 4 + 12
        else:                             # '1111' — wide
            append((i << 2) | 3)
            bp += 4 + 32
        i += 1
    return np.asarray(acc, np.int64)


def _native(lib, name, data: bytes, m: int) -> np.ndarray:
    out = np.empty(m, np.int64)
    k = getattr(lib, name)(_padded(data), m, out)
    return out[:k]


def gorilla_scan(data: bytes, m: int) -> np.ndarray:
    """Packed non-zero-record array for a Gorilla stream of ``m`` records:
    ``(i << 15) | (new_window << 14) | (sig << 7) | shift`` per entry."""
    lib = _lib()
    if lib is not None:
        return _native(lib, "gorilla_scan", data, m)
    return _gorilla_scan_py(data, m)


def chimp_scan(data: bytes, m: int) -> np.ndarray:
    """Packed non-zero-record array for a Chimp stream of ``m`` records:
    ``(i << 15) | (case << 13) | (width << 6) | shift`` per entry."""
    lib = _lib()
    if lib is not None:
        return _native(lib, "chimp_scan", data, m)
    return _chimp_scan_py(data, m)


def index_scan(data: bytes, m: int) -> np.ndarray:
    """Packed non-zero-record array for a dod index stream of ``m``
    records: ``(i << 2) | bucket`` per entry."""
    lib = _lib()
    if lib is not None:
        return _native(lib, "index_scan", data, m)
    return _index_scan_py(data, m)
