"""Write-ahead journal for crash-safe :class:`~repro.store.store.CameoStore`
ingest.

The store file itself is append-mostly but *not* crash-safe on its own: the
footer catalog lives at the tail and is truncated away at the start of every
append run, so a writer that dies mid-run leaves a store with no catalog and
(possibly) a torn block at EOF.  The journal closes that gap.  It is a
sidecar file (``<store>.wal``) that records

1. a **checkpoint** — an image of the last durably published footer (or the
   bare header when no footer has been written yet), plus the layout
   parameters needed to reconstruct an empty store, and
2. the sequence of **acked pushes** since that checkpoint, as raw float64
   payloads.

Recovery rolls the store file back to the checkpointed footer (restoring the
footer bytes that the append run truncated) and then *replays* the journaled
pushes through the deterministic compression pipeline.  Because compression
is deterministic and chunking-invariant, replay regenerates byte-identical
blocks — the journal never needs to record compressed output, only the raw
points the caller was told were accepted.

On-disk format
--------------
::

    b"CAMEOWAL\\x01"                        # 9-byte header
    [u32 payload_len][u32 crc32(payload)][payload]   # repeated records

Record payloads start with a one-byte type tag:

``type 1 — CHECKPOINT`` (always the first record of a journal generation)
    ``u8 store_version | u64 footer_offset | u32 meta_len | meta_json |
    u32 footer_len | footer_bytes``.  ``footer_bytes`` is the verbatim
    zlib-compressed footer blob (``b""`` when the store has never written
    one); ``meta_json`` carries ``block_len`` / ``value_codec`` /
    ``entropy`` so an empty store can be re-created with the right layout.

``type 2 — PUSH``
    ``u8 pad | u16 sid_len | sid_utf8 | u64 start | u32 m | u16 channels |
    m*(channels or 1) float64 LE values``.  ``channels == 0`` marks a 1-D
    payload.  ``start`` is the absolute point index of the first value
    (``StreamingCompressor.n_seen`` at ack time), which makes replay
    idempotent: records at or below the resumed compressor's watermark are
    skipped, and a gap raises instead of silently corrupting.

A torn tail — short record header, short payload, or checksum mismatch — is
detected by the scan and the journal is treated as ending at the last intact
record (the crash happened mid-append; that record was never acked as
journaled).  A checkpoint record anywhere but position 0 also stops the
scan: generations are whole-file rewrites, so a mid-file checkpoint can only
be corruption.

Group commit
------------
``append_push`` writes through to the OS immediately (``flush``), so an
acked push survives a *process* crash as soon as the call returns.  The
more expensive ``fsync`` — the power-loss barrier — is amortized: the
journal fsyncs when either ``group_bytes`` of un-synced payload or
``group_ms`` of wall-clock time has accumulated since the last barrier.
``group_ms=0`` degenerates to fsync-per-push.  Checkpoints are atomic:
the new generation is written to ``<store>.wal.tmp``, fsynced, and
``os.replace``d over the live journal, so a crash during checkpointing
leaves either the old or the new journal, never a torn hybrid.

``CAMEO_FSYNC=0`` disables every ``os.fsync`` in the package (tests,
throwaway runs); the journal degrades to process-crash safety only.
"""

from __future__ import annotations

import json
import os
import struct
import time
import zlib
from typing import List, NamedTuple, Optional, Sequence

import numpy as np

from ..obs import OBS

MAGIC = b"CAMEOWAL\x01"
_REC = struct.Struct("<II")          # payload length, crc32(payload)
_CKPT_HEAD = struct.Struct("<BBQ")   # type, store_version, footer_offset
_PUSH_HEAD = struct.Struct("<BBH")   # type, pad, sid_len
_PUSH_BODY = struct.Struct("<QIH")   # start, m, channels (0 == 1-D)

REC_CHECKPOINT = 1
REC_PUSH = 2

# Cap on a single record payload: a push of ~128 Mi float64 values.  Anything
# larger in a length prefix is treated as a torn/corrupt record by the scan.
_MAX_PAYLOAD = 1 << 30

DEFAULT_GROUP_MS = 5.0
DEFAULT_GROUP_BYTES = 256 << 10


def fsync_enabled() -> bool:
    """``True`` unless ``CAMEO_FSYNC=0`` opts out of durability barriers."""
    return os.environ.get("CAMEO_FSYNC", "1") != "0"


def maybe_fsync(f) -> None:
    """Flush ``f`` to the OS and — unless ``CAMEO_FSYNC=0`` — to stable
    storage.  The flush always happens; only the fsync is gated, so tests
    that disable barriers still exercise the same write ordering."""
    f.flush()
    if fsync_enabled():
        os.fsync(f.fileno())


class Checkpoint(NamedTuple):
    """Image of the store's last published state.

    ``footer == b""`` means the store had no footer yet (fresh ``mode="w"``
    run): recovery rolls the file back to the bare header and rebuilds the
    layout from ``meta``.
    """

    store_version: int
    footer_offset: int
    meta: dict              # block_len / value_codec / entropy
    footer: bytes           # verbatim zlib footer blob, b"" if none


class PushRecord(NamedTuple):
    """One acked push: ``x`` is float64 ``[m]`` or ``[m, C]``, ``start`` the
    absolute index of ``x[0]`` in the stream."""

    sid: str
    start: int
    x: np.ndarray


class WalScan(NamedTuple):
    """Result of :func:`scan`: the generation's checkpoint, the intact push
    records after it, and whether a torn tail was dropped."""

    checkpoint: Optional[Checkpoint]
    pushes: List[PushRecord]
    torn: bool


def _encode_checkpoint(ckpt: Checkpoint) -> bytes:
    meta = json.dumps(ckpt.meta, sort_keys=True).encode("utf-8")
    return b"".join([
        _CKPT_HEAD.pack(REC_CHECKPOINT, ckpt.store_version,
                        ckpt.footer_offset),
        struct.pack("<I", len(meta)), meta,
        struct.pack("<I", len(ckpt.footer)), ckpt.footer,
    ])


def _decode_checkpoint(payload: bytes) -> Checkpoint:
    rtype, version, off = _CKPT_HEAD.unpack_from(payload, 0)
    pos = _CKPT_HEAD.size
    (mlen,) = struct.unpack_from("<I", payload, pos)
    pos += 4
    meta = json.loads(payload[pos:pos + mlen].decode("utf-8"))
    pos += mlen
    (flen,) = struct.unpack_from("<I", payload, pos)
    pos += 4
    footer = payload[pos:pos + flen]
    if len(footer) != flen:
        raise ValueError("checkpoint record truncated")
    return Checkpoint(version, off, meta, footer)


def _encode_push(rec: PushRecord) -> bytes:
    x = np.ascontiguousarray(rec.x, dtype=np.float64)
    if x.ndim == 1:
        m, channels = x.shape[0], 0
    elif x.ndim == 2:
        m, channels = int(x.shape[0]), int(x.shape[1])
    else:
        raise ValueError(f"push payload must be 1-D or 2-D, got {x.ndim}-D")
    sid = rec.sid.encode("utf-8")
    if len(sid) > 0xFFFF:
        raise ValueError("series id too long to journal")
    return b"".join([
        _PUSH_HEAD.pack(REC_PUSH, 0, len(sid)), sid,
        _PUSH_BODY.pack(int(rec.start), m, channels),
        x.astype("<f8", copy=False).tobytes(),
    ])


def _decode_push(payload: bytes) -> PushRecord:
    rtype, _pad, sid_len = _PUSH_HEAD.unpack_from(payload, 0)
    pos = _PUSH_HEAD.size
    sid = payload[pos:pos + sid_len].decode("utf-8")
    pos += sid_len
    start, m, channels = _PUSH_BODY.unpack_from(payload, pos)
    pos += _PUSH_BODY.size
    count = m * (channels if channels else 1)
    data = np.frombuffer(payload, dtype="<f8", count=count, offset=pos)
    if data.shape[0] != count:
        raise ValueError("push record truncated")
    x = data.astype(np.float64)
    if channels:
        x = x.reshape(m, channels)
    return PushRecord(sid, int(start), x)


def _iter_records(blob: bytes):
    """Yield intact ``(payload, end_offset)`` pairs from a journal image,
    stopping (not raising) at the first torn or corrupt record."""
    pos = len(MAGIC)
    total = len(blob)
    while pos + _REC.size <= total:
        plen, crc = _REC.unpack_from(blob, pos)
        body_at = pos + _REC.size
        if plen > _MAX_PAYLOAD or body_at + plen > total:
            return                    # torn length prefix or short payload
        payload = blob[body_at:body_at + plen]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            return                    # torn or bit-flipped payload
        pos = body_at + plen
        yield payload, pos


def scan(path: str) -> Optional[WalScan]:
    """Read a journal file tolerantly.

    Returns ``None`` when the file is missing, empty, or does not start
    with the journal magic (nothing recoverable).  Otherwise returns the
    checkpoint plus every intact push record, with ``torn=True`` when a
    trailing partial record was discarded.
    """
    try:
        with open(path, "rb") as f:
            blob = f.read()
    except FileNotFoundError:
        return None
    if len(blob) < len(MAGIC) or blob[:len(MAGIC)] != MAGIC:
        return None
    ckpt: Optional[Checkpoint] = None
    pushes: List[PushRecord] = []
    end = len(MAGIC)
    for payload, pos in _iter_records(blob):
        if not payload:
            break
        rtype = payload[0]
        if rtype == REC_CHECKPOINT:
            if ckpt is not None:
                break                 # generations never embed checkpoints
            try:
                ckpt = _decode_checkpoint(payload)
            except Exception:
                break
        elif rtype == REC_PUSH:
            if ckpt is None:
                break                 # pushes before a checkpoint: corrupt
            try:
                pushes.append(_decode_push(payload))
            except Exception:
                break
        else:
            break                     # unknown record type: stop cleanly
        end = pos
    return WalScan(ckpt, pushes, torn=end < len(blob))


class WriteAheadLog:
    """Length-prefixed, checksummed journal with synchronous group commit.

    One instance belongs to exactly one writable :class:`CameoStore`; the
    store owns the lifecycle (``start`` at open, ``checkpoint`` after every
    footer publish, ``close`` — optionally removing the file — at store
    close)."""

    def __init__(self, path: str, f, *, group_ms: float, group_bytes: int):
        self.path = path
        self._f = f
        self.group_ms = float(group_ms)
        self.group_bytes = int(group_bytes)
        self._unsynced_bytes = 0
        self._unsynced_records = 0
        self._window_start: Optional[float] = None

    # -- lifecycle -----------------------------------------------------

    @classmethod
    def start(cls, path: str, checkpoint: Checkpoint,
              carry: Sequence[PushRecord] = (), *,
              group_ms: float = DEFAULT_GROUP_MS,
              group_bytes: int = DEFAULT_GROUP_BYTES) -> "WriteAheadLog":
        """Open a fresh journal generation at ``path``.

        The generation is built in ``path + ".tmp"`` (header, checkpoint,
        then ``carry`` — pushes from the previous generation that are still
        un-replayed), fsynced, and atomically published with
        ``os.replace``.  A crash at any point leaves either the previous
        journal or the complete new one."""
        tmp = path + ".tmp"
        f = open(tmp, "wb")
        try:
            f.write(MAGIC)
            for payload in [_encode_checkpoint(checkpoint)] + [
                    _encode_push(r) for r in carry]:
                f.write(_REC.pack(len(payload), zlib.crc32(payload)
                                  & 0xFFFFFFFF))
                f.write(payload)
            maybe_fsync(f)
        except BaseException:
            f.close()
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        f.close()
        os.replace(tmp, path)
        if fsync_enabled():
            # the rename itself must be durable before the store may
            # truncate state the journal now owns
            dfd = os.open(os.path.dirname(os.path.abspath(path)) or ".",
                          os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        out = open(path, "ab")
        if OBS.enabled:
            OBS.inc("wal.checkpoints")
        return cls(path, out, group_ms=group_ms, group_bytes=group_bytes)

    def checkpoint(self, checkpoint: Checkpoint,
                   carry: Sequence[PushRecord] = ()) -> None:
        """Truncate the journal to a new generation rooted at
        ``checkpoint``.  ``carry`` keeps acked pushes that the checkpointed
        footer does *not* already cover (streams that were journaled but
        never resumed this run)."""
        self._f.close()
        fresh = WriteAheadLog.start(self.path, checkpoint, carry,
                                    group_ms=self.group_ms,
                                    group_bytes=self.group_bytes)
        self._f = fresh._f
        self._unsynced_bytes = 0
        self._unsynced_records = 0
        self._window_start = None

    def close(self, remove: bool = False) -> None:
        """Sync and close the journal; ``remove=True`` deletes the file
        (used on clean store close, when the footer supersedes it)."""
        if self._f.closed:
            return
        self.sync()
        self._f.close()
        if remove:
            try:
                os.unlink(self.path)
            except OSError:
                pass

    # -- appends -------------------------------------------------------

    def append_push(self, rec: PushRecord) -> None:
        """Journal one acked push.  Returns once the record is handed to
        the OS (process-crash safe); the power-loss barrier is amortized
        by the group-commit policy."""
        payload = _encode_push(rec)
        self._f.write(_REC.pack(len(payload),
                                zlib.crc32(payload) & 0xFFFFFFFF))
        self._f.write(payload)
        self._f.flush()
        nbytes = _REC.size + len(payload)
        self._unsynced_bytes += nbytes
        self._unsynced_records += 1
        if self._window_start is None:
            self._window_start = time.perf_counter()
        if OBS.enabled:
            OBS.inc("wal.records")
            OBS.inc("wal.append_bytes", nbytes)
        elapsed_ms = (time.perf_counter() - self._window_start) * 1e3
        if (self._unsynced_bytes >= self.group_bytes
                or elapsed_ms >= self.group_ms):
            self.sync()

    def sync(self) -> None:
        """Group-commit barrier: one fsync covering every append since the
        previous barrier."""
        if not self._unsynced_records:
            return
        batch = self._unsynced_records
        t0 = time.perf_counter()
        maybe_fsync(self._f)
        if OBS.enabled:
            OBS.inc("wal.group_commits")
            OBS.observe("wal.fsync_seconds", time.perf_counter() - t0)
            OBS.observe("wal.group_batch_records", float(batch))
        self._unsynced_bytes = 0
        self._unsynced_records = 0
        self._window_start = None
