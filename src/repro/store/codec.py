"""Byte-true bitstream codecs for the CameoStore physical layer.

The paper's headline metric is a compression *ratio*, but ratios only mean
something once a byte stream exists.  This module materializes the two
streams a stored CAMEO series consists of:

* **kept-index stream** — delta-of-delta bit-packing in the Gorilla
  timestamp style ('0' for a repeated delta, then 7/9/12/32-bit buckets).
  CAMEO's kept indices are near-arithmetic at low CR (long runs of
  delta==const cost one bit per point) and stay cheap at high CR.
* **value stream** — Gorilla or Chimp XOR float codecs.  These are the
  *encoder* forms of the bit-cost counters in ``baselines/lossless.py``
  (Table 2): the branch plans are computed once here and shared by both the
  counters and the emitters, so counted bits == emitted bits exactly, by
  construction (and by test).

Both streams can be wrapped in an optional entropy stage (zstd when the
``zstandard`` module is present, stdlib zlib otherwise — the same fallback
discipline as ``checkpoint/manager.py``); the wrap is only kept when it
actually shrinks the payload, and the chosen codec is recorded so decode
never guesses.

Everything here is plain numpy + stdlib: no jax, importable from anywhere
(``baselines/lossless.py`` delegates its fast paths to the shared plans).
All value codecs operate on 64-bit IEEE doubles; float32 inputs are upcast
(exactly) and round-trip bit-true through a float32 cast on the way out.
"""
from __future__ import annotations

import zlib

import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: entropy wrap falls back to stdlib zlib
    zstandard = None

VALUE_CODECS = ("gorilla", "chimp")
ENTROPY_CODECS = ("none", "zlib", "zstd")

_CHIMP_LZ_BUCKETS = np.array([0, 8, 12, 16, 18, 20, 22, 24])

_U64_ONE = np.uint64(1)


# ---------------------------------------------------------------------------
# bit-level IO
# ---------------------------------------------------------------------------

class BitWriter:
    """MSB-first bit packer.  O(1) amortized per write; bounded accumulator."""

    __slots__ = ("_buf", "_acc", "_nacc", "bit_length")

    def __init__(self):
        self._buf = bytearray()
        self._acc = 0          # partial bits, < 8 of them after each write
        self._nacc = 0
        self.bit_length = 0

    def write(self, value: int, nbits: int):
        if nbits <= 0:
            return
        self.bit_length += nbits
        acc = (self._acc << nbits) | (int(value) & ((1 << nbits) - 1))
        nacc = self._nacc + nbits
        buf = self._buf
        while nacc >= 8:
            nacc -= 8
            buf.append((acc >> nacc) & 0xFF)
        self._acc = acc & ((1 << nacc) - 1)
        self._nacc = nacc

    def getvalue(self) -> bytes:
        if self._nacc:
            return bytes(self._buf) + bytes(
                [(self._acc << (8 - self._nacc)) & 0xFF])
        return bytes(self._buf)


class BitReader:
    """MSB-first bit reader over ``bytes`` (the BitWriter's inverse)."""

    __slots__ = ("_data", "_pos", "_acc", "_nacc")

    def __init__(self, data: bytes):
        self._data = data
        self._pos = 0
        self._acc = 0
        self._nacc = 0

    def read(self, nbits: int) -> int:
        if nbits <= 0:
            return 0
        acc, nacc, pos, data = self._acc, self._nacc, self._pos, self._data
        while nacc < nbits:
            acc = (acc << 8) | data[pos]
            pos += 1
            nacc += 8
        nacc -= nbits
        out = (acc >> nacc) & ((1 << nbits) - 1)
        self._acc = acc & ((1 << nacc) - 1)
        self._nacc = nacc
        self._pos = pos
        return out


# ---------------------------------------------------------------------------
# vectorized XOR bit-geometry (shared by counters and encoders)
# ---------------------------------------------------------------------------

def bit_length_u64(v: np.ndarray) -> np.ndarray:
    """Vectorized ``int.bit_length`` for a uint64 array (0 -> 0).

    Binary-search over shifted masks — exact for the full 64-bit range
    (float log2 would mis-round near powers of two above 2**53).
    """
    v = np.asarray(v, np.uint64).copy()
    bl = np.zeros(v.shape, np.int64)
    for s in (32, 16, 8, 4, 2, 1):
        su = np.uint64(s)
        big = v >= (_U64_ONE << su)
        bl[big] += s
        v[big] >>= su
    bl += (v != 0)
    return bl


def xor_parts(x: np.ndarray):
    """(bits, xor, lz, tz) of a float64 series, fully vectorized.

    ``xor[i] = bits[i+1] ^ bits[i]``; ``lz``/``tz`` are leading/trailing zero
    counts of each xor (64 for xor == 0) — the vectorized form of the
    per-value Python loop the Table 2 counters used to run.
    """
    bits = np.ascontiguousarray(np.asarray(x, np.float64)).view(np.uint64)
    xor = bits[1:] ^ bits[:-1]
    bl = bit_length_u64(xor)
    lz = np.where(xor == 0, 64, 64 - bl)
    lowbit = xor & (~xor + _U64_ONE)
    tz = np.where(xor == 0, 64, bit_length_u64(lowbit) - 1)
    return bits, xor, lz.astype(np.int64), tz.astype(np.int64)


# ---------------------------------------------------------------------------
# Gorilla value codec (Pelkonen et al. 2015)
# ---------------------------------------------------------------------------

def _gorilla_plan(xor, lz, tz):
    """Branch plan for the Gorilla value stream.

    Returns ``(case, sig, shift)`` aligned with ``xor``: case 0 = zero xor
    ('0'), 1 = window reuse ('10' + sig bits), 2 = new window ('11' + 5-bit
    LZ + 6-bit length + sig bits); ``shift`` is the right-shift producing the
    emitted meaningful bits.  The meaningful-bit *window* chain is inherently
    sequential (each reuse decision depends on the last reset), so this scan
    runs in Python — but over the precomputed vectorized bit geometry, which
    is where the old per-value loops spent their time.
    """
    m = xor.shape[0]
    li_l = np.minimum(lz, 31).tolist()    # gorilla caps LZ at 31 (5-bit field)
    tz_l = tz.tolist()
    nz_l = (xor != 0).tolist()
    case = [0] * m
    sig = [0] * m
    shift = [0] * m
    plz, ptz = -1, -1
    for i in range(m):
        if not nz_l[i]:
            continue
        li, ti = li_l[i], tz_l[i]
        if plz >= 0 and li >= plz and ti >= ptz:
            case[i] = 1
            sig[i] = 64 - plz - ptz
            shift[i] = ptz
        else:
            case[i] = 2
            sig[i] = 64 - li - ti
            shift[i] = ti
            plz, ptz = li, ti
    return (np.asarray(case, np.int64), np.asarray(sig, np.int64),
            np.asarray(shift, np.int64))


def gorilla_stream_bits(x) -> int:
    """Exact bit size of :func:`gorilla_encode`'s stream (vectorized tally)."""
    x = np.asarray(x, np.float64)
    if x.shape[0] == 0:
        return 0
    _, xor, lz, tz = xor_parts(x)
    case, sig, _ = _gorilla_plan(xor, lz, tz)
    bits = np.where(case == 0, 1,
                    np.where(case == 1, 2 + sig, 2 + 5 + 6 + sig))
    return 64 + int(bits.sum())


def gorilla_encode(x) -> bytes:
    """Gorilla XOR value stream for a float64 series (lossless)."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    w = BitWriter()
    if n == 0:
        return w.getvalue()
    bits, xor, lz, tz = xor_parts(x)
    w.write(int(bits[0]), 64)
    case, sig, shift = _gorilla_plan(xor, lz, tz)
    xor_l, sig_l, shift_l = xor.tolist(), sig.tolist(), shift.tolist()
    for i, c in enumerate(case.tolist()):
        if c == 0:
            w.write(0, 1)
        elif c == 1:
            w.write(0b10, 2)
            w.write(xor_l[i] >> shift_l[i], sig_l[i])
        else:
            w.write(0b11, 2)
            w.write(64 - sig_l[i] - shift_l[i], 5)
            w.write(sig_l[i] & 0x3F, 6)        # 64 wraps to 0; decode maps back
            w.write(xor_l[i] >> shift_l[i], sig_l[i])
    return w.getvalue()


def gorilla_decode(data: bytes, n: int) -> np.ndarray:
    """Inverse of :func:`gorilla_encode`; returns float64 [n]."""
    out = np.empty(n, np.uint64)
    if n == 0:
        return out.view(np.float64)
    r = BitReader(data)
    prev = r.read(64)
    out[0] = prev
    plz, ptz = -1, -1
    for i in range(1, n):
        if r.read(1):
            if r.read(1):                       # '11' — new window
                li = r.read(5)
                sig = r.read(6) or 64
                ti = 64 - li - sig
                xor = r.read(sig) << ti
                plz, ptz = li, ti
            else:                               # '10' — reuse window
                xor = r.read(64 - plz - ptz) << ptz
            prev ^= xor
        out[i] = prev
    return out.view(np.float64)


# ---------------------------------------------------------------------------
# Chimp value codec (Liakos et al. 2022, plain variant)
# ---------------------------------------------------------------------------

def _chimp_plan(xor, lz, tz):
    """Branch plan for the (plain) Chimp stream — fully vectorized.

    Chimp's only carried state is the previous leading-zero bucket, and it is
    a function of the *previous element alone* (zero-xor and center-form
    entries reset it), so unlike Gorilla there is no sequential chain.

    Returns ``(case, lzb, bi)``: case 0 = zero xor, 1 = center form
    (tz > 6), 2 = bucket reuse, 3 = new bucket; ``lzb`` the rounded
    leading-zero bucket, ``bi`` its 3-bit index.
    """
    bi = np.searchsorted(_CHIMP_LZ_BUCKETS, np.minimum(lz, 24),
                         side="right") - 1
    lzb = _CHIMP_LZ_BUCKETS[bi]
    resets = (xor == 0) | (tz > 6)
    prev_bucket = np.concatenate(
        [[-1], np.where(resets[:-1], -1, lzb[:-1])])
    case = np.where(xor == 0, 0,
                    np.where(tz > 6, 1,
                             np.where(lzb == prev_bucket, 2, 3)))
    return case.astype(np.int64), lzb.astype(np.int64), bi.astype(np.int64)


def chimp_stream_bits(x) -> int:
    """Exact bit size of :func:`chimp_encode`'s stream (vectorized tally)."""
    x = np.asarray(x, np.float64)
    if x.shape[0] == 0:
        return 0
    _, xor, lz, tz = xor_parts(x)
    case, lzb, _ = _chimp_plan(xor, lz, tz)
    center = np.maximum(64 - lzb - tz, 0)
    bits = np.where(case == 0, 2,
                    np.where(case == 1, 2 + 3 + 6 + center,
                             np.where(case == 2, 2 + (64 - lzb),
                                      2 + 3 + (64 - lzb))))
    return 64 + int(bits.sum())


def chimp_encode(x) -> bytes:
    """Chimp XOR value stream for a float64 series (lossless)."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    w = BitWriter()
    if n == 0:
        return w.getvalue()
    bits, xor, lz, tz = xor_parts(x)
    w.write(int(bits[0]), 64)
    case, lzb, bi = _chimp_plan(xor, lz, tz)
    xor_l, tz_l = xor.tolist(), tz.tolist()
    lzb_l, bi_l = lzb.tolist(), bi.tolist()
    for i, c in enumerate(case.tolist()):
        if c == 0:
            w.write(0b00, 2)
        elif c == 1:
            center = max(64 - lzb_l[i] - tz_l[i], 0)
            w.write(0b01, 2)
            w.write(bi_l[i], 3)
            w.write(center & 0x3F, 6)
            w.write(xor_l[i] >> tz_l[i], center)
        elif c == 2:
            w.write(0b10, 2)
            w.write(xor_l[i], 64 - lzb_l[i])
        else:
            w.write(0b11, 2)
            w.write(bi_l[i], 3)
            w.write(xor_l[i], 64 - lzb_l[i])
    return w.getvalue()


def chimp_decode(data: bytes, n: int) -> np.ndarray:
    """Inverse of :func:`chimp_encode`; returns float64 [n]."""
    out = np.empty(n, np.uint64)
    if n == 0:
        return out.view(np.float64)
    r = BitReader(data)
    prev = r.read(64)
    out[0] = prev
    buckets = _CHIMP_LZ_BUCKETS.tolist()
    prev_lzb = -1
    for i in range(1, n):
        c = r.read(2)
        if c == 0b00:
            xor = 0
            prev_lzb = -1
        elif c == 0b01:
            lzb = buckets[r.read(3)]
            center = r.read(6) or 64
            ti = 64 - lzb - center
            xor = r.read(center) << ti
            prev_lzb = -1
        elif c == 0b10:
            xor = r.read(64 - prev_lzb)
        else:
            prev_lzb = buckets[r.read(3)]
            xor = r.read(64 - prev_lzb)
        prev ^= xor
        out[i] = prev
    return out.view(np.float64)


VALUE_ENCODERS = {"gorilla": gorilla_encode, "chimp": chimp_encode}
VALUE_DECODERS = {"gorilla": gorilla_decode, "chimp": chimp_decode}
VALUE_BIT_COUNTERS = {"gorilla": gorilla_stream_bits,
                      "chimp": chimp_stream_bits}


# ---------------------------------------------------------------------------
# kept-index stream: delta-of-delta bit packing (Gorilla timestamp style)
# ---------------------------------------------------------------------------

# (control bits, control width, payload bits, payload offset) per bucket;
# dod in [lo, hi] is stored as dod - lo in `payload bits` bits.
_DOD_BUCKETS = (
    (0b10, 2, 7, -63),       # dod in [-63, 64]
    (0b110, 3, 9, -255),     # dod in [-255, 256]
    (0b1110, 4, 12, -2047),  # dod in [-2047, 2048]
)
_DOD_WIDE_CTRL, _DOD_WIDE_CTRLW, _DOD_WIDE_BITS = 0b1111, 4, 32


def _dod_terms(idx: np.ndarray):
    idx = np.asarray(idx, np.int64)
    deltas = np.diff(idx)
    if np.any(deltas <= 0):
        raise ValueError("kept indices must be strictly increasing")
    dods = np.diff(deltas, prepend=np.int64(1))  # first delta vs implicit 1
    if dods.size and np.abs(dods).max() >= (1 << 31):
        raise ValueError("index delta-of-delta outside the 32-bit bucket")
    return dods


def index_stream_bits(idx) -> int:
    """Exact bit size of :func:`encode_indices`' stream (vectorized tally)."""
    idx = np.asarray(idx, np.int64)
    if idx.shape[0] == 0:
        return 0
    dods = _dod_terms(idx)
    bits = np.full(dods.shape, _DOD_WIDE_CTRLW + _DOD_WIDE_BITS, np.int64)
    for ctrl, cw, pb, lo in reversed(_DOD_BUCKETS):
        hi = lo + (1 << pb) - 1
        bits = np.where((dods >= lo) & (dods <= hi), cw + pb, bits)
    bits = np.where(dods == 0, 1, bits)
    return 32 + int(bits.sum())


def encode_indices(idx) -> bytes:
    """Delta-of-delta stream for strictly-increasing int indices.

    The first index is stored in 32 raw bits; the first delta is coded as a
    dod against an implicit previous delta of 1 (the unit-stride prior —
    CAMEO kept sets at moderate CR are long runs of consecutive indices,
    which cost one bit per point here).
    """
    idx = np.asarray(idx, np.int64)
    w = BitWriter()
    if idx.shape[0] == 0:
        return w.getvalue()
    if not (0 <= idx[0] < (1 << 32)):
        raise ValueError(f"first index {idx[0]} outside u32 range")
    w.write(int(idx[0]), 32)
    for dod in _dod_terms(idx).tolist():
        if dod == 0:
            w.write(0, 1)
            continue
        for ctrl, cw, pb, lo in _DOD_BUCKETS:
            hi = lo + (1 << pb) - 1
            if lo <= dod <= hi:
                w.write(ctrl, cw)
                w.write(dod - lo, pb)
                break
        else:
            w.write(_DOD_WIDE_CTRL, _DOD_WIDE_CTRLW)
            w.write(dod & 0xFFFFFFFF, _DOD_WIDE_BITS)
    return w.getvalue()


def decode_indices(data: bytes, count: int) -> np.ndarray:
    """Inverse of :func:`encode_indices`; returns int64 [count]."""
    out = np.empty(count, np.int64)
    if count == 0:
        return out
    r = BitReader(data)
    cur = r.read(32)
    out[0] = cur
    delta = 1
    for i in range(1, count):
        if r.read(1) == 0:
            dod = 0
        else:
            for ctrl, cw, pb, lo in _DOD_BUCKETS:
                if r.read(1) == 0:               # matched this bucket's ctrl
                    dod = r.read(pb) + lo
                    break
            else:
                raw = r.read(_DOD_WIDE_BITS)
                dod = raw - (1 << 32) if raw >= (1 << 31) else raw
        delta += dod
        cur += delta
        out[i] = cur
    return out


# ---------------------------------------------------------------------------
# entropy wrap (checkpoint/manager.py fallback discipline)
# ---------------------------------------------------------------------------

def entropy_wrap(raw: bytes, codec: str = "auto"):
    """Optionally entropy-code ``raw``.  Returns ``(payload, codec_used)``;
    the wrap is dropped (``"none"``) whenever it does not shrink the bytes.
    """
    if codec == "none":
        return raw, "none"
    if codec not in ("auto", "zstd", "zlib"):
        raise ValueError(f"unknown entropy codec {codec!r}")
    if codec in ("auto", "zstd") and zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3).compress(raw)
        name = "zstd"
    else:
        comp = zlib.compress(raw, 6)
        name = "zlib"
    if len(comp) < len(raw):
        return comp, name
    return raw, "none"


def entropy_unwrap(payload: bytes, codec: str) -> bytes:
    if codec == "none":
        return payload
    if codec == "zstd":
        if zstandard is None:
            raise IOError("block is zstd-compressed but the zstandard "
                          "module is not installed")
        return zstandard.ZstdDecompressor().decompress(payload)
    if codec == "zlib":
        return zlib.decompress(payload)
    raise ValueError(f"unknown entropy codec {codec!r}")


# ---------------------------------------------------------------------------
# byte-true compression ratios
# ---------------------------------------------------------------------------

def encode_series_payload(indices, values, *, value_codec: str = "gorilla",
                          entropy: str = "auto"):
    """Encode one series' kept set as (index stream || value stream).

    Returns ``(payload, info)`` where ``info`` records the pre-wrap bit
    sizes and the entropy codec actually used.  This is the codec-only
    payload (no block headers) — the honest numerator for Table-2-style
    bits-per-value comparisons.
    """
    idx_bytes = encode_indices(indices)
    val_bytes = VALUE_ENCODERS[value_codec](values)
    raw = (len(idx_bytes).to_bytes(4, "little") + idx_bytes + val_bytes)
    payload, used = entropy_wrap(raw, entropy)
    info = dict(idx_bits=index_stream_bits(indices),
                val_bits=VALUE_BIT_COUNTERS[value_codec](values),
                raw_nbytes=len(raw), nbytes=len(payload),
                entropy=used, value_codec=value_codec)
    return payload, info


def decode_series_payload(payload: bytes, n_kept: int, entropy: str,
                          value_codec: str = "gorilla"):
    """Inverse of :func:`encode_series_payload` -> (indices, values)."""
    raw = entropy_unwrap(payload, entropy)
    idx_len = int.from_bytes(raw[:4], "little")
    idx = decode_indices(raw[4:4 + idx_len], n_kept)
    vals = VALUE_DECODERS[value_codec](raw[4 + idx_len:], n_kept)
    return idx, vals


def compression_ratio_bytes(res, *, value_codec: str = "gorilla",
                            entropy: str = "auto") -> float:
    """Byte-true CR: raw float64 bytes over encoded-payload bytes.

    The point-count CR (``core.cameo.compression_ratio``) divides *counts*;
    this divides *bytes*, with the kept set actually materialized through
    the index + value codecs (entropy-wrapped).  ``res`` is a
    ``CompressResult`` (or anything with ``.kept`` / ``.xr``).
    """
    from repro.core.cameo import kept_points  # cameo does not import store
    idx, vals = kept_points(res)
    n = int(res.kept.shape[0])
    payload, _ = encode_series_payload(idx, vals, value_codec=value_codec,
                                       entropy=entropy)
    return (8.0 * n) / max(len(payload), 1)
