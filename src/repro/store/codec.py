"""Byte-true bitstream codecs for the CameoStore physical layer.

The paper's headline metric is a compression *ratio*, but ratios only mean
something once a byte stream exists.  This module materializes the two
streams a stored CAMEO series consists of:

* **kept-index stream** — delta-of-delta bit-packing in the Gorilla
  timestamp style ('0' for a repeated delta, then 7/9/12/32-bit buckets).
  CAMEO's kept indices are near-arithmetic at low CR (long runs of
  delta==const cost one bit per point) and stay cheap at high CR.
* **value stream** — Gorilla or Chimp XOR float codecs.  These are the
  *encoder* forms of the bit-cost counters in ``baselines/lossless.py``
  (Table 2): the branch plans are computed once here and shared by both the
  counters and the emitters, so counted bits == emitted bits exactly, by
  construction (and by test).

Both directions are numpy-vectorized (Sprintz's lesson: bit-packed
time-series codecs only pay off when decode is, PAPERS.md):

* **encode** — the branch plan yields one (value, width) field pair per
  record; :func:`_pack_fields` packs all fields in bulk (ragged bit
  scatter + ``np.packbits``).
* **decode** — a single cheap control-stream scan (a few integer ops per
  non-zero record; runs of zero-control records are consumed in bulk)
  recovers each record's branch case and payload bit offset, then
  :func:`_gather_fields` extracts every payload field in one shot and the
  value chains close with ``np.bitwise_xor.accumulate`` (XOR codecs) /
  second-order ``np.cumsum`` (delta-of-delta indices).

The original one-record-at-a-time forms are kept as ``*_loop`` parity
oracles: they pin the published encodings in their most literal shape, and
the property tests hold the vectorized paths bit-identical to them.

Both streams can be wrapped in an optional entropy stage (zstd when the
``zstandard`` module is present, stdlib zlib otherwise — the same fallback
discipline as ``checkpoint/manager.py``); the wrap is only kept when it
actually shrinks the payload, and the chosen codec is recorded so decode
never guesses.

Everything here is plain numpy + stdlib: no jax, importable from anywhere
(``baselines/lossless.py`` delegates its fast paths to the shared plans).
All value codecs operate on 64-bit IEEE doubles; float32 inputs are upcast
(exactly) and round-trip bit-true through a float32 cast on the way out.
"""
from __future__ import annotations

import zlib

import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: entropy wrap falls back to stdlib zlib
    zstandard = None

VALUE_CODECS = ("gorilla", "chimp")
ENTROPY_CODECS = ("none", "zlib", "zstd")

_CHIMP_LZ_BUCKETS = np.array([0, 8, 12, 16, 18, 20, 22, 24])

_U64_ONE = np.uint64(1)


# ---------------------------------------------------------------------------
# bit-level IO (loop forms; the vectorized paths use _pack/_gather_fields)
# ---------------------------------------------------------------------------

class BitWriter:
    """MSB-first bit packer.  O(1) amortized per write; bounded accumulator."""

    __slots__ = ("_buf", "_acc", "_nacc", "bit_length")

    def __init__(self):
        self._buf = bytearray()
        self._acc = 0          # partial bits, < 8 of them after each write
        self._nacc = 0
        self.bit_length = 0

    def write(self, value: int, nbits: int):
        if nbits <= 0:
            return
        self.bit_length += nbits
        acc = (self._acc << nbits) | (int(value) & ((1 << nbits) - 1))
        nacc = self._nacc + nbits
        buf = self._buf
        while nacc >= 8:
            nacc -= 8
            buf.append((acc >> nacc) & 0xFF)
        self._acc = acc & ((1 << nacc) - 1)
        self._nacc = nacc

    def getvalue(self) -> bytes:
        if self._nacc:
            return bytes(self._buf) + bytes(
                [(self._acc << (8 - self._nacc)) & 0xFF])
        return bytes(self._buf)


class BitReader:
    """MSB-first bit reader over ``bytes`` (the BitWriter's inverse)."""

    __slots__ = ("_data", "_pos", "_acc", "_nacc")

    def __init__(self, data: bytes):
        self._data = data
        self._pos = 0
        self._acc = 0
        self._nacc = 0

    def read(self, nbits: int) -> int:
        if nbits <= 0:
            return 0
        acc, nacc, pos, data = self._acc, self._nacc, self._pos, self._data
        while nacc < nbits:
            acc = (acc << 8) | data[pos]
            pos += 1
            nacc += 8
        nacc -= nbits
        out = (acc >> nacc) & ((1 << nbits) - 1)
        self._acc = acc & ((1 << nacc) - 1)
        self._nacc = nacc
        self._pos = pos
        return out


# ---------------------------------------------------------------------------
# bulk bit framing (shared by the vectorized encoders AND decoders)
# ---------------------------------------------------------------------------

def _pack_fields(values, widths) -> bytes:
    """Pack bit-fields MSB-first: field ``k`` occupies ``widths[k]`` bits
    starting at ``sum(widths[:k])`` — the vectorized form of a
    ``BitWriter.write`` loop (bit-identical output, including the zero-pad
    of the final partial byte).  Zero-width fields emit nothing; values
    wider than their field are truncated to the low ``width`` bits, like
    ``BitWriter.write``'s mask.

    Works in the bit domain with uint8 C kernels only: each value explodes
    to its 64 MSB-first bits (``np.unpackbits``), a ragged row mask keeps
    the low ``width`` bits of every row, and the boolean fancy-index
    concatenates them in stream order for ``np.packbits``.
    """
    widths = np.asarray(widths, np.int64)
    total = int(widths.sum())
    if total == 0:
        return b""
    nz = widths > 0
    v = np.ascontiguousarray(np.asarray(values, np.uint64)[nz])
    wd = widths[nz]
    bits64 = np.unpackbits(v.byteswap().view(np.uint8)).reshape(-1, 64)
    keep = np.arange(64) >= (64 - wd)[:, None]
    return np.packbits(bits64[keep]).tobytes()


def _gather_fields(data: bytes, starts, widths) -> np.ndarray:
    """Extract bit-fields from an MSB-first stream: field ``k`` is
    ``widths[k]`` bits at absolute bit offset ``starts[k]`` — the
    vectorized form of a ``BitReader.read`` loop.  Returns uint64 values
    (0 where ``width == 0``).

    Each field spans at most 9 bytes (64 bits + 7 bits of misalignment),
    so one ``[k, 9]`` byte-window gather + a big-endian view + two shifts
    recover every field at once.
    """
    widths = np.asarray(widths, np.int64)
    out = np.zeros(widths.shape[0], np.uint64)
    nz = widths > 0
    wd = widths[nz]
    if wd.shape[0] == 0:
        return out
    pos = np.asarray(starts, np.int64)[nz]
    d = np.frombuffer(data, np.uint8)
    d = np.concatenate([d, np.zeros(9, np.uint8)])
    r = (pos & 7).astype(np.uint64)
    win = d[(pos >> 3)[:, None] + np.arange(9)]
    w64 = np.ascontiguousarray(win[:, :8]).view(">u8")[:, 0].astype(np.uint64)
    b8 = win[:, 8].astype(np.uint64)
    aligned = (w64 << r) | (b8 >> (np.uint64(8) - r))   # bits [pos, pos+64)
    out[nz] = aligned >> (np.uint64(64) - wd.astype(np.uint64))
    return out


# The sequential control-stream scans (the only non-bulk part of decode)
# live in store/_scan.py: native C via ctypes when a compiler is around,
# pure-Python 24-bit-window fallback otherwise — identical packed output.
from repro.store import _scan


# ---------------------------------------------------------------------------
# vectorized XOR bit-geometry (shared by counters and encoders)
# ---------------------------------------------------------------------------

def bit_length_u64(v: np.ndarray) -> np.ndarray:
    """Vectorized ``int.bit_length`` for a uint64 array (0 -> 0).

    Binary-search over shifted masks — exact for the full 64-bit range
    (float log2 would mis-round near powers of two above 2**53).
    """
    v = np.asarray(v, np.uint64).copy()
    bl = np.zeros(v.shape, np.int64)
    for s in (32, 16, 8, 4, 2, 1):
        su = np.uint64(s)
        big = v >= (_U64_ONE << su)
        bl[big] += s
        v[big] >>= su
    bl += (v != 0)
    return bl


def xor_parts(x: np.ndarray):
    """(bits, xor, lz, tz) of a float64 series, fully vectorized.

    ``xor[i] = bits[i+1] ^ bits[i]``; ``lz``/``tz`` are leading/trailing zero
    counts of each xor (64 for xor == 0) — the vectorized form of the
    per-value Python loop the Table 2 counters used to run.
    """
    bits = np.ascontiguousarray(np.asarray(x, np.float64)).view(np.uint64)
    xor = bits[1:] ^ bits[:-1]
    bl = bit_length_u64(xor)
    lz = np.where(xor == 0, 64, 64 - bl)
    lowbit = xor & (~xor + _U64_ONE)
    tz = np.where(xor == 0, 64, bit_length_u64(lowbit) - 1)
    return bits, xor, lz.astype(np.int64), tz.astype(np.int64)


# ---------------------------------------------------------------------------
# Gorilla value codec (Pelkonen et al. 2015)
# ---------------------------------------------------------------------------

def _gorilla_plan(xor, lz, tz):
    """Branch plan for the Gorilla value stream.

    Returns ``(case, sig, shift)`` aligned with ``xor``: case 0 = zero xor
    ('0'), 1 = window reuse ('10' + sig bits), 2 = new window ('11' + 5-bit
    LZ + 6-bit length + sig bits); ``shift`` is the right-shift producing the
    emitted meaningful bits.  The meaningful-bit *window* chain is inherently
    sequential (each reuse decision depends on the last reset), so this scan
    runs in Python — but over the precomputed vectorized bit geometry, which
    is where the old per-value loops spent their time.
    """
    m = xor.shape[0]
    li_l = np.minimum(lz, 31).tolist()    # gorilla caps LZ at 31 (5-bit field)
    tz_l = tz.tolist()
    nz_l = (xor != 0).tolist()
    case = [0] * m
    sig = [0] * m
    shift = [0] * m
    plz, ptz = -1, -1
    for i in range(m):
        if not nz_l[i]:
            continue
        li, ti = li_l[i], tz_l[i]
        if plz >= 0 and li >= plz and ti >= ptz:
            case[i] = 1
            sig[i] = 64 - plz - ptz
            shift[i] = ptz
        else:
            case[i] = 2
            sig[i] = 64 - li - ti
            shift[i] = ti
            plz, ptz = li, ti
    return (np.asarray(case, np.int64), np.asarray(sig, np.int64),
            np.asarray(shift, np.int64))


def gorilla_stream_bits(x) -> int:
    """Exact bit size of :func:`gorilla_encode`'s stream (vectorized tally)."""
    x = np.asarray(x, np.float64)
    if x.shape[0] == 0:
        return 0
    _, xor, lz, tz = xor_parts(x)
    case, sig, _ = _gorilla_plan(xor, lz, tz)
    bits = np.where(case == 0, 1,
                    np.where(case == 1, 2 + sig, 2 + 5 + 6 + sig))
    return 64 + int(bits.sum())


def gorilla_encode(x) -> bytes:
    """Gorilla XOR value stream for a float64 series (lossless).

    Vectorized: the branch plan maps each record to one header field and
    one payload field; :func:`_pack_fields` packs the whole stream in bulk.
    Byte-identical to :func:`gorilla_encode_loop`.
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if n == 0:
        return b""
    bits, xor, lz, tz = xor_parts(x)
    case, sig, shift = _gorilla_plan(xor, lz, tz)
    m = xor.shape[0]
    li = 64 - sig - shift                 # the capped LZ, for case-2 headers
    hdr_val = np.where(case == 0, 0,
                       np.where(case == 1, 0b10,
                                (0b11 << 11) | (li << 6) | (sig & 0x3F)))
    hdr_w = np.where(case == 0, 1, np.where(case == 1, 2, 13))
    pay_val = xor >> np.minimum(shift, 63).astype(np.uint64)
    pay_w = np.where(case == 0, 0, sig)
    vals = np.empty(1 + 2 * m, np.uint64)
    wids = np.empty(1 + 2 * m, np.int64)
    vals[0], wids[0] = bits[0], 64
    vals[1::2], wids[1::2] = hdr_val, hdr_w
    vals[2::2], wids[2::2] = pay_val, pay_w
    return _pack_fields(vals, wids)


def gorilla_decode(data: bytes, n: int) -> np.ndarray:
    """Inverse of :func:`gorilla_encode`; returns float64 [n].

    Vectorized: one control-stream scan recovers each record's payload bit
    offset/width (runs of '0' control bits — zero xors — are consumed in
    bulk straight off the 24-bit windows), payloads are gathered in one
    :func:`_gather_fields` call, and the XOR chain closes with
    ``np.bitwise_xor.accumulate``.  Bit-true inverse, property-tested
    against :func:`gorilla_decode_loop`.
    """
    if n == 0:
        return np.empty(0, np.uint64).view(np.float64)
    a = _scan.gorilla_scan(data, n - 1)
    stream = np.zeros(n, np.uint64)
    stream[0] = int.from_bytes(data[:8], "big")   # MSB-first head field
    if a.shape[0]:
        ri = a >> 15
        sig = (a >> 7) & 0x7F
        hdr_w = np.where(a & 0x4000, 13, 2)
        body = hdr_w + sig
        # payload offsets: 64 head bits + 1 bit per preceding zero-xor
        # record + every preceding non-zero record's header + payload
        pos = (64 + (ri - np.arange(ri.shape[0]))
               + np.cumsum(body) - body + hdr_w)
        xors = _gather_fields(data, pos, sig)
        stream[ri + 1] = xors << (a & 0x3F).astype(np.uint64)
    return np.bitwise_xor.accumulate(stream).view(np.float64)


def gorilla_encode_loop(x) -> bytes:
    """Parity oracle: :func:`gorilla_encode` as the literal per-record
    ``BitWriter`` loop the published scheme describes."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    w = BitWriter()
    if n == 0:
        return w.getvalue()
    bits, xor, lz, tz = xor_parts(x)
    w.write(int(bits[0]), 64)
    case, sig, shift = _gorilla_plan(xor, lz, tz)
    xor_l, sig_l, shift_l = xor.tolist(), sig.tolist(), shift.tolist()
    for i, c in enumerate(case.tolist()):
        if c == 0:
            w.write(0, 1)
        elif c == 1:
            w.write(0b10, 2)
            w.write(xor_l[i] >> shift_l[i], sig_l[i])
        else:
            w.write(0b11, 2)
            w.write(64 - sig_l[i] - shift_l[i], 5)
            w.write(sig_l[i] & 0x3F, 6)        # 64 wraps to 0; decode maps back
            w.write(xor_l[i] >> shift_l[i], sig_l[i])
    return w.getvalue()


def gorilla_decode_loop(data: bytes, n: int) -> np.ndarray:
    """Parity oracle: :func:`gorilla_decode` as the literal per-record
    ``BitReader`` loop."""
    out = np.empty(n, np.uint64)
    if n == 0:
        return out.view(np.float64)
    r = BitReader(data)
    prev = r.read(64)
    out[0] = prev
    plz, ptz = -1, -1
    for i in range(1, n):
        if r.read(1):
            if r.read(1):                       # '11' — new window
                li = r.read(5)
                sig = r.read(6) or 64
                ti = 64 - li - sig
                xor = r.read(sig) << ti
                plz, ptz = li, ti
            else:                               # '10' — reuse window
                xor = r.read(64 - plz - ptz) << ptz
            prev ^= xor
        out[i] = prev
    return out.view(np.float64)


# ---------------------------------------------------------------------------
# Chimp value codec (Liakos et al. 2022, plain variant)
# ---------------------------------------------------------------------------

def _chimp_plan(xor, lz, tz):
    """Branch plan for the (plain) Chimp stream — fully vectorized.

    Chimp's only carried state is the previous leading-zero bucket, and it is
    a function of the *previous element alone* (zero-xor and center-form
    entries reset it), so unlike Gorilla there is no sequential chain.

    Returns ``(case, lzb, bi)``: case 0 = zero xor, 1 = center form
    (tz > 6), 2 = bucket reuse, 3 = new bucket; ``lzb`` the rounded
    leading-zero bucket, ``bi`` its 3-bit index.
    """
    bi = np.searchsorted(_CHIMP_LZ_BUCKETS, np.minimum(lz, 24),
                         side="right") - 1
    lzb = _CHIMP_LZ_BUCKETS[bi]
    resets = (xor == 0) | (tz > 6)
    prev_bucket = np.concatenate(
        [[-1], np.where(resets[:-1], -1, lzb[:-1])])
    case = np.where(xor == 0, 0,
                    np.where(tz > 6, 1,
                             np.where(lzb == prev_bucket, 2, 3)))
    return case.astype(np.int64), lzb.astype(np.int64), bi.astype(np.int64)


def chimp_stream_bits(x) -> int:
    """Exact bit size of :func:`chimp_encode`'s stream (vectorized tally)."""
    x = np.asarray(x, np.float64)
    if x.shape[0] == 0:
        return 0
    _, xor, lz, tz = xor_parts(x)
    case, lzb, _ = _chimp_plan(xor, lz, tz)
    center = np.maximum(64 - lzb - tz, 0)
    bits = np.where(case == 0, 2,
                    np.where(case == 1, 2 + 3 + 6 + center,
                             np.where(case == 2, 2 + (64 - lzb),
                                      2 + 3 + (64 - lzb))))
    return 64 + int(bits.sum())


def chimp_encode(x) -> bytes:
    """Chimp XOR value stream for a float64 series (lossless).

    Vectorized bulk packing; byte-identical to :func:`chimp_encode_loop`.
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if n == 0:
        return b""
    bits, xor, lz, tz = xor_parts(x)
    case, lzb, bi = _chimp_plan(xor, lz, tz)
    m = xor.shape[0]
    center = np.maximum(64 - lzb - tz, 0)
    hdr_val = np.select(
        [case == 0, case == 1, case == 2],
        [0, (0b01 << 9) | (bi << 6) | (center & 0x3F), 0b10],
        default=(0b11 << 3) | bi)
    hdr_w = np.select([case == 0, case == 1, case == 2], [2, 11, 2],
                      default=5)
    pay_val = np.where(case == 1,
                       xor >> np.minimum(tz, 63).astype(np.uint64), xor)
    pay_w = np.select([case == 0, case == 1], [0, center],
                      default=64 - lzb)
    vals = np.empty(1 + 2 * m, np.uint64)
    wids = np.empty(1 + 2 * m, np.int64)
    vals[0], wids[0] = bits[0], 64
    vals[1::2], wids[1::2] = hdr_val, hdr_w
    vals[2::2], wids[2::2] = pay_val, pay_w
    return _pack_fields(vals, wids)


def chimp_decode(data: bytes, n: int) -> np.ndarray:
    """Inverse of :func:`chimp_encode`; returns float64 [n].

    Vectorized control-scan + bulk gather + ``np.bitwise_xor.accumulate``,
    like :func:`gorilla_decode` ('00' zero-xor runs consumed in bulk).
    Bit-true inverse, property-tested against :func:`chimp_decode_loop`.
    """
    if n == 0:
        return np.empty(0, np.uint64).view(np.float64)
    a = _scan.chimp_scan(data, n - 1)
    stream = np.zeros(n, np.uint64)
    stream[0] = int.from_bytes(data[:8], "big")   # MSB-first head field
    if a.shape[0]:
        ri = a >> 15
        width = (a >> 6) & 0x7F
        hdr_w = np.array([0, 11, 2, 5])[(a >> 13) & 3]
        body = hdr_w + width
        # payload offsets: 64 head bits + 2 bits per preceding zero-xor
        # record + every preceding non-zero record's header + payload
        pos = (64 + 2 * (ri - np.arange(ri.shape[0]))
               + np.cumsum(body) - body + hdr_w)
        xors = _gather_fields(data, pos, width)
        stream[ri + 1] = xors << (a & 0x3F).astype(np.uint64)
    return np.bitwise_xor.accumulate(stream).view(np.float64)


def chimp_encode_loop(x) -> bytes:
    """Parity oracle: :func:`chimp_encode` as the literal per-record
    ``BitWriter`` loop."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    w = BitWriter()
    if n == 0:
        return w.getvalue()
    bits, xor, lz, tz = xor_parts(x)
    w.write(int(bits[0]), 64)
    case, lzb, bi = _chimp_plan(xor, lz, tz)
    xor_l, tz_l = xor.tolist(), tz.tolist()
    lzb_l, bi_l = lzb.tolist(), bi.tolist()
    for i, c in enumerate(case.tolist()):
        if c == 0:
            w.write(0b00, 2)
        elif c == 1:
            center = max(64 - lzb_l[i] - tz_l[i], 0)
            w.write(0b01, 2)
            w.write(bi_l[i], 3)
            w.write(center & 0x3F, 6)
            w.write(xor_l[i] >> tz_l[i], center)
        elif c == 2:
            w.write(0b10, 2)
            w.write(xor_l[i], 64 - lzb_l[i])
        else:
            w.write(0b11, 2)
            w.write(bi_l[i], 3)
            w.write(xor_l[i], 64 - lzb_l[i])
    return w.getvalue()


def chimp_decode_loop(data: bytes, n: int) -> np.ndarray:
    """Parity oracle: :func:`chimp_decode` as the literal per-record
    ``BitReader`` loop."""
    out = np.empty(n, np.uint64)
    if n == 0:
        return out.view(np.float64)
    r = BitReader(data)
    prev = r.read(64)
    out[0] = prev
    buckets = _CHIMP_LZ_BUCKETS.tolist()
    prev_lzb = -1
    for i in range(1, n):
        c = r.read(2)
        if c == 0b00:
            xor = 0
            prev_lzb = -1
        elif c == 0b01:
            lzb = buckets[r.read(3)]
            center = r.read(6) or 64
            ti = 64 - lzb - center
            xor = r.read(center) << ti
            prev_lzb = -1
        elif c == 0b10:
            xor = r.read(64 - prev_lzb)
        else:
            prev_lzb = buckets[r.read(3)]
            xor = r.read(64 - prev_lzb)
        prev ^= xor
        out[i] = prev
    return out.view(np.float64)


VALUE_ENCODERS = {"gorilla": gorilla_encode, "chimp": chimp_encode}
VALUE_DECODERS = {"gorilla": gorilla_decode, "chimp": chimp_decode}
VALUE_ENCODERS_LOOP = {"gorilla": gorilla_encode_loop,
                       "chimp": chimp_encode_loop}
VALUE_DECODERS_LOOP = {"gorilla": gorilla_decode_loop,
                       "chimp": chimp_decode_loop}
VALUE_BIT_COUNTERS = {"gorilla": gorilla_stream_bits,
                      "chimp": chimp_stream_bits}


# ---------------------------------------------------------------------------
# kept-index stream: delta-of-delta bit packing (Gorilla timestamp style)
# ---------------------------------------------------------------------------

# (control bits, control width, payload bits, payload offset) per bucket;
# dod in [lo, hi] is stored as dod - lo in `payload bits` bits.
_DOD_BUCKETS = (
    (0b10, 2, 7, -63),       # dod in [-63, 64]
    (0b110, 3, 9, -255),     # dod in [-255, 256]
    (0b1110, 4, 12, -2047),  # dod in [-2047, 2048]
)
_DOD_WIDE_CTRL, _DOD_WIDE_CTRLW, _DOD_WIDE_BITS = 0b1111, 4, 32
_DOD_LOS = np.array([lo for *_, lo in _DOD_BUCKETS] + [0], np.int64)


def _dod_terms(idx: np.ndarray):
    idx = np.asarray(idx, np.int64)
    deltas = np.diff(idx)
    if np.any(deltas <= 0):
        raise ValueError("kept indices must be strictly increasing")
    dods = np.diff(deltas, prepend=np.int64(1))  # first delta vs implicit 1
    if dods.size and np.abs(dods).max() >= (1 << 31):
        raise ValueError("index delta-of-delta outside the 32-bit bucket")
    return dods


def index_stream_bits(idx) -> int:
    """Exact bit size of :func:`encode_indices`' stream (vectorized tally)."""
    idx = np.asarray(idx, np.int64)
    if idx.shape[0] == 0:
        return 0
    dods = _dod_terms(idx)
    bits = np.full(dods.shape, _DOD_WIDE_CTRLW + _DOD_WIDE_BITS, np.int64)
    for ctrl, cw, pb, lo in reversed(_DOD_BUCKETS):
        hi = lo + (1 << pb) - 1
        bits = np.where((dods >= lo) & (dods <= hi), cw + pb, bits)
    bits = np.where(dods == 0, 1, bits)
    return 32 + int(bits.sum())


def encode_indices(idx) -> bytes:
    """Delta-of-delta stream for strictly-increasing int indices.

    The first index is stored in 32 raw bits; the first delta is coded as a
    dod against an implicit previous delta of 1 (the unit-stride prior —
    CAMEO kept sets at moderate CR are long runs of consecutive indices,
    which cost one bit per point here).  Vectorized bulk packing;
    byte-identical to :func:`encode_indices_loop`.
    """
    idx = np.asarray(idx, np.int64)
    if idx.shape[0] == 0:
        return b""
    if not (0 <= idx[0] < (1 << 32)):
        raise ValueError(f"first index {idx[0]} outside u32 range")
    dods = _dod_terms(idx)
    m = dods.shape[0]
    hdr_val = np.zeros(m, np.int64)
    hdr_w = np.ones(m, np.int64)
    pay_val = np.zeros(m, np.int64)
    pay_w = np.zeros(m, np.int64)
    left = dods != 0
    for ctrl, cw, pb, lo in _DOD_BUCKETS:
        hi = lo + (1 << pb) - 1
        sel = left & (dods >= lo) & (dods <= hi)
        hdr_val[sel] = ctrl
        hdr_w[sel] = cw
        pay_val[sel] = dods[sel] - lo
        pay_w[sel] = pb
        left &= ~sel
    hdr_val[left] = _DOD_WIDE_CTRL
    hdr_w[left] = _DOD_WIDE_CTRLW
    pay_val[left] = dods[left] & 0xFFFFFFFF
    pay_w[left] = _DOD_WIDE_BITS
    vals = np.empty(1 + 2 * m, np.uint64)
    wids = np.empty(1 + 2 * m, np.int64)
    vals[0], wids[0] = int(idx[0]), 32
    vals[1::2], wids[1::2] = hdr_val, hdr_w
    vals[2::2], wids[2::2] = pay_val, pay_w
    return _pack_fields(vals, wids)


def decode_indices(data: bytes, count: int) -> np.ndarray:
    """Inverse of :func:`encode_indices`; returns int64 [count].

    Vectorized control-scan (runs of '0' — repeated deltas — consumed in
    bulk) + one payload gather; the index chain closes with second-order
    ``np.cumsum`` (dod -> delta -> index).  Bit-true inverse,
    property-tested against :func:`decode_indices_loop`.
    """
    if count == 0:
        return np.empty(0, np.int64)
    m = count - 1
    a = _scan.index_scan(data, m)
    idx0 = int.from_bytes(data[:4], "big")        # MSB-first head field
    dods = np.zeros(m, np.int64)
    if a.shape[0]:
        ri = a >> 2
        bucket = a & 3
        hdr_w = np.array([2, 3, 4, 4])[bucket]
        width = np.array([7, 9, 12, 32])[bucket]
        body = hdr_w + width
        # payload offsets: 32 head bits + 1 bit per preceding repeated
        # delta + every preceding non-zero record's control + payload
        pos = (32 + (ri - np.arange(ri.shape[0]))
               + np.cumsum(body) - body + hdr_w)
        raw = _gather_fields(data, pos, width).astype(np.int64)
        dod = raw + _DOD_LOS[bucket]
        wide = bucket == 3
        dod[wide] = np.where(raw[wide] >= (1 << 31),
                             raw[wide] - (1 << 32), raw[wide])
        dods[ri] = dod
    deltas = np.cumsum(dods) + 1          # delta chain starts at implicit 1
    out = np.empty(count, np.int64)
    out[0] = idx0
    out[1:] = idx0 + np.cumsum(deltas)
    return out


def encode_indices_loop(idx) -> bytes:
    """Parity oracle: :func:`encode_indices` as the literal per-record
    ``BitWriter`` loop."""
    idx = np.asarray(idx, np.int64)
    w = BitWriter()
    if idx.shape[0] == 0:
        return w.getvalue()
    if not (0 <= idx[0] < (1 << 32)):
        raise ValueError(f"first index {idx[0]} outside u32 range")
    w.write(int(idx[0]), 32)
    for dod in _dod_terms(idx).tolist():
        if dod == 0:
            w.write(0, 1)
            continue
        for ctrl, cw, pb, lo in _DOD_BUCKETS:
            hi = lo + (1 << pb) - 1
            if lo <= dod <= hi:
                w.write(ctrl, cw)
                w.write(dod - lo, pb)
                break
        else:
            w.write(_DOD_WIDE_CTRL, _DOD_WIDE_CTRLW)
            w.write(dod & 0xFFFFFFFF, _DOD_WIDE_BITS)
    return w.getvalue()


def decode_indices_loop(data: bytes, count: int) -> np.ndarray:
    """Parity oracle: :func:`decode_indices` as the literal per-record
    ``BitReader`` loop."""
    out = np.empty(count, np.int64)
    if count == 0:
        return out
    r = BitReader(data)
    cur = r.read(32)
    out[0] = cur
    delta = 1
    for i in range(1, count):
        if r.read(1) == 0:
            dod = 0
        else:
            for ctrl, cw, pb, lo in _DOD_BUCKETS:
                if r.read(1) == 0:               # matched this bucket's ctrl
                    dod = r.read(pb) + lo
                    break
            else:
                raw = r.read(_DOD_WIDE_BITS)
                dod = raw - (1 << 32) if raw >= (1 << 31) else raw
        delta += dod
        cur += delta
        out[i] = cur
    return out


# ---------------------------------------------------------------------------
# entropy wrap (checkpoint/manager.py fallback discipline)
# ---------------------------------------------------------------------------

def entropy_wrap(raw: bytes, codec: str = "auto"):
    """Optionally entropy-code ``raw``.  Returns ``(payload, codec_used)``;
    the wrap is dropped (``"none"``) whenever it does not shrink the bytes.
    """
    if codec == "none":
        return raw, "none"
    if codec not in ("auto", "zstd", "zlib"):
        raise ValueError(f"unknown entropy codec {codec!r}")
    if codec in ("auto", "zstd") and zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3).compress(raw)
        name = "zstd"
    else:
        comp = zlib.compress(raw, 6)
        name = "zlib"
    if len(comp) < len(raw):
        return comp, name
    return raw, "none"


def entropy_unwrap(payload: bytes, codec: str) -> bytes:
    if codec == "none":
        return payload
    if codec == "zstd":
        if zstandard is None:
            raise IOError("block is zstd-compressed but the zstandard "
                          "module is not installed")
        return zstandard.ZstdDecompressor().decompress(payload)
    if codec == "zlib":
        return zlib.decompress(payload)
    raise ValueError(f"unknown entropy codec {codec!r}")


# ---------------------------------------------------------------------------
# byte-true compression ratios
# ---------------------------------------------------------------------------

def encode_series_payload(indices, values, *, value_codec: str = "gorilla",
                          entropy: str = "auto"):
    """Encode one series' kept set as (index stream || value stream).

    Returns ``(payload, info)`` where ``info`` records the pre-wrap bit
    sizes and the entropy codec actually used.  This is the codec-only
    payload (no block headers) — the honest numerator for Table-2-style
    bits-per-value comparisons.
    """
    idx_bytes = encode_indices(indices)
    val_bytes = VALUE_ENCODERS[value_codec](values)
    raw = (len(idx_bytes).to_bytes(4, "little") + idx_bytes + val_bytes)
    payload, used = entropy_wrap(raw, entropy)
    info = dict(idx_bits=index_stream_bits(indices),
                val_bits=VALUE_BIT_COUNTERS[value_codec](values),
                raw_nbytes=len(raw), nbytes=len(payload),
                entropy=used, value_codec=value_codec)
    return payload, info


def decode_series_payload(payload: bytes, n_kept: int, entropy: str,
                          value_codec: str = "gorilla"):
    """Inverse of :func:`encode_series_payload` -> (indices, values)."""
    raw = entropy_unwrap(payload, entropy)
    idx_len = int.from_bytes(raw[:4], "little")
    idx = decode_indices(raw[4:4 + idx_len], n_kept)
    vals = VALUE_DECODERS[value_codec](raw[4 + idx_len:], n_kept)
    return idx, vals


def compression_ratio_bytes(res, *, value_codec: str = "gorilla",
                            entropy: str = "auto") -> float:
    """Byte-true CR: raw float64 bytes over encoded-payload bytes.

    The point-count CR (``core.cameo.compression_ratio``) divides *counts*;
    this divides *bytes*, with the kept set actually materialized through
    the index + value codecs (entropy-wrapped).  ``res`` is a
    ``CompressResult`` (or anything with ``.kept`` / ``.xr``).
    """
    from repro.core.cameo import kept_points  # cameo does not import store
    idx, vals = kept_points(res)
    n = int(res.kept.shape[0])
    payload, _ = encode_series_payload(idx, vals, value_codec=value_codec,
                                       entropy=entropy)
    return (8.0 * n) / max(len(payload), 1)
