"""CameoStore — the on-disk physical layer under the compressor.

Application code reaches this layer through the :mod:`repro.api` façade
(``repro.api.open`` → ``Dataset.write/stream/series``); the store is the
internal it drives.

File layout (append-oriented: blocks stream to disk as series are ingested,
the index is a footer written on ``flush``/``close``)::

    magic "CAMEOST\\x03" (or \\x04 once a multivariate block exists)
    [u32 body_len][block body + crc32] ...      (blocks, any series order)
    footer JSON (zlib)                           (series catalog)
    [u64 footer_offset][u32 footer_len][magic]

Format v3 derives the four redundant aggregate header rows from the edge
vectors + scalar moments at parse time instead of storing them (see
``store/blocks.py`` — ~2.3x further header shrink on top of the v2
shuffle+delta coding).  Format **v4** adds multivariate series — one
shared delta-of-delta kept-index stream per block, per-column value
streams and per-column Eq. 7 metadata; the v4 magic is written exactly
when the first multivariate block is (``_require_mvar`` rewrites the head
magic in place), so univariate-only files stay bit-identical to v3
writers.  v2/v3 files read fine (the per-block flags byte / catalog
``channels`` say which layout a body uses); v1 files are refused loudly —
reingest them.

Durability contract (details + journal format: ``store/README.md``)
-------------------------------------------------------------------
Writable stores keep a sidecar **write-ahead journal** (``<path>.wal``,
:mod:`repro.store.wal`; opt out with ``wal=False`` / ``CAMEO_WAL=0``).
Acked stream pushes land in the journal *before* compression, with one
group-commit fsync amortized over ``wal_group_ms`` / ``wal_group_bytes``
of appends; ``flush()``/``close()`` publish the footer atomically — body
fsynced before the tail marker that makes readers trust it — and then
checkpoint (truncate) the journal.  A crashed writer leaves a file with a
torn tail: a partial block, footer, or tail marker.  ``mode="r"`` still
refuses it loudly rather than serve a partial catalog, but reopening with
``mode="a"`` **recovers**: the store rolls back to the journal's
checkpoint (the last published footer, byte-identical), and the acked
pushes past it replay deterministically through the streaming façade
(``repro.api`` / ``ingest_stream(resume=True)``) — so a crash never loses
an acked push, and the recovered file is byte-identical to a clean run of
the same feed.  All fsyncs honor the ``CAMEO_FSYNC=0`` escape hatch
(tests), which downgrades power-loss durability to process-crash
durability without changing any write ordering.

Two ingest paths share the block writer:

* ``append_series`` — one shot: a finished ``CompressResult`` becomes
  blocks + a complete catalog entry.
* ``open_stream`` — a :class:`StreamSession` that absorbs closed stream
  windows (``core/streaming``) as they arrive and writes each block the
  moment its right border is provable, holding only O(block + window)
  state.  Blocks, offsets and the final footer are **byte-identical** to
  the one-shot write of the same kept points — the session replays
  ``plan_block_bounds``'s greedy rule incrementally (a border ``t1``
  commits once a kept point ``>= t1 + L`` exists, which rules out the
  tail-merge clamp).  ``flush()`` (or ``close``) rewrites the footer so
  the ingested prefix is durable and readable mid-stream; an incomplete
  session's state — pending points *and* an opaque client blob (the
  serving layer stashes its ``StreamingCompressor`` state there) — rides
  along in the footer, so reopening with ``mode="a"`` resumes the stream
  exactly where it stopped.

The reader serves random-access **window decodes** that touch only the
blocks overlapping the window (block borders are kept points, so no
interpolation segment crosses a block — see ``store/blocks.py``), plus
header-only block metadata for ``store/query.py``'s pushdown aggregates.

Reads are cached through a **byte-budgeted decoded-block LRU**
(``cache_bytes``; default 64 MiB): a hit skips the pread, the bitstream
decode *and* — once a window read has touched the block — the jitted
reconstruction, so hot windows and repeated pushdown queries run at
memcpy speed.  ``append_series`` invalidates the appended series' entries
and ``cache_stats()`` reports hits/misses/evictions for the serving layer.
Cache-miss fetches of multi-block windows coalesce blocks that sit
contiguously in the file into single preads; **read-only opens** go one
further and serve block bodies from an mmap of the file, so warm misses
are page-cache slices with no syscalls at all (``CAMEO_MMAP=0`` or
platforms without usable mmap fall back to the pread path — results are
byte-identical either way).

Roundtrip contract (tested property-style): for any compressed series,
``read_kept`` reproduces the kept mask and kept values bit-exactly, and
``read_series``/``read_window`` reproduce the canonical reconstruction —
the one-shot interpolation of the kept points — **bit-exactly**.  For the
rounds mode that canonical form *is* ``CompressResult.xr``; see
``append_series`` for the sequential mode's last-ulp caveat.  The store is
a lossless physical encoding of the compressor's lossy output.
"""
from __future__ import annotations

import collections
import json
import os
import struct
import zlib
from typing import Dict, List

import numpy as np

from repro.obs import OBS
from repro.store import codec as _codec
from repro.store import wal as _wal
from repro.store.blocks import (
    BlockMeta,
    build_block,
    build_mblock,
    parse_block,
    parse_mblock,
    plan_block_bounds,
    reconstruct_block,
)

MAGIC = b"CAMEOST\x03"
_MAGICS = {2: b"CAMEOST\x02", 3: MAGIC,   # readable format versions
           4: b"CAMEOST\x04"}             # v4 = v3 + multivariate blocks
_TAIL = struct.Struct("<QI")          # footer offset, footer byte length
DEFAULT_CACHE_BYTES = 64 << 20


def _json_default(o):
    """Footer-catalog JSON fallback: numpy scalars serialize as their exact
    Python kind.  The old ``default=float`` coerced numpy *integers* to
    float too — silently inexact past 2**53 (block offsets, ``n``, block
    borders in a large store) and wrong-typed on reload."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(
        f"footer catalog cannot serialize {type(o).__name__!r} values")

# cache-entry slots: [meta, kept_idx, kept_vals, xr_or_None, nbytes]
_E_META, _E_IDX, _E_VALS, _E_XR, _E_NBYTES = range(5)


class BlockCache:
    """Byte-budgeted LRU over decoded blocks.

    Entries hold the decoded kept points and, once a window read has needed
    it, the block's reconstruction; ``grow`` accounts the late-attached
    reconstruction bytes.  A zero budget disables caching (every ``put``
    evicts immediately), which the eviction tests rely on.

    ``pin`` marks an entry hot-tier resident: pinned entries still count
    against the budget but are skipped by eviction (the serving layer pins
    blocks of latency-critical windows; see ``server/tiers.py``).  When
    every entry is pinned the cache is allowed to run over budget rather
    than evict a pin — unpinning re-triggers eviction on the next put.
    """

    __slots__ = ("budget", "nbytes", "hits", "misses", "evictions", "_d",
                 "_pinned")

    def __init__(self, budget: int):
        self.budget = int(budget)
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._d = collections.OrderedDict()
        self._pinned = set()

    def get(self, key):
        e = self._d.get(key)
        if e is None:
            self.misses += 1
            if OBS.enabled:
                OBS.inc("store.cache.misses")
            return None
        self._d.move_to_end(key)
        self.hits += 1
        if OBS.enabled:
            OBS.inc("store.cache.hits")
        return e

    def put(self, key, entry):
        old = self._d.pop(key, None)
        if old is not None:
            self.nbytes -= old[_E_NBYTES]
        self._d[key] = entry
        self.nbytes += entry[_E_NBYTES]
        self._evict()
        if OBS.enabled:
            OBS.gauge("store.cache.nbytes", self.nbytes)

    def grow(self, key, extra: int):
        if key in self._d:
            self._d[key][_E_NBYTES] += extra
            self.nbytes += extra
            self._evict()

    def pin(self, key) -> bool:
        """Exempt a resident entry from eviction; returns False on miss."""
        if key not in self._d:
            return False
        self._pinned.add(key)
        return True

    def unpin(self, key):
        self._pinned.discard(key)

    def invalidate(self, sid: str):
        for key in [k for k in self._d if k[0] == sid]:
            self.nbytes -= self._d.pop(key)[_E_NBYTES]
            self._pinned.discard(key)

    def drop(self, key):
        """Invalidate one block entry (streamed per-append invalidation)."""
        e = self._d.pop(key, None)
        if e is not None:
            self.nbytes -= e[_E_NBYTES]
            self._pinned.discard(key)

    def clear(self):
        self._d.clear()
        self._pinned.clear()
        self.nbytes = 0

    def _evict(self):
        ev = 0
        while self.nbytes > self.budget and self._d:
            if self._pinned:
                key = next((k for k in self._d if k not in self._pinned),
                           None)
                if key is None:
                    break          # everything resident is pinned
                e = self._d.pop(key)
            else:
                _, e = self._d.popitem(last=False)
            self.nbytes -= e[_E_NBYTES]
            self.evictions += 1
            ev += 1
        if ev and OBS.enabled:
            OBS.inc("store.cache.evictions", ev)

    def stats(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, entries=len(self._d),
                    pinned=len(self._pinned),
                    nbytes=self.nbytes, budget=self.budget)


class CameoStore:
    """One store file: append-oriented writer + random-access reader.

    Use :meth:`create` (new file), :meth:`open` (finalized file, read-only)
    or ``open(path, mode="a")`` (resume appending).  A store created in this
    process serves reads immediately from its in-memory catalog; a reopened
    store loads the catalog from the footer.  ``cache_bytes`` budgets the
    decoded-block LRU (0 disables caching).
    """

    def __init__(self, path: str, mode: str, *, block_len: int = 4096,
                 value_codec: str = "gorilla", entropy: str = "auto",
                 cache_bytes: int = DEFAULT_CACHE_BYTES, version: int = 3,
                 wal: bool = None,
                 wal_group_ms: float = _wal.DEFAULT_GROUP_MS,
                 wal_group_bytes: int = _wal.DEFAULT_GROUP_BYTES):
        if value_codec not in _codec.VALUE_CODECS:
            raise ValueError(f"unknown value codec {value_codec!r}")
        if version not in _MAGICS:
            raise ValueError(f"unknown store version {version}; have "
                             f"{sorted(_MAGICS)}")
        self.path = path
        self.block_len = int(block_len)
        self.value_codec = value_codec
        self.entropy = entropy
        self.version = int(version)
        self._series: Dict[str, dict] = {}   # sid -> catalog entry
        self._tenants: Dict[str, dict] = {}  # tenant -> config (server layer)
        self._dead_nbytes = 0    # bytes orphaned by compaction/tier rewrites
        # per-tier fetch counters (hot tier = the decoded-block LRU, whose
        # hits/misses live in cache_stats): "warm" = plain block bodies read
        # from mmap/pread, "cold" = entropy-wrapped bodies (see
        # store/maintenance.py) that pay an unwrap on top of the fetch
        self._tier_counts = dict(warm_hits=0, warm_bytes=0,
                                 cold_hits=0, cold_bytes=0)
        # O(1) running ingest totals (see ingest_totals) — bumped on every
        # append/stream emit, recomputed from the catalog on open
        self._totals = dict(series=0, points=0, n_kept=0,
                            stored_nbytes=0, raw_nbytes=0)
        self._cache = BlockCache(cache_bytes)  # (sid, bi) -> decoded entry
        self._metas: Dict[tuple, "BlockMeta"] = {}  # header-only cache
        self._streams: Dict[str, "StreamSession"] = {}  # open ingest streams
        self._writable = mode in ("w", "a")
        self._footer_dirty = False   # a footer sits at EOF; truncate first
        self._mm = None              # mmap view (lazy for writable opens)
        self._mm_stale = False       # file grew since the map was taken
        self._mm_ok = True           # mmap attempt failed; stop retrying
        self._wal = None             # WriteAheadLog of a writable store
        self._wal_pending: Dict[str, list] = {}  # journaled, un-replayed
        self._wal_group_ms = float(wal_group_ms)
        self._wal_group_bytes = int(wal_group_bytes)
        use_wal = self._writable and (
            wal if wal is not None
            else os.environ.get("CAMEO_WAL", "1") not in ("0", "false", "off"))
        if mode == "w":
            self._f = open(path, "w+b")
            self._f.write(_MAGICS[self.version])
            if use_wal:
                self._attach_wal(None)
        elif mode in ("r", "a"):
            self._f = open(path, "r+b" if mode == "a" else "rb")
            scan = (_wal.scan(self._wal_path())
                    if mode == "a" and use_wal else None)
            recovered_empty = False
            try:
                self._load_footer()
            except IOError:
                if scan is None or scan.checkpoint is None:
                    if mode != "a" and os.path.exists(self._wal_path()):
                        self._f.close()
                        raise IOError(
                            f"{self.path}: torn store with a recovery "
                            "journal alongside — reopen with mode='a' to "
                            "recover the acked prefix") from None
                    self._f.close()
                    raise
                recovered_empty = not scan.checkpoint.footer
                self._recover(scan.checkpoint)
            if mode == "r":
                self._mm = self._open_mmap()
            else:
                # defer the footer truncation to the first append: until new
                # bytes exist, the old footer (the sole copy of the catalog
                # and any stashed stream-resume state) stays intact, so a
                # crash between reopen and the first write loses nothing
                self._footer_dirty = not recovered_empty
                if use_wal:
                    self._attach_wal(scan)
        else:
            raise ValueError(f"unknown mode {mode!r}")

    # -- constructors -------------------------------------------------------

    @classmethod
    def create(cls, path: str, *, block_len: int = 4096,
               value_codec: str = "gorilla", entropy: str = "auto",
               cache_bytes: int = DEFAULT_CACHE_BYTES, version: int = 3,
               wal: bool = None,
               wal_group_ms: float = _wal.DEFAULT_GROUP_MS,
               wal_group_bytes: int = _wal.DEFAULT_GROUP_BYTES
               ) -> "CameoStore":
        return cls(path, "w", block_len=block_len, value_codec=value_codec,
                   entropy=entropy, cache_bytes=cache_bytes, version=version,
                   wal=wal, wal_group_ms=wal_group_ms,
                   wal_group_bytes=wal_group_bytes)

    @classmethod
    def open(cls, path: str, mode: str = "r", *,
             cache_bytes: int = DEFAULT_CACHE_BYTES, wal: bool = None,
             wal_group_ms: float = _wal.DEFAULT_GROUP_MS,
             wal_group_bytes: int = _wal.DEFAULT_GROUP_BYTES
             ) -> "CameoStore":
        return cls(path, mode, cache_bytes=cache_bytes, wal=wal,
                   wal_group_ms=wal_group_ms, wal_group_bytes=wal_group_bytes)

    # -- context / lifecycle ------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        if self._f.closed:
            return
        if self._writable:
            self._write_footer()
        if self._wal is not None:
            # the footer just published (and was fsynced) supersedes the
            # journal — except for acked pushes of streams that were never
            # resumed this run, which only the journal still holds
            self._wal.close(remove=not self._wal_pending)
            self._wal = None
        self._invalidate_mmap()
        self._f.close()

    # -- write-ahead journal ------------------------------------------------

    def _wal_path(self) -> str:
        return os.fspath(self.path) + ".wal"

    def _wal_checkpoint(self, footer: bytes = None) -> "_wal.Checkpoint":
        """Checkpoint image of the store's current published state: the
        footer bytes at EOF (or the ones just written, when passed in) and
        the layout parameters needed to rebuild an empty store."""
        meta = dict(block_len=self.block_len, value_codec=self.value_codec,
                    entropy=self.entropy)
        if footer is None:
            if self._footer_dirty and getattr(
                    self, "_footer_offset", None) is not None:
                pos = self._f.tell()
                self._f.seek(self._footer_offset)
                footer = self._f.read(self._footer_len)
                self._f.seek(pos)
            else:
                footer = b""
        off = self._footer_offset if footer else len(MAGIC)
        return _wal.Checkpoint(self.version, off, meta, footer)

    def _attach_wal(self, scan) -> None:
        """Start a journal generation for this writable store.  ``scan`` is
        the tolerant read of the previous generation (or ``None``): its
        acked pushes that the catalog does not already cover become
        ``_wal_pending`` — the streaming façade replays them on resume —
        and are carried into the new generation so they survive further
        crashes until a footer covers them."""
        pending: Dict[str, list] = {}
        if scan is not None:
            for rec in scan.pushes:
                e = self._series.get(rec.sid)
                if e is not None and not e.get("streaming"):
                    continue     # finalized after this record was acked
                pending.setdefault(rec.sid, []).append(rec)
        self._wal_pending = pending
        carry = [r for recs in pending.values() for r in recs]
        self._wal = _wal.WriteAheadLog.start(
            self._wal_path(), self._wal_checkpoint(), carry,
            group_ms=self._wal_group_ms, group_bytes=self._wal_group_bytes)

    def _recover(self, ckpt: "_wal.Checkpoint") -> None:
        """Roll a torn store file back to the journal's checkpoint image:
        truncate everything past the last published footer, restore the
        footer bytes the append run had truncated (plus tail marker and
        head magic for a crash mid-v4-upgrade), and reload the catalog.
        With no footer in the checkpoint the store rolls back to the bare
        header.  The journaled pushes past the checkpoint are *not* lost —
        they replay through the streaming façade on resume."""
        f = self._f
        end = f.seek(0, os.SEEK_END)
        if ckpt.footer:
            if end < ckpt.footer_offset:
                f.close()
                raise IOError(
                    f"{self.path}: store is shorter than its journal "
                    "checkpoint — the file lost bytes below the last "
                    "published footer; cannot recover")
            f.seek(ckpt.footer_offset)
            f.truncate()
            f.write(ckpt.footer)
            f.write(_TAIL.pack(ckpt.footer_offset, len(ckpt.footer)))
            f.write(_MAGICS[ckpt.store_version])
            f.seek(0)
            f.write(_MAGICS[ckpt.store_version])
            _wal.maybe_fsync(f)
            self._load_footer()
        else:
            f.seek(0)
            f.truncate()
            f.write(_MAGICS[ckpt.store_version])
            _wal.maybe_fsync(f)
            self.version = int(ckpt.store_version)
            self.block_len = int(ckpt.meta.get("block_len", self.block_len))
            self.value_codec = ckpt.meta.get("value_codec", self.value_codec)
            self.entropy = ckpt.meta.get("entropy", self.entropy)
            self._series = {}
            self._tenants = {}
            self._dead_nbytes = 0
            self._totals = dict(series=0, points=0, n_kept=0,
                                stored_nbytes=0, raw_nbytes=0)
        if OBS.enabled:
            OBS.inc("wal.recoveries")

    # -- mmap read path ------------------------------------------------------

    def _open_mmap(self):
        """Page-cache-backed view of the store file; ``None`` when
        disabled (``CAMEO_MMAP=0``) or unavailable (non-POSIX mmap quirks,
        empty/special files) — callers fall back to pread."""
        if os.environ.get("CAMEO_MMAP", "1").lower() in ("0", "false", "off"):
            return None
        try:
            import mmap as _mmap
            return _mmap.mmap(self._f.fileno(), 0, access=_mmap.ACCESS_READ)
        except (ImportError, AttributeError, ValueError, OSError):
            return None

    def _mmap(self):
        """The current mmap view, taken lazily.  Read-only opens map once
        at open; writable opens map on first read and **remap** after the
        file grows (``_append_body`` marks the view stale; the remap
        flushes buffered writes first so the page cache is current) —
        a reader never sees a stale or short view after an append."""
        if self._mm_stale:
            self._invalidate_mmap()
        if self._mm is None and self._writable and self._mm_ok:
            self._f.flush()
            self._mm = self._open_mmap()
            if self._mm is None:
                self._mm_ok = False   # unavailable/disabled: stop retrying
        return self._mm

    def _invalidate_mmap(self):
        """Drop the current map (before any truncation: a view over
        truncated pages would fault on access)."""
        if self._mm is not None:
            self._mm.close()
            self._mm = None
        self._mm_stale = False

    def flush(self):
        """Rewrite the footer so everything ingested so far — including the
        readable prefix of open stream sessions, whose resume state is
        embedded — survives a crash: the footer body and tail marker are
        ``os.fsync``'d in order (see ``_write_footer``), so the durability
        promise holds through power loss, not just a process crash
        (``CAMEO_FSYNC=0`` downgrades it to page-cache durability for
        tests).  Appending after a flush truncates the stale footer first
        (the next flush/close writes a fresh one)."""
        if not self._writable:
            raise IOError("store opened read-only")
        self._write_footer()

    def _ensure_appendable(self):
        """Truncate a footer left at EOF by ``flush()`` before appending."""
        if self._footer_dirty:
            self._invalidate_mmap()
            self._f.seek(self._footer_offset)
            self._f.truncate()
            self._footer_dirty = False

    def _append_body(self, body: bytes) -> int:
        """Write one length-prefixed block body at EOF; returns its offset."""
        self._ensure_appendable()
        off = self._f.seek(0, os.SEEK_END)
        self._f.write(struct.pack("<I", len(body)))
        self._f.write(body)
        self._mm_stale = True   # the map no longer covers the new bytes
        if OBS.enabled:
            OBS.inc("store.write.blocks")
            OBS.inc("store.write.bytes", 4 + len(body))
        return off

    def _bump_totals(self, *, series=0, points=0, n_kept=0, stored=0):
        """Advance the O(1) running ingest totals (channel-expanded
        points; ``raw_nbytes`` is always 8 bytes/point)."""
        t = self._totals
        t["series"] += series
        t["points"] += points
        t["n_kept"] += n_kept
        t["stored_nbytes"] += stored
        t["raw_nbytes"] += 8 * points

    def _write_footer(self):
        self._ensure_appendable()
        for sid, sess in self._streams.items():
            self._series[sid]["stream_state"] = sess._stash()
        off = self._f.seek(0, os.SEEK_END)
        cat = {"block_len": self.block_len, "value_codec": self.value_codec,
               "entropy": self.entropy, "series": self._series}
        # optional keys are written only when set, so stores that never see
        # the server layer / maintenance rewrites stay byte-identical to
        # what previous writers produced
        if self._tenants:
            cat["tenants"] = self._tenants
        if self._dead_nbytes:
            cat["dead_nbytes"] = self._dead_nbytes
        footer = zlib.compress(json.dumps(
            cat, default=_json_default).encode())
        # two-phase publish: the footer body must be durable *before* the
        # tail marker that makes readers trust it — a crash between the
        # barriers leaves a torn tail (recoverable), never a tail marker
        # pointing at garbage
        self._f.write(footer)
        _wal.maybe_fsync(self._f)
        self._f.write(_TAIL.pack(off, len(footer)))
        self._f.write(_MAGICS[self.version])
        _wal.maybe_fsync(self._f)
        self._footer_offset = off
        self._footer_len = len(footer)
        self._footer_dirty = True
        if self._wal is not None:
            # the published footer is the new checkpoint; only pushes of
            # never-resumed streams still need the journal to carry them
            carry = [r for recs in self._wal_pending.values() for r in recs]
            self._wal.checkpoint(self._wal_checkpoint(footer), carry)

    def _load_footer(self):
        f = self._f
        f.seek(0)
        head = f.read(len(MAGIC))
        versions = {m: v for v, m in _MAGICS.items()}
        if head not in versions:
            if head[:-1] == MAGIC[:-1]:
                raise IOError(f"{self.path}: CameoStore format "
                              f"v{head[-1]} is not readable by this build "
                              f"(v{max(_MAGICS)}) — reingest the series "
                              "into a fresh store")
            raise IOError(f"{self.path}: not a CameoStore file")
        self.version = versions[head]
        end = f.seek(0, os.SEEK_END)
        tail_len = _TAIL.size + len(MAGIC)
        if end < len(MAGIC) + tail_len:
            raise IOError(f"{self.path}: truncated store (no footer)")
        f.seek(end - tail_len)
        tail = f.read(tail_len)
        if tail[-len(MAGIC):] != head:
            raise IOError(f"{self.path}: missing footer magic — the writer "
                          "crashed mid-run; reopen with mode='a' to recover "
                          "from the journal, or reingest")
        off, flen = _TAIL.unpack(tail[:_TAIL.size])
        f.seek(off)
        try:
            meta = json.loads(zlib.decompress(f.read(flen)).decode())
        except Exception as e:   # garbage tail pointer / torn footer bytes
            raise IOError(
                f"{self.path}: corrupt footer ({e}); reopen with mode='a' "
                "to recover from the journal, or reingest") from None
        self._footer_len = flen
        self.block_len = int(meta.get("block_len", self.block_len))
        self.value_codec = meta.get("value_codec", self.value_codec)
        self.entropy = meta.get("entropy", self.entropy)
        self._series = meta["series"]
        self._tenants = meta.get("tenants", {})
        self._dead_nbytes = int(meta.get("dead_nbytes", 0))
        self._footer_offset = off
        t = self._totals = dict(series=0, points=0, n_kept=0,
                                stored_nbytes=0, raw_nbytes=0)
        for e in self._series.values():   # one O(series) pass at open
            C = int(e.get("channels", 1))
            t["series"] += 1
            t["points"] += e["n"] * C
            t["n_kept"] += e["n_kept"] * C
            t["stored_nbytes"] += e["stored_nbytes"]
            t["raw_nbytes"] += 8 * e["n"] * C

    # -- ingest -------------------------------------------------------------

    def _check_mvar_writable(self):
        """Validate (without touching the file) that this store's format
        can hold multivariate series."""
        if self.version < 3:
            raise ValueError(
                "multivariate series need a v3+ store (the v2 compat "
                "format is univariate-only)")

    def _require_mvar(self):
        """Flip the file format to v4 at the first multivariate block.

        Files that only ever hold univariate series keep the v3 magic and
        stay bit-identical to pre-v4 writers; the upgrade (head magic
        rewritten in place, footer magic follows ``self.version``) happens
        exactly when the first multivariate block is written.  Ordering
        matters for crash safety: any stale footer is truncated *before*
        the head magic flips, so a crash mid-upgrade leaves a file that is
        already recognizably mid-write (no footer) — never an intact v3
        footer behind a v4 head, which ``_load_footer``'s tail==head check
        would refuse even though the old catalog was still good.
        """
        self._check_mvar_writable()
        if self.version < 4:
            self._ensure_appendable()
            self.version = 4
            self._f.seek(0)
            self._f.write(_MAGICS[4])

    @property
    def _block_meta_version(self) -> int:
        """Univariate block layout version (v4 files still write v3
        univariate block bodies — v4 only adds the multivariate layout)."""
        return min(self.version, 3)

    def _mvar_body(self, kept_idx, kept_vals, *, t0: int, t1: int,
                   is_last: bool, dtype: str, cfg, x64, x_off: int = 0):
        """Encode one multivariate block: per-column canonical
        reconstructions over the owned range + optional residual moments.
        Shared by ``append_series`` and ``StreamSession`` so streamed and
        one-shot multivariate files stay byte-identical."""
        self._require_mvar()
        o1 = t1 + 1 if is_last else t1
        C = kept_vals.shape[1]
        owned = np.stack(
            [reconstruct_block(kept_idx - t0,
                               np.ascontiguousarray(kept_vals[:, c]),
                               t1 - t0 + 1, dtype)[:o1 - t0]
             for c in range(C)], axis=1)
        resid = None if x64 is None else x64[t0 - x_off:o1 - x_off] - owned
        return build_mblock(
            kept_idx, kept_vals, t0=t0, t1=t1, is_last=is_last,
            owned_xr=owned, L=cfg.lags, kappa=cfg.kappa, stat=cfg.stat,
            eps=cfg.eps, resid=resid, value_codec=self.value_codec,
            entropy=self.entropy)

    def _append_multivariate(self, sid: str, res, cfg, X=None) -> dict:
        """Write one multivariate series (see ``append_series``)."""
        kept = np.asarray(res.kept)
        xr = np.asarray(res.xr)
        n, C = xr.shape
        self._check_mvar_writable()
        kept_idx = np.nonzero(kept)[0].astype(np.int64)
        kept_vals = np.ascontiguousarray(xr[kept_idx])
        x64 = None if X is None else np.asarray(X, np.float64)[:n]
        bounds = plan_block_bounds(kept_idx, self.block_len, cfg.lags)
        devs = np.asarray(getattr(res, "deviations",
                                  np.full(C, float(res.deviation))),
                          np.float64)

        blocks: List[dict] = []
        nbytes = payload_nbytes = meta_nbytes = meta_raw_nbytes = 0
        for bi in range(len(bounds) - 1):
            t0, t1 = bounds[bi], bounds[bi + 1]
            is_last = bi == len(bounds) - 2
            sel = (kept_idx >= t0) & (kept_idx <= t1)
            body, binfo = self._mvar_body(
                kept_idx[sel], kept_vals[sel], t0=t0, t1=t1,
                is_last=is_last, dtype=str(xr.dtype), cfg=cfg, x64=x64)
            off = self._append_body(body)
            nbytes += 4 + len(body)
            payload_nbytes += binfo["payload_nbytes"]
            meta_nbytes += binfo["meta_nbytes"]
            meta_raw_nbytes += binfo["meta_raw_nbytes"]
            blocks.append(dict(offset=off, nbytes=len(body), t0=t0, t1=t1))
        self._f.flush()
        entry = dict(
            n=n, n_kept=int(kept_idx.shape[0]), dtype=str(xr.dtype),
            eps=float(cfg.eps), stat=cfg.stat, lags=int(cfg.lags),
            kappa=int(cfg.kappa), deviation=float(res.deviation),
            value_codec=self.value_codec, stored_nbytes=nbytes,
            payload_nbytes=payload_nbytes,
            meta_nbytes=meta_nbytes, meta_raw_nbytes=meta_raw_nbytes,
            has_resid=x64 is not None, channels=C,
            deviations=[float(d) for d in devs], blocks=blocks)
        self._series[sid] = entry
        self._bump_totals(series=1, points=n * C,
                          n_kept=entry["n_kept"] * C, stored=nbytes)
        self._cache.invalidate(sid)
        for key in [k for k in self._metas if k[0] == sid]:
            del self._metas[key]
        return entry

    def append_series(self, sid: str, res, cfg, x=None) -> dict:
        """Write one compressed series.

        ``res`` is a ``CompressResult`` (anything with ``.kept`` / ``.xr``
        works), ``cfg`` the ``CameoConfig`` it was produced under, and ``x``
        optionally the *original* series — when given, per-block residual
        moments are stored and pushdown value aggregates carry deterministic
        error bounds **vs the original** (otherwise vs the reconstruction).
        Returns the catalog entry (byte sizes, per-block extents).  Any
        cached decoded blocks for ``sid`` are invalidated.

        The stored reconstruction is the *canonical* one-shot interpolation
        of the kept points (the paper's §4.1 decompression), computed here
        per block so the write-time metadata is self-consistent with every
        future decode.  For the rounds mode this is bit-identical to
        ``res.xr``; the sequential mode's ``xr`` is accumulated incrementally
        during compression, so its dead positions can differ from the
        canonical interpolation in the last ulp — kept points are bit-exact
        either way.
        """
        if not self._writable:
            raise IOError("store opened read-only")
        if sid in self._series:
            raise ValueError(f"series {sid!r} already stored")
        kept = np.asarray(res.kept)
        xr = np.asarray(res.xr)
        if xr.ndim == 2:
            return self._append_multivariate(sid, res, cfg, X=x)
        n = int(kept.shape[0])
        kept_idx = np.nonzero(kept)[0].astype(np.int64)
        x64 = None if x is None else np.asarray(x, np.float64)[:n]
        bounds = plan_block_bounds(kept_idx, self.block_len, cfg.lags)

        blocks: List[dict] = []
        nbytes = payload_nbytes = meta_nbytes = meta_raw_nbytes = 0
        for bi in range(len(bounds) - 1):
            t0, t1 = bounds[bi], bounds[bi + 1]
            is_last = bi == len(bounds) - 2
            o1 = t1 + 1 if is_last else t1
            sel = (kept_idx >= t0) & (kept_idx <= t1)
            bidx, bvals = kept_idx[sel], xr[kept_idx[sel]]
            owned_xr = reconstruct_block(
                bidx - t0, bvals, t1 - t0 + 1, str(xr.dtype))[:o1 - t0]
            body, binfo = build_block(
                bidx, bvals, t0=t0, t1=t1,
                is_last=is_last, owned_xr=owned_xr,
                L=cfg.lags, kappa=cfg.kappa, stat=cfg.stat, eps=cfg.eps,
                resid=None if x64 is None else x64[t0:o1] - owned_xr,
                value_codec=self.value_codec, entropy=self.entropy,
                meta_version=self._block_meta_version)
            off = self._append_body(body)
            nbytes += 4 + len(body)
            payload_nbytes += binfo["payload_nbytes"]
            meta_nbytes += binfo["meta_nbytes"]
            meta_raw_nbytes += binfo["meta_raw_nbytes"]
            blocks.append(dict(offset=off, nbytes=len(body), t0=t0, t1=t1))
        self._f.flush()
        entry = dict(
            n=n, n_kept=int(kept_idx.shape[0]), dtype=str(xr.dtype),
            eps=float(cfg.eps), stat=cfg.stat, lags=int(cfg.lags),
            kappa=int(cfg.kappa), deviation=float(res.deviation),
            value_codec=self.value_codec, stored_nbytes=nbytes,
            payload_nbytes=payload_nbytes,
            meta_nbytes=meta_nbytes, meta_raw_nbytes=meta_raw_nbytes,
            has_resid=x64 is not None, blocks=blocks)
        self._series[sid] = entry
        self._bump_totals(series=1, points=n, n_kept=entry["n_kept"],
                          stored=nbytes)
        self._cache.invalidate(sid)
        for key in [k for k in self._metas if k[0] == sid]:
            del self._metas[key]
        return entry

    def open_stream(self, sid: str, cfg, *, dtype: str = None,
                    with_resid: bool = True, channels: int = 1,
                    resume: bool = False,
                    block_len: int = None) -> "StreamSession":
        """Open a streaming append session for one series.

        The session absorbs closed stream windows (``StreamSession.append``
        / ``append_window``) and writes blocks incrementally; the series is
        queryable over its written prefix the whole time and finalizes on
        ``StreamSession.close``.  With ``resume=True`` the session continues
        an incomplete stream from the state stashed in the footer by a
        previous ``flush()``/store close (open the store with ``mode="a"``).

        ``with_resid`` stores Plato-style residual moments (the appended
        windows then carry the original points, which they do by
        construction).  The finalized series — blocks, offsets, catalog
        entry — is byte-identical to a one-shot ``append_series`` of the
        same kept points.

        ``block_len`` overrides the store-wide block length for this
        session only (the ingest server seals small low-latency blocks per
        stream and lets the compaction worker rewrite them to full size
        later — see ``store/maintenance.py``).  The override rides along in
        the resume stash, so a resumed session keeps sealing at the same
        length.
        """
        if not self._writable:
            raise IOError("store opened read-only")
        if resume:
            entry = self._series.get(sid)
            if entry is None or not entry.get("streaming"):
                raise ValueError(
                    f"series {sid!r} has no incomplete stream to resume")
            if sid in self._streams:
                raise ValueError(f"series {sid!r} already has an open "
                                 "stream session")
            # validate before consuming the stash: a failed resume attempt
            # (wrong cfg) must leave the stream resumable with the right one
            for key, want in (("eps", float(cfg.eps)), ("stat", cfg.stat),
                              ("lags", int(cfg.lags)),
                              ("kappa", int(cfg.kappa))):
                if entry[key] != want:
                    raise ValueError(
                        f"series {sid!r}: resume cfg mismatch on {key}: "
                        f"stored {entry[key]!r} vs {want!r}")
            stash = entry.pop("stream_state", None)
            if stash is None:
                raise ValueError(
                    f"series {sid!r}: no stream state stashed — the "
                    "previous writer crashed before flush()/close")
            sess = StreamSession(self, sid, cfg, dtype=stash["dtype"],
                                 with_resid=stash["with_resid"],
                                 entry=entry, stash=stash,
                                 block_len=block_len)
        else:
            if sid in self._series:
                raise ValueError(f"series {sid!r} already stored")
            dtype = dtype or getattr(cfg, "dtype", "float64")
            entry = dict(
                n=0, n_kept=0, dtype=str(np.dtype(dtype)),
                eps=float(cfg.eps), stat=cfg.stat, lags=int(cfg.lags),
                kappa=int(cfg.kappa), deviation=0.0,
                value_codec=self.value_codec, stored_nbytes=0,
                payload_nbytes=0, meta_nbytes=0, meta_raw_nbytes=0,
                has_resid=bool(with_resid), blocks=[], streaming=True)
            if int(channels) > 1:
                # validate only — the v4 magic flips at the first
                # multivariate block write, so a crash between open and
                # the first block leaves the old footer fully readable
                self._check_mvar_writable()
                entry["channels"] = int(channels)
                entry["deviations"] = [0.0] * int(channels)
            self._series[sid] = entry
            self._bump_totals(series=1)
            sess = StreamSession(self, sid, cfg, dtype=entry["dtype"],
                                 with_resid=with_resid, entry=entry,
                                 block_len=block_len)
        self._streams[sid] = sess
        return sess

    # -- catalog ------------------------------------------------------------

    def series_ids(self) -> List[str]:
        return list(self._series)

    def series_meta(self, sid: str) -> dict:
        return self._series[sid]

    def __contains__(self, sid: str) -> bool:
        return sid in self._series

    # -- block access -------------------------------------------------------

    def _finish_body(self, blk: dict, raw: bytes) -> bytes:
        """Tier accounting + cold-tier unwrap of one fetched body.

        Catalog entries of cold blocks carry a ``"wrap"`` key naming the
        entropy codec their on-disk body is wrapped in (see
        ``store/maintenance.py``); the unwrap reproduces the original
        length-prefixed body — crc and all — so every downstream parse and
        answer is byte-identical across tiers."""
        t = self._tier_counts
        wrap = blk.get("wrap")
        if wrap is None:
            t["warm_hits"] += 1
            t["warm_bytes"] += len(raw)
            return raw
        t["cold_hits"] += 1
        t["cold_bytes"] += len(raw)
        if OBS.enabled:
            OBS.inc("store.tier.cold.hits")
            OBS.inc("store.tier.cold.bytes", len(raw))
        return _codec.entropy_unwrap(bytes(raw), wrap)

    def _read_body(self, blk: dict) -> bytes:
        mm = self._mmap()
        if mm is not None:
            off = blk["offset"]
            blen, = struct.unpack_from("<I", mm, off)
            if OBS.enabled:
                OBS.inc("store.read.mmap_bytes", 4 + blen)
                OBS.inc("store.read.blocks_fetched")
            return self._finish_body(blk, mm[off + 4:off + 4 + blen])
        self._f.seek(blk["offset"])
        blen, = struct.unpack("<I", self._f.read(4))
        if OBS.enabled:
            OBS.inc("store.read.pread_bytes", 4 + blen)
            OBS.inc("store.read.blocks_fetched")
        return self._finish_body(blk, self._f.read(blen))

    def _read_bodies(self, blks: List[dict]) -> List[bytes]:
        """One body per catalog entry; blocks that sit contiguously in the
        file are fetched with a single seek+read instead of one pread per
        block (multi-block windows of an uninterleaved series are one IO).
        With an mmap attached every body is a page-cache slice — no
        syscalls at all, so no coalescing is needed."""
        if self._mmap() is not None:
            return [self._read_body(b) for b in blks]
        out: List[bytes] = []
        i = 0
        while i < len(blks):
            j = i
            end = blks[j]["offset"] + 4 + blks[j]["nbytes"]
            while j + 1 < len(blks) and blks[j + 1]["offset"] == end:
                j += 1
                end = blks[j]["offset"] + 4 + blks[j]["nbytes"]
            self._f.seek(blks[i]["offset"])
            buf = self._f.read(end - blks[i]["offset"])
            if OBS.enabled:
                OBS.inc("store.read.coalesced_runs")
                OBS.inc("store.read.pread_bytes", len(buf))
                OBS.inc("store.read.blocks_fetched", j - i + 1)
            pos = 0
            for k in range(i, j + 1):
                blen, = struct.unpack_from("<I", buf, pos)
                out.append(self._finish_body(blks[k],
                                             buf[pos + 4:pos + 4 + blen]))
                pos += 4 + blen
            i = j + 1
        return out

    def channels(self, sid: str) -> int:
        """Number of value columns (1 for univariate series)."""
        return int(self._series[sid].get("channels", 1))

    def _parse(self, sid: str):
        """Body parser for this series' block layout (v4 multivariate
        blocks vs the univariate v2/v3 layout)."""
        return parse_mblock if self.channels(sid) > 1 else parse_block

    def block_meta(self, sid: str, bi: int) -> BlockMeta:
        """Header metadata of one block (no bitstream decode) — cached, so
        repeated pushdown queries never re-read interior blocks.  For a
        multivariate series this is an ``MBlockMeta``; project one column
        with ``.col(c)``."""
        key = (sid, bi)
        meta = self._metas.get(key)
        if meta is None:
            blk = self._series[sid]["blocks"][bi]
            meta, _, _ = self._parse(sid)(self._read_body(blk),
                                          with_payload=False)
            self._metas[key] = meta
        return meta

    def block_metas(self, sid: str) -> List[BlockMeta]:
        """Header-only metadata of every block of a series; uncached
        headers are fetched with coalesced preads."""
        blks = self._series[sid]["blocks"]
        parse = self._parse(sid)
        missing = [bi for bi in range(len(blks))
                   if (sid, bi) not in self._metas]
        if missing:
            bodies = self._read_bodies([blks[bi] for bi in missing])
            for bi, body in zip(missing, bodies):
                meta, _, _ = parse(body, with_payload=False)
                self._metas[(sid, bi)] = meta
        return [self._metas[(sid, bi)] for bi in range(len(blks))]

    def _blocks(self, sid: str, bis: List[int]) -> List[list]:
        """Decoded cache entries for several blocks of one series; misses
        are fetched with coalesced preads and decoded in file order."""
        entries = {}
        misses = []
        for bi in bis:
            e = self._cache.get((sid, bi))
            if e is None:
                misses.append(bi)
            else:
                entries[bi] = e
        if misses:
            blks = self._series[sid]["blocks"]
            parse = self._parse(sid)
            bodies = self._read_bodies([blks[bi] for bi in misses])
            for bi, body in zip(misses, bodies):
                meta, idx, vals = parse(body)
                pmeta = (meta.sxx.nbytes if hasattr(meta, "sxx")
                         else meta.agg.nbytes)
                e = [meta, idx, vals, None,
                     idx.nbytes + vals.nbytes + pmeta
                     + meta.head_vec.nbytes + meta.tail_vec.nbytes + 256]
                self._cache.put((sid, bi), e)
                self._metas[(sid, bi)] = meta
                entries[bi] = e
        return [entries[bi] for bi in bis]

    def _block(self, sid: str, bi: int):
        """Decoded block (meta, global kept indices, values) — cached."""
        e = self._blocks(sid, [bi])[0]
        return e[_E_META], e[_E_IDX], e[_E_VALS]

    def prefetch(self, sid: str, a: int = 0, b: int = None) -> List[int]:
        """Decode the blocks overlapping ``[a, b)`` into the hot-tier LRU
        (coalesced fetches, same as a window read would) without
        materializing the window; returns the warmed block indices."""
        entry = self._series[sid]
        if b is None:
            b = entry["n"]
        bis = self._overlapping(sid, int(a), int(b))
        self._blocks(sid, bis)
        return bis

    def _overlapping(self, sid: str, a: int, b: int):
        """Indices of blocks whose *owned* range intersects [a, b).  While a
        stream session is still appending, no block owns its right border —
        the final point arrives with the closing block."""
        entry = self._series[sid]
        streaming = bool(entry.get("streaming"))
        out = []
        for bi, blk in enumerate(entry["blocks"]):
            is_last = bi == len(entry["blocks"]) - 1 and not streaming
            o1 = blk["t1"] + 1 if is_last else blk["t1"]
            if blk["t0"] < b and o1 > a:
                out.append(bi)
        return out

    # -- reads --------------------------------------------------------------

    def read_kept(self, sid: str):
        """(indices, values) of the stored kept points over the readable
        range ``[0, n)`` — for a still-streaming series that excludes the
        last block's right border (it reappears as the next block's first
        point when the stream continues).  Multivariate values come back
        ``[k, C]`` (the shared index stream is one array either way)."""
        entry = self._series[sid]
        dtype = np.dtype(entry["dtype"])
        C = int(entry.get("channels", 1))
        nb = len(entry["blocks"])
        if nb == 0:      # streaming series before its first block commits
            return (np.empty(0, np.int64),
                    np.empty(0 if C == 1 else (0, C), dtype))
        idx_parts, val_parts = [], []
        streaming = bool(entry.get("streaming"))
        for bi, e in enumerate(self._blocks(sid, list(range(nb)))):
            idx, vals = e[_E_IDX], e[_E_VALS]
            if bi < nb - 1 or streaming:   # shared border belongs to next
                idx, vals = idx[:-1], vals[:-1]
            idx_parts.append(idx)
            val_parts.append(vals)
        return (np.concatenate(idx_parts),
                np.concatenate(val_parts).astype(dtype))

    def kept_mask(self, sid: str) -> np.ndarray:
        mask = np.zeros(self._series[sid]["n"], bool)
        mask[self.read_kept(sid)[0]] = True
        return mask

    def read_window(self, sid: str, a: int, b: int,
                    col: int = None) -> np.ndarray:
        """Reconstruction slice ``xr[a:b]``, decoding only the blocks whose
        range overlaps the window.  Bit-exact vs the full reconstruction.
        Per-block reconstructions are attached to the LRU entries, so a hot
        window skips pread, bitstream decode *and* interpolation.

        For a multivariate series the slice is ``[b-a, C]``; ``col``
        selects a single column (``[b-a]``).  All columns of a touched
        block are reconstructed and cached together — a per-column query
        loop pays the interpolation once."""
        entry = self._series[sid]
        n = entry["n"]
        C = int(entry.get("channels", 1))
        if col is not None and not (0 <= int(col) < C):
            raise ValueError(f"column {col} outside [0, {C}) for {sid!r}")
        a, b = max(int(a), 0), min(int(b), n)
        dtype = np.dtype(entry["dtype"])
        if b <= a:
            return np.empty((0,) if C == 1 or col is not None else (0, C),
                            dtype)
        out = np.empty((b - a,) if C == 1 else (b - a, C), dtype)
        bis = self._overlapping(sid, a, b)
        for bi, e in zip(bis, self._blocks(sid, bis)):
            meta, xr_b = e[_E_META], e[_E_XR]
            if xr_b is None:
                if C == 1:
                    xr_b = reconstruct_block(
                        e[_E_IDX] - meta.t0, e[_E_VALS], meta.span,
                        str(dtype))
                else:
                    xr_b = np.stack(
                        [reconstruct_block(
                            e[_E_IDX] - meta.t0,
                            np.ascontiguousarray(e[_E_VALS][:, c]),
                            meta.span, str(dtype)) for c in range(C)],
                        axis=1)
                e[_E_XR] = xr_b
                self._cache.grow((sid, bi), xr_b.nbytes)
            lo, hi = max(a, meta.o0), min(b, meta.o1)
            out[lo - a:hi - a] = xr_b[lo - meta.t0:hi - meta.t0]
        if col is not None and C > 1:
            return np.ascontiguousarray(out[:, col])
        return out

    def read_series(self, sid: str, col: int = None) -> np.ndarray:
        """Whole-series reconstruction (bit-exact vs ``CompressResult.xr``;
        ``[n, C]`` for multivariate series, ``col`` selects one column)."""
        return self.read_window(sid, 0, self._series[sid]["n"], col=col)

    # -- accounting ---------------------------------------------------------

    def cache_stats(self) -> dict:
        """Decoded-block LRU counters (hits/misses/evictions/bytes)."""
        return self._cache.stats()

    def tier_stats(self) -> dict:
        """Per-tier read counters.  ``hot`` is the decoded-block LRU (a hit
        never touches the file), ``warm`` counts plain body fetches from
        mmap/pread, ``cold`` counts entropy-wrapped body fetches (bytes are
        the wrapped on-disk sizes); ``dead_nbytes`` is the file space
        orphaned by compaction / tier rewrites (reclaimable by a copying
        rewrite of the store)."""
        c = self._cache
        t = self._tier_counts
        return dict(
            hot=dict(hits=c.hits, misses=c.misses, nbytes=c.nbytes,
                     pinned=len(c._pinned)),
            warm=dict(hits=t["warm_hits"], nbytes=t["warm_bytes"]),
            cold=dict(hits=t["cold_hits"], nbytes=t["cold_bytes"]),
            dead_nbytes=self._dead_nbytes)

    def ingest_totals(self) -> dict:
        """O(1) running ingest totals across every stored series.

        ``points``/``n_kept`` are channel-expanded (``n * C``) and
        ``raw_nbytes`` is 8 bytes/point, matching the per-series
        ``compression_stats`` conventions; still-streaming series count
        their committed (readable) prefix.  Maintained incrementally on
        every append/stream emit and rebuilt in one O(series) pass at
        open — this is what ``Dataset.stats()`` and
        ``TimeSeriesService.stats()`` serve instead of walking
        ``compression_stats`` per poll (pass ``deep=True`` there for
        the exhaustive walk)."""
        return dict(self._totals)

    def compression_stats(self, sid: str) -> dict:
        """Point-count CR vs byte-true CRs for one stored series.

        ``bytes_cr`` divides by the physical file bytes (codec payloads +
        block headers with their compacted ``[5, L]`` pushdown metadata);
        ``codec_cr`` divides by the codec payloads alone (the
        Table-2-comparable number).  ``meta_nbytes`` / ``meta_raw_nbytes``
        expose what the shuffle+delta coding saved on header metadata.
        """
        e = self._series[sid]
        C = int(e.get("channels", 1))
        raw_nbytes = 8 * e["n"] * C
        payload = e.get("payload_nbytes", e["stored_nbytes"])
        return dict(
            n=e["n"], n_kept=e["n_kept"], channels=C,
            point_cr=e["n"] / max(e["n_kept"], 1),
            stored_nbytes=e["stored_nbytes"],
            payload_nbytes=payload,
            meta_nbytes=e.get("meta_nbytes", 0),
            meta_raw_nbytes=e.get("meta_raw_nbytes", 0),
            bytes_cr=raw_nbytes / max(e["stored_nbytes"], 1),
            codec_cr=raw_nbytes / max(payload, 1),
            raw_nbytes=raw_nbytes)


class StreamSession:
    """Streaming append session for one series (see ``open_stream``).

    Feed it contiguous stream windows — ``append(start, x, kept)`` or
    ``append_window(w)`` with a ``core/streaming.WindowResult`` — and it
    writes a block the moment the incremental planner can prove the
    block's right border matches what ``plan_block_bounds`` would pick on
    the full kept set: a border ``t1`` (the first kept point
    ``>= t0 + block_len``) commits once some kept point ``>= t1 + L``
    has been seen, which rules the tail-merge clamp out.  ``close()``
    plans the remaining tail with the full rule and finalizes the catalog
    entry; the result is byte-identical to the one-shot path.

    Freshly written blocks get *per-block* cache invalidation (they are
    new keys — existing cached blocks of the series stay valid, unlike
    ``append_series``'s wholesale invalidation of a replaced series).

    State held: the kept points past the last committed border, the raw
    originals over the same span (residual metadata), and the contiguity
    cursor — O(block_len + window).  ``_stash()`` round-trips all of it
    (plus an opaque ``state_provider()`` client blob) through the footer
    JSON bit-exactly for ``resume``.
    """

    def __init__(self, store: CameoStore, sid: str, cfg, *, dtype: str,
                 with_resid: bool, entry: dict, stash: dict = None,
                 block_len: int = None):
        self._store = store
        self.sid = sid
        self.cfg = cfg
        self.dtype = np.dtype(dtype)
        self.with_resid = bool(with_resid)
        self._entry = entry
        self.channels = int(entry.get("channels", 1))
        # a stashed override wins over the argument: the session must keep
        # planning the same borders it was planning before the resume
        if stash is not None and stash.get("block_len") is not None:
            block_len = stash["block_len"]
        self._block_len_override = None if not block_len else int(block_len)
        self._block_len = max(
            int(self._block_len_override or store.block_len), int(cfg.lags))
        self._closed = False
        self.state_provider = None        # callable -> JSON-safe blob
        self.restored_client_state = None
        # pending value/original buffers are [k] univariate, [k, C] mvar
        vshape = (0,) if self.channels == 1 else (0, self.channels)
        # pending state: consolidated arrays + unconsolidated append parts
        # (appends go to the lists; concatenation is deferred until a block
        # border is actually provable, so tiny-chunk feeds stay O(1)
        # amortized instead of re-copying the pending buffers every push)
        self._idx_parts: List[np.ndarray] = []
        self._val_parts: List[np.ndarray] = []
        self._x_parts: List[np.ndarray] = []
        if stash is None:
            self._kept_idx = np.empty(0, np.int64)
            self._kept_vals = np.empty(vshape, self.dtype)
            self._x = np.empty(vshape, np.float64)
            self._x_off = 0          # absolute index of _x[0]
            self._next = None        # expected start of the next append
            self._bound = None       # last committed block border
            self._committed = 0      # kept points strictly inside coverage
            self._total_kept = 0     # unique kept points seen
        else:
            self._kept_idx = np.asarray(stash["kept_idx"], np.int64)
            self._kept_vals = np.asarray(
                stash["kept_vals"],
                np.float64).reshape(-1, *vshape[1:]).astype(self.dtype)
            self._x = np.asarray(stash["x"],
                                 np.float64).reshape(-1, *vshape[1:])
            self._x_off = int(stash["x_off"])
            self._next = None if stash["next"] is None else int(stash["next"])
            self._bound = (None if stash["bound"] is None
                           else int(stash["bound"]))
            self._committed = int(stash["committed"])
            self._total_kept = int(stash["total_kept"])
            self.restored_client_state = stash.get("client")
        self._first_kept = (int(self._kept_idx[0])
                            if self._kept_idx.shape[0] else None)
        self._last_kept = (int(self._kept_idx[-1])
                           if self._kept_idx.shape[0] else None)

    # -- lifecycle -----------------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        # finalize only on clean exit: an exception mid-feed must leave the
        # stream incomplete (and hence resumable), not truncate it into a
        # series that claims to be whole
        if exc[0] is None and not self._closed:
            self.close()

    def flush(self):
        """Make the ingested prefix durable (rewrites the store footer,
        embedding this session's resume state)."""
        self._store.flush()

    # -- ingest --------------------------------------------------------------

    def append_window(self, w) -> None:
        """Absorb one closed stream window (``core/streaming.WindowResult``
        or anything with ``.start``, ``.x``, ``.kept``)."""
        self.append(w.start, w.x, w.kept)

    def append_windows(self, wins) -> None:
        """Absorb a burst of closed stream windows (a batched-ingest drain)
        with one border scan for the whole burst: every window buffers
        first, then every provable block commits.  Bytes are identical to
        appending the windows one at a time — the committed borders depend
        only on the accumulated kept set, not on the call pattern."""
        for w in wins:
            self._absorb(w.start, w.x, w.kept)
        self._commit_ready()

    def append(self, start: int, x, kept) -> None:
        """Absorb the contiguous window ``x`` at absolute index ``start``
        with its kept mask; writes every block whose border is provable."""
        self._absorb(start, x, kept)
        self._commit_ready()

    def _absorb(self, start: int, x, kept) -> None:
        if self._closed:
            raise ValueError(f"stream session for {self.sid!r} is closed")
        x = np.asarray(x)
        kept = np.asarray(kept, bool)
        if self.channels == 1:
            if x.shape != kept.shape or x.ndim != 1:
                raise ValueError(f"window shapes disagree: x {x.shape} vs "
                                 f"kept {kept.shape}")
        elif (x.ndim != 2 or x.shape[1] != self.channels
                or kept.shape != x.shape[:1]):
            raise ValueError(
                f"multivariate window wants x [m, {self.channels}] and "
                f"kept [m]; got x {x.shape}, kept {kept.shape}")
        if self._next is not None and int(start) != self._next:
            raise ValueError(f"non-contiguous append: expected index "
                             f"{self._next}, got {start}")
        if self._next is None:
            self._x_off = int(start)
        self._next = int(start) + x.shape[0]
        idx = int(start) + np.flatnonzero(kept)
        if idx.shape[0]:
            self._idx_parts.append(idx)
            self._val_parts.append(x[kept].astype(self.dtype))
            if self._first_kept is None:
                self._first_kept = int(idx[0])
            self._last_kept = int(idx[-1])
            self._total_kept += int(idx.shape[0])
        if self.with_resid:
            self._x_parts.append(np.asarray(x, np.float64))

    def _consolidate(self) -> None:
        if self._idx_parts:
            self._kept_idx = np.concatenate(
                [self._kept_idx] + self._idx_parts)
            self._kept_vals = np.concatenate(
                [self._kept_vals] + self._val_parts)
            self._idx_parts, self._val_parts = [], []
        if self._x_parts:
            self._x = np.concatenate([self._x] + self._x_parts)
            self._x_parts = []

    def _commit_ready(self) -> None:
        L = int(self.cfg.lags)
        t0 = self._first_kept if self._bound is None else self._bound
        if (self._last_kept is None or t0 is None
                or self._last_kept < t0 + self._block_len + L):
            return        # no border provable yet; keep buffering parts
        self._consolidate()
        while True:
            kept = self._kept_idx
            if kept.shape[0] == 0:
                return
            t0 = int(kept[0]) if self._bound is None else self._bound
            j = int(np.searchsorted(kept, t0 + self._block_len, "left"))
            if j >= kept.shape[0]:
                return
            t1 = int(kept[j])
            if int(kept[-1]) < t1 + L:
                return        # tail-merge clamp not ruled out yet
            self._emit(j, t1, is_last=False)

    def _emit(self, j: int, t1: int, is_last: bool) -> None:
        kept, vals = self._kept_idx, self._kept_vals
        if not is_last:
            kept, vals = kept[:j + 1], vals[:j + 1]
        t0 = int(kept[0])
        o1 = t1 + 1 if is_last else t1
        cfg = self.cfg
        store = self._store
        if self.channels > 1:
            body, binfo = store._mvar_body(
                kept, vals, t0=t0, t1=t1, is_last=is_last,
                dtype=str(self.dtype), cfg=cfg,
                x64=self._x if self.with_resid else None,
                x_off=self._x_off)
        else:
            owned_xr = reconstruct_block(kept - t0, vals, t1 - t0 + 1,
                                         str(self.dtype))[:o1 - t0]
            resid = None
            if self.with_resid:
                resid = (self._x[t0 - self._x_off:o1 - self._x_off]
                         - owned_xr)
            body, binfo = build_block(
                kept, vals, t0=t0, t1=t1, is_last=is_last,
                owned_xr=owned_xr, L=cfg.lags, kappa=cfg.kappa,
                stat=cfg.stat, eps=cfg.eps, resid=resid,
                value_codec=store.value_codec, entropy=store.entropy,
                meta_version=store._block_meta_version)
        off = store._append_body(body)
        e = self._entry
        old_n, old_kept = e["n"], e["n_kept"]
        bi = len(e["blocks"])
        e["blocks"].append(dict(offset=off, nbytes=len(body), t0=t0, t1=t1))
        e["stored_nbytes"] += 4 + len(body)
        e["payload_nbytes"] += binfo["payload_nbytes"]
        e["meta_nbytes"] += binfo["meta_nbytes"]
        e["meta_raw_nbytes"] += binfo["meta_raw_nbytes"]
        # per-append invalidation: only the new block's (never-yet-cached)
        # key — previously decoded blocks of this series stay valid
        store._cache.drop((self.sid, bi))
        store._metas.pop((self.sid, bi), None)
        if is_last:
            self._committed = self._total_kept
            self._kept_idx = self._kept_idx[:0]
            self._kept_vals = self._kept_vals[:0]
            self._x = self._x[:0]
            e["n"] = t1 + 1
        else:
            self._committed += j
            self._kept_idx = self._kept_idx[j:]
            self._kept_vals = self._kept_vals[j:]
            if self.with_resid:
                self._x = self._x[t1 - self._x_off:]
            self._x_off = t1
            self._bound = t1
            e["n"] = t1
        e["n_kept"] = self._committed
        C = self.channels
        store._bump_totals(points=(e["n"] - old_n) * C,
                           n_kept=(e["n_kept"] - old_kept) * C,
                           stored=4 + len(body))

    # -- finalize ------------------------------------------------------------

    def close(self, deviation: float = 0.0, deviations=None) -> dict:
        """Write the tail blocks (full ``plan_block_bounds`` rule, the last
        one owning the stream's end point), finalize the catalog entry to
        the exact one-shot form, and return it.  ``deviation`` is recorded
        in the catalog (the serving layer passes the streaming compressor's
        exact measured global deviation); multivariate sessions also record
        the per-column ``deviations``."""
        if self._closed:
            raise ValueError(f"stream session for {self.sid!r} already "
                             "closed")
        if self._total_kept < 2:
            raise ValueError("a stored series needs at least 2 kept points")
        self._consolidate()
        # tail planning is the planner itself, not a re-implementation: the
        # pending kept set starts at the last committed border (or the first
        # kept point), and the greedy rule only ever looks forward, so
        # planning the suffix reproduces the whole-series plan's tail —
        # which is what keeps streamed files byte-identical to one-shot
        bounds = plan_block_bounds(self._kept_idx, self._block_len,
                                   int(self.cfg.lags))
        last = int(bounds[-1])
        for bi in range(len(bounds) - 1):
            t1 = int(bounds[bi + 1])
            j = int(np.searchsorted(self._kept_idx, t1, "left"))
            self._emit(j, t1, is_last=(bi == len(bounds) - 2))
        # the finalized blocks are durable before the catalog entry that
        # publishes them can be (CAMEO_FSYNC=0 keeps just the write order)
        _wal.maybe_fsync(self._store._f)
        e = self._entry
        e["n"] = last + 1
        e["n_kept"] = self._total_kept
        e["deviation"] = float(deviation)
        if self.channels > 1:
            e["deviations"] = [float(d) for d in (
                deviations if deviations is not None
                else [deviation] * self.channels)]
        e.pop("streaming", None)
        e.pop("stream_state", None)
        # canonical key order — the finalized entry (hence the final footer
        # bytes) must match append_series's one-shot form exactly
        keys = ("n", "n_kept", "dtype", "eps", "stat", "lags", "kappa",
                "deviation", "value_codec", "stored_nbytes",
                "payload_nbytes", "meta_nbytes", "meta_raw_nbytes",
                "has_resid")
        keys += (("channels", "deviations", "blocks") if self.channels > 1
                 else ("blocks",))
        final = {k: e[k] for k in keys}
        self._entry = final
        self._store._series[self.sid] = final
        self._store._streams.pop(self.sid, None)
        self._closed = True
        return final

    # -- resume support ------------------------------------------------------

    def _stash(self) -> dict:
        """JSON-safe session state for the footer (floats round-trip via
        repr, so the resume is bit-exact)."""
        self._consolidate()
        return dict(
            dtype=str(self.dtype), with_resid=self.with_resid,
            block_len=self._block_len_override,
            bound=self._bound, next=self._next, x_off=self._x_off,
            committed=self._committed, total_kept=self._total_kept,
            kept_idx=[int(i) for i in self._kept_idx],
            kept_vals=np.asarray(self._kept_vals, np.float64).tolist(),
            x=np.asarray(self._x, np.float64).tolist(),
            client=(self.state_provider() if self.state_provider is not None
                    else None))
