"""CameoStore — the on-disk physical layer under the compressor.

File layout (append-oriented: blocks stream to disk as series are ingested,
the index is a footer written on ``close``)::

    magic "CAMEOST\\x01"
    [u32 body_len][block body + crc32] ...      (blocks, any series order)
    footer JSON (zlib)                           (series catalog)
    [u64 footer_offset][u32 footer_len][magic]

A crashed writer leaves a file without a footer; ``CameoStore.open`` refuses
it loudly rather than serving a partial catalog.  Reopening with
``mode="a"`` truncates the footer and keeps appending — restart-safe ingest
for the serving layer.

The reader serves random-access **window decodes** that touch only the
blocks overlapping the window (block borders are kept points, so no
interpolation segment crosses a block — see ``store/blocks.py``), plus
header-only block metadata for ``store/query.py``'s pushdown aggregates.

Roundtrip contract (tested property-style): for any compressed series,
``read_kept`` reproduces the kept mask and kept values bit-exactly, and
``read_series``/``read_window`` reproduce the canonical reconstruction —
the one-shot interpolation of the kept points — **bit-exactly**.  For the
rounds mode that canonical form *is* ``CompressResult.xr``; see
``append_series`` for the sequential mode's last-ulp caveat.  The store is
a lossless physical encoding of the compressor's lossy output.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Dict, List, Optional

import numpy as np

from repro.store import codec as _codec
from repro.store.blocks import (
    BlockMeta,
    build_block,
    parse_block,
    plan_block_bounds,
    reconstruct_block,
)

MAGIC = b"CAMEOST\x01"
_TAIL = struct.Struct("<QI")          # footer offset, footer byte length


class CameoStore:
    """One store file: append-oriented writer + random-access reader.

    Use :meth:`create` (new file), :meth:`open` (finalized file, read-only)
    or ``open(path, mode="a")`` (resume appending).  A store created in this
    process serves reads immediately from its in-memory catalog; a reopened
    store loads the catalog from the footer.
    """

    def __init__(self, path: str, mode: str, *, block_len: int = 4096,
                 value_codec: str = "gorilla", entropy: str = "auto"):
        if value_codec not in _codec.VALUE_CODECS:
            raise ValueError(f"unknown value codec {value_codec!r}")
        self.path = path
        self.block_len = int(block_len)
        self.value_codec = value_codec
        self.entropy = entropy
        self._series: Dict[str, dict] = {}   # sid -> catalog entry
        self._cache: Dict[tuple, tuple] = {}  # (sid, bi) -> (meta, idx, vals)
        self._metas: Dict[tuple, "BlockMeta"] = {}  # header-only cache
        self._writable = mode in ("w", "a")
        if mode == "w":
            self._f = open(path, "w+b")
            self._f.write(MAGIC)
        elif mode in ("r", "a"):
            self._f = open(path, "r+b" if mode == "a" else "rb")
            self._load_footer()
            if mode == "a":
                self._f.seek(self._footer_offset)
                self._f.truncate()
        else:
            raise ValueError(f"unknown mode {mode!r}")

    # -- constructors -------------------------------------------------------

    @classmethod
    def create(cls, path: str, *, block_len: int = 4096,
               value_codec: str = "gorilla",
               entropy: str = "auto") -> "CameoStore":
        return cls(path, "w", block_len=block_len, value_codec=value_codec,
                   entropy=entropy)

    @classmethod
    def open(cls, path: str, mode: str = "r") -> "CameoStore":
        return cls(path, mode)

    # -- context / lifecycle ------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        if self._f.closed:
            return
        if self._writable:
            self._write_footer()
        self._f.close()

    def _write_footer(self):
        off = self._f.seek(0, os.SEEK_END)
        footer = zlib.compress(json.dumps(
            {"block_len": self.block_len, "value_codec": self.value_codec,
             "entropy": self.entropy, "series": self._series},
            default=float).encode())
        self._f.write(footer)
        self._f.write(_TAIL.pack(off, len(footer)))
        self._f.write(MAGIC)
        self._f.flush()
        self._footer_offset = off

    def _load_footer(self):
        f = self._f
        if f.read(len(MAGIC)) != MAGIC:
            raise IOError(f"{self.path}: not a CameoStore file")
        end = f.seek(0, os.SEEK_END)
        tail_len = _TAIL.size + len(MAGIC)
        if end < len(MAGIC) + tail_len:
            raise IOError(f"{self.path}: truncated store (no footer)")
        f.seek(end - tail_len)
        tail = f.read(tail_len)
        if tail[-len(MAGIC):] != MAGIC:
            raise IOError(f"{self.path}: missing footer magic — the writer "
                          "crashed before close(); reingest or salvage "
                          "blocks manually")
        off, flen = _TAIL.unpack(tail[:_TAIL.size])
        f.seek(off)
        meta = json.loads(zlib.decompress(f.read(flen)).decode())
        self.block_len = int(meta.get("block_len", self.block_len))
        self.value_codec = meta.get("value_codec", self.value_codec)
        self.entropy = meta.get("entropy", self.entropy)
        self._series = meta["series"]
        self._footer_offset = off

    # -- ingest -------------------------------------------------------------

    def append_series(self, sid: str, res, cfg, x=None) -> dict:
        """Write one compressed series.

        ``res`` is a ``CompressResult`` (anything with ``.kept`` / ``.xr``
        works), ``cfg`` the ``CameoConfig`` it was produced under, and ``x``
        optionally the *original* series — when given, per-block residual
        moments are stored and pushdown value aggregates carry deterministic
        error bounds **vs the original** (otherwise vs the reconstruction).
        Returns the catalog entry (byte sizes, per-block extents).

        The stored reconstruction is the *canonical* one-shot interpolation
        of the kept points (the paper's §4.1 decompression), computed here
        per block so the write-time metadata is self-consistent with every
        future decode.  For the rounds mode this is bit-identical to
        ``res.xr``; the sequential mode's ``xr`` is accumulated incrementally
        during compression, so its dead positions can differ from the
        canonical interpolation in the last ulp — kept points are bit-exact
        either way.
        """
        if not self._writable:
            raise IOError("store opened read-only")
        if sid in self._series:
            raise ValueError(f"series {sid!r} already stored")
        kept = np.asarray(res.kept)
        xr = np.asarray(res.xr)
        n = int(kept.shape[0])
        kept_idx = np.nonzero(kept)[0].astype(np.int64)
        x64 = None if x is None else np.asarray(x, np.float64)[:n]
        bounds = plan_block_bounds(kept_idx, self.block_len, cfg.lags)

        blocks: List[dict] = []
        nbytes = payload_nbytes = 0
        for bi in range(len(bounds) - 1):
            t0, t1 = bounds[bi], bounds[bi + 1]
            is_last = bi == len(bounds) - 2
            o1 = t1 + 1 if is_last else t1
            sel = (kept_idx >= t0) & (kept_idx <= t1)
            bidx, bvals = kept_idx[sel], xr[kept_idx[sel]]
            owned_xr = reconstruct_block(
                bidx - t0, bvals, t1 - t0 + 1, str(xr.dtype))[:o1 - t0]
            body, pbytes = build_block(
                bidx, bvals, t0=t0, t1=t1,
                is_last=is_last, owned_xr=owned_xr,
                L=cfg.lags, kappa=cfg.kappa, stat=cfg.stat, eps=cfg.eps,
                resid=None if x64 is None else x64[t0:o1] - owned_xr,
                value_codec=self.value_codec, entropy=self.entropy)
            off = self._f.seek(0, os.SEEK_END)
            self._f.write(struct.pack("<I", len(body)))
            self._f.write(body)
            nbytes += 4 + len(body)
            payload_nbytes += pbytes
            blocks.append(dict(offset=off, nbytes=len(body), t0=t0, t1=t1))
        self._f.flush()
        entry = dict(
            n=n, n_kept=int(kept_idx.shape[0]), dtype=str(xr.dtype),
            eps=float(cfg.eps), stat=cfg.stat, lags=int(cfg.lags),
            kappa=int(cfg.kappa), deviation=float(res.deviation),
            value_codec=self.value_codec, stored_nbytes=nbytes,
            payload_nbytes=payload_nbytes,
            has_resid=x64 is not None, blocks=blocks)
        self._series[sid] = entry
        return entry

    # -- catalog ------------------------------------------------------------

    def series_ids(self) -> List[str]:
        return list(self._series)

    def series_meta(self, sid: str) -> dict:
        return self._series[sid]

    def __contains__(self, sid: str) -> bool:
        return sid in self._series

    # -- block access -------------------------------------------------------

    def _read_body(self, blk: dict) -> bytes:
        self._f.seek(blk["offset"])
        blen, = struct.unpack("<I", self._f.read(4))
        return self._f.read(blen)

    def block_meta(self, sid: str, bi: int) -> BlockMeta:
        """Header metadata of one block (no bitstream decode) — cached, so
        repeated pushdown queries never re-read interior blocks."""
        key = (sid, bi)
        meta = self._metas.get(key)
        if meta is None:
            blk = self._series[sid]["blocks"][bi]
            meta, _, _ = parse_block(self._read_body(blk),
                                     with_payload=False)
            self._metas[key] = meta
        return meta

    def block_metas(self, sid: str) -> List[BlockMeta]:
        """Header-only metadata of every block of a series."""
        return [self.block_meta(sid, bi)
                for bi in range(len(self._series[sid]["blocks"]))]

    def _block(self, sid: str, bi: int):
        """Decoded block (meta, global kept indices, values) — cached."""
        key = (sid, bi)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        blk = self._series[sid]["blocks"][bi]
        meta, idx, vals = parse_block(self._read_body(blk))
        if len(self._cache) >= 128:
            self._cache.pop(next(iter(self._cache)))
        self._cache[key] = (meta, idx, vals)
        self._metas[key] = meta
        return meta, idx, vals

    def _overlapping(self, sid: str, a: int, b: int):
        """Indices of blocks whose *owned* range intersects [a, b)."""
        entry = self._series[sid]
        out = []
        for bi, blk in enumerate(entry["blocks"]):
            is_last = bi == len(entry["blocks"]) - 1
            o1 = blk["t1"] + 1 if is_last else blk["t1"]
            if blk["t0"] < b and o1 > a:
                out.append(bi)
        return out

    # -- reads --------------------------------------------------------------

    def read_kept(self, sid: str):
        """(indices, values) of the stored kept points, whole series."""
        idx_parts, val_parts = [], []
        nb = len(self._series[sid]["blocks"])
        for bi in range(nb):
            meta, idx, vals = self._block(sid, bi)
            if bi < nb - 1:          # shared border point belongs to next
                idx, vals = idx[:-1], vals[:-1]
            idx_parts.append(idx)
            val_parts.append(vals)
        dtype = np.dtype(self._series[sid]["dtype"])
        return (np.concatenate(idx_parts),
                np.concatenate(val_parts).astype(dtype))

    def kept_mask(self, sid: str) -> np.ndarray:
        mask = np.zeros(self._series[sid]["n"], bool)
        mask[self.read_kept(sid)[0]] = True
        return mask

    def read_window(self, sid: str, a: int, b: int) -> np.ndarray:
        """Reconstruction slice ``xr[a:b]``, decoding only the blocks whose
        range overlaps the window.  Bit-exact vs the full reconstruction."""
        entry = self._series[sid]
        n = entry["n"]
        a, b = max(int(a), 0), min(int(b), n)
        dtype = np.dtype(entry["dtype"])
        if b <= a:
            return np.empty(0, dtype)
        out = np.empty(b - a, dtype)
        for bi in self._overlapping(sid, a, b):
            meta, idx, vals = self._block(sid, bi)
            xr_b = reconstruct_block(idx - meta.t0, vals, meta.span,
                                     str(dtype))
            lo, hi = max(a, meta.o0), min(b, meta.o1)
            out[lo - a:hi - a] = xr_b[lo - meta.t0:hi - meta.t0]
        return out

    def read_series(self, sid: str) -> np.ndarray:
        """Whole-series reconstruction (bit-exact vs ``CompressResult.xr``)."""
        return self.read_window(sid, 0, self._series[sid]["n"])

    # -- accounting ---------------------------------------------------------

    def compression_stats(self, sid: str) -> dict:
        """Point-count CR vs byte-true CRs for one stored series.

        ``bytes_cr`` divides by the physical file bytes (codec payloads +
        block headers with their ``[5, L]`` pushdown metadata — for large
        ``L`` on short series the metadata dominates, which is the price of
        metadata-only aggregate queries); ``codec_cr`` divides by the codec
        payloads alone (the Table-2-comparable number).
        """
        e = self._series[sid]
        raw_nbytes = 8 * e["n"]
        payload = e.get("payload_nbytes", e["stored_nbytes"])
        return dict(
            n=e["n"], n_kept=e["n_kept"],
            point_cr=e["n"] / max(e["n_kept"], 1),
            stored_nbytes=e["stored_nbytes"],
            payload_nbytes=payload,
            bytes_cr=raw_nbytes / max(e["stored_nbytes"], 1),
            codec_cr=raw_nbytes / max(payload, 1),
            raw_nbytes=raw_nbytes)
