"""CameoStore — the on-disk physical layer under the compressor.

File layout (append-oriented: blocks stream to disk as series are ingested,
the index is a footer written on ``close``)::

    magic "CAMEOST\\x02"
    [u32 body_len][block body + crc32] ...      (blocks, any series order)
    footer JSON (zlib)                           (series catalog)
    [u64 footer_offset][u32 footer_len][magic]

Format v2 (this magic) compacts the per-block ``[5, L]`` aggregate and
edge-vector metadata with the lossless shuffle+delta coder in
``store/blocks.py``; v1 files are refused loudly — reingest them.

A crashed writer leaves a file without a footer; ``CameoStore.open`` refuses
it loudly rather than serving a partial catalog.  Reopening with
``mode="a"`` truncates the footer and keeps appending — restart-safe ingest
for the serving layer.

The reader serves random-access **window decodes** that touch only the
blocks overlapping the window (block borders are kept points, so no
interpolation segment crosses a block — see ``store/blocks.py``), plus
header-only block metadata for ``store/query.py``'s pushdown aggregates.

Reads are cached through a **byte-budgeted decoded-block LRU**
(``cache_bytes``; default 64 MiB): a hit skips the pread, the bitstream
decode *and* — once a window read has touched the block — the jitted
reconstruction, so hot windows and repeated pushdown queries run at
memcpy speed.  ``append_series`` invalidates the appended series' entries
and ``cache_stats()`` reports hits/misses/evictions for the serving layer.
Cache-miss fetches of multi-block windows coalesce blocks that sit
contiguously in the file into single preads.

Roundtrip contract (tested property-style): for any compressed series,
``read_kept`` reproduces the kept mask and kept values bit-exactly, and
``read_series``/``read_window`` reproduce the canonical reconstruction —
the one-shot interpolation of the kept points — **bit-exactly**.  For the
rounds mode that canonical form *is* ``CompressResult.xr``; see
``append_series`` for the sequential mode's last-ulp caveat.  The store is
a lossless physical encoding of the compressor's lossy output.
"""
from __future__ import annotations

import collections
import json
import os
import struct
import zlib
from typing import Dict, List

import numpy as np

from repro.store import codec as _codec
from repro.store.blocks import (
    BlockMeta,
    build_block,
    parse_block,
    plan_block_bounds,
    reconstruct_block,
)

MAGIC = b"CAMEOST\x02"
_TAIL = struct.Struct("<QI")          # footer offset, footer byte length
DEFAULT_CACHE_BYTES = 64 << 20

# cache-entry slots: [meta, kept_idx, kept_vals, xr_or_None, nbytes]
_E_META, _E_IDX, _E_VALS, _E_XR, _E_NBYTES = range(5)


class BlockCache:
    """Byte-budgeted LRU over decoded blocks.

    Entries hold the decoded kept points and, once a window read has needed
    it, the block's reconstruction; ``grow`` accounts the late-attached
    reconstruction bytes.  A zero budget disables caching (every ``put``
    evicts immediately), which the eviction tests rely on.
    """

    __slots__ = ("budget", "nbytes", "hits", "misses", "evictions", "_d")

    def __init__(self, budget: int):
        self.budget = int(budget)
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._d = collections.OrderedDict()

    def get(self, key):
        e = self._d.get(key)
        if e is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return e

    def put(self, key, entry):
        old = self._d.pop(key, None)
        if old is not None:
            self.nbytes -= old[_E_NBYTES]
        self._d[key] = entry
        self.nbytes += entry[_E_NBYTES]
        self._evict()

    def grow(self, key, extra: int):
        if key in self._d:
            self._d[key][_E_NBYTES] += extra
            self.nbytes += extra
            self._evict()

    def invalidate(self, sid: str):
        for key in [k for k in self._d if k[0] == sid]:
            self.nbytes -= self._d.pop(key)[_E_NBYTES]

    def clear(self):
        self._d.clear()
        self.nbytes = 0

    def _evict(self):
        while self.nbytes > self.budget and self._d:
            _, e = self._d.popitem(last=False)
            self.nbytes -= e[_E_NBYTES]
            self.evictions += 1

    def stats(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, entries=len(self._d),
                    nbytes=self.nbytes, budget=self.budget)


class CameoStore:
    """One store file: append-oriented writer + random-access reader.

    Use :meth:`create` (new file), :meth:`open` (finalized file, read-only)
    or ``open(path, mode="a")`` (resume appending).  A store created in this
    process serves reads immediately from its in-memory catalog; a reopened
    store loads the catalog from the footer.  ``cache_bytes`` budgets the
    decoded-block LRU (0 disables caching).
    """

    def __init__(self, path: str, mode: str, *, block_len: int = 4096,
                 value_codec: str = "gorilla", entropy: str = "auto",
                 cache_bytes: int = DEFAULT_CACHE_BYTES):
        if value_codec not in _codec.VALUE_CODECS:
            raise ValueError(f"unknown value codec {value_codec!r}")
        self.path = path
        self.block_len = int(block_len)
        self.value_codec = value_codec
        self.entropy = entropy
        self._series: Dict[str, dict] = {}   # sid -> catalog entry
        self._cache = BlockCache(cache_bytes)  # (sid, bi) -> decoded entry
        self._metas: Dict[tuple, "BlockMeta"] = {}  # header-only cache
        self._writable = mode in ("w", "a")
        if mode == "w":
            self._f = open(path, "w+b")
            self._f.write(MAGIC)
        elif mode in ("r", "a"):
            self._f = open(path, "r+b" if mode == "a" else "rb")
            self._load_footer()
            if mode == "a":
                self._f.seek(self._footer_offset)
                self._f.truncate()
        else:
            raise ValueError(f"unknown mode {mode!r}")

    # -- constructors -------------------------------------------------------

    @classmethod
    def create(cls, path: str, *, block_len: int = 4096,
               value_codec: str = "gorilla", entropy: str = "auto",
               cache_bytes: int = DEFAULT_CACHE_BYTES) -> "CameoStore":
        return cls(path, "w", block_len=block_len, value_codec=value_codec,
                   entropy=entropy, cache_bytes=cache_bytes)

    @classmethod
    def open(cls, path: str, mode: str = "r", *,
             cache_bytes: int = DEFAULT_CACHE_BYTES) -> "CameoStore":
        return cls(path, mode, cache_bytes=cache_bytes)

    # -- context / lifecycle ------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        if self._f.closed:
            return
        if self._writable:
            self._write_footer()
        self._f.close()

    def _write_footer(self):
        off = self._f.seek(0, os.SEEK_END)
        footer = zlib.compress(json.dumps(
            {"block_len": self.block_len, "value_codec": self.value_codec,
             "entropy": self.entropy, "series": self._series},
            default=float).encode())
        self._f.write(footer)
        self._f.write(_TAIL.pack(off, len(footer)))
        self._f.write(MAGIC)
        self._f.flush()
        self._footer_offset = off

    def _load_footer(self):
        f = self._f
        head = f.read(len(MAGIC))
        if head != MAGIC:
            if head[:-1] == MAGIC[:-1]:
                raise IOError(f"{self.path}: CameoStore format "
                              f"v{head[-1]} is not v{MAGIC[-1]} — reingest "
                              "the series into a fresh store")
            raise IOError(f"{self.path}: not a CameoStore file")
        end = f.seek(0, os.SEEK_END)
        tail_len = _TAIL.size + len(MAGIC)
        if end < len(MAGIC) + tail_len:
            raise IOError(f"{self.path}: truncated store (no footer)")
        f.seek(end - tail_len)
        tail = f.read(tail_len)
        if tail[-len(MAGIC):] != MAGIC:
            raise IOError(f"{self.path}: missing footer magic — the writer "
                          "crashed before close(); reingest or salvage "
                          "blocks manually")
        off, flen = _TAIL.unpack(tail[:_TAIL.size])
        f.seek(off)
        meta = json.loads(zlib.decompress(f.read(flen)).decode())
        self.block_len = int(meta.get("block_len", self.block_len))
        self.value_codec = meta.get("value_codec", self.value_codec)
        self.entropy = meta.get("entropy", self.entropy)
        self._series = meta["series"]
        self._footer_offset = off

    # -- ingest -------------------------------------------------------------

    def append_series(self, sid: str, res, cfg, x=None) -> dict:
        """Write one compressed series.

        ``res`` is a ``CompressResult`` (anything with ``.kept`` / ``.xr``
        works), ``cfg`` the ``CameoConfig`` it was produced under, and ``x``
        optionally the *original* series — when given, per-block residual
        moments are stored and pushdown value aggregates carry deterministic
        error bounds **vs the original** (otherwise vs the reconstruction).
        Returns the catalog entry (byte sizes, per-block extents).  Any
        cached decoded blocks for ``sid`` are invalidated.

        The stored reconstruction is the *canonical* one-shot interpolation
        of the kept points (the paper's §4.1 decompression), computed here
        per block so the write-time metadata is self-consistent with every
        future decode.  For the rounds mode this is bit-identical to
        ``res.xr``; the sequential mode's ``xr`` is accumulated incrementally
        during compression, so its dead positions can differ from the
        canonical interpolation in the last ulp — kept points are bit-exact
        either way.
        """
        if not self._writable:
            raise IOError("store opened read-only")
        if sid in self._series:
            raise ValueError(f"series {sid!r} already stored")
        kept = np.asarray(res.kept)
        xr = np.asarray(res.xr)
        n = int(kept.shape[0])
        kept_idx = np.nonzero(kept)[0].astype(np.int64)
        x64 = None if x is None else np.asarray(x, np.float64)[:n]
        bounds = plan_block_bounds(kept_idx, self.block_len, cfg.lags)

        blocks: List[dict] = []
        nbytes = payload_nbytes = meta_nbytes = meta_raw_nbytes = 0
        for bi in range(len(bounds) - 1):
            t0, t1 = bounds[bi], bounds[bi + 1]
            is_last = bi == len(bounds) - 2
            o1 = t1 + 1 if is_last else t1
            sel = (kept_idx >= t0) & (kept_idx <= t1)
            bidx, bvals = kept_idx[sel], xr[kept_idx[sel]]
            owned_xr = reconstruct_block(
                bidx - t0, bvals, t1 - t0 + 1, str(xr.dtype))[:o1 - t0]
            body, binfo = build_block(
                bidx, bvals, t0=t0, t1=t1,
                is_last=is_last, owned_xr=owned_xr,
                L=cfg.lags, kappa=cfg.kappa, stat=cfg.stat, eps=cfg.eps,
                resid=None if x64 is None else x64[t0:o1] - owned_xr,
                value_codec=self.value_codec, entropy=self.entropy)
            off = self._f.seek(0, os.SEEK_END)
            self._f.write(struct.pack("<I", len(body)))
            self._f.write(body)
            nbytes += 4 + len(body)
            payload_nbytes += binfo["payload_nbytes"]
            meta_nbytes += binfo["meta_nbytes"]
            meta_raw_nbytes += binfo["meta_raw_nbytes"]
            blocks.append(dict(offset=off, nbytes=len(body), t0=t0, t1=t1))
        self._f.flush()
        entry = dict(
            n=n, n_kept=int(kept_idx.shape[0]), dtype=str(xr.dtype),
            eps=float(cfg.eps), stat=cfg.stat, lags=int(cfg.lags),
            kappa=int(cfg.kappa), deviation=float(res.deviation),
            value_codec=self.value_codec, stored_nbytes=nbytes,
            payload_nbytes=payload_nbytes,
            meta_nbytes=meta_nbytes, meta_raw_nbytes=meta_raw_nbytes,
            has_resid=x64 is not None, blocks=blocks)
        self._series[sid] = entry
        self._cache.invalidate(sid)
        for key in [k for k in self._metas if k[0] == sid]:
            del self._metas[key]
        return entry

    # -- catalog ------------------------------------------------------------

    def series_ids(self) -> List[str]:
        return list(self._series)

    def series_meta(self, sid: str) -> dict:
        return self._series[sid]

    def __contains__(self, sid: str) -> bool:
        return sid in self._series

    # -- block access -------------------------------------------------------

    def _read_body(self, blk: dict) -> bytes:
        self._f.seek(blk["offset"])
        blen, = struct.unpack("<I", self._f.read(4))
        return self._f.read(blen)

    def _read_bodies(self, blks: List[dict]) -> List[bytes]:
        """One body per catalog entry; blocks that sit contiguously in the
        file are fetched with a single seek+read instead of one pread per
        block (multi-block windows of an uninterleaved series are one IO)."""
        out: List[bytes] = []
        i = 0
        while i < len(blks):
            j = i
            end = blks[j]["offset"] + 4 + blks[j]["nbytes"]
            while j + 1 < len(blks) and blks[j + 1]["offset"] == end:
                j += 1
                end = blks[j]["offset"] + 4 + blks[j]["nbytes"]
            self._f.seek(blks[i]["offset"])
            buf = self._f.read(end - blks[i]["offset"])
            pos = 0
            for _ in range(i, j + 1):
                blen, = struct.unpack_from("<I", buf, pos)
                out.append(buf[pos + 4:pos + 4 + blen])
                pos += 4 + blen
            i = j + 1
        return out

    def block_meta(self, sid: str, bi: int) -> BlockMeta:
        """Header metadata of one block (no bitstream decode) — cached, so
        repeated pushdown queries never re-read interior blocks."""
        key = (sid, bi)
        meta = self._metas.get(key)
        if meta is None:
            blk = self._series[sid]["blocks"][bi]
            meta, _, _ = parse_block(self._read_body(blk),
                                     with_payload=False)
            self._metas[key] = meta
        return meta

    def block_metas(self, sid: str) -> List[BlockMeta]:
        """Header-only metadata of every block of a series; uncached
        headers are fetched with coalesced preads."""
        blks = self._series[sid]["blocks"]
        missing = [bi for bi in range(len(blks))
                   if (sid, bi) not in self._metas]
        if missing:
            bodies = self._read_bodies([blks[bi] for bi in missing])
            for bi, body in zip(missing, bodies):
                meta, _, _ = parse_block(body, with_payload=False)
                self._metas[(sid, bi)] = meta
        return [self._metas[(sid, bi)] for bi in range(len(blks))]

    def _blocks(self, sid: str, bis: List[int]) -> List[list]:
        """Decoded cache entries for several blocks of one series; misses
        are fetched with coalesced preads and decoded in file order."""
        entries = {}
        misses = []
        for bi in bis:
            e = self._cache.get((sid, bi))
            if e is None:
                misses.append(bi)
            else:
                entries[bi] = e
        if misses:
            blks = self._series[sid]["blocks"]
            bodies = self._read_bodies([blks[bi] for bi in misses])
            for bi, body in zip(misses, bodies):
                meta, idx, vals = parse_block(body)
                e = [meta, idx, vals, None,
                     idx.nbytes + vals.nbytes + meta.agg.nbytes
                     + meta.head_vec.nbytes + meta.tail_vec.nbytes + 256]
                self._cache.put((sid, bi), e)
                self._metas[(sid, bi)] = meta
                entries[bi] = e
        return [entries[bi] for bi in bis]

    def _block(self, sid: str, bi: int):
        """Decoded block (meta, global kept indices, values) — cached."""
        e = self._blocks(sid, [bi])[0]
        return e[_E_META], e[_E_IDX], e[_E_VALS]

    def _overlapping(self, sid: str, a: int, b: int):
        """Indices of blocks whose *owned* range intersects [a, b)."""
        entry = self._series[sid]
        out = []
        for bi, blk in enumerate(entry["blocks"]):
            is_last = bi == len(entry["blocks"]) - 1
            o1 = blk["t1"] + 1 if is_last else blk["t1"]
            if blk["t0"] < b and o1 > a:
                out.append(bi)
        return out

    # -- reads --------------------------------------------------------------

    def read_kept(self, sid: str):
        """(indices, values) of the stored kept points, whole series."""
        idx_parts, val_parts = [], []
        nb = len(self._series[sid]["blocks"])
        for bi, e in enumerate(self._blocks(sid, list(range(nb)))):
            idx, vals = e[_E_IDX], e[_E_VALS]
            if bi < nb - 1:          # shared border point belongs to next
                idx, vals = idx[:-1], vals[:-1]
            idx_parts.append(idx)
            val_parts.append(vals)
        dtype = np.dtype(self._series[sid]["dtype"])
        return (np.concatenate(idx_parts),
                np.concatenate(val_parts).astype(dtype))

    def kept_mask(self, sid: str) -> np.ndarray:
        mask = np.zeros(self._series[sid]["n"], bool)
        mask[self.read_kept(sid)[0]] = True
        return mask

    def read_window(self, sid: str, a: int, b: int) -> np.ndarray:
        """Reconstruction slice ``xr[a:b]``, decoding only the blocks whose
        range overlaps the window.  Bit-exact vs the full reconstruction.
        Per-block reconstructions are attached to the LRU entries, so a hot
        window skips pread, bitstream decode *and* interpolation."""
        entry = self._series[sid]
        n = entry["n"]
        a, b = max(int(a), 0), min(int(b), n)
        dtype = np.dtype(entry["dtype"])
        if b <= a:
            return np.empty(0, dtype)
        out = np.empty(b - a, dtype)
        bis = self._overlapping(sid, a, b)
        for bi, e in zip(bis, self._blocks(sid, bis)):
            meta, xr_b = e[_E_META], e[_E_XR]
            if xr_b is None:
                xr_b = reconstruct_block(e[_E_IDX] - meta.t0, e[_E_VALS],
                                         meta.span, str(dtype))
                e[_E_XR] = xr_b
                self._cache.grow((sid, bi), xr_b.nbytes)
            lo, hi = max(a, meta.o0), min(b, meta.o1)
            out[lo - a:hi - a] = xr_b[lo - meta.t0:hi - meta.t0]
        return out

    def read_series(self, sid: str) -> np.ndarray:
        """Whole-series reconstruction (bit-exact vs ``CompressResult.xr``)."""
        return self.read_window(sid, 0, self._series[sid]["n"])

    # -- accounting ---------------------------------------------------------

    def cache_stats(self) -> dict:
        """Decoded-block LRU counters (hits/misses/evictions/bytes)."""
        return self._cache.stats()

    def compression_stats(self, sid: str) -> dict:
        """Point-count CR vs byte-true CRs for one stored series.

        ``bytes_cr`` divides by the physical file bytes (codec payloads +
        block headers with their compacted ``[5, L]`` pushdown metadata);
        ``codec_cr`` divides by the codec payloads alone (the
        Table-2-comparable number).  ``meta_nbytes`` / ``meta_raw_nbytes``
        expose what the shuffle+delta coding saved on header metadata.
        """
        e = self._series[sid]
        raw_nbytes = 8 * e["n"]
        payload = e.get("payload_nbytes", e["stored_nbytes"])
        return dict(
            n=e["n"], n_kept=e["n_kept"],
            point_cr=e["n"] / max(e["n_kept"], 1),
            stored_nbytes=e["stored_nbytes"],
            payload_nbytes=payload,
            meta_nbytes=e.get("meta_nbytes", 0),
            meta_raw_nbytes=e.get("meta_raw_nbytes", 0),
            bytes_cr=raw_nbytes / max(e["stored_nbytes"], 1),
            codec_cr=raw_nbytes / max(payload, 1),
            raw_nbytes=raw_nbytes)
