"""Chunked block format for stored CAMEO series.

A stored series is a sequence of blocks whose borders sit **on kept
points** — the writer snaps nominal ``block_len`` boundaries forward to the
next kept index, and consecutive blocks share that boundary point.  This is
the same discipline as ``core/parallel``'s pinned partition borders: no
interpolation segment ever crosses a block, so a block decodes to the exact
reconstruction slice using only its own kept points, and window reads touch
only overlapping blocks.

Every block header carries the compression contract (``n``, ``n_kept``,
``eps``, ``stat``, ``kappa``, ``L``) plus the pushdown metadata
``store/query.py`` answers aggregates from:

* the five per-lag ACF sufficient statistics of the block's owned slice
  (Eq. 7: ``sx, sxl, sx2, sxl2, sxx``, each ``[L]``);
* value moments (sum, sum of squares, min, max) and the first/last ``L``
  reconstruction values (the cross-block lag products for windowed ACF);
* signed residual moments vs the *original* series when the writer had it
  (``sum e``, ``sum e^2``, ``sum xr*e``, ``max |e|``) — the Plato-style
  deterministic error-bound inputs.

Header metadata is stored **compacted** twice over.  First, the moment
rows are *derived, not stored* (format v3): of the five Eq. 7 rows only
the lagged products ``sxx`` are physically kept — ``sx``, ``sxl``,
``sx2`` and ``sxl2`` are reconstructed at parse time from the scalar
moments plus the first/last-``L`` edge vectors the header already
carries (``sx(l) = vsum - sum(last l values)`` and mirrored forms; the
exact derivation ``store/query.py`` has always used for windowed ACF).
That shrinks the stored per-lag metadata ``(5L + |hv| + |tv|) /
(L + |hv| + |tv|)`` ≈ 2.3x on top of the coding below.  The derived rows
are *exact-on-derivation* (deterministic, equal to the v2 stored values
up to summation-order rounding); ``sxx`` — the only row the pushdown
ACF consumes from metadata — stays bit-exact.  v2 blocks (which store
all five rows) are still parsed bit-exactly; the block flags byte says
which layout a body uses.

Second, the surviving vectors (``sxx`` + the two edge vectors) go
through a lossless xor-delta over the float64 bit patterns followed by
a byte-plane shuffle (the blosc/Sprintz filter idea) and the shared
entropy wrap.  Neighboring entries share exponent and high mantissa
bytes, so the deltas are mostly-zero byte planes that zlib/zstd
collapse — min_temp-style ``L=365`` headers stop dominating their
payloads.  That roundtrip is bit-exact (uint64 xor + ``np.bitwise_xor.
accumulate``), so the deterministic pushdown bounds in ``store/query.py``
are untouched.

Ownership is half-open: block ``i`` owns ``[t0, t1)`` (the shared right
border belongs to the next block) except the last block, which owns its end
point too.  Owned spans are kept ``>= L`` (tail blocks merge into their
predecessor) so cross-block lag pairs only ever straddle *adjacent* blocks.

Reconstruction goes through the same jitted interpolation the compressor
uses (``core.cameo._reconstruct``), padded to power-of-two lengths so a
handful of compiled shapes serve every block: XLA fuses the interpolation
into an FMA, so a plain numpy re-implementation is *not* bit-identical —
decode must take the identical code path to honor the store's bit-true
contract.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cameo import _reconstruct
from repro.store import codec as _codec

STAT_CODES = {"acf": 0, "pacf": 1}
STAT_NAMES = {v: k for k, v in STAT_CODES.items()}
_VCODEC_CODES = {"gorilla": 0, "chimp": 1}
_VCODEC_NAMES = {v: k for k, v in _VCODEC_CODES.items()}
_ENTROPY_CODES = {"none": 0, "zlib": 1, "zstd": 2}
_ENTROPY_NAMES = {v: k for k, v in _ENTROPY_CODES.items()}

_FLAG_LAST = 1
_FLAG_RESID = 2
_FLAG_META_V3 = 4      # header stores only sxx; moment rows derived at parse

# fixed header: t0 t1 n_kept | L kappa hv_len tv_len | stat vcodec entropy
# flags meta_codec | eps vmin vmax vsum vsumsq r1 r2 rx emax | idx_bits
# val_bits raw_nbytes payload_nbytes meta_nbytes
_HDR = struct.Struct("<QQI HHHH BBBBB 9d QQIII")


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    """Decoded block header (everything except the payload streams)."""

    t0: int                 # global index of the first kept point (inclusive)
    t1: int                 # global index of the last kept point (inclusive)
    n_kept: int
    L: int
    kappa: int
    stat: str
    eps: float
    is_last: bool
    has_resid: bool
    vmin: float
    vmax: float
    vsum: float
    vsumsq: float
    r1: float               # sum of residuals  e = x - xr   (owned slice)
    r2: float               # sum of squared residuals
    rx: float               # sum of xr * e
    emax: float             # max |e|
    agg: np.ndarray         # [5, L] Eq. 7 sufficient stats of the owned slice
    head_vec: np.ndarray    # first min(L, owned) reconstruction values
    tail_vec: np.ndarray    # last  min(L, owned) reconstruction values
    idx_bits: int
    val_bits: int
    raw_nbytes: int
    payload_nbytes: int
    vcodec: str
    entropy: str

    @property
    def span(self) -> int:
        """Covered x-range length (inclusive of both kept borders)."""
        return self.t1 - self.t0 + 1

    @property
    def o0(self) -> int:
        return self.t0

    @property
    def o1(self) -> int:
        """Owned range end (exclusive): the shared border belongs to the
        next block, except for the final block."""
        return self.t1 + 1 if self.is_last else self.t1


# ---------------------------------------------------------------------------
# block planning — borders snapped to kept points
# ---------------------------------------------------------------------------

def plan_block_bounds(kept_idx: np.ndarray, block_len: int, L: int):
    """Block boundaries (kept indices, shared between neighbors).

    Boundaries start at ``kept_idx[0]`` and advance to the first kept index
    at least ``block_len`` away, so every owned span is ``>= block_len``
    (``block_len`` is clamped to ``>= L`` so cross-block lag pairs stay
    adjacent); a tail shorter than ``L`` merges into the previous block.
    """
    kept = np.asarray(kept_idx, np.int64)
    if kept.shape[0] < 2:
        raise ValueError("a stored series needs at least 2 kept points")
    block_len = max(int(block_len), int(L))
    bounds = [int(kept[0])]
    last = int(kept[-1])
    while bounds[-1] < last:
        j = int(np.searchsorted(kept, bounds[-1] + block_len, side="left"))
        nxt = int(kept[min(j, kept.shape[0] - 1)])
        if nxt >= last or last - nxt < L:
            nxt = last
        bounds.append(nxt)
    return bounds


def pack_meta_vectors(flat: np.ndarray, entropy: str = "auto"):
    """Losslessly compact float64 metadata vectors -> (payload, codec).

    xor-delta over the uint64 bit patterns (smooth aggregate vectors leave
    mostly-zero high bytes), then a byte-plane shuffle (all 1st bytes, all
    2nd bytes, ...) so the zero runs are contiguous for the entropy wrap.
    Bit-exact for every IEEE value incl. NaN payloads and infinities.
    """
    u = np.ascontiguousarray(np.asarray(flat, np.float64)).view(np.uint64)
    if u.shape[0] == 0:
        return b"", "none"
    d = np.empty_like(u)
    d[0] = u[0]
    d[1:] = u[1:] ^ u[:-1]
    planes = np.ascontiguousarray(d.view(np.uint8).reshape(-1, 8).T)
    return _codec.entropy_wrap(planes.tobytes(), entropy)


def unpack_meta_vectors(payload: bytes, count: int,
                        codec: str) -> np.ndarray:
    """Bit-exact inverse of :func:`pack_meta_vectors` -> float64 [count]."""
    if count == 0:
        return np.empty(0, np.float64)
    raw = _codec.entropy_unwrap(payload, codec)
    d = np.ascontiguousarray(
        np.frombuffer(raw, np.uint8).reshape(8, count).T).view(np.uint64)
    return np.bitwise_xor.accumulate(d.ravel()).view(np.float64)


def _slice_aggregates(v: np.ndarray, L: int) -> np.ndarray:
    """Eq. 7 sufficient statistics of a value slice, numpy form, [5, L]."""
    v = np.asarray(v, np.float64)
    m = v.shape[0]
    cs = np.concatenate([[0.0], np.cumsum(v)])
    cs2 = np.concatenate([[0.0], np.cumsum(v * v)])
    agg = np.zeros((5, L))
    for j in range(L):
        l = j + 1
        if m <= l:
            continue
        agg[0, j] = cs[m - l]                 # sx:  head sum
        agg[1, j] = cs[m] - cs[l]             # sxl: tail sum
        agg[2, j] = cs2[m - l]                # sx2
        agg[3, j] = cs2[m] - cs2[l]           # sxl2
        agg[4, j] = float(np.dot(v[:m - l], v[l:]))   # sxx
    return agg


def _slice_lag_products(v: np.ndarray, L: int) -> np.ndarray:
    """Row 4 of :func:`_slice_aggregates` alone (the only stored row in v3)."""
    v = np.asarray(v, np.float64)
    m = v.shape[0]
    return np.array([float(np.dot(v[:m - l], v[l:])) if m > l else 0.0
                     for l in range(1, L + 1)])


def derive_aggregate_rows(sxx: np.ndarray, hv: np.ndarray, tv: np.ndarray,
                          vsum: float, vsumsq: float, m: int) -> np.ndarray:
    """Reassemble the ``[5, L]`` Eq. 7 table from the v3 header fields.

    ``m`` is the owned-slice length.  For every defined lag (``l < m``) the
    moment rows follow from the scalar totals and the edge vectors::

        sx(l)   = vsum   - sum(last  l values)     (tail cumsum of ``tv``)
        sxl(l)  = vsum   - sum(first l values)     (head cumsum of ``hv``)
        sx2(l)  = vsumsq - sum(last  l squares)
        sxl2(l) = vsumsq - sum(first l squares)

    Defined lags satisfy ``l <= min(L, m-1) <= len(hv) == len(tv)``, so the
    cumulative sums always cover them.  Exact-on-derivation: deterministic,
    equal to the v2 stored rows up to summation-order rounding; ``sxx``
    passes through untouched (bit-exact).
    """
    L = sxx.shape[0]
    agg = np.zeros((5, L))
    # the stored sxx is already zero on undefined lags (writer masks m <= l)
    agg[4] = sxx
    if m <= 1 or hv.shape[0] == 0:
        return agg
    l = np.arange(1, L + 1)
    valid = l < m
    k = np.clip(l - 1, 0, tv.shape[0] - 1)
    kh = np.clip(l - 1, 0, hv.shape[0] - 1)
    cst = np.cumsum(tv[::-1])
    cst2 = np.cumsum((tv * tv)[::-1])
    csh = np.cumsum(hv)
    csh2 = np.cumsum(hv * hv)
    agg[0] = np.where(valid, vsum - cst[k], 0.0)
    agg[1] = np.where(valid, vsum - csh[kh], 0.0)
    agg[2] = np.where(valid, vsumsq - cst2[k], 0.0)
    agg[3] = np.where(valid, vsumsq - csh2[kh], 0.0)
    return agg


# ---------------------------------------------------------------------------
# encode / decode
# ---------------------------------------------------------------------------

def build_block(kept_idx, kept_vals, *, t0: int, t1: int, is_last: bool,
                owned_xr: np.ndarray, L: int, kappa: int, stat: str,
                eps: float, resid: Optional[np.ndarray] = None,
                resid_moments: Optional[tuple] = None,
                value_codec: str = "gorilla", entropy: str = "auto",
                meta_version: int = 3):
    """Encode one block -> ``(body, info)``.

    ``kept_idx``/``kept_vals`` are the kept points in ``[t0, t1]`` (global
    indices, both borders included); ``owned_xr`` is the reconstruction over
    the owned range and ``resid`` the residual ``x - xr`` over the same
    range when the original was available.  ``resid_moments`` is the
    alternative when the original is *not* available but its Plato moments
    are: a ``(r1, r2, rx, emax)`` tuple stored verbatim — the compaction
    rewriter merges blocks whose owned ranges exactly partition the merged
    range, so the moments of the merged block are the sums (max for
    ``emax``) of the parts' stored moments.  ``info`` carries
    ``payload_nbytes`` (the codec-only stream size), ``meta_nbytes`` (the
    compacted aggregate/edge metadata) and ``meta_raw_nbytes`` (what the
    stored metadata vectors would cost uncompacted) — header metadata is
    accounted separately from the payload because for large ``L`` on short
    blocks it can dominate, and the two CR flavors should stay tellable
    apart.  ``meta_version=3`` (default) stores only the ``sxx`` row and
    derives the four moment rows at parse; ``meta_version=2`` writes the
    legacy all-five-rows layout (kept writable for compatibility tests)."""
    if meta_version not in (2, 3):
        raise ValueError(f"unknown block meta version {meta_version}")
    kept_idx = np.asarray(kept_idx, np.int64)
    kept_vals = np.asarray(kept_vals, np.float64)
    owned_xr = np.asarray(owned_xr, np.float64)
    local_idx = kept_idx - t0
    payload, pinfo = _codec.encode_series_payload(
        local_idx, kept_vals, value_codec=value_codec, entropy=entropy)

    hv = owned_xr[:min(L, owned_xr.shape[0])]
    tv = owned_xr[-min(L, owned_xr.shape[0]):]
    if meta_version == 3:
        agg_stored = _slice_lag_products(owned_xr, L)
    else:
        agg_stored = _slice_aggregates(owned_xr, L).ravel()

    flags = (_FLAG_LAST if is_last else 0)
    if meta_version == 3:
        flags |= _FLAG_META_V3
    if resid is not None:
        resid = np.asarray(resid, np.float64)
        flags |= _FLAG_RESID
        r1, r2 = float(resid.sum()), float(np.dot(resid, resid))
        rx = float(np.dot(owned_xr, resid))
        emax = float(np.max(np.abs(resid))) if resid.size else 0.0
    elif resid_moments is not None:
        flags |= _FLAG_RESID
        r1, r2, rx, emax = (float(v) for v in resid_moments)
    else:
        r1 = r2 = rx = emax = 0.0

    meta_flat = np.concatenate([agg_stored, hv, tv])
    meta_payload, meta_codec = pack_meta_vectors(meta_flat, entropy)

    header = _HDR.pack(
        t0, t1, int(kept_idx.shape[0]),
        L, kappa, hv.shape[0], tv.shape[0],
        STAT_CODES[stat], _VCODEC_CODES[value_codec],
        _ENTROPY_CODES[pinfo["entropy"]], flags,
        _ENTROPY_CODES[meta_codec],
        float(eps), float(owned_xr.min()), float(owned_xr.max()),
        float(owned_xr.sum()), float(np.dot(owned_xr, owned_xr)),
        r1, r2, rx, emax,
        pinfo["idx_bits"], pinfo["val_bits"],
        pinfo["raw_nbytes"], pinfo["nbytes"], len(meta_payload))
    body = header + meta_payload + payload
    info = dict(payload_nbytes=len(payload),
                meta_nbytes=len(meta_payload),
                meta_raw_nbytes=int(meta_flat.nbytes))
    return body + struct.pack("<I", zlib.crc32(body)), info


def parse_block(body: bytes, *, with_payload: bool = True):
    """Decode a block body -> ``(BlockMeta, kept_idx_global, kept_vals)``.

    ``with_payload=False`` skips the bitstream decode (header-only reads for
    pushdown queries) and returns ``(meta, None, None)``.
    """
    crc_stored, = struct.unpack("<I", body[-4:])
    body = body[:-4]
    if zlib.crc32(body) != crc_stored:
        raise IOError("block corrupt: crc mismatch")
    (t0, t1, n_kept, L, kappa, hv_len, tv_len, stat_c, vcodec_c, ent_c,
     flags, meta_c, eps, vmin, vmax, vsum, vsumsq, r1, r2, rx, emax,
     idx_bits, val_bits, raw_nbytes, payload_nbytes,
     meta_nbytes) = _HDR.unpack(body[:_HDR.size])
    off = _HDR.size
    is_v3 = bool(flags & _FLAG_META_V3)
    agg_rows = 1 if is_v3 else 5
    meta_count = agg_rows * L + hv_len + tv_len
    meta_flat = unpack_meta_vectors(body[off:off + meta_nbytes], meta_count,
                                    _ENTROPY_NAMES[meta_c])
    off += meta_nbytes
    hv = meta_flat[agg_rows * L:agg_rows * L + hv_len]
    tv = meta_flat[agg_rows * L + hv_len:]
    if is_v3:
        owned = (t1 + 1 if flags & _FLAG_LAST else t1) - t0
        agg = derive_aggregate_rows(meta_flat[:L], hv, tv, vsum, vsumsq,
                                    owned)
    else:
        agg = meta_flat[:5 * L].reshape(5, L)
    meta = BlockMeta(
        t0=t0, t1=t1, n_kept=n_kept, L=L, kappa=kappa,
        stat=STAT_NAMES[stat_c], eps=eps,
        is_last=bool(flags & _FLAG_LAST), has_resid=bool(flags & _FLAG_RESID),
        vmin=vmin, vmax=vmax, vsum=vsum, vsumsq=vsumsq,
        r1=r1, r2=r2, rx=rx, emax=emax,
        agg=agg, head_vec=hv, tail_vec=tv,
        idx_bits=idx_bits, val_bits=val_bits, raw_nbytes=raw_nbytes,
        payload_nbytes=payload_nbytes,
        vcodec=_VCODEC_NAMES[vcodec_c], entropy=_ENTROPY_NAMES[ent_c])
    if not with_payload:
        return meta, None, None
    payload = body[off:off + payload_nbytes]
    local_idx, vals = _codec.decode_series_payload(
        payload, n_kept, meta.entropy, meta.vcodec)
    return meta, local_idx + t0, vals


# ---------------------------------------------------------------------------
# multivariate blocks (store format v4): one shared index stream per block,
# per-column value streams + per-column pushdown metadata
# ---------------------------------------------------------------------------

# fixed mvar header: t0 t1 n_kept C | L kappa hv_len tv_len | stat vcodec
# idx_ent vals_ent flags meta_codec | eps | idx_bits val_bits | raw_nbytes
# idx_nbytes vals_nbytes meta_nbytes
_MHDR = struct.Struct("<QQIH HHHH BBBBBB d QQ IIII")


@dataclasses.dataclass(frozen=True)
class MBlockMeta:
    """Decoded multivariate block header.

    The per-column fields are arrays over the ``channels`` axis; ``col(c)``
    projects one column into an ordinary :class:`BlockMeta` (the Eq. 7
    moment rows derived exactly as for v3 univariate headers), so the
    pushdown machinery in ``store/query.py`` serves any single column
    without knowing the block is multivariate.
    """

    t0: int
    t1: int
    n_kept: int
    channels: int
    L: int
    kappa: int
    stat: str
    eps: float
    is_last: bool
    has_resid: bool
    vmin: np.ndarray        # [C]
    vmax: np.ndarray        # [C]
    vsum: np.ndarray        # [C]
    vsumsq: np.ndarray      # [C]
    r1: np.ndarray          # [C]
    r2: np.ndarray          # [C]
    rx: np.ndarray          # [C]
    emax: np.ndarray        # [C]
    sxx: np.ndarray         # [C, L] Eq. 7 lagged products per column
    head_vec: np.ndarray    # [C, min(L, owned)]
    tail_vec: np.ndarray    # [C, min(L, owned)]
    idx_bits: int
    val_bits: int
    raw_nbytes: int
    payload_nbytes: int
    vcodec: str
    entropy: str

    @property
    def span(self) -> int:
        return self.t1 - self.t0 + 1

    @property
    def o0(self) -> int:
        return self.t0

    @property
    def o1(self) -> int:
        return self.t1 + 1 if self.is_last else self.t1

    def col(self, c: int) -> BlockMeta:
        """Single-column view: a v3-equivalent univariate header whose
        moment rows are derived from this block's per-column metadata."""
        agg = derive_aggregate_rows(
            self.sxx[c], self.head_vec[c], self.tail_vec[c],
            float(self.vsum[c]), float(self.vsumsq[c]), self.o1 - self.t0)
        return BlockMeta(
            t0=self.t0, t1=self.t1, n_kept=self.n_kept, L=self.L,
            kappa=self.kappa, stat=self.stat, eps=self.eps,
            is_last=self.is_last, has_resid=self.has_resid,
            vmin=float(self.vmin[c]), vmax=float(self.vmax[c]),
            vsum=float(self.vsum[c]), vsumsq=float(self.vsumsq[c]),
            r1=float(self.r1[c]), r2=float(self.r2[c]),
            rx=float(self.rx[c]), emax=float(self.emax[c]),
            agg=agg, head_vec=self.head_vec[c], tail_vec=self.tail_vec[c],
            idx_bits=self.idx_bits, val_bits=self.val_bits,
            raw_nbytes=self.raw_nbytes, payload_nbytes=self.payload_nbytes,
            vcodec=self.vcodec, entropy=self.entropy)


def build_mblock(kept_idx, kept_vals, *, t0: int, t1: int, is_last: bool,
                 owned_xr: np.ndarray, L: int, kappa: int, stat: str,
                 eps: float, resid: Optional[np.ndarray] = None,
                 value_codec: str = "gorilla", entropy: str = "auto"):
    """Encode one multivariate block -> ``(body, info)``.

    ``kept_vals`` is ``[k, C]`` (per-column values on the shared kept
    index), ``owned_xr`` ``[owned, C]`` the per-column reconstructions over
    the owned range, ``resid`` optionally ``[owned, C]``.  The index stream
    is encoded **once** — the Sprintz-style shared-timestamp saving — while
    values and the Eq. 7 pushdown metadata stay per-column, so single-column
    reads and per-column error bounds cost nothing extra.
    """
    kept_idx = np.asarray(kept_idx, np.int64)
    kept_vals = np.asarray(kept_vals, np.float64)
    owned_xr = np.asarray(owned_xr, np.float64)
    if kept_vals.ndim != 2 or owned_xr.ndim != 2:
        raise ValueError("multivariate blocks want [k, C] values and "
                         "[owned, C] reconstructions")
    C = kept_vals.shape[1]
    local_idx = kept_idx - t0

    idx_bytes = _codec.encode_indices(local_idx)
    idx_payload, idx_ent = _codec.entropy_wrap(idx_bytes, entropy)
    streams = [_codec.VALUE_ENCODERS[value_codec](
        np.ascontiguousarray(kept_vals[:, c])) for c in range(C)]
    vals_raw = b"".join(len(s).to_bytes(4, "little") + s for s in streams)
    vals_payload, vals_ent = _codec.entropy_wrap(vals_raw, entropy)
    val_bits = sum(_codec.VALUE_BIT_COUNTERS[value_codec](
        np.ascontiguousarray(kept_vals[:, c])) for c in range(C))

    h = min(L, owned_xr.shape[0])
    hv = owned_xr[:h].T                      # [C, h]
    tv = owned_xr[owned_xr.shape[0] - h:].T  # [C, h]
    sxx = np.stack([_slice_lag_products(owned_xr[:, c], L)
                    for c in range(C)])      # [C, L]
    flags = _FLAG_LAST if is_last else 0
    if resid is not None:
        resid = np.asarray(resid, np.float64)
        flags |= _FLAG_RESID
        r1 = resid.sum(axis=0)
        r2 = np.einsum("nc,nc->c", resid, resid)
        rx = np.einsum("nc,nc->c", owned_xr, resid)
        emax = (np.abs(resid).max(axis=0) if resid.shape[0]
                else np.zeros(C))
    else:
        r1 = r2 = rx = emax = np.zeros(C)
    scalars = np.stack([
        owned_xr.min(axis=0), owned_xr.max(axis=0),
        owned_xr.sum(axis=0), np.einsum("nc,nc->c", owned_xr, owned_xr),
        r1, r2, rx, emax])                   # [8, C]

    meta_flat = np.concatenate([scalars.ravel(), sxx.ravel(),
                                hv.ravel(), tv.ravel()])
    meta_payload, meta_codec = pack_meta_vectors(meta_flat, entropy)

    raw_nbytes = len(idx_bytes) + len(vals_raw)
    header = _MHDR.pack(
        t0, t1, int(kept_idx.shape[0]), C,
        L, kappa, h, h,
        STAT_CODES[stat], _VCODEC_CODES[value_codec],
        _ENTROPY_CODES[idx_ent], _ENTROPY_CODES[vals_ent], flags,
        _ENTROPY_CODES[meta_codec],
        float(eps),
        _codec.index_stream_bits(local_idx), val_bits,
        raw_nbytes, len(idx_payload), len(vals_payload), len(meta_payload))
    body = header + meta_payload + idx_payload + vals_payload
    info = dict(payload_nbytes=len(idx_payload) + len(vals_payload),
                meta_nbytes=len(meta_payload),
                meta_raw_nbytes=int(meta_flat.nbytes))
    return body + struct.pack("<I", zlib.crc32(body)), info


def parse_mblock(body: bytes, *, with_payload: bool = True):
    """Decode a multivariate block body -> ``(MBlockMeta, kept_idx_global,
    kept_vals [k, C])``; ``with_payload=False`` skips the bitstreams."""
    crc_stored, = struct.unpack("<I", body[-4:])
    body = body[:-4]
    if zlib.crc32(body) != crc_stored:
        raise IOError("block corrupt: crc mismatch")
    (t0, t1, n_kept, C, L, kappa, hv_len, tv_len, stat_c, vcodec_c,
     idx_ent_c, vals_ent_c, flags, meta_c, eps, idx_bits, val_bits,
     raw_nbytes, idx_nbytes, vals_nbytes,
     meta_nbytes) = _MHDR.unpack(body[:_MHDR.size])
    off = _MHDR.size
    meta_count = 8 * C + C * L + C * hv_len + C * tv_len
    meta_flat = unpack_meta_vectors(body[off:off + meta_nbytes], meta_count,
                                    _ENTROPY_NAMES[meta_c])
    off += meta_nbytes
    scalars = meta_flat[:8 * C].reshape(8, C)
    p = 8 * C
    sxx = meta_flat[p:p + C * L].reshape(C, L)
    p += C * L
    hv = meta_flat[p:p + C * hv_len].reshape(C, hv_len)
    p += C * hv_len
    tv = meta_flat[p:p + C * tv_len].reshape(C, tv_len)
    meta = MBlockMeta(
        t0=t0, t1=t1, n_kept=n_kept, channels=C, L=L, kappa=kappa,
        stat=STAT_NAMES[stat_c], eps=eps,
        is_last=bool(flags & _FLAG_LAST),
        has_resid=bool(flags & _FLAG_RESID),
        vmin=scalars[0], vmax=scalars[1], vsum=scalars[2],
        vsumsq=scalars[3], r1=scalars[4], r2=scalars[5], rx=scalars[6],
        emax=scalars[7], sxx=sxx, head_vec=hv, tail_vec=tv,
        idx_bits=idx_bits, val_bits=val_bits, raw_nbytes=raw_nbytes,
        payload_nbytes=idx_nbytes + vals_nbytes,
        vcodec=_VCODEC_NAMES[vcodec_c],
        entropy=_ENTROPY_NAMES[vals_ent_c])
    if not with_payload:
        return meta, None, None
    idx_raw = _codec.entropy_unwrap(body[off:off + idx_nbytes],
                                    _ENTROPY_NAMES[idx_ent_c])
    local_idx = _codec.decode_indices(idx_raw, n_kept)
    off += idx_nbytes
    vals_raw = _codec.entropy_unwrap(body[off:off + vals_nbytes],
                                     _ENTROPY_NAMES[vals_ent_c])
    vals = np.empty((n_kept, C), np.float64)
    pos = 0
    for c in range(C):
        slen = int.from_bytes(vals_raw[pos:pos + 4], "little")
        pos += 4
        vals[:, c] = _codec.VALUE_DECODERS[meta.vcodec](
            vals_raw[pos:pos + slen], n_kept)
        pos += slen
    return meta, local_idx + t0, vals


# ---------------------------------------------------------------------------
# bit-exact block reconstruction
# ---------------------------------------------------------------------------

_recon_jit = None


def reconstruct_block(local_idx: np.ndarray, vals: np.ndarray, span: int,
                      dtype: str = "float64") -> np.ndarray:
    """Reconstruction over a block's covered range from its kept points.

    Runs the compressor's own jitted interpolation on a power-of-two padded
    buffer (so a few compiled shapes cover all blocks; jit caches per
    shape); the result is bit-identical to the matching slice of
    ``CompressResult.xr``.
    """
    global _recon_jit
    if _recon_jit is None:
        import jax
        from repro.obs import OBS
        _recon_jit = jax.jit(_reconstruct)
        OBS.register_jit("store.reconstruct", _recon_jit)
    m = 1 << max(1, int(span - 1).bit_length())
    jdt = jnp.dtype(dtype)
    buf = np.zeros(m, jdt)
    buf[np.asarray(local_idx)] = np.asarray(vals, jdt)
    alive = np.zeros(m, bool)
    alive[np.asarray(local_idx)] = True
    out = _recon_jit(jnp.asarray(buf), jnp.asarray(alive))
    return np.asarray(out)[:span]
