"""CameoStore: the physical block store under the CAMEO compressor.

Application code should reach this layer through the :mod:`repro.api`
façade (``repro.api.open(path, cfg)``) — the modules here are the
internals it drives:

* ``store.codec``  — byte-true bitstream codecs (delta-of-delta kept-index
  packing, Gorilla/Chimp XOR value streams, optional zstd/zlib wrap) and
  the byte-true ``compression_ratio_bytes``.  Both directions are
  vectorized (bulk bit packing / control-scan + bulk gather, see
  ``store._scan``); the ``*_loop`` forms are the parity oracles.
* ``store.blocks`` — chunked block format; borders pinned on kept points;
  headers carry (n, n_kept, eps, stat, kappa, L) + the Eq. 7 ACF
  sufficient statistics and pushdown metadata.  Format v3 stores only the
  ``sxx`` row and the edge vectors (the four moment rows are derived at
  parse time, ~2.3x header shrink); vectors are compacted losslessly with
  xor-delta + byte-plane shuffle coding.  Format v4 adds **multivariate
  blocks**: one shared delta-of-delta index stream per block, per-column
  Gorilla/Chimp value streams and per-column Eq. 7 metadata
  (``build_mblock``/``parse_mblock``; ``MBlockMeta.col(c)`` projects one
  column onto the univariate header contract).  v2/v3 files read fine,
  and files that never hold a multivariate series keep the v3 magic
  bit-identically.
* ``store.store``  — append-oriented writer / random-access reader
  (``CameoStore``); window decodes touch only overlapping blocks, are
  bit-exact vs the compressor's reconstruction, and ride a byte-budgeted
  decoded-block LRU (``cache_bytes``).  Read-only opens are served from a
  **page-cache-backed mmap** where available (``CAMEO_MMAP=0`` or
  non-POSIX environments fall back to coalesced preads).  ``open_stream``
  opens a :class:`StreamSession` (univariate or multivariate) that
  appends blocks as stream windows close, serves the written prefix
  mid-stream, and resumes bit-exactly from footer-stashed state — the
  finalized file is byte-identical to the one-shot write.
* ``store.wal``    — per-store write-ahead journal (``<path>.wal``):
  length-prefixed checksummed records, group-commit fsync amortization,
  footer-image checkpoints, tolerant torn-tail scan.  Writable stores
  attach one by default (``CAMEO_WAL=0`` opts out); ``mode="a"`` opens
  recover a crashed writer's acked pushes through it — see
  ``store/README.md`` for the durability contract.
* ``store.query``  — Plato-style pushdown aggregates (sum/mean/var/ACF)
  with deterministic error bounds; ``ColumnView`` projects one column of
  a multivariate series onto the same machinery, and ``query(...,
  col=None)`` answers all columns off a single header pass.

Exports resolve lazily (PEP 562): ``store.codec`` is plain numpy + stdlib
and must stay importable without dragging in jax — ``baselines/lossless.py``
pulls its vectorized Table-2 counters from there — while ``store.store`` /
``store.blocks`` need jax for the bit-exact block reconstruction.

The free ``window_*`` re-exports are **deprecated** in favor of
``repro.api`` ``Series.sum/mean/var/acf`` (same code underneath;
``repro.store.query`` itself is the internal engine and does not warn).
"""
import functools
import importlib
import warnings

_EXPORTS = {
    "CameoStore": "repro.store.store",
    "StreamSession": "repro.store.store",
    "WriteAheadLog": "repro.store.wal",
    "chimp_stream_bits": "repro.store.codec",
    "compression_ratio_bytes": "repro.store.codec",
    "encode_series_payload": "repro.store.codec",
    "gorilla_stream_bits": "repro.store.codec",
}
# deprecated free-function query surface: kept working, but warns — the
# façade (repro.api Series.sum/mean/var/acf) is the documented path
_DEPRECATED_QUERY = ("window_acf", "window_mean", "window_sum", "window_var")
_SUBMODULES = ("blocks", "codec", "query", "store", "wal")


def _deprecated_query(name):
    fn = getattr(importlib.import_module("repro.store.query"), name)

    @functools.wraps(fn)
    def shim(*args, **kwargs):
        warnings.warn(
            f"repro.store.{name} is deprecated; use repro.api.open(...)"
            f".series(sid).{name.split('_', 1)[1]} (or repro.store.query."
            f"{name} if you really want the internal engine)",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)

    return shim


def __getattr__(name):
    if name in _DEPRECATED_QUERY:
        return _deprecated_query(name)
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.store.{name}")
    raise AttributeError(f"module 'repro.store' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS) | set(_DEPRECATED_QUERY)
                  | set(_SUBMODULES))
