"""CameoStore: the physical block store under the CAMEO compressor.

* ``store.codec``  — byte-true bitstream codecs (delta-of-delta kept-index
  packing, Gorilla/Chimp XOR value streams, optional zstd/zlib wrap) and
  the byte-true ``compression_ratio_bytes``.  Both directions are
  vectorized (bulk bit packing / control-scan + bulk gather, see
  ``store._scan``); the ``*_loop`` forms are the parity oracles.
* ``store.blocks`` — chunked block format; borders pinned on kept points;
  headers carry (n, n_kept, eps, stat, kappa, L) + the Eq. 7 ACF
  sufficient statistics and pushdown metadata.  Format v3 stores only the
  ``sxx`` row and the edge vectors (the four moment rows are derived at
  parse time, ~2.3x header shrink); vectors are compacted losslessly with
  xor-delta + byte-plane shuffle coding.  v2 files read fine.
* ``store.store``  — append-oriented writer / random-access reader
  (``CameoStore``); window decodes touch only overlapping blocks (misses
  fetched with coalesced preads), are bit-exact vs the compressor's
  reconstruction, and ride a byte-budgeted decoded-block LRU
  (``cache_bytes``).  ``open_stream`` opens a :class:`StreamSession` that
  appends blocks as stream windows close (``core/streaming``), serves the
  written prefix mid-stream, and resumes bit-exactly from footer-stashed
  state — the finalized file is byte-identical to the one-shot write.
* ``store.query``  — Plato-style pushdown aggregates (sum/mean/var/ACF)
  with deterministic error bounds; edge-block decodes hit the same LRU.

Exports resolve lazily (PEP 562): ``store.codec`` is plain numpy + stdlib
and must stay importable without dragging in jax — ``baselines/lossless.py``
pulls its vectorized Table-2 counters from there — while ``store.store`` /
``store.blocks`` need jax for the bit-exact block reconstruction.
"""
import importlib

_EXPORTS = {
    "CameoStore": "repro.store.store",
    "StreamSession": "repro.store.store",
    "window_acf": "repro.store.query",
    "window_mean": "repro.store.query",
    "window_sum": "repro.store.query",
    "window_var": "repro.store.query",
    "chimp_stream_bits": "repro.store.codec",
    "compression_ratio_bytes": "repro.store.codec",
    "encode_series_payload": "repro.store.codec",
    "gorilla_stream_bits": "repro.store.codec",
}
_SUBMODULES = ("blocks", "codec", "query", "store")


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.store.{name}")
    raise AttributeError(f"module 'repro.store' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS) | set(_SUBMODULES))
