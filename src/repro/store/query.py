"""Pushdown aggregates over compressed windows (Plato-style, PAPERS.md).

Aggregate queries over an arbitrary time window ``[a, b)`` are answered
from **block metadata** wherever the window fully covers a block's owned
range, falling back to a partial decode *only at the (at most two)
window-edge blocks*.  Every answer comes back as ``(value, bound)`` with a
deterministic error bound:

* ``sum`` / ``mean`` / ``var`` — when the series was ingested with its
  original (``append_series(..., x=...)``), interior blocks contribute
  their stored signed residual moments, so their part of the answer equals
  the **original** series' aggregate exactly; only the decoded edge slices
  contribute uncertainty, bounded by ``n_edge * max|residual|`` (and the
  matched second-moment form for ``var``).  Without residual metadata the
  same machinery answers exactly over the *reconstruction* (bounds then
  cover float rounding only).
* ``acf`` — the window ACF of the reconstruction, assembled exactly from
  the per-block Eq. 7 sufficient statistics, the stored first/last-``L``
  edge vectors (cross-block lag products), and decoded edge slices.  Its
  bound covers the floating-point reassembly error (computed from aggregate
  magnitudes, not measured), i.e. the answer is exact-on-reconstruction up
  to that bound.  The compression-time guarantee ``deviation <= eps``
  relating the reconstruction's *global* ACF to the original's is recorded
  in the series catalog and reported alongside.

Every bound is computed from stored metadata + deterministic float-slop
terms — never from comparing against a full decode.

The only decodes this module ever triggers are the window-edge blocks, and
those ride the store's decoded-block LRU (``CameoStore(cache_bytes=...)``)
— a repeated window query is answered from cached headers + cached edge
reconstructions without touching the bitstreams, which is the steady-state
(warm) pushdown latency the store benchmark reports.
"""
from __future__ import annotations

from time import perf_counter as _perf_counter

import numpy as np

from repro.obs import OBS

_U = 2.0 ** -52          # one ulp at 1.0
_SLOP = 64.0             # growth allowance on accumulated rounding


def _segments(store, sid: str, a: int, b: int):
    """Ordered window cover: ``(kind, meta, lo, hi, vals)`` per block, where
    ``kind == "meta"`` means the window fully covers the block's owned range
    (metadata only) and ``"edge"`` means a partial decode of ``[lo, hi)``.
    Only the overlapping blocks' headers are touched (cached in the store)."""
    segs = []
    n_meta = n_edge = 0
    for bi in store._overlapping(sid, a, b):
        m = store.block_meta(sid, bi)
        lo, hi = max(a, m.o0), min(b, m.o1)
        if lo == m.o0 and hi == m.o1:
            segs.append(("meta", m, lo, hi, None))
            n_meta += 1
        else:
            segs.append(
                ("edge", m, lo, hi,
                 np.asarray(store.read_window(sid, lo, hi), np.float64)))
            n_edge += 1
    if OBS.enabled:
        # pushdown-vs-decode decision counters, per block and per call
        OBS.inc("query.segments_meta", n_meta)
        OBS.inc("query.segments_edge", n_edge)
        OBS.inc("query.meta_only" if n_edge == 0 else
                "query.with_edge_decode")
    return segs


def _check_window(store, sid, a, b):
    n = store.series_meta(sid)["n"]
    a, b = int(a), int(b)
    if not (0 <= a < b <= n):
        raise ValueError(f"window [{a}, {b}) outside series [0, {n})")
    return a, b


def _moments(segs):
    """(S, bS, Q, bQ, scale): first/second moments of the *original* window
    (when residual metadata exists; else the reconstruction) and their
    deterministic bounds, plus a value-scale proxy for float slop."""
    S = bS = Q = bQ = 0.0
    scale = 0.0
    for kind, m, lo, hi, vals in segs:
        amax = max(abs(m.vmin), abs(m.vmax)) + m.emax
        scale = max(scale, amax)
        if kind == "meta":
            S += m.vsum + m.r1
            Q += m.vsumsq + 2.0 * m.rx + m.r2
        else:
            ne = hi - lo
            S += float(vals.sum())
            Q += float(np.dot(vals, vals))
            bS += ne * m.emax
            bQ += ne * (2.0 * amax * m.emax + m.emax * m.emax)
    return S, bS, Q, bQ, scale


def window_sum(store, sid: str, a: int, b: int):
    a, b = _check_window(store, sid, a, b)
    segs = _segments(store, sid, a, b)
    S, bS, _, _, scale = _moments(segs)
    return S, bS + _U * _SLOP * (b - a) * scale


def window_mean(store, sid: str, a: int, b: int):
    s, bs = window_sum(store, sid, a, b)
    nw = b - a
    return s / nw, bs / nw


def window_var(store, sid: str, a: int, b: int):
    """Population variance of the window, interval-propagated through
    ``Q/n - (S/n)^2``."""
    a, b = _check_window(store, sid, a, b)
    segs = _segments(store, sid, a, b)
    S, bS, Q, bQ, scale = _moments(segs)
    nw = b - a
    slop = _U * _SLOP * nw * scale
    bS, bQ = bS + slop, bQ + slop * scale
    mean = S / nw
    bmean = bS / nw
    var = Q / nw - mean * mean
    bound = bQ / nw + 2.0 * abs(mean) * bmean + bmean * bmean
    return var, bound


def _window_head_tail(segs, L: int):
    """First/last ``min(L, nw)`` reconstruction values of the window, from
    stored edge vectors (meta segments own >= L values) or decoded slices."""
    head_parts, got = [], 0
    for kind, m, lo, hi, vals in segs:
        src = vals if kind == "edge" else m.head_vec
        head_parts.append(src[:L - got])
        got += head_parts[-1].shape[0]
        if got >= L:
            break
    tail_parts, got = [], 0
    for kind, m, lo, hi, vals in reversed(segs):
        src = vals if kind == "edge" else m.tail_vec
        take = src[max(0, src.shape[0] - (L - got)):]
        tail_parts.append(take)
        got += take.shape[0]
        if got >= L:
            break
    return (np.concatenate(head_parts),
            np.concatenate(list(reversed(tail_parts))))


def _lag_products(v: np.ndarray, L: int) -> np.ndarray:
    out = np.zeros(L)
    m = v.shape[0]
    for j in range(min(L, m - 1)):
        out[j] = float(np.dot(v[:m - j - 1], v[j + 1:]))
    return out


def _cross_lag(tail_a: np.ndarray, head_b: np.ndarray, L: int) -> np.ndarray:
    """Lag products for pairs straddling two consecutive segments:
    ``out[l-1] = sum_j tail_a[-j] * head_b[l-j]`` over valid ``j``."""
    out = np.zeros(L)
    la, lb = tail_a.shape[0], head_b.shape[0]
    for j in range(L):
        l = j + 1
        jhi = min(l, la)          # how far back into A pairs can start
        jlo = max(1, l - lb + 1)  # partner must exist within B's head
        if jhi < jlo:
            continue
        out[j] = float(np.dot(tail_a[la - jhi:la - jlo + 1],
                              head_b[l - jhi:l - jlo + 1]))
    return out


def window_acf(store, sid: str, a: int, b: int):
    """Window ACF (Eq. 2) of the reconstruction over ``[a, b)`` with a
    deterministic float-reassembly bound; see the module docstring for the
    guarantee structure.  Requires ``b - a > lags``."""
    a, b = _check_window(store, sid, a, b)
    entry = store.series_meta(sid)
    L = entry["lags"]
    nw = b - a
    if nw <= L + 1:
        raise ValueError(f"window of {nw} points too short for {L} lags")
    segs = _segments(store, sid, a, b)

    total = total2 = 0.0
    sxx = np.zeros(L)
    prev_tail = None
    for kind, m, lo, hi, vals in segs:
        if kind == "meta":
            total += m.vsum
            total2 += m.vsumsq
            sxx += m.agg[4]
            head, tail = m.head_vec, m.tail_vec
        else:
            total += float(vals.sum())
            total2 += float(np.dot(vals, vals))
            sxx += _lag_products(vals, L)
            head, tail = vals[:L], vals[-L:]
        if prev_tail is not None:
            sxx += _cross_lag(prev_tail, head, L)
        prev_tail = tail

    whead, wtail = _window_head_tail(segs, L)
    l = np.arange(1, L + 1, dtype=np.float64)
    csh = np.cumsum(whead)
    csh2 = np.cumsum(whead * whead)
    cst = np.cumsum(wtail[::-1])          # cst[j] = sum of last j+1 values
    cst2 = np.cumsum((wtail * wtail)[::-1])
    sx = total - cst[:L]
    sxl = total - csh[:L]
    sx2 = total2 - cst2[:L]
    sxl2 = total2 - csh2[:L]

    m_l = nw - l
    num = m_l * sxx - sx * sxl
    vh = m_l * sx2 - sx * sx
    vt = m_l * sxl2 - sxl * sxl
    denom2 = vh * vt
    tiny = 1e-30
    denom = np.sqrt(np.maximum(denom2, tiny))
    ok = denom2 > tiny
    acf = np.where(ok, num / denom, 0.0)

    # float-reassembly budget from aggregate magnitudes (Cauchy-Schwarz:
    # |sxx| <= Q, |sx| <= sqrt(nw*Q)), never from a reference decode.
    C = _U * 4096.0
    Q = max(total2, tiny)
    err_lin = C * Q * (m_l + nw)          # |m*agg| + |sx*sxl| style products
    err_denom = (err_lin * np.abs(vt) + np.abs(vh) * err_lin) / (2.0 * denom)
    bound = np.where(
        ok, (err_lin + np.abs(acf) * err_denom) / denom + C, 2.0)
    return acf, bound


AGGREGATES = {
    "sum": window_sum,
    "mean": window_mean,
    "var": window_var,
    "acf": window_acf,
}


class ColumnView:
    """Single-column façade over a multivariate series.

    Duck-types the four store entry points the pushdown machinery touches
    (``series_meta`` / ``_overlapping`` / ``block_meta`` / ``read_window``),
    projecting every multivariate block header onto one column
    (``MBlockMeta.col``) and every decode onto one value stream — so the
    aggregate functions above serve per-column answers *unchanged*, with
    the same deterministic bound structure they give univariate series.
    """

    def __init__(self, store, sid: str, col: int):
        C = store.channels(sid)
        if not (0 <= int(col) < C):
            raise ValueError(f"column {col} outside [0, {C}) for {sid!r}")
        self._store = store
        self._sid = sid
        self.col = int(col)

    def series_meta(self, sid: str) -> dict:
        return self._store.series_meta(self._sid)

    def _overlapping(self, sid: str, a: int, b: int):
        return self._store._overlapping(self._sid, a, b)

    def block_meta(self, sid: str, bi: int):
        meta = self._store.block_meta(self._sid, bi)
        return meta.col(self.col) if hasattr(meta, "col") else meta

    def read_window(self, sid: str, a: int, b: int):
        return self._store.read_window(self._sid, a, b, col=self.col)


def query(store, sid: str, kind: str, a=None, b=None, col=None):
    """Dispatch a pushdown aggregate; ``a``/``b`` default to the full
    series.  Returns ``(value, bound)``.

    For a multivariate series, ``col`` selects one column; with
    ``col=None`` the aggregate runs across **all** columns off a single
    header pass (interior block headers are parsed once and cached, every
    column projects from the same ``MBlockMeta``), returning stacked
    ``(values [C, ...], bounds [C, ...])`` arrays.
    """
    if not OBS.enabled:
        return _query(store, sid, kind, a, b, col)
    t0 = _perf_counter()
    out = _query(store, sid, kind, a, b, col)
    OBS.observe("query.seconds", _perf_counter() - t0)
    OBS.inc("query.count")
    OBS.inc(f"query.kind.{kind}")
    # realized bound width: the widest bound the answer shipped with
    OBS.observe("query.bound_width", float(np.max(out[1])))
    return out


def _query(store, sid, kind, a, b, col):
    if kind not in AGGREGATES:
        raise ValueError(f"unknown aggregate {kind!r}; have "
                         f"{sorted(AGGREGATES)}")
    entry = store.series_meta(sid)
    n = entry["n"]
    a = 0 if a is None else a
    b = n if b is None else b
    C = int(entry.get("channels", 1))
    if C == 1:
        if col not in (None, 0):
            raise ValueError(f"column {col} outside [0, 1) for "
                             f"univariate series {sid!r}")
        return AGGREGATES[kind](store, sid, a, b)
    if col is not None:
        return AGGREGATES[kind](ColumnView(store, sid, col), sid, a, b)
    vals, bounds = zip(*(AGGREGATES[kind](ColumnView(store, sid, c),
                                          sid, a, b) for c in range(C)))
    return np.asarray(vals), np.asarray(bounds)
