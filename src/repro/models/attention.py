"""Grouped-query attention: training/prefill (full or chunked flash-style)
and single-token decode against a (possibly windowed ring) KV cache.

Sharding: query heads are tensor-parallel over ``model``; KV heads are
sharded only when divisible (else replicated — the divisibility guard in
repro.sharding).  Softmax statistics are always f32.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.models.layers import apply_mrope, apply_rope, rmsnorm, rmsnorm_defs
from repro.models.params import ParamDef

NEG_INF = -1e30


def attention_defs(d: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool = False, qkv_bias: bool = False):
    defs = {
        "q": ParamDef((d, n_heads, head_dim), ("fsdp", "tp", None)),
        "k": ParamDef((d, n_kv, head_dim), ("fsdp", "kv_tp", None)),
        "v": ParamDef((d, n_kv, head_dim), ("fsdp", "kv_tp", None)),
        "o": ParamDef((n_heads, head_dim, d), ("tp", None, "fsdp")),
    }
    if qkv_bias:
        defs["q_bias"] = ParamDef((n_heads, head_dim), ("tp", None), init="zeros")
        defs["k_bias"] = ParamDef((n_kv, head_dim), ("kv_tp", None), init="zeros")
        defs["v_bias"] = ParamDef((n_kv, head_dim), ("kv_tp", None), init="zeros")
    if qk_norm:
        defs["q_norm"] = rmsnorm_defs(head_dim)
        defs["k_norm"] = rmsnorm_defs(head_dim)
    return defs


def _project_qkv(p, x, spec):
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["k"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["v"])
    if "q_bias" in p:
        q = q + p["q_bias"]
        k = k + p["k_bias"]
        v = v + p["v_bias"]
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = shd.constrain(q, "act_batch", "act_seq", "act_heads", None)
    k = shd.constrain(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = shd.constrain(v, "act_batch", "act_seq", "act_kv_heads", None)
    return q, k, v


def _rope_qk(q, k, positions, spec):
    if spec.pos == "rope":
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    elif spec.pos == "mrope":
        # positions: [3, B, S]
        q = apply_mrope(q, positions, spec.mrope_sections, spec.rope_theta)
        k = apply_mrope(k, positions, spec.mrope_sections, spec.rope_theta)
    return q, k


def _mask(q_pos, k_pos, window: Optional[int]):
    """causal (+ sliding window) mask: [..., S_q, S_k] boolean (True=keep)."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return ok


def _sdpa(q, k, v, mask, scale):
    """q [B,Sq,H,dh], k/v [B,Sk,K,dh], mask [B,Sq,Sk] -> [B,Sq,H,dh]."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, dh)


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, scale, q_chunk, kv_chunk):
    """Flash-style online-softmax attention: O(S) memory, scan over chunks."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    nq = S // q_chunk
    nk = S // kv_chunk
    qg = q.reshape(B, nq, q_chunk, K, G, dh)
    qp = q_pos.reshape(B, nq, q_chunk) if q_pos.ndim == 2 else \
        q_pos.reshape(nq, q_chunk)
    kc = k.reshape(B, nk, kv_chunk, K, dh)
    vc = v.reshape(B, nk, kv_chunk, K, dh)
    kp = k_pos.reshape(B, nk, kv_chunk) if k_pos.ndim == 2 else \
        k_pos.reshape(nk, kv_chunk)

    def q_block(qi_and_pos):
        qi, qpos = qi_and_pos  # [B,qc,K,G,dh], [B,qc] or [qc]

        def kv_step(carry, kv):
            m, l, acc = carry
            kj, vj, kpos = kv
            if qpos.ndim == 1:
                msk = _mask(qpos, kpos, window)[None]          # [1,qc,kc]
            else:
                msk = _mask(qpos, kpos, window)                 # [B,qc,kc]
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            s = jnp.where(msk[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1),
             kp.swapaxes(0, 1) if kp.ndim == 3 else kp))
        out = acc / jnp.maximum(l[..., None], 1e-30)            # [B,K,G,qc,dh]
        return jnp.einsum("bkgqd->bqkgd", out)

    qg_t = qg.swapaxes(0, 1)                                    # [nq,B,qc,K,G,dh]
    qp_t = qp.swapaxes(0, 1) if qp.ndim == 3 else qp
    out = jax.lax.map(q_block, (qg_t, qp_t))                    # [nq,B,qc,K,G,dh]
    out = out.swapaxes(0, 1).reshape(B, S, H, dh)
    return out


def attend_train(p, x, positions, spec):
    """Full-sequence attention for train/prefill.  Returns (out, (k, v))."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, spec)
    q, k = _rope_qk(q, k, positions, spec)
    scale = 1.0 / np.sqrt(q.shape[-1])
    pos = positions if positions.ndim == 2 else positions[0]   # mrope: use t
    if spec.attn_chunk is not None and S > spec.attn_chunk:
        out = _sdpa_chunked(q, k, v, pos, pos, spec.window, scale,
                            q_chunk=spec.attn_chunk, kv_chunk=spec.attn_chunk)
    else:
        mask = _mask(pos, pos, spec.window)
        out = _sdpa(q, k, v, mask, scale)
    out = out.astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["o"])
    return shd.constrain(y, "act_batch", "act_res_seq", "act_embed"), (k, v)


# ---------------------------------------------------------------------------
# decode with (windowed ring) KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array        # [B, size, K, dh] (activ dtype, or int8 quantized)
    v: jax.Array        # [B, size, K, dh]
    pos_ids: jax.Array  # [B, size] int32, -1 where empty
    k_scale: jax.Array  # [B, size, K, 1] f32 when int8, else [1] placeholder
    v_scale: jax.Array


def kv_cache_size(spec, max_len: int) -> int:
    if spec.window is not None:
        return min(spec.window, max_len)
    prune = max(getattr(spec, "kv_prune", 1), 1)
    return max(max_len // prune, 1)


def _quantized(spec) -> bool:
    return getattr(spec, "kv_cache_dtype", "same") == "int8"


def _quantize_kv(x):
    """[..., dh] -> (int8 values, f32 scale[..., 1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_kv_cache(spec, B: int, max_len: int, dtype) -> KVCache:
    size = kv_cache_size(spec, max_len)
    shape = (B, size, spec.n_kv, spec.head_dim)
    if _quantized(spec):
        return KVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            pos_ids=jnp.full((B, size), -1, jnp.int32),
            k_scale=jnp.ones(shape[:-1] + (1,), jnp.float32),
            v_scale=jnp.ones(shape[:-1] + (1,), jnp.float32))
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos_ids=jnp.full((B, size), -1, jnp.int32),
        k_scale=jnp.ones((1,), jnp.float32),
        v_scale=jnp.ones((1,), jnp.float32))


def kv_cache_specs(spec, B: int, max_len: int, dtype, mesh, rules):
    size = kv_cache_size(spec, max_len)
    kv_shape = (B, size, spec.n_kv, spec.head_dim)
    kv_axes = ("act_cache_batch", "act_cache_seq", "act_kv_heads", None)
    qdt = jnp.int8 if _quantized(spec) else dtype

    def sds(shape, axes, dt):
        return jax.ShapeDtypeStruct(
            shape, dt, sharding=shd.named_sharding(shape, axes, mesh, rules))

    if _quantized(spec):
        sc_shape = kv_shape[:-1] + (1,)
        k_scale = sds(sc_shape, kv_axes, jnp.float32)
        v_scale = sds(sc_shape, kv_axes, jnp.float32)
    else:
        k_scale = sds((1,), (None,), jnp.float32)
        v_scale = sds((1,), (None,), jnp.float32)
    return KVCache(
        k=sds(kv_shape, kv_axes, qdt), v=sds(kv_shape, kv_axes, qdt),
        pos_ids=sds((B, size), ("act_cache_batch", None), jnp.int32),
        k_scale=k_scale, v_scale=v_scale)


def attend_decode(p, x, pos, cache: KVCache, spec):
    """One-token decode: x [B, 1, d], pos scalar int32 (uniform across batch).

    Writes the new KV at ``pos % size`` (ring for windowed layers) and
    attends over all valid cache entries.  Returns (out [B,1,d], new cache).
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, spec)
    positions = jnp.full((B, 1), pos, jnp.int32)
    if spec.pos == "mrope":
        positions3 = jnp.broadcast_to(positions[None], (3, B, 1))
        q, k_new = _rope_qk(q, k_new, positions3, spec)
    else:
        q, k_new = _rope_qk(q, k_new, positions, spec)

    size = cache.k.shape[1]
    slot = jnp.mod(pos, size).astype(jnp.int32)
    z = jnp.zeros((), jnp.int32)
    if _quantized(spec):
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        kq8 = jax.lax.dynamic_update_slice(cache.k, kq, (z, slot, z, z))
        vq8 = jax.lax.dynamic_update_slice(cache.v, vq, (z, slot, z, z))
        k_scale = jax.lax.dynamic_update_slice(
            cache.k_scale, ks, (z, slot, z, z))
        v_scale = jax.lax.dynamic_update_slice(
            cache.v_scale, vs, (z, slot, z, z))
        k = _dequantize_kv(kq8, k_scale, x.dtype)
        v = _dequantize_kv(vq8, v_scale, x.dtype)
        new_cache_kv = (kq8, vq8, k_scale, v_scale)
    else:
        k = jax.lax.dynamic_update_slice(cache.k, k_new, (z, slot, z, z))
        v = jax.lax.dynamic_update_slice(cache.v, v_new, (z, slot, z, z))
        new_cache_kv = (k, v, cache.k_scale, cache.v_scale)
    pos_ids = jax.lax.dynamic_update_slice(
        cache.pos_ids, positions, (z, slot))

    scale = 1.0 / np.sqrt(q.shape[-1])
    H = q.shape[2]
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, q.shape[-1])
    scores = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = (pos_ids >= 0) & (pos_ids <= pos)
    if spec.window is not None:
        valid &= (pos - pos_ids) < spec.window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    out = out.reshape(B, 1, H, q.shape[-1]).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["o"])
    y = shd.constrain(y, "act_batch", None, "act_embed")
    ck, cv, cks, cvs = new_cache_kv
    return y, KVCache(k=ck, v=cv, pos_ids=pos_ids, k_scale=cks, v_scale=cvs)
