"""Mamba2 (state-space duality) block: chunked SSD scan for train/prefill,
O(1)-state recurrent step for decode (arXiv:2405.21060).

TPU adaptation: the within-chunk quadratic term and the chunk-state
contraction are einsums (MXU); the inter-chunk recurrence is a lax.scan over
``T/Q`` chunk states.  All SSD-internal math runs in f32 (exponents are
non-positive by construction, so everything is bounded by 1).

Tensor-parallel sharding: heads (x/z/dt projections, A, D, gated norm) are
sharded over ``model``; the group-shared B/C projections are replicated
(groups are the GQA analogue for SSMs).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.models.layers import rmsnorm_defs
from repro.models.params import ParamDef


def mamba_defs(spec):
    d, di, gn, hm, wc = (spec.d_model, spec.d_inner, spec.n_groups * spec.d_state,
                         spec.m_heads, spec.conv_width)
    return {
        "in_z": ParamDef((d, di), ("fsdp", "tp")),
        "in_x": ParamDef((d, di), ("fsdp", "tp")),
        "in_B": ParamDef((d, gn), ("fsdp", None)),
        "in_C": ParamDef((d, gn), ("fsdp", None)),
        "in_dt": ParamDef((d, hm), ("fsdp", "tp")),
        "conv_x": ParamDef((wc, di), (None, "tp"), scale=0.5),
        "conv_B": ParamDef((wc, gn), (None, None), scale=0.5),
        "conv_C": ParamDef((wc, gn), (None, None), scale=0.5),
        "A_log": ParamDef((hm,), ("tp",), init="ones"),
        "dt_bias": ParamDef((hm,), ("tp",), init="zeros"),
        "D": ParamDef((hm,), ("tp",), init="ones"),
        "norm": rmsnorm_defs(di, axes=("tp",)),
        "out": ParamDef((di, d), ("tp", "fsdp")),
    }


def _causal_conv(x, kernel):
    """Depthwise causal conv: x [B, T, C], kernel [w, C]."""
    w, C = kernel.shape
    rhs = kernel[:, None, :].astype(x.dtype)       # [w, 1, C]
    return jax.lax.conv_general_dilated(
        x, rhs, window_strides=(1,), padding=[(w - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)


def _gated_norm(p, y, z, eps=1e-6):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps)
            * p["scale"].astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x, dt, A, Bm, Cm, D, Q: int, s0=None):
    """Chunked SSD.  x [B,T,H,P] f32, dt [B,T,H] (post-softplus), A [H] (<0),
    Bm/Cm [B,T,G,N].  Returns (y [B,T,H,P], final_state [B,H,P,N]).

    Single lax.scan over chunks: the quadratic within-chunk term (L matrix,
    O(Q^2) memory) only ever exists for ONE chunk at a time — essential at
    32k+ sequence lengths (materializing all chunks would be TBs)."""
    B_, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = T // Q
    assert nc * Q == T, (T, Q)

    Ah = A.reshape(G, rep)
    Dh = D.reshape(G, rep)
    causal = jnp.tril(jnp.ones((Q, Q), x.dtype))
    if s0 is None:
        s0 = jnp.zeros((B_, G, rep, P, N), x.dtype)

    # chunk-major inputs for the scan: [nc, B, Q, ...]
    xq = x.reshape(B_, nc, Q, G, rep, P).swapaxes(0, 1)
    dtq = dt.reshape(B_, nc, Q, G, rep).swapaxes(0, 1)
    Bq = Bm.reshape(B_, nc, Q, G, N).swapaxes(0, 1)
    Cq = Cm.reshape(B_, nc, Q, G, N).swapaxes(0, 1)

    def chunk_step(s, inp):
        xc, dtc, Bc, Cc = inp                       # [B,Q,...]
        dA = dtc * Ah                               # [B,Q,G,rep] (<=0)
        cum = jnp.cumsum(dA, axis=1)
        # within-chunk quadratic term
        diff = cum[:, :, None] - cum[:, None, :]    # [B,Qi,Qj,G,rep]
        Lmat = jnp.exp(diff) * causal[None, :, :, None, None]
        scores = jnp.einsum("bign,bjgn->bijg", Cc, Bc)
        xt = xc * dtc[..., None]                    # x_j * dt_j
        y_diag = jnp.einsum("bijg,bijgr,bjgrp->bigrp", scores, Lmat, xt)
        # contribution of the carried state
        decay_in = jnp.exp(cum)                     # [B,Q,G,rep]
        y_off = jnp.einsum("bign,bgrpn->bigrp", Cc, s) * decay_in[..., None]
        # new chunk state
        decay_end = jnp.exp(cum[:, -1:] - cum)      # [B,Q,G,rep]
        st = jnp.einsum("bjgn,bjgrp->bgrpn", Bc, xt * decay_end[..., None])
        chunk_decay = jnp.exp(cum[:, -1])           # [B,G,rep]
        s_new = s * chunk_decay[..., None, None] + st
        y = y_diag + y_off + Dh[..., None] * xc
        return s_new, y

    s_final, ys = jax.lax.scan(chunk_step, s0, (xq, dtq, Bq, Cq))
    y = ys.swapaxes(0, 1).reshape(B_, T, H, P)
    return y, s_final.reshape(B_, H, P, N)


class MambaCache(NamedTuple):
    ssm: jax.Array      # [B, H, P, N] f32
    conv_x: jax.Array   # [B, w-1, d_inner]
    conv_B: jax.Array   # [B, w-1, G*N]
    conv_C: jax.Array   # [B, w-1, G*N]


def init_mamba_cache(spec, B: int, dtype) -> MambaCache:
    w = spec.conv_width
    return MambaCache(
        ssm=jnp.zeros((B, spec.m_heads, spec.headdim, spec.d_state),
                      jnp.float32),
        conv_x=jnp.zeros((B, w - 1, spec.d_inner), dtype),
        conv_B=jnp.zeros((B, w - 1, spec.n_groups * spec.d_state), dtype),
        conv_C=jnp.zeros((B, w - 1, spec.n_groups * spec.d_state), dtype),
    )


def mamba_cache_specs(spec, B: int, dtype, mesh, rules):
    w = spec.conv_width

    def sds(shape, axes, dt):
        return jax.ShapeDtypeStruct(
            shape, dt, sharding=shd.named_sharding(shape, axes, mesh, rules))

    gn = spec.n_groups * spec.d_state
    return MambaCache(
        ssm=sds((B, spec.m_heads, spec.headdim, spec.d_state),
                ("act_cache_batch", "act_heads", None, None), jnp.float32),
        conv_x=sds((B, w - 1, spec.d_inner),
                   ("act_cache_batch", None, "act_inner"), dtype),
        conv_B=sds((B, w - 1, gn), ("act_cache_batch", None, None), dtype),
        conv_C=sds((B, w - 1, gn), ("act_cache_batch", None, None), dtype),
    )


def _projections(p, x):
    z = jnp.einsum("btd,de->bte", x, p["in_z"])
    xx = jnp.einsum("btd,de->bte", x, p["in_x"])
    Bp = jnp.einsum("btd,de->bte", x, p["in_B"])
    Cp = jnp.einsum("btd,de->bte", x, p["in_C"])
    dt = jnp.einsum("btd,dh->bth", x, p["in_dt"])
    z = shd.constrain(z, "act_batch", "act_seq", "act_inner")
    xx = shd.constrain(xx, "act_batch", "act_seq", "act_inner")
    return z, xx, Bp, Cp, dt


def mamba_train(p, x, spec, s0=None):
    """Full-sequence Mamba2 block.  x [B, T, d] -> (y, final MambaCache)."""
    B_, T, d = x.shape
    H, P, G, N = spec.m_heads, spec.headdim, spec.n_groups, spec.d_state

    z, xx, Bp, Cp, dt = _projections(p, x)
    xx_conv_in, Bp_in, Cp_in = xx, Bp, Cp
    xx = jax.nn.silu(_causal_conv(xx, p["conv_x"]))
    Bp = jax.nn.silu(_causal_conv(Bp, p["conv_B"]))
    Cp = jax.nn.silu(_causal_conv(Cp, p["conv_C"]))

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt_f = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    # pad T to a chunk multiple; padded steps have dt=0 => identity updates
    Q = spec.mamba_chunk
    pad = (-T) % Q
    Tp = T + pad
    padt = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
    y, s_fin = ssd_chunked(
        padt(xx.astype(jnp.float32)).reshape(B_, Tp, H, P),
        padt(dt_f) * (jnp.arange(Tp) < T)[None, :, None], A,
        padt(Bp.astype(jnp.float32)).reshape(B_, Tp, G, N),
        padt(Cp.astype(jnp.float32)).reshape(B_, Tp, G, N),
        p["D"].astype(jnp.float32), Q=Q,
        s0=None if s0 is None else s0.astype(jnp.float32))
    y = y[:, :T].reshape(B_, T, H * P).astype(x.dtype)
    y = _gated_norm(p["norm"], y, z)
    out = jnp.einsum("bte,ed->btd", y, p["out"])
    w = spec.conv_width
    cache = MambaCache(
        ssm=s_fin,
        conv_x=xx_conv_in[:, T - (w - 1):, :],
        conv_B=Bp_in[:, T - (w - 1):, :],
        conv_C=Cp_in[:, T - (w - 1):, :],
    )
    return shd.constrain(out, "act_batch", "act_res_seq", "act_embed"), cache


def mamba_decode(p, x, cache: MambaCache, spec):
    """Single-token recurrent step.  x [B, 1, d] -> (y [B, 1, d], cache)."""
    B_ = x.shape[0]
    H, P, G, N = spec.m_heads, spec.headdim, spec.n_groups, spec.d_state
    w = spec.conv_width

    z, xx, Bp, Cp, dt = _projections(p, x)

    def conv_step(cache_c, new, kernel):
        window = jnp.concatenate([cache_c, new], axis=1)        # [B, w, C]
        out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                         kernel.astype(jnp.float32))
        return jax.nn.silu(out).astype(new.dtype), window[:, 1:, :]

    xx1, ncx = conv_step(cache.conv_x, xx, p["conv_x"])
    Bp1, ncb = conv_step(cache.conv_B, Bp, p["conv_B"])
    Cp1, ncc = conv_step(cache.conv_C, Cp, p["conv_C"])

    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [H]
    dt_f = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))   # [B,H]
    xh = xx1.astype(jnp.float32).reshape(B_, H, P)
    Bh = Bp1.astype(jnp.float32).reshape(B_, G, N)
    Ch = Cp1.astype(jnp.float32).reshape(B_, G, N)
    rep = H // G

    decay = jnp.exp(dt_f * A)                                    # [B,H]
    # state' = state*decay + (dt*x) outer B
    xdt = (xh * dt_f[..., None]).reshape(B_, G, rep, P)
    upd = jnp.einsum("bgn,bgrp->bgrpn", Bh, xdt).reshape(B_, H, P, N)
    s = cache.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bgn,bgrpn->bgrp", Ch,
                   s.reshape(B_, G, rep, P, N)).reshape(B_, H, P)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B_, 1, H * P).astype(x.dtype)
    y = _gated_norm(p["norm"], y, z)
    out = jnp.einsum("bte,ed->btd", y, p["out"])
    out = shd.constrain(out, "act_batch", None, "act_embed")
    return out, MambaCache(ssm=s, conv_x=ncx, conv_B=ncb, conv_C=ncc)
