"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The baseline scatter-dispatch MoE (models/moe.py) writes into a
[B, E, C, d] capacity buffer whose expert axis is model-sharded; GSPMD
cannot statically place data-dependent scatters, so it materializes the
buffer with all-gather-class collectives — the dry-run measured ~70 TB of
wire traffic per device per step on kimi-k2 train_4k (EXPERIMENTS.md §Perf).

This implementation routes tokens the way production MoE systems do:

  per device: route -> bucket (token,choice) pairs by destination model
  shard -> all_to_all over ``model`` -> local capacity dispatch -> expert
  FFN (resident expert shard) -> reverse all_to_all -> weighted combine.

Only the selected tokens cross the wire: ~ T_loc * k * d * 2 bytes * 2
directions per layer, about three orders of magnitude less than the
scatter baseline.  Everything is shape-static (capacity-bounded), so it
jits/lowers like any other layer; autodiff flows through all_to_all and
the scatters.

Selected per-config via ``ModelConfig.moe_impl = "a2a"``; falls back to the
scatter path when no mesh with a ``model`` axis is active (single-device
tests) or when E doesn't divide by the model axis.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.models.layers import mlp
from repro.models import moe as moe_base


def _positions_by_dest(dest, n_dest: int, cap: int):
    """dest: [n] int32 destination ids.  Returns slot [n] within each
    destination's send bucket (sequential order, overflow >= cap)."""
    oh = jax.nn.one_hot(dest, n_dest, dtype=jnp.int32)          # [n, D]
    pos = jnp.cumsum(oh, axis=0) - oh                           # exclusive
    return jnp.take_along_axis(pos, dest[:, None], axis=1)[:, 0]


_Q8_GROUP = 128


def _q8(t):
    """Per-128-group int8 quantization for a2a payloads (outlier-robust,
    DeepSeek-V3-style): returns (int8 values, f32 per-group scales)."""
    shape = t.shape
    g = shape[-1] // _Q8_GROUP
    tg = t.astype(jnp.float32).reshape(shape[:-1] + (g, _Q8_GROUP))
    s = jnp.maximum(jnp.max(jnp.abs(tg), axis=-1, keepdims=True),
                    1e-8) / 127.0
    q = jnp.clip(jnp.round(tg / s), -127, 127)
    return q.astype(jnp.int8).reshape(shape), s


def _dq8(q, s, dtype):
    shape = q.shape
    g = shape[-1] // _Q8_GROUP
    qg = q.astype(jnp.float32).reshape(shape[:-1] + (g, _Q8_GROUP))
    return (qg * s).reshape(shape).astype(dtype)


def moe_apply_a2a(p, x, spec, mesh, axis: str = "model",
                  quantize: bool = False):
    """x: [B, S, d] global under pjit.  Returns (y, aux).

    The sequence axis is split over ``model`` on entry whenever divisible:
    each model column routes 1/mp of its data-row's tokens, which divides
    every dispatch buffer (and the all-to-all wire bytes) by mp.  Without
    the split, tokens are replicated across the model axis and each column
    routes the full row (measured 10x extra a2a traffic on kimi train_4k —
    EXPERIMENTS.md §Perf iteration A-2).

    ``quantize=True`` sends int8 payloads (+f32 per-token scales) through
    the *dispatch* all-to-all — 2x wire reduction on that direction at <1%
    relative token error.  The return path stays bf16: expert outputs are
    often dominated by a few large coordinates, and per-row int8 there
    costs ~30% relative logit error on a 3-layer probe (iteration A-5)."""
    mp = mesh.shape[axis]
    E = spec.n_experts
    e_loc = E // mp
    d = x.shape[-1]
    k = spec.top_k
    cf = spec.capacity_factor

    data_axes = tuple(a for a in mesh.axis_names if a != axis)
    seq_split = x.shape[1] % mp == 0 and x.shape[1] >= mp
    x_spec = P(data_axes, axis if seq_split else None, None)
    router_spec = P(None, None)
    w_spec = P(axis, None, None)

    def body(xb, wr, wg, wu, wo):
        B_loc, S, _ = xb.shape
        t = B_loc * S
        xt = xb.reshape(t, d)

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            wr.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        w, eidx = jax.lax.top_k(probs, k)                        # [t, k]
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

        n = t * k
        eflat = eidx.reshape(n)
        wflat = w.reshape(n)
        dest = eflat // e_loc                                    # model shard
        cs = int(np.ceil(t * k / mp * cf / 8.0) * 8)             # send cap
        slot = _positions_by_dest(dest, mp, cs)
        keep = slot < cs
        slot_c = jnp.minimum(slot, cs - 1)

        # masked .add everywhere: overflow entries contribute zeros instead
        # of stomping the clamped slot (slots are unique for kept entries)
        src = jnp.arange(n, dtype=jnp.int32)
        send_x = jnp.zeros((mp, cs, d), xb.dtype).at[dest, slot_c].add(
            jnp.where(keep[:, None], xt[src // k], 0.0), mode="drop")
        # metadata: local expert id (+1; 0 = empty), source flat index (+1)
        send_e = jnp.zeros((mp, cs), jnp.int32).at[dest, slot_c].add(
            jnp.where(keep, eflat % e_loc + 1, 0), mode="drop")
        send_s = jnp.zeros((mp, cs), jnp.int32).at[dest, slot_c].add(
            jnp.where(keep, src + 1, 0), mode="drop")

        if quantize:
            sq, ss = _q8(send_x)
            recv_x = _dq8(jax.lax.all_to_all(sq, axis, 0, 0, tiled=False),
                          jax.lax.all_to_all(ss, axis, 0, 0, tiled=False),
                          xb.dtype)
        else:
            recv_x = jax.lax.all_to_all(send_x, axis, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, axis, 0, 0, tiled=False)

        # local dispatch into the resident expert shard
        rx = recv_x.reshape(mp * cs, d)
        re = recv_e.reshape(mp * cs)
        valid = re > 0
        le = jnp.where(valid, re - 1, 0)
        C2 = int(np.ceil(mp * cs / e_loc * cf / 8.0) * 8)
        pos2 = _positions_by_dest(jnp.where(valid, le, e_loc), e_loc + 1, C2)
        keep2 = valid & (pos2 < C2)
        pos2c = jnp.minimum(pos2, C2 - 1)
        buf = jnp.zeros((e_loc, C2, d), xb.dtype).at[le, pos2c].add(
            jnp.where(keep2[:, None], rx, 0.0), mode="drop")

        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        hidden = jax.nn.silu(g) * u
        out = jnp.einsum("ecf,efd->ecd", hidden, wo)

        # gather back to recv slots, reverse a2a, combine at the source
        y_slots = jnp.where(keep2[:, None],
                            out[le, pos2c], 0.0).reshape(mp, cs, d)
        back = jax.lax.all_to_all(y_slots, axis, 0, 0, tiled=False)
        # back[dst, slot] now holds results for our original send buckets
        y_tok = jnp.zeros((n, d), xb.dtype)
        flat_src = send_s.reshape(mp * cs) - 1          # -1 = empty slot
        y_tok = y_tok.at[jnp.maximum(flat_src, 0)].add(
            jnp.where((flat_src >= 0)[:, None],
                      back.reshape(mp * cs, d), 0.0), mode="drop")
        y = jnp.einsum("tkd,tk->td",
                       y_tok.reshape(t, k, d),
                       wflat.reshape(t, k).astype(xb.dtype))

        # aux losses (local estimates; pjit averages via the outer mean)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32),
                      axis=0)
        lb = E * jnp.sum(me * ce)
        zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
        aux = spec.aux_loss_coef * lb + spec.router_z_coef * zl
        aux = jax.lax.pmean(aux, axis)
        for a in data_axes:
            aux = jax.lax.pmean(aux, a)
        return y.reshape(B_loc, S, d), aux

    shard = shd.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, router_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    y, aux = shard(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    if "shared" in p:
        y = y + mlp(p["shared"], x, kind="swiglu")
    return y, aux


def moe_apply_a2a_2d(p, x, spec, mesh, axis: str = "model",
                     ff_axis: str = "data"):
    """Weight-resident serving variant: experts sharded over ``model`` (EP)
    AND their ff dim over ``data`` — no FSDP weight gathers at all.  Tokens
    are replicated across ``data`` on entry (trivial for decode: one token
    per sequence) so the partial-ff contributions reduce with a tiny
    ``psum`` of activations instead of tens-of-GB weight all-gathers
    (EXPERIMENTS.md §Perf iteration B)."""
    mp = mesh.shape[axis]
    E = spec.n_experts
    e_loc = E // mp
    d = x.shape[-1]
    k = spec.top_k
    cf = spec.capacity_factor
    data_axes = tuple(a for a in mesh.axis_names if a != axis)

    x_spec = P(None, None, None)             # tokens replicated over data
    router_spec = P(None, None)
    wi_spec = P(axis, None, ff_axis)         # [E, d, ff]: EP x ff-sharded
    wo_spec = P(axis, ff_axis, None)

    def body(xb, wr, wg, wu, wo):
        B_, S, _ = xb.shape
        t = B_ * S
        xt = xb.reshape(t, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            wr.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        w, eidx = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

        n = t * k
        eflat = eidx.reshape(n)
        dest = eflat // e_loc
        cs = int(np.ceil(t * k / mp * cf / 8.0) * 8)
        slot = _positions_by_dest(dest, mp, cs)
        keep = slot < cs
        slot_c = jnp.minimum(slot, cs - 1)
        src = jnp.arange(n, dtype=jnp.int32)
        send_x = jnp.zeros((mp, cs, d), xb.dtype).at[dest, slot_c].add(
            jnp.where(keep[:, None], xt[src // k], 0.0), mode="drop")
        send_e = jnp.zeros((mp, cs), jnp.int32).at[dest, slot_c].add(
            jnp.where(keep, eflat % e_loc + 1, 0), mode="drop")
        send_s = jnp.zeros((mp, cs), jnp.int32).at[dest, slot_c].add(
            jnp.where(keep, src + 1, 0), mode="drop")
        recv_x = jax.lax.all_to_all(send_x, axis, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, axis, 0, 0, tiled=False)

        rx = recv_x.reshape(mp * cs, d)
        re = recv_e.reshape(mp * cs)
        valid = re > 0
        le = jnp.where(valid, re - 1, 0)
        C2 = int(np.ceil(mp * cs / e_loc * cf / 8.0) * 8)
        pos2 = _positions_by_dest(jnp.where(valid, le, e_loc), e_loc + 1, C2)
        keep2 = valid & (pos2 < C2)
        pos2c = jnp.minimum(pos2, C2 - 1)
        buf = jnp.zeros((e_loc, C2, d), xb.dtype).at[le, pos2c].add(
            jnp.where(keep2[:, None], rx, 0.0), mode="drop")

        g = jnp.einsum("ecd,edf->ecf", buf, wg)      # ff-sharded over data
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        hidden = jax.nn.silu(g) * u
        out = jnp.einsum("ecf,efd->ecd", hidden, wo)  # partial over ff shard
        for a in (ff_axis if isinstance(ff_axis, tuple) else (ff_axis,)):
            out = jax.lax.psum(out, a)                # tiny: slots x d

        y_slots = jnp.where(keep2[:, None],
                            out[le, pos2c], 0.0).reshape(mp, cs, d)
        back = jax.lax.all_to_all(y_slots, axis, 0, 0, tiled=False)
        y_tok = jnp.zeros((n, d), xb.dtype)
        flat_src = send_s.reshape(mp * cs) - 1
        y_tok = y_tok.at[jnp.maximum(flat_src, 0)].add(
            jnp.where((flat_src >= 0)[:, None],
                      back.reshape(mp * cs, d), 0.0), mode="drop")
        y = jnp.einsum("tkd,tk->td", y_tok.reshape(t, k, d),
                       w.reshape(t, k).astype(xb.dtype))
        aux = jnp.asarray(0.0, jnp.float32)
        return y.reshape(B_, S, d), aux

    shard = shd.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, router_spec, wi_spec, wi_spec, wo_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    y, aux = shard(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    if "shared" in p:
        y = y + mlp(p["shared"], x, kind="swiglu")
    return y, aux


def moe_apply(p, x, spec, impl: str = "scatter"):
    """Dispatching wrapper: a2a when requested and a model axis is active."""
    mesh = shd.active_mesh()
    usable = mesh is not None and "model" in mesh.axis_names \
        and mesh.shape["model"] > 1 \
        and spec.n_experts % mesh.shape["model"] == 0
    if impl == "a2a" and usable:
        return moe_apply_a2a(p, x, spec, mesh)
    if impl == "a2a_q8" and usable:
        return moe_apply_a2a(p, x, spec, mesh, quantize=True)
    if impl == "a2a2d" and usable:
        return moe_apply_a2a_2d(p, x, spec, mesh)
    return moe_base.moe_apply(p, x, spec)
