"""Composable decoder model over block patterns (dense / MoE / SSM / hybrid).

* ``model_defs``      — ParamDef tree (stacked block params for scan).
* ``forward``         — train-time logits (+ aux losses).
* ``prefill``         — logits + per-layer caches for serving.
* ``decode_step``     — one-token step against stacked caches (``serve_step``
                        in the dry-run lowers this).

The repeated block pattern is scanned (HLO size independent of depth);
remainder layers are applied unrolled.  Remat policy is configurable.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.configs.base import LayerCtx, LayerSpec, ModelConfig, layer_ctx
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.layers import (
    embed, embed_defs, mlp, mlp_defs, rmsnorm, rmsnorm_defs,
    sinusoidal_positions, unembed, unembed_defs,
)
from repro.models.params import ParamDef, stack_defs


# ---------------------------------------------------------------------------
# definitions
# ---------------------------------------------------------------------------

def layer_defs(cfg: ModelConfig, ls: LayerSpec):
    ctx = layer_ctx(cfg, ls)
    d = {"pre_norm": rmsnorm_defs(cfg.d_model)}
    if ls.kind == "attn":
        d["attn"] = attn.attention_defs(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qk_norm=cfg.qk_norm, qkv_bias=cfg.qkv_bias)
    elif ls.kind == "mamba":
        d["mamba"] = mb.mamba_defs(ctx)
    else:
        raise ValueError(ls.kind)
    if cfg.sandwich_norm:
        d["post_mix_norm"] = rmsnorm_defs(cfg.d_model)
    if ls.moe or ls.mlp:
        d["mlp_norm"] = rmsnorm_defs(cfg.d_model)
        if ls.moe:
            d["moe"] = moe_mod.moe_defs(
                cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
                cfg.n_shared_experts)
        else:
            d["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, kind=cfg.mlp_kind)
        if cfg.sandwich_norm:
            d["post_mlp_norm"] = rmsnorm_defs(cfg.d_model)
    return d


def model_defs(cfg: ModelConfig):
    block = {f"sub{j}": layer_defs(cfg, ls)
             for j, ls in enumerate(cfg.pattern)}
    defs = {
        "embed": embed_defs(cfg.vocab, cfg.d_model),
        "blocks": stack_defs(block, cfg.n_blocks),
        "final_norm": rmsnorm_defs(cfg.d_model),
    }
    for j, ls in enumerate(cfg.remainder):
        defs[f"rem{j}"] = layer_defs(cfg, ls)
    if not cfg.tie_embeddings:
        defs["lm_head"] = unembed_defs(cfg.d_model, cfg.vocab)
    return defs


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _apply_layer_full(cfg, ls, p, h, positions, want_cache: bool,
                      max_len: Optional[int] = None):
    """Full-sequence layer (train/prefill). Returns (h, aux, cache|None)."""
    ctx = layer_ctx(cfg, ls)
    res = h
    u = rmsnorm(p["pre_norm"], h, cfg.norm_eps)
    cache = None
    if ls.kind == "attn":
        mix, (k, v) = attn.attend_train(p["attn"], u, positions, ctx)
        if want_cache:
            pos2 = positions if positions.ndim == 2 else positions[0]
            cache = _kv_cache_from_prefill(ctx, k, v, pos2, cfg, max_len)
    else:
        mix, mcache = mb.mamba_train(p["mamba"], u, ctx)
        if want_cache:
            cache = mcache
    if cfg.sandwich_norm:
        mix = rmsnorm(p["post_mix_norm"], mix, cfg.norm_eps)
    h = res + mix
    aux = jnp.asarray(0.0, jnp.float32)
    if not (ls.moe or ls.mlp):
        return h, aux, cache
    res = h
    u = rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
    if ls.moe:
        from repro.models.moe_a2a import moe_apply as moe_dispatch
        y, aux = moe_dispatch(p["moe"], u, ctx, impl=cfg.moe_impl)
    else:
        y = mlp(p["mlp"], u, kind=cfg.mlp_kind)
    if cfg.sandwich_norm:
        y = rmsnorm(p["post_mlp_norm"], y, cfg.norm_eps)
    return res + y, aux, cache


def _kv_cache_from_prefill(ctx, k, v, positions, cfg, max_len=None):
    """Place prefill K/V (already rotated) into a ring cache of the layer's
    cache size (capacity ``max_len``), slotting position p at p % size."""
    B, S = positions.shape
    size = attn.kv_cache_size(ctx, max_len or S)
    if size >= S:
        pad = size - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pc = jnp.pad(positions.astype(jnp.int32), ((0, 0), (0, pad)),
                     constant_values=-1)
    else:
        # windowed/pruned layer: keep the last `size` tokens, ring-placed
        k_tail = k[:, S - size:, :, :]
        v_tail = v[:, S - size:, :, :]
        pos_tail = positions[:, S - size:].astype(jnp.int32)
        slots = jnp.mod(pos_tail, size)                   # [B, size]
        bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, size))
        kc = jnp.zeros_like(k_tail).at[bidx, slots].set(k_tail)
        vc = jnp.zeros_like(v_tail).at[bidx, slots].set(v_tail)
        pc = jnp.full((B, size), -1, jnp.int32).at[bidx, slots].set(pos_tail)
    if attn._quantized(ctx):
        kq, ks = attn._quantize_kv(kc)
        vq, vs = attn._quantize_kv(vc)
        return attn.KVCache(k=kq, v=vq, pos_ids=pc, k_scale=ks, v_scale=vs)
    one = jnp.ones((1,), jnp.float32)
    return attn.KVCache(k=kc, v=vc, pos_ids=pc, k_scale=one, v_scale=one)


def _apply_layer_decode(cfg, ls, p, h, pos, cache):
    ctx = layer_ctx(cfg, ls)
    res = h
    u = rmsnorm(p["pre_norm"], h, cfg.norm_eps)
    if ls.kind == "attn":
        mix, cache = attn.attend_decode(p["attn"], u, pos, cache, ctx)
    else:
        mix, cache = mb.mamba_decode(p["mamba"], u, cache, ctx)
    if cfg.sandwich_norm:
        mix = rmsnorm(p["post_mix_norm"], mix, cfg.norm_eps)
    h = res + mix
    if not (ls.moe or ls.mlp):
        return h, cache
    res = h
    u = rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
    if ls.moe:
        from repro.models.moe_a2a import moe_apply as moe_dispatch
        y, _ = moe_dispatch(p["moe"], u, ctx, impl=cfg.moe_impl)
    else:
        y = mlp(p["mlp"], u, kind=cfg.mlp_kind)
    if cfg.sandwich_norm:
        y = rmsnorm(p["post_mlp_norm"], y, cfg.norm_eps)
    return res + y, cache


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = embed(params["embed"], tokens, scale_by_dim=cfg.scale_embed)
    h = h.astype(cfg.adtype())
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.adtype())   # [B, n_patches, d]
        h = jnp.concatenate([pe, h[:, pe.shape[1]:, :]], axis=1)
    if cfg.pos == "mrope":
        positions = batch.get("positions")
        if positions is None:
            base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            positions = jnp.broadcast_to(base[None], (3, B, S))
    else:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.pos == "sinusoidal":
            h = h + sinusoidal_positions(positions, cfg.d_model).astype(h.dtype)
    return h, positions


def _head(cfg, params, h):
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    return unembed(params.get("lm_head"), h, tied_table=tied)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch):
    """Training forward: logits [B, S, V] f32 + scalar aux loss."""
    h, positions = _embed_inputs(cfg, params, batch)

    def block_body(carry, block_params):
        hh, aux = carry
        for j, ls in enumerate(cfg.pattern):
            hh, a, _ = _apply_layer_full(cfg, ls, block_params[f"sub{j}"],
                                         hh, positions, want_cache=False)
            aux = aux + a
        return (hh, aux), None

    body = _remat_wrap(cfg, block_body)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.asarray(0.0, jnp.float32)),
                               params["blocks"])
    for j, ls in enumerate(cfg.remainder):
        h, a, _ = _apply_layer_full(cfg, ls, params[f"rem{j}"], h,
                                    positions, want_cache=False)
        aux = aux + a
    return _head(cfg, params, h), aux


def prefill(params, cfg: ModelConfig, batch, max_len: Optional[int] = None):
    """Prefill: last-position logits + caches (stacked for the scan blocks).

    ``max_len`` sets cache capacity for subsequent decode steps."""
    h, positions = _embed_inputs(cfg, params, batch)

    def block_body(carry, block_params):
        hh = carry
        caches = {}
        for j, ls in enumerate(cfg.pattern):
            hh, _, c = _apply_layer_full(cfg, ls, block_params[f"sub{j}"],
                                         hh, positions, want_cache=True,
                                         max_len=max_len)
            caches[f"sub{j}"] = c
        return hh, caches

    h, block_caches = jax.lax.scan(block_body, h, params["blocks"])
    caches = {"blocks": block_caches}
    for j, ls in enumerate(cfg.remainder):
        h, _, c = _apply_layer_full(cfg, ls, params[f"rem{j}"], h,
                                    positions, want_cache=True,
                                    max_len=max_len)
        caches[f"rem{j}"] = c
    logits = _head(cfg, params, h[:, -1:, :])
    return logits, caches


def decode_step(params, cfg: ModelConfig, token, caches, pos):
    """One decode step: token [B, 1] int32, pos scalar int32.

    Returns (logits [B, 1, V], new caches).  This is ``serve_step``.
    """
    batch = {"tokens": token}
    h = embed(params["embed"], token, scale_by_dim=cfg.scale_embed)
    h = h.astype(cfg.adtype())
    if cfg.pos == "sinusoidal":
        p1 = jnp.full((token.shape[0], 1), pos, jnp.int32)
        h = h + sinusoidal_positions(p1, cfg.d_model).astype(h.dtype)

    def block_body(carry, xs):
        hh = carry
        block_params, block_cache = xs
        new_cache = {}
        for j, ls in enumerate(cfg.pattern):
            hh, c = _apply_layer_decode(cfg, ls, block_params[f"sub{j}"],
                                        hh, pos, block_cache[f"sub{j}"])
            new_cache[f"sub{j}"] = c
        return hh, new_cache

    h, new_block_caches = jax.lax.scan(
        block_body, h, (params["blocks"], caches["blocks"]))
    out_caches = {"blocks": new_block_caches}
    for j, ls in enumerate(cfg.remainder):
        h, c = _apply_layer_decode(cfg, ls, params[f"rem{j}"], h, pos,
                                   caches[f"rem{j}"])
        out_caches[f"rem{j}"] = c
    logits = _head(cfg, params, h)
    return logits, out_caches


# ---------------------------------------------------------------------------
# cache initialization / dry-run specs
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, B: int, max_len: int, dtype=None):
    dtype = dtype or cfg.adtype()

    def one(ls: LayerSpec):
        ctx = layer_ctx(cfg, ls)
        if ls.kind == "attn":
            return attn.init_kv_cache(ctx, B, max_len, dtype)
        return mb.init_mamba_cache(ctx, B, dtype)

    def stack(c):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_blocks,) + a.shape), c)

    caches = {"blocks": {f"sub{j}": stack(one(ls))
                         for j, ls in enumerate(cfg.pattern)}}
    for j, ls in enumerate(cfg.remainder):
        caches[f"rem{j}"] = one(ls)
    return caches


def cache_specs(cfg: ModelConfig, B: int, max_len: int, mesh, rules,
                dtype=None):
    """ShapeDtypeStructs (with shardings) for the dry-run serve_step."""
    dtype = dtype or cfg.adtype()

    def one(ls: LayerSpec):
        ctx = layer_ctx(cfg, ls)
        if ls.kind == "attn":
            return attn.kv_cache_specs(ctx, B, max_len, dtype, mesh, rules)
        return mb.mamba_cache_specs(ctx, B, dtype, mesh, rules)

    def stack(c):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (cfg.n_blocks,) + s.shape, s.dtype,
                sharding=_stacked_sharding(s, mesh)), c)

    caches = {"blocks": {f"sub{j}": stack(one(ls))
                         for j, ls in enumerate(cfg.pattern)}}
    for j, ls in enumerate(cfg.remainder):
        caches[f"rem{j}"] = one(ls)
    return caches


def _stacked_sharding(s: jax.ShapeDtypeStruct, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = s.sharding.spec if hasattr(s.sharding, "spec") else P()
    return NamedSharding(mesh, P(*((None,) + tuple(spec))))
