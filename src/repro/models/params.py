"""Parameter definition trees: one declaration site for shape, dtype,
logical sharding axes, and initializer.

A model builds a nested dict of :class:`ParamDef`; from it we derive
* real parameters (``init_params`` — deterministic per-path RNG folding),
* abstract parameters for the dry-run (``abstract_params`` —
  ``ShapeDtypeStruct`` with ``NamedSharding``, no allocation),
* sharding specs (``param_shardings``), and
* parameter counts (``count_params``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Any, ...]                 # logical axes, len == len(shape)
    dtype: Any = jnp.float32
    init: str = "linear"                  # linear | embed | zeros | ones
    fan_axis: int = 0                     # fan-in dim for "linear"
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def _map_defs(fn, defs, path=()):
    if _is_def(defs):
        return fn(path, defs)
    return {k: _map_defs(fn, v, path + (k,)) for k, v in defs.items()}


def init_params(defs, key: jax.Array, param_dtype=None):
    """Materialize parameters; RNG folded deterministically per tree path."""

    def one(path, d: ParamDef):
        dtype = param_dtype or d.dtype
        k = key
        for p in path:
            k = jax.random.fold_in(k, hash(p) % (2 ** 31))
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "embed":
            return (jax.random.normal(k, d.shape, jnp.float32)
                    * d.scale).astype(dtype)
        fan_in = d.shape[d.fan_axis]
        std = d.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)

    return _map_defs(one, defs)


def abstract_params(defs, mesh=None, rules=None, param_dtype=None):
    """ShapeDtypeStruct tree with NamedSharding — dry-run stand-ins."""

    def one(path, d: ParamDef):
        dtype = param_dtype or d.dtype
        if mesh is not None:
            s = shd.named_sharding(d.shape, d.axes, mesh, rules)
            return jax.ShapeDtypeStruct(d.shape, dtype, sharding=s)
        return jax.ShapeDtypeStruct(d.shape, dtype)

    return _map_defs(one, defs)


def param_shardings(defs, mesh=None, rules=None):
    def one(path, d: ParamDef):
        return shd.named_sharding(d.shape, d.axes, mesh, rules)

    return _map_defs(one, defs)


def param_specs(defs, mesh=None, rules=None):
    def one(path, d: ParamDef):
        return shd.spec_for(d.shape, d.axes, mesh, rules)

    return _map_defs(one, defs)


def count_params(defs) -> int:
    total = 0

    def one(path, d: ParamDef):
        nonlocal total
        total += int(np.prod(d.shape))
        return None

    _map_defs(one, defs)
    return total


def stack_defs(defs, n: int, axis_name=None):
    """Add a leading layer/stage axis of size n to every def (for scan)."""

    def one(path, d: ParamDef):
        return ParamDef(shape=(n,) + d.shape, axes=(axis_name,) + d.axes,
                        dtype=d.dtype, init=d.init,
                        fan_axis=d.fan_axis + 1, scale=d.scale)

    return _map_defs(one, defs)
