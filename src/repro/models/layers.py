"""Core layers: RMSNorm, rotary embeddings (RoPE / M-RoPE / sinusoidal),
embedding, and gated/plain MLPs.  Pure functions over ParamDef trees."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(dim: int, axes=("none",)):
    return {"scale": ParamDef((dim,), axes, init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, dh]; positions: [..., S] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.asarray(rope_freqs(dh, theta))                # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs    # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float = 10000.0):
    """Multimodal RoPE (Qwen2-VL): three position streams (t, h, w) rotate
    disjoint frequency sections of the head dim.

    x: [B, S, H, dh]; positions3: [3, B, S]; sections: half-dim split,
    sum(sections) == dh // 2.
    """
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(dh, theta))                # [half]
    # pick, per frequency index, which position stream drives it
    sel = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos_per_freq = jnp.take(positions3, jnp.asarray(sel), axis=0)  # [half,B,S]
    ang = jnp.einsum("fbs,f->bsf", pos_per_freq.astype(jnp.float32), freqs)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, dim: int):
    """Classic transformer sinusoidal embedding; positions [..., S] -> [..., S, dim]."""
    half = dim // 2
    freqs = 1.0 / (10000.0 ** (np.arange(half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_defs(vocab: int, d: int):
    return {"table": ParamDef((vocab, d), ("embed_vocab", "fsdp"),
                              init="embed", scale=1.0)}


def embed(p, tokens, *, scale_by_dim: bool = False):
    h = jnp.take(p["table"], tokens, axis=0)
    if scale_by_dim:
        h = h * jnp.asarray(np.sqrt(p["table"].shape[1]), h.dtype)
    return shd.constrain(h, "act_batch", "act_res_seq", "act_embed")


def unembed_defs(d: int, vocab: int):
    return {"kernel": ParamDef((d, vocab), ("fsdp", "embed_vocab"))}


def unembed(p, h, *, tied_table=None, compute_dtype=jnp.float32):
    if tied_table is not None:
        logits = jnp.einsum("...d,vd->...v", h.astype(compute_dtype),
                            tied_table.astype(compute_dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", h.astype(compute_dtype),
                            p["kernel"].astype(compute_dtype))
    return shd.constrain(logits, "act_batch", "act_seq", "act_vocab")


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_defs(d: int, ff: int, kind: str = "swiglu"):
    if kind == "swiglu":
        return {
            "wi_gate": ParamDef((d, ff), ("fsdp", "tp")),
            "wi_up": ParamDef((d, ff), ("fsdp", "tp")),
            "wo": ParamDef((ff, d), ("tp", "fsdp")),
        }
    if kind == "gelu":
        return {
            "wi": ParamDef((d, ff), ("fsdp", "tp")),
            "wo": ParamDef((ff, d), ("tp", "fsdp")),
        }
    raise ValueError(kind)


def mlp(p, x, kind: str = "swiglu"):
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, p["wi_up"])
        g = shd.constrain(g, "act_batch", "act_seq", "act_ff")
        u = shd.constrain(u, "act_batch", "act_seq", "act_ff")
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        h = shd.constrain(h, "act_batch", "act_seq", "act_ff")
        h = jax.nn.gelu(h)
    out = jnp.einsum("...f,fd->...d", h, p["wo"])
    return shd.constrain(out, "act_batch", "act_res_seq", "act_embed")
