"""Mixture-of-Experts with top-k routing and scatter-based dispatch.

Design (DESIGN.md hardware-adaptation): GShard-style dispatch *einsums* are
O(T·E·C·d) — at 384 experts they would dwarf the expert FFN itself — so
dispatch here is position-computation (per-group one-hot cumsums) + scatter
into a capacity buffer ``[B, E, C, d]`` and gather on the way back.  Tokens
are grouped by batch row (already data-sharded), experts are sharded over
the ``model`` axis (EP); the buffer is 2D-sharded, which makes the SPMD
partitioner materialize the token->expert exchange as all-to-all-class
collectives (visible in the dry-run roofline).

Capacity overflow drops tokens (standard); the residual stream carries them.
Aux losses: switch-style load-balancing + router z-loss, returned to the
caller for accumulation across layers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.models.layers import mlp, mlp_defs
from repro.models.params import ParamDef


def moe_defs(d: int, ff: int, n_experts: int, n_shared: int = 0):
    defs = {
        "router": ParamDef((d, n_experts), ("fsdp", None), scale=0.1),
        "wi_gate": ParamDef((n_experts, d, ff), ("experts", "fsdp", None),
                            fan_axis=1),
        "wi_up": ParamDef((n_experts, d, ff), ("experts", "fsdp", None),
                          fan_axis=1),
        "wo": ParamDef((n_experts, ff, d), ("experts", None, "fsdp"),
                       fan_axis=1),
    }
    if n_shared:
        defs["shared"] = mlp_defs(d, ff * n_shared, kind="swiglu")
    return defs


def _positions_in_expert(eidx, n_experts: int):
    """GShard position computation, per batch-row group.

    eidx: [B, S, k] expert ids.  Returns pos [B, S, k] int32: the slot each
    assignment takes inside its (batch-row, expert) capacity bucket, counting
    choice 0 of all tokens first, then choice 1, etc.
    """
    B, S, k = eidx.shape
    base = jnp.zeros((B, n_experts), jnp.int32)
    pos = []
    for j in range(k):
        oh = jax.nn.one_hot(eidx[:, :, j], n_experts, dtype=jnp.int32)
        cum = jnp.cumsum(oh, axis=1) - oh                       # exclusive
        pos_j = jnp.take_along_axis(
            cum + base[:, None, :], eidx[:, :, j:j + 1], axis=2)[..., 0]
        base = base + jnp.sum(oh, axis=1)
        pos.append(pos_j)
    return jnp.stack(pos, axis=-1)


def moe_apply(p, x, spec):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E = spec.n_experts
    k = spec.top_k
    cf = spec.capacity_factor
    C = int(np.ceil(S * k / E * cf / 8.0) * 8)
    C = max(C, 8)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, eidx = jax.lax.top_k(probs, k)                           # [B,S,k]
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    pos = _positions_in_expert(eidx, E)                         # [B,S,k]
    keep = pos < C
    pos_c = jnp.minimum(pos, C - 1)

    # scatter tokens into the capacity buffer [B, E, C, d]
    bb = jnp.broadcast_to(jnp.arange(B)[:, None, None], (B, S, k))
    xb = jnp.broadcast_to(x[:, :, None, :], (B, S, k, d))
    xb = jnp.where(keep[..., None], xb, 0.0)
    buf = jnp.zeros((B, E, C, d), x.dtype)
    buf = buf.at[bb, eidx, pos_c].add(xb, mode="drop")
    buf = shd.constrain(buf, "act_batch", "act_experts", None, None)

    # expert FFN (experts sharded over `model`)
    g = jnp.einsum("becd,edf->becf", buf, p["wi_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["wi_up"])
    hidden = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", hidden, p["wo"])
    out_buf = shd.constrain(out_buf, "act_batch", "act_experts", None, None)

    # gather back + weighted combine
    y_tok = out_buf[bb, eidx, pos_c]                            # [B,S,k,d]
    wmask = (w * keep.astype(w.dtype)).astype(x.dtype)
    y = jnp.einsum("bskd,bsk->bsd", y_tok, wmask)
    y = shd.constrain(y, "act_batch", "act_res_seq", "act_embed")

    if "shared" in p:
        y = y + mlp(p["shared"], x, kind="swiglu")

    # aux: switch load-balance + router z-loss
    me = jnp.mean(probs, axis=(0, 1))                           # [E]
    ce = jnp.mean(
        jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    lb = E * jnp.sum(me * ce)
    zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = spec.aux_loss_coef * lb + spec.router_z_coef * zl
    return y, aux
