"""Adafactor (Shazeer & Stern 2018): factored second moments.

For the trillion-parameter MoE cells the optimizer state shrinks from
2x-fp32-params (AdamW) to ~rank-1 factors — the difference between fitting
and not fitting a pod (see EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.99
    eps: float = 1e-30
    clip_threshold: float = 1.0
    min_dim_factored: int = 128


class AdafactorState(NamedTuple):
    vr: dict     # row factors (or full v for small/1D params)
    vc: dict     # col factors (None-like zeros for unfactored)
    step: jax.Array


def _factored(shape, cfg) -> bool:
    return len(shape) >= 2 and shape[-1] >= cfg.min_dim_factored \
        and shape[-2] >= cfg.min_dim_factored


def adafactor_init(params, cfg: AdafactorConfig) -> AdafactorState:
    def vr_init(p):
        if _factored(p.shape, cfg):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc_init(p):
        if _factored(p.shape, cfg):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(vr=jax.tree.map(vr_init, params),
                          vc=jax.tree.map(vc_init, params),
                          step=jnp.zeros((), jnp.int32))


def adafactor_update(grads, state: AdafactorState, params, lr,
                     cfg: AdafactorConfig):
    step = state.step + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8  # decay schedule
    beta = jnp.minimum(beta, cfg.decay)

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if _factored(p.shape, cfg):
            vr_new = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc_new = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            rfac = vr_new / jnp.maximum(
                jnp.mean(vr_new, axis=-1, keepdims=True), cfg.eps)
            u = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc_new)[..., None, :]
                     + cfg.eps)
        else:
            vr_new = beta * vr + (1 - beta) * g2
            vc_new = vc
            u = g / (jnp.sqrt(vr_new) + cfg.eps)
        # update clipping (RMS threshold)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, vr_new, vc_new

    flat = jax.tree.map(upd, grads, state.vr, state.vc, params)
    istuple = lambda t: isinstance(t, tuple)
    p_new = jax.tree.map(lambda t: t[0], flat, is_leaf=istuple)
    vr = jax.tree.map(lambda t: t[1], flat, is_leaf=istuple)
    vc = jax.tree.map(lambda t: t[2], flat, is_leaf=istuple)
    return p_new, AdafactorState(vr=vr, vc=vc, step=step)
