"""AdamW in pure JAX (no optax in this environment).

Optimizer state mirrors the parameter tree, so pjit shards it identically to
the (FSDP-sharded) parameters — ZeRO falls out of the sharding rules.
``state_dtype`` lets trillion-parameter configs keep m/v in bf16.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[str] = None   # None: same as param dtype


class AdamWState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = cfg.state_dtype

    def z(p):
        return jnp.zeros(p.shape, jnp.dtype(dt) if dt else p.dtype)

    return AdamWState(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                      step=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, lr, cfg: AdamWConfig):
    step = state.step + 1
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, mf.astype(m.dtype), vf.astype(v.dtype)

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    p_new = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return p_new, AdamWState(m=m_new, v=v_new, step=step), gnorm
