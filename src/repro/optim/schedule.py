"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * (step + 1.0) / max(warmup, 1)   # lr > 0 from step 0
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full((), peak_lr, jnp.float32)


def warmup_linear(step, *, peak_lr: float, warmup: int, total: int, **_):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * (step + 1.0) / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return jnp.where(step < warmup, warm, peak_lr * (1.0 - prog))


SCHEDULES = {"warmup_cosine": warmup_cosine, "constant": constant,
             "warmup_linear": warmup_linear}
