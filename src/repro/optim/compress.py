"""Gradient compression with error feedback — CAMEO's idea applied to the
gradient plane (DESIGN.md §4 beyond-paper): keep only the *important points*
of each gradient tensor and let an error-feedback residual carry the rest,
exactly as CAMEO keeps statistically important samples and lets linear
interpolation carry the rest.

Two codecs:

* ``topk``  — keep the top ``ratio`` fraction by magnitude (line-
  simplification analog; the kept set is the "important points").
* ``int8``  — per-tensor scale quantization (8x volume reduction).

Used by ``train.dp_shardmap`` where the data-parallel all-reduce is explicit
(``psum``), so compressed bytes are visible in the dry-run collective
analysis.  Error feedback makes both codecs convergent (tested on a
quadratic in tests/test_optim.py).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    codec: str = "topk"    # "topk" | "int8" | "none"
    ratio: float = 0.05    # topk keep fraction


def topk_mask(g: jax.Array, ratio: float) -> jax.Array:
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(ratio * flat.shape[0]))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress(g: jax.Array, cfg: CompressConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (compressed-then-decompressed gradient, residual)."""
    if cfg.codec == "none":
        return g, jnp.zeros_like(g)
    if cfg.codec == "topk":
        m = topk_mask(g, cfg.ratio)
        kept = g * m
        return kept, g - kept
    if cfg.codec == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127)
        deq = q * scale
        return deq, g - deq
    raise ValueError(cfg.codec)


def compress_with_feedback(grads, residuals, cfg: CompressConfig):
    """Error feedback: compress (g + residual); the un-sent mass becomes the
    next residual.  Applied leaf-wise over the gradient tree."""
    def one(g, r):
        total = g.astype(jnp.float32) + r
        sent, new_r = compress(total, cfg)
        return sent, new_r

    pairs = jax.tree.map(one, grads, residuals)
    istuple = lambda t: isinstance(t, tuple)
    sent = jax.tree.map(lambda t: t[0], pairs, is_leaf=istuple)
    res = jax.tree.map(lambda t: t[1], pairs, is_leaf=istuple)
    return sent, res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
