"""Fused prefix-feasibility scan — the rounds-mode selection step.

One round of the batched-greedy mode picks the ``k_max`` lowest-impact
candidates (rank order), filters them to an independent set, and must then
find the largest rank prefix whose combined removal still satisfies the
deviation constraint.  The historical implementation bisected over prefix
length, re-running a dense O(nL) reconstruction + aggregate update per
probe.  This module computes the *whole deviation curve* — ``dev[j]`` =
exact deviation after applying candidates ``0..j`` — in one fused pass:

* reference backend — a closed-form vectorized evaluation.  Candidate
  segments are pairwise disjoint (independent-set invariant), so the linear
  aggregate deltas are a plain per-candidate einsum + cumulative sum; the
  quadratic terms (``sx2``/``sxl2``/``sxx``) see earlier candidates only
  through the running delta field ``D``, which is gathered per candidate
  from the exclusive cumulative delta rows.  O(K·(W + L)·L) total, no
  sequential dependence beyond two cumsums.

* pallas backend — a single kernel pass (`grid=(1,)`) holding the running
  reconstruction ``z = y + D`` in VMEM scratch; each rank step reads its
  ``W + 2L`` context via dynamic slices, updates the five aggregates and the
  scratch in place, and emits that prefix's deviation.  This is the fused
  form of Eq. 9 ranking + Eq. 10/11 maintenance the TPU path runs natively
  (interpret mode elsewhere, as with the other kernels in this package).

Both forms are exact for every ``kappa`` (the ``z``-context accounts for
boundary-bin sharing between segments mapped onto the aggregate series) and
take the valid length ``ny`` as a runtime scalar so padded-bucket callers
never recompile across lengths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


# ---------------------------------------------------------------------------
# reference form: vectorized deviation curve
# ---------------------------------------------------------------------------

def _moment_deltas(d, ctx, ystarts, ny, *, L: int, form: str = "auto"):
    """Five per-lag aggregate deltas ``[K, 5, L]`` for independent windowed
    deltas ``d [K, Wy]`` given their series context ``ctx [K, Wy + 2L]``.

    ``form`` picks the bilinear-term lowering: ``"einsum"`` (shift-basis
    contraction), ``"roll"`` (one batched roll-and-reduce over the lag
    axis), ``"slices"`` (L-unrolled static slices), or ``"auto"`` (roll on
    CPU, einsum elsewhere — see the comment at the term).

    Relies on the padded-bucket discipline — the series (and hence ``ctx``)
    is zero beyond ``ny`` and before 0, and deltas only touch valid
    positions — which makes every head/tail validity mask either implicit
    (the bilinear ``sxx`` term: invalid partners are zero) or a contiguous
    cut in the window axis (the four moment sums: prefix-sum gathers
    instead of ``[K, Wy, L]`` mask einsums).
    """
    K, Wy = d.shape
    l = jnp.arange(1, L + 1)

    z_at = ctx[:, L:L + Wy]
    e = d * (2.0 * z_at + d)
    # head keeps abs_t <= ny-1-l  <=>  j < ny - l - s   (contiguous prefix);
    # tail keeps abs_t >= l       <=>  j >= l - s       (contiguous suffix).
    cdz = jnp.pad(jnp.cumsum(d, axis=1), ((0, 0), (1, 0)))
    cez = jnp.pad(jnp.cumsum(e, axis=1), ((0, 0), (1, 0)))
    c_head = jnp.clip(ny - l[None, :] - ystarts[:, None], 0, Wy)
    c_tail = jnp.clip(l[None, :] - ystarts[:, None], 0, Wy)
    dsx = jnp.take_along_axis(cdz, c_head, axis=1)
    dsx2 = jnp.take_along_axis(cez, c_head, axis=1)
    dsxl = cdz[:, -1:] - jnp.take_along_axis(cdz, c_tail, axis=1)
    dsxl2 = cez[:, -1:] - jnp.take_along_axis(cez, c_tail, axis=1)

    # Bilinear term, three equivalent lowerings.  As a contraction, the
    # lag-shifted context reads are three gathers against a constant
    # [Wy, L] shift basis, summed and contracted in one einsum — O(1)
    # emitted ops, the right shape wherever gathers run at memory speed
    # (TPU).  XLA's CPU emitter however runs that gather an order of
    # magnitude slower than contiguous reads (measured ~8ms/window on the
    # stream bench), and the historical L-unrolled static-slice chain is
    # dispatch-bound on the legacy runtime (~1.5us per emitted op, 2L+ ops
    # per call) — so on CPU the lag axis is one *batched* roll-and-reduce:
    # a single vmapped op the emitter fuses into one [L, K, Wy] pass.  All
    # forms are pinned against each other by `tests/test_contractions.py`.
    d_pad = jnp.pad(d, ((0, 0), (0, L)))
    if form == "auto":
        form = "roll" if jax.default_backend() == "cpu" else "einsum"
    if form == "slices":
        dsxx = jnp.stack(
            [jnp.sum(d * (ctx[:, L + lag:L + lag + Wy]
                          + ctx[:, L - lag:L - lag + Wy]
                          + d_pad[:, lag:lag + Wy]), axis=1)
             for lag in range(1, L + 1)], axis=1)
    elif form == "roll":
        # No wraparound reaches the kept [:Wy] prefix: the largest shift is
        # L + lag <= 2L against width Wy + 2L (and lag <= L against the
        # d_pad width Wy + L), so no validity mask is needed.
        def lag_term(lag):
            g = (jnp.roll(ctx, -(L + lag), axis=1)[:, :Wy]
                 + jnp.roll(ctx, -(L - lag), axis=1)[:, :Wy]
                 + jnp.roll(d_pad, -lag, axis=1)[:, :Wy])
            return jnp.sum(d * g, axis=1)

        dsxx = jax.vmap(lag_term, out_axes=1)(l)
    else:
        w = jnp.arange(Wy)
        shift = w[:, None] + l[None, :]                   # [Wy, L]: w + lag
        G = ctx[:, L + shift] + ctx[:, (L + w[:, None]) - l[None, :]] \
            + d_pad[:, shift]
        dsxx = jnp.einsum("kw,kwl->kl", d, G)
    return jnp.stack([dsx, dsxl, dsx2, dsxl2, dsxx], axis=1)  # [K, 5, L]


def _moment_deltas_ref(d, ctx, ystarts, ny, *, L: int):
    """Loop oracle for :func:`_moment_deltas` — the historical L-unrolled
    slice-multiply-sum form of the bilinear term, kept for parity tests of
    the einsum contraction (`tests/test_contractions.py`)."""
    K, Wy = d.shape
    l = jnp.arange(1, L + 1)
    z_at = ctx[:, L:L + Wy]
    e = d * (2.0 * z_at + d)
    cdz = jnp.pad(jnp.cumsum(d, axis=1), ((0, 0), (1, 0)))
    cez = jnp.pad(jnp.cumsum(e, axis=1), ((0, 0), (1, 0)))
    c_head = jnp.clip(ny - l[None, :] - ystarts[:, None], 0, Wy)
    c_tail = jnp.clip(l[None, :] - ystarts[:, None], 0, Wy)
    dsx = jnp.take_along_axis(cdz, c_head, axis=1)
    dsx2 = jnp.take_along_axis(cez, c_head, axis=1)
    dsxl = cdz[:, -1:] - jnp.take_along_axis(cdz, c_tail, axis=1)
    dsxl2 = cez[:, -1:] - jnp.take_along_axis(cez, c_tail, axis=1)
    d_pad = jnp.pad(d, ((0, 0), (0, L)))
    dsxx = jnp.stack(
        [jnp.sum(d * (ctx[:, L + lag:L + lag + Wy]
                      + ctx[:, L - lag:L - lag + Wy]
                      + d_pad[:, lag:lag + Wy]), axis=1)
         for lag in range(1, L + 1)], axis=1)
    return jnp.stack([dsx, dsxl, dsx2, dsxl2, dsxx], axis=1)  # [K, 5, L]


def solo_moment_rows(y, dyws, ystarts, ny, *, L: int):
    """Aggregate-delta rows ``[K, 5, L]`` for each candidate applied *alone*
    on the current reconstruction (context gathered from ``y`` only)."""
    K, Wy = dyws.shape
    nyb = y.shape[0]
    dt = y.dtype
    starts = jnp.clip(ystarts, 0, nyb - 1)
    kk = jnp.arange(Wy + 2 * L)
    ctx = jnp.pad(y, (L, L + Wy))[starts[:, None] + kk[None, :]]
    return _moment_deltas(dyws.astype(dt), ctx, ystarts, ny, L=L)


def window_acf_rows(y, dyws, ystarts, agg_table, ny, *, L: int):
    """Independent per-candidate Eq. 9 ACF rows ``[K, L]`` under the
    padded-bucket discipline (mask-free form of
    ``ref.acf_after_window_delta_rows`` — the rounds-mode ranking hot path).
    """
    dt = y.dtype
    dagg = solo_moment_rows(y, dyws, ystarts, ny, L=L)
    cum = dagg + agg_table[None]
    l = jnp.arange(1, L + 1)
    m = (ny - l).astype(dt)[None, :]
    return _ref.acf_from_moments(cum[:, 0], cum[:, 1], cum[:, 2],
                                 cum[:, 3], cum[:, 4], m)


def window_rows(cfg, y, dyws, ystarts, agg_table, ny, *, L: int):
    """Backend-dispatched tier-impact rows: the Pallas kernel on a real TPU,
    the einsum contraction elsewhere (same eligibility rule as
    :func:`prefix_devs`)."""
    from repro.kernels import ops as _ops
    if _ops._kernel_eligible(cfg.backend, cfg.stat, cfg.measure) \
            and not _ops.interpret_mode():
        return window_rows_pallas(y, dyws, ystarts, agg_table, ny, L=L)
    return window_acf_rows(y, dyws, ystarts, agg_table, ny, L=L)


def prefix_moment_rows(y, dyws, ystarts, ok, ny, *, L: int):
    """Per-candidate aggregate-delta rows ``[K, 5, L]`` under the running
    reconstruction that applies every earlier ``ok`` candidate.

    ``dyws [K, Wy]`` are the candidates' aggregate-space delta windows in
    rank order, starting at ``ystarts [K]``; ``ok [K]`` gates which rank
    positions actually apply (independent-set survivors).  ``ny`` is the
    (possibly traced) valid length of ``y``; ``y`` must be zero-padded
    beyond it.
    """
    K, Wy = dyws.shape
    nyb = y.shape[0]
    dt = y.dtype
    d = dyws * ok.astype(dt)[:, None]
    starts = jnp.clip(ystarts, 0, nyb - 1)

    # Exclusive running delta field D_{<j}, as dense per-candidate rows.
    place = jax.vmap(
        lambda dr, s: jax.lax.dynamic_update_slice(
            jnp.zeros((nyb + Wy,), dt), dr, (s,))[:nyb])(d, starts)
    d_ex = jnp.cumsum(place, axis=0) - place

    # Per-candidate context of the running reconstruction z = y + D_{<j}.
    kk = jnp.arange(Wy + 2 * L)
    gidx = starts[:, None] + kk[None, :]
    y_pad = jnp.pad(y, (L, L + Wy))
    dex_pad = jnp.pad(d_ex, ((0, 0), (L, L + Wy)))
    ctx = y_pad[gidx] + jnp.take_along_axis(dex_pad, gidx, axis=1)

    return _moment_deltas(d, ctx, ystarts, ny, L=L)           # [K, 5, L]


def prefix_acf_rows_ref(y, dyws, ystarts, ok, agg_table, ny, *, L: int):
    """ACF rows ``[K, L]`` after each rank-prefix of windowed removals
    (see :func:`prefix_moment_rows` for the argument contract)."""
    dt = y.dtype
    dagg = prefix_moment_rows(y, dyws, ystarts, ok, ny, L=L)
    cum = jnp.cumsum(dagg, axis=0) + agg_table[None]
    l = jnp.arange(1, L + 1)
    m = (ny - l).astype(dt)[None, :]
    return _ref.acf_from_moments(cum[:, 0], cum[:, 1], cum[:, 2],
                                 cum[:, 3], cum[:, 4], m)


# ---------------------------------------------------------------------------
# pallas form: independent per-candidate Eq. 9 rows
# ---------------------------------------------------------------------------

def _window_rows_kernel(dy_ref, s_ref, y_pad_ref, agg_ref, ny_ref, out_ref,
                        *, K: int, Wy: int, L: int):
    """Per-candidate trial ACF rows ``[K, L]`` — the kernel twin of
    :func:`window_acf_rows` (tier-impact ranking).  Candidates are
    independent, so each grid-free ``k`` step reads its ``Wy + 2L`` context
    straight from the padded series and never mutates shared state."""
    dtype = y_pad_ref.dtype
    ny = ny_ref[0]
    tiny = jnp.asarray(1e-30, dtype)

    def step(k, _):
        s = s_ref[k]
        d = dy_ref[k, :].reshape(1, Wy)
        idx = s + jax.lax.broadcasted_iota(jnp.int32, (1, Wy), 1)
        jj = jax.lax.broadcasted_iota(jnp.int32, (1, Wy), 1)
        z_at = y_pad_ref[pl.dslice(s + L, Wy)].reshape(1, Wy)
        e = d * (2.0 * z_at + d)

        def lag_body(lag, row):
            lm1 = lag - 1
            z_f = y_pad_ref[pl.dslice(s + L + lag, Wy)].reshape(1, Wy)
            z_b = y_pad_ref[pl.dslice(s + L - lag, Wy)].reshape(1, Wy)
            head = (idx <= ny - 1 - lag).astype(dtype)
            tail = (idx >= lag).astype(dtype)
            d_f = jnp.where(jj + lag < Wy, jnp.roll(d, -lag, axis=1), 0.0)
            sx = agg_ref[0, lm1] + jnp.sum(d * head)
            sxl = agg_ref[1, lm1] + jnp.sum(d * tail)
            sx2 = agg_ref[2, lm1] + jnp.sum(e * head)
            sxl2 = agg_ref[3, lm1] + jnp.sum(e * tail)
            sxx = agg_ref[4, lm1] + jnp.sum(
                d * (z_f * head + z_b * tail + d_f * head))
            m = (ny - lag).astype(dtype)
            num = m * sxx - sx * sxl
            den2 = (m * sx2 - sx * sx) * (m * sxl2 - sxl * sxl)
            den = jnp.sqrt(jnp.maximum(den2, tiny))
            rho = jnp.where(den2 > tiny, num / den, jnp.zeros_like(num))
            return jax.lax.dynamic_update_slice(
                row, rho.reshape(1, 1), (0, lm1))

        row = jax.lax.fori_loop(
            1, L + 1, lag_body, jnp.zeros((1, L), dtype))
        out_ref[pl.dslice(k, 1), :] = row
        return 0

    jax.lax.fori_loop(0, K, step, 0)


@functools.partial(jax.jit, static_argnames=("L", "interpret"))
def window_rows_pallas(y, dyws, ystarts, agg_table, ny, *, L: int,
                       interpret: bool = False):
    """Pallas form of :func:`window_acf_rows`: per-candidate Eq. 9 ACF rows
    ``[K, L]``.  TPU decision path (interpret mode for parity tests only —
    same convention as :func:`prefix_devs_pallas`)."""
    K, Wy = dyws.shape
    nyb = y.shape[0]
    dtype = y.dtype
    y_pad = jnp.pad(y, (L, L + Wy))
    starts = jnp.clip(ystarts, 0, nyb - 1).astype(jnp.int32)
    ny_arr = jnp.asarray(ny, jnp.int32).reshape(1)

    kernel = functools.partial(_window_rows_kernel, K=K, Wy=Wy, L=L)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(dyws.shape, lambda i: (0, 0)),
            pl.BlockSpec(starts.shape, lambda i: (0,)),
            pl.BlockSpec(y_pad.shape, lambda i: (0,)),
            pl.BlockSpec(agg_table.shape, lambda i: (0, 0)),
            pl.BlockSpec(ny_arr.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((K, L), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, L), dtype),
        interpret=interpret,
    )(dyws.astype(dtype), starts, y_pad, agg_table, ny_arr)


# ---------------------------------------------------------------------------
# pallas form: one fused pass with the running reconstruction in VMEM
# ---------------------------------------------------------------------------

def _prefix_scan_kernel(dy_ref, s_ref, ok_ref, y_pad_ref, agg_ref, p0_ref,
                        ny_ref, eps_ref, out_ref, z_ref,
                        *, K: int, Wy: int, L: int, measure: str,
                        greedy: bool):
    dtype = y_pad_ref.dtype
    z_ref[...] = y_pad_ref[...]
    ny = ny_ref[0]
    eps = eps_ref[0]
    tiny = jnp.asarray(1e-30, dtype)

    def step(k, agg5):
        s = s_ref[k]
        d = dy_ref[k, :].reshape(1, Wy) * ok_ref[k]
        idx = s + jax.lax.broadcasted_iota(jnp.int32, (1, Wy), 1)
        jj = jax.lax.broadcasted_iota(jnp.int32, (1, Wy), 1)
        z_at = z_ref[pl.dslice(s + L, Wy)].reshape(1, Wy)
        e = d * (2.0 * z_at + d)

        def lag_body(lag, carry):
            trial5, acc = carry
            lm1 = lag - 1
            z_f = z_ref[pl.dslice(s + L + lag, Wy)].reshape(1, Wy)
            z_b = z_ref[pl.dslice(s + L - lag, Wy)].reshape(1, Wy)
            head = (idx <= ny - 1 - lag).astype(dtype)
            tail = (idx >= lag).astype(dtype)
            d_f = jnp.where(jj + lag < Wy, jnp.roll(d, -lag, axis=1), 0.0)
            sx = trial5[0, lm1] + jnp.sum(d * head)
            sxl = trial5[1, lm1] + jnp.sum(d * tail)
            sx2 = trial5[2, lm1] + jnp.sum(e * head)
            sxl2 = trial5[3, lm1] + jnp.sum(e * tail)
            sxx = trial5[4, lm1] + jnp.sum(
                d * (z_f * head + z_b * tail + d_f * head))
            col = jnp.stack([sx, sxl, sx2, sxl2, sxx])
            trial5 = jax.lax.dynamic_update_slice(
                trial5, col[:, None], (0, lm1))
            m = (ny - lag).astype(dtype)
            num = m * sxx - sx * sxl
            den2 = (m * sx2 - sx * sx) * (m * sxl2 - sxl * sxl)
            den = jnp.sqrt(jnp.maximum(den2, tiny))
            rho = jnp.where(den2 > tiny, num / den, jnp.zeros_like(num))
            diff = rho - p0_ref[lm1]
            if measure == "mae":
                acc = acc + jnp.abs(diff)
            elif measure == "rmse":
                acc = acc + diff * diff
            else:                                            # cheb
                acc = jnp.maximum(acc, jnp.abs(diff))
            return trial5, acc

        trial5, acc = jax.lax.fori_loop(
            1, L + 1, lag_body, (agg5, jnp.asarray(0.0, dtype)))
        if measure == "mae":
            dev = acc / L
        elif measure == "rmse":
            dev = jnp.sqrt(acc / L)
        else:
            dev = acc
        out_ref[pl.dslice(k, 1)] = dev.reshape(1)
        if greedy:
            # Conditional commit: the candidate joins the running
            # reconstruction only when its trial deviation fits.
            take = (ok_ref[k] > 0) & (dev <= eps)
            gate = take.astype(dtype)
            z_ref[pl.dslice(s + L, Wy)] = (z_at + gate * d).reshape(Wy)
            return jnp.where(take, trial5, agg5)
        z_ref[pl.dslice(s + L, Wy)] = (z_at + d).reshape(Wy)
        return trial5

    jax.lax.fori_loop(0, K, step, agg_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("L", "measure", "greedy", "interpret"))
def prefix_devs_pallas(y, dyws, ystarts, ok, agg_table, p0, ny, eps=None, *,
                       L: int, measure: str = "mae", greedy: bool = False,
                       interpret: bool = False):
    """Per-rank deviations [K] via the fused Pallas round kernel.

    With ``greedy=False`` every ``ok`` candidate commits and the output is
    the prefix deviation curve.  With ``greedy=True`` a candidate commits
    only when its trial deviation fits within ``eps`` — the output is each
    candidate's *trial* deviation on top of the committed set, so the taken
    mask is recovered as ``ok & (out <= eps)``.
    """
    from jax.experimental.pallas import tpu as pltpu
    K, Wy = dyws.shape
    nyb = y.shape[0]
    dtype = y.dtype
    y_pad = jnp.pad(y.astype(dtype), (L, L + Wy))
    okf = ok.astype(dtype)
    starts = jnp.clip(ystarts, 0, nyb - 1).astype(jnp.int32)
    ny_arr = jnp.asarray(ny, jnp.int32).reshape(1)
    eps_arr = jnp.asarray(
        jnp.inf if eps is None else eps, dtype).reshape(1)

    kernel = functools.partial(
        _prefix_scan_kernel, K=K, Wy=Wy, L=L, measure=measure, greedy=greedy)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(dyws.shape, lambda i: (0, 0)),
            pl.BlockSpec(starts.shape, lambda i: (0,)),
            pl.BlockSpec(okf.shape, lambda i: (0,)),
            pl.BlockSpec(y_pad.shape, lambda i: (0,)),
            pl.BlockSpec(agg_table.shape, lambda i: (0, 0)),
            pl.BlockSpec(p0.shape, lambda i: (0,)),
            pl.BlockSpec(ny_arr.shape, lambda i: (0,)),
            pl.BlockSpec(eps_arr.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((K,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((K,), dtype),
        scratch_shapes=[pltpu.VMEM((nyb + 2 * L + Wy,), dtype)],
        interpret=interpret,
    )(dyws.astype(dtype), starts, okf, y_pad, agg_table, p0, ny_arr,
      eps_arr)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def prefix_devs(cfg, y, dyws, ystarts, ok, agg, p0, ny):
    """Backend-dispatched deviation curve for one round's rank prefix.

    The Pallas kernel runs only on a real TPU: its sequential per-lag
    accumulation differs from the reference's vectorized reduction order,
    so interpret-mode execution is reserved for the direct parity test
    (tolerance-based) instead of the decision path — off-TPU, every
    backend choice selects prefixes from the identical reference curve.
    """
    from repro.core import measures as _measures
    from repro.kernels import ops as _ops
    table = _ops.agg_to_table(agg)
    L = cfg.lags
    if _ops._kernel_eligible(cfg.backend, cfg.stat, cfg.measure) \
            and not _ops.interpret_mode():
        return prefix_devs_pallas(
            y, dyws, ystarts, ok, table, p0, ny, L=L, measure=cfg.measure,
            interpret=False)
    rows = prefix_acf_rows_ref(y, dyws, ystarts, ok, table, ny, L=L)
    if cfg.stat == "acf" and cfg.measure in _ref.KERNEL_MEASURES:
        return _ref.measure_rows(rows, p0, cfg.measure)
    mfn = _measures.get_measure(cfg.measure)
    transform = _ops._transform_fn(cfg.stat)
    return jax.vmap(lambda r: mfn(transform(r), p0))(rows)


def greedy_feasible(cfg, y, dyws, ystarts, ok, agg, p0, ny, eps):
    """Backend-dispatched greedy feasible-subset selection for one round.

    Walks the rank-ordered candidates once, committing each candidate whose
    trial deviation on top of the already-committed set stays within
    ``eps`` — violators are *skipped*, not terminal, so the round harvests
    every boundary-compatible candidate instead of stopping at the first
    infeasible prefix.  Returns ``(take [K] bool, devs [K])`` where ``devs``
    are the per-candidate trial deviations.

    The Pallas form maintains the exact committed reconstruction in VMEM.
    The reference form scans precomputed aggregate-delta rows whose contexts
    assume every earlier ``ok`` candidate applied — a skip leaves a small
    cross-lag bilinear error in later rows, which is why callers must
    re-validate the final subset with the authoritative dense update (the
    rounds loop does, with the feasible prefix as fallback).
    """
    from repro.core import measures as _measures
    from repro.kernels import ops as _ops
    table = _ops.agg_to_table(agg)
    L = cfg.lags
    dt = y.dtype
    if _ops._kernel_eligible(cfg.backend, cfg.stat, cfg.measure) \
            and not _ops.interpret_mode():
        devs = prefix_devs_pallas(
            y, dyws, ystarts, ok, table, p0, ny, eps, L=L,
            measure=cfg.measure, greedy=True, interpret=False)
        return ok & (devs <= eps), devs

    dagg = prefix_moment_rows(y, dyws, ystarts, ok, ny, L=L)
    l = jnp.arange(1, L + 1)
    m = (ny - l).astype(dt)
    if cfg.stat == "acf" and cfg.measure in _ref.KERNEL_MEASURES:
        def dev_fn(rho):
            return _ref.measure_rows(rho[None], p0, cfg.measure)[0]
    else:
        mfn = _measures.get_measure(cfg.measure)
        transform = _ops._transform_fn(cfg.stat)

        def dev_fn(rho):
            return mfn(transform(rho), p0)

    def step(cum, inp):
        dk, okk = inp
        trial = cum + dk
        rho = _ref.acf_from_moments(trial[0], trial[1], trial[2],
                                    trial[3], trial[4], m)
        dev = dev_fn(rho)
        take = okk & (dev <= eps)
        return jnp.where(take, trial, cum), (take, dev)

    _, (take, devs) = jax.lax.scan(step, table, (dagg, ok))
    return take, devs
