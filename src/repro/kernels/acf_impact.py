"""Pallas TPU kernel for GetAllImpact (paper Algorithm 2) — CAMEO's hot loop.

For every candidate point i, computes the deviation measure between the
hypothetical ACF after a single-point delta at i (Eq. 8) and the original
ACF.  This is O(n·L) work with O(1) state per lag — VPU-shaped: the L-loop
runs sequentially in-kernel while each step is a [1, B] vector op over the
candidate block, and the five per-lag aggregates live in SMEM-like scalar
reads from a VMEM-resident [5, L] table.

Tiling: the candidate axis is blocked (B a multiple of 128 lanes); the padded
series (n + 2L, zero halos) stays fully VMEM-resident — for the paper's
workloads (n <= ~1M, f32) that is <= 4 MB of the ~16 MB VMEM budget.  The
lag-shifted reads y[i±l] then become cheap dynamic slices instead of
gathers.  Out-of-range lag reads land in the zero halo and are nulled by the
head/tail masks (same masking as the reference math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _measure_init(measure: str, B: int, dtype):
    if measure in ("mae", "rmse"):
        return jnp.zeros((1, B), dtype)
    if measure == "cheb":
        return jnp.zeros((1, B), dtype)
    raise ValueError(f"kernel supports mae/rmse/cheb, got {measure!r}")


def _measure_update(measure: str, acc, diff):
    if measure == "mae":
        return acc + jnp.abs(diff)
    if measure == "rmse":
        return acc + diff * diff
    return jnp.maximum(acc, jnp.abs(diff))


def _measure_final(measure: str, acc, L: int):
    if measure == "mae":
        return acc / L
    if measure == "rmse":
        return jnp.sqrt(acc / L)
    return acc


def acf_impact_kernel(y_pad_ref, d_ref, agg_ref, p0_ref, out_ref,
                      *, n: int, L: int, B: int, measure: str):
    """One grid step: impacts for candidate block [pid*B, (pid+1)*B)."""
    pid = pl.program_id(0)
    s = pid * B
    dtype = y_pad_ref.dtype

    idx = s + jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)     # [1, B]
    d = d_ref[...].reshape(1, B)
    # y at the candidate positions (offset L in the padded series)
    yi = y_pad_ref[pl.dslice(s + L, B)].reshape(1, B)
    e = d * (2.0 * yi + d)
    valid = (idx >= 0) & (idx <= n - 1)

    def lag_body(lag, acc):
        lm1 = lag - 1
        y_f = y_pad_ref[pl.dslice(s + L + lag, B)].reshape(1, B)
        y_b = y_pad_ref[pl.dslice(s + L - lag, B)].reshape(1, B)
        head = ((idx <= n - 1 - lag) & valid).astype(dtype)
        tail = ((idx >= lag) & valid).astype(dtype)

        sx = agg_ref[0, lm1] + d * head
        sxl = agg_ref[1, lm1] + d * tail
        sx2 = agg_ref[2, lm1] + e * head
        sxl2 = agg_ref[3, lm1] + e * tail
        sxx = agg_ref[4, lm1] + d * (y_f * head + y_b * tail)

        m = (n - lag).astype(dtype)
        num = m * sxx - sx * sxl
        den2 = (m * sx2 - sx * sx) * (m * sxl2 - sxl * sxl)
        tiny = jnp.asarray(1e-30, dtype)
        col = jnp.where(den2 > tiny,
                        num * jax.lax.rsqrt(jnp.maximum(den2, tiny)),
                        jnp.zeros_like(num))
        return _measure_update(measure, acc, col - p0_ref[lm1])

    acc = jax.lax.fori_loop(1, L + 1, lag_body,
                            _measure_init(measure, B, dtype))
    out_ref[...] = _measure_final(measure, acc, L).reshape(B)


@functools.partial(
    jax.jit, static_argnames=("L", "measure", "block", "interpret"))
def acf_impact_pallas(y, dval, agg_table, p0, *, L: int, measure: str = "mae",
                      block: int = 1024, interpret: bool = False):
    """Impacts [n] via the Pallas kernel.

    ``agg_table`` is the stacked [5, L] aggregate table
    (sx, sxl, sx2, sxl2, sxx); ``p0`` the original ACF [L].
    """
    n = y.shape[0]
    dtype = y.dtype
    B = block
    pad = (-n) % B
    npad = n + pad
    y_pad = jnp.pad(y, (L, L + pad))          # zero halos both sides
    d_pad = jnp.pad(dval, (0, pad))

    grid = (npad // B,)
    kernel = functools.partial(
        acf_impact_kernel, n=n, L=L, B=B, measure=measure)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(y_pad.shape, lambda i: (0,)),       # full series
            pl.BlockSpec((B,), lambda i: (i,)),              # delta block
            pl.BlockSpec(agg_table.shape, lambda i: (0, 0)),  # aggregates
            pl.BlockSpec(p0.shape, lambda i: (0,)),          # original ACF
        ],
        out_specs=pl.BlockSpec((B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), dtype),
        interpret=interpret,
    )(y_pad, d_pad, agg_table, p0)
    return out[:n]
