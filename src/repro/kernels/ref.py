"""Pure-jnp reference implementations — the single source of truth for the
Eq. 2/8/9 per-lag math.

Every Pallas kernel in this package has its oracle here, and the ``core``
layer delegates to these functions instead of re-deriving the formulas
(``core/aggregates.py`` keeps only the *update* math of Eqs. 10-11 plus the
alive-neighbor geometry).  This module intentionally imports nothing from
``repro.core`` so the kernel layer sits at the bottom of the dependency
stack; aggregate arguments are any structure indexable as five per-lag
``[L]`` arrays ``(sx, sxl, sx2, sxl2, sxx)`` — the ``core.acf.Aggregates``
NamedTuple and the stacked ``[5, L]`` kernel table both qualify.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def acf_from_moments(sx, sxl, sx2, sxl2, sxx, m):
    """Eq. 2: normalized per-lag ACF from the five moment sums.

    Broadcasts over any leading batch dims; ``m = ny - l`` per lag.
    """
    num = m * sxx - sx * sxl
    den2 = (m * sx2 - sx * sx) * (m * sxl2 - sxl * sxl)
    tiny = jnp.asarray(1e-30, num.dtype)
    den = jnp.sqrt(jnp.maximum(den2, tiny))
    return jnp.where(den2 > tiny, num / den, jnp.zeros_like(num))


def head_tail_masks(idx: jax.Array, ny: int, L: int, dtype):
    """Head/tail validity masks for absolute indices ``idx`` (shape [...]).

    Returns ``(head, tail)`` of shape ``[..., L]`` where
    ``head[..., l-1] = idx <= ny-1-l`` and ``tail[..., l-1] = idx >= l``.
    """
    l = jnp.arange(1, L + 1)
    head = (idx[..., None] <= (ny - 1 - l)).astype(dtype)
    tail = (idx[..., None] >= l).astype(dtype)
    return head, tail


def measure_rows(rows: jax.Array, p0: jax.Array, measure: str) -> jax.Array:
    """Kernel-supported deviation measures over ``[..., L]`` ACF rows."""
    diff = rows - p0[None, :]
    if measure == "mae":
        return jnp.mean(jnp.abs(diff), axis=-1)
    if measure == "rmse":
        return jnp.sqrt(jnp.mean(diff * diff, axis=-1))
    if measure == "cheb":
        return jnp.max(jnp.abs(diff), axis=-1)
    raise ValueError(measure)


KERNEL_MEASURES = ("mae", "rmse", "cheb")


def as_table(agg) -> jax.Array:
    """The packed ``[5, L]`` moment table for any aggregate structure
    (``core.acf.Aggregates`` NamedTuple or an already-stacked array)."""
    if isinstance(agg, jax.Array) or isinstance(agg, jnp.ndarray):
        return agg
    return jnp.stack([agg[0], agg[1], agg[2], agg[3], agg[4]])


def acf_from_table(rows: jax.Array, m: jax.Array) -> jax.Array:
    """Eq. 2 over packed moment rows ``[..., 5, L]`` → ACF ``[..., L]``."""
    return acf_from_moments(rows[..., 0, :], rows[..., 1, :], rows[..., 2, :],
                            rows[..., 3, :], rows[..., 4, :], m)


# ---------------------------------------------------------------------------
# Eq. 8 — hypothetical ACF after a single-point delta (Algorithm 2 ranking)
# ---------------------------------------------------------------------------

def acf_after_single_delta(agg, y: jax.Array, idx: jax.Array,
                           dval: jax.Array, *, ny=None) -> jax.Array:
    """Hypothetical ACF (per Eq. 8) after adding ``dval[p]`` at ``idx[p]``,
    independently for each p.  Returns ``[P, L]``.

    ``ny`` (optionally traced) overrides the valid length when ``y`` lives in
    a zero-padded bucket.
    """
    if ny is None:
        ny = y.shape[0]
    L = agg[0].shape[-1]
    dtype = y.dtype
    head, tail = head_tail_masks(idx, ny, L, dtype)        # [P, L]
    l = jnp.arange(1, L + 1)
    y_pad = jnp.pad(y, (L, L))
    y_fwd = y_pad[(idx + L)[:, None] + l[None, :]]         # y[i+l]
    y_bwd = y_pad[(idx + L)[:, None] - l[None, :]]         # y[i-l]
    y_at = y[idx]                                          # [P]

    d = dval[:, None]                                      # [P, 1]
    e = (dval * (2.0 * y_at + dval))[:, None]              # [P, 1]

    # Five flat [P, L] moment rows: a packed [P, 5, L] stack would be two
    # fewer dispatches but materializes 5 PL elements through a concat the
    # legacy CPU runtime doesn't fuse — measurably slower at P = nb.
    tab = as_table(agg)
    sx = tab[0][None, :] + d * head
    sxl = tab[1][None, :] + d * tail
    sx2 = tab[2][None, :] + e * head
    sxl2 = tab[3][None, :] + e * tail
    sxx = tab[4][None, :] + d * (y_fwd * head + y_bwd * tail)

    m = (ny - l).astype(dtype)[None, :]
    return acf_from_moments(sx, sxl, sx2, sxl2, sxx, m)


@functools.partial(jax.jit, static_argnames=("L", "measure"))
def acf_impact_ref(y, dval, agg_table, p0, *, L: int, measure: str = "mae"):
    """Oracle for kernels.acf_impact: Algorithm-2 impacts for all points."""
    n = y.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    rows = acf_after_single_delta(agg_table, y, idx, dval)  # [n, L]
    return measure_rows(rows, p0, measure)


# ---------------------------------------------------------------------------
# Eq. 9 — hypothetical ACF after a windowed (segment) delta
# ---------------------------------------------------------------------------

def _window_delta_acf(agg, dwins, abs_t, y_at, y_fwd, y_bwd, *, ny: int):
    """Shared Eq. 9 core: hypothetical ACF ``[P, L]`` from per-candidate
    delta windows plus pre-gathered series values.

    ``abs_t [P, W]`` are global positions; ``y_at [P, W]`` the series at the
    window, ``y_fwd``/``y_bwd [P, W, L]`` the ±lag-shifted values (zero out
    of range).  Both context layouts (shared 1-D chunk, per-candidate rows)
    reduce to this after their gathers.
    """
    L = agg[0].shape[-1]
    P, W = dwins.shape
    dtype = y_at.dtype
    head, tail = head_tail_masks(abs_t, ny, L, dtype)       # [P, W, L]

    d = dwins                                               # [P, W]
    e = d * (2.0 * y_at + d)

    l = jnp.arange(1, L + 1)
    j = jnp.arange(W)
    d_padded = jnp.pad(d, ((0, 0), (0, L)))
    d_fwd = d_padded[:, j[:, None] + l[None, :]]            # [P, W, L]

    # All five Eq. 9 moment deltas as one [P, 5, W] x [P, 5, W, L]
    # contraction: the per-row weights are d or e, the per-row bases the
    # head/tail masks (plus the shifted-context row for the bilinear term).
    coeff = jnp.stack([d, d, e, e, d], axis=1)              # [P, 5, W]
    basis = jnp.stack(
        [head, tail, head, tail,
         (y_fwd + d_fwd) * head + y_bwd * tail], axis=1)    # [P, 5, W, L]
    rows = as_table(agg)[None] + jnp.einsum("paw,pawl->pal", coeff, basis)

    m = (ny - l).astype(dtype)[None, :]
    return acf_from_table(rows, m)


def _window_delta_acf_ref(agg, dwins, abs_t, y_at, y_fwd, y_bwd, *, ny: int):
    """Per-moment-einsum oracle for :func:`_window_delta_acf` (the historical
    form with one contraction per moment row), kept for parity tests of the
    fused ``[P, 5, W] x [P, 5, W, L]`` contraction."""
    L = agg[0].shape[-1]
    dtype = y_at.dtype
    head, tail = head_tail_masks(abs_t, ny, L, dtype)       # [P, W, L]
    d = dwins
    e = d * (2.0 * y_at + d)
    dsx = jnp.einsum("pw,pwl->pl", d, head)
    dsxl = jnp.einsum("pw,pwl->pl", d, tail)
    dsx2 = jnp.einsum("pw,pwl->pl", e, head)
    dsxl2 = jnp.einsum("pw,pwl->pl", e, tail)
    l = jnp.arange(1, L + 1)
    W = dwins.shape[1]
    j = jnp.arange(W)
    d_padded = jnp.pad(d, ((0, 0), (0, L)))
    d_fwd = d_padded[:, j[:, None] + l[None, :]]            # [P, W, L]
    dsxx = jnp.einsum(
        "pw,pwl->pl", d, y_fwd * head + y_bwd * tail) + jnp.einsum(
        "pw,pwl->pl", d, d_fwd * head)
    m = (ny - l).astype(dtype)[None, :]
    return acf_from_moments(
        agg[0][None, :] + dsx, agg[1][None, :] + dsxl,
        agg[2][None, :] + dsx2, agg[3][None, :] + dsxl2,
        agg[4][None, :] + dsxx, m)


def acf_after_window_delta_ctx(agg, y_ctx: jax.Array, starts: jax.Array,
                               dwins: jax.Array, *, ny: int, off) -> jax.Array:
    """Hypothetical ACF after applying each candidate's *windowed* delta
    independently (vectorized Eq. 9).  Returns ``[P, L]``.

    This is the exact ranking form: it accounts for the full re-interpolated
    segment of a removal, including the cross-lag bilinear term, unlike the
    single-delta Algorithm-2 approximation.  The context form supports the
    coarse-grained partitioned mode: ``y_ctx`` is a local chunk with L-point
    halos on each side (+W right padding) and ``off`` is the chunk's global
    offset; out-of-series context positions must be zero.
    """
    L = agg[0].shape[-1]
    _, W = dwins.shape
    j = jnp.arange(W)
    l = jnp.arange(1, L + 1)
    loc_t = starts[:, None] + j[None, :]                    # [P, W] local
    abs_t = off + loc_t                                     # [P, W] global
    y_at = y_ctx[loc_t + L]                                 # [P, W]
    y_fwd = y_ctx[loc_t[..., None] + L + l]                 # [P, W, L]
    y_bwd = y_ctx[loc_t[..., None] + L - l]
    return _window_delta_acf(agg, dwins, abs_t, y_at, y_fwd, y_bwd, ny=ny)


def candidate_contexts(y: jax.Array, starts: jax.Array, *, L: int, W: int):
    """Per-candidate ``[P, W + 2L]`` y-context windows for the windowed
    kernel: ``ctx[p, k] = y[starts[p] - L + k]`` with zeros out of range.

    ``starts`` are *local* indices into ``y`` (callers supply haloed chunks
    plus the matching local starts in the partitioned mode).
    """
    y_pad = jnp.pad(y, (L, L + W))
    k = jnp.arange(W + 2 * L)
    return y_pad[jnp.clip(starts[:, None], 0, y.shape[0]) + k[None, :]]


def acf_after_window_delta_rows(agg, y_rows: jax.Array, starts_abs: jax.Array,
                                dwins: jax.Array, *, ny: int) -> jax.Array:
    """Eq. 9 hypothetical ACF from per-candidate ``[P, W + 2L]`` context rows
    (the kernel's input layout — see :func:`candidate_contexts`).
    Returns ``[P, L]``.
    """
    L = agg[0].shape[-1]
    _, W = dwins.shape
    j = jnp.arange(W)
    l = jnp.arange(1, L + 1)
    abs_t = starts_abs[:, None] + j[None, :]                # [P, W] global
    y_at = y_rows[:, L:L + W]                               # [P, W]
    y_fwd = y_rows[:, L + j[:, None] + l[None, :]]          # [P, W, L]
    y_bwd = y_rows[:, L + j[:, None] - l[None, :]]
    return _window_delta_acf(agg, dwins, abs_t, y_at, y_fwd, y_bwd, ny=ny)


@functools.partial(jax.jit, static_argnames=("ny", "measure"))
def acf_window_impact_ref(y_rows, dwins, starts_abs, agg_table, p0, *,
                          ny: int, measure: str = "mae"):
    """Oracle for kernels.acf_window_impact: exact Eq. 9 ranking impacts."""
    rows = acf_after_window_delta_rows(
        agg_table, y_rows, starts_abs, dwins, ny=ny)
    return measure_rows(rows, p0, measure)


# ---------------------------------------------------------------------------
# Eq. 7 — lagged products (ExtractAggregates hot term), cross/halo'd form
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("L",))
def lag_xdot(a, b_ext, *, L: int):
    """``out[l-1] = sum_{t < m} a[t] * b_ext[t + l]`` for l in 1..L, as one
    ``[m] x [m, L]`` contraction against a constant shift basis.

    ``b_ext`` has length ``m + L`` (the caller appends an L-point halo —
    zeros for a plain series, the next chunk's head for partitioned work).
    """
    m = a.shape[0]
    shifted = b_ext[jnp.arange(m)[:, None] + jnp.arange(1, L + 1)[None, :]]
    return a @ shifted


@functools.partial(jax.jit, static_argnames=("L",))
def lag_xdot_ref(a, b_ext, *, L: int):
    """Loop oracle for :func:`lag_xdot` (one dynamic slice per lag)."""
    m = a.shape[0]

    def one(l):
        seg = jax.lax.dynamic_slice(b_ext, (l,), (m,))
        return jnp.sum(a * seg)

    return jax.vmap(one)(jnp.arange(1, L + 1))


@functools.partial(jax.jit, static_argnames=("L",))
def lag_dot_ref(y, *, L: int):
    """Oracle for kernels.lag_dot: sxx[l-1] = sum_t y_t y_{t+l}."""
    return lag_xdot_ref(y, jnp.pad(y, (0, L)), L=L)
