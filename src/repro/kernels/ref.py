"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.acf import Aggregates
from repro.core.aggregates import acf_after_single_delta


@functools.partial(jax.jit, static_argnames=("L", "measure"))
def acf_impact_ref(y, dval, agg_table, p0, *, L: int, measure: str = "mae"):
    """Oracle for kernels.acf_impact: Algorithm-2 impacts for all points."""
    n = y.shape[0]
    agg = Aggregates(sx=agg_table[0], sxl=agg_table[1], sx2=agg_table[2],
                     sxl2=agg_table[3], sxx=agg_table[4])
    idx = jnp.arange(n, dtype=jnp.int32)
    rows = acf_after_single_delta(agg, y, idx, dval)     # [n, L]
    diff = rows - p0[None, :]
    if measure == "mae":
        return jnp.mean(jnp.abs(diff), axis=1)
    if measure == "rmse":
        return jnp.sqrt(jnp.mean(diff * diff, axis=1))
    if measure == "cheb":
        return jnp.max(jnp.abs(diff), axis=1)
    raise ValueError(measure)


@functools.partial(jax.jit, static_argnames=("L",))
def lag_dot_ref(y, *, L: int):
    """Oracle for kernels.lag_dot: sxx[l-1] = sum_t y_t y_{t+l}."""
    n = y.shape[0]

    def one(l):
        shifted = jnp.roll(y, -l)
        mask = jnp.arange(n) <= (n - 1 - l)
        return jnp.sum(jnp.where(mask, y * shifted, 0.0))

    return jax.vmap(one)(jnp.arange(1, L + 1))
