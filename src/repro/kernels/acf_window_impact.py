"""Pallas TPU kernel for the exact windowed (Eq. 9) ranking impact.

For every candidate point, computes the deviation measure between the
hypothetical ACF after re-interpolating the candidate's whole segment (the
up-to-``W``-point delta window of a removal) and the original ACF.  This is
the math behind ``rank="window"`` — the exact Eq. 9 ranking that the
single-delta Algorithm-2 kernel (``acf_impact``) only approximates.

Layout: candidates are blocked along the grid axis; each candidate carries a
self-contained ``[W + 2L]`` y-context row (gathered once outside the kernel
by XLA — the per-candidate segment starts are data-dependent, so this hoists
the one true gather out of the O(P·W·L) hot loop) and a ``[W + L]``
right-padded delta window.  In-kernel, the L-loop runs sequentially and each
step is pure ``[B, W]`` VPU work: the lag-shifted reads ``y[t±l]`` and the
bilinear cross term ``d_t d_{t+l}`` become contiguous 2-D dynamic slices of
the context/delta blocks, and the five per-lag moment deltas are masked
row-sums.  VMEM per block: ``B·(2W + 3L)`` values — ~¼ MB for the default
``B=256, W=64, L=48`` at f64.

Starts are *absolute* (global) indices: the head/tail validity masks of
Eq. 9 depend only on the global position, which lets the partitioned mode
pass haloed local contexts plus global starts with no other changes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.acf_impact import (_measure_final, _measure_init,
                                      _measure_update)


def acf_window_impact_kernel(yc_ref, d_ref, s_ref, agg_ref, p0_ref, out_ref,
                             *, ny: int, L: int, W: int, B: int, measure: str):
    """One grid step: windowed impacts for a [B] candidate block."""
    dtype = yc_ref.dtype
    d = d_ref[:, :W]                                       # [B, W]
    y_at = yc_ref[:, L:L + W]                              # y at the window
    e = d * (2.0 * y_at + d)
    j = jax.lax.broadcasted_iota(jnp.int32, (B, W), 1)
    abs_t = s_ref[...].reshape(B, 1) + j                   # global positions

    def lag_body(lag, acc):
        lm1 = lag - 1
        y_f = yc_ref[:, pl.dslice(L + lag, W)]             # y[t + l]
        y_b = yc_ref[:, pl.dslice(L - lag, W)]             # y[t - l]
        d_f = d_ref[:, pl.dslice(lag, W)]                  # d[t + l]
        head = (abs_t <= ny - 1 - lag).astype(dtype)
        tail = (abs_t >= lag).astype(dtype)

        sx = agg_ref[0, lm1] + jnp.sum(d * head, axis=1).reshape(1, B)
        sxl = agg_ref[1, lm1] + jnp.sum(d * tail, axis=1).reshape(1, B)
        sx2 = agg_ref[2, lm1] + jnp.sum(e * head, axis=1).reshape(1, B)
        sxl2 = agg_ref[3, lm1] + jnp.sum(e * tail, axis=1).reshape(1, B)
        sxx = agg_ref[4, lm1] + jnp.sum(
            d * (y_f * head + y_b * tail + d_f * head), axis=1).reshape(1, B)

        m = (ny - lag).astype(dtype)
        num = m * sxx - sx * sxl
        den2 = (m * sx2 - sx * sx) * (m * sxl2 - sxl * sxl)
        tiny = jnp.asarray(1e-30, dtype)
        col = jnp.where(den2 > tiny,
                        num * jax.lax.rsqrt(jnp.maximum(den2, tiny)),
                        jnp.zeros_like(num))
        return _measure_update(measure, acc, col - p0_ref[lm1])

    acc = jax.lax.fori_loop(1, L + 1, lag_body,
                            _measure_init(measure, B, dtype))
    out_ref[...] = _measure_final(measure, acc, L).reshape(B)


@functools.partial(
    jax.jit, static_argnames=("ny", "L", "measure", "block", "interpret"))
def acf_window_impact_pallas(y_ctx, dwins, starts_abs, agg_table, p0, *,
                             ny: int, L: int, measure: str = "mae",
                             block: int = 256, interpret: bool = False):
    """Windowed impacts [P] via the Pallas kernel.

    ``y_ctx`` is the per-candidate ``[P, W + 2L]`` context
    (``y_ctx[p, k] = y[start_p - L + k]``, zero out of range — see
    ``kernels.ref.candidate_contexts``); ``dwins`` the ``[P, W]`` delta
    windows (zero beyond each candidate's span); ``starts_abs`` the global
    index of each window's first position; ``agg_table`` the stacked [5, L]
    aggregate table and ``p0`` the original ACF [L].
    """
    P, W = dwins.shape
    dtype = y_ctx.dtype
    B = min(block, max(P, 1))
    pad = (-P) % B
    yc = jnp.pad(y_ctx, ((0, pad), (0, 0)))
    d_pad = jnp.pad(dwins, ((0, pad), (0, L)))       # +L for d[t+l] reads
    s_pad = jnp.pad(starts_abs, (0, pad))

    grid = ((P + pad) // B,)
    kernel = functools.partial(
        acf_window_impact_kernel, ny=ny, L=L, W=W, B=B, measure=measure)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, W + 2 * L), lambda i: (i, 0)),   # contexts
            pl.BlockSpec((B, W + L), lambda i: (i, 0)),       # delta windows
            pl.BlockSpec((B,), lambda i: (i,)),               # global starts
            pl.BlockSpec(agg_table.shape, lambda i: (0, 0)),  # aggregates
            pl.BlockSpec(p0.shape, lambda i: (0,)),           # original ACF
        ],
        out_specs=pl.BlockSpec((B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P + pad,), dtype),
        interpret=interpret,
    )(yc, d_pad, s_pad, agg_table, p0)
    return out[:P]
