"""jit'd public wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; everywhere else (this CPU
container) they execute in ``interpret=True`` mode, which runs the kernel
body through XLA on CPU — bit-faithful to the kernel semantics, so the
tests' allclose-vs-oracle checks validate the real kernel logic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.acf import Aggregates
from repro.kernels.acf_impact import acf_impact_pallas
from repro.kernels.lag_dot import lag_dot_pallas
from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def agg_to_table(agg: Aggregates) -> jax.Array:
    return jnp.stack([agg.sx, agg.sxl, agg.sx2, agg.sxl2, agg.sxx])


def acf_impact(y, dval, agg, p0, *, measure: str = "mae",
               block: int = 1024, use_kernel: bool = True):
    """Algorithm-2 impacts for all points: D(ACF_after_delta_i, P0), [n]."""
    L = p0.shape[0]
    table = agg_to_table(agg) if isinstance(agg, Aggregates) else agg
    if not use_kernel:
        return _ref.acf_impact_ref(y, dval, table, p0, L=L, measure=measure)
    return acf_impact_pallas(
        y, dval, table, p0, L=L, measure=measure, block=block,
        interpret=_interpret())


def lag_dot(y, L: int, *, block: int = 4096, use_kernel: bool = True):
    """Lagged self-products sxx_l for l=1..L, [L]."""
    if not use_kernel:
        return _ref.lag_dot_ref(y, L=L)
    return lag_dot_pallas(y, L=L, block=block, interpret=_interpret())
