"""Unified impact-engine backend: one dispatch point for all CAMEO
impact/aggregate math.

Every ranking/aggregate computation in the compressor — Algorithm-2
single-delta impacts (Eq. 8), exact windowed impacts (Eq. 9), and the lagged
products of ExtractAggregates (Eq. 7) — goes through this module.  The math
itself lives exactly once: ``kernels/ref.py`` holds the pure-jnp reference
forms (also the test oracles), and ``kernels/acf_impact.py`` /
``kernels/acf_window_impact.py`` / ``kernels/lag_dot.py`` hold the Pallas
TPU kernels that implement the same formulas.  ``core/cameo.py`` and
``core/parallel.py`` are thin callers.

Backend selection
-----------------
Three backends, chosen per-call (and plumbed from ``CameoConfig.backend``):

* ``"pallas"``    — the hand-written Pallas kernels.  Native on TPU; in any
  other process they execute in ``interpret=True`` mode, which runs the
  kernel body through XLA on CPU — bit-faithful to the kernel semantics, so
  allclose-vs-oracle checks validate the real kernel logic (but interpret
  mode is *slow*; it is a correctness path, not a CPU fast path).
* ``"reference"`` — the pure-jnp forms from ``kernels/ref.py`` (chunked the
  same way the kernels tile VMEM, so peak memory matches).
* ``"auto"``      — platform-detected default: ``"pallas"`` on TPU,
  ``"reference"`` everywhere else.

Environment overrides (read at trace time):

* ``CAMEO_BACKEND=pallas|reference`` — overrides how ``"auto"`` resolves
  (explicit backend choices are never overridden).
* ``CAMEO_FORCE_INTERPRET=1`` — forces ``interpret=True`` for the Pallas
  kernels even on TPU (kernel debugging).

The Pallas kernels cover ``stat="acf"`` with the vector measures
``mae | rmse | cheb`` reduced in-kernel.  Other measures and the PACF
transform need the full hypothetical-ACF rows, so those configurations fall
back to the reference math regardless of the requested backend (the
``backend="pallas"`` vs ``"reference"`` parity guarantee is unaffected —
both produce identical rankings either way).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import measures as _measures
from repro.kernels import ref as _ref
from repro.kernels.acf_impact import acf_impact_pallas
from repro.kernels.acf_window_impact import acf_window_impact_pallas
from repro.kernels.lag_dot import lag_dot_pallas

BACKENDS = ("auto", "pallas", "reference")

# measures the kernels reduce in-register (others fall back to reference)
KERNEL_MEASURES = _ref.KERNEL_MEASURES


def resolve_backend(backend: str = "auto") -> str:
    """Resolve ``"auto"`` to a concrete backend (honors ``CAMEO_BACKEND``)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    if backend == "auto":
        env = os.environ.get("CAMEO_BACKEND", "").strip()
        if env:
            if env not in ("pallas", "reference"):
                raise ValueError(f"CAMEO_BACKEND={env!r} not in "
                                 f"('pallas', 'reference')")
            return env
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return backend


def interpret_mode() -> bool:
    """Pallas interpret flag: on for non-TPU, or if CAMEO_FORCE_INTERPRET."""
    if os.environ.get("CAMEO_FORCE_INTERPRET", "").strip() not in ("", "0"):
        return True
    return jax.default_backend() != "tpu"


def agg_to_table(agg) -> jax.Array:
    """Stack an ``Aggregates`` five-tuple into the kernels' [5, L] table."""
    if isinstance(agg, jax.Array):
        return agg
    return jnp.stack(list(agg))


def _transform_fn(stat: str):
    if stat == "acf":
        return lambda r: r
    if stat == "pacf":
        from repro.core.acf import pacf_from_acf  # deferred: core imports ops
        return pacf_from_acf
    raise ValueError(f"unknown stat {stat!r}")


def _kernel_eligible(backend: str, stat: str, measure: str) -> bool:
    return (resolve_backend(backend) == "pallas" and stat == "acf"
            and measure in KERNEL_MEASURES)


# ---------------------------------------------------------------------------
# Eq. 7 — lagged products
# ---------------------------------------------------------------------------

def lag_dot(a, L: int, *, b=None, halo=None, block: int = 4096,
            backend: str = "auto"):
    """``out[l-1] = sum_{t<m} a_t * b_ext_{t+l}`` for l=1..L, shape [L].

    Defaults (``b=None, halo=None``) give the Eq. 7 self-products ``sxx``.
    ``b`` computes cross lagged products; ``halo`` appends an L-point
    continuation of ``b`` past the chunk end (the partitioned mode's
    cross-chunk overlap terms).
    """
    if resolve_backend(backend) == "pallas":
        return lag_dot_pallas(a, b, halo, L=L, block=block,
                              interpret=interpret_mode())
    b_ext = a if b is None else b
    if halo is not None:
        b_ext = jnp.concatenate([b_ext, halo[:L].astype(b_ext.dtype)])
    else:
        b_ext = jnp.pad(b_ext, (0, L))
    return _ref.lag_xdot(a, b_ext, L=L)


# ---------------------------------------------------------------------------
# Eq. 8 — single-delta impacts (Algorithm 2)
# ---------------------------------------------------------------------------

def acf_impact(y, dval, agg, p0, *, measure: str = "mae",
               block: int = 1024, backend: str = "auto"):
    """Algorithm-2 impacts for all points: D(ACF_after_delta_i, P0), [n]."""
    table = agg_to_table(agg)
    L = p0.shape[0]
    if resolve_backend(backend) == "pallas":
        return acf_impact_pallas(
            y, dval, table, p0, L=L, measure=measure, block=block,
            interpret=interpret_mode())
    return _ref.acf_impact_ref(y, dval, table, p0, L=L, measure=measure)


# ---------------------------------------------------------------------------
# Eq. 9 — windowed impacts
# ---------------------------------------------------------------------------

def window_impact(y, dwins, starts, agg, p0, *, measure: str = "mae",
                  block: int = 256, backend: str = "auto"):
    """Exact Eq. 9 impacts for P candidate windows against series ``y``.

    ``dwins [P, W]`` are zero-padded delta windows starting at ``starts [P]``
    (absolute indices into ``y``).  Returns ``[P]``.
    """
    table = agg_to_table(agg)
    L = p0.shape[0]
    ny = y.shape[0]
    rows_ctx = _ref.candidate_contexts(y, starts, L=L, W=dwins.shape[1])
    if resolve_backend(backend) == "pallas":
        return acf_window_impact_pallas(
            rows_ctx, dwins, starts, table, p0, ny=ny, L=L, measure=measure,
            block=block, interpret=interpret_mode())
    return _ref.acf_window_impact_ref(
        rows_ctx, dwins, starts, table, p0, ny=ny, measure=measure)


# ---------------------------------------------------------------------------
# ranking engine — the GetAllImpact hot path used by the compressor
# ---------------------------------------------------------------------------

def _measure_transform(cfg):
    return _measures.get_measure(cfg.measure), _transform_fn(cfg.stat)


def _single_impacts_kernel(cfg, table, y, dval, p0, n: int):
    """Kernel-path Eq. 8 impacts for all n x-candidates.

    For ``kappa > 1`` the x→y index map ``i -> i // kappa`` is not unit
    stride, so the contiguous-slice kernel runs once per residue class
    ``i mod kappa`` — each class maps bijectively onto y positions.
    """
    kap = cfg.kappa
    interp = interpret_mode()
    if kap == 1:
        return acf_impact_pallas(y, dval, table, p0, L=cfg.lags,
                                 measure=cfg.measure, block=1024,
                                 interpret=interp)
    dmat = dval.reshape(n // kap, kap)
    outs = [acf_impact_pallas(y, dmat[:, r], table, p0, L=cfg.lags,
                              measure=cfg.measure, block=1024,
                              interpret=interp)
            for r in range(kap)]
    return jnp.stack(outs, axis=-1).reshape(n)


def _single_impacts_ref(cfg, agg, y, y_idx, dval, p0, n: int):
    """Reference-path Eq. 8 impacts, chunked like the kernel tiles VMEM."""
    mfn, transform = _measure_transform(cfg)
    chunk = min(cfg.impact_chunk, n)
    pad = (-n) % chunk
    ii = jnp.pad(y_idx, (0, pad))
    dd = jnp.pad(dval, (0, pad))

    def one_chunk(args):
        ci, cd = args
        rows = _ref.acf_after_single_delta(agg, y, ci, cd)    # [chunk, L]
        return jax.vmap(lambda r: mfn(transform(r), p0))(rows)

    nchunks = (n + pad) // chunk
    return jax.lax.map(
        one_chunk, (ii.reshape(nchunks, chunk), dd.reshape(nchunks, chunk))
    ).reshape(-1)[:n]


def _rank_single(cfg, agg, y, xr, alive, p0, n: int):
    """Algorithm-2 (single-delta) ranking impact for all n points."""
    from repro.core.aggregates import alive_neighbors, interpolate_at
    dt = cfg.jdtype()
    idx = jnp.arange(n, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive)
    xhat = interpolate_at(xr, prev, nxt, idx)
    dx = xhat - xr
    if cfg.kappa == 1:
        y_idx, dval = idx, dx
    else:
        y_idx = idx // cfg.kappa
        dval = dx / jnp.asarray(cfg.kappa, dt)

    if _kernel_eligible(cfg.backend, cfg.stat, cfg.measure):
        imp = _single_impacts_kernel(cfg, agg_to_table(agg), y, dval, p0, n)
    else:
        imp = _single_impacts_ref(cfg, agg, y, y_idx, dval, p0, n)

    inf = jnp.asarray(jnp.inf, dt)
    removable = alive & (idx > 0) & (idx < n - 1)
    return jnp.where(removable, imp.astype(dt), inf)


def _window_chunk(cfg, agg, y_ctx, ystart, dyw, p0, off, ny: int,
                  use_kernel: bool):
    """Eq. 9 impacts for one chunk of candidates against a 1-D haloed
    context ``y_ctx`` (``y_ctx[k] = y_local[k - L]``, zeros out of range)."""
    L = cfg.lags
    mfn, transform = _measure_transform(cfg)
    if use_kernel:
        Wy = dyw.shape[1]
        k = jnp.arange(Wy + 2 * L)
        rows_ctx = y_ctx[ystart[:, None] + k[None, :]]        # [c, Wy + 2L]
        return acf_window_impact_pallas(
            rows_ctx, dyw, off + ystart, agg_to_table(agg), p0, ny=ny, L=L,
            measure=cfg.measure, block=256, interpret=interpret_mode())
    rows = _ref.acf_after_window_delta_ctx(
        agg, y_ctx, ystart, dyw, ny=ny, off=off)
    return jax.vmap(lambda r: mfn(transform(r), p0))(rows)


def x_window_to_y(cfg, dwin, start):
    """Map x-space delta windows onto the target (aggregate) series.

    ``dwin`` is ``[..., W]`` with matching ``start`` shape ``[...]``; for
    ``kappa == 1`` this is the identity, otherwise each window is
    segment-summed onto the ``Wy = W // kappa + 2`` covered y cells.
    """
    kap = cfg.kappa
    if kap == 1:
        return dwin, start
    W = dwin.shape[-1]
    Wy = W // kap + 2
    dt = dwin.dtype
    b0 = start // kap
    j = jnp.arange(W, dtype=jnp.int32)
    seg = (start[..., None] + j) // kap - b0[..., None]
    ssum = lambda d, s: jax.ops.segment_sum(d, s, num_segments=Wy)
    if dwin.ndim == 1:
        dyw = ssum(dwin, seg)
    else:
        dyw = jax.vmap(ssum)(dwin, seg)
    return dyw / jnp.asarray(kap, dt), b0


def _rank_window_ctx(cfg, agg, y_ctx, xr_loc, alive_loc, p0, off_y, ny: int,
                     fallback: str):
    """Exact windowed (Eq. 9) ranking impact for all local candidates.

    ``y_ctx`` is the 1-D haloed target context (L left halo, >= L + W right
    pad), ``off_y`` the chunk's global y offset.  Candidates whose segment
    outgrew the static window ``W`` either fall back to the single-delta
    estimate (``fallback="single"``, global mode — their actual removal is
    still checked exactly by the dense update) or rank unremovable
    (``fallback="inf"``, partitioned mode).
    """
    from repro.core.aggregates import alive_neighbors, segment_deltas
    dt = cfg.jdtype()
    W = cfg.window
    mx = xr_loc.shape[0]
    idx = jnp.arange(mx, dtype=jnp.int32)
    prev, nxt = alive_neighbors(alive_loc)
    inf = jnp.asarray(jnp.inf, dt)
    use_kernel = _kernel_eligible(cfg.backend, cfg.stat, cfg.measure)

    chunk = min(cfg.impact_chunk, mx)
    pad = (-mx) % chunk
    idx_p = jnp.pad(idx, (0, pad))

    def one_chunk(ci):
        dwin, start, span = segment_deltas(xr_loc, prev, nxt, ci, W)
        dyw, ystart = x_window_to_y(cfg, dwin, start)
        imp = _window_chunk(cfg, agg, y_ctx, ystart, dyw, p0, off_y, ny,
                            use_kernel)
        return imp.astype(dt), span

    nchunks = (mx + pad) // chunk
    imp, span = jax.lax.map(one_chunk, idx_p.reshape(nchunks, chunk))
    imp = imp.reshape(-1)[:mx]
    span = span.reshape(-1)[:mx]

    # fallback="single": overgrown entries keep their truncated-window value
    # here; ranking_impact replaces every one of them with the single-delta
    # estimate, so nothing downstream observes it.
    overgrown = span > W
    if fallback == "inf":
        imp = jnp.where(overgrown, inf, imp)

    removable = alive_loc & (idx > 0) & (idx < mx - 1)
    return jnp.where(removable, imp, inf), overgrown


def ranking_impact(cfg, agg, y, xr, alive, p0, n: int, *, rank=None):
    """GetAllImpact: ranking impact for every point of a whole series.

    Dispatches on ``rank`` (default ``cfg.rank``): ``"single"`` is the
    Algorithm-2 Eq. 8 approximation, ``"window"`` the exact Eq. 9 segment
    form with single-delta fallback for overgrown segments.
    """
    rank = cfg.rank if rank is None else rank
    if rank == "single":
        return _rank_single(cfg, agg, y, xr, alive, p0, n)
    if rank != "window":
        raise ValueError(f"unknown rank {rank!r}")
    ny = y.shape[0]
    L, W = cfg.lags, cfg.window
    dt = cfg.jdtype()
    y_ctx = jnp.pad(y, (L, L + W))
    imp, overgrown = _rank_window_ctx(
        cfg, agg, y_ctx, xr, alive, p0, 0, ny, fallback="single")
    imp_sd = _rank_single(cfg, agg, y, xr, alive, p0, n)
    return jnp.where(overgrown, imp_sd, imp).astype(dt)


def chunk_ranking_impact(cfg, agg, y_ctx, xr_c, alive_c, p0, off_y, ny: int):
    """Partitioned-mode ranking: exact windowed impacts for one partition's
    candidates (overgrown segments rank +inf — unremovable here)."""
    imp, _ = _rank_window_ctx(
        cfg, agg, y_ctx, xr_c, alive_c, p0, off_y, ny, fallback="inf")
    return imp


def window_impact_at(cfg, agg, y, xr, prev, nxt, cand, p0):
    """Exact (Eq. 9) ranking impact of removing each alive point in ``cand``
    (the sequential mode's ReHeap recompute).  Overgrown segments and series
    endpoints rank +inf."""
    from repro.core.aggregates import segment_deltas
    dt = cfg.jdtype()
    n = xr.shape[0]
    ny = y.shape[0]
    L, W = cfg.lags, cfg.window
    dwin, start, span = segment_deltas(xr, prev, nxt, cand, W)
    dyw, ystart = x_window_to_y(cfg, dwin, start)
    y_ctx = jnp.pad(y, (L, L + W))
    use_kernel = _kernel_eligible(cfg.backend, cfg.stat, cfg.measure)
    imp = _window_chunk(cfg, agg, y_ctx, ystart, dyw, p0, 0, ny, use_kernel)
    interior = (cand > 0) & (cand < n - 1)
    inf = jnp.asarray(jnp.inf, dt)
    return jnp.where((span <= W) & interior, imp.astype(dt), inf)
