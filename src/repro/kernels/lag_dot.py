"""Pallas TPU kernel for the lagged self-products ``sxx_l`` (Eq. 7).

ExtractAggregates is O(nL), dominated by ``sxx_l = sum_t y_t * y_{t+l}``
(paper §4.2); the four moment sums are O(n + L) prefix work and stay in XLA.
The kernel streams the series through VMEM in blocks along the time axis and
accumulates the [L] partial products across sequential grid steps (TPU grid
iteration is sequential, so accumulation into the output block is safe).

Each block loads ``[B + L]`` values (B-aligned slab + L halo from the next
slab — zero past the series end, which also masks the invalid lag pairs) and
runs an L-step loop of [1, B] multiply-reduce ops on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def lag_dot_kernel(y_ref, yh_ref, out_ref, *, L: int, B: int, Lpad: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    y_blk = y_ref[...].reshape(1, B)          # this slab

    def lag_body(lag, acc):
        seg = yh_ref[0, pl.dslice(lag, B)].reshape(1, B)  # slab + halo ref
        acc = acc.at[lag - 1].add(jnp.sum(y_blk * seg))
        return acc

    partial = jax.lax.fori_loop(
        1, L + 1, lag_body, jnp.zeros((Lpad,), out_ref.dtype))
    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("L", "block", "interpret"))
def lag_dot_pallas(y, b=None, halo=None, *, L: int, block: int = 4096,
                   interpret: bool = False):
    """``out[l-1] = sum_{t < n} a_t * b_ext_{t+l}`` for l in 1..L, shape [L].

    With the defaults (``b=None, halo=None``) this is the Eq. 7 lagged
    self-product ``sxx``.  The kernel body already separates the main operand
    (``y``) from the lag-shifted one, so the same kernel computes *cross*
    lagged products (``b``) and *halo'd* chunk-local products (``halo`` — an
    L-point continuation of ``b`` past the chunk end, used by the
    partitioned mode's overlap terms).
    """
    n = y.shape[0]
    dtype = y.dtype
    B = block
    pad = (-n) % B
    npad = n + pad
    Lpad = max(128, ((L + 127) // 128) * 128)   # lane-aligned accumulator
    y_main = jnp.pad(y, (0, pad))
    b_base = y if b is None else b
    if halo is not None:
        b_base = jnp.concatenate([b_base, halo[:L].astype(dtype)])
    y_halo = jnp.pad(b_base, (0, npad + Lpad - b_base.shape[0]))

    grid = (npad // B,)
    kernel = functools.partial(lag_dot_kernel, L=L, B=B, Lpad=Lpad)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B,), lambda i: (i,)),
            # pre-materialized per-block halo slabs, one row per grid step
            pl.BlockSpec((1, B + Lpad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((Lpad,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((Lpad,), dtype),
        interpret=interpret,
    )(y_main, _halo_view(y_halo, npad, B, Lpad))
    return out[:L]


def _halo_view(y_halo, npad: int, B: int, Lpad: int):
    """Materialize per-block halo slabs [nblocks, B + Lpad] so BlockSpec
    indexing stays non-overlapping (Pallas blocks must tile the input)."""
    nblocks = npad // B
    idx = (jnp.arange(nblocks) * B)[:, None] + jnp.arange(B + Lpad)[None, :]
    return y_halo[idx]
