"""Baselines: each respects the ACF constraint (or its search reports an
achieving parameter); lossless bit counters behave sanely."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.constrain import acf_constrained_search, acf_deviation
from repro.baselines.functional import (pmc_compress, simpiece_compress,
                                        swing_compress)
from repro.baselines.line_simpl import LINE_SIMPL_BASELINES, compress_baseline
from repro.baselines.lossless import (chimp_bits_per_value,
                                      chimp_bits_per_value_loop,
                                      gorilla_bits_per_value,
                                      gorilla_bits_per_value_loop)
from repro.baselines.transform import fft_compress
from repro.core.cameo import CameoConfig


def _series(n=1024, seed=1):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return (3 * np.sin(2 * np.pi * t / 24) + np.sin(2 * np.pi * t / 168)
            + 0.15 * rng.standard_normal(n))


CFG = CameoConfig(eps=0.02, lags=24, dtype="float64")


@pytest.mark.parametrize("name", sorted(LINE_SIMPL_BASELINES))
def test_line_simpl_respects_constraint(name):
    x = jnp.asarray(_series())
    res = compress_baseline(x, CFG, name)
    assert float(res.deviation) <= CFG.eps + 1e-12
    assert int(res.n_kept) < x.shape[0]


def test_pmc_error_bound():
    x = _series()
    recon, stored = pmc_compress(x, 0.5)
    assert float(np.max(np.abs(np.asarray(recon) - x))) <= 0.5 + 1e-9
    assert stored < 2 * len(x)


def test_swing_reconstruction_reasonable():
    x = _series(seed=3)
    recon, stored = swing_compress(x, 0.4)
    err = float(np.max(np.abs(np.asarray(recon) - x)))
    assert err <= 1.0           # swing guarantees <= err per point (approx.)
    assert stored < 2 * len(x)


def test_simpiece_error_bound():
    x = _series(seed=4)
    recon, stored = simpiece_compress(x, 0.5)
    err = float(np.max(np.abs(np.asarray(recon) - x)))
    assert err <= 0.5 + 0.5 + 1e-9  # intercept quantization + slope bound
    assert stored > 0


def test_fft_more_coeffs_less_error():
    x = _series(seed=5)
    r1, _ = fft_compress(x, 4)
    r2, _ = fft_compress(x, 64)
    e1 = float(np.mean((np.asarray(r1) - x) ** 2))
    e2 = float(np.mean((np.asarray(r2) - x) ** 2))
    assert e2 <= e1 + 1e-12


@pytest.mark.parametrize("fn,isint", [
    (pmc_compress, False), (swing_compress, False),
    (simpiece_compress, False), (fft_compress, True),
])
def test_constrained_search_meets_eps(fn, isint):
    x = _series(seed=6)
    recon, stored, dev, p = acf_constrained_search(
        x, CFG, fn, param_is_int=isint, iters=8)
    assert dev <= CFG.eps + 1e-9
    assert stored > 0


def test_lossless_bits_per_value():
    x = _series(seed=7)
    g = gorilla_bits_per_value(x)
    c = chimp_bits_per_value(x)
    assert 1.0 <= g <= 80.0
    assert 1.0 <= c <= 80.0
    # constant series compresses to almost nothing
    const = np.ones(1000)
    assert gorilla_bits_per_value(const) < 2.0
    assert chimp_bits_per_value(const) < 3.0


def test_lossless_vectorized_matches_loop_forms():
    """The vectorized Table 2 fast paths (shared with store/codec.py) must
    agree bit-for-bit with the literal per-value loop oracles."""
    rng = np.random.default_rng(11)
    for x in [rng.standard_normal(3000),          # random
              np.full(2000, -3.5),                # constant
              _series(seed=12),                   # seasonal + noise
              rng.integers(0, 2**64, 1000,
                           dtype=np.uint64).view(np.float64)]:
        assert gorilla_bits_per_value(x) == gorilla_bits_per_value_loop(x)
        assert chimp_bits_per_value(x) == chimp_bits_per_value_loop(x)
