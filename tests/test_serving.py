"""Serving engine: batched generation, determinism, EOS handling."""
import jax
import numpy as np

from repro.configs.registry import get_reduced
from repro.models.model import model_defs
from repro.models.params import init_params
from repro.serving.engine import Engine, ServeConfig


def _engine(temp=0.0, arch="smollm-135m", **kw):
    cfg = get_reduced(arch)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, Engine(cfg, params, ServeConfig(max_new_tokens=8,
                                                temperature=temp, **kw))


def test_greedy_generation_deterministic():
    cfg, eng = _engine()
    prompts = np.tile(np.arange(16, dtype=np.int32) % cfg.vocab, (3, 1))
    out1 = eng.generate(prompts)
    out2 = eng.generate(prompts)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (3, 8)
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_identical_prompts_identical_outputs():
    cfg, eng = _engine()
    prompts = np.tile(np.arange(12, dtype=np.int32) % cfg.vocab, (4, 1))
    out = eng.generate(prompts)
    for i in range(1, 4):
        np.testing.assert_array_equal(out[0], out[i])


def test_sampled_generation_runs():
    cfg, eng = _engine(temp=0.8)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(2, 10)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 8)


def test_mamba_engine():
    cfg, eng = _engine(arch="mamba2-2.7b")
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, size=(2, 16)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 8)
