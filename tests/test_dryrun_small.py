"""Dry-run machinery on a small forced-device-count mesh (subprocess) +
HLO collective-parser unit tests.  Proves the production path end to end
without the 512-device compile cost."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The subprocess cases below force an 8-device host platform via XLA_FLAGS
# and are verified to pass there; on single-device hosts they are skipped to
# keep the default suite fast and device-count-independent.  Run them with
# XLA_FLAGS=--xla_force_host_platform_device_count=8 in the parent to opt in.
multi_device = pytest.mark.skipif(
    jax.device_count() == 1,
    reason="device-count-sensitive subprocess test; parent has 1 device "
           "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


SMALL_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro import sharding as shd
    from repro.configs.registry import get_reduced
    from repro.launch.hlo import collective_summary
    from repro.launch.specs import (batch_specs, default_train_config,
                                    opt_state_abstract, params_abstract)
    from repro.train.step import build_train_step
    from repro.models.model import decode_step, prefill
    from repro.models.model import cache_specs

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = shd.default_rules()
    out = {}
    for arch in ["qwen3-0.6b", "mamba2-2.7b", "jamba-1.5-large-398b"]:
        cfg = get_reduced(arch)
        with shd.use_sharding(mesh, rules):
            tcfg = default_train_config(cfg)
            params = params_abstract(cfg, mesh, rules)
            opt = opt_state_abstract(params, tcfg, mesh)
            tokens = jax.ShapeDtypeStruct((8, 64), jnp.int32,
                sharding=shd.named_sharding((8, 64), ("act_batch", "act_seq"),
                                            mesh, rules))
            step_fn = build_train_step(cfg, tcfg)
            lowered = jax.jit(step_fn).lower(
                params, opt, {"tokens": tokens},
                jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
            hlo = compiled.as_text()
            cs = collective_summary(hlo, 8, default_trip=cfg.n_blocks)
            # serve_step too
            caches = cache_specs(cfg, 8, 64, mesh, rules)
            tok = jax.ShapeDtypeStruct((8, 1), jnp.int32,
                sharding=shd.named_sharding((8, 1), ("act_batch", None),
                                            mesh, rules))
            dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, t, c, pos)
                          ).lower(params, caches, tok,
                                  jax.ShapeDtypeStruct((), jnp.int32))
            dec_compiled = dec.compile()
        out[arch] = {"collective_bytes": cs["per_device_wire_bytes"],
                     "n_sites": cs["n_sites"]}
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_small_mesh_dryrun_compiles_and_parses():
    r = _run(SMALL_DRYRUN)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    for arch, d in out.items():
        assert d["collective_bytes"] > 0, arch   # DP grad sync at minimum
        assert d["n_sites"] > 0, arch


SHARDMAP_PARALLEL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp, json
    from repro.core.cameo import CameoConfig
    from repro.core.parallel import (compress_partitioned,
                                     compress_partitioned_shardmap)
    n = 2048
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.sin(2*np.pi*np.arange(n)/24)
                    + 0.15*rng.standard_normal(n))
    cfg = CameoConfig(eps=0.02, lags=12, dtype="float64")
    mesh = jax.make_mesh((8,), ("data",))
    a = compress_partitioned(x, cfg, T=8)
    b = compress_partitioned_shardmap(x, cfg, mesh, axis="data")
    same_kept = bool(jnp.all(a.kept == b.kept))
    print("RESULT:" + json.dumps({
        "same_kept": same_kept,
        "dev_a": float(a.deviation), "dev_b": float(b.deviation),
        "cr": n / int(b.n_kept)}))
""")


@pytest.mark.slow
@multi_device
def test_shardmap_parallel_cameo_matches_global_form():
    r = _run(SHARDMAP_PARALLEL)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["same_kept"], out
    assert abs(out["dev_a"] - out["dev_b"]) < 1e-9
    assert out["dev_b"] <= 0.02 + 1e-12
    assert out["cr"] > 1.5


# ---------------------------------------------------------------------------
# HLO parser units (no subprocess)
# ---------------------------------------------------------------------------

SYNTH_HLO = """
HloModule m

%body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]{1,0}) parameter(0)
  %ag = f32[64,256]{1,0} all-gather(%x), replica_groups=[2,4]<=[8], dimensions={1}
  %ar = f32[64,64]{1,0} all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add.1
  ROOT %t = (s32[], f32[64,64]{1,0}) tuple(%i, %ar)
}

ENTRY %main.1 (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %w = (s32[], f32[64,64]{1,0}) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %cp = f32[32,32]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  ROOT %gte = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_collective_parser_units():
    from repro.launch.hlo import collective_summary, parse_collectives
    colls = parse_collectives(SYNTH_HLO, total_devices=8)
    kinds = sorted(c.kind for c in colls)
    assert kinds == ["all-gather", "all-reduce", "collective-permute"]
    by = {c.kind: c for c in colls}
    # all-gather: 64*256*4 bytes, group 4, inside while x10
    ag = by["all-gather"]
    assert ag.group == 4 and ag.multiplier == 10
    assert ag.bytes_buffer == 64 * 256 * 4
    assert abs(ag.wire_bytes - ag.bytes_buffer * 3 / 4) < 1e-6
    ar = by["all-reduce"]
    assert ar.group == 4 and ar.multiplier == 10
    cp = by["collective-permute"]
    assert cp.multiplier == 1 and cp.bytes_buffer == 32 * 32 * 4
    s = collective_summary(SYNTH_HLO, 8)
    assert s["per_device_wire_bytes"] > 0


MOE_A2A_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro import sharding as shd
    from repro.configs.registry import get_reduced
    from repro.models.model import forward, model_defs
    from repro.models.params import init_params

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = shd.default_rules()
    cfg = get_reduced("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg.vocab)}
    with shd.use_sharding(mesh, rules):
        cfg_s = dataclasses.replace(cfg, moe_impl="scatter")
        cfg_a = dataclasses.replace(cfg, moe_impl="a2a")
        ls, _ = jax.jit(lambda p, b: forward(p, cfg_s, b))(params, batch)
        la, _ = jax.jit(lambda p, b: forward(p, cfg_a, b))(params, batch)
    err = float(jnp.max(jnp.abs(ls - la)))
    print("RESULT:" + json.dumps({"err": err}))
""")


@pytest.mark.slow
@multi_device
def test_moe_a2a_matches_scatter():
    r = _run(MOE_A2A_EQUIV)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["err"] < 1e-3, out


MOE_VARIANTS_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro import sharding as shd
    from repro.configs.registry import get_reduced
    from repro.models.model import forward, model_defs
    from repro.models.params import init_params

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = shd.default_rules()
    cfg = get_reduced("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg.vocab)}
    outs = {}
    with shd.use_sharding(mesh, rules):
        for impl in ["scatter", "a2a", "a2a_q8", "a2a2d"]:
            ci = dataclasses.replace(cfg, moe_impl=impl)
            l, _ = jax.jit(lambda p, b: forward(p, ci, b))(params, batch)
            outs[impl] = l
    base = outs["scatter"]
    rms = float(jnp.sqrt(jnp.mean(base * base)))
    errs = {k: {"max": float(jnp.max(jnp.abs(v - base))) / max(rms, 1e-6),
                "mean": float(jnp.mean(jnp.abs(v - base))) / max(rms, 1e-6)}
            for k, v in outs.items()}
    print("RESULT:" + json.dumps(errs))
""")


@pytest.mark.slow
@multi_device
def test_all_moe_impls_agree():
    r = _run(MOE_VARIANTS_EQUIV)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    errs = json.loads(line[len("RESULT:"):])
    assert errs["a2a"]["max"] < 1e-3, errs     # exact paths: bit-level
    assert errs["a2a2d"]["max"] < 1e-3, errs
    # int8 dispatch: mean logit perturbation stays small; the max can spike
    # when a borderline token flips experts (inherent to lossy dispatch)
    assert errs["a2a_q8"]["mean"] < 0.02, errs


DP_SHARDMAP_STEP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import get_reduced
    from repro.models.model import model_defs
    from repro.models.params import init_params
    from repro.optim.compress import CompressConfig, init_residuals
    from repro.optim.adamw import adamw_init
    from repro.train.dp_shardmap import build_dp_train_step
    from repro.train.step import TrainConfig
    from repro.launch.hlo import collective_summary

    mesh = jax.make_mesh((8,), ("data",))
    cfg = get_reduced("smollm-135m")
    tcfg = TrainConfig(peak_lr=1e-3)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    step = build_dp_train_step(cfg, tcfg, mesh,
                               CompressConfig(codec="topk", ratio=0.1))
    opt = adamw_init(params, tcfg.adamw)
    res = init_residuals(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (16, 32),
                                          0, cfg.vocab)}
    p2, opt2, res2, metrics = step(params, opt, res, batch,
                                   jnp.asarray(0, jnp.int32))
    # losses finite + params changed + residual nonzero (error feedback)
    ok_loss = bool(jnp.isfinite(metrics["loss"]))
    changed = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))))
                  for a, b in zip(jax.tree.leaves(params),
                                  jax.tree.leaves(p2)))
    resid = max(float(jnp.max(jnp.abs(r))) for r in jax.tree.leaves(res2))
    print("RESULT:" + json.dumps({"ok_loss": ok_loss, "changed": changed,
                                  "resid": resid}))
""")


@pytest.mark.slow
@multi_device
def test_dp_shardmap_compressed_gradients():
    r = _run(DP_SHARDMAP_STEP)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["ok_loss"] and out["changed"] > 0 and out["resid"] > 0, out
