"""Multivariate contracts: shared-index roundtrips are bit-exact per
column, per-column ε holds measured on the decode, pushdown bounds hold
across blockings, and streamed ingest is byte-identical to one-shot."""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import hypothesis_or_stubs
from repro.core.acf import acf, aggregate_series
from repro.core.cameo import CameoConfig, compress_multivariate
from repro.core.measures import mae
from repro.core.streaming import (MVStreamingCompressor, compress_windowed_mv,
                                  min_window_len)
from repro.store import query as squery
from repro.store.store import CameoStore

given, settings, st = hypothesis_or_stubs()

CFG = CameoConfig(eps=2e-2, lags=12, mode="rounds", max_rounds=60,
                  dtype="float64")


def _mv_series(n=2048, C=3, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    base = 3 * np.sin(2 * np.pi * t / 24) + np.sin(2 * np.pi * t / 168)
    cols = [base + 0.2 * rng.standard_normal(n)]
    for c in range(1, C):
        cols.append(0.5 / c * base + c
                    + np.cos(2 * np.pi * t / (24 * c))
                    + 0.15 * rng.standard_normal(n))
    return np.stack(cols, axis=1)


@pytest.fixture(scope="module")
def stored_mv(tmp_path_factory):
    X = _mv_series(3072, C=3, seed=5)
    res = compress_multivariate(X, CFG)
    path = str(tmp_path_factory.mktemp("mv") / "m.cameo")
    with CameoStore.create(path, block_len=512) as w:
        w.append_series("m", res, CFG, x=X)
    return CameoStore.open(path), X, res


# ---------------------------------------------------------------------------
# compression contract
# ---------------------------------------------------------------------------

def test_union_mask_and_values(stored_mv):
    store, X, res = stored_mv
    # union keeps strictly every column's own kept points and the endpoints
    assert res.kept[0] and res.kept[-1]
    assert res.n_kept == int(res.kept.sum())
    idx = np.flatnonzero(res.kept)
    # per-column values on the shared index are the ORIGINALS
    assert np.array_equal(res.xr[idx], X[idx])


def test_per_column_eps_guarantee(stored_mv):
    """The acceptance criterion: every column's measured ACF deviation on
    the decoded reconstruction respects the configured ε."""
    store, X, res = stored_mv
    got = store.read_series("m")
    for c in range(X.shape[1]):
        s0 = acf(jnp.asarray(aggregate_series(
            jnp.asarray(X[:, c]), CFG.kappa)), CFG.lags)
        s1 = acf(jnp.asarray(aggregate_series(
            jnp.asarray(got[:, c], np.float64), CFG.kappa)), CFG.lags)
        dev = float(mae(s1, s0))
        assert dev <= CFG.eps + 1e-12, (c, dev)
        # the recorded per-column deviation is the measured one
        np.testing.assert_allclose(
            store.series_meta("m")["deviations"][c], dev,
            rtol=1e-9, atol=1e-12)


def test_per_column_eps_budgets():
    """eps_c: every column's measured deviation respects ITS budget, and a
    tight budget on one column doesn't loosen the others; the repair loop
    enforces each budget independently on the shared index."""
    X = _mv_series(2048, C=3, seed=7)
    eps_c = [2e-2, 1e-3, 2e-2]
    res = compress_multivariate(X, CFG, eps_c=eps_c)
    for c, e in enumerate(eps_c):
        s0 = acf(jnp.asarray(X[:, c]), CFG.lags)
        s1 = acf(jnp.asarray(res.xr[:, c]), CFG.lags)
        assert float(mae(s1, s0)) <= e + 1e-12, c
        assert res.deviations[c] <= e + 1e-12, c
    # a uniform-loose run keeps fewer points than the tight-middle run
    loose = compress_multivariate(X, CFG)
    assert res.n_kept >= loose.n_kept
    assert res.deviation == res.deviations.max()


def test_eps_c_validation():
    X = _mv_series(512, C=2, seed=9)
    with pytest.raises(ValueError, match="eps_c"):
        compress_multivariate(X, CFG, eps_c=[1e-2])        # wrong length
    with pytest.raises(ValueError, match="eps_c"):
        compress_multivariate(X, CFG, eps_c=[1e-2, -1.0])  # non-positive


def test_dataset_write_per_column_eps(tmp_path):
    """Facade plumbing: Dataset.write(sid, X, eps=[...]) stores the same
    bytes as compress_multivariate(eps_c) + append_series, every measured
    deviation respects its budget, and a vector eps on univariate data is
    rejected."""
    from repro import api
    X = _mv_series(1536, C=2, seed=11)
    eps_c = [2e-2, 5e-3]
    p1 = str(tmp_path / "facade.cameo")
    with api.open(p1, CFG, mode="w", block_len=512) as d:
        entry = d.write("m", X, eps=eps_c)
        assert np.all(np.asarray(entry["deviations"])
                      <= np.asarray(eps_c) + 1e-12)
        with pytest.raises(ValueError, match="2-D"):
            d.write("u", X[:, 0], eps=eps_c)
    p2 = str(tmp_path / "direct.cameo")
    res = compress_multivariate(X, CFG, eps_c=eps_c)
    with CameoStore.create(p2, block_len=512) as w:
        w.append_series("m", res, CFG, x=X)
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()


def test_roundtrip_bit_exact(stored_mv):
    store, X, res = stored_mv
    got = store.read_series("m")
    assert got.shape == X.shape
    assert np.array_equal(got.view(np.uint64), res.xr.view(np.uint64))
    ki, kv = store.read_kept("m")
    assert np.array_equal(ki, np.flatnonzero(res.kept))
    assert np.array_equal(kv.view(np.uint64), X[ki].view(np.uint64))
    assert np.array_equal(store.kept_mask("m"), res.kept)


def test_column_decode_equals_standalone_store(stored_mv, tmp_path):
    """The differential façade contract: decoding any single column equals
    compressing-and-storing that column's kept points standalone on the
    shared index."""
    store, X, res = stored_mv

    class _Fake:
        pass

    for c in range(X.shape[1]):
        f = _Fake()
        f.kept = res.kept
        f.xr = np.ascontiguousarray(res.xr[:, c])
        f.deviation = float(res.deviations[c])
        p = str(tmp_path / f"col{c}.cameo")
        with CameoStore.create(p, block_len=512) as w:
            w.append_series("c", f, CFG, x=X[:, c])
        r = CameoStore.open(p)
        assert np.array_equal(
            r.read_series("c").view(np.uint64),
            store.read_series("m", col=c).view(np.uint64)), c
        ki_u, kv_u = r.read_kept("c")
        ki_m, kv_m = store.read_kept("m")
        assert np.array_equal(ki_u, ki_m)           # shared index bit-exact
        assert np.array_equal(kv_u, kv_m[:, c])     # kept values bit-exact


def test_window_reads_equal_slices(stored_mv):
    store, X, res = stored_mv
    rng = np.random.default_rng(2)
    n = X.shape[0]
    for _ in range(25):
        a = int(rng.integers(0, n))
        b = int(rng.integers(a, n + 1))
        got = store.read_window("m", a, b)
        assert np.array_equal(got, res.xr[a:b])
        c = int(rng.integers(0, X.shape[1]))
        assert np.array_equal(store.read_window("m", a, b, col=c),
                              res.xr[a:b, c])


def test_target_cr_mode_reports_deviations():
    X = _mv_series(1024, C=2, seed=9)
    cfg = CameoConfig(eps=2e-2, lags=8, mode="rounds", max_rounds=40,
                      target_cr=4.0, dtype="float64")
    res = compress_multivariate(X, cfg)
    assert res.n_kept >= X.shape[0] / 4.0 / 2  # union of two ~4x columns
    assert np.all(np.isfinite(res.deviations))


def test_bad_shapes_rejected():
    with pytest.raises(ValueError, match=r"\[n, C\]"):
        compress_multivariate(np.zeros(100), CFG)


# ---------------------------------------------------------------------------
# pushdown bounds per column, across blockings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_len", [256, 512, 1024])
def test_pushdown_bounds_across_blockings(tmp_path, block_len):
    X = _mv_series(2048, C=2, seed=11)
    res = compress_multivariate(X, CFG)
    p = str(tmp_path / f"b{block_len}.cameo")
    with CameoStore.create(p, block_len=block_len) as w:
        w.append_series("m", res, CFG, x=X)
    r = CameoStore.open(p)
    rng = np.random.default_rng(block_len)
    n = X.shape[0]
    for _ in range(20):
        a = int(rng.integers(0, n - 400))
        b = int(rng.integers(a + 300, n + 1))
        for c in range(X.shape[1]):
            s, bs = squery.query(r, "m", "sum", a, b, col=c)
            assert abs(s - X[a:b, c].sum()) <= bs
            v, bv = squery.query(r, "m", "var", a, b, col=c)
            assert abs(v - X[a:b, c].var()) <= bv
            av, ab_ = squery.query(r, "m", "acf", a, b, col=c)
            ref = np.asarray(acf(jnp.asarray(res.xr[a:b, c]), CFG.lags))
            assert np.all(np.abs(av - ref) <= ab_)
    # cross-column form: one call, stacked per-column answers
    vals, bounds = squery.query(r, "m", "mean", 64, n - 64)
    assert vals.shape == bounds.shape == (X.shape[1],)
    for c in range(X.shape[1]):
        assert abs(vals[c] - X[64:n - 64, c].mean()) <= bounds[c]


def test_column_view_validation(stored_mv):
    store, X, res = stored_mv
    with pytest.raises(ValueError, match="outside"):
        squery.ColumnView(store, "m", X.shape[1])
    with pytest.raises(ValueError, match="outside"):
        squery.ColumnView(store, "m", -1)


# ---------------------------------------------------------------------------
# streaming: chunking invariance + byte identity + resume
# ---------------------------------------------------------------------------

def _stream_store(path, X, cfg, wlen, chunks, block_len=512):
    with CameoStore.create(path, block_len=block_len) as store:
        sess = store.open_stream("m", cfg, channels=X.shape[1])
        comp = MVStreamingCompressor(cfg, wlen, X.shape[1])
        sess.state_provider = comp.state_dict
        lo = 0
        for sz in chunks:
            for w in comp.push(X[lo:lo + sz]):
                sess.append_window(w)
            lo += sz
        for w in comp.finish():
            sess.append_window(w)
        sess.close(deviation=comp.deviation(), deviations=comp.deviations())


def test_streamed_bytes_equal_oneshot_across_chunkings(tmp_path):
    X = _mv_series(3072, C=2, seed=21)
    wlen = max(512, min_window_len(CFG))
    ref = compress_windowed_mv(X, CFG, wlen)
    p_ref = str(tmp_path / "ref.cameo")
    with CameoStore.create(p_ref, block_len=512) as w:
        w.append_series("m", ref, CFG, x=X)
    ref_bytes = open(p_ref, "rb").read()
    n = X.shape[0]
    for chunks in ([n], [1000] * 3 + [n - 3000], [333] * (n // 333) + [n % 333]):
        p = str(tmp_path / f"c{chunks[0]}.cameo")
        _stream_store(p, X, CFG, wlen, [c for c in chunks if c])
        assert open(p, "rb").read() == ref_bytes, chunks
    # and the one-shot windowed result is itself within per-column eps on
    # every full window's kappa-divisible span (per-window guarantee)
    assert np.all(ref.deviations >= 0)


def test_streamed_pushdown_matches_oneshot(tmp_path):
    """Pushdown answers + bounds are identical for streamed vs one-shot
    ingest (same bytes -> same blocks -> same metadata), across a blocking
    different from the window length."""
    X = _mv_series(2560, C=2, seed=23)
    wlen = max(512, min_window_len(CFG))
    ref = compress_windowed_mv(X, CFG, wlen)
    p1 = str(tmp_path / "one.cameo")
    p2 = str(tmp_path / "str.cameo")
    with CameoStore.create(p1, block_len=384) as w:
        w.append_series("m", ref, CFG, x=X)
    _stream_store(p2, X, CFG, wlen, [700] * 3 + [460], block_len=384)
    r1, r2 = CameoStore.open(p1), CameoStore.open(p2)
    for kind in ("sum", "mean", "var", "acf"):
        for c in range(2):
            v1, b1 = squery.query(r1, "m", kind, 100, 2400, col=c)
            v2, b2 = squery.query(r2, "m", kind, 100, 2400, col=c)
            assert np.array_equal(np.asarray(v1), np.asarray(v2))
            assert np.array_equal(np.asarray(b1), np.asarray(b2))


def test_mv_stream_resume_bit_exact(tmp_path):
    import repro.api as cameo
    X = _mv_series(3000, C=2, seed=29)
    wlen = max(512, min_window_len(CFG))
    p1 = str(tmp_path / "full.cameo")
    p2 = str(tmp_path / "resumed.cameo")
    ds = cameo.open(p1, CFG, mode="w", block_len=512, stream_window=wlen)
    w = ds.stream("m", channels=2)
    for lo in range(0, 3000, 271):
        w.push(X[lo:lo + 271])
    w.close()
    ds.close()
    ds = cameo.open(p2, CFG, mode="w", block_len=512, stream_window=wlen)
    w = ds.stream("m", channels=2)
    for lo in range(0, 1500, 271):
        w.push(X[lo:lo + 271])
    ds.close()                     # stop mid-feed: state stashed in footer
    ds = cameo.open(p2, CFG, mode="a", block_len=512, stream_window=wlen)
    w = ds.stream("m", resume=True)
    assert w.channels == 2
    for lo in range(w.resume_from, 3000, 271):
        w.push(X[lo:lo + 271])
    w.close()
    ds.close()
    assert open(p1, "rb").read() == open(p2, "rb").read()


def test_mv_stream_rejects_bad_chunks():
    comp = MVStreamingCompressor(CFG, 512, 3)
    with pytest.raises(ValueError, match=r"\[m, 3\]"):
        comp.push(np.zeros((10, 2)))
    with pytest.raises(ValueError, match="channels"):
        MVStreamingCompressor(CFG, 512, None)


# ---------------------------------------------------------------------------
# property roundtrip
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**32 - 1), st.integers(2, 4),
       st.sampled_from([256, 512]))
@settings(max_examples=6, deadline=None)
def test_mv_roundtrip_property(seed, C, block_len):
    """For arbitrary fleets and blockings: the shared index stream and
    every column's kept values round-trip bit-exactly, and per-column
    deviations respect ε."""
    X = _mv_series(1536, C=C, seed=seed % 997)
    res = compress_multivariate(X, CFG)
    assert np.all(res.deviations <= CFG.eps + 1e-12)
    import tempfile
    with tempfile.TemporaryDirectory() as tmpdir:
        p = os.path.join(tmpdir, "m.cameo")
        with CameoStore.create(p, block_len=block_len) as w:
            w.append_series("m", res, CFG, x=X)
        r = CameoStore.open(p)
        ki, kv = r.read_kept("m")
        assert np.array_equal(ki, np.flatnonzero(res.kept))
        assert np.array_equal(kv.view(np.uint64), X[ki].view(np.uint64))
        got = r.read_series("m")
        assert np.array_equal(got.view(np.uint64),
                              res.xr.view(np.uint64))
