"""The unified telemetry layer (``repro.obs``) — contract tests.

What is pinned here:

* ``StreamingHistogram`` quantiles against ``np.quantile`` oracles on
  random streams (the log-bucketed sketch promises ~4.4% relative error);
* the disabled path costs one attribute lookup — a microbench bounds it,
  and ``obs.span`` returns the shared ``NULL_SPAN`` identity;
* the Prometheus-style exposition is byte-deterministic (golden test);
* span nesting depth / parent attribution / attrs via the JSONL sink;
* the observer property: ingesting and querying with ``CAMEO_OBS`` on
  produces **byte-identical stores and bit-identical query answers** to
  running with it off;
* ``recompile_watermark`` covers every registered jitted entry point and
  the old ``core.streaming.compile_cache_size`` survives as a deprecated
  shim over it;
* the unified ``stats()`` schema: ``Dataset.stats()`` fast (O(1) running
  totals) vs ``deep=True`` (per-series walk) agree, and
  ``TimeSeriesService.stats()`` is a key-superset with equal shared keys;
* the acceptance snapshot: a streamed multivariate ingest plus a pushdown
  query session reports push-latency quantiles, window/queue counters,
  the recompile watermark, cache hit rates, and realized bound widths.
"""
import json
import math
import os
import time

import numpy as np
import pytest

import repro.obs as obs
from repro.obs import OBS, MetricsRegistry, NULL_SPAN, StreamingHistogram
from repro.obs import sanitize_metric_name
from repro.core.cameo import CameoConfig, compress

CFG = CameoConfig(eps=2e-2, lags=8, mode="rounds", max_rounds=60,
                  dtype="float64")


def _series(n, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return (3 * np.sin(2 * np.pi * t / 24) + np.sin(2 * np.pi * t / 168)
            + 0.2 * rng.standard_normal(n))


@pytest.fixture
def obs_state():
    """Reset the process-wide registry on entry (a CAMEO_OBS=1 suite run
    accumulates metrics from every preceding test) and restore the
    enabled flag + sinks on exit, so suite runs with CAMEO_OBS=1 and =0
    both stay hermetic."""
    was = obs.enabled()
    sinks = list(OBS._sinks)
    obs.reset()
    yield OBS
    OBS._sinks[:] = sinks
    obs.reset()
    OBS.enabled = was


# ---------------------------------------------------------------------------
# StreamingHistogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,dist", [
    (0, "lognormal"), (1, "exponential"), (2, "uniform")])
def test_histogram_quantiles_vs_numpy(seed, dist):
    rng = np.random.default_rng(seed)
    n = 5000
    if dist == "lognormal":
        v = rng.lognormal(mean=-7.0, sigma=2.0, size=n)   # latency-like
    elif dist == "exponential":
        v = rng.exponential(scale=3e-3, size=n)
    else:
        v = rng.uniform(1.0, 1e4, size=n)
    h = StreamingHistogram()
    for x in v:
        h.observe(x)
    assert h.count == n
    assert h.sum == pytest.approx(float(v.sum()))
    assert h.min == float(v.min()) and h.max == float(v.max())
    for q in (0.5, 0.95, 0.99):
        got = h.quantile(q)
        want = float(np.quantile(v, q, method="inverted_cdf"))
        # one bucket of sketch error (~4.4%) plus discretization slack
        assert got == pytest.approx(want, rel=0.06), (q, got, want)


def test_histogram_edges():
    h = StreamingHistogram()
    snap = h.snapshot()
    assert snap["count"] == 0 and math.isnan(snap["p50"])
    h.observe(float("nan"))                     # dropped, not poisoned
    assert h.count == 0
    h.observe(-2.0)
    h.observe(0.0)
    h.observe(4.0)
    assert h.count == 3 and h.min == -2.0 and h.max == 4.0
    # 2/3 of the mass is non-positive: the median resolves to the min
    assert h.quantile(0.5) == -2.0
    assert h.quantile(0.99) == pytest.approx(4.0, rel=0.05)


def test_sanitize_metric_name():
    assert sanitize_metric_name("a.b-c") == "a_b_c"
    assert sanitize_metric_name("1abc") == "_1abc"
    assert sanitize_metric_name("query.kind.sum") == "query_kind_sum"


# ---------------------------------------------------------------------------
# Disabled-path cost
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop(obs_state):
    obs.disable()
    s = obs.span("anything", k=1)
    assert s is NULL_SPAN
    with s as inner:
        inner.set("x", 2)            # no-op, chainable
    assert obs.snapshot()["counters"] == {}


def test_disabled_path_microbench(obs_state):
    """The guarded call site must cost about one attribute lookup: bound
    it both relative to an unguarded pass loop and absolutely."""
    obs.disable()
    n = 100_000

    def guarded():
        t0 = time.perf_counter()
        for _ in range(n):
            if OBS.enabled:
                OBS.inc("never")
        return time.perf_counter() - t0

    def bare():
        t0 = time.perf_counter()
        for _ in range(n):
            pass
        return time.perf_counter() - t0

    g = min(guarded() for _ in range(5))
    b = min(bare() for _ in range(5))
    per_iter = g / n
    # generous bounds so a loaded CI runner can't flake: an attribute
    # lookup is ~30ns; a regression to real work (dict writes, timers)
    # costs 10-100x more than either floor
    assert per_iter < 2e-6, f"disabled guard costs {per_iter * 1e9:.0f}ns"
    assert g < 20 * max(b, 1e-9) + 1e-3
    assert obs.snapshot()["counters"] == {}


# ---------------------------------------------------------------------------
# Exposition
# ---------------------------------------------------------------------------

def test_exposition_golden():
    reg = MetricsRegistry(enabled=True)
    reg.inc("a.b", 3)
    reg.gauge("g", 2.5)
    reg.observe("h", 1.0)
    assert reg.exposition() == (
        "# TYPE cameo_a_b counter\n"
        "cameo_a_b_total 3\n"
        "# TYPE cameo_g gauge\n"
        "cameo_g 2.5\n"
        "# TYPE cameo_h summary\n"
        'cameo_h{quantile="0.5"} 1\n'
        'cameo_h{quantile="0.95"} 1\n'
        'cameo_h{quantile="0.99"} 1\n'
        "cameo_h_sum 1\n"
        "cameo_h_count 1\n")


def test_exposition_labeled_golden():
    """Labeled metrics render Prometheus-style: sorted label keys,
    escaped values, one TYPE line per metric base, and the unlabeled
    series first.  The unlabeled output above is byte-unchanged."""
    reg = MetricsRegistry(enabled=True)
    reg.inc("a.b", 3)
    reg.inc("a.b", 2, labels={"tenant": "t0"})
    reg.inc("a.b", 1, labels={"tenant": "t1", "shard": 's"x\\y'})
    reg.gauge("g", 1.5, labels={"shard": "s1"})
    reg.observe("h", 1.0, labels={"tenant": "t0"})
    assert reg.exposition() == (
        "# TYPE cameo_a_b counter\n"
        "cameo_a_b_total 3\n"
        'cameo_a_b_total{shard="s\\"x\\\\y",tenant="t1"} 1\n'
        'cameo_a_b_total{tenant="t0"} 2\n'
        "# TYPE cameo_g gauge\n"
        'cameo_g{shard="s1"} 1.5\n'
        "# TYPE cameo_h summary\n"
        'cameo_h{tenant="t0",quantile="0.5"} 1\n'
        'cameo_h{tenant="t0",quantile="0.95"} 1\n'
        'cameo_h{tenant="t0",quantile="0.99"} 1\n'
        'cameo_h_sum{tenant="t0"} 1\n'
        'cameo_h_count{tenant="t0"} 1\n')


def test_exposition_groups_type_lines_by_sanitized_base():
    """A metric name that raw-sorts *between* a base and its labeled
    keys (``a.b.c`` < ``a.b{``) must not split the base family across
    two ``# TYPE`` lines — Prometheus parsers reject the duplicate."""
    reg = MetricsRegistry(enabled=True)
    reg.inc("a.b", 1)
    reg.inc("a.b", 2, labels={"tenant": "t0"})
    reg.inc("a.b.c", 3)
    text = reg.exposition()
    types = [ln for ln in text.splitlines() if ln.startswith("# TYPE")]
    assert len(types) == len(set(types))
    assert text == (
        "# TYPE cameo_a_b counter\n"
        "cameo_a_b_total 1\n"
        'cameo_a_b_total{tenant="t0"} 2\n'
        "# TYPE cameo_a_b_c counter\n"
        "cameo_a_b_c_total 3\n")


def test_exposition_watermark_line_only_with_jits():
    reg = MetricsRegistry(enabled=True)
    assert "recompile_watermark" not in reg.exposition()
    compress(np.asarray(_series(256)), CFG)     # ensure OBS has real jits
    assert "cameo_recompile_watermark" in OBS.exposition()


def test_registry_reset_keeps_structure():
    reg = MetricsRegistry(enabled=True)
    with pytest.raises(TypeError):
        reg.register_jit("plain", lambda: None)
    seen = []
    reg._sinks.append(seen.append)
    reg.inc("c")
    reg.observe("h", 1.0)
    reg.reset()
    snap = reg.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}
    assert reg._sinks == [seen.append]          # sinks survive reset


# ---------------------------------------------------------------------------
# Spans + events
# ---------------------------------------------------------------------------

def test_span_nesting_attrs_jsonl(obs_state, tmp_path):
    path = str(tmp_path / "events.jsonl")
    obs.enable()
    obs.reset()
    OBS._sinks[:] = [obs.jsonl_sink(path)]
    with obs.span("outer", sid="s1"):
        assert obs.current_span().name == "outer"
        with obs.span("inner") as sp:
            sp.set("rows", 7)
            assert sp.depth == 1 and sp.parent == "outer"
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("x")
    evs = [json.loads(line) for line in open(path)]
    by_name = {e["name"]: e for e in evs}
    assert by_name["inner"]["depth"] == 1
    assert by_name["inner"]["parent"] == "outer"
    assert by_name["inner"]["attrs"] == {"rows": 7}
    assert by_name["outer"]["depth"] == 0 and by_name["outer"]["parent"] is None
    assert by_name["boom"]["error"] == "ValueError"
    snap = obs.snapshot()
    assert snap["counters"]["span.outer.calls"] == 1
    assert snap["histograms"]["span.inner.seconds"]["count"] == 1
    assert all(e["ts"] > 0 for e in evs)


def test_event_api_and_sink_errors_are_swallowed(obs_state):
    obs.enable()
    got = []

    def bad_sink(ev):
        raise RuntimeError("sink down")

    OBS._sinks[:] = [bad_sink, got.append]
    obs.event("checkpoint", step=3)             # must not raise
    assert got and got[0]["ev"] == "checkpoint" and got[0]["step"] == 3
    obs.disable()
    obs.event("dropped")
    assert len(got) == 1


# ---------------------------------------------------------------------------
# Recompile watermark + shim
# ---------------------------------------------------------------------------

def test_recompile_watermark_covers_entry_points(obs_state):
    compress(np.asarray(_series(256)), CFG)
    counts = obs.recompile_counts()
    assert "cameo.rounds" in counts
    assert obs.recompile_watermark() == sum(counts.values())
    assert counts["cameo.rounds"] >= 1
    # warm repeat: no new programs
    before = obs.recompile_watermark()
    compress(np.asarray(_series(256, seed=3)), CFG)
    assert obs.recompile_watermark() == before


def test_compile_cache_size_shim_warns(obs_state):
    from repro.core.streaming import compile_cache_size
    with pytest.warns(DeprecationWarning):
        n = compile_cache_size()
    assert n == obs.recompile_watermark()


# ---------------------------------------------------------------------------
# The observer property: identical bytes and answers with obs on vs off
# ---------------------------------------------------------------------------

def _ingest_and_query(path):
    """One full session: streamed univariate + one-shot multivariate
    ingest, then a pushdown + decode query mix.  Returns the answers."""
    import repro.api as api

    x = _series(1536, seed=11)
    X = np.stack([x, 0.5 * np.roll(x, 7) + 0.1 * _series(1536, seed=12)],
                 axis=1)
    with api.open(path, CFG, mode="w", block_len=256,
                  stream_window=256) as ds:
        with ds.stream("uni", queue_depth=2) as w:
            for lo in range(0, len(x), 613):
                w.push(x[lo:lo + 613])
        ds.write("mv", X)
    ds = api.open(path, cache_bytes=1 << 20)
    s, m = ds.series("uni"), ds.series("mv")
    out = dict(
        uni_sum=s.sum(100, 1400), uni_mean=s.mean(), uni_var=s.var(),
        uni_acf=s.acf(0, 1024), uni_win=s.window(200, 700),
        uni_win_hot=s.window(200, 700),
        mv_mean=m.mean(50, 1500), mv_win=m.window(0, 300, col=1))
    stats = ds.stats()
    ds.close()
    return out, stats


def test_obs_on_off_differential(obs_state, tmp_path):
    p_off, p_on = str(tmp_path / "off.cameo"), str(tmp_path / "on.cameo")
    obs.disable()
    out_off, stats_off = _ingest_and_query(p_off)
    obs.enable()
    obs.reset()
    out_on, stats_on = _ingest_and_query(p_on)
    with open(p_off, "rb") as f1, open(p_on, "rb") as f2:
        assert f1.read() == f2.read(), \
            "enabling telemetry changed the stored bytes"
    for k in out_off:
        a, b = out_off[k], out_on[k]
        if isinstance(a, tuple):
            for ai, bi in zip(a, b):
                np.testing.assert_array_equal(np.asarray(ai), np.asarray(bi))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # unified stats totals are telemetry-independent too (cache counters
    # differ only if instrumentation changed access patterns — they must
    # not, so compare them as well)
    assert stats_off == stats_on
    # and the enabled session actually recorded the instrumentation
    snap = obs.snapshot()
    assert snap["counters"]["stream.windows"] >= 6
    assert snap["histograms"]["stream.push_seconds"]["count"] >= 1


# ---------------------------------------------------------------------------
# Unified stats schema
# ---------------------------------------------------------------------------

UNIFIED_KEYS = {"series", "points", "n_kept", "stored_nbytes", "raw_nbytes",
                "point_cr", "bytes_cr", "cache"}


def test_dataset_stats_fast_matches_deep(tmp_path):
    import repro.api as api

    path = str(tmp_path / "d.cameo")
    x = _series(1024, seed=5)
    X = np.stack([x, np.roll(x, 3)], axis=1)
    with api.open(path, CFG, mode="w", block_len=256,
                  stream_window=256) as ds:
        ds.write("a", x)
        ds.write("m", X)
        with ds.stream("s") as w:          # streamed series counted too
            w.push(_series(700, seed=6))
        fast = ds.stats()
        deep = ds.stats(deep=True)
    assert UNIFIED_KEYS <= set(fast)
    assert set(fast) | {"per_series"} == set(deep)
    for k in fast:
        assert fast[k] == deep[k], k
    per = deep["per_series"]
    assert set(per) == {"a", "m", "s"}
    # the O(1) running totals agree with the exhaustive walk
    assert fast["series"] == len(per)
    assert fast["points"] == sum(p["n"] * p["channels"] for p in per.values())
    assert fast["n_kept"] == sum(
        p["n_kept"] * p["channels"] for p in per.values())
    assert fast["stored_nbytes"] == sum(
        p["stored_nbytes"] for p in per.values())
    assert fast["raw_nbytes"] == sum(p["raw_nbytes"] for p in per.values())


def test_ingest_totals_survive_reopen_and_resume(tmp_path):
    import repro.api as api

    path = str(tmp_path / "r.cameo")
    x = _series(1100, seed=9)
    ds = api.open(path, CFG, mode="w", block_len=256, stream_window=256)
    w = ds.stream("s")
    w.push(x[:600])
    ds.close()                               # mid-stream: state stashed
    ds = api.open(path, CFG, mode="a", block_len=256, stream_window=256)
    w = ds.stream("s", resume=True)
    w.push(x[w.resume_from:])
    w.close()
    fast = ds.stats()
    deep = ds.stats(deep=True)["per_series"]["s"]
    ds.close()
    assert fast["points"] == deep["n"] == 1100
    assert fast["n_kept"] == deep["n_kept"]
    assert fast["stored_nbytes"] == deep["stored_nbytes"]


def test_service_stats_superset(tmp_path):
    from repro.serving.ts_service import TimeSeriesService, TsServiceConfig

    path = str(tmp_path / "svc.cameo")
    with TimeSeriesService(path, CFG, TsServiceConfig(
            block_len=256, stream_window=256)) as svc:
        with pytest.warns(DeprecationWarning):
            svc.submit("a", _series(512, seed=1))
        svc.flush()
        st = svc.stats()
        assert UNIFIED_KEYS | {"ingested", "pending", "batches",
                               "streams"} <= set(st)
        assert st["series"] == 1 and st["ingested"] == 1
        deep = svc.stats(deep=True)
        assert set(deep["per_series"]) == {"a"}
        for k in UNIFIED_KEYS - {"cache"}:
            assert st[k] == deep[k], k


# ---------------------------------------------------------------------------
# Acceptance: the end-to-end snapshot
# ---------------------------------------------------------------------------

def test_acceptance_snapshot(obs_state, tmp_path):
    """Streamed multivariate ingest + a pushdown query session must light
    up every pillar of the snapshot: push-latency quantiles, window and
    queue counters, the recompile watermark, cache hit rates, and the
    realized pushdown bound widths."""
    import repro.api as api

    obs.enable()
    obs.reset()
    path = str(tmp_path / "acc.cameo")
    rng = np.random.default_rng(21)
    n, C = 1500, 3                           # 5 full windows + a padded tail
    base = _series(n, seed=21)
    X = np.stack([base] + [
        (0.7 + 0.1 * c) * np.roll(base, 5 * c)
        + 0.05 * rng.standard_normal(n) for c in range(1, C)], axis=1)
    with api.open(path, CFG, mode="w", block_len=256,
                  stream_window=256) as ds:
        with ds.stream("rack", channels=C, queue_depth=2) as w:
            for lo in range(0, n, 521):
                w.push(X[lo:lo + 521])
    ds = api.open(path, cache_bytes=1 << 20)
    s = ds.series("rack")
    s.mean(100, 1400)
    s.acf(0, 1024)
    s.window(200, 600)
    s.window(200, 600)                       # hot decode: cache hit
    stats = ds.stats()
    ds.close()

    snap = obs.snapshot()
    c, h = snap["counters"], snap["histograms"]
    push = h["stream.push_seconds"]
    assert push["count"] == 3 and push["p50"] > 0 and push["p95"] > 0
    assert c["stream.windows"] == 6          # 5 full + 1 padded tail
    assert c["stream.pad_to_bucket_hits"] >= 1
    assert c["stream.queue_drains"] >= 1
    assert h["stream.window_eps_headroom"]["max"] <= 1.0 + 1e-9
    assert snap["recompiles"]["total"] >= 1
    assert {"cameo.rounds", "cameo.sequential", "cameo.mvar_reconstruct",
            "store.reconstruct"} <= set(snap["recompiles"]["entries"])
    assert c["store.cache.hits"] >= 1
    assert c["query.count"] == 2             # mean + acf pushdowns
    assert h["query.bound_width"]["count"] == 2
    assert np.isfinite(h["query.bound_width"]["max"])
    assert c["query.segments_meta"] >= 1
    # the unified stats view agrees with the ingest
    assert stats["series"] == 1 and stats["points"] == n * C
    # and the whole registry round-trips through the text exposition
    text = obs.exposition()
    assert "cameo_stream_windows_total 6" in text
    assert 'cameo_stream_push_seconds{quantile="0.5"}' in text
