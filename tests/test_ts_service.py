"""TimeSeriesService: batched ingest equals per-series compression
bit-for-bit, queries serve flushed series immediately, restart resumes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cameo import CameoConfig, compress
from repro.serving.ts_service import TimeSeriesService, TsServiceConfig
from repro.store.store import CameoStore

CFG = CameoConfig(eps=2e-2, lags=12, mode="rounds", max_rounds=60,
                  dtype="float64")


def _fleet(lengths, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for i, n in enumerate(lengths):
        t = np.arange(n)
        out[f"s{i}"] = (np.sin(2 * np.pi * t / 24 + i)
                        + 0.1 * rng.standard_normal(n))
    return out


def test_service_ingest_query_roundtrip(tmp_path):
    path = str(tmp_path / "svc.cameo")
    fleet = _fleet([512] * 5 + [1024] * 2)
    scfg = TsServiceConfig(max_batch=4, block_len=128)
    with TimeSeriesService(path, CFG, scfg) as svc:
        for sid, x in fleet.items():
            svc.submit(sid, x)
        # the 512-group auto-flushed at max_batch; queries work mid-stream
        stats = svc.stats()
        assert stats["ingested"] == 4 and stats["pending"] == 3
        ref = np.asarray(compress(jnp.asarray(fleet["s0"]), CFG).xr)
        assert np.array_equal(svc.query_window("s0", 40, 200), ref[40:200])
        v, b = svc.query_aggregate("s1", "mean", 10, 400)
        assert abs(v - fleet["s1"][10:400].mean()) <= b
        with pytest.raises(ValueError, match="already submitted"):
            svc.submit("s0", fleet["s0"])

    # after close: every series stored, batched results == single-series
    store = CameoStore.open(path)
    assert sorted(store.series_ids()) == sorted(fleet)
    for sid, x in fleet.items():
        ref = np.asarray(compress(jnp.asarray(x), CFG).xr)
        got = store.read_series(sid)
        assert np.array_equal(got.view(np.uint64), ref.view(np.uint64)), sid
    final = [store.compression_stats(s) for s in store.series_ids()]
    assert all(f["bytes_cr"] > 1.0 for f in final)


def test_service_resume_appends(tmp_path):
    path = str(tmp_path / "svc.cameo")
    fleet = _fleet([512] * 3, seed=1)
    with TimeSeriesService(path, CFG, TsServiceConfig(block_len=128)) as svc:
        for sid, x in list(fleet.items())[:2]:
            svc.submit(sid, x)
    with TimeSeriesService(path, CFG, TsServiceConfig(block_len=128),
                           resume=True) as svc:
        assert sorted(svc.series_ids()) == ["s0", "s1"]
        svc.submit("s2", fleet["s2"])
    store = CameoStore.open(path)
    assert sorted(store.series_ids()) == ["s0", "s1", "s2"]
    ref = np.asarray(compress(jnp.asarray(fleet["s2"]), CFG).xr)
    assert np.array_equal(store.read_series("s2"), ref)


def test_service_cache_stats(tmp_path):
    path = str(tmp_path / "cache.cameo")
    fleet = _fleet([512] * 2, seed=3)
    scfg = TsServiceConfig(block_len=128, cache_bytes=1 << 20)
    with TimeSeriesService(path, CFG, scfg) as svc:
        for sid, x in fleet.items():
            svc.submit(sid, x)
        svc.flush()
        first = svc.query_window("s0", 10, 400)
        again = svc.query_window("s0", 10, 400)
        assert np.array_equal(first, again)
        stats = svc.stats()
        assert stats["cache"]["hits"] > 0
        assert stats["cache"]["budget"] == 1 << 20
        assert stats["cache"]["nbytes"] <= stats["cache"]["budget"]
        # repeated pushdown queries ride the same cache: the second query's
        # edge-block decodes must be served from the LRU
        svc.query_aggregate("s1", "mean", 10, 400)
        h0 = svc.stats()["cache"]["hits"]
        svc.query_aggregate("s1", "mean", 10, 400)
        assert svc.stats()["cache"]["hits"] > h0


def test_service_routes_through_server_byte_identical(tmp_path):
    """The deprecated service shims ride the ingest server's
    default-tenant session API; the stored file must stay byte-identical
    to the direct Dataset façade driving the same feed."""
    import warnings

    import repro.api as cameo
    from repro.core.streaming import min_window_len
    from repro.server import IngestServer

    x = _fleet([700], seed=5)["s0"]
    wlen = max(256, min_window_len(CFG))
    p_svc = str(tmp_path / "svc.cameo")
    p_ds = str(tmp_path / "ds.cameo")
    scfg = TsServiceConfig(block_len=128, stream_window=wlen)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with TimeSeriesService(p_svc, CFG, scfg) as svc:
            assert isinstance(svc._server, IngestServer)   # the reroute
            svc.submit("a", x)
            svc.flush()
            h = svc.ingest_stream("b", window_len=wlen)
            for lo in range(0, 700, 130):
                h.push(x[lo:lo + 130])
            h.close()
    with cameo.open(p_ds, CFG, mode="w", block_len=128,
                    stream_window=wlen) as ds:
        ds.write_batch({"a": x})
        with ds.stream("b") as w:
            for lo in range(0, 700, 130):
                w.push(x[lo:lo + 130])
    assert open(p_svc, "rb").read() == open(p_ds, "rb").read()


def test_service_sequential_mode_fallback(tmp_path):
    cfg = CameoConfig(eps=2e-2, lags=8, mode="sequential", hops=8,
                      window=32, dtype="float64")
    path = str(tmp_path / "seq.cameo")
    fleet = _fleet([400] * 2, seed=2)
    with TimeSeriesService(path, cfg, TsServiceConfig(block_len=100)) as svc:
        for sid, x in fleet.items():
            svc.submit(sid, x)
    store = CameoStore.open(path)
    for sid, x in fleet.items():
        res = compress(jnp.asarray(x), cfg)
        ref = np.asarray(res.xr)
        kept = np.asarray(res.kept)
        got = store.read_series(sid)
        # sequential mode accumulates xr incrementally; the store serves the
        # canonical one-shot interpolation: kept points bit-exact, dead
        # positions agree to the last ulp
        assert np.array_equal(store.kept_mask(sid), kept)
        assert np.array_equal(got[kept], ref[kept])
        np.testing.assert_allclose(got, ref, rtol=0, atol=1e-12)
        # and the served window IS the canonical decompression
        idx = np.nonzero(kept)[0]
        assert np.array_equal(got, store.read_window(sid, 0, len(x)))
        assert got.shape == ref.shape and idx[0] == 0
