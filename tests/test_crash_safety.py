"""Crash safety: the write-ahead journal (store/wal.py) + torn-tail
recovery make `mode="a"` reopen lossless for acked pushes.

The harness simulates kill-at-arbitrary-byte crashes by building a *crash
image* — a writer is driven partway and abandoned with its OS-level file
contents captured — and then truncating the store (or journal) at every
structural offset class: inside a block body, inside the footer, inside
the tail marker, inside a journal record.  Recovery must always land on
the last consistent prefix, replay every acked push, and produce a file
byte-identical to a clean uninterrupted run of the same feed.
"""
import os
import shutil

import numpy as np
import pytest

import repro.api as api
from repro.core.cameo import CameoConfig
from repro.serving.ts_service import TimeSeriesService, TsServiceConfig
from repro.store import wal as walmod
from repro.store.store import CameoStore

CFG = CameoConfig(eps=2e-2, lags=8, mode="rounds", max_rounds=60,
                  dtype="float64")
W = 64          # stream window
BLK = 64        # store block length
CHUNK = 37      # deliberately misaligned with W and BLK
N = 1200


def _series(n=N, seed=7):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return (3 * np.sin(2 * np.pi * t / 24) + np.sin(2 * np.pi * t / 168)
            + 0.2 * rng.standard_normal(n))


def _open_ds(p, mode):
    return api.open(p, CFG, mode=mode, block_len=BLK, stream_window=W)


def _push_range(w, x, a, b):
    for i in range(a, b, CHUNK):
        w.push(x[i:min(i + CHUNK, b)])


def _clean_run(p, x, upto=None, flush_only=False):
    """Uninterrupted reference writer; returns the final file bytes."""
    upto = len(x) if upto is None else upto
    ds = _open_ds(p, "w")
    w = ds.stream("s")
    _push_range(w, x, 0, upto)
    if flush_only:
        ds.flush()
        blob = open(p, "rb").read()
        w.close()
        ds.close()
        return blob
    w.close()
    ds.close()
    return open(p, "rb").read()


def _snapshot_crash(store, p):
    """Capture the writer's OS-visible file state as the crash image at
    ``p`` (+ ``.wal``): what a kill -9 leaves in the page cache.  The live
    writer keeps running on its own path and is closed cleanly afterwards
    (closing a file object whose fd was os.close()d would double-close a
    reused descriptor)."""
    store._f.flush()
    if store._wal is not None:
        store._wal._f.flush()
    shutil.copyfile(store.path, p)
    if store._wal is not None:
        shutil.copyfile(store._wal.path, p + ".wal")


def _crash_writer(p, x, upto, flush_at=None):
    """Drive a writer to ``upto`` acked points and leave its crash image
    at ``p``.  Returns the acked count."""
    live = p + ".live"
    ds = _open_ds(live, "w")
    w = ds.stream("s")
    acked = 0
    for i in range(0, upto, CHUNK):
        c = x[i:min(i + CHUNK, upto)]
        w.push(c)
        acked += len(c)
        if flush_at is not None and acked >= flush_at:
            ds.flush()
            flush_at = None
    _snapshot_crash(ds.store, p)
    w.close()
    ds.close()
    return acked


def _finish_feed(p, x, total=N):
    """Reopen, resume, feed the rest of ``x``, close; returns final bytes
    plus the resume point the recovery landed on."""
    ds = _open_ds(p, "a")
    w = ds.stream("s", resume=True)
    start = w.resume_from
    _push_range(w, x, start, total)
    w.close()
    ds.close()
    return open(p, "rb").read(), start


# ---------------------------------------------------------------------------
# end-to-end recovery
# ---------------------------------------------------------------------------

def test_crash_resume_byte_identity(tmp_path):
    """Crash after a mid-run flush: every acked push is recovered and the
    finished file is byte-identical to a clean uninterrupted run."""
    x = _series()
    p = str(tmp_path / "c.cameo")
    acked = _crash_writer(p, x, 800, flush_at=400)
    got, start = _finish_feed(p, x)
    assert start == acked                       # no acked push lost
    assert got == _clean_run(str(tmp_path / "ref.cameo"), x)
    assert not os.path.exists(p + ".wal")       # clean close retires it


def test_crash_before_any_flush_recovers_from_journal_alone(tmp_path):
    """A stream that crashed before any footer existed lives only in the
    journal: recovery re-creates it from scratch and replays."""
    x = _series()
    p = str(tmp_path / "c.cameo")
    acked = _crash_writer(p, x, 500, flush_at=None)
    got, start = _finish_feed(p, x)
    assert start == acked
    assert got == _clean_run(str(tmp_path / "ref.cameo"), x)


def test_multivariate_crash_recovery(tmp_path):
    """v4 stores recover too — including the head-magic rollback when the
    crash interrupted the v3→v4 upgrade window."""
    x = _series()
    X = np.stack([x, np.roll(x, 5) * 0.7], axis=1)
    p = str(tmp_path / "mv.cameo")
    ds = _open_ds(p + ".live", "w")
    w = ds.stream("mv", channels=2)
    acked = 0
    for i in range(0, 700, CHUNK):
        c = X[i:min(i + CHUNK, 700)]
        w.push(c)
        acked += len(c)
        if acked >= 300 and acked < 300 + CHUNK:
            ds.flush()
    _snapshot_crash(ds.store, p)
    w.close()
    ds.close()

    ds2 = _open_ds(p, "a")
    w2 = ds2.stream("mv", channels=2, resume=True)
    assert w2.resume_from == acked
    for i in range(acked, N, CHUNK):
        w2.push(X[i:min(i + CHUNK, N)])
    w2.close()
    ds2.close()

    pr = str(tmp_path / "ref.cameo")
    ds = _open_ds(pr, "w")
    w = ds.stream("mv", channels=2)
    for i in range(0, N, CHUNK):
        w.push(X[i:min(i + CHUNK, N)])
    w.close()
    ds.close()
    assert open(p, "rb").read() == open(pr, "rb").read()


def test_service_stop_crash_resume_byte_identity(tmp_path):
    """Service-level stop (clean close), then a crash on the resumed run,
    then a second resume: the finished store is byte-identical to the
    uninterrupted feed."""
    x = _series()
    p = str(tmp_path / "svc.cameo")
    scfg = TsServiceConfig(block_len=BLK, stream_window=W)
    svc = TimeSeriesService(p, CFG, scfg)
    with pytest.warns(DeprecationWarning):
        h = svc.ingest_stream("s")
    _push_range(h, x, 0, 400)
    svc.close()                                  # clean stop, stream open

    live = p + ".live"
    shutil.copyfile(p, live)                     # second leg on a copy
    svc = TimeSeriesService(live, CFG, scfg, resume=True)
    with pytest.warns(DeprecationWarning):
        h = svc.ingest_stream("s", resume=True)
    assert h.resume_from == 400
    _push_range(h, x, 400, 900)
    _snapshot_crash(svc.store, p)                # crash mid-second-run
    h.close()
    svc.close()

    svc = TimeSeriesService(p, CFG, scfg, resume=True)
    with pytest.warns(DeprecationWarning):
        h = svc.ingest_stream("s", resume=True)
    assert h.resume_from == 900                  # nothing acked was lost
    _push_range(h, x, 900, N)
    h.close()
    svc.close()
    assert open(p, "rb").read() == _clean_run(
        str(tmp_path / "ref.cameo"), x)


# ---------------------------------------------------------------------------
# kill-at-every-offset fault injection
# ---------------------------------------------------------------------------

def _recovery_floor(wal_path):
    """Bytes of the store file the journal checkpoint still needs: below
    this offset a truncation is data loss beyond crash semantics (the
    checkpointed footer itself is restored *from the journal*, so cuts
    anywhere at or past ``footer_offset`` are recoverable)."""
    scanres = walmod.scan(wal_path)
    return scanres.checkpoint.footer_offset


def test_kill_at_every_store_offset(tmp_path):
    """Truncate the crashed store file at every offset class past the
    journal checkpoint — mid-block, mid-footer, mid-tail-marker, empty
    tail — and assert recovery always lands on the acked prefix,
    byte-identical to a clean run of the same pushes."""
    x = _series()
    img = tmp_path / "img"
    img.mkdir()
    p = str(img / "c.cameo")
    acked = _crash_writer(p, x, 800, flush_at=400)
    store_blob = open(p, "rb").read()
    wal_blob = open(p + ".wal", "rb").read()
    floor = _recovery_floor(p + ".wal")
    assert floor <= len(store_blob)

    # the recovered-prefix reference: a clean writer over exactly the
    # acked pushes, flushed (recovery + flush must reproduce it, bit for
    # bit, regardless of where the crash tore the file)
    ref_prefix = _clean_run(str(tmp_path / "refp.cameo"), x, upto=acked,
                            flush_only=True)

    tail = len(store_blob) - floor
    offsets = set(range(floor, len(store_blob) + 1,
                        max(1, tail // 40)))       # interior sweep
    offsets |= {floor, floor + 1,                  # checkpoint boundary
                len(store_blob) - 1, len(store_blob),   # EOF classes
                }
    offsets |= {len(store_blob) - k for k in range(1, 13)}  # tail marker
    work = tmp_path / "w"
    for cut in sorted(offsets):
        if work.exists():
            shutil.rmtree(work)
        work.mkdir()
        q = str(work / "c.cameo")
        with open(q, "wb") as f:
            f.write(store_blob[:cut])
        with open(q + ".wal", "wb") as f:
            f.write(wal_blob)
        ds = _open_ds(q, "a")                     # must always load
        w = ds.stream("s", resume=True)
        assert w.resume_from == acked, f"cut={cut}: lost acked pushes"
        ds.flush()
        got = open(q, "rb").read()
        assert got == ref_prefix, f"cut={cut}: recovered prefix differs"
        w.close()
        ds.close()


def test_kill_at_every_wal_offset(tmp_path):
    """Truncate the journal at record boundaries and mid-record: recovery
    lands on the last intact record prefix (a torn append was never acked
    as journaled), and finishing the feed stays byte-identical."""
    x = _series()
    img = tmp_path / "img"
    img.mkdir()
    p = str(img / "c.cameo")
    _crash_writer(p, x, 500, flush_at=None)   # no footer: journal-only
    store_blob = open(p, "rb").read()
    wal_blob = open(p + ".wal", "rb").read()

    # record layout of the journal image (checkpoint first, then pushes)
    ends = [pos for _, pos in walmod._iter_records(wal_blob)]
    assert len(ends) >= 3
    ckpt_end = ends[0]
    ref = _clean_run(str(tmp_path / "ref.cameo"), x)

    cases = []                 # (cut, points the scan must still see)
    pts = 0
    for i, end in enumerate(ends[1:]):
        prev_pts = pts
        pts += min(CHUNK, 500 - i * CHUNK)
        cases.append((end, pts))               # exactly at a boundary
        cases.append((end - 3, prev_pts))      # torn checksum/payload
    cases.append((ckpt_end, 0))                # no pushes survive

    work = tmp_path / "w"
    for k, (cut, want_pts) in enumerate(cases):
        if work.exists():
            shutil.rmtree(work)
        work.mkdir()
        q = str(work / "c.cameo")
        with open(q, "wb") as f:
            f.write(store_blob)
        with open(q + ".wal", "wb") as f:
            f.write(wal_blob[:cut])
        if want_pts == 0:
            # nothing journaled: the sid is unknown — resume must refuse,
            # but a fresh (non-resume) stream of the same sid works
            ds = _open_ds(q, "a")
            with pytest.raises(ValueError, match="no incomplete stream"):
                ds.stream("s", resume=True)
            ds.close()
            continue
        ds = _open_ds(q, "a")
        w = ds.stream("s", resume=True)
        assert w.resume_from == want_pts, f"cut={cut}"
        if k % 7 == 0:
            # torn-away pushes were never acked as journaled: re-feeding
            # from the resume point must converge to the clean run
            _push_range(w, x, w.resume_from, N)
            w.close()
            ds.close()
            assert open(q, "rb").read() == ref, f"cut={cut}"
        else:
            ds.close()       # stash the resumed stream and move on


def test_torn_checkpoint_is_refused(tmp_path):
    """A journal torn inside its checkpoint record cannot vouch for the
    store; with the store itself torn too the open must fail loudly (the
    checkpoint rewrite is atomic, so a real crash cannot produce this)."""
    x = _series()
    p = str(tmp_path / "c.cameo")
    _crash_writer(p, x, 500, flush_at=400)
    wal_blob = open(p + ".wal", "rb").read()
    with open(p + ".wal", "wb") as f:
        f.write(wal_blob[:len(walmod.MAGIC) + 5])
    with pytest.raises(IOError, match="missing footer|corrupt footer"):
        CameoStore.open(p, mode="a")


# ---------------------------------------------------------------------------
# journal mechanics
# ---------------------------------------------------------------------------

def test_group_commit_amortizes_fsync(tmp_path):
    """Group commit batches many appends behind one barrier: an unbounded
    window yields zero barriers until the checkpoint; a zero window
    degenerates to one barrier per push."""
    from repro import obs
    x = _series()
    was = obs.enabled()
    obs.enable()
    obs.reset()
    try:
        p = str(tmp_path / "g.cameo")
        ds = api.open(p, CFG, mode="w", block_len=BLK, stream_window=W,
                      wal_group_ms=60_000.0, wal_group_bytes=1 << 30)
        w = ds.stream("s")
        _push_range(w, x, 0, 500)
        snap = obs.snapshot()["counters"]
        assert snap.get("wal.records", 0) == len(range(0, 500, CHUNK))
        assert snap.get("wal.group_commits", 0) == 0
        w.close()
        ds.close()

        obs.reset()
        p2 = str(tmp_path / "g0.cameo")
        ds = api.open(p2, CFG, mode="w", block_len=BLK, stream_window=W,
                      wal_group_ms=0.0)
        w = ds.stream("s")
        _push_range(w, x, 0, 500)
        snap = obs.snapshot()["counters"]
        pushes = len(range(0, 500, CHUNK))
        assert snap.get("wal.group_commits", 0) == pushes
        w.close()
        ds.close()
    finally:
        obs.enable() if was else obs.disable()
        obs.reset()


def test_wal_bytes_do_not_change_store_bytes(tmp_path):
    """The journal is a sidecar: store bytes are identical with the
    journal on, off, and across group-commit policies."""
    x = _series()
    blobs = []
    for name, kw in (("on.cameo", dict()),
                     ("off.cameo", dict(wal=False)),
                     ("g0.cameo", dict(wal_group_ms=0.0))):
        p = str(tmp_path / name)
        ds = api.open(p, CFG, mode="w", block_len=BLK, stream_window=W,
                      **kw)
        w = ds.stream("s")
        _push_range(w, x, 0, N)
        w.close()
        ds.close()
        blobs.append(open(p, "rb").read())
    assert blobs[0] == blobs[1] == blobs[2]
    assert not os.path.exists(str(tmp_path / "off.cameo") + ".wal")


def test_wal_disabled_keeps_legacy_refusal(tmp_path, monkeypatch):
    """CAMEO_WAL=0 restores the old behavior exactly: no sidecar file and
    a torn store is refused loudly even in append mode."""
    monkeypatch.setenv("CAMEO_WAL", "0")
    x = _series()
    p = str(tmp_path / "c.cameo")
    ds = _open_ds(p + ".live", "w")
    w = ds.stream("s")
    _push_range(w, x, 0, 500)
    assert ds.store._wal is None
    assert not os.path.exists(p + ".live.wal")
    _snapshot_crash(ds.store, p)
    w.close()
    ds.close()
    with pytest.raises(IOError, match="missing footer"):
        CameoStore.open(p, mode="a")


def test_fresh_stream_supersedes_crashed_journal(tmp_path):
    """Opening the same sid *without* resume after a crash starts over:
    the journaled pushes are consumed (not replayed into the new feed)."""
    x = _series()
    p = str(tmp_path / "c.cameo")
    _crash_writer(p, x, 500, flush_at=None)
    ds = _open_ds(p, "a")
    w = ds.stream("s")                    # deliberate fresh start
    assert w.resume_from == 0
    _push_range(w, x, 0, N)
    w.close()
    ds.close()
    assert open(p, "rb").read() == _clean_run(
        str(tmp_path / "ref.cameo"), x)


def test_push_acks_only_valid_chunks(tmp_path):
    """A rejected chunk must never reach the journal (an ack would promise
    replay of data the compressor refused)."""
    x = _series()
    p = str(tmp_path / "c.cameo")
    ds = _open_ds(p, "w")
    w = ds.stream("s")
    w.push(x[:100])
    with pytest.raises(ValueError):
        w.push(np.stack([x[:10], x[:10]], axis=1))   # 2-D into 1-D stream
    scanres = walmod.scan(p + ".wal")
    assert sum(r.x.shape[0] for r in scanres.pushes) == 100
    w.close()
    ds.close()


def test_journal_roundtrip_units():
    """Record codecs: push and checkpoint payloads round-trip exactly."""
    rec = walmod.PushRecord("sensor/α", 12345678901234,
                            np.linspace(-1e300, 1e300, 37))
    out = walmod._decode_push(walmod._encode_push(rec))
    assert out.sid == rec.sid and out.start == rec.start
    assert np.array_equal(out.x.view(np.uint64), rec.x.view(np.uint64))
    mv = walmod.PushRecord("mv", 0, np.ones((5, 3)))
    out = walmod._decode_push(walmod._encode_push(mv))
    assert out.x.shape == (5, 3)
    ck = walmod.Checkpoint(4, 2**41, dict(block_len=64), b"zlib-bytes")
    out = walmod._decode_checkpoint(walmod._encode_checkpoint(ck))
    assert out == ck
