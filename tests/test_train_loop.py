"""End-to-end training loop: loss decreases; preemption/resume determinism."""
import pytest
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.data.pipeline import token_batch
from repro.models.model import model_defs
from repro.models.params import init_params
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainConfig


def _structured_batch_fn(cfg, batch, seq):
    """Learnable synthetic task: tokens follow a fixed cyclic pattern."""
    def fn(step):
        rng = np.random.default_rng(step % 7)
        base = (np.arange(seq) + rng.integers(0, 8)) % 32
        toks = np.tile(base, (batch, 1)).astype(np.int32)
        return {"tokens": jnp.asarray(toks)}
    return fn


def test_loss_decreases():
    cfg = get_reduced("smollm-135m")
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    tcfg = TrainConfig(peak_lr=3e-3, warmup=5, total_steps=60,
                       z_loss=0.0)
    lcfg = LoopConfig(steps=60, ckpt_dir=None, log_every=5)
    _, _, hist = train_loop(cfg, tcfg, lcfg, params,
                            _structured_batch_fn(cfg, 4, 32))
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


@pytest.mark.slow
def test_resume_is_bit_consistent(tmp_path):
    """Interrupted-then-resumed training produces the same parameters as an
    uninterrupted run (deterministic data + checkpointed opt state)."""
    cfg = get_reduced("qwen3-0.6b")
    params0 = init_params(model_defs(cfg), jax.random.PRNGKey(1))
    tcfg = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=20, z_loss=0.0)
    bfn = _structured_batch_fn(cfg, 2, 32)

    # uninterrupted 20 steps
    pA, _, _ = train_loop(cfg, tcfg, LoopConfig(steps=20, ckpt_dir=None),
                          jax.tree.map(jnp.copy, params0), bfn)
    # interrupted: 10 steps (checkpoint every 10), then resume to 20
    d = str(tmp_path / "ck")
    train_loop(cfg, tcfg, LoopConfig(steps=10, ckpt_dir=d, ckpt_every=10),
               jax.tree.map(jnp.copy, params0), bfn)
    pB, _, _ = train_loop(cfg, tcfg,
                          LoopConfig(steps=20, ckpt_dir=d, ckpt_every=10),
                          jax.tree.map(jnp.copy, params0), bfn)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), pA, pB)))
    assert diff < 1e-5, f"resume drifted by {diff}"
