import jax
import pytest

# CAMEO math is validated against float64 oracles; model code is
# dtype-explicit so this flag is behavior-neutral for the LM substrate.
jax.config.update("jax_enable_x64", True)


def hypothesis_or_stubs():
    """(given, settings, st) — real hypothesis when installed, otherwise
    stand-ins that let the module collect with property tests skipped."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        class _MissingStrategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            return lambda f: pytest.mark.skip(
                reason="hypothesis not installed")(f)

        return given, given, _MissingStrategies()
