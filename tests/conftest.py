import jax

# CAMEO math is validated against float64 oracles; model code is
# dtype-explicit so this flag is behavior-neutral for the LM substrate.
jax.config.update("jax_enable_x64", True)
