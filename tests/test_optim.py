"""Optimizers, schedules, and gradient compression (error feedback)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, global_norm)
from repro.optim.adafactor import (AdafactorConfig, adafactor_init,
                                   adafactor_update)
from repro.optim.compress import (CompressConfig, compress_with_feedback,
                                  init_residuals)
from repro.optim.schedule import warmup_cosine, warmup_linear


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      clip_norm=None)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = adamw_init(p, cfg)
    p2, state2, _ = adamw_update(g, state, p, 0.01, cfg)
    # hand-rolled reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    want = np.asarray(p["w"]) - 0.01 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-6)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(weight_decay=0.0)
    p = {"w": jnp.asarray(np.linspace(-2, 2, 8))}
    state = adamw_init(p, cfg)
    target = jnp.asarray(np.ones(8))
    for _ in range(300):
        g = {"w": 2 * (p["w"] - target)}
        p, state, _ = adamw_update(g, state, p, 0.05, cfg)
    assert float(jnp.max(jnp.abs(p["w"] - target))) < 0.05


def test_adafactor_converges_and_state_is_factored():
    cfg = AdafactorConfig(min_dim_factored=4)
    p = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)))}
    state = adafactor_init(p, cfg)
    assert state.vr["w"].shape == (8,)
    assert state.vc["w"].shape == (8,)
    target = jnp.ones((8, 8))
    loss0 = float(jnp.sum((p["w"] - target) ** 2))
    for _ in range(200):
        g = {"w": 2 * (p["w"] - target)}
        p, state = adafactor_update(g, state, p, 0.05, cfg)
    assert float(jnp.sum((p["w"] - target) ** 2)) < 0.2 * loss0


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-6


def test_schedules():
    assert abs(float(warmup_cosine(0, peak_lr=1.0, warmup=10, total=100))
               - 0.1) < 1e-6   # 1-indexed warmup: lr > 0 at step 0
    assert abs(float(warmup_cosine(10, peak_lr=1.0, warmup=10, total=100))
               - 1.0) < 1e-6
    end = float(warmup_cosine(100, peak_lr=1.0, warmup=10, total=100))
    assert end < 0.11
    assert float(warmup_linear(100, peak_lr=1.0, warmup=10, total=100)) < 1e-6


def test_gradient_compression_error_feedback_convergence():
    """SGD + top-k compression w/ error feedback still converges; without
    feedback it stalls (the residual is what makes CAMEO-style dropping
    safe on the gradient plane)."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(16, 16)))
    b = jnp.asarray(rng.normal(size=(16,)))

    def loss(w):
        return jnp.sum((A @ w - b) ** 2)

    gfn = jax.grad(loss)
    ccfg = CompressConfig(codec="topk", ratio=0.2)
    w = {"w": jnp.zeros(16)}
    res = init_residuals(w)
    step = jax.jit(lambda w, r: compress_with_feedback(
        {"w": gfn(w["w"])}, r, ccfg))
    for _ in range(2000):
        sent, res = step(w, res)
        w = {"w": w["w"] - 0.01 * sent["w"]}
    assert float(loss(w["w"])) < 0.15 * float(loss(jnp.zeros(16)))


def test_int8_compression_roundtrip_accuracy():
    ccfg = CompressConfig(codec="int8")
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(64,)))}
    sent, res = compress_with_feedback(g, init_residuals(g), ccfg)
    rel = float(jnp.linalg.norm(sent["w"] - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 0.02
