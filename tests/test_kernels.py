"""Per-kernel validation: shape/dtype sweeps + hypothesis, vs ref.py oracles
(interpret mode executes the kernel body, so this validates kernel logic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

# optional dep: property tests skip when hypothesis is missing, rest run
given, settings, st = hypothesis_or_stubs()

from repro.core.acf import acf_from_aggregates, extract_aggregates  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.ops import (acf_impact, agg_to_table, lag_dot,  # noqa: E402
                               window_impact)


def _setup(n, L, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = (np.sin(2 * np.pi * np.arange(n) / 24)
         + 0.2 * rng.standard_normal(n)).astype(dtype)
    y = jnp.asarray(x)
    agg = extract_aggregates(y, L)
    tab = agg_to_table(agg).astype(dtype)
    p0 = acf_from_aggregates(agg, n).astype(dtype)
    dval = jnp.asarray((0.1 * rng.standard_normal(n)).astype(dtype))
    return y, dval, tab, p0


@pytest.mark.parametrize("n,L,block", [
    (256, 4, 128), (1000, 24, 256), (4096, 48, 1024), (513, 7, 256),
    (2048, 1, 512),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("measure", ["mae", "rmse", "cheb"])
def test_acf_impact_kernel_sweep(n, L, block, dtype, measure):
    y, dval, tab, p0 = _setup(n, L, dtype)
    got = acf_impact(y, dval, tab, p0, measure=measure, block=block,
                     backend="pallas")
    want = ref.acf_impact_ref(y, dval, tab, p0, L=L, measure=measure)
    tol = 3e-5 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,L,block", [
    (256, 8, 128), (5000, 64, 512), (4096, 365, 2048), (777, 3, 256),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_lag_dot_kernel_sweep(n, L, block, dtype):
    y, *_ = _setup(n, L if L < n else n - 1, dtype, seed=1)
    got = lag_dot(y, L, block=block, backend="pallas")
    want = ref.lag_dot_ref(y, L=L)
    tol = 2e-4 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * float(jnp.max(jnp.abs(want))))


@pytest.mark.parametrize("n,L", [(512, 12), (1000, 24)])
def test_lag_dot_kernel_cross_and_halo(n, L):
    """The generalized kernel contract: cross products a·b_ext with an
    L-point halo continuation (the partitioned overlap terms)."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal(n))
    b = jnp.asarray(rng.standard_normal(n))
    halo = jnp.asarray(rng.standard_normal(L))
    got = lag_dot(a, L, b=b, halo=halo, block=256, backend="pallas")
    want = ref.lag_xdot_ref(a, jnp.concatenate([b, halo]), L=L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)
    # reference dispatch hits the same oracle
    got_r = lag_dot(a, L, b=b, halo=halo, backend="reference")
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n,L,W,block", [
    (512, 12, 16, 128), (1000, 24, 64, 256), (513, 7, 32, 128),
])
@pytest.mark.parametrize("measure", ["mae", "rmse", "cheb"])
def test_acf_window_impact_kernel_sweep(n, L, W, block, measure):
    """New Eq. 9 windowed-impact kernel vs its jnp oracle."""
    rng = np.random.default_rng(7)
    y, _, tab, p0 = _setup(n, L, np.float64, seed=7)
    P = 200
    starts = jnp.asarray(rng.integers(0, n - 1, P), jnp.int32)
    spans = rng.integers(1, W + 1, P)
    dwins = rng.standard_normal((P, W)) * 0.1
    dwins = jnp.asarray(dwins * (np.arange(W)[None, :] < spans[:, None]))
    got = window_impact(y, dwins, starts, tab, p0, measure=measure,
                        block=block, backend="pallas")
    want = window_impact(y, dwins, starts, tab, p0, measure=measure,
                        backend="reference")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)


def test_window_impact_matches_recompute():
    """Windowed impacts equal brute-force ACF-recompute deviations."""
    n, L, W = 256, 8, 16
    y, _, tab, p0 = _setup(n, L, np.float64, seed=5)
    starts = jnp.asarray([0, 100, 200, 250], jnp.int32)
    rng = np.random.default_rng(5)
    dwins_np = 0.3 * rng.standard_normal((4, W))
    for p, s in enumerate(np.asarray(starts)):
        dwins_np[p, max(0, n - s):] = 0.0        # stay inside the series
    dwins = jnp.asarray(dwins_np)
    got = window_impact(y, dwins, starts, tab, p0, measure="mae",
                        backend="pallas")
    from repro.core.acf import acf
    for p, s in enumerate(np.asarray(starts)):
        dense = np.zeros(n)
        dense[s:s + W] = dwins_np[p, : n - s]
        want = float(jnp.mean(jnp.abs(acf(y + jnp.asarray(dense), L) - p0)))
        assert abs(float(got[p]) - want) < 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(100, 700), st.integers(1, 20), st.integers(0, 100))
def test_acf_impact_kernel_hypothesis(n, L, seed):
    y, dval, tab, p0 = _setup(n, L, np.float64, seed=seed)
    got = acf_impact(y, dval, tab, p0, measure="mae", block=128,
                     backend="pallas")
    want = ref.acf_impact_ref(y, dval, tab, p0, L=L, measure="mae")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)


def test_kernel_matches_cameo_core_math():
    """The kernel's impact row equals the core acf_after_single_delta +
    measure composition used by the compressor."""
    from repro.core.aggregates import acf_after_single_delta
    from repro.core.acf import Aggregates
    n, L = 512, 12
    y, dval, tab, p0 = _setup(n, L, np.float64, seed=7)
    agg = Aggregates(*[tab[i] for i in range(5)])
    rows = acf_after_single_delta(agg, y, jnp.arange(n, dtype=jnp.int32), dval)
    want = jnp.mean(jnp.abs(rows - p0[None, :]), axis=1)
    got = acf_impact(y, dval, tab, p0, measure="mae", block=256,
                     backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)
