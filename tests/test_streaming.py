"""Streaming CAMEO ingest — the differential harness.

The contract under test (see ``core/streaming`` / ``store.open_stream``):
for *any* chunking of the input feed, the streaming path produces kept
masks, reconstructions, deviations and **store bytes** identical to the
one-shot windowed path (``compress_windowed`` + ``append_series``), across
window/block boundaries, eps/kappa settings, mid-stream flushes and
resume-after-close (``mode="a"``) sessions.  Satellites: pushdown answers
over a streamed store agree with the one-shot store across different
blockings, and the decoded-block LRU stays exact under interleaved
append/read soak.
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import hypothesis_or_stubs
from repro.core.acf import acf, aggregate_series
from repro.core import measures
from repro.core.cameo import CameoConfig, compress
# the warning-free internal oracle: the public compress_windowed is a
# deprecated shim over it (pinned separately in tests/test_api.py)
from repro.core.streaming import (RunningAggregates, StreamingCompressor,
                                  _compress_windowed as compress_windowed,
                                  min_window_len)
from repro.serving.ts_service import TimeSeriesService, TsServiceConfig
from repro.store import query as squery
from repro.store.store import CameoStore

given, settings, st = hypothesis_or_stubs()

CFG = CameoConfig(eps=2e-2, lags=8, mode="rounds", max_rounds=60,
                  dtype="float64")
W = 256     # stream window used throughout (keeps jit shapes few)


def _series(n, seed=0, offset=0.0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return (3 * np.sin(2 * np.pi * t / 24) + np.sin(2 * np.pi * t / 168)
            + 0.2 * rng.standard_normal(n) + offset)


def _stream(x, cfg, wlen, cuts, queue_depth=1):
    """Feed ``x`` split at ``cuts`` through a StreamingCompressor; returns
    (kept, xr, deviation, windows)."""
    sc = StreamingCompressor(cfg, wlen, queue_depth=queue_depth)
    wins = []
    for chunk in np.split(x, sorted(cuts)):
        wins += sc.push(chunk)
    wins += sc.finish()
    kept = np.concatenate([w.kept for w in wins]) if wins else np.empty(0)
    xr = np.concatenate([w.xr for w in wins]) if wins else np.empty(0)
    return kept, xr, sc.deviation(), wins


# ---------------------------------------------------------------------------
# tentpole: chunking invariance, bit-exact vs the one-shot windowed path
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**32 - 1),
       st.lists(st.integers(0, 640), max_size=6),
       st.sampled_from([512, 515, 640]),     # tails: none / verbatim / short
       st.sampled_from([2e-2, 1e-3]))
@settings(max_examples=12, deadline=None)
def test_chunking_invariance_bit_exact(seed, cuts, n, eps):
    """Any push() chunking — including empty and 1-point chunks — produces
    masks/reconstructions/deviation bit-identical to compress_windowed."""
    x = _series(n, seed=seed % 1000)
    cfg = CameoConfig(eps=float(eps), lags=8, mode="rounds", max_rounds=60,
                      dtype="float64")
    ref = compress_windowed(x, cfg, W)
    kept, xr, dev, _ = _stream(x, cfg, W, [min(c, n) for c in cuts])
    assert np.array_equal(kept, np.asarray(ref.kept))
    assert np.array_equal(xr.view(np.uint64),
                          np.asarray(ref.xr).view(np.uint64))
    assert dev == float(ref.deviation)


def test_windows_equal_oneshot_compress_per_slice():
    """Each full window's mask/xr is bit-identical to compress() on that
    slice — the tie back to the paper path."""
    x = _series(640, seed=3)
    _, _, _, wins = _stream(x, CFG, W, [100, 400])
    for w in wins[:2]:      # full windows (the tail is the short-path)
        ref = compress(jnp.asarray(x[w.start:w.start + W]), CFG)
        assert np.array_equal(w.kept, np.asarray(ref.kept))
        assert np.array_equal(w.xr.view(np.uint64),
                              np.asarray(ref.xr).view(np.uint64))
        assert w.n_kept == int(ref.n_kept)
    # the 128-point tail window compresses too (ny >= L+2)
    assert wins[2].x.shape[0] == 128 and wins[2].n_kept < 128


def test_single_window_equals_full_compress():
    """window_len >= n: streaming reproduces one-shot compress(x) exactly,
    for any chunking."""
    x = _series(512, seed=5)
    ref = compress(jnp.asarray(x), CFG)
    for cuts in ([], [7], [1, 2, 3, 500]):
        kept, xr, _, _ = _stream(x, CFG, 512, cuts)
        assert np.array_equal(kept, np.asarray(ref.kept))
        assert np.array_equal(xr.view(np.uint64),
                              np.asarray(ref.xr).view(np.uint64))


def test_sequential_mode_streams_too():
    cfg = CameoConfig(eps=2e-2, lags=8, mode="sequential", hops=8,
                      window=32, dtype="float64")
    x = _series(400, seed=7)
    ref = compress_windowed(x, cfg, 200)
    kept, xr, dev, wins = _stream(x, cfg, 200, [33, 340])
    assert np.array_equal(kept, np.asarray(ref.kept))
    assert np.array_equal(xr.view(np.uint64),
                          np.asarray(ref.xr).view(np.uint64))
    r0 = compress(jnp.asarray(x[:200]), cfg)
    assert np.array_equal(wins[0].kept, np.asarray(r0.kept))


def test_kappa_streaming_and_verbatim_tail():
    cfg = CameoConfig(eps=5e-2, lags=6, kappa=4, mode="rounds",
                      max_rounds=60, dtype="float64")
    n = 512 + 17        # tail 17: ndiv=16, ny=4 < L+2 -> verbatim
    x = _series(n, seed=11)
    assert min_window_len(cfg) <= 256
    ref = compress_windowed(x, cfg, 256)
    kept, xr, dev, wins = _stream(x, cfg, 256, [3, 259, 400])
    assert np.array_equal(kept, np.asarray(ref.kept))
    assert np.array_equal(xr.view(np.uint64),
                          np.asarray(ref.xr).view(np.uint64))
    assert dev == float(ref.deviation)
    # verbatim tail: every point kept, reconstruction == original
    assert wins[-1].x.shape[0] == 17 and wins[-1].kept.all()
    # per-window tie for kappa mode
    r0 = compress(jnp.asarray(x[:256]), cfg)
    assert np.array_equal(wins[0].kept, np.asarray(r0.kept))


def test_streaming_deviation_matches_direct_acf():
    """The incremental Eq. 7 global accounting equals a from-scratch ACF of
    the assembled reconstruction — finalized AND mid-stream (the pending
    window's lag pairs are folded on the fly)."""
    for kappa in (1, 4):
        cfg = CameoConfig(eps=2e-2, lags=8, kappa=kappa, mode="rounds",
                          max_rounds=60, dtype="float64")
        n = 1024
        x = _series(n, seed=13)
        sc = StreamingCompressor(cfg, 256)
        wins = sc.push(x[:768])
        # mid-stream: exact over the closed-window prefix
        xr_pre = np.concatenate([w.xr for w in wins])
        np_pre = (xr_pre.shape[0] // kappa) * kappa
        y0p = aggregate_series(jnp.asarray(x[:np_pre]), kappa)
        y1p = aggregate_series(jnp.asarray(xr_pre[:np_pre]), kappa)
        direct_pre = float(measures.mae(acf(y1p, cfg.lags),
                                        acf(y0p, cfg.lags)))
        assert abs(sc.deviation() - direct_pre) < 1e-9
        wins += sc.push(x[768:]) + sc.finish()
        dev = sc.deviation()
        xr = np.concatenate([w.xr for w in wins])
        y0 = aggregate_series(jnp.asarray(x), kappa)
        y1 = aggregate_series(jnp.asarray(xr), kappa)
        direct = float(measures.mae(acf(y1, cfg.lags), acf(y0, cfg.lags)))
        assert abs(dev - direct) < 1e-9
        # per-window eps guarantee held everywhere the window compressed
        assert dev < 10 * cfg.eps      # sanity: global stays near budget


def test_running_aggregates_match_batch_extraction():
    from repro.core.acf import extract_aggregates
    rng = np.random.default_rng(17)
    y = rng.standard_normal(500)
    ra = RunningAggregates(12)
    for c in np.split(y, [120, 260, 490]):
        ra.append(c)
    ra.finalize()
    got = ra.aggregates()
    want = extract_aggregates(jnp.asarray(y), 12)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-12, atol=1e-9)


def test_streaming_compressor_validation():
    with pytest.raises(ValueError, match="divisible"):
        StreamingCompressor(CameoConfig(kappa=3), 256)
    with pytest.raises(ValueError, match="shorter than the minimum"):
        StreamingCompressor(CameoConfig(lags=200), 128)
    sc = StreamingCompressor(CFG, 256)
    sc.push(_series(100))
    sc.finish()
    with pytest.raises(ValueError, match="finished"):
        sc.push(np.zeros(3))


# ---------------------------------------------------------------------------
# tentpole: streamed store bytes == one-shot store bytes
# ---------------------------------------------------------------------------

def _write_oneshot(path, x, cfg, wlen, block_len):
    ref = compress_windowed(x, cfg, wlen)
    with CameoStore.create(path, block_len=block_len) as s:
        s.append_series("s", ref, cfg, x=x)
    return ref


def _write_streamed(path, x, cfg, wlen, block_len, cuts, reopen_at=(),
                    queue_depth=1):
    """Stream ``x`` into ``path``; optionally close+reopen the store (with
    state stashed in the footer) after the chunks listed in ``reopen_at``."""
    sc = StreamingCompressor(cfg, wlen, queue_depth=queue_depth)
    store = CameoStore.create(path, block_len=block_len)
    sess = store.open_stream("s", cfg)
    sess.state_provider = sc.state_dict
    for ci, chunk in enumerate(np.split(x, sorted(cuts))):
        for w in sc.push(chunk):
            sess.append_window(w)
        if ci in reopen_at:
            store.close()
            store = CameoStore.open(path, "a")
            sess = store.open_stream("s", cfg, resume=True)
            sc = StreamingCompressor.from_state(
                cfg, sess.restored_client_state)
            sess.state_provider = sc.state_dict
    for w in sc.finish():
        sess.append_window(w)
    sess.close(deviation=sc.deviation())
    store.close()


@given(st.integers(0, 2**32 - 1),
       st.lists(st.integers(0, 1280), max_size=5),
       st.sampled_from([192, 256, 512]))
@settings(max_examples=8, deadline=None)
def test_streamed_store_bytes_equal_oneshot(seed, cuts, block_len):
    """The acceptance criterion at the physical layer: for any chunking,
    the streamed store file is byte-identical to the one-shot write."""
    import tempfile
    x = _series(1280, seed=seed % 1000, offset=5.0)
    with tempfile.TemporaryDirectory() as tmp:
        p1, p2 = os.path.join(tmp, "a.cameo"), os.path.join(tmp, "b.cameo")
        _write_oneshot(p1, x, CFG, W, block_len)
        _write_streamed(p2, x, CFG, W, block_len,
                        [min(c, len(x)) for c in cuts])
        with open(p1, "rb") as f1, open(p2, "rb") as f2:
            assert f1.read() == f2.read()


def test_resume_after_close_bit_exact(tmp_path):
    """mode="a" resume: close the store mid-stream (state stashed in the
    footer), reopen, continue — the final file is byte-identical to an
    uninterrupted run, wherever the interruption lands."""
    x = _series(1280, seed=23, offset=2.0)
    p_ref = str(tmp_path / "ref.cameo")
    _write_oneshot(p_ref, x, CFG, W, 200)
    with open(p_ref, "rb") as f:
        want = f.read()
    cuts = [150, 400, 700, 1000]
    for reopen_at in ([0], [2], [0, 1, 2, 3]):
        p = str(tmp_path / f"r{reopen_at[0]}_{len(reopen_at)}.cameo")
        _write_streamed(p, x, CFG, W, 200, cuts, reopen_at=reopen_at)
        with open(p, "rb") as f:
            assert f.read() == want, f"reopen_at={reopen_at}"


@given(st.integers(0, 2**32 - 1),
       st.lists(st.integers(0, 2048), max_size=5),
       st.sampled_from([1, 2, 8]))
@settings(max_examples=12, deadline=None)
def test_queue_depth_masks_bit_exact(seed, cuts, K):
    """The batched drain (``queue_depth=K`` windows per ``compress_batch``
    program) is bit-identical to the synchronous per-window path for any
    chunking — masks, reconstructions and the global deviation."""
    x = _series(2048, seed=seed % 1000)
    ref = compress_windowed(x, CFG, W)
    kept, xr, dev, _ = _stream(x, CFG, W, [min(c, len(x)) for c in cuts],
                               queue_depth=K)
    assert np.array_equal(kept, np.asarray(ref.kept))
    assert np.array_equal(xr.view(np.uint64),
                          np.asarray(ref.xr).view(np.uint64))
    assert dev == float(ref.deviation)


@given(st.integers(0, 2**32 - 1),
       st.lists(st.integers(0, 2048), max_size=4),
       st.sampled_from([2, 8]),
       st.sampled_from([(), (0,), (1, 2)]))
@settings(max_examples=8, deadline=None)
def test_queue_depth_store_bytes_and_resume(seed, cuts, K, reopen_at):
    """Store bytes under a batched queue — including stop/resume with
    up-to-K pending windows serialized in the stash — equal the one-shot
    write for any chunking and any interruption point."""
    import tempfile
    x = _series(2048, seed=seed % 1000, offset=1.0)
    cuts = [min(c, len(x)) for c in cuts]
    reopen_at = tuple(r for r in reopen_at if r <= len(cuts))
    with tempfile.TemporaryDirectory() as tmp:
        p1, p2 = os.path.join(tmp, "a.cameo"), os.path.join(tmp, "b.cameo")
        _write_oneshot(p1, x, CFG, W, 256)
        _write_streamed(p2, x, CFG, W, 256, cuts, reopen_at=reopen_at,
                        queue_depth=K)
        with open(p1, "rb") as f1, open(p2, "rb") as f2:
            assert f1.read() == f2.read()


def test_queue_depth_store_bytes_deterministic(tmp_path):
    """Non-hypothesis anchor for the batched-queue byte contract: K ∈
    {2, 8} × a fixed adversarial chunking × resume points, bytes equal to
    the one-shot write (runs even without hypothesis installed)."""
    x = _series(2048, seed=89, offset=1.0)
    p_ref = str(tmp_path / "ref.cameo")
    _write_oneshot(p_ref, x, CFG, W, 256)
    with open(p_ref, "rb") as f:
        want = f.read()
    cuts = [1, 97, 513, 1025, 2000]
    for K in (2, 8):
        for reopen_at in ((), (0,), (2, 3)):
            p = str(tmp_path / f"k{K}_{len(reopen_at)}.cameo")
            _write_streamed(p, x, CFG, W, 256, cuts, reopen_at=reopen_at,
                            queue_depth=K)
            with open(p, "rb") as f:
                assert f.read() == want, (K, reopen_at)


def test_queue_depth_state_roundtrip_preserves_queue():
    """state_dict/from_state with a part-filled queue: the restored
    compressor finishes the feed bit-identically to an uninterrupted one."""
    x = _series(2400, seed=97)
    ref = compress_windowed(x, CFG, W)
    for stop in (300, 700, 1100):       # queue holds 1..K-1 closed windows
        sc = StreamingCompressor(CFG, W, queue_depth=8)
        wins = sc.push(x[:stop])
        sc2 = StreamingCompressor.from_state(CFG, sc.state_dict())
        assert sc2.queue_depth == 8
        wins += sc2.push(x[stop:]) + sc2.finish()
        kept = np.concatenate([w.kept for w in wins])
        xr = np.concatenate([w.xr for w in wins])
        assert np.array_equal(kept, np.asarray(ref.kept))
        assert np.array_equal(xr.view(np.uint64),
                              np.asarray(ref.xr).view(np.uint64))
        assert sc2.deviation() == float(ref.deviation)


def test_service_queue_depth_bytes_equal(tmp_path):
    """ingest_stream(queue_depth=K) through the full service stack writes
    the same file as the synchronous service path."""
    x = _series(2048, seed=101)
    scfg = TsServiceConfig(block_len=256, stream_window=W)
    paths = []
    for K in (1, 4):
        p = str(tmp_path / f"k{K}.cameo")
        with TimeSeriesService(p, CFG, scfg) as svc:
            h = svc.ingest_stream("s", queue_depth=K)
            for lo in range(0, len(x), 333):
                h.push(x[lo:lo + 333])
            h.close()
        paths.append(p)
    with open(paths[0], "rb") as f1, open(paths[1], "rb") as f2:
        assert f1.read() == f2.read()


def test_midstream_flush_serves_readable_prefix(tmp_path):
    """flush() makes the written prefix durable and readable by a second
    (read-only) handle while the stream keeps appending."""
    x = _series(1024, seed=29)
    ref = compress_windowed(x, CFG, W)
    refxr = np.asarray(ref.xr)
    p = str(tmp_path / "mid.cameo")
    sc = StreamingCompressor(CFG, W)
    store = CameoStore.create(p, block_len=128)
    sess = store.open_stream("s", CFG)
    for w in sc.push(x[:700]):
        sess.append_window(w)
    sess.flush()
    reader = CameoStore.open(p)          # separate handle, footer present
    n_cov = reader.series_meta("s")["n"]
    assert 0 < n_cov <= 700
    got = reader.read_window("s", 0, n_cov)
    assert np.array_equal(got.view(np.uint64),
                          refxr[:n_cov].view(np.uint64))
    ki, kv = reader.read_kept("s")
    assert np.array_equal(ki, np.flatnonzero(np.asarray(ref.kept)[:n_cov]))
    v, b = squery.window_mean(reader, "s", 0, n_cov)
    assert abs(v - x[:n_cov].mean()) <= b
    # the writer keeps going after the flush (footer truncated first)
    for w in sc.push(x[700:]) + sc.finish():
        sess.append_window(w)
    sess.close(deviation=sc.deviation())
    store.close()
    final = CameoStore.open(p)
    assert np.array_equal(final.read_series("s").view(np.uint64),
                          refxr.view(np.uint64))
    assert np.array_equal(final.kept_mask("s"), np.asarray(ref.kept))


def test_stream_session_validation(tmp_path):
    x = _series(600, seed=31)
    p = str(tmp_path / "v.cameo")
    store = CameoStore.create(p, block_len=128)
    sess = store.open_stream("s", CFG)
    with pytest.raises(ValueError, match="already stored"):
        store.open_stream("s", CFG)
    sc = StreamingCompressor(CFG, W)
    for w in sc.push(x):
        sess.append_window(w)
    with pytest.raises(ValueError, match="non-contiguous"):
        sess.append(999, x[:10], np.ones(10, bool))
    with pytest.raises(ValueError, match="no incomplete stream"):
        store.open_stream("t", CFG, resume=True)
    for w in sc.finish():
        sess.append_window(w)
    sess.close()
    with pytest.raises(ValueError, match="closed"):
        sess.append(600, x[:10], np.ones(10, bool))
    store.close()
    with pytest.raises(ValueError, match="no incomplete stream"):
        CameoStore.open(p, "a").open_stream("s", CFG, resume=True)


def test_exception_mid_feed_leaves_stream_resumable(tmp_path):
    """An exception inside the session context must NOT finalize the
    series — the feed is incomplete and has to stay resumable."""
    x = _series(1280, seed=53)
    p = str(tmp_path / "exc.cameo")
    p_ref = str(tmp_path / "ref.cameo")
    _write_oneshot(p_ref, x, CFG, W, 200)
    sc = StreamingCompressor(CFG, W)
    store = CameoStore.create(p, block_len=200)
    with pytest.raises(RuntimeError, match="feed died"):
        with store.open_stream("s", CFG) as sess:
            for w in sc.push(x[:900]):
                sess.append_window(w)
            raise RuntimeError("feed died")
    assert store.series_meta("s").get("streaming"), \
        "exception must not finalize the stream"
    store.close()                    # stashes the incomplete session
    store = CameoStore.open(p, "a")
    sess = store.open_stream("s", CFG, resume=True)
    for w in sc.push(x[900:]) + sc.finish():
        sess.append_window(w)
    sess.close(deviation=sc.deviation())
    store.close()
    with open(p, "rb") as f1, open(p_ref, "rb") as f2:
        assert f1.read() == f2.read()


def test_reopen_append_keeps_footer_until_first_write(tmp_path):
    """mode="a" must not truncate the footer eagerly: a crash between the
    reopen and the first new write loses nothing."""
    x = _series(640, seed=59)
    p = str(tmp_path / "keep.cameo")
    _write_oneshot(p, x, CFG, W, 256)
    with open(p, "rb") as f:
        before = f.read()
    w = CameoStore.open(p, "a")      # reopen for append...
    # ...and "crash" (no writes, no close): the on-disk file is untouched
    with open(p, "rb") as f:
        assert f.read() == before
    r = CameoStore.open(p)           # still a fully valid store
    assert np.array_equal(r.kept_mask("s"),
                          np.asarray(compress_windowed(x, CFG, W).kept))
    w.close()                        # clean close rewrites the same footer
    with open(p, "rb") as f:
        assert f.read() == before


def test_service_resume_unwinds_on_missing_client_state(tmp_path):
    """ingest_stream(resume=True) on a stream written through the raw store
    API must fail cleanly: the stash is restored and the session slot is
    freed, so a raw-store resume still works afterwards."""
    x = _series(900, seed=61)
    cfg = CFG
    p = str(tmp_path / "raw.cameo")
    sc = StreamingCompressor(cfg, W)
    store = CameoStore.create(p, block_len=128)
    sess = store.open_stream("s", cfg)   # no state_provider attached
    for w in sc.push(x[:600]):
        sess.append_window(w)
    store.close()
    svc = TimeSeriesService(p, cfg, TsServiceConfig(block_len=128),
                            resume=True)
    with pytest.raises(ValueError, match="no compressor state"):
        svc.ingest_stream("s", resume=True)
    sess = svc.store.open_stream("s", cfg, resume=True)   # slot is free
    for w in sc.push(x[600:]) + sc.finish():
        sess.append_window(w)
    sess.close(deviation=sc.deviation())
    svc.close()
    r = CameoStore.open(p)
    ref = compress_windowed(x, cfg, W)
    assert np.array_equal(r.read_series("s").view(np.uint64),
                          np.asarray(ref.xr).view(np.uint64))


def test_resume_cfg_mismatch_preserves_stash(tmp_path):
    """A resume attempt with the wrong cfg must fail without consuming the
    stashed state — retrying with the right cfg succeeds."""
    import dataclasses
    x = _series(900, seed=73)
    p = str(tmp_path / "cfg.cameo")
    sc = StreamingCompressor(CFG, W)
    store = CameoStore.create(p, block_len=128)
    sess = store.open_stream("s", CFG)
    for w in sc.push(x[:600]):
        sess.append_window(w)
    store.close()
    store = CameoStore.open(p, "a")
    wrong = dataclasses.replace(CFG, eps=0.5)
    with pytest.raises(ValueError, match="cfg mismatch"):
        store.open_stream("s", wrong, resume=True)
    sess = store.open_stream("s", CFG, resume=True)   # stash intact
    for w in sc.push(x[600:]) + sc.finish():
        sess.append_window(w)
    sess.close(deviation=sc.deviation())
    store.close()
    ref = compress_windowed(x, CFG, W)
    r = CameoStore.open(p)
    assert np.array_equal(r.read_series("s").view(np.uint64),
                          np.asarray(ref.xr).view(np.uint64))


def test_read_kept_empty_before_first_block(tmp_path):
    p = str(tmp_path / "empty.cameo")
    store = CameoStore.create(p, block_len=4096)
    sess = store.open_stream("s", CFG)
    x = _series(64, seed=67)
    sess.append(0, x, np.ones(64, bool))     # far short of a block border
    ki, kv = store.read_kept("s")
    assert ki.shape == (0,) and kv.shape == (0,)
    assert store.kept_mask("s").shape == (0,)
    assert store.read_window("s", 0, 10).shape == (0,)


def test_arbitrary_mask_point_by_point_equals_oneshot(tmp_path):
    """Mask-level differential, independent of the compressor: any kept
    mask fed through the session one point at a time produces the same
    store bytes as a one-shot append_series of that mask."""
    rng = np.random.default_rng(71)
    n = 1500
    x = _series(n, seed=71)
    kept = rng.random(n) < 0.3
    kept[0] = kept[-1] = True
    p1 = str(tmp_path / "one.cameo")
    p2 = str(tmp_path / "pp.cameo")

    class _R:
        def __init__(self):
            self.kept = jnp.asarray(kept)
            self.xr = jnp.asarray(x)
            self.deviation = 0.0

    with CameoStore.create(p1, block_len=256) as s:
        s.append_series("s", _R(), CFG, x=x)
    with CameoStore.create(p2, block_len=256) as s:
        sess = s.open_stream("s", CFG)
        for i in range(n):
            sess.append(i, x[i:i + 1], kept[i:i + 1])
        sess.close()
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()


# ---------------------------------------------------------------------------
# satellite: pushdown answers agree across blockings (streamed vs one-shot)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pushdown_pair(tmp_path_factory):
    """The same stream written twice with *different* block lengths: the
    streamed store's block borders land elsewhere, so windows straddle
    different boundaries — answers must still agree within stated bounds."""
    x = _series(2048, seed=37, offset=5.0)
    d = tmp_path_factory.mktemp("pushdown")
    p1, p2 = str(d / "one.cameo"), str(d / "str.cameo")
    _write_oneshot(p1, x, CFG, W, 320)
    _write_streamed(p2, x, CFG, W, 192, [97, 513, 1025, 1700])
    return CameoStore.open(p1), CameoStore.open(p2), x


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_pushdown_differential_streamed_vs_oneshot(pushdown_pair, seed):
    one, strm, x = pushdown_pair
    rng = np.random.default_rng(seed)
    n = len(x)
    a = int(rng.integers(0, n - 64))
    b = int(rng.integers(a + 40, n + 1))
    for kind in ("sum", "mean", "var"):
        v1, b1 = squery.query(one, "s", kind, a, b)
        v2, b2 = squery.query(strm, "s", kind, a, b)
        assert abs(v1 - v2) <= b1 + b2, (kind, a, b)
        truth = dict(sum=x[a:b].sum(), mean=x[a:b].mean(),
                     var=x[a:b].var())[kind]
        assert abs(v2 - truth) <= b2, (kind, a, b)
    if b - a > CFG.lags + 1:
        v1, b1 = squery.window_acf(one, "s", a, b)
        v2, b2 = squery.window_acf(strm, "s", a, b)
        assert np.all(np.abs(v1 - v2) <= b1 + b2), (a, b)


def test_pushdown_streamed_blocks_straddled(pushdown_pair):
    """Deterministic boundary cases: windows that start/end exactly on and
    one point around every streamed block border."""
    one, strm, x = pushdown_pair
    metas = strm.block_metas("s")
    assert len(metas) > 3, "fixture must have several streamed blocks"
    for m in metas[1:-1]:
        for a, b in ((m.t0 - 1, m.t1 + 2), (m.t0, m.t1 + 1),
                     (m.t0 + 1, m.t1)):
            v, bound = squery.window_sum(strm, "s", a, b)
            assert abs(v - x[a:b].sum()) <= bound
            v1, b1 = squery.window_sum(one, "s", a, b)
            assert abs(v - v1) <= bound + b1


# ---------------------------------------------------------------------------
# satellite: decoded-block LRU soak under interleaved append/read
# ---------------------------------------------------------------------------

def _cache_exact(store):
    c = store._cache
    assert c.nbytes == sum(e[4] for e in c._d.values()), \
        "LRU byte accounting drifted"
    assert c.nbytes <= max(c.budget, 0) or not c._d


def test_cache_soak_interleaved_append_read(tmp_path):
    """Interleave streamed appends with window reads + pushdown queries:
    the LRU must never serve a stale reconstruction and its byte accounting
    stays exact after every operation (per-append invalidation regression
    guard)."""
    x = _series(2048, seed=41, offset=3.0)
    ref = compress_windowed(x, CFG, W)
    refxr = np.asarray(ref.xr)
    p = str(tmp_path / "soak.cameo")
    rng = np.random.default_rng(5)
    sc = StreamingCompressor(CFG, W)
    store = CameoStore.create(p, block_len=160, cache_bytes=1 << 20)
    sess = store.open_stream("s", CFG)
    reads = 0
    for chunk in np.split(x, [97, 300, 515, 700, 1025, 1400, 1800]):
        for w in sc.push(chunk):
            sess.append_window(w)
        _cache_exact(store)
        n_cov = store.series_meta("s")["n"]
        for _ in range(3):
            if n_cov < 4:
                break
            a = int(rng.integers(0, n_cov - 2))
            b = int(rng.integers(a + 1, n_cov + 1))
            got = store.read_window("s", a, b)
            assert np.array_equal(got.view(np.uint64),
                                  refxr[a:b].view(np.uint64)), (a, b)
            _cache_exact(store)
            reads += 1
        if n_cov > 64:
            v, bnd = squery.window_mean(store, "s", 0, n_cov)
            assert abs(v - x[:n_cov].mean()) <= bnd
            _cache_exact(store)
    for w in sc.finish():
        sess.append_window(w)
    sess.close(deviation=sc.deviation())
    _cache_exact(store)
    assert reads > 10
    stats = store.cache_stats()
    assert stats["hits"] > 0, "soak must exercise cache hits"
    got = store.read_series("s")
    assert np.array_equal(got.view(np.uint64), refxr.view(np.uint64))
    assert np.array_equal(store.kept_mask("s"), np.asarray(ref.kept))
    store.close()


# ---------------------------------------------------------------------------
# O(window) state + the service-level ingest_stream API
# ---------------------------------------------------------------------------

def test_stream_state_stays_bounded(tmp_path):
    """The compressor buffer and the session's pending buffers stay
    O(window + block) no matter how long the feed runs."""
    cfg = CameoConfig(eps=2e-2, lags=8, mode="rounds", max_rounds=40,
                      dtype="float64")
    p = str(tmp_path / "b.cameo")
    store = CameoStore.create(p, block_len=128)
    sess = store.open_stream("s", cfg)
    sc = StreamingCompressor(cfg, 256)
    cap = 128 + 2 * 256 + 64        # block + 2 windows + slack
    rng = np.random.default_rng(43)
    fed = 0
    while fed < 6000:
        chunk = rng.standard_normal(int(rng.integers(1, 300)))
        fed += len(chunk)
        for w in sc.push(chunk):
            sess.append_window(w)
        assert sc._buf.shape[0] < 256 + 300
        pending_x = sess._x.shape[0] + sum(p.shape[0]
                                           for p in sess._x_parts)
        pending_kept = sess._kept_idx.shape[0] + sum(
            p.shape[0] for p in sess._idx_parts)
        assert pending_x <= cap
        assert pending_kept <= cap
    assert len(store.series_meta("s")["blocks"]) > 10
    for w in sc.finish():
        sess.append_window(w)
    sess.close()
    store.close()


def test_service_ingest_stream_end_to_end(tmp_path):
    """ingest_stream == one-shot windowed path, queries mid-stream work,
    and service close/reopen resumes bit-exactly."""
    x = _series(2000, seed=47)
    cfg = CameoConfig(eps=2e-2, lags=8, mode="rounds", max_rounds=60,
                      dtype="float64")
    scfg = TsServiceConfig(block_len=256, stream_window=512)
    p = str(tmp_path / "svc.cameo")
    svc = TimeSeriesService(p, cfg, scfg)
    h = svc.ingest_stream("feed")
    h.push(x[:700])
    h.push(x[700:1100])
    assert svc.stats()["streams"] == 1
    n_cov = svc.store.series_meta("feed")["n"]
    mid = svc.query_window("feed", 0, n_cov)
    v, b = svc.query_aggregate("feed", "mean", 0, n_cov)
    assert abs(v - x[:n_cov].mean()) <= b
    with pytest.raises(ValueError, match="already"):
        svc.ingest_stream("feed")
    svc.close()                      # stashes the open stream

    svc2 = TimeSeriesService(p, cfg, scfg, resume=True)
    h2 = svc2.ingest_stream("feed", resume=True)
    assert h2.resume_from == 1100
    h2.push(x[1100:])
    entry = h2.close()
    dev = h2.deviation()
    svc2.close()

    ref = compress_windowed(x, cfg, 512)
    p_ref = str(tmp_path / "ref.cameo")
    with CameoStore.create(p_ref, block_len=256) as s:
        s.append_series("feed", ref, cfg, x=x)
    with open(p, "rb") as f1, open(p_ref, "rb") as f2:
        assert f1.read() == f2.read()
    assert dev == float(ref.deviation)
    assert entry["n"] == 2000 and entry["n_kept"] == int(ref.n_kept)
    r = CameoStore.open(p)
    assert np.array_equal(mid, np.asarray(ref.xr)[:len(mid)])
    assert np.array_equal(r.kept_mask("feed"), np.asarray(ref.kept))
