"""Checkpoint manager: atomicity, checksums, keep-k, resume, reshard."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 8))),
            "b": {"c": jnp.asarray(rng.normal(size=(3,))),
                  "d": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, manifest = ckpt.restore(str(tmp_path), 5, template=t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 5


def test_keep_k(tmp_path):
    t = _tree()
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, t, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_corruption_detected(tmp_path):
    t = _tree()
    path = ckpt.save(str(tmp_path), 1, t)
    with open(os.path.join(path, "manifest.json")) as f:
        codec = json.load(f)["codec"]
    arr = os.path.join(path, ckpt._array_file(codec))
    raw = ckpt._decompress_bytes(open(arr, "rb").read(), codec)
    bad = bytearray(raw)
    bad[100] ^= 0xFF
    open(arr, "wb").write(ckpt._compress_bytes(bytes(bad), codec))
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), 1, template=t)


def test_partial_save_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_save(tmp_path):
    t = _tree(3)
    th = ckpt.save_async(str(tmp_path), 9, t)
    th.join()
    restored, _ = ckpt.restore(str(tmp_path), 9, template=t)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_restore_casts_dtype_template(tmp_path):
    t = {"w": jnp.asarray(np.ones((4,)), jnp.float32)}
    ckpt.save(str(tmp_path), 1, t)
    tpl = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    restored, _ = ckpt.restore(str(tmp_path), 1, template=tpl)
    assert restored["w"].dtype == jnp.bfloat16
