"""Impact-engine backend layer: pallas ≡ reference parity (ranking, whole
compressions) and the batched multi-series front-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.acf import acf_from_aggregates, aggregate_series, \
    extract_aggregates
from repro.core.cameo import (CameoConfig, compress_batch, compress_rounds,
                              compress_sequential)
from repro.kernels import ops


def _series(n, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return jnp.asarray(np.sin(2 * np.pi * t / 24)
                       + 0.5 * np.sin(2 * np.pi * t / 168)
                       + 0.15 * rng.standard_normal(n))


def _ranking_setup(cfg, n, seed=0):
    x = _series(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    alive = jnp.asarray(rng.random(n) > 0.3)
    alive = alive.at[0].set(True).at[-1].set(True)
    from repro.core.cameo import _reconstruct, _stat_transform
    xr = _reconstruct(x, alive)
    y = aggregate_series(xr, cfg.kappa)
    agg = extract_aggregates(y, cfg.lags)
    p0 = _stat_transform(cfg)(acf_from_aggregates(agg, y.shape[0]))
    return x, xr, alive, y, agg, p0


@pytest.mark.parametrize("rank", ["single", "window"])
@pytest.mark.parametrize("kappa", [1, 4])
@pytest.mark.parametrize("measure", ["mae", "rmse", "cheb"])
def test_ranking_impact_backend_parity(rank, kappa, measure):
    """pallas (interpret) ≡ reference GetAllImpact, all kernel measures."""
    n = 512
    cfg = CameoConfig(lags=12, rank=rank, kappa=kappa, measure=measure,
                      backend="reference", impact_chunk=256)
    x, xr, alive, y, agg, p0 = _ranking_setup(cfg, n)
    ref_imp = ops.ranking_impact(cfg, agg, y, xr, alive, p0, n)
    pal_imp = ops.ranking_impact(
        dataclasses.replace(cfg, backend="pallas"), agg, y, xr, alive, p0, n)
    np.testing.assert_allclose(np.asarray(ref_imp), np.asarray(pal_imp),
                               rtol=1e-9, atol=1e-9)


def test_ranking_impact_pacf_falls_back():
    """Configs the kernels can't serve (pacf / non-kernel measures) produce
    identical results under both backend names (reference fallback)."""
    n = 256
    for kw in [dict(stat="pacf"), dict(measure="mape")]:
        cfg = CameoConfig(lags=8, backend="reference", **kw)
        x, xr, alive, y, agg, p0 = _ranking_setup(cfg, n, seed=3)
        a = ops.ranking_impact(cfg, agg, y, xr, alive, p0, n)
        b = ops.ranking_impact(
            dataclasses.replace(cfg, backend="pallas"),
            agg, y, xr, alive, p0, n)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("rank", ["single", "window"])
@pytest.mark.parametrize("kappa", [1, 4])
def test_compress_rounds_backend_identical_kept(rank, kappa):
    """Acceptance: backend="pallas" (interpret on CPU) produces identical
    kept masks to backend="reference" end to end."""
    x = _series(768, seed=4)
    cfg = CameoConfig(eps=0.02, lags=12, mode="rounds", rank=rank,
                      kappa=kappa, backend="reference")
    a = compress_rounds(x, cfg)
    b = compress_rounds(x, dataclasses.replace(cfg, backend="pallas"))
    np.testing.assert_array_equal(np.asarray(a.kept), np.asarray(b.kept))
    assert abs(float(a.deviation) - float(b.deviation)) < 1e-9


def test_compress_backend_identical_kept_quickstart_series():
    """Acceptance criterion on the quickstart dataset (uk_elec)."""
    from repro.data.synthetic import make_dataset
    x = jnp.asarray(make_dataset("uk_elec", seed=0, length=1024))
    cfg = CameoConfig(eps=1e-2, lags=24, mode="rounds",
                      backend="reference")
    a = compress_rounds(x, cfg)
    b = compress_rounds(x, dataclasses.replace(cfg, backend="pallas"))
    np.testing.assert_array_equal(np.asarray(a.kept), np.asarray(b.kept))
    assert float(a.deviation) <= cfg.eps + 1e-12


def test_compress_sequential_backend_runs():
    """Sequential mode threads the backend through ReHeap + init impacts."""
    x = _series(384, seed=5)
    cfg = CameoConfig(eps=0.05, lags=8, mode="sequential", backend="pallas")
    res = compress_sequential(x, cfg)
    assert float(res.deviation) <= cfg.eps + 1e-12
    ref = compress_sequential(
        x, dataclasses.replace(cfg, backend="reference"))
    np.testing.assert_array_equal(np.asarray(res.kept), np.asarray(ref.kept))


def test_extract_aggregates_backend_parity():
    y = _series(1000, seed=6)
    a = extract_aggregates(y, 24, backend="reference")
    b = extract_aggregates(y, 24, backend="pallas")
    for f in a._fields:
        np.testing.assert_allclose(np.asarray(getattr(a, f)),
                                   np.asarray(getattr(b, f)),
                                   rtol=1e-10, atol=1e-10)


def test_resolve_backend():
    assert ops.resolve_backend("pallas") == "pallas"
    assert ops.resolve_backend("reference") == "reference"
    assert ops.resolve_backend("auto") in ("pallas", "reference")
    with pytest.raises(ValueError):
        ops.resolve_backend("nope")


def _prefix_setup(seed=9, nyb=160, ny=150, K=12, Wy=16, L=8):
    """Synthetic fused-round inputs honouring the kernel contract: y is
    zero-padded beyond ny, candidate delta windows stay inside [0, ny)."""
    from repro.core.cameo import _stat_transform
    rng = np.random.default_rng(seed)
    y = np.zeros(nyb)
    y[:ny] = np.asarray(_series(ny, seed=seed))
    starts = rng.integers(0, ny - Wy, size=K).astype(np.int32)
    dyws = 0.1 * rng.standard_normal((K, Wy))
    ok = rng.random(K) > 0.25
    agg = extract_aggregates(jnp.asarray(y[:ny]), L)
    p0 = acf_from_aggregates(agg, ny)
    table = ops.agg_to_table(agg)
    return (jnp.asarray(y), jnp.asarray(dyws), jnp.asarray(starts),
            jnp.asarray(ok), table, p0)


@pytest.mark.parametrize("measure", ["mae", "rmse", "cheb"])
def test_prefix_devs_pallas_interpret_parity(measure):
    """Fused-round parity: the Pallas prefix-deviation kernel (interpret
    mode on CPU) matches the reference prefix rows to fp tolerance — the
    accumulation orders differ, so this is allclose, not bit-equality."""
    from repro.kernels import fused_round as fr
    from repro.kernels import ref as kref
    y, dyws, starts, ok, table, p0 = _prefix_setup()
    L = int(table.shape[-1])
    ny = 150
    rows = fr.prefix_acf_rows_ref(y, dyws, starts, ok, table, ny, L=L)
    want = kref.measure_rows(rows, p0, measure)
    got = fr.prefix_devs_pallas(y, dyws, starts, ok, table, p0, ny,
                                L=L, measure=measure, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)


def test_greedy_feasible_pallas_interpret_matches_oracle():
    """The greedy fused pass (conditional commit in VMEM) against a pure
    numpy oracle that rebuilds the reconstruction and recomputes the ACF
    from scratch at every trial."""
    from repro.core import measures
    from repro.core.acf import acf
    from repro.kernels import fused_round as fr
    y, dyws, starts, ok, table, p0 = _prefix_setup(seed=11)
    L = int(table.shape[-1])
    ny, Wy, K = 150, dyws.shape[1], dyws.shape[0]
    eps = 0.02
    devs = fr.prefix_devs_pallas(y, dyws, starts, ok, table, p0, ny, eps,
                                 L=L, measure="mae", greedy=True,
                                 interpret=True)
    take = np.asarray(ok) & (np.asarray(devs) <= eps)

    z = np.asarray(y).copy()
    oracle_devs, oracle_take = [], []
    for k in range(K):
        s = int(starts[k])
        trial = z.copy()
        trial[s:s + Wy] += np.asarray(dyws[k]) * float(ok[k])
        dev = float(measures.mae(acf(jnp.asarray(trial[:ny]), L), p0))
        commit = bool(ok[k]) and dev <= eps
        if commit:
            z = trial
        oracle_devs.append(dev)
        oracle_take.append(commit)
    np.testing.assert_allclose(np.asarray(devs), oracle_devs,
                               rtol=1e-8, atol=1e-9)
    # decisions are tolerance-robust here: no trial lands within 1e-6 of eps
    assert min(abs(d - eps) for d in oracle_devs) > 1e-6
    np.testing.assert_array_equal(take, np.asarray(oracle_take))


def test_compress_batch_matches_per_series():
    """The batched front-end is bit-identical to per-series rounds runs."""
    n, B = 512, 3
    xs = jnp.stack([_series(n, seed=s) for s in range(B)])
    cfg = CameoConfig(eps=0.02, lags=12, mode="rounds")
    batch = compress_batch(xs, cfg)
    assert batch.kept.shape == (B, n)
    for i in range(B):
        one = compress_rounds(xs[i], cfg)
        np.testing.assert_array_equal(np.asarray(batch.kept[i]),
                                      np.asarray(one.kept))
        assert abs(float(batch.deviation[i]) - float(one.deviation)) < 1e-12
        assert int(batch.iters[i]) == int(one.iters)


def test_compress_batch_validates_inputs():
    cfg = CameoConfig(mode="sequential")
    with pytest.raises(ValueError):
        compress_batch(jnp.zeros((2, 64)), cfg)
    with pytest.raises(ValueError):
        compress_batch(jnp.zeros(64), CameoConfig())
