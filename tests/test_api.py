"""The repro.api façade: univariate operations are byte/answer-identical
to the legacy call paths they replace, streams resume, and the deprecated
entry points warn but keep working."""
import os
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

import repro.api as cameo
from repro.core.acf import acf, pacf_from_acf
from repro.core.cameo import CameoConfig, compress
from repro.core.streaming import _compress_windowed, min_window_len
from repro.serving.ts_service import TimeSeriesService, TsServiceConfig
from repro.store import query as squery
from repro.store.store import CameoStore

CFG = CameoConfig(eps=2e-2, lags=12, mode="rounds", max_rounds=60,
                  dtype="float64")


def _series(n=2048, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return (np.sin(2 * np.pi * t / 24) + np.sin(2 * np.pi * t / 168)
            + 0.1 * rng.standard_normal(n))


# ---------------------------------------------------------------------------
# differential façade contract (univariate)
# ---------------------------------------------------------------------------

def test_write_bytes_identical_to_legacy_submit(tmp_path):
    x = _series(2048, seed=1)
    p_old = str(tmp_path / "old.cameo")
    p_new = str(tmp_path / "new.cameo")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with TimeSeriesService(p_old, CFG,
                               TsServiceConfig(block_len=512)) as svc:
            svc.submit("s", x)
    with cameo.open(p_new, CFG, mode="w", block_len=512) as ds:
        ds.write("s", x)
    assert open(p_old, "rb").read() == open(p_new, "rb").read()
    # and the file stays v3: no multivariate block was ever written
    assert open(p_new, "rb").read(8) == b"CAMEOST\x03"


def test_series_answers_identical_to_legacy_query(tmp_path):
    x = _series(2048, seed=2)
    p = str(tmp_path / "q.cameo")
    with cameo.open(p, CFG, mode="w", block_len=512) as ds:
        ds.write("s", x)
    ds = cameo.open(p)             # read-only handle, no cfg needed
    s = ds.series("s")
    store = CameoStore.open(p)
    n = len(x)
    assert np.array_equal(s.window(100, 1800), store.read_window("s", 100,
                                                                 1800))
    assert np.array_equal(s.window(), store.read_series("s"))
    for name, legacy in (("sum", squery.window_sum),
                         ("mean", squery.window_mean),
                         ("var", squery.window_var),
                         ("acf", squery.window_acf)):
        got = getattr(s, name)(64, n - 64)
        ref = legacy(store, "s", 64, n - 64)
        assert np.array_equal(np.asarray(got[0]), np.asarray(ref[0])), name
        assert np.array_equal(np.asarray(got[1]), np.asarray(ref[1])), name
    ki, kv = s.kept()
    ki2, kv2 = store.read_kept("s")
    assert np.array_equal(ki, ki2) and np.array_equal(kv, kv2)
    assert s.n == n and s.channels == 1
    assert s.stats()["bytes_cr"] > 1.0
    assert s.deviations.shape == (1,)
    ds.close()
    store.close()


def test_stream_bytes_identical_to_legacy_and_oneshot(tmp_path):
    x = _series(3000, seed=3)
    wlen = max(1024, min_window_len(CFG))
    p_old = str(tmp_path / "old.cameo")
    p_new = str(tmp_path / "new.cameo")
    p_ref = str(tmp_path / "ref.cameo")
    scfg = TsServiceConfig(block_len=512, stream_window=wlen)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with TimeSeriesService(p_old, CFG, scfg) as svc:
            h = svc.ingest_stream("s")
            for lo in range(0, 3000, 271):
                h.push(x[lo:lo + 271])
            h.close()
    with cameo.open(p_new, CFG, mode="w", block_len=512,
                    stream_window=wlen) as ds:
        with ds.stream("s") as w:
            for lo in range(0, 3000, 271):
                w.push(x[lo:lo + 271])
    # one-shot windowed reference through the internal oracle
    ref = _compress_windowed(x, CFG, wlen)
    with CameoStore.create(p_ref, block_len=512) as st:
        st.append_series("s", ref, CFG, x=x)
    old_b, new_b, ref_b = (open(p, "rb").read()
                           for p in (p_old, p_new, p_ref))
    assert new_b == old_b
    assert new_b == ref_b


def test_stream_resume_roundtrip(tmp_path):
    x = _series(3000, seed=4)
    wlen = max(1024, min_window_len(CFG))
    p1 = str(tmp_path / "full.cameo")
    p2 = str(tmp_path / "res.cameo")
    with cameo.open(p1, CFG, mode="w", block_len=512,
                    stream_window=wlen) as ds:
        with ds.stream("s") as w:
            for lo in range(0, 3000, 333):
                w.push(x[lo:lo + 333])
    ds = cameo.open(p2, CFG, mode="w", block_len=512, stream_window=wlen)
    w = ds.stream("s")
    for lo in range(0, 1332, 333):
        w.push(x[lo:lo + 333])
    ds.close()                      # stop mid-feed
    ds = cameo.open(p2, CFG, mode="a", block_len=512, stream_window=wlen)
    w = ds.stream("s", resume=True)
    for lo in range(w.resume_from, 3000, 333):
        w.push(x[lo:lo + 333])
    entry = w.close()
    ds.close()
    assert open(p1, "rb").read() == open(p2, "rb").read()
    assert entry["n"] == 3000


def test_write_batch_equals_per_series(tmp_path):
    xs = {f"s{i}": _series(512, seed=10 + i) for i in range(3)}
    xs["long"] = _series(1024, seed=20)
    p = str(tmp_path / "b.cameo")
    with cameo.open(p, CFG, mode="w", block_len=256) as ds:
        entries = ds.write_batch(xs)
    assert sorted(entries) == sorted(xs)
    r = CameoStore.open(p)
    for sid, x in xs.items():
        ref = np.asarray(compress(jnp.asarray(x), CFG).xr)
        assert np.array_equal(r.read_series(sid).view(np.uint64),
                              ref.view(np.uint64)), sid
    with pytest.raises(ValueError, match="1-D"):
        with cameo.open(str(tmp_path / "b2.cameo"), CFG, mode="w") as ds:
            ds.write_batch({"m": np.zeros((64, 2))})


def test_pacf_value_and_bound(tmp_path):
    x = _series(2048, seed=6)
    p = str(tmp_path / "p.cameo")
    with cameo.open(p, CFG, mode="w", block_len=512) as ds:
        ds.write("s", x)
    s = cameo.open(p).series("s")
    av, ab = s.acf(100, 1900)
    pv, pb = s.pacf(100, 1900)
    # value: exactly the compressor's Durbin-Levinson transform of the
    # pushdown ACF answer
    assert np.array_equal(pv, np.asarray(pacf_from_acf(jnp.asarray(av))))
    # bound: covers the PACF of the exact reconstruction ACF
    xr = np.asarray(s.window(100, 1900), np.float64)
    ref = np.asarray(pacf_from_acf(acf(jnp.asarray(xr), CFG.lags)))
    assert np.all(np.abs(pv - ref) <= pb)


# ---------------------------------------------------------------------------
# handle ergonomics + validation
# ---------------------------------------------------------------------------

def test_open_modes(tmp_path):
    p = str(tmp_path / "m.cameo")
    with pytest.raises(ValueError, match="needs a CameoConfig"):
        cameo.open(p)              # missing file defaults to "w": needs cfg
    with cameo.open(p, CFG) as ds:          # default "w" on a fresh path
        ds.write("s", _series(512, seed=7))
        assert ds.writable and "s" in ds and list(ds) == ["s"]
    ds = cameo.open(p)                      # default "r" once it exists
    assert not ds.writable
    with pytest.raises(IOError, match="read-only"):
        ds.write("t", _series(512))
    assert ds.stats()["series"] == 1
    ds.close()
    with cameo.open(p, CFG, mode="a") as ds:  # append
        ds.write("t", _series(512, seed=8))
    assert sorted(cameo.open(p).sids()) == ["s", "t"]
    with pytest.raises(ValueError, match="unknown mode"):
        cameo.open(p, CFG, mode="x")
    with pytest.raises(ValueError, match=r"\[n\] or \[n, C\]"):
        with cameo.open(str(tmp_path / "z.cameo"), CFG) as ds:
            ds.write("bad", np.zeros((4, 4, 4)))


def test_single_column_2d_writes_univariate(tmp_path):
    """[n, 1] input squeezes to a plain univariate series (no v4 block)."""
    x = _series(1024, seed=9)
    p = str(tmp_path / "c1.cameo")
    with cameo.open(p, CFG, mode="w", block_len=256) as ds:
        ds.write("s", x[:, None])
    assert open(p, "rb").read(8) == b"CAMEOST\x03"
    s = cameo.open(p).series("s")
    assert s.channels == 1 and s.window().ndim == 1


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_legacy_entry_points_warn(tmp_path):
    x = _series(1024, seed=12)
    p = str(tmp_path / "w.cameo")
    with TimeSeriesService(p, CFG, TsServiceConfig(block_len=256)) as svc:
        with pytest.warns(DeprecationWarning, match="submit is deprecated"):
            svc.submit("s", x)
        svc.flush()
        with pytest.warns(DeprecationWarning,
                          match="ingest_stream is deprecated"):
            h = svc.ingest_stream("t", window_len=max(512,
                                                      min_window_len(CFG)))
        h.push(x)
        h.close()

    import repro.store as store_pkg
    r = CameoStore.open(p)
    with pytest.warns(DeprecationWarning, match="window_mean is deprecated"):
        v, b = store_pkg.window_mean(r, "s", 10, 500)
    # the shim forwards to the very same engine the façade uses
    assert (v, b) == squery.window_mean(r, "s", 10, 500)

    from repro.core.streaming import compress_windowed
    with pytest.warns(DeprecationWarning, match="compress_windowed"):
        compress_windowed(x, CFG, max(512, min_window_len(CFG)))


def test_mvar_convenience_through_facade(tmp_path):
    """Dataset.write with [n, C] + Series col reads (smoke-level; the deep
    multivariate contracts live in test_multivariate.py)."""
    rng = np.random.default_rng(13)
    n = 1536
    X = np.stack([_series(n, seed=14),
                  _series(n, seed=15) + 0.5], axis=1)
    p = str(tmp_path / "mv.cameo")
    with cameo.open(p, CFG, mode="w", block_len=384) as ds:
        entry = ds.write("m", X)
    assert entry["channels"] == 2
    assert open(p, "rb").read(8) == b"CAMEOST\x04"
    s = cameo.open(p).series("m")
    assert s.channels == 2
    assert s.window().shape == (n, 2)
    v, b = s.mean(100, 1400)
    assert v.shape == b.shape == (2,)
    for c in range(2):
        assert abs(v[c] - X[100:1400, c].mean()) <= b[c]
    pv, pb = s.pacf(col=1)
    assert pv.shape == (CFG.lags,)
    assert np.all(s.deviations <= CFG.eps + 1e-12)
