"""Coarse-grained parallel CAMEO (paper §4.4 -> collectives)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures
from repro.core.acf import acf, extract_aggregates
from repro.core.cameo import CameoConfig, decompress
from repro.core.parallel import (chunk_agg_contrib, chunk_delta_contrib,
                                 compress_partitioned,
                                 compress_partitioned_local)


def _series(n, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return jnp.asarray(np.sin(2 * np.pi * t / 24)
                       + 0.15 * rng.standard_normal(n))


def test_partitioned_aggregates_equal_global():
    n, L, T = 1024, 12, 4
    x = _series(n)
    m = n // T
    yp = x.reshape(T, m)
    halos = jnp.concatenate([yp[1:, :L], jnp.zeros((1, L))], axis=0)
    contribs = jax.vmap(
        lambda yc, hr, off: chunk_agg_contrib(yc, hr, off, n, L)
    )(yp, halos, jnp.arange(T, dtype=jnp.int32) * m)
    agg_par = jax.tree.map(lambda a: a.sum(0), contribs)
    agg_glob = extract_aggregates(x, L)
    for f in agg_glob._fields:
        np.testing.assert_allclose(np.asarray(getattr(agg_par, f)),
                                   np.asarray(getattr(agg_glob, f)),
                                   rtol=1e-10, atol=1e-8)


def test_partitioned_delta_contrib_crosses_boundaries():
    n, L, T = 512, 8, 4
    x = _series(n, seed=1)
    m = n // T
    rng = np.random.default_rng(2)
    delta = jnp.asarray(rng.standard_normal(n) * 0.1)
    yp, dp = x.reshape(T, m), delta.reshape(T, m)
    hy = jnp.concatenate([yp[1:, :L], jnp.zeros((1, L))], axis=0)
    hd = jnp.concatenate([dp[1:, :L], jnp.zeros((1, L))], axis=0)
    contribs = jax.vmap(
        lambda yc, dc, a, b, off: chunk_delta_contrib(yc, dc, a, b, off, n, L)
    )(yp, dp, hy, hd, jnp.arange(T, dtype=jnp.int32) * m)
    dagg = jax.tree.map(lambda a: a.sum(0), contribs)
    base = extract_aggregates(x, L)
    want = extract_aggregates(x + delta, L)
    for f in base._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(base, f)) + np.asarray(getattr(dagg, f)),
            np.asarray(getattr(want, f)), rtol=1e-9, atol=1e-8)


def test_lockstep_partitioned_guarantee():
    n = 1024
    x = _series(n, seed=3)
    cfg = CameoConfig(eps=0.02, lags=12, dtype="float64")
    res = compress_partitioned(x, cfg, T=4)
    assert float(res.deviation) <= cfg.eps + 1e-12
    kept = np.asarray(res.kept)
    recon = decompress(np.nonzero(kept)[0], np.asarray(res.xr)[kept], n)
    dev_true = float(measures.mae(acf(recon, 12), acf(x, 12)))
    assert abs(dev_true - float(res.deviation)) < 1e-8
    assert n / int(res.n_kept) > 2.0


def test_local_budget_variant_conservative():
    n = 1024
    x = _series(n, seed=4)
    cfg = CameoConfig(eps=0.02, lags=12, dtype="float64")
    res = compress_partitioned_local(x, cfg, T=4)
    # local-budget semantics: global deviation measured; typically well
    # under the budget (the paper's partitions are conservative)
    assert float(res.deviation) <= cfg.eps + 1e-9
