"""Per-arch reduced smoke tests: forward/train/decode on CPU, plus the
prefill==forward cache-consistency invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_reduced
from repro.data.pipeline import token_batch
from repro.models.model import (decode_step, forward, init_caches,
                                model_defs, prefill)
from repro.models.params import count_params, init_params
from repro.train.step import TrainConfig, build_train_step, init_opt_state

B, S = 2, 32

# the two largest reduced archs dominate suite time; their param cases are
# marked slow so CI's fast subset (-m "not slow") skips them
_HEAVY_ARCHS = ("jamba-1.5-large-398b", "gemma3-27b")


def _arch_params(ids):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS
            else a for a in ids]


def _setup(arch):
    cfg = get_reduced(arch)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    batch = token_batch(cfg, B, S, step=0)
    return cfg, params, batch


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_forward_smoke(arch):
    cfg, params, batch = _setup(arch)
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_train_step_smoke(arch):
    cfg, params, batch = _setup(arch)
    tcfg = TrainConfig(num_microbatches=1, total_steps=10, warmup=2)
    step_fn = jax.jit(build_train_step(cfg, tcfg))
    opt = init_opt_state(params, tcfg)
    p2, opt2, metrics = step_fn(params, opt, batch, jnp.asarray(0))
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2)
    assert max(jax.tree.leaves(changed)) > 0


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_decode_step_smoke(arch):
    cfg, params, batch = _setup(arch)
    _, caches = jax.jit(
        lambda p, b: prefill(p, cfg, b, max_len=S + 4))(params, batch)
    tok = batch["tokens"][:, -1:]
    logits, new_caches = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, jnp.asarray(S, jnp.int32))
    )(params, tok, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", _arch_params(
    ["qwen3-0.6b", "gemma3-27b", "mamba2-2.7b",
     "jamba-1.5-large-398b", "musicgen-large"]))
def test_prefill_decode_matches_forward(arch):
    """decode at position S must reproduce forward logits on S+1 tokens
    (MoE archs excluded here unless capacity is loss-free)."""
    import dataclasses
    cfg = get_reduced(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = init_params(model_defs(cfg), jax.random.PRNGKey(1))
    batch = token_batch(cfg, B, S, step=1)
    full = token_batch(cfg, B, S + 1, step=1)
    # keep the first S tokens identical
    full["tokens"] = jnp.concatenate(
        [batch["tokens"], full["tokens"][:, -1:]], axis=1)
    logits_full, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, full)
    _, caches = jax.jit(
        lambda p, b: prefill(p, cfg, b, max_len=S + 4))(params, batch)
    ld, _ = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, jnp.asarray(S, jnp.int32))
    )(params, full["tokens"][:, -1:], caches)
    np.testing.assert_allclose(np.asarray(ld[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_param_counts_match_arch_names():
    from repro.configs.registry import (active_param_count, get_config,
                                        param_count)
    expect = {
        "stablelm-12b": (11e9, 13e9), "gemma3-27b": (26e9, 28e9),
        "qwen3-0.6b": (0.55e9, 0.65e9), "smollm-135m": (0.12e9, 0.15e9),
        "qwen3-moe-235b-a22b": (230e9, 240e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
        "qwen2-vl-2b": (1.4e9, 1.7e9), "mamba2-2.7b": (2.5e9, 2.9e9),
        "musicgen-large": (2.2e9, 2.6e9),
        "jamba-1.5-large-398b": (380e9, 410e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, (arch, n)
    assert 20e9 <= active_param_count(get_config("qwen3-moe-235b-a22b")) <= 25e9
    assert 28e9 <= active_param_count(get_config("kimi-k2-1t-a32b")) <= 36e9


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(model_defs(cfg), jax.random.PRNGKey(2))
    batch = token_batch(cfg, 4, S, step=3)
    t1 = TrainConfig(num_microbatches=1, peak_lr=1e-3)
    t2 = TrainConfig(num_microbatches=2, peak_lr=1e-3)
    s1 = jax.jit(build_train_step(cfg, t1))
    s2 = jax.jit(build_train_step(cfg, t2))
    p1, _, m1 = s1(params, init_opt_state(params, t1), batch, jnp.asarray(0))
    p2, _, m2 = s2(params, init_opt_state(params, t2), batch, jnp.asarray(0))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), p1, p2)))
    assert diff < 5e-4
