"""ACF/PACF correctness + the paper's core invariant: incremental aggregate
maintenance equals from-scratch recomputation after arbitrary edits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

# optional dep: property tests skip when hypothesis is missing, rest run
given, settings, st = hypothesis_or_stubs()

from repro.core.acf import (acf, acf_from_aggregates, acf_stationary,
                            aggregate_series, extract_aggregates,
                            pacf, pacf_from_acf)
from repro.core.aggregates import (acf_after_single_delta,
                                   acf_after_window_delta, alive_neighbors,
                                   apply_delta_dense, apply_delta_window,
                                   interpolate_at, segment_deltas)


def _series(n, seed=0, period=24):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return jnp.asarray(np.sin(2 * np.pi * t / period)
                       + 0.2 * rng.standard_normal(n))


def _acf_direct(x, L):
    x = np.asarray(x)
    n = len(x)
    return np.array([np.corrcoef(x[: n - l], x[l:])[0, 1]
                     for l in range(1, L + 1)])


def test_acf_matches_pearson_per_lag():
    x = _series(512)
    got = np.asarray(acf(x, 16))
    want = _acf_direct(x, 16)
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_acf_from_aggregates_roundtrip():
    x = _series(300, seed=3)
    agg = extract_aggregates(x, 10)
    np.testing.assert_allclose(np.asarray(acf_from_aggregates(agg, 300)),
                               np.asarray(acf(x, 10)), atol=1e-12)


def test_acf_stationary_close_to_nonstationary_for_stationary_series():
    x = _series(4096, seed=1)
    a = np.asarray(acf(x, 8))
    b = np.asarray(acf_stationary(x, 8))
    np.testing.assert_allclose(a, b, atol=0.02)


def test_pacf_lag1_equals_acf1_and_ar1_structure():
    # AR(1): PACF cuts off after lag 1
    rng = np.random.default_rng(0)
    n = 20000
    e = rng.standard_normal(n)
    x = np.empty(n)
    x[0] = e[0]
    for i in range(1, n):
        x[i] = 0.6 * x[i - 1] + e[i]
    p = np.asarray(pacf(jnp.asarray(x), 6))
    r = np.asarray(acf(jnp.asarray(x), 6))
    assert abs(p[0] - r[0]) < 1e-9
    assert abs(p[0] - 0.6) < 0.05
    assert np.all(np.abs(p[1:]) < 0.05)


def test_aggregate_series_modes():
    x = jnp.asarray(np.arange(12, dtype=np.float64))
    np.testing.assert_allclose(aggregate_series(x, 4, "mean"),
                               [1.5, 5.5, 9.5])
    np.testing.assert_allclose(aggregate_series(x, 4, "max"), [3, 7, 11])
    np.testing.assert_allclose(aggregate_series(x, 4, "sum"), [6, 22, 38])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8),
       st.lists(st.tuples(st.integers(0, 199), st.floats(-3, 3)),
                min_size=1, max_size=12))
def test_incremental_dense_equals_recompute(seed, L, edits):
    """THE paper invariant (Eq. 8/9): aggregate updates == recompute."""
    n = 200
    x = _series(n, seed=seed)
    agg = extract_aggregates(x, L)
    delta = np.zeros(n)
    for idx, val in edits:
        delta[idx] += val
    delta = jnp.asarray(delta)
    got = apply_delta_dense(agg, x, delta)
    want = extract_aggregates(x + delta, L)
    for f in got._fields:
        np.testing.assert_allclose(np.asarray(getattr(got, f)),
                                   np.asarray(getattr(want, f)),
                                   rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 10), st.integers(0, 199))
def test_incremental_window_equals_recompute(seed, L, start):
    n = 200
    W = 16
    start = min(start, n - 1)
    rng = np.random.default_rng(seed)
    x = _series(n, seed=seed)
    dwin_np = rng.standard_normal(W)
    # zero out deltas that would fall off the series end
    for j in range(W):
        if start + j >= n:
            dwin_np[j] = 0.0
    dwin = jnp.asarray(dwin_np)
    got = apply_delta_window(extract_aggregates(x, L), x, dwin,
                             jnp.asarray(start, jnp.int32), W=W, L=L)
    dense = np.zeros(n)
    dense[start:start + W] = dwin_np[: max(0, min(W, n - start))]
    want = extract_aggregates(x + jnp.asarray(dense), L)
    for f in got._fields:
        np.testing.assert_allclose(np.asarray(getattr(got, f)),
                                   np.asarray(getattr(want, f)),
                                   rtol=1e-9, atol=1e-9)


def test_single_delta_rows_match_recompute():
    n, L = 128, 6
    x = _series(n, seed=9)
    agg = extract_aggregates(x, L)
    idx = jnp.asarray([0, 1, 63, 126, 127], jnp.int32)
    dval = jnp.asarray([0.5, -1.0, 2.0, 0.1, -0.3])
    rows = acf_after_single_delta(agg, x, idx, dval)
    for r, (i, d) in zip(np.asarray(rows),
                         zip(np.asarray(idx), np.asarray(dval))):
        want = acf(x.at[i].add(d), L)
        np.testing.assert_allclose(r, np.asarray(want), rtol=1e-9, atol=1e-9)


def test_window_delta_rows_match_recompute():
    n, L, W = 128, 6, 8
    x = _series(n, seed=11)
    agg = extract_aggregates(x, L)
    starts = jnp.asarray([0, 50, 120], jnp.int32)
    rng = np.random.default_rng(4)
    dwins_np = rng.standard_normal((3, W))
    dwins_np[2, :] = 0
    dwins_np[2, :5] = rng.standard_normal(5)  # stay inside series
    dwins = jnp.asarray(dwins_np)
    rows = acf_after_window_delta(agg, x, starts, dwins)
    for r, s, d in zip(np.asarray(rows), np.asarray(starts), dwins_np):
        dense = np.zeros(n)
        dense[s:s + W] = d[: n - s]
        want = acf(x + jnp.asarray(dense), L)
        np.testing.assert_allclose(r, np.asarray(want), rtol=1e-8, atol=1e-8)


def test_alive_neighbors_and_interpolation():
    alive = jnp.asarray([True, False, False, True, True, False, True])
    prev, nxt = alive_neighbors(alive)
    assert prev.tolist() == [-1, 0, 0, 0, 3, 4, 4]
    assert nxt.tolist() == [3, 3, 3, 4, 6, 6, 7]
    x = jnp.asarray([0.0, 9.0, 9.0, 3.0, 4.0, 9.0, 7.0])
    i = jnp.asarray([1, 2, 5])
    xi = interpolate_at(x, prev[i], nxt[i], i)
    np.testing.assert_allclose(np.asarray(xi), [1.0, 2.0, 5.5])


def test_segment_deltas_matches_reinterpolation():
    x = _series(64, seed=5)
    alive = jnp.ones(64, bool).at[jnp.asarray([10, 11, 30])].set(False)
    prev, nxt = alive_neighbors(alive)
    dwin, start, span = segment_deltas(x, prev, nxt,
                                       jnp.asarray([12, 31]), 8)
    # removing 12 re-interpolates (9, 13) interior = 10, 11, 12
    assert int(start[0]) == 10 and int(span[0]) == 3
    assert int(start[1]) == 30 and int(span[1]) == 2
