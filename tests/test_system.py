"""End-to-end behaviour tests: the full CAMEO data plane (compress -> hard
guarantee -> decompress -> downstream forecasting on compressed data), the
paper's headline comparisons in miniature, and the LM-side integration."""
import pytest
import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.line_simpl import compress_baseline
from repro.core import measures
from repro.core.acf import acf, aggregate_series
from repro.core.cameo import (CameoConfig, compress, compression_ratio,
                              decompress, kept_points)
from repro.data.pipeline import SeriesTokenizer, series_windows
from repro.data.synthetic import make_dataset


def _holt_winters_additive(x, period, horizon, alpha=0.3, beta=0.05,
                           gamma=0.2):
    """Simple additive Holt-Winters, numpy (forecasting oracle)."""
    x = np.asarray(x, np.float64)
    n = len(x)
    level = x[:period].mean()
    trend = (x[period:2 * period].mean() - x[:period].mean()) / period
    season = x[:period] - level
    for t in range(n):
        s = season[t % period]
        new_level = alpha * (x[t] - s) + (1 - alpha) * (level + trend)
        trend = beta * (new_level - level) + (1 - beta) * trend
        season[t % period] = gamma * (x[t] - new_level) + (1 - gamma) * s
        level = new_level
    return np.array([level + (h + 1) * trend + season[(n + h) % period]
                     for h in range(horizon)])


def test_end_to_end_compress_forecast():
    """Paper §5.8 in miniature: forecasting on CAMEO-compressed data stays
    close to forecasting on raw data, at a high compression ratio."""
    x = make_dataset("uk_elec", seed=0, length=4800)
    xj = jnp.asarray(x)
    cfg = CameoConfig(eps=0.0, lags=48, target_cr=6.0, mode="sequential",
                      hops=24, window=64, dtype="float64")
    res = compress(xj, cfg)
    idx, vals = kept_points(res)
    recon = np.asarray(decompress(idx, vals, len(x)))

    horizon, period = 48, 48
    train_raw, test = x[:-horizon], x[-horizon:]
    train_cmp = recon[:-horizon]
    f_raw = _holt_winters_additive(train_raw, period, horizon)
    f_cmp = _holt_winters_additive(train_cmp, period, horizon)
    sm_raw = float(measures.msmape(jnp.asarray(test), jnp.asarray(f_raw)))
    sm_cmp = float(measures.msmape(jnp.asarray(test), jnp.asarray(f_cmp)))
    # compressed-data forecasts stay in the same quality regime as raw ones
    # (greedy tie-breaks vary with CPU thread scheduling, so the bound is
    # order-of-magnitude, not percent-level; the fig12 bench tracks the
    # tight comparison)
    assert sm_cmp <= max(4.0 * sm_raw, 0.25), (sm_raw, sm_cmp)
    assert compression_ratio(res) >= 5.9


@pytest.mark.slow
def test_cameo_beats_vw_on_seasonal_data():
    """Headline claim (Fig. 6-flavored): at equal ACF budget CAMEO compresses
    at least as well as the strongest line-simplification baseline on a
    seasonal dataset (checked on two seeds to avoid flakiness)."""
    wins = 0
    for seed in [0, 1]:
        x = jnp.asarray(make_dataset("uk_elec", seed=seed, length=4096))
        cfg = CameoConfig(eps=5e-3, lags=48, dtype="float64")
        cr_cameo = compression_ratio(compress(x, cfg))
        r = compress_baseline(x, cfg, "vw")
        cr_vw = 4096.0 / float(r.n_kept)
        if cr_cameo >= cr_vw * 0.9:
            wins += 1
    assert wins >= 1


@pytest.mark.slow
def test_lm_trains_on_cameo_compressed_series():
    """The LM substrate consumes the CAMEO data plane: tokenize a compressed
    sensor stream and take gradient steps on a reduced arch."""
    from repro.configs.registry import get_reduced
    from repro.models.model import model_defs
    from repro.models.params import init_params
    from repro.train.step import TrainConfig, build_train_step, init_opt_state

    x = make_dataset("elec_power", seed=1, length=2976)
    res = compress(jnp.asarray(x),
                   CameoConfig(eps=1e-2, lags=48, dtype="float64"))
    idx, vals = kept_points(res)
    recon = np.asarray(decompress(idx, vals, len(x)))

    cfg = get_reduced("musicgen-large")   # audio/time-series-native arch
    tok = SeriesTokenizer.fit(x, vocab=cfg.vocab)
    windows = series_windows(tok.encode(recon), window=32, stride=16)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    tcfg = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=10, z_loss=0.0)
    step = jax.jit(build_train_step(cfg, tcfg))
    opt = init_opt_state(params, tcfg)
    losses = []
    for i in range(8):
        batch = {"tokens": jnp.asarray(windows[i * 4:(i + 1) * 4])}
        params, opt, m = step(params, opt, batch, jnp.asarray(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
