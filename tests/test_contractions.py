"""Parity: the batched matmul-shaped contractions of the round body vs
their retained per-lag loop oracles.

The rounds-mode hot path lowers every Eq. 7/8/9 term to O(1) einsum/gather
ops against constant shift bases (see ``kernels/README.md``); each fused
form keeps its historical per-lag oracle next to it precisely so these
property tests can pin the algebra across lag depths, aggregation factors
and deviation measures.  Tolerances are float64-tight: the contraction and
the loop differ only in reduction order.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_stubs

from repro.core.acf import aggregate_series, extract_aggregates
from repro.core.aggregates import apply_delta_dense, apply_delta_dense_ref
from repro.kernels import fused_round as fused
from repro.kernels import ref

given, settings, st = hypothesis_or_stubs()

_L = st.sampled_from([1, 4, 12])
_KAPPA = st.sampled_from([1, 4])
_MEASURE = st.sampled_from(["mae", "rmse", "cheb"])


def _target_series(seed, n, kappa):
    """A zero-padded aggregate-space series plus its valid length: raw
    signal of length ``n * kappa`` pushed through the Def. 2 tumbling
    aggregation, then padded-bucket style (zeros beyond ``ny``)."""
    rng = np.random.default_rng(seed)
    t = np.arange(n * kappa)
    x = (np.sin(2 * np.pi * t / 24) + 0.5 * np.sin(2 * np.pi * t / 7)
         + 0.2 * rng.standard_normal(n * kappa))
    y = np.asarray(aggregate_series(jnp.asarray(x), kappa))
    ny = y.shape[0]
    pad = int(rng.integers(0, 17))
    return jnp.asarray(np.pad(y, (0, pad))), ny, rng


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), _L, _KAPPA)
def test_moment_deltas_matches_loop_oracle(seed, L, kappa):
    """fused_round._moment_deltas bilinear-term lowerings — "einsum"
    (shift-basis contraction, the TPU form) and "roll" (batched
    roll-and-reduce, the CPU form) — both ≡ _moment_deltas_ref
    (L-unrolled slices).  Forms are requested explicitly so neither leg
    is vacuous regardless of the backend the test runs on."""
    y, ny, rng = _target_series(seed, 96, kappa)
    K, Wy = 5, 8
    starts = jnp.asarray(
        rng.integers(0, max(ny - Wy, 1), size=K), jnp.int32)
    d = jnp.asarray(0.3 * rng.standard_normal((K, Wy)))
    # the solo-candidate context gather (solo_moment_rows layout)
    kk = jnp.arange(Wy + 2 * L)
    ctx = jnp.pad(y, (L, L + Wy))[starts[:, None] + kk[None, :]]
    b = fused._moment_deltas_ref(d, ctx, starts, ny, L=L)
    for form in ("einsum", "roll"):
        a = fused._moment_deltas(d, ctx, starts, ny, L=L, form=form)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-11, atol=1e-11,
                                   err_msg=f"form={form}")


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), _L, _KAPPA, _MEASURE)
def test_window_delta_acf_matches_per_moment_oracle(seed, L, kappa, measure):
    """ref._window_delta_acf (one fused [P,5,W]x[P,5,W,L] contraction) ≡
    _window_delta_acf_ref (one einsum per moment row), and the ranking
    impacts derived from both rows agree for every kernel measure."""
    y, ny, rng = _target_series(seed, 128, kappa)
    agg = extract_aggregates(y[:ny], L)
    P, W = 6, 10
    starts = jnp.asarray(
        rng.integers(0, max(ny - W, 1), size=P), jnp.int32)
    dwins = jnp.asarray(0.3 * rng.standard_normal((P, W)))
    rows_ctx = ref.candidate_contexts(y[:ny], starts, L=L, W=W)
    fused_rows = ref.acf_after_window_delta_rows(
        agg, rows_ctx, starts, dwins, ny=ny)
    j = jnp.arange(W)
    l = jnp.arange(1, L + 1)
    abs_t = starts[:, None] + j[None, :]
    y_at = rows_ctx[:, L:L + W]
    y_fwd = rows_ctx[:, L + j[:, None] + l[None, :]]
    y_bwd = rows_ctx[:, L + j[:, None] - l[None, :]]
    oracle_rows = ref._window_delta_acf_ref(
        agg, dwins, abs_t, y_at, y_fwd, y_bwd, ny=ny)
    np.testing.assert_allclose(np.asarray(fused_rows),
                               np.asarray(oracle_rows),
                               rtol=1e-10, atol=1e-10)
    p0 = jnp.asarray(rng.standard_normal(L) * 0.1)
    np.testing.assert_allclose(
        np.asarray(ref.measure_rows(fused_rows, p0, measure)),
        np.asarray(ref.measure_rows(oracle_rows, p0, measure)),
        rtol=1e-10, atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), _L)
def test_lag_xdot_matches_slice_oracle(seed, L):
    """ref.lag_xdot ([m] x [m, L] shift-basis matmul) ≡ lag_xdot_ref
    (one dynamic slice + reduce per lag), with a non-trivial halo."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(8, 200))
    a = jnp.asarray(rng.standard_normal(m))
    b_ext = jnp.asarray(rng.standard_normal(m + L))
    np.testing.assert_allclose(
        np.asarray(ref.lag_xdot(a, b_ext, L=L)),
        np.asarray(ref.lag_xdot_ref(a, b_ext, L=L)),
        rtol=1e-11, atol=1e-11)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), _L, _KAPPA)
def test_apply_delta_dense_matches_roll_oracle(seed, L, kappa):
    """aggregates.apply_delta_dense (Eq. 10/11) ≡ apply_delta_dense_ref
    (per-lag roll-mask-sum oracle) for both bilinear lowerings — "gather"
    ([nyb, L] shift basis, the accelerator form) and "roll" (batched
    roll-and-reduce, the CPU form) — in both the NamedTuple and
    packed-table carry forms, under padded buckets."""
    y, ny, rng = _target_series(seed, 96, kappa)
    agg = extract_aggregates(y[:ny], L)
    delta = np.zeros(y.shape[0])
    lo = int(rng.integers(0, max(ny - 12, 1)))
    delta[lo:lo + 12] = 0.4 * rng.standard_normal(min(12, ny - lo))
    delta = jnp.asarray(delta)
    oracle = apply_delta_dense_ref(agg, y, delta, ny=ny)
    table = jnp.stack(list(agg))
    for form in ("gather", "roll"):
        new = apply_delta_dense(agg, y, delta, ny=ny, form=form)
        for got, want in zip(new, oracle):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-11, atol=1e-11,
                                       err_msg=f"form={form}")
        # packed [5, L] table carry (the rounds-loop form): one fused add
        new_t = apply_delta_dense(table, y, delta, ny=ny, form=form)
        np.testing.assert_allclose(np.asarray(new_t),
                                   np.asarray(jnp.stack(list(oracle))),
                                   rtol=1e-11, atol=1e-11,
                                   err_msg=f"form={form}")


@pytest.mark.parametrize("L", [4, 12, 48])
@pytest.mark.parametrize("kappa", [1, 4])
def test_bilinear_forms_parity_deterministic(L, kappa):
    """Seeded (hypothesis-free) cross-check of every bilinear lowering:
    all _moment_deltas forms agree with the slice oracle and all
    apply_delta_dense forms agree with the roll oracle.  Runs in every
    environment — the property tests above skip without hypothesis."""
    y, ny, rng = _target_series(7 * L + kappa, 96, kappa)
    K, Wy = 5, 8
    starts = jnp.asarray(
        rng.integers(0, max(ny - Wy, 1), size=K), jnp.int32)
    d = jnp.asarray(0.3 * rng.standard_normal((K, Wy)))
    kk = jnp.arange(Wy + 2 * L)
    ctx = jnp.pad(y, (L, L + Wy))[starts[:, None] + kk[None, :]]
    want = fused._moment_deltas_ref(d, ctx, starts, ny, L=L)
    for form in ("einsum", "roll", "slices"):
        got = fused._moment_deltas(d, ctx, starts, ny, L=L, form=form)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-11, atol=1e-11,
                                   err_msg=f"form={form}")
    agg = extract_aggregates(y[:ny], L)
    delta = np.zeros(y.shape[0])
    lo = int(rng.integers(0, max(ny - 12, 1)))
    delta[lo:lo + 12] = 0.4 * rng.standard_normal(min(12, ny - lo))
    delta = jnp.asarray(delta)
    oracle = jnp.stack(list(apply_delta_dense_ref(agg, y, delta, ny=ny)))
    table = jnp.stack(list(agg))
    for form in ("gather", "roll"):
        got_t = apply_delta_dense(table, y, delta, ny=ny, form=form)
        np.testing.assert_allclose(np.asarray(got_t), np.asarray(oracle),
                                   rtol=1e-11, atol=1e-11,
                                   err_msg=f"form={form}")


@pytest.mark.parametrize("L", [4, 12])
def test_window_rows_pallas_interpret_parity(L):
    """The fused tier-impact kernel (interpret mode) reproduces the
    einsum contraction's Eq. 9 ACF rows."""
    rng = np.random.default_rng(3)
    nyb, ny, K, Wy = 128, 120, 7, 16
    y = np.zeros(nyb)
    y[:ny] = rng.standard_normal(ny)
    y = jnp.asarray(y)
    dyws = jnp.asarray(0.1 * rng.standard_normal((K, Wy)))
    starts = jnp.asarray(rng.integers(0, ny - Wy, size=K), jnp.int32)
    table = jnp.stack(list(extract_aggregates(y[:ny], L)))
    a = fused.window_acf_rows(y, dyws, starts, table, ny, L=L)
    b = fused.window_rows_pallas(y, dyws, starts, table, ny, L=L,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-9, atol=1e-9)
