"""Multi-tenant ingest server (``repro.server``) — contract tests.

What is pinned here:

* **concurrent differential**: N producer threads feeding N tenants
  through one server produce per-series block bodies and catalog entries
  identical to N serial single-tenant runs — before *and* after
  background compaction;
* **crash recovery with active sessions**: a kill-anywhere crash image
  of a server with open tenant sessions replays every acked push on
  ``resume=True``, per tenant;
* **compaction**: merging runs of small streamed blocks preserves
  windows and kept points bit-exactly, keeps aggregate answers within
  their bounds, and a crash at *any byte offset* of the rewrite rolls
  back (or forward) to a consistent footer — never torn state;
* **tiers**: demoting a series cold (entropy-wrapped bodies) and
  promoting it back is answer-invariant; pin/prefetch and the per-tier
  hit/byte counters behave;
* **admission / quotas**: ``backpressure="reject"`` raises
  :class:`ServerBusy` when slots run out; a tenant's ``max_points``
  quota refuses the push *before* it is journaled/acked;
* **tenant catalog**: registration persists across close/reopen, tenant
  ε overrides are honored, and the default tenant is exactly the legacy
  unprefixed view;
* **/metrics**: the WSGI hook serves the obs exposition with per-tenant
  labeled counters.
"""
import os
import shutil
import threading

import numpy as np
import pytest

from repro.core.cameo import CameoConfig
from repro.server import (
    DEFAULT_TENANT,
    IngestServer,
    QuotaExceeded,
    ServerBusy,
    ServerConfig,
    tenant_sid,
)
from repro.store import maintenance as maint
from repro.store.store import CameoStore

CFG = CameoConfig(eps=2e-2, lags=8, mode="rounds", max_rounds=60,
                  dtype="float64")
W = 64            # stream window
SEAL = 64         # small sealed blocks (stream-latency tier)
BLK = 256         # full-size blocks (compaction target)
CHUNK = 37        # misaligned with W and SEAL on purpose
N = 1100


def _series(n=N, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return (3 * np.sin(2 * np.pi * t / 24 + seed)
            + 0.2 * rng.standard_normal(n))


def _scfg(**kw):
    base = dict(block_len=BLK, seal_block_len=SEAL, stream_window=W,
                auto_compact=False)
    base.update(kw)
    return ServerConfig(**base)


def _feed(sess, x):
    for i in range(0, len(x), CHUNK):
        sess.push(x[i:i + CHUNK])


def _bodies(store, sid):
    """Per-series block bodies (unwrapped) + location-free block facts."""
    entry = store._series[sid]
    bodies = [bytes(b) for b in store._read_bodies(entry["blocks"])]
    facts = [(b["nbytes"], b["t0"], b["t1"]) for b in entry["blocks"]]
    return bodies, facts


def _entry_key(store, sid):
    e = store.series_meta(sid)
    return {k: e[k] for k in ("n", "n_kept", "eps", "stored_nbytes",
                              "payload_nbytes", "deviation")}


def _snapshot_crash(store, p):
    """OS-visible crash image of a live writer (see test_crash_safety)."""
    store._f.flush()
    if store._wal is not None:
        store._wal._f.flush()
    shutil.copyfile(store.path, p)
    if store._wal is not None:
        shutil.copyfile(store._wal.path, p + ".wal")


# ---------------------------------------------------------------------------
# the concurrent differential
# ---------------------------------------------------------------------------

def test_concurrent_producers_match_serial(tmp_path):
    NT = 4
    tenants = [f"t{i}" for i in range(NT)]
    feeds = {t: _series(seed=i) for i, t in enumerate(tenants)}

    # serial references: one single-tenant store per tenant, same knobs
    refs = {}
    for t in tenants:
        p = str(tmp_path / f"ref-{t}.cameo")
        srv = IngestServer(p, CFG, _scfg())
        srv.register_tenant(t)
        with srv.session("s", tenant=t) as sess:
            _feed(sess, feeds[t])
        srv.close()
        store = CameoStore.open(p)
        refs[t] = (_bodies(store, tenant_sid(t, "s")),
                   _entry_key(store, tenant_sid(t, "s")))
        store.close()

    # concurrent run: NT threads race into one server
    p = str(tmp_path / "fleet.cameo")
    srv = IngestServer(p, CFG, _scfg(max_sessions=NT))
    for t in tenants:
        srv.register_tenant(t)
    start = threading.Barrier(NT)
    errs = []

    def producer(t):
        try:
            start.wait()
            with srv.session("s", tenant=t) as sess:
                _feed(sess, feeds[t])
        except Exception as e:              # pragma: no cover
            errs.append((t, e))

    threads = [threading.Thread(target=producer, args=(t,)) for t in tenants]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs

    # pre-compaction: per-series bodies/entries identical to serial runs
    for t in tenants:
        sid = tenant_sid(t, "s")
        assert _bodies(srv.store, sid) == refs[t][0], t
        assert _entry_key(srv.store, sid) == refs[t][1], t

    # post-compaction: compact both sides, compare again
    for t in tenants:
        srv.compact("s", tenant=t)
    for t in tenants:
        pr = str(tmp_path / f"ref-{t}.cameo")
        store = CameoStore(pr, "a")
        maint.compact_series(store, tenant_sid(t, "s"), target_len=BLK)
        ref_bodies = _bodies(store, tenant_sid(t, "s"))
        ref_entry = _entry_key(store, tenant_sid(t, "s"))
        store.close()
        sid = tenant_sid(t, "s")
        assert _bodies(srv.store, sid) == ref_bodies, t
        assert _entry_key(srv.store, sid) == ref_entry, t
        got = srv.view(t).series("s").window()
        assert got.shape == feeds[t].shape
    srv.close()


def test_background_compaction_worker(tmp_path):
    """auto_compact: closing a session queues it; drain() then shows the
    merged layout and byte-identical windows."""
    x = _series(seed=9)
    p = str(tmp_path / "bg.cameo")
    srv = IngestServer(p, CFG, _scfg(auto_compact=True))
    srv.register_tenant("a")
    with srv.session("s", tenant="a") as sess:
        _feed(sess, x)
    before = srv.view("a").series("s").window()
    srv.drain_compaction()
    st = srv.stats()
    assert st["compaction"]["compacted"] == 1
    assert st["compaction"]["last_error"] is None
    assert st["tiers"]["dead_nbytes"] > 0
    after = srv.view("a").series("s").window()
    assert np.array_equal(before.view(np.uint64), after.view(np.uint64))
    srv.close()


# ---------------------------------------------------------------------------
# crash recovery with active sessions
# ---------------------------------------------------------------------------

def test_crash_recovery_with_active_sessions(tmp_path):
    tenants = ["a", "b"]
    feeds = {t: _series(seed=i + 3) for i, t in enumerate(tenants)}
    cut = 600

    live = str(tmp_path / "live.cameo")
    img = str(tmp_path / "crash.cameo")
    srv = IngestServer(live, CFG, _scfg())
    acked = {}
    sessions = {}
    for t in tenants:
        srv.register_tenant(t)
        sessions[t] = srv.session("s", tenant=t)
    for t in tenants:
        for i in range(0, cut, CHUNK):
            c = feeds[t][i:min(i + CHUNK, cut)]
            sessions[t].push(c)
            acked[t] = acked.get(t, 0) + len(c)
    _snapshot_crash(srv.store, img)          # kill -9 with sessions open
    for t in tenants:
        sessions[t].close()
    srv.close()

    srv2 = IngestServer(img, CFG, _scfg(), resume=True)
    assert sorted(srv2.catalog.tenants()) == tenants
    for t in tenants:
        sess = srv2.session("s", tenant=t, resume=True)
        assert sess.resume_from == acked[t], t   # nothing acked was lost
        for i in range(sess.resume_from, len(feeds[t]), CHUNK):
            sess.push(feeds[t][i:i + CHUNK])
        sess.close()
    srv2.close()

    # every tenant's finished series answers like a clean reference run
    for i, t in enumerate(tenants):
        pr = str(tmp_path / f"cref-{t}.cameo")
        ref = IngestServer(pr, CFG, _scfg())
        ref.register_tenant(t)
        with ref.session("s", tenant=t) as sess:
            _feed(sess, feeds[t])
        ref.close()
        a = CameoStore.open(img)
        b = CameoStore.open(pr)
        ga = a.read_window(tenant_sid(t, "s"), 0, len(feeds[t]))
        gb = b.read_window(tenant_sid(t, "s"), 0, len(feeds[t]))
        assert np.array_equal(ga.view(np.uint64), gb.view(np.uint64)), t
        assert _bodies(a, tenant_sid(t, "s")) == _bodies(b, tenant_sid(t, "s"))
        a.close()
        b.close()


def test_compaction_crash_at_every_offset_rolls_back(tmp_path):
    """Truncate the store at every offset class inside a compaction
    rewrite (paired with the pre-rewrite journal, as a real crash would
    leave it): recovery must land on the pre- or post-compaction footer,
    both of which answer identically."""
    x = _series(n=700, seed=11)
    p = str(tmp_path / "c.cameo")
    srv = IngestServer(p, CFG, _scfg())
    srv.register_tenant("a")
    with srv.session("s", tenant="a") as sess:
        _feed(sess, x)
    srv.flush()
    sid = tenant_sid("a", "s")
    want = srv.view("a").series("s").window()
    pre = str(tmp_path / "pre.cameo")
    _snapshot_crash(srv.store, pre)          # pre-rewrite image (+ .wal)
    pre_len = os.path.getsize(pre)
    srv.compact("s", tenant="a")
    srv.store._f.flush()
    final = open(p, "rb").read()
    srv.close()

    img = str(tmp_path / "img.cameo")
    for off in list(range(pre_len, len(final), 149)) + [len(final)]:
        with open(img, "wb") as f:
            f.write(final[:off])
        shutil.copyfile(pre + ".wal", img + ".wal")
        store = CameoStore(img, "a")
        got = store.read_window(sid, 0, len(x))
        assert np.array_equal(got.view(np.uint64), want.view(np.uint64)), off
        store.close()


# ---------------------------------------------------------------------------
# compaction answer equivalence
# ---------------------------------------------------------------------------

def test_compaction_preserves_answers(tmp_path):
    x = _series(seed=21)
    p = str(tmp_path / "m.cameo")
    srv = IngestServer(p, CFG, _scfg())
    srv.register_tenant("a")
    with srv.session("s", tenant="a") as sess:
        _feed(sess, x)
    s = srv.view("a").series("s")
    w0 = s.window()
    k0 = s.kept()
    aggs0 = {k: getattr(s, k)() for k in ("mean", "var", "acf")}
    nblk0 = len(srv.store.series_meta(tenant_sid("a", "s"))["blocks"])

    rep = srv.compact("s", tenant="a")
    assert rep["runs"] >= 1 and rep["blocks_after"] < rep["blocks_before"]
    assert rep["dead_nbytes"] > 0
    assert nblk0 == rep["blocks_before"]

    w1 = s.window()
    k1 = s.kept()
    assert np.array_equal(w0.view(np.uint64), w1.view(np.uint64))
    assert np.array_equal(k0[0], k1[0])
    assert np.array_equal(k0[1].view(np.uint64), k1[1].view(np.uint64))
    for kind, (v0, b0) in aggs0.items():
        v1, b1 = getattr(s, kind)()
        np.testing.assert_allclose(v1, v0, rtol=0, atol=1e-9)
        assert np.all(np.asarray(b1) >= 0)
        # the recomputed answer stays inside the old bound and vice versa
        assert np.all(np.abs(np.asarray(v1) - np.asarray(v0))
                      <= np.asarray(b0) + np.asarray(b1) + 1e-12), kind

    # idempotent: a second pass finds nothing to merge
    rep2 = srv.compact("s", tenant="a")
    assert rep2["runs"] == 0
    # survives close/reopen (footer republish is durable)
    srv.close()
    store = CameoStore.open(p)
    got = store.read_window(tenant_sid("a", "s"), 0, len(x))
    assert np.array_equal(got.view(np.uint64), w0.view(np.uint64))
    store.close()


# ---------------------------------------------------------------------------
# tiers
# ---------------------------------------------------------------------------

def test_tier_demote_promote_answer_invariant(tmp_path):
    x = np.round(_series(seed=31), 2)        # compressible bodies
    p = str(tmp_path / "t.cameo")
    srv = IngestServer(p, CFG, _scfg())
    srv.register_tenant("a")
    with srv.session("s", tenant="a") as sess:
        _feed(sess, x)
    srv.compact("s", tenant="a")
    sid = tenant_sid("a", "s")
    bodies0, _ = _bodies(srv.store, sid)
    w0 = srv.view("a").series("s").window()
    m0 = srv.view("a").series("s").mean()

    assert srv.tiers._lock is srv._lock       # rewrites serialize with pushes
    rep = srv.tiers.demote_cold(sid)
    assert rep["rewritten"] >= 1
    assert any("wrap" in b for b in srv.store._series[sid]["blocks"])
    srv.store._cache.clear()                 # force cold fetches
    w1 = srv.view("a").series("s").window()
    assert np.array_equal(w0.view(np.uint64), w1.view(np.uint64))
    assert srv.view("a").series("s").mean() == m0
    bodies1, _ = _bodies(srv.store, sid)
    assert bodies0 == bodies1                # unwrap is byte-identical
    ts = srv.tiers.stats()
    assert ts["cold"]["hits"] >= 1 and ts["cold"]["nbytes"] > 0

    rep = srv.tiers.promote_warm(sid)
    assert rep["rewritten"] >= 1
    assert all("wrap" not in b for b in srv.store._series[sid]["blocks"])
    srv.store._cache.clear()
    w2 = srv.view("a").series("s").window()
    assert np.array_equal(w0.view(np.uint64), w2.view(np.uint64))

    # cold tier survives close/reopen
    srv.tiers.demote_cold(sid)
    srv.close()
    store = CameoStore.open(p)
    got = store.read_window(sid, 0, len(x))
    assert np.array_equal(got.view(np.uint64), w0.view(np.uint64))
    store.close()


def test_tier_pin_and_prefetch(tmp_path):
    x = _series(seed=41)
    p = str(tmp_path / "pin.cameo")
    srv = IngestServer(p, CFG, _scfg())
    with srv.session("s") as sess:
        _feed(sess, x)
    sid = "s"
    bis = srv.tiers.prefetch(sid)
    assert bis and srv.store.cache_stats()["entries"] >= len(bis)
    h0 = srv.store.cache_stats()["hits"]
    srv.series("s").window(0, W)
    assert srv.store.cache_stats()["hits"] > h0   # served hot

    pinned = srv.tiers.pin(sid, 0, 2 * W)
    assert srv.store.cache_stats()["pinned"] == len(pinned)
    cache = srv.store._cache
    assert all((sid, bi) in cache._pinned for bi in pinned)
    # pinned entries survive an eviction storm
    cache.budget = 1
    cache._evict()
    assert all((sid, bi) in cache._d for bi in pinned)
    srv.tiers.unpin(sid)
    assert srv.store.cache_stats()["pinned"] == 0
    cache._evict()
    assert not cache._d                      # now evictable
    srv.close()


# ---------------------------------------------------------------------------
# admission, quotas, catalog
# ---------------------------------------------------------------------------

def test_backpressure_reject_and_slots(tmp_path):
    p = str(tmp_path / "bp.cameo")
    srv = IngestServer(p, CFG, _scfg(max_sessions=1,
                                     backpressure="reject"))
    s1 = srv.session("a")
    with pytest.raises(ServerBusy):
        srv.session("b")
    s1.push(_series(n=256, seed=1))
    s1.close()                                # slot freed
    with srv.session("b") as s2:
        s2.push(_series(n=256, seed=2))
    srv.close()

    p2 = str(tmp_path / "bp2.cameo")
    srv = IngestServer(p2, CFG, _scfg(max_sessions=4))
    s3 = srv.session("c")
    with pytest.raises(ValueError, match="already has an open session"):
        srv.session("c")                      # dup releases its slot
    s3.push(_series(n=128, seed=8))
    s3.close()
    for name in ("d", "e", "f", "g"):         # all 4 slots reusable
        s = srv.session(name)
        s.push(_series(n=128, seed=8))
        s.close()
    srv.close()


def test_quota_refused_before_ack(tmp_path):
    p = str(tmp_path / "q.cameo")
    srv = IngestServer(p, CFG, _scfg())
    srv.register_tenant("a", max_points=500)
    sess = srv.session("s", tenant="a")
    sess.push(_series(n=400, seed=1))
    n0 = sess.n_seen
    with pytest.raises(QuotaExceeded):
        sess.push(_series(n=200, seed=2))
    assert sess.n_seen == n0                  # refused before journal/ack
    sess.push(_series(n=100, seed=3))         # exactly to the cap is fine
    sess.close()
    with pytest.raises(QuotaExceeded):
        srv.write("s2", _series(n=10, seed=4), tenant="a")
    assert "s2" not in srv.view("a")
    srv.close()


def test_view_ingest_routes_through_server(tmp_path):
    """``view()`` hands out a :class:`ServerView`: its ingest methods go
    back through the server, so a view write cannot bypass the lock or
    the ``max_points`` quota, and ``view().stream()`` takes a real
    admission slot."""
    p = str(tmp_path / "vw.cameo")
    srv = IngestServer(p, CFG, _scfg(max_sessions=1,
                                     backpressure="reject"))
    srv.register_tenant("a", max_points=100)
    v = srv.view("a")
    with pytest.raises(QuotaExceeded):
        v.write("s", _series(n=10_000, seed=1))
    assert "s" not in v
    with pytest.raises(QuotaExceeded):
        v.write_batch({"s": _series(n=64, seed=1),
                       "u": _series(n=64, seed=2)})
    assert srv.catalog.usage("a")["points"] == 0

    sess = v.stream("s")                      # a full ServerSession
    with pytest.raises(ServerBusy):
        srv.session("other")                  # the view's stream holds
    with pytest.raises(QuotaExceeded):        # the only slot
        sess.push(_series(n=101, seed=3))
    sess.push(_series(n=100, seed=3))
    sess.close()
    assert srv.catalog.usage("a")["points"] == 100
    srv.close()


def test_reregister_merges_tenant_config(tmp_path):
    """Re-registering updates only the kwargs that were passed — an eps
    refresh must not silently drop an existing quota (or vice versa)."""
    p = str(tmp_path / "rr.cameo")
    srv = IngestServer(p, CFG, _scfg())
    srv.register_tenant("a", eps=5e-2, max_points=1000)
    srv.register_tenant("a", eps=8e-2)
    assert srv.catalog.config("a") == {"eps": 8e-2, "max_points": 1000}
    srv.register_tenant("a", max_points=500)
    assert srv.catalog.config("a") == {"eps": 8e-2, "max_points": 500}
    srv.close()


def test_failed_close_releases_admission_slot(tmp_path):
    """A failed writer finalize must still free the admission slot, and
    the close must stay retryable without double-releasing the bounded
    semaphore."""
    p = str(tmp_path / "fc.cameo")
    srv = IngestServer(p, CFG, _scfg(max_sessions=1,
                                     backpressure="reject"))
    sess = srv.session("s")
    sess.push(_series(n=256, seed=1))
    orig, boom = sess._w.close, {"armed": True}

    def flaky_close():
        if boom.pop("armed", None):
            raise RuntimeError("finalize failed")
        return orig()

    sess._w.close = flaky_close
    with pytest.raises(RuntimeError, match="finalize failed"):
        sess.close()
    assert not sess.closed                    # still retryable
    with srv.session("other") as s2:          # the slot was freed anyway
        s2.push(_series(n=128, seed=2))
    sess.close()                              # retry: no double release
    assert sess.closed
    srv.close()


def test_tenant_catalog_persists_and_eps_applies(tmp_path):
    p = str(tmp_path / "cat.cameo")
    srv = IngestServer(p, CFG, _scfg())
    srv.register_tenant("loose", eps=8e-2, max_points=10 ** 6)
    with srv.session("s", tenant="loose") as sess:
        sess.push(_series(n=512, seed=5))
    assert srv.store.series_meta("loose/s")["eps"] == pytest.approx(8e-2)
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.session("s", tenant="ghost")
    with pytest.raises(ValueError, match="must not contain"):
        srv.register_tenant("a/b")
    srv.close()

    srv2 = IngestServer(p, CFG, _scfg(), resume=True)
    assert srv2.catalog.tenants() == ["loose"]
    assert srv2.catalog.config("loose") == {"eps": 8e-2,
                                            "max_points": 10 ** 6}
    u = srv2.catalog.usage("loose")
    assert u["series"] == 1 and u["points"] == 512
    srv2.close()


def test_default_tenant_is_legacy_view(tmp_path):
    """Unprefixed sids belong to the default tenant; a plain store footer
    stays byte-identical when no tenant is ever registered."""
    p = str(tmp_path / "d.cameo")
    pr = str(tmp_path / "dr.cameo")
    x = _series(n=512, seed=6)
    srv = IngestServer(p, CFG, _scfg())
    with srv.session("s") as sess:
        _feed(sess, x)
    srv.close()
    # a raw dataset run with the same knobs writes the same file
    import repro.api as cameo
    with cameo.open(pr, CFG, mode="w", block_len=BLK,
                    stream_window=W) as ds:
        with ds.stream("s", block_len=SEAL) as w:
            _feed(w, x)
    assert open(p, "rb").read() == open(pr, "rb").read()

    srv = IngestServer(p, CFG, _scfg(), resume=True)
    srv.register_tenant("a")
    srv.write("s", x, tenant="a")
    assert srv.catalog.series_of(DEFAULT_TENANT) == ["s"]
    assert srv.catalog.series_of("a") == ["s"]
    assert sorted(srv.store.series_ids()) == ["a/s", "s"]
    srv.close()


# ---------------------------------------------------------------------------
# /metrics
# ---------------------------------------------------------------------------

def test_metrics_endpoint_serves_labeled_exposition(tmp_path):
    import repro.obs as obs
    from repro.obs import OBS
    was = obs.enabled()
    sinks = list(OBS._sinks)
    obs.reset()
    obs.enable()
    try:
        p = str(tmp_path / "m.cameo")
        srv = IngestServer(p, CFG, _scfg())
        srv.register_tenant("acme")
        with srv.session("s", tenant="acme") as sess:
            sess.push(_series(n=256, seed=7))
        txt = srv.metrics_text()
        assert "# TYPE cameo_server_tenant_points counter" in txt
        assert ('cameo_server_tenant_points_total{tenant="acme"} 256'
                in txt)
        assert "cameo_server_pushes_total 1" in txt

        app = srv.metrics_app()
        seen = {}

        def start_response(status, headers):
            seen["status"] = status
            seen["headers"] = dict(headers)

        body = b"".join(app({"PATH_INFO": "/metrics"}, start_response))
        assert seen["status"].startswith("200")
        assert seen["headers"]["Content-Type"].startswith("text/plain")
        assert body.decode() == srv.metrics_text()
        b404 = b"".join(app({"PATH_INFO": "/other"}, start_response))
        assert seen["status"].startswith("404") and b404
        srv.close()
    finally:
        OBS._sinks[:] = sinks
        obs.reset()
        OBS.enabled = was
