"""Sharding rules: divisibility guard, rule tables, data pipeline."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.data.pipeline import SeriesTokenizer, forecast_batches, series_windows
from repro.data.synthetic import DATASETS, dataset_cameo_kwargs, make_dataset


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_divisibility_guard():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = shd.default_rules()
    # 9 heads don't divide 16 -> replicated; 32 do -> sharded
    assert shd.spec_for((576, 9, 64), ("fsdp", "tp", None), mesh, rules) == \
        P("data", None, None)
    assert shd.spec_for((5120, 32, 160), ("fsdp", "tp", None), mesh, rules) \
        == P("data", "model", None)


def test_multi_pod_batch_axes():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = shd.default_rules(multi_pod=True)
    assert shd.spec_for((256, 4096), ("act_batch", "act_seq"), mesh, rules) \
        == P(("pod", "data"), None)
    # batch=1 cannot shard
    assert shd.spec_for((1, 4096), ("act_batch", "act_seq"), mesh, rules) \
        == P(None, None)


def test_constrain_noop_outside_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "act_batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_datasets_registry():
    for name, spec in DATASETS.items():
        x = make_dataset(name, seed=0, length=min(spec.length, 20000))
        assert np.isfinite(x).all(), name
        assert len(x) > 100
        kw = dataset_cameo_kwargs(name)
        assert kw["lags"] >= 1 and kw["kappa"] >= 1
        if spec.kappa > 1:
            assert len(x) % spec.kappa == 0 or True  # registry lengths divide


def test_dataset_determinism():
    a = make_dataset("uk_elec", seed=3, length=5000)
    b = make_dataset("uk_elec", seed=3, length=5000)
    np.testing.assert_array_equal(a, b)
    c = make_dataset("uk_elec", seed=4, length=5000)
    assert np.abs(a - c).max() > 0


def test_solar_has_repeated_zeros():
    x = make_dataset("solar", seed=0, length=28800)
    frac_same = np.mean(np.diff(x) == 0)
    assert frac_same > 0.3  # night plateaus (paper: 75% p_=)


def test_series_tokenizer_roundtrip():
    x = make_dataset("min_temp", seed=0, length=2000)
    tok = SeriesTokenizer.fit(x, vocab=1024)
    enc = tok.encode(x)
    dec = tok.decode(enc)
    rng = x.max() - x.min()
    assert np.max(np.abs(dec - x)) <= rng / 1023 + 1e-9
    w = series_windows(enc, window=64, stride=32)
    assert w.shape[1] == 64
    b1 = forecast_batches(w, 8, step=5)
    b2 = forecast_batches(w, 8, step=5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
