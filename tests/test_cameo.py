"""CAMEO compression: hard-guarantee semantics, both modes, all variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import measures
from repro.core.acf import acf, aggregate_series, pacf_from_acf
from repro.core.cameo import (CameoConfig, compress, compression_ratio,
                              decompress, kept_points)


def _series(n, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return jnp.asarray(np.sin(2 * np.pi * t / 24)
                       + 0.5 * np.sin(2 * np.pi * t / 168)
                       + 0.15 * rng.standard_normal(n))


def _true_deviation(x, res, cfg):
    idx, vals = kept_points(res)
    recon = decompress(idx, vals, x.shape[0])
    y0 = aggregate_series(x, cfg.kappa)
    y1 = aggregate_series(recon, cfg.kappa)
    s0, s1 = acf(y0, cfg.lags), acf(y1, cfg.lags)
    if cfg.stat == "pacf":
        s0, s1 = pacf_from_acf(s0), pacf_from_acf(s1)
    return float(measures.get_measure(cfg.measure)(s1, s0))


@pytest.mark.parametrize("mode", ["rounds", "sequential"])
def test_eps_guarantee_and_exact_reporting(mode):
    x = _series(512)
    cfg = CameoConfig(eps=0.02, lags=12, mode=mode, dtype="float64")
    res = compress(x, cfg)
    assert float(res.deviation) <= cfg.eps + 1e-12
    # the reported deviation is exact w.r.t. the true reconstruction
    assert abs(_true_deviation(x, res, cfg) - float(res.deviation)) < 1e-8
    assert compression_ratio(res) > 1.5


def test_kept_points_bit_exact():
    x = _series(256, seed=2)
    cfg = CameoConfig(eps=0.05, lags=8, dtype="float64")
    res = compress(x, cfg)
    kept = np.asarray(res.kept)
    np.testing.assert_array_equal(np.asarray(res.xr)[kept],
                                  np.asarray(x)[kept])
    # endpoints always kept
    assert kept[0] and kept[-1]


def test_eps_zero_removes_almost_nothing():
    x = _series(256, seed=3)
    cfg = CameoConfig(eps=0.0, lags=8, dtype="float64")
    res = compress(x, cfg)
    assert compression_ratio(res) < 1.2


def test_monotone_in_eps():
    x = _series(512, seed=4)
    crs = []
    for eps in [1e-3, 1e-2, 5e-2]:
        res = compress(x, CameoConfig(eps=eps, lags=12, dtype="float64"))
        crs.append(compression_ratio(res))
    assert crs[0] <= crs[1] + 0.5 and crs[1] <= crs[2] + 0.5


def test_kappa_aggregates_variant():
    x = _series(512, seed=5)
    cfg = CameoConfig(eps=0.02, lags=8, kappa=8, dtype="float64")
    res = compress(x, cfg)
    assert float(res.deviation) <= cfg.eps + 1e-12
    assert abs(_true_deviation(x, res, cfg) - float(res.deviation)) < 1e-8


def test_pacf_variant():
    x = _series(512, seed=6)
    cfg = CameoConfig(eps=0.05, lags=8, stat="pacf", dtype="float64")
    res = compress(x, cfg)
    assert float(res.deviation) <= cfg.eps + 1e-12
    assert abs(_true_deviation(x, res, cfg) - float(res.deviation)) < 1e-8


def test_compression_centric_def3():
    x = _series(512, seed=7)
    cfg = CameoConfig(lags=8, target_cr=8.0, dtype="float64")
    res = compress(x, cfg)
    assert compression_ratio(res) >= 7.9


def test_max_cr_halt():
    x = _series(512, seed=8)
    cfg = CameoConfig(eps=1.0, lags=8, max_cr=4.0, dtype="float64")
    res = compress(x, cfg)
    assert compression_ratio(res) <= 4.3


def test_decompress_interpolation():
    idx = [0, 4, 8]
    vals = [0.0, 4.0, 0.0]
    recon = np.asarray(decompress(idx, vals, 9))
    np.testing.assert_allclose(recon, [0, 1, 2, 3, 4, 3, 2, 1, 0])


def test_measures_registry():
    a = jnp.asarray([1.0, 2.0, 3.0])
    b = jnp.asarray([1.5, 2.0, 2.0])
    assert abs(float(measures.mae(a, b)) - 0.5) < 1e-12
    assert abs(float(measures.cheb(a, b)) - 1.0) < 1e-12
    assert float(measures.rmse(a, b)) > 0
    with pytest.raises(ValueError):
        measures.get_measure("nope")
